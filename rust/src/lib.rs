//! # AutoGMap
//!
//! Reproduction of *"AutoGMap: Learning to Map Large-scale Sparse Graphs on
//! Memristive Crossbars"* (Lyu et al., IEEE TNNLS 2023) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! - **L3 (this crate)**: the coordinator — RL training loop, environment,
//!   baselines, Cuthill-McKee reordering, crossbar simulator, CLI.
//! - **L2 (python/compile/model.py)**: the LSTM controller rollout and the
//!   REINFORCE+Adam train step, AOT-lowered to HLO text.
//! - **L1 (python/compile/kernels/)**: Pallas kernels (fused LSTM cell,
//!   blocked crossbar MVM) called from L2.
//!
//! Python never runs at request time: `make artifacts` lowers the L1/L2
//! computations once; the Rust binary loads them through PJRT.
//!
//! ## Training backends
//!
//! Training runs behind the pluggable [`agent::TrainBackend`] trait with
//! two implementations, selected per command via
//! `--backend {native,pjrt,auto}`:
//!
//! - **native** ([`agent::native::NativeBackend`]) — pure Rust, no
//!   artifacts required: sampling rollouts through the
//!   [`agent::lstm`] mirror on a std-thread worker pool, full
//!   backprop-through-time for the L2 controller (fused LSTM gates,
//!   per-step FC heads, log-softmax), the REINFORCE-with-baseline
//!   gradient, and a fused Adam step. Bit-deterministic for a fixed seed
//!   regardless of worker count. Controller shapes come from
//!   [`runtime::Manifest::builtin`] when no artifacts manifest exists.
//! - **pjrt** ([`agent::backend::PjrtBackend`]) — the AOT path above
//!   (two PJRT calls per epoch).
//!
//! `auto` (the default) picks pjrt exactly when `artifacts/manifest.json`
//! is present. The `train-bench` CLI subcommand tracks native training
//! throughput (`BENCH_train.json`) like `serve-bench` does for the engine.
//!
//! ## Serving layer
//!
//! Training produces a mapping scheme; the [`engine`] subsystem turns it
//! into production traffic capacity. A scheme compiles into an
//! [`engine::ExecPlan`]: all-zero tiles elided, duplicate programmings
//! shared in one contiguous f32 **program arena** (per-program offset,
//! extents, compile-time nnz, kernel kind), the tile schedule
//! stable-sorted into disjoint **row bands**, and per-program
//! **density-adaptive kernels** — the dense row-dot kernel, or a compiled
//! CSR-within-tile kernel below a density threshold (retunable at load
//! time via `--dense-threshold`). Plans ship as JSON artifacts (version 3
//! adds the shared row-pattern table and the lane width; versions 1 and 2
//! still load — the loader backfills the pattern table and recomputes the
//! lane alignment). The plan's tiles are distributed over a simulated
//! crossbar [`engine::Fleet`] for latency/energy accounting, and an
//! [`engine::BatchExecutor`] worker pool serves batched MVM requests in
//! two modes — scalar per-request fan-out, or row-band spans sharded
//! across workers *within* a request batch with a multi-RHS kernel (one
//! arena traversal per span per batch).
//!
//! **The hot path is vectorized.** Every dense program body starts on an
//! [`engine::LANE`]-cell arena boundary (padding inserted at compile
//! time), and the kernels unroll 4-wide over *independent accumulation
//! chains only* — four output rows per step in the dense kernel, four
//! requests per step in the multi-RHS kernels, four pipelined gather
//! products folded in program order in the sparse kernel — so f64
//! addition order never changes. Sparse programs with identical
//! column-index signatures (FNV-hashed, exact-compared) share one
//! compiled **row pattern**: one index body serves many programs, private
//! values stay per-program. Every mode is bit-identical to the
//! [`crossbar::CrossbarArray::mvm`] oracle for any worker count and batch
//! size: each output row is produced by one worker in one fixed band
//! order, and the sparse kernel only skips exact-zero products. The
//! `serve-bench` CLI subcommand replays synthetic request traces against
//! the engine (named datasets or `--dataset rmat` synthetic graphs) and
//! records the scalar baseline, the single-thread vectorized kernels, the
//! optimized executor throughput, and a per-kernel roofline breakdown
//! (dense/sparse nnz/s, arena bytes, pattern-dedup hit rate) side by side
//! in `BENCH_engine.json` (`--assert-speedup` turns the vectorized-vs-
//! scalar comparison into a CI regression gate).
//!
//! ## Large-scale mapping
//!
//! The [`mapper`] subsystem scales the method past the controller's
//! native grid (the paper tops out at qh1484): RCM-reorder, slice the
//! banded matrix into overlapping controller-sized windows, run
//! trained-controller inference per *unique* window occupancy signature
//! in parallel (scheme cache — repeated sparsity patterns are mapped
//! once), stitch the per-window schemes into a validated
//! [`scheme::CompositeScheme`] with off-window nnz accounted as digital
//! spill, compile each window to an [`engine::ExecPlan`], and merge the
//! plans into one fleet-servable schedule. The `map-large` CLI subcommand
//! drives a 100k-node R-MAT graph end-to-end and emits
//! `BENCH_mapper.json`.
//!
//! ## API tour: build → save → load → serve
//!
//! The [`api`] facade is the front door over all of the above. Flat plans
//! and composites implement one [`engine::Servable`] trait, one generic
//! [`engine::BatchExecutor`] serves both, and a deployment moves through a
//! single self-contained bundle file:
//!
//! ```no_run
//! use autogmap::api::{Deployment, DeploymentBuilder, Source, Strategy};
//! # fn main() -> autogmap::api::Result<()> {
//! let dep = DeploymentBuilder::new(
//!     Source::Rmat { nodes: 10_000, degree: 8, seed: 42 },
//!     Strategy::Hierarchical { controller: "qh882_dyn4".into(), overlap: 4 },
//! ).build()?;                                               // map + compile once
//! dep.save(std::path::Path::new("bundle.json"))?;           // pay the cost once
//! let served = Deployment::load(std::path::Path::new("bundle.json"))?; // pure load
//! let y = served.mvm(&vec![1.0; 10_000])?;                  // exact, original ids
//! # let _ = y; Ok(()) }
//! ```
//!
//! The `deploy` CLI subcommand is `build()` + `save()`; the long-running
//! `serve` subcommand wraps [`api::serve_loop`] around a loaded bundle —
//! NDJSON requests on stdin, responses plus periodic throughput stats on
//! stdout. Constructing `BatchExecutor`s by hand (or the removed
//! `CompositeExecutor` alias) is the deprecated path: new code should go
//! through [`api::Deployment::executor`], which keeps the permutation,
//! fleet, and provenance attached.
//!
//! ## Network serving
//!
//! The [`net`] subsystem scales the serving story from one bundle on
//! stdin to many bundles behind a socket: a
//! [`net::DeploymentRegistry`] holds N loaded deployments on one shared
//! worker pool, and a [`net::NetServer`] speaks the same NDJSON dialect
//! over TCP, routing each request by its `"tenant"` deployment id. Per
//! tenant it adds bounded admission (typed `busy` rejections at the
//! queue-depth limit), optional pre-execution deadlines (typed
//! `deadline` rejections), live stats (`{"admin":"stats"}`), and
//! zero-downtime bundle hot-swap
//! (`{"admin":{"reload":{"id","bundle"}}}` — an atomic `Arc` swap;
//! in-flight requests finish on the old plan). Socket answers stay
//! bit-identical to [`api::Deployment::mvm`] per tenant, under
//! concurrency and across a mid-stream swap; the `serve-net` CLI
//! subcommand exposes it, and `serve-net --bench` self-checks that
//! invariant under concurrent load (the CI `net-smoke` gate). Both
//! transports share one request-dispatch core ([`api::dispatch`]), so
//! error objects are byte-identical on stdin and socket.
//!
//! ## Graph algorithms
//!
//! The [`algo`] subsystem turns a mapped matrix from a `y = Ax` answerer
//! into an asset amortized across whole algorithms — the GraphR-style
//! iterated-SpMV formulations of **PageRank** (damped power iteration,
//! L1-residual convergence), **BFS** and **SSSP** (boolean and min–plus
//! semirings applied in the digital post-step; the programmed arena is
//! untouched), and the **multi-layer GCN forward** (one multi-RHS batch
//! per layer through the span kernel, dense weight GEMM + ReLU between
//! layers). Algorithms run over any [`engine::Servable`] via the
//! [`algo::MvmEngine`] adapters, report an [`algo::AlgoTrace`]
//! (iterations, residual curve, amortized nnz/s), and are served
//! end-to-end: the request kinds `{"pagerank":{...}}`, `{"bfs":{...}}`,
//! `{"sssp":{...}}`, `{"gcn":{...}}` are answered identically by the
//! stdin `serve` loop and the TCP tier (typed `no_converge` errors
//! included), per-algorithm counters surface in both stats surfaces, and
//! the `algo-bench` CLI subcommand ledgers iterations/s and amortized
//! nnz/s per algorithm on flat vs composite plans in `BENCH_algo.json`.
//! BFS/SSSP answers are bit-identical to queue-based references;
//! PageRank/GCN match dense CSR oracles within 1e-5 at identical
//! iteration counts (`tests/integration_algo.rs`).
//!
//! ## Fault tolerance
//!
//! The [`fault`] subsystem accepts that the programmed arena is an
//! *imperfect analog substrate* and makes the serving stack survive it:
//! a deterministic, seedable device-fault model ([`fault::FaultKind`] —
//! stuck-at-zero / stuck-at-one cells, per-bank conductance drift,
//! whole-bank outage) injected at the fleet/bank level so faults corrupt
//! exactly the programs mapped to the afflicted bank; ABFT column
//! checksums folded at arm time and verified against every served MVM
//! (one extra dot per request), plus a periodic known-vector scrub probe
//! per bank; and a self-healing repair loop — detected corruption is
//! localized by bit-diff against the healthy image, the afflicted rows
//! are quarantined onto a digital CSR fallback (answers stay
//! **bit-identical to the host oracle while degraded**, and responses
//! carry `"degraded": true` on both transports), and repair re-programs
//! the healthy image onto surviving banks behind an atomic
//! generation-numbered epoch swap ([`fault::FaultHarness::repair`],
//! `{"admin":{"repair":{"id"}}}` on the wire). Health counters ride
//! along in every [`engine::ServeStats`]. When no fault has been
//! injected, an armed harness serves bit-identically to the unarmed
//! path. The `fault-bench` chaos harness injects mid-stream under
//! concurrent TCP clients, oracle-checks every response (zero wrong
//! answers may escape), and ledgers detection latency, repair latency,
//! and degraded throughput into `BENCH_fault.json` (the CI `fault-smoke`
//! gate).
//!
//! ## Dynamic graphs
//!
//! The [`delta`] subsystem lets a *live* deployment accept edge inserts,
//! deletes, and reweights without remapping from scratch — the missing
//! piece between the paper's static mapping pipeline and a serving
//! system whose graph changes under it. A [`delta::DeltaEngine`]
//! attaches to any [`api::Deployment`] and layers an exact digital
//! overlay (same shape as the composite spill path) over the programmed
//! arena: every MVM answers `y = (A ± Δ)x` bit-identically to a host-CSR
//! oracle of the *mutated* graph while the arena itself stays untouched.
//! When the overlay grows stale, [`delta::DeltaEngine::remap`] folds it
//! back into crossbar form *incrementally*: the graph is re-windowed,
//! but only delta-touched windows rerun controller inference — the
//! engine's persistent [`mapper::cache::SchemeCache`] serves every
//! untouched window by construction — and the new plan swaps in behind a
//! generation number while queries keep serving (updates landing
//! mid-remap are replayed onto the new base, never lost). The wire
//! surface is identical on the stdin `serve` loop and the TCP tier:
//! `{"update":{"edges":[[r,c,w],...]}}` lines (weight 0 deletes),
//! `{"admin":{"remap":..}}`, `--remap-after N` auto-folding, and delta
//! counters in every stats object. The `delta-bench` CLI subcommand
//! races concurrent updaters against queriers on a 10k-node R-MAT graph,
//! checks every answer against a mutating oracle, and ledgers update/s,
//! query/s, and incremental-vs-full remap latency into `BENCH_delta.json`
//! (the CI `delta-smoke` gate asserts zero mismatches and an incremental
//! speedup). Random interleaved update/query/remap streams are
//! propchecked bit-exact in `tests/integration_delta.rs`.

pub mod agent;
pub mod algo;
pub mod api;
pub mod baselines;
pub mod coordinator;
pub mod crossbar;
pub mod delta;
pub mod engine;
pub mod fault;
pub mod graph;
pub mod mapper;
pub mod net;
pub mod reorder;
pub mod runtime;
pub mod scheme;
pub mod util;
pub mod viz;
