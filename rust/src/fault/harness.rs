//! The fault harness: healthy-image snapshot, epoch state machine, ABFT
//! verification, scrub probes, quarantine, and repair. See the module doc
//! of [`crate::fault`] for the lifecycle; this file is the mechanism.

use super::{FaultKind, FaultOptions, FaultSpec};
use crate::api::deploy::{DeployedPlan, Deployment};
use crate::api::error::{Error, Result};
use crate::engine::{AssignPolicy, BatchExecutor, ExecPlan, FaultHealth, Fleet};
use crate::graph::{Coo, Csr};
use crate::util::rng::Pcg64;
use std::collections::{BTreeSet, HashMap};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

/// One generation of fault state. Epochs are immutable once installed;
/// every state transition (inject, quarantine, repair) builds a fresh
/// epoch and swaps the `Arc` — a serving batch snapshots one epoch and
/// finishes on it, exactly like the net tier's bundle hot-swap.
#[derive(Clone, Debug)]
pub struct FaultEpoch {
    /// monotone generation counter (bumps on inject/detect/repair)
    pub generation: u64,
    /// the plan this epoch serves: the healthy image, or a corrupted
    /// clone of it after an injection
    pub plan: Arc<DeployedPlan>,
    /// rows answered by the digital reference instead of the arena
    pub quarantined_rows: Vec<bool>,
    /// count of `true` entries in `quarantined_rows`
    pub quarantined_row_count: usize,
    /// programs whose arena bytes differ from the healthy image *and*
    /// have been detected (localized) — injection alone leaves this unchanged
    pub quarantined_programs: BTreeSet<usize>,
    /// banks retired from the assignment (stay retired across repair)
    pub failed_banks: Vec<bool>,
    /// arena cells currently differing from the healthy image
    pub faulty_cells: u64,
    /// serving in degraded mode (some rows on the digital fallback)
    pub degraded: bool,
}

/// What one [`FaultHarness::inject`] did. `cells_changed` and `programs`
/// are *cumulative*: the total corruption currently present relative to
/// the healthy image (a second injection reports the union), which is the
/// ground truth a chaos harness checks detection coverage against.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct InjectReport {
    /// epoch generation after the injection
    pub generation: u64,
    /// arena cells whose bits differ from the healthy image
    pub cells_changed: u64,
    /// programs containing at least one differing cell
    pub programs: Vec<usize>,
}

/// The armed fault-tolerance state attached to a
/// [`crate::api::Deployment`]: the healthy program image, the digital
/// reference matrix and its column checksums, per-bank probe references,
/// the live tile→bank assignment, the current [`FaultEpoch`], and the
/// lifecycle counters. All serving-path state is lock-light: one `RwLock`
/// read per batch for the epoch snapshot; injection, localization, and
/// repair serialize on `inject_lock`.
#[derive(Debug)]
pub struct FaultHarness {
    dim: usize,
    banks: usize,
    policy: AssignPolicy,
    /// the healthy plan — the image repair swaps back in (pointer-equal
    /// to the deployment's own plan, which keeps the fast path fast)
    healthy: Arc<DeployedPlan>,
    /// full served-order matrix rebuilt from the healthy arena (f32 cells
    /// widened exactly to f64) plus the composite's digital spill: both
    /// the quarantine fallback and the chaos harness's host-CSR oracle
    reference: Csr,
    /// ABFT column checksums of `reference`: `cs_j = Σ_i A_ij`
    col_checksum: Vec<f64>,
    /// live tile→bank assignment (repair re-derives it excluding failed
    /// banks)
    assignment: RwLock<Vec<usize>>,
    /// the fixed known vector scrub probes push through every bank
    probe: Vec<f64>,
    /// healthy per-bank probe outputs under the current assignment
    probe_ref: RwLock<Vec<Vec<f64>>>,
    epoch: RwLock<Arc<FaultEpoch>>,
    opts: FaultOptions,
    /// serializes inject / localize / repair (state transitions)
    inject_lock: Mutex<()>,
    served: AtomicU64,
    verify_checks: AtomicU64,
    verify_detections: AtomicU64,
    scrubs: AtomicU64,
    scrub_detections: AtomicU64,
    repairs: AtomicU64,
    degraded_served: AtomicU64,
    /// test hook: panic on the next served batch (exercises the typed
    /// `internal` error boundary without a real bug)
    poison_next: AtomicBool,
}

impl FaultHarness {
    /// Snapshot `plan` as the healthy image and precompute the detection
    /// state (reference matrix, column checksums, per-bank probe
    /// references under `fleet`'s assignment).
    pub fn new(plan: Arc<DeployedPlan>, fleet: &Fleet, opts: FaultOptions) -> FaultHarness {
        let dim = plan.exec_plan().dim;
        let exec = plan.exec_plan();
        let mut coo = Coo::new(dim, dim);
        for t in &exec.tiles {
            let prog = exec.program(t.program);
            for r in 0..t.rows {
                for c in 0..t.cols {
                    let v = prog[r * t.cols + c];
                    if v != 0.0 {
                        coo.push(t.row0 + r, t.col0 + c, v as f64);
                    }
                }
            }
        }
        if let DeployedPlan::Composite(cp) = &*plan {
            for r in 0..cp.spill.rows {
                for (i, &c) in cp.spill.row(r).iter().enumerate() {
                    coo.push(r, c, cp.spill.row_vals(r)[i]);
                }
            }
        }
        let reference = coo.to_csr();
        let mut col_checksum = vec![0.0f64; dim];
        for r in 0..dim {
            for (i, &c) in reference.row(r).iter().enumerate() {
                col_checksum[c] += reference.row_vals(r)[i];
            }
        }
        let mut rng = Pcg64::new(0x7363_7275_6270_726f, 0x6265); // "scrubpro","be"
        let probe: Vec<f64> = (0..dim).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let harness = FaultHarness {
            dim,
            banks: fleet.banks,
            policy: fleet.policy,
            epoch: RwLock::new(Arc::new(FaultEpoch {
                generation: 0,
                plan: plan.clone(),
                quarantined_rows: vec![false; dim],
                quarantined_row_count: 0,
                quarantined_programs: BTreeSet::new(),
                failed_banks: vec![false; fleet.banks],
                faulty_cells: 0,
                degraded: false,
            })),
            healthy: plan,
            reference,
            col_checksum,
            assignment: RwLock::new(fleet.assignment.clone()),
            probe,
            probe_ref: RwLock::new(Vec::new()),
            opts,
            inject_lock: Mutex::new(()),
            served: AtomicU64::new(0),
            verify_checks: AtomicU64::new(0),
            verify_detections: AtomicU64::new(0),
            scrubs: AtomicU64::new(0),
            scrub_detections: AtomicU64::new(0),
            repairs: AtomicU64::new(0),
            degraded_served: AtomicU64::new(0),
            poison_next: AtomicBool::new(false),
        };
        let refs = {
            let a = harness.assignment.read().unwrap();
            (0..harness.banks)
                .map(|b| harness.bank_probe(harness.healthy.exec_plan(), &a, b))
                .collect()
        };
        *harness.probe_ref.write().unwrap() = refs;
        harness
    }

    /// The current epoch (a consistent snapshot — batches finish on the
    /// epoch they started with).
    pub fn current_epoch(&self) -> Arc<FaultEpoch> {
        self.epoch.read().unwrap().clone()
    }

    /// Current epoch generation.
    pub fn generation(&self) -> u64 {
        self.current_epoch().generation
    }

    /// The digital reference matrix (served order) — the host-CSR oracle.
    pub fn reference(&self) -> &Csr {
        &self.reference
    }

    /// One exact MVM (served order) through the digital reference.
    pub fn reference_mvm(&self, x: &[f64]) -> Vec<f64> {
        self.reference.spmv(x)
    }

    /// Live tile→bank assignment (changes on repair).
    pub fn assignment(&self) -> Vec<usize> {
        self.assignment.read().unwrap().clone()
    }

    /// Harness configuration.
    pub fn options(&self) -> &FaultOptions {
        &self.opts
    }

    /// Live health counters (the `health` block of
    /// [`crate::engine::ServeStats`]).
    pub fn health(&self) -> FaultHealth {
        let e = self.current_epoch();
        FaultHealth {
            armed: true,
            degraded: e.degraded,
            faulty_cells: e.faulty_cells,
            quarantined_programs: e.quarantined_programs.len(),
            quarantined_rows: e.quarantined_row_count,
            failed_banks: e.failed_banks.iter().filter(|b| **b).count(),
            verify_checks: self.verify_checks.load(Ordering::Relaxed),
            verify_detections: self.verify_detections.load(Ordering::Relaxed),
            scrubs: self.scrubs.load(Ordering::Relaxed),
            scrub_detections: self.scrub_detections.load(Ordering::Relaxed),
            repairs: self.repairs.load(Ordering::Relaxed),
            degraded_served: self.degraded_served.load(Ordering::Relaxed),
            generation: e.generation,
        }
    }

    /// Mark the next served batch to panic inside the execution path — a
    /// deterministic stand-in for a poisoned request, caught at the
    /// request boundary and answered as a typed `internal` error.
    pub fn poison_next_request(&self) {
        self.poison_next.store(true, Ordering::SeqCst);
    }

    // ---- inject ---------------------------------------------------------

    /// Corrupt the programs currently mapped to `spec.bank` per the fault
    /// model, deterministically in `spec.seed`. Installs a new epoch
    /// carrying the corrupted plan; quarantine state is deliberately left
    /// unchanged — injection is silent, detection has to find it.
    pub fn inject(&self, spec: &FaultSpec) -> Result<InjectReport> {
        let _g = self.inject_lock.lock().unwrap();
        if spec.bank >= self.banks {
            return Err(Error::Validate(format!(
                "fault bank {} out of range (fleet has {} banks)",
                spec.bank, self.banks
            )));
        }
        let cur = self.current_epoch();
        let assignment = self.assignment.read().unwrap().clone();
        let targets: Vec<usize> = {
            let tiles = &cur.plan.exec_plan().tiles;
            let mut set = BTreeSet::new();
            for (i, t) in tiles.iter().enumerate() {
                if assignment[i] == spec.bank {
                    set.insert(t.program);
                }
            }
            set.into_iter().collect()
        };
        if targets.is_empty() {
            return Ok(InjectReport {
                generation: cur.generation,
                cells_changed: cur.faulty_cells,
                programs: cur.quarantined_programs.iter().copied().collect(),
            });
        }
        let mut plan: DeployedPlan = (*cur.plan).clone();
        // stuck-at-one level: the healthy program's max-abs cell (a fully
        // "on" device), 1.0 for an all-zero program
        let stuck: HashMap<usize, f32> = targets
            .iter()
            .map(|&p| {
                let m = self
                    .healthy
                    .exec_plan()
                    .program(p)
                    .iter()
                    .fold(0.0f32, |m, v| m.max(v.abs()));
                (p, if m > 0.0 { m } else { 1.0 })
            })
            .collect();
        let mut rng = Pcg64::new(spec.seed ^ 0x6465_765f_666c_7400, spec.bank as u64); // "dev_flt"
        let kind = spec.kind;
        plan.exec_plan_mut().mutate_program_cells(&targets, |p, _r, _c, v| match kind {
            FaultKind::StuckZero { rate } => {
                if rng.bool(rate) {
                    0.0
                } else {
                    v
                }
            }
            FaultKind::StuckOne { rate } => {
                if rng.bool(rate) {
                    stuck[&p]
                } else {
                    v
                }
            }
            FaultKind::Drift { sigma, ticks } => {
                let mut f = 1.0f64;
                for _ in 0..ticks {
                    f *= 1.0 + sigma * rng.normal();
                }
                if v == 0.0 {
                    v
                } else {
                    (v as f64 * f) as f32
                }
            }
            FaultKind::Outage => 0.0,
        });
        // ground truth by construction: bit-diff against the healthy
        // image (a draw that hit an already-zero cell changed nothing)
        let (cells, progs) = self.diff_programs(plan.exec_plan());
        let next = FaultEpoch {
            generation: cur.generation + 1,
            plan: Arc::new(plan),
            quarantined_rows: cur.quarantined_rows.clone(),
            quarantined_row_count: cur.quarantined_row_count,
            quarantined_programs: cur.quarantined_programs.clone(),
            failed_banks: cur.failed_banks.clone(),
            faulty_cells: cells,
            degraded: cur.degraded,
        };
        let generation = next.generation;
        *self.epoch.write().unwrap() = Arc::new(next);
        Ok(InjectReport {
            generation,
            cells_changed: cells,
            programs: progs.into_iter().collect(),
        })
    }

    // ---- serve + verify -------------------------------------------------

    /// The armed serving path: permute a request batch into served order,
    /// execute it on the current epoch's plan, answer quarantined rows
    /// from the digital reference, verify every output against the ABFT
    /// column checksums (recomputing any tripped answer exactly), permute
    /// back, and run the scrub cadence. Returns the answers in original
    /// node ids plus the degraded flag for this batch.
    ///
    /// On a healthy epoch this is byte-for-byte the unarmed
    /// `execute_permuted` path (same executor, same recycled buffers)
    /// plus one checksum dot per request.
    pub fn serve_permuted(
        &self,
        dep: &Deployment,
        exec: &BatchExecutor<DeployedPlan>,
        xs: Vec<Vec<f64>>,
        sharded: bool,
    ) -> (Vec<Vec<f64>>, bool) {
        if self.poison_next.swap(false, Ordering::SeqCst) {
            panic!("fault harness: request poisoned by poison_next_request");
        }
        let epoch = self.current_epoch();
        let permuted: Vec<Vec<f64>> = xs.iter().map(|x| dep.permute_in(x)).collect();
        let n = permuted.len() as u64;
        let fast = Arc::ptr_eq(&epoch.plan, &self.healthy);
        let tmp;
        let run: &BatchExecutor<DeployedPlan> = if fast {
            exec
        } else {
            // corrupted epoch: a throwaway executor over the epoch's plan
            // on the caller's worker pool (threads shared, buffers not)
            tmp = BatchExecutor::with_pool(epoch.plan.clone(), exec.pool().clone());
            &tmp
        };
        let kept = permuted.clone();
        let mut ys = if sharded {
            run.execute_batch_sharded(permuted)
        } else {
            run.execute_batch(permuted)
        };
        let mut trips = 0u64;
        for (x, y) in kept.iter().zip(ys.iter_mut()) {
            if epoch.quarantined_row_count > 0 {
                for (r, q) in epoch.quarantined_rows.iter().enumerate() {
                    if *q {
                        y[r] = self.row_dot(r, x);
                    }
                }
            }
            if !self.verify(x, y) {
                trips += 1;
                // detected corruption the quarantine does not cover yet:
                // answer this request exactly from the reference
                *y = self.reference.spmv(x);
            }
        }
        let outs: Vec<Vec<f64>> = ys.iter().map(|y| dep.permute_out(y)).collect();
        run.recycle(ys);
        self.verify_checks.fetch_add(n, Ordering::Relaxed);
        if trips > 0 {
            self.verify_detections.fetch_add(trips, Ordering::Relaxed);
            self.localize_and_quarantine();
        }
        let degraded = epoch.degraded || trips > 0;
        if degraded {
            self.degraded_served.fetch_add(n, Ordering::Relaxed);
        }
        let before = self.served.fetch_add(n, Ordering::Relaxed);
        let se = self.opts.scrub_every;
        if se > 0 && before / se != (before + n) / se {
            self.scrub();
        }
        (outs, degraded)
    }

    /// One ABFT verification: `Σ_r y_r` against `Σ_j cs_j·x_j`, tolerance
    /// scaled by the magnitude actually summed.
    fn verify(&self, x: &[f64], y: &[f64]) -> bool {
        let mut pred = 0.0f64;
        let mut scale = 0.0f64;
        for (cs, xv) in self.col_checksum.iter().zip(x) {
            let t = cs * xv;
            pred += t;
            scale += t.abs();
        }
        let mut act = 0.0f64;
        for v in y {
            act += v;
            scale += v.abs();
        }
        (act - pred).abs() <= self.opts.tol_scale * (scale + 1.0)
    }

    /// One exact reference row-dot (bit-identical to [`Csr::spmv`]'s
    /// per-row accumulation) — the digital fallback for quarantined rows.
    fn row_dot(&self, r: usize, x: &[f64]) -> f64 {
        let cols = self.reference.row(r);
        let vals = self.reference.row_vals(r);
        let mut acc = 0.0f64;
        for (&c, &v) in cols.iter().zip(vals.iter()) {
            acc += v * x[c];
        }
        acc
    }

    // ---- detect ---------------------------------------------------------

    /// Push the known probe vector through every surviving bank of the
    /// current epoch's plan and compare against the healthy references
    /// bit-exactly. On a mismatch, localize and quarantine. Returns true
    /// when the scrub found corruption that was not already quarantined.
    pub fn scrub(&self) -> bool {
        self.scrubs.fetch_add(1, Ordering::Relaxed);
        let epoch = self.current_epoch();
        let plan = epoch.plan.exec_plan();
        let assignment = self.assignment.read().unwrap().clone();
        let mismatch = {
            let refs = self.probe_ref.read().unwrap();
            (0..self.banks).any(|b| {
                !epoch.failed_banks[b] && self.bank_probe(plan, &assignment, b) != refs[b]
            })
        };
        if !mismatch {
            return false;
        }
        self.scrub_detections.fetch_add(1, Ordering::Relaxed);
        self.localize_and_quarantine();
        true
    }

    /// Bank `bank`'s contribution to `A·probe` under `assignment`:
    /// per-tile dense row dots in tile order, accumulated into a
    /// dim-length output. Deterministic, so healthy state compares
    /// bit-exactly across calls.
    fn bank_probe(&self, plan: &ExecPlan, assignment: &[usize], bank: usize) -> Vec<f64> {
        let mut out = vec![0.0f64; self.dim];
        for (i, t) in plan.tiles.iter().enumerate() {
            if assignment[i] != bank {
                continue;
            }
            let prog = plan.program(t.program);
            for r in 0..t.rows {
                let mut acc = 0.0f64;
                for c in 0..t.cols {
                    acc += prog[r * t.cols + c] as f64 * self.probe[t.col0 + c];
                }
                out[t.row0 + r] += acc;
            }
        }
        out
    }

    /// Bit-diff a plan's arena against the healthy image: total differing
    /// cells and the set of programs containing any.
    fn diff_programs(&self, plan: &ExecPlan) -> (u64, BTreeSet<usize>) {
        let healthy = self.healthy.exec_plan();
        let mut cells = 0u64;
        let mut progs = BTreeSet::new();
        for p in 0..healthy.num_programs() {
            let diff = healthy
                .program(p)
                .iter()
                .zip(plan.program(p).iter())
                .filter(|(a, b)| a.to_bits() != b.to_bits())
                .count() as u64;
            if diff > 0 {
                cells += diff;
                progs.insert(p);
            }
        }
        (cells, progs)
    }

    /// Localize corruption exactly (arena bit-diff per program), mark
    /// every row of every tile referencing a corrupted program for the
    /// digital fallback, retire the banks those tiles sit on, and install
    /// the degraded epoch. No-op when the diff adds nothing beyond the
    /// current quarantine.
    fn localize_and_quarantine(&self) {
        let _g = self.inject_lock.lock().unwrap();
        let cur = self.current_epoch();
        let (cells, progs) = self.diff_programs(cur.plan.exec_plan());
        if progs.is_empty() {
            return;
        }
        let known = progs.iter().all(|p| cur.quarantined_programs.contains(p));
        if known && cells == cur.faulty_cells && cur.degraded {
            return;
        }
        let plan = cur.plan.exec_plan();
        let mut rows = vec![false; self.dim];
        let mut failed = cur.failed_banks.clone();
        {
            let assignment = self.assignment.read().unwrap();
            for (i, t) in plan.tiles.iter().enumerate() {
                if progs.contains(&t.program) {
                    for q in rows.iter_mut().skip(t.row0).take(t.rows) {
                        *q = true;
                    }
                    failed[assignment[i]] = true;
                }
            }
        }
        let count = rows.iter().filter(|q| **q).count();
        let next = FaultEpoch {
            generation: cur.generation + 1,
            plan: cur.plan.clone(),
            quarantined_rows: rows,
            quarantined_row_count: count,
            quarantined_programs: progs,
            failed_banks: failed,
            faulty_cells: cells,
            degraded: true,
        };
        *self.epoch.write().unwrap() = Arc::new(next);
    }

    // ---- repair ---------------------------------------------------------

    /// Re-program onto healthy banks and swap the healthy image back in:
    /// re-derive the tile→bank assignment excluding every failed bank,
    /// recompute the per-bank probe references, and install a clean epoch
    /// whose plan is pointer-equal to the deployment's own (restoring the
    /// fast path). Failed banks stay retired. Returns the new generation.
    pub fn repair(&self) -> Result<u64> {
        let _g = self.inject_lock.lock().unwrap();
        let cur = self.current_epoch();
        let fleet = Fleet::assign_excluding(
            self.healthy.exec_plan(),
            self.banks,
            self.policy,
            &cur.failed_banks,
        )
        .map_err(|e| Error::Validate(format!("repair: {e:#}")))?;
        let refs: Vec<Vec<f64>> = (0..self.banks)
            .map(|b| self.bank_probe(self.healthy.exec_plan(), &fleet.assignment, b))
            .collect();
        *self.assignment.write().unwrap() = fleet.assignment;
        *self.probe_ref.write().unwrap() = refs;
        let next = FaultEpoch {
            generation: cur.generation + 1,
            plan: self.healthy.clone(),
            quarantined_rows: vec![false; self.dim],
            quarantined_row_count: 0,
            quarantined_programs: BTreeSet::new(),
            failed_banks: cur.failed_banks.clone(),
            faulty_cells: 0,
            degraded: false,
        };
        let generation = next.generation;
        *self.epoch.write().unwrap() = Arc::new(next);
        self.repairs.fetch_add(1, Ordering::Relaxed);
        Ok(generation)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{DeploymentBuilder, Source, Strategy};
    use crate::graph::synth;

    fn armed_deployment(banks: usize) -> Deployment {
        let mut dep = DeploymentBuilder::new(
            Source::Matrix {
                label: "qm7".into(),
                matrix: synth::qm7_like(5828),
            },
            Strategy::FixedBlock { block: 2 },
        )
        .grid(2)
        .banks(banks)
        .workers(2)
        .build()
        .unwrap();
        dep.arm_fault_harness(FaultOptions::default());
        dep
    }

    fn requests(dim: usize, n: usize) -> Vec<Vec<f64>> {
        (0..n)
            .map(|s| (0..dim).map(|i| ((i * 7 + s * 3) % 13) as f64 - 6.0).collect())
            .collect()
    }

    #[test]
    fn reference_matches_the_healthy_plan_oracle() {
        let dep = armed_deployment(2);
        let h = dep.fault_harness().unwrap().clone();
        let dim = dep.provenance.dim;
        for x in requests(dim, 3) {
            let via_plan = dep.mvm(&x).unwrap();
            let via_ref = dep.mvm_oracle(&x).unwrap();
            for (a, b) in via_plan.iter().zip(via_ref.iter()) {
                assert!((a - b).abs() < 1e-9, "{a} vs {b}");
            }
        }
        assert_eq!(h.generation(), 0);
        assert!(!h.health().degraded);
        assert!(h.health().armed);
    }

    #[test]
    fn inject_detect_quarantine_repair_lifecycle() {
        let dep = armed_deployment(2);
        let h = dep.fault_harness().unwrap().clone();
        let dim = dep.provenance.dim;
        let exec = dep.executor(2);
        let xs = requests(dim, 4);
        let want: Vec<Vec<f64>> = xs.iter().map(|x| dep.mvm(x).unwrap()).collect();
        let oracle: Vec<Vec<f64>> = xs.iter().map(|x| dep.mvm_oracle(x).unwrap()).collect();

        // healthy epoch: bit-identical to the plain deployment answer
        let (ys, degraded) = h.serve_permuted(&dep, &exec, xs.clone(), true);
        assert!(!degraded);
        assert_eq!(ys, want);

        // inject a stuck-at-zero burst on bank 0 — silent until served
        let report = h
            .inject(&FaultSpec {
                bank: 0,
                kind: FaultKind::StuckZero { rate: 0.7 },
                seed: 11,
            })
            .unwrap();
        assert!(report.cells_changed > 0, "injection must corrupt cells");
        assert!(!h.current_epoch().degraded, "injection alone must stay silent");

        // first served batch detects, recomputes exactly, quarantines
        let (ys, degraded) = h.serve_permuted(&dep, &exec, xs.clone(), true);
        assert!(degraded);
        for ((y, w), o) in ys.iter().zip(want.iter()).zip(oracle.iter()) {
            for ((a, b), c) in y.iter().zip(w.iter()).zip(o.iter()) {
                assert!(
                    a.to_bits() == b.to_bits() || a.to_bits() == c.to_bits(),
                    "degraded answer must match plan or oracle bit-exactly: {a} vs {b}/{c}"
                );
            }
        }
        let e = h.current_epoch();
        assert!(e.degraded);
        assert_eq!(
            e.quarantined_programs.iter().copied().collect::<Vec<_>>(),
            report.programs,
            "every corrupted program must be detected"
        );
        assert!(h.health().verify_detections > 0);

        // degraded serving stays oracle-exact on quarantined rows
        let (ys, degraded) = h.serve_permuted(&dep, &exec, xs.clone(), false);
        assert!(degraded);
        for ((y, w), o) in ys.iter().zip(want.iter()).zip(oracle.iter()) {
            for ((a, b), c) in y.iter().zip(w.iter()).zip(o.iter()) {
                assert!(a.to_bits() == b.to_bits() || a.to_bits() == c.to_bits());
            }
        }

        // repair: healthy image back, failed bank retired, fast path restored
        let gen = h.repair().unwrap();
        assert!(gen > e.generation);
        let e = h.current_epoch();
        assert!(!e.degraded);
        assert_eq!(e.quarantined_row_count, 0);
        assert!(e.failed_banks.iter().any(|b| *b));
        assert!(h.assignment().iter().all(|&b| !e.failed_banks[b]));
        let (ys, degraded) = h.serve_permuted(&dep, &exec, xs, true);
        assert!(!degraded);
        assert_eq!(ys, want, "post-repair serving must be bit-identical again");
        assert_eq!(h.health().repairs, 1);
    }

    #[test]
    fn scrub_detects_silent_corruption() {
        let dep = armed_deployment(2);
        let h = dep.fault_harness().unwrap().clone();
        h.inject(&FaultSpec {
            bank: 1,
            kind: FaultKind::Outage,
            seed: 3,
        })
        .unwrap();
        // no traffic has exercised the fault; the probe finds it
        assert!(h.scrub(), "scrub must detect the outage");
        let e = h.current_epoch();
        assert!(e.degraded);
        assert!(e.failed_banks.iter().any(|b| *b));
        assert_eq!(h.health().scrub_detections, 1);
        // a second scrub adds nothing new
        assert!(!h.scrub());
        assert_eq!(h.health().scrub_detections, 1);
    }

    #[test]
    fn injecting_every_bank_then_repair_fails_cleanly() {
        let dep = armed_deployment(2);
        let h = dep.fault_harness().unwrap().clone();
        for bank in 0..2 {
            h.inject(&FaultSpec {
                bank,
                kind: FaultKind::Outage,
                seed: bank as u64,
            })
            .unwrap();
            h.scrub();
        }
        let e = h.current_epoch();
        assert!(e.failed_banks.iter().all(|b| *b), "both banks must be retired");
        // nowhere left to re-program: a typed error, not a panic
        let err = h.repair().unwrap_err();
        assert_eq!(err.kind(), "validate");
        // degraded serving still answers exactly from the reference
        let exec = dep.executor(1);
        let xs = requests(dep.provenance.dim, 2);
        let oracle: Vec<Vec<f64>> = xs.iter().map(|x| dep.mvm_oracle(x).unwrap()).collect();
        let (ys, degraded) = h.serve_permuted(&dep, &exec, xs, true);
        assert!(degraded);
        assert_eq!(ys, oracle);
    }

    #[test]
    fn out_of_range_bank_is_a_typed_error() {
        let dep = armed_deployment(2);
        let h = dep.fault_harness().unwrap();
        let err = h
            .inject(&FaultSpec {
                bank: 9,
                kind: FaultKind::Outage,
                seed: 0,
            })
            .unwrap_err();
        assert_eq!(err.kind(), "validate");
        assert!(err.to_string().contains('9'));
    }
}
