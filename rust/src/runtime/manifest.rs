//! Reader for `artifacts/manifest.json`, the ABI contract emitted by
//! `python/compile/aot.py`.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// One controller parameter: name + shape, in ABI order.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One controller configuration (matches `model.ControllerConfig`).
#[derive(Clone, Debug)]
pub struct ControllerEntry {
    pub name: String,
    /// grid cells on the diagonal (N); steps = N-1.
    pub n: usize,
    pub hidden: usize,
    pub fill_classes: usize,
    pub batch: usize,
    pub bilstm: bool,
    pub steps: usize,
    /// ordered parameter ABI
    pub params: Vec<ParamSpec>,
    /// artifact kind -> file name ("rollout" / "greedy" / "train")
    pub artifacts: BTreeMap<String, String>,
}

impl ControllerEntry {
    /// Build an entry — including its ordered parameter ABI — from the
    /// model dimensions alone (mirrors `model.param_spec`). No artifacts
    /// are attached; this is how the native training backend gets a
    /// config when `artifacts/` has never been built.
    pub fn from_dims(
        name: &str,
        n: usize,
        hidden: usize,
        fill_classes: usize,
        batch: usize,
        bilstm: bool,
    ) -> ControllerEntry {
        assert!(n >= 2, "controller needs at least 2 grid cells");
        let t = n - 1;
        let head_in = if bilstm { 2 * hidden } else { hidden };
        let mut params = vec![
            ParamSpec { name: "x0".into(), shape: vec![hidden] },
            ParamSpec { name: "lstm_w".into(), shape: vec![2 * hidden, 4 * hidden] },
            ParamSpec { name: "lstm_b".into(), shape: vec![4 * hidden] },
        ];
        if bilstm {
            params.push(ParamSpec { name: "bwd_emb".into(), shape: vec![t, hidden] });
            params.push(ParamSpec { name: "bwd_w".into(), shape: vec![2 * hidden, 4 * hidden] });
            params.push(ParamSpec { name: "bwd_b".into(), shape: vec![4 * hidden] });
        }
        params.push(ParamSpec { name: "fc_d_w".into(), shape: vec![t, head_in, 2] });
        params.push(ParamSpec { name: "fc_d_b".into(), shape: vec![t, 2] });
        if fill_classes > 0 {
            params.push(ParamSpec {
                name: "fc_f_w".into(),
                shape: vec![t, head_in, fill_classes],
            });
            params.push(ParamSpec { name: "fc_f_b".into(), shape: vec![t, fill_classes] });
        }
        ControllerEntry {
            name: name.to_string(),
            n,
            hidden,
            fill_classes,
            batch,
            bilstm,
            steps: t,
            params,
            artifacts: BTreeMap::new(),
        }
    }

    pub fn total_param_elements(&self) -> usize {
        self.params.iter().map(|p| p.elements()).sum()
    }

    pub fn artifact(&self, kind: &str) -> Result<&str> {
        self.artifacts
            .get(kind)
            .map(|s| s.as_str())
            .with_context(|| format!("config {} has no {kind} artifact", self.name))
    }
}

/// One blocked-MVM geometry.
#[derive(Clone, Debug)]
pub struct MvmEntry {
    pub name: String,
    /// crossbar tile side
    pub k: usize,
    /// max tiles per call
    pub nb: usize,
    /// output row segments
    pub nr: usize,
    pub artifact: String,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub fingerprint: String,
    pub configs: BTreeMap<String, ControllerEntry>,
    pub mvm: BTreeMap<String, MvmEntry>,
}

fn req_usize(v: &Json, key: &str, ctx: &str) -> Result<usize> {
    v.get(key)
        .as_usize()
        .with_context(|| format!("{ctx}: missing/invalid integer field {key:?}"))
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing manifest {}", path.display()))
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let root = Json::parse(text).context("manifest is not valid JSON")?;
        let mut configs = BTreeMap::new();
        let Some(cfg_obj) = root.get("configs").as_obj() else {
            bail!("manifest missing `configs` object");
        };
        for (name, v) in cfg_obj {
            let mut params = Vec::new();
            for p in v.get("params").as_arr().unwrap_or(&[]) {
                let pname = p
                    .get("name")
                    .as_str()
                    .with_context(|| format!("config {name}: param missing name"))?;
                let shape = p
                    .get("shape")
                    .as_arr()
                    .with_context(|| format!("config {name}: param {pname} missing shape"))?
                    .iter()
                    .map(|d| d.as_usize().context("non-integer dim"))
                    .collect::<Result<Vec<_>>>()?;
                params.push(ParamSpec {
                    name: pname.to_string(),
                    shape,
                });
            }
            if params.is_empty() {
                bail!("config {name} has no params");
            }
            let mut artifacts = BTreeMap::new();
            if let Some(arts) = v.get("artifacts").as_obj() {
                for (k, f) in arts {
                    artifacts.insert(
                        k.clone(),
                        f.as_str()
                            .with_context(|| format!("config {name}: bad artifact entry {k}"))?
                            .to_string(),
                    );
                }
            }
            let ctx = format!("config {name}");
            configs.insert(
                name.clone(),
                ControllerEntry {
                    name: name.clone(),
                    n: req_usize(v, "n", &ctx)?,
                    hidden: req_usize(v, "hidden", &ctx)?,
                    fill_classes: req_usize(v, "fill_classes", &ctx)?,
                    batch: req_usize(v, "batch", &ctx)?,
                    bilstm: v.get("bilstm").as_bool().unwrap_or(false),
                    steps: req_usize(v, "steps", &ctx)?,
                    params,
                    artifacts,
                },
            );
        }
        let mut mvm = BTreeMap::new();
        if let Some(mvm_obj) = root.get("mvm").as_obj() {
            for (name, v) in mvm_obj {
                let ctx = format!("mvm {name}");
                mvm.insert(
                    name.clone(),
                    MvmEntry {
                        name: name.clone(),
                        k: req_usize(v, "k", &ctx)?,
                        nb: req_usize(v, "nb", &ctx)?,
                        nr: req_usize(v, "nr", &ctx)?,
                        artifact: v
                            .get("artifact")
                            .as_str()
                            .with_context(|| format!("mvm {name}: missing artifact"))?
                            .to_string(),
                    },
                );
            }
        }
        Ok(Manifest {
            fingerprint: root.get("fingerprint").as_str().unwrap_or("").to_string(),
            configs,
            mvm,
        })
    }

    /// The paper's controller configurations (mirrors aot.py's
    /// `CONTROLLER_CONFIGS`), constructed from dimensions alone. This is
    /// what the native training backend trains against when no
    /// `artifacts/` directory exists; when a real manifest *is* present
    /// its entries take precedence (same shapes, plus artifact files).
    pub fn builtin() -> Manifest {
        let specs: [(&str, usize, usize, usize, usize, bool); 10] = [
            ("qm7_diag", 11, 10, 0, 8, false),
            ("qm7_fill", 11, 10, 2, 8, false),
            ("qm7_fill_bilstm", 11, 10, 2, 8, true),
            ("qm7_dyn4", 11, 10, 4, 8, false),
            ("qm7_dyn6", 11, 10, 6, 8, false),
            ("qm7_dyn4_b32", 11, 10, 4, 32, false),
            ("qh882_dyn4", 28, 10, 4, 8, false),
            ("qh882_dyn6", 28, 10, 6, 8, false),
            ("qh1484_dyn4", 47, 10, 4, 8, false),
            ("qh1484_dyn6", 47, 10, 6, 8, false),
        ];
        let configs = specs
            .iter()
            .map(|&(name, n, hidden, fill, batch, bilstm)| {
                (
                    name.to_string(),
                    ControllerEntry::from_dims(name, n, hidden, fill, batch, bilstm),
                )
            })
            .collect();
        Manifest {
            fingerprint: "builtin".to_string(),
            configs,
            mvm: BTreeMap::new(),
        }
    }

    pub fn config(&self, name: &str) -> Result<&ControllerEntry> {
        self.configs
            .get(name)
            .with_context(|| format!("manifest has no controller config {name:?}"))
    }

    pub fn mvm_entry(&self, name: &str) -> Result<&MvmEntry> {
        self.mvm
            .get(name)
            .with_context(|| format!("manifest has no mvm config {name:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "fingerprint": "abc",
      "configs": {
        "qm7_dyn4": {
          "n": 11, "hidden": 10, "fill_classes": 4, "batch": 8,
          "bilstm": false, "steps": 10,
          "params": [
            {"name": "x0", "shape": [10]},
            {"name": "lstm_w", "shape": [20, 40]}
          ],
          "artifacts": {"rollout": "rollout_qm7_dyn4.hlo.txt"}
        }
      },
      "mvm": {
        "mvm_qm7": {"k": 2, "nb": 128, "nr": 11, "artifact": "mvm_qm7.hlo.txt"}
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let c = m.config("qm7_dyn4").unwrap();
        assert_eq!(c.n, 11);
        assert_eq!(c.params.len(), 2);
        assert_eq!(c.params[1].elements(), 800);
        assert_eq!(c.total_param_elements(), 810);
        assert_eq!(c.artifact("rollout").unwrap(), "rollout_qm7_dyn4.hlo.txt");
        assert!(c.artifact("train").is_err());
        let mv = m.mvm_entry("mvm_qm7").unwrap();
        assert_eq!((mv.k, mv.nb, mv.nr), (2, 128, 11));
        assert!(m.config("nope").is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("not json").is_err());
        assert!(
            Manifest::parse(r#"{"configs": {"x": {"n": 1, "params": []}}}"#).is_err()
        );
    }

    #[test]
    fn builtin_configs_match_model_param_spec() {
        let m = Manifest::builtin();
        // the full aot.py roster exists, with the paper's dimensions
        for name in [
            "qm7_diag", "qm7_fill", "qm7_fill_bilstm", "qm7_dyn4", "qm7_dyn6",
            "qm7_dyn4_b32", "qh882_dyn4", "qh882_dyn6", "qh1484_dyn4", "qh1484_dyn6",
        ] {
            let c = m.config(name).unwrap();
            assert_eq!(c.steps, c.n - 1, "{name}");
            assert_eq!(c.hidden, 10, "{name}");
            assert!(c.artifacts.is_empty(), "{name}: builtin has no artifacts");
        }
        let c = m.config("qh1484_dyn6").unwrap();
        assert_eq!((c.n, c.steps, c.fill_classes), (47, 46, 6));
        // ABI order and shapes mirror model.param_spec
        let d = m.config("qm7_dyn4").unwrap();
        let names: Vec<&str> = d.params.iter().map(|p| p.name.as_str()).collect();
        assert_eq!(names, ["x0", "lstm_w", "lstm_b", "fc_d_w", "fc_d_b", "fc_f_w", "fc_f_b"]);
        assert_eq!(d.params[1].shape, vec![20, 40]);
        assert_eq!(d.params[5].shape, vec![10, 10, 4]);
        let bi = m.config("qm7_fill_bilstm").unwrap();
        let names: Vec<&str> = bi.params.iter().map(|p| p.name.as_str()).collect();
        assert_eq!(
            names,
            ["x0", "lstm_w", "lstm_b", "bwd_emb", "bwd_w", "bwd_b", "fc_d_w", "fc_d_b", "fc_f_w", "fc_f_b"]
        );
        // bilstm heads read [h, hb] -> head_in = 2H
        assert_eq!(bi.params[6].shape, vec![10, 20, 2]);
        assert!(m.config("nope").is_err());
    }

    #[test]
    fn reads_real_manifest_if_present() {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
        if !p.exists() {
            return; // artifacts not built in this checkout
        }
        let m = Manifest::load(&p).unwrap();
        assert!(m.configs.contains_key("qm7_dyn4"));
        assert!(m.configs.contains_key("qh882_dyn6"));
        assert!(m.mvm.contains_key("mvm_qm7"));
        let c = m.config("qh1484_dyn6").unwrap();
        assert_eq!(c.n, 47);
        assert_eq!(c.steps, 46);
        assert_eq!(c.fill_classes, 6);
    }
}
