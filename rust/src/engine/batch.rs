//! Batch executor: MVM request serving against a compiled plan, fanned out
//! over the crate-wide [`crate::util::pool::WorkerPool`] (the same
//! substrate the native trainer uses for rollouts/BPTT — one copy of the
//! queue/condvar machinery, with panic propagation instead of hangs).
//!
//! Numerics stay on the host (the banks of a [`super::fleet::Fleet`] model
//! latency/energy, not arithmetic). Two serving modes, both **bit-identical
//! to the single-threaded scalar loop** (and therefore to the
//! [`crate::crossbar::CrossbarArray::mvm`] oracle) for any worker count and
//! batch size:
//!
//! - [`BatchExecutor::execute_batch`] — the seed mode: each request is
//!   executed by exactly one worker, which walks the plan's tile schedule
//!   in band order. Parallelism is across requests only.
//! - [`BatchExecutor::execute_batch_sharded`] — the optimized mode: the
//!   plan's disjoint row bands are partitioned into nnz-balanced spans
//!   ([`Servable::shard_spans`]), each span goes to one worker, and
//!   that worker serves **every** request's rows for its span with the
//!   multi-RHS kernel ([`Servable::mvm_span_batch`]) — one arena
//!   traversal per span per batch instead of per request. Each output row
//!   is written by exactly one worker in a fixed band order, so results
//!   carry no scheduling nondeterminism.
//!
//! Output buffers are pooled: a worker pops a previously returned buffer
//! (or allocates on a cold pool), fills it in place, and hands it to the
//! caller; callers recycle buffers via [`BatchExecutor::recycle`] so a
//! steady-state serving loop performs no output allocation.

use super::plan::ExecPlan;
use crate::util::pool::WorkerPool;
use std::sync::{Arc, Mutex};

/// Fault-tolerance health counters carried inside [`ServeStats`].
///
/// Plans themselves report the all-zero default (a bare plan has no fault
/// harness); `crate::api::Deployment::stats` overlays the live numbers
/// from its armed [`crate::fault::FaultHarness`], and the net tier's
/// `{"admin":"stats"}` response serializes them so operators can watch the
/// inject → detect → quarantine → repair lifecycle from the wire.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultHealth {
    /// a fault harness is armed on this deployment
    pub armed: bool,
    /// serving in degraded mode (quarantined rows answered digitally)
    pub degraded: bool,
    /// arena cells currently differing from the healthy program image
    pub faulty_cells: u64,
    /// programs quarantined off the crossbar path
    pub quarantined_programs: usize,
    /// output rows served by the exact digital fallback while degraded
    pub quarantined_rows: usize,
    /// banks retired from the assignment after localization
    pub failed_banks: usize,
    /// ABFT checksum verifications performed
    pub verify_checks: u64,
    /// verifications that tripped (corruption detected at serve time)
    pub verify_detections: u64,
    /// periodic scrub probes executed
    pub scrubs: u64,
    /// scrub probes that detected corruption
    pub scrub_detections: u64,
    /// completed repair cycles (re-program + atomic swap back in)
    pub repairs: u64,
    /// responses served while a degraded epoch was current
    pub degraded_served: u64,
    /// fault-epoch generation number (bumps on inject/detect/repair)
    pub generation: u64,
}

/// Program-level serving statistics every [`Servable`] reports — the
/// numbers deployment tooling (bundles, the `serve` loop, bench ledgers)
/// prints without knowing which plan shape it is holding.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServeStats {
    /// matrix dimension D (request/response length)
    pub dim: usize,
    /// placed crossbar tiles in the schedule
    pub tiles: usize,
    /// deduplicated program buffers
    pub programs: usize,
    /// disjoint row bands of the schedule
    pub bands: usize,
    /// programs on the dense row-dot kernel
    pub kernel_dense: usize,
    /// programs on the compiled CSR-within-tile kernel
    pub kernel_sparse: usize,
    /// non-zeros served per MVM through the dense kernel (per-tile sums)
    pub nnz_dense: u64,
    /// non-zeros served per MVM through the sparse kernel (per-tile sums)
    pub nnz_sparse: u64,
    /// deduplicated sparse row patterns (compiled kernel bodies)
    pub patterns: usize,
    /// sparse programs served by a pattern another program interned first
    pub pattern_dedup_hits: usize,
    /// non-zeros served by crossbar tiles
    pub mapped_nnz: u64,
    /// non-zeros served from digital sparse storage (0 for flat plans)
    pub spilled_nnz: u64,
    /// programmed crossbar cells (clipped extents)
    pub area_cells: u64,
    /// fault-tolerance health counters (all-zero unless a harness is armed)
    pub health: FaultHealth,
    /// edge updates applied since deploy (0 unless a delta engine is live)
    pub delta_updates: u64,
    /// overlay entries pending the next remap (0 unless a delta engine is live)
    pub delta_pending: usize,
    /// incremental remaps folded into the plan (0 unless a delta engine is live)
    pub delta_remaps: u64,
}

impl ServeStats {
    /// Total non-zeros one MVM touches (mapped + digital spill).
    pub fn total_nnz(&self) -> u64 {
        self.mapped_nnz + self.spilled_nnz
    }
}

/// The unified serving API: anything a [`BatchExecutor`] (or the
/// `api::Deployment` facade above it) can serve. One trait covers both
/// plan shapes the repo produces — the engine's flat [`ExecPlan`] and the
/// mapper's `CompositePlan` (merged window plans + digital spill) — so
/// there is exactly one executor and one serving code path.
///
/// Contract: `mvm_batch_into`, `mvm_span_batch`, and every executor mode
/// built on them must be **bit-identical** to the scalar [`Self::mvm_into`]
/// loop for any worker count and batch size.
pub trait Servable: Send + Sync + 'static {
    /// Matrix dimension D: request and response vector length.
    fn dim(&self) -> usize;

    /// Scalar MVM into a reusable output buffer (cleared + resized to
    /// `dim()`): the reference serving path every other mode must match
    /// bit for bit.
    fn mvm_into(&self, x: &[f64], y: &mut Vec<f64>);

    /// Allocating convenience wrapper around [`Self::mvm_into`].
    fn mvm(&self, x: &[f64]) -> Vec<f64> {
        let mut y = Vec::new();
        self.mvm_into(x, &mut y);
        y
    }

    /// Multi-RHS convenience over the full row range: `ys` is cleared and
    /// resized to match `xs`; each `ys[b]` is bit-identical to
    /// `mvm_into(&xs[b], ..)`.
    fn mvm_batch_into(&self, xs: &[Vec<f64>], ys: &mut Vec<Vec<f64>>) {
        for (i, x) in xs.iter().enumerate() {
            assert_eq!(x.len(), self.dim(), "request {i} input length mismatch");
        }
        ys.resize_with(xs.len(), Vec::new);
        for y in ys.iter_mut() {
            y.clear();
            y.resize(self.dim(), 0.0);
        }
        self.mvm_span_batch((0, self.dim()), xs, ys);
    }

    /// Disjoint, ordered row spans covering [0, dim()) for intra-request
    /// sharding; the executor hands each span to one worker. Spans must
    /// not split a row band (every output row belongs to exactly one
    /// span). Default: a single span, i.e. no intra-request sharding.
    fn shard_spans(&self, shards: usize) -> Vec<(usize, usize)> {
        let _ = shards;
        vec![(0, self.dim())]
    }

    /// Multi-RHS span kernel: fill `outs[b]` (zero-filled, length
    /// `span.1 - span.0`) with output rows [span.0, span.1) of request
    /// `xs[b]`. Must be bit-identical to [`Self::mvm_into`] restricted to
    /// those rows.
    fn mvm_span_batch(&self, span: (usize, usize), xs: &[Vec<f64>], outs: &mut [Vec<f64>]);

    /// Total non-zeros one MVM touches (mapped + digital spill).
    fn nnz(&self) -> u64;

    /// Programmed crossbar cells (clipped extents).
    fn area_cells(&self) -> u64;

    /// Program-level serving statistics (tiles, programs, bands, kernel
    /// mix, mapped/spilled nnz, area).
    fn stats(&self) -> ServeStats;
}

impl Servable for ExecPlan {
    fn dim(&self) -> usize {
        self.dim
    }

    fn mvm_into(&self, x: &[f64], y: &mut Vec<f64>) {
        ExecPlan::mvm_into(self, x, y)
    }

    fn shard_spans(&self, shards: usize) -> Vec<(usize, usize)> {
        self.band_spans(shards)
    }

    fn mvm_span_batch(&self, span: (usize, usize), xs: &[Vec<f64>], outs: &mut [Vec<f64>]) {
        ExecPlan::mvm_span_batch(self, span, xs, outs)
    }

    fn nnz(&self) -> u64 {
        self.mapped_nnz()
    }

    fn area_cells(&self) -> u64 {
        self.cells()
    }

    fn stats(&self) -> ServeStats {
        let (kernel_dense, kernel_sparse) = self.kernel_counts();
        let (nnz_dense, nnz_sparse) = self.kernel_nnz();
        ServeStats {
            dim: self.dim,
            tiles: self.tiles.len(),
            programs: self.num_programs(),
            bands: self.bands().len(),
            kernel_dense,
            kernel_sparse,
            nnz_dense,
            nnz_sparse,
            patterns: self.num_patterns(),
            pattern_dedup_hits: self.pattern_dedup_hits(),
            mapped_nnz: self.mapped_nnz(),
            spilled_nnz: 0,
            area_cells: self.cells(),
            health: FaultHealth::default(),
            delta_updates: 0,
            delta_pending: 0,
            delta_remaps: 0,
        }
    }
}

/// Thread-pool executor bound to one plan.
///
/// The pool is held behind an `Arc` so several executors can share one set
/// of worker threads: a multi-tenant registry ([`crate::net`]) builds one
/// [`WorkerPool`] and binds every tenant's executor to it with
/// [`BatchExecutor::with_pool`], so N tenants cost N plans but only one
/// pool's worth of threads. [`WorkerPool::run`] is safe under concurrent
/// callers (each call carries its own result sink), so tenants can execute
/// simultaneously.
pub struct BatchExecutor<P: Servable = ExecPlan> {
    plan: Arc<P>,
    pool: Arc<WorkerPool>,
    buffers: Arc<Mutex<Vec<Vec<f64>>>>,
}

impl<P: Servable> BatchExecutor<P> {
    /// Spawn `workers` worker threads serving requests against `plan`.
    pub fn new(plan: Arc<P>, workers: usize) -> BatchExecutor<P> {
        BatchExecutor::with_pool(plan, Arc::new(WorkerPool::new(workers)))
    }

    /// Bind `plan` to an existing shared worker pool instead of spawning a
    /// private one. Buffer pools stay per-executor (output buffer length is
    /// plan-dimension-specific); only the threads are shared.
    pub fn with_pool(plan: Arc<P>, pool: Arc<WorkerPool>) -> BatchExecutor<P> {
        BatchExecutor {
            plan,
            pool,
            buffers: Arc::new(Mutex::new(Vec::new())),
        }
    }

    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// The executor's worker pool, for sharing with further executors via
    /// [`BatchExecutor::with_pool`].
    pub fn pool(&self) -> &Arc<WorkerPool> {
        &self.pool
    }

    pub fn plan(&self) -> &P {
        &self.plan
    }

    fn check_batch(&self, xs: &[Vec<f64>]) {
        for (i, x) in xs.iter().enumerate() {
            assert_eq!(
                x.len(),
                self.plan.dim(),
                "request {i} has {} elements, plan expects {}",
                x.len(),
                self.plan.dim()
            );
        }
    }

    /// Execute a batch of input vectors; blocks until every request in the
    /// batch completes and returns outputs in request order. One worker
    /// per request, scalar kernels (the seed serving mode).
    pub fn execute_batch(&self, xs: Vec<Vec<f64>>) -> Vec<Vec<f64>> {
        if xs.is_empty() {
            return Vec::new();
        }
        self.check_batch(&xs);
        let xs = Arc::new(xs);
        let jobs: Vec<_> = (0..xs.len())
            .map(|i| {
                let xs = xs.clone();
                let plan = self.plan.clone();
                let buffers = self.buffers.clone();
                move || {
                    let mut y = buffers.lock().unwrap().pop().unwrap_or_default();
                    plan.mvm_into(&xs[i], &mut y);
                    y
                }
            })
            .collect();
        self.pool.run(jobs)
    }

    /// Execute a batch in the optimized mode: row bands sharded across
    /// workers *within* the request batch, each shard serving all
    /// requests' rows with the multi-RHS kernel. Outputs are stitched in
    /// fixed span order and are bit-identical to [`Self::execute_batch`]
    /// for any worker count and batch size.
    pub fn execute_batch_sharded(&self, xs: Vec<Vec<f64>>) -> Vec<Vec<f64>> {
        if xs.is_empty() {
            return Vec::new();
        }
        self.check_batch(&xs);
        let spans = self.plan.shard_spans(self.pool.workers());
        let xs = Arc::new(xs);
        let jobs: Vec<_> = spans
            .iter()
            .map(|&span| {
                let xs = xs.clone();
                let plan = self.plan.clone();
                move || {
                    let rows = span.1 - span.0;
                    let mut outs: Vec<Vec<f64>> =
                        (0..xs.len()).map(|_| vec![0.0f64; rows]).collect();
                    plan.mvm_span_batch(span, &xs, &mut outs);
                    outs
                }
            })
            .collect();
        let parts = self.pool.run(jobs);
        let batch = xs.len();
        let mut ys: Vec<Vec<f64>> = Vec::with_capacity(batch);
        {
            let mut pool = self.buffers.lock().unwrap();
            for _ in 0..batch {
                ys.push(pool.pop().unwrap_or_default());
            }
        }
        // spans are contiguous and cover [0, dim), so every element is
        // overwritten below — only re-shape buffers that need it
        for y in ys.iter_mut() {
            if y.len() != self.plan.dim() {
                y.clear();
                y.resize(self.plan.dim(), 0.0);
            }
        }
        for (span, part) in spans.iter().zip(parts) {
            for (y, rows) in ys.iter_mut().zip(part) {
                y[span.0..span.1].copy_from_slice(&rows);
            }
        }
        ys
    }

    /// Return output buffers to the pool so later batches reuse them.
    pub fn recycle(&self, bufs: Vec<Vec<f64>>) {
        self.buffers.lock().unwrap().extend(bufs);
    }

    /// Buffers currently waiting in the reuse pool (observability/tests).
    pub fn pooled_buffers(&self) -> usize {
        self.buffers.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crossbar::place;
    use crate::engine::fleet::{AssignPolicy, Fleet};
    use crate::engine::plan::compile;
    use crate::graph::{synth, Coo, GridSummary};
    use crate::reorder::{reorder, Reordering};
    use crate::scheme::{parse_actions, FillRule, Scheme};
    use crate::util::propcheck::check;

    #[test]
    fn empty_batch_is_a_noop() {
        let m = synth::qm7_like(5828);
        let g = GridSummary::new(&m, 2);
        let scheme = Scheme {
            diag_len: vec![g.n],
            fill_len: vec![],
        };
        let plan = Arc::new(compile(&m, &g, &scheme).unwrap());
        let exec = BatchExecutor::new(plan, 2);
        assert!(exec.execute_batch(Vec::new()).is_empty());
        assert!(exec.execute_batch_sharded(Vec::new()).is_empty());
    }

    #[test]
    fn buffers_are_recycled_across_batches() {
        let m = synth::qm7_like(5828);
        let g = GridSummary::new(&m, 2);
        let scheme = Scheme {
            diag_len: vec![g.n],
            fill_len: vec![],
        };
        let plan = Arc::new(compile(&m, &g, &scheme).unwrap());
        let exec = BatchExecutor::new(plan, 2);
        let xs: Vec<Vec<f64>> = (0..4).map(|i| vec![i as f64; 22]).collect();
        let ys = exec.execute_batch(xs.clone());
        assert_eq!(exec.pooled_buffers(), 0);
        exec.recycle(ys);
        assert_eq!(exec.pooled_buffers(), 4);
        let ys2 = exec.execute_batch(xs.clone());
        // all four buffers came back out of the pool
        assert_eq!(exec.pooled_buffers(), 0);
        assert_eq!(ys2.len(), 4);
        // the sharded mode shares the same pool
        exec.recycle(ys2);
        let ys3 = exec.execute_batch_sharded(xs);
        assert_eq!(exec.pooled_buffers(), 0);
        assert_eq!(ys3.len(), 4);
    }

    #[test]
    fn executors_share_one_worker_pool() {
        let m = synth::qm7_like(5828);
        let g = GridSummary::new(&m, 2);
        let scheme = Scheme {
            diag_len: vec![g.n],
            fill_len: vec![],
        };
        let plan = Arc::new(compile(&m, &g, &scheme).unwrap());
        let solo = BatchExecutor::new(plan.clone(), 3);
        // a second executor rides on the first one's pool: same thread
        // count, no new threads, and answers stay bit-identical
        let shared = BatchExecutor::with_pool(plan, solo.pool().clone());
        assert_eq!(shared.workers(), 3);
        assert!(Arc::ptr_eq(solo.pool(), shared.pool()));
        let xs: Vec<Vec<f64>> = (0..5).map(|i| vec![0.5 * i as f64; 22]).collect();
        let a = solo.execute_batch(xs.clone());
        let b = shared.execute_batch_sharded(xs);
        assert_eq!(a, b, "shared-pool executor must be bit-identical");
        // buffer pools are per-executor even when threads are shared
        shared.recycle(b);
        assert_eq!(solo.pooled_buffers(), 0);
        assert_eq!(shared.pooled_buffers(), 5);
    }

    #[test]
    fn results_arrive_in_request_order() {
        let m = synth::qh882_like(1);
        let r = reorder(&m, Reordering::CuthillMckee);
        let g = GridSummary::new(&r.matrix, 32);
        let scheme = Scheme {
            diag_len: vec![g.n],
            fill_len: vec![],
        };
        let plan = Arc::new(compile(&r.matrix, &g, &scheme).unwrap());
        let arr = place(&r.matrix, &g, &scheme).unwrap();
        let exec = BatchExecutor::new(plan, 4);
        let xs: Vec<Vec<f64>> = (0..16)
            .map(|s| (0..882).map(|i| ((i + s * 31) % 23) as f64 - 11.0).collect())
            .collect();
        let ys = exec.execute_batch(xs.clone());
        for (x, y) in xs.iter().zip(ys.iter()) {
            let want = arr.mvm(x);
            for (a, b) in y.iter().zip(want.iter()) {
                assert!((a - b).abs() < 1e-9, "{a} vs {b}");
            }
        }
        // and the sharded mode returns the identical answers
        let ys2 = exec.execute_batch_sharded(xs);
        assert_eq!(ys, ys2, "sharded mode must be bit-identical");
    }

    #[test]
    fn batch_executor_matches_oracle_property() {
        // The engine acceptance property: across random matrices, schemes,
        // batch sizes, and fleet sizes (1, 2, 8 banks/workers), both
        // serving modes reproduce CrossbarArray::mvm within 1e-9
        // everywhere, and agree with each other exactly.
        check("engine_batch_matches_oracle", 10, |rng| {
            let dim = 16 + rng.below(60) as usize;
            let mut coo = Coo::new(dim, dim);
            for _ in 0..dim * 3 {
                let a = rng.below(dim as u64) as usize;
                let b = rng.below(dim as u64) as usize;
                coo.push_sym(a.max(b), a.min(b), rng.uniform(-1.0, 1.0));
            }
            let m = coo.to_csr();
            let r = reorder(&m, Reordering::CuthillMckee);
            let grid = 2 + rng.below(6) as usize;
            let g = GridSummary::new(&r.matrix, grid);
            if g.n < 2 {
                return Ok(());
            }
            let d: Vec<u8> = (0..g.n - 1).map(|_| rng.below(2) as u8).collect();
            let f: Vec<usize> = (0..g.n - 1).map(|_| rng.below(4) as usize).collect();
            let s = parse_actions(g.n, &d, &f, FillRule::Dynamic { grades: 4 });
            let arr = place(&r.matrix, &g, &s).map_err(|e| format!("{e:#}"))?;
            let plan = Arc::new(compile(&r.matrix, &g, &s).map_err(|e| format!("{e:#}"))?);
            for &banks in &[1usize, 2, 8] {
                // the fleet partitions the same plan the executor serves
                let fleet = Fleet::assign(&plan, banks, AssignPolicy::BalancedNnz)
                    .map_err(|e| format!("{e:#}"))?;
                if fleet.loads.iter().map(|l| l.tiles).sum::<usize>() != plan.tiles.len() {
                    return Err("fleet lost tiles".into());
                }
                let exec = BatchExecutor::new(plan.clone(), banks);
                let bsz = 1 + rng.below(12) as usize;
                let xs: Vec<Vec<f64>> = (0..bsz)
                    .map(|_| (0..dim).map(|_| rng.uniform(-2.0, 2.0)).collect())
                    .collect();
                let ys = exec.execute_batch(xs.clone());
                for (x, y) in xs.iter().zip(ys.iter()) {
                    let want = arr.mvm(x);
                    for (i, (a, b)) in y.iter().zip(want.iter()).enumerate() {
                        if (a - b).abs() > 1e-9 {
                            return Err(format!(
                                "banks {banks} batch {bsz} row {i}: {a} vs {b}"
                            ));
                        }
                    }
                }
                let sharded = exec.execute_batch_sharded(xs);
                if sharded != ys {
                    return Err(format!("banks {banks}: sharded mode diverged"));
                }
            }
            Ok(())
        });
    }
}
