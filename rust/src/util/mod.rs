//! Utility substrates built in-repo because the offline vendored crate set
//! contains no `rand`, `serde`, `clap`, `criterion`, `proptest`, or
//! `rayon` ([`pool`] covers the order-preserving fan-out the trainer
//! needs).

pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
pub mod propcheck;
pub mod rng;
