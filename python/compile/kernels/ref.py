"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

Every kernel in this package must match its oracle to float32 tolerance
across the hypothesis sweep in python/tests/test_kernels.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def lstm_cell_ref(x, h_prev, c_prev, w, b):
    """Reference LSTM cell, gate packing (f, i, g, o) — Eqs. (9)-(14)."""
    xh = jnp.concatenate([x, h_prev], axis=-1)
    z = xh @ w + b[None, :]
    hidden = h_prev.shape[-1]
    f = jax.nn.sigmoid(z[:, 0 * hidden : 1 * hidden])
    i = jax.nn.sigmoid(z[:, 1 * hidden : 2 * hidden])
    g = jnp.tanh(z[:, 2 * hidden : 3 * hidden])
    o = jax.nn.sigmoid(z[:, 3 * hidden : 4 * hidden])
    c = f * c_prev + i * g
    h = o * jnp.tanh(c)
    return h, c


def block_mvm_ref(tiles, x_tiles, row_onehot):
    """Reference blocked MVM: per-tile matvec + one-hot row accumulation."""
    y_tiles = jnp.einsum("nkj,nj->nk", tiles, x_tiles)
    return jnp.einsum("nr,nk->rk", row_onehot, y_tiles)
