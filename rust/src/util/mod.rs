//! Utility substrates built in-repo because the offline vendored crate set
//! contains no `rand`, `serde`, `clap`, `criterion`, or `proptest`.

pub mod bench;
pub mod cli;
pub mod json;
pub mod propcheck;
pub mod rng;
