//! Multi-layer spectral GCN forward (Eq. 1 of the paper) over a mapped
//! adjacency — the motivating workload, now served through the same
//! [`MvmEngine`] loop as the traversals:
//!
//! ```text
//! Z_{l+1} = σ( D̂^{-1/2} Â D̂^{-1/2} · Z_l W_l ),   Â = A + I
//! ```
//!
//! Per layer the host computes the dense feature transform `Z W_l` (a
//! GEMM over the small weight matrix), splits the result into its
//! `out_dim` feature columns, and pushes **all columns through the engine
//! as one multi-RHS batch** — on the sharded executor path that is one
//! [`crate::engine::Servable::mvm_span_batch`] arena traversal per span
//! per layer, the amortization the paper is after. ReLU (when the layer
//! asks for it) is the digital post-step.
//!
//! [`normalized_adjacency`] builds the symmetric-normalized matrix that
//! gets mapped; [`GcnLayer::forward_dense`] is the host CSR oracle the
//! property suite holds `gcn_forward` to within 1e-5.

use super::{AlgoTrace, MvmEngine};
use crate::api::error::{Error, Result};
use crate::graph::{Coo, Csr};
use crate::util::rng::Pcg64;
use std::time::Instant;

/// Symmetric-normalized adjacency with self-loops: D̂^{-1/2}(A+I)D̂^{-1/2}.
pub fn normalized_adjacency(a: &Csr) -> Csr {
    assert_eq!(a.rows, a.cols, "GCN adjacency must be square");
    let n = a.rows;
    // Â = A + I
    let mut coo = Coo::new(n, n);
    for r in 0..n {
        for (i, &c) in a.row(r).iter().enumerate() {
            if r != c {
                coo.push(r, c, a.row_vals(r)[i]);
            }
        }
        coo.push(r, r, a.get(r, r) + 1.0);
    }
    let ahat = coo.to_csr();
    // degrees
    let deg: Vec<f64> = (0..n).map(|r| ahat.row_vals(r).iter().sum()).collect();
    let dinv_sqrt: Vec<f64> = deg
        .iter()
        .map(|&d| if d > 0.0 { 1.0 / d.sqrt() } else { 0.0 })
        .collect();
    let mut out = Coo::new(n, n);
    for r in 0..n {
        for (i, &c) in ahat.row(r).iter().enumerate() {
            out.push(r, c, dinv_sqrt[r] * ahat.row_vals(r)[i] * dinv_sqrt[c]);
        }
    }
    out.to_csr()
}

/// One GCN layer's dense weights, row-major [in_dim, out_dim].
#[derive(Clone, Debug)]
pub struct GcnLayer {
    pub in_dim: usize,
    pub out_dim: usize,
    pub w: Vec<f64>,
    pub relu: bool,
}

impl GcnLayer {
    /// He-initialized weights from a seed — the deterministic constructor
    /// both transports use for the `{"gcn":{...}}` request kind, so a
    /// stdin run and a socket run answer with identical features.
    pub fn random(in_dim: usize, out_dim: usize, relu: bool, seed: u64) -> GcnLayer {
        let mut rng = Pcg64::seed_from_u64(seed ^ 0x6763_6e5f_7731_0001);
        let scale = (2.0 / in_dim as f64).sqrt();
        GcnLayer {
            in_dim,
            out_dim,
            w: (0..in_dim * out_dim)
                .map(|_| rng.normal() * scale)
                .collect(),
            relu,
        }
    }

    /// Z W (node-feature transform), Z row-major [n, in_dim].
    fn transform(&self, z: &[f64], n: usize) -> Vec<f64> {
        let mut out = vec![0.0; n * self.out_dim];
        for r in 0..n {
            for i in 0..self.in_dim {
                let zv = z[r * self.in_dim + i];
                if zv == 0.0 {
                    continue;
                }
                let wrow = &self.w[i * self.out_dim..(i + 1) * self.out_dim];
                for (o, wv) in out[r * self.out_dim..(r + 1) * self.out_dim]
                    .iter_mut()
                    .zip(wrow)
                {
                    *o += zv * wv;
                }
            }
        }
        out
    }

    fn activate(&self, x: &mut [f64]) {
        if self.relu {
            for v in x.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
        }
    }

    /// Dense oracle: σ(A_norm (Z W)).
    pub fn forward_dense(&self, a_norm: &Csr, z: &[f64]) -> Vec<f64> {
        let n = a_norm.rows;
        assert_eq!(z.len(), n * self.in_dim);
        let zw = self.transform(z, n);
        // propagate each output column through the sparse matrix
        let mut out = vec![0.0; n * self.out_dim];
        let mut col = vec![0.0; n];
        for o in 0..self.out_dim {
            for r in 0..n {
                col[r] = zw[r * self.out_dim + o];
            }
            let prop = a_norm.spmv(&col);
            for r in 0..n {
                out[r * self.out_dim + o] = prop[r];
            }
        }
        self.activate(&mut out);
        out
    }
}

/// Validate a layer stack against the input feature width, with messages
/// that name the offending wire field.
pub fn validate_layers(layers: &[GcnLayer], n: usize, x_len: usize) -> Result<()> {
    if layers.is_empty() {
        return Err(Error::Validate("gcn.layers must name at least one layer".into()));
    }
    if x_len != n * layers[0].in_dim {
        return Err(Error::Validate(format!(
            "gcn.x carries {x_len} features for {n} nodes; layer 0 expects {} per node",
            layers[0].in_dim
        )));
    }
    for (k, pair) in layers.windows(2).enumerate() {
        if pair[1].in_dim != pair[0].out_dim {
            return Err(Error::Validate(format!(
                "gcn.layers[{}].in_dim is {} but layer {} produces {}",
                k + 1,
                pair[1].in_dim,
                k,
                pair[0].out_dim
            )));
        }
    }
    for (k, l) in layers.iter().enumerate() {
        if l.in_dim == 0 || l.out_dim == 0 {
            return Err(Error::Validate(format!(
                "gcn.layers[{k}] has a zero dimension ({}→{})",
                l.in_dim, l.out_dim
            )));
        }
    }
    Ok(())
}

/// Run the multi-layer forward pass on `engine`. `x` is the input feature
/// matrix, row-major `[n, layers[0].in_dim]`; the result is row-major
/// `[n, layers.last().out_dim]`. One engine batch per layer; the trace's
/// residual curve records each layer's max-abs activation.
pub fn gcn_forward<E: MvmEngine>(
    engine: &E,
    x: &[f64],
    layers: &[GcnLayer],
) -> Result<(Vec<f64>, AlgoTrace)> {
    let n = engine.dim();
    validate_layers(layers, n, x.len())?;
    let t0 = Instant::now();

    let mut z = x.to_vec();
    let mut residuals = Vec::with_capacity(layers.len());
    let mut mvms = 0u64;
    for layer in layers {
        let zw = layer.transform(&z, n);
        // one multi-RHS batch per layer: every output feature column at once
        let cols: Vec<Vec<f64>> = (0..layer.out_dim)
            .map(|o| (0..n).map(|r| zw[r * layer.out_dim + o]).collect())
            .collect();
        let props = engine.mvm_batch(cols);
        mvms += layer.out_dim as u64;
        let mut next = vec![0.0; n * layer.out_dim];
        for (o, prop) in props.iter().enumerate() {
            for r in 0..n {
                next[r * layer.out_dim + o] = prop[r];
            }
        }
        layer.activate(&mut next);
        residuals.push(next.iter().fold(0.0f64, |m, &v| m.max(v.abs())));
        z = next;
    }

    let wall_s = t0.elapsed().as_secs_f64();
    let trace = AlgoTrace {
        algorithm: "gcn",
        iterations: layers.len(),
        converged: true,
        residuals,
        mvms,
        nnz_total: mvms * engine.nnz(),
        wall_s,
    };
    Ok((z, trace))
}

/// Max absolute elementwise difference — the agreement metric the oracle
/// comparisons report.
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::CsrEngine;
    use crate::graph::synth;

    #[test]
    fn normalization_rows_bounded() {
        let a = synth::qm7_like(5828);
        let nrm = normalized_adjacency(&a);
        assert_eq!(nrm.nnz(), a.nnz() + a.rows); // self loops added
        // spectral norm of sym-normalized adjacency is <= 1; cheap proxy:
        // every entry within (0, 1]
        for r in 0..nrm.rows {
            for &v in nrm.row_vals(r) {
                assert!(v > 0.0 && v <= 1.0 + 1e-12);
            }
        }
        assert!(nrm.is_symmetric());
    }

    #[test]
    fn multi_layer_forward_matches_dense_oracle() {
        let a = synth::qm7_like(5828);
        let nrm = normalized_adjacency(&a);
        let n = nrm.rows;
        let layers = vec![
            GcnLayer::random(6, 8, true, 1),
            GcnLayer::random(8, 3, false, 2),
        ];
        let mut rng = Pcg64::seed_from_u64(9);
        let x: Vec<f64> = (0..n * 6).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let (got, trace) = gcn_forward(&CsrEngine(&nrm), &x, &layers).unwrap();
        let mut want = x.clone();
        for layer in &layers {
            want = layer.forward_dense(&nrm, &want);
        }
        assert!(max_abs_diff(&got, &want) < 1e-12);
        assert_eq!(trace.iterations, 2);
        assert_eq!(trace.mvms, 8 + 3);
        assert_eq!(trace.residuals.len(), 2);
        assert_eq!(got.len(), n * 3);
    }

    #[test]
    fn relu_applied_per_layer_flag() {
        let a = synth::qm7_like(5828);
        let nrm = normalized_adjacency(&a);
        let n = nrm.rows;
        let mut rng = Pcg64::seed_from_u64(3);
        let x: Vec<f64> = (0..n * 3).map(|_| rng.uniform(-2.0, 2.0)).collect();
        let relu = vec![GcnLayer::random(3, 3, true, 7)];
        let (out, _) = gcn_forward(&CsrEngine(&nrm), &x, &relu).unwrap();
        assert!(out.iter().all(|&v| v >= 0.0));
        let lin = vec![GcnLayer { relu: false, ..relu[0].clone() }];
        let (out2, _) = gcn_forward(&CsrEngine(&nrm), &x, &lin).unwrap();
        assert!(out2.iter().any(|&v| v < 0.0));
    }

    #[test]
    fn shape_errors_name_the_field() {
        let a = synth::qm7_like(5828);
        let nrm = normalized_adjacency(&a);
        let n = nrm.rows;
        let err = gcn_forward(&CsrEngine(&nrm), &[], &[]).unwrap_err();
        assert!(err.to_string().contains("gcn.layers"), "{err}");
        let layers = vec![GcnLayer::random(4, 2, true, 1)];
        let err = gcn_forward(&CsrEngine(&nrm), &vec![0.0; n * 3], &layers).unwrap_err();
        assert!(err.to_string().contains("gcn.x"), "{err}");
        let bad_chain = vec![GcnLayer::random(4, 2, true, 1), GcnLayer::random(3, 2, true, 2)];
        let err = gcn_forward(&CsrEngine(&nrm), &vec![0.0; n * 4], &bad_chain).unwrap_err();
        assert!(err.to_string().contains("gcn.layers[1]"), "{err}");
    }
}
