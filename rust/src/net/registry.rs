//! The multi-tenant deployment registry: N loaded bundles behind one
//! worker pool, with per-tenant admission control and live hot-swap.
//!
//! A [`DeploymentRegistry`] owns one [`Tenant`] per deployment id. Each
//! tenant holds its *current* [`TenantEntry`] — the loaded
//! [`Deployment`] plus a [`BatchExecutor`] bound to the registry-wide
//! shared [`WorkerPool`] — behind an `RwLock<Arc<..>>`:
//!
//! - **Serving** clones the `Arc` out of the lock
//!   ([`Tenant::entry`]) *before* executing, so a request always runs to
//!   completion against one consistent plan no matter what the registry
//!   does concurrently.
//! - **Hot-swap** ([`DeploymentRegistry::reload`]) loads the new bundle
//!   from disk *outside* any lock, then replaces the `Arc` under a brief
//!   write lock. In-flight requests finish on the old entry (they hold
//!   their own `Arc`); every request admitted after the swap sees the new
//!   one. Nothing is dropped, nothing is answered by a half-installed
//!   plan.
//!
//! Admission control is a bounded in-flight counter per tenant: a request
//! [`Tenant::admit`]ted at the depth limit gets a typed
//! [`Error::Busy`] *before* any execution, and the RAII [`AdmitGuard`]
//! releases the slot however the request ends. All tenants share one
//! worker pool (threads scale with the machine, not with the number of
//! deployed graphs); per-tenant output-buffer pools stay private because
//! buffer length is plan-dimension-specific.

use crate::api::dispatch::{self, AlgoAnswer, AlgoRequest};
use crate::api::{DeployedPlan, Deployment, Error, Result};
use crate::delta::{DeltaEngine, RemapReport};
use crate::engine::{BatchExecutor, Servable};
use crate::util::json::Json;
use crate::util::pool::WorkerPool;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Instant;

/// Registry-wide serving configuration.
#[derive(Clone, Debug)]
pub struct RegistryOptions {
    /// worker threads in the shared pool (all tenants execute on it)
    pub workers: usize,
    /// per-tenant in-flight request cap; at the limit new requests get a
    /// typed `busy` rejection
    pub queue_depth: usize,
    /// band-sharded multi-RHS execution (false = scalar per-request mode)
    pub sharded: bool,
    /// arm a fault harness ([`crate::fault::FaultHarness`]) on every
    /// loaded deployment — each generation (initial load and every
    /// hot-swap) gets its own harness over its own healthy image
    pub fault: Option<crate::fault::FaultOptions>,
    /// auto-remap threshold for dynamic tenants: after this many edge
    /// updates since the last remap, the next update folds the overlay
    /// into a fresh arena generation. 0 disables auto-remap (updates
    /// accumulate in the overlay until `{"admin":{"remap":..}}`).
    pub remap_after: usize,
}

impl Default for RegistryOptions {
    fn default() -> RegistryOptions {
        RegistryOptions {
            workers: 8,
            queue_depth: 32,
            sharded: true,
            fault: None,
            remap_after: 0,
        }
    }
}

/// One immutable generation of a tenant: the deployment and the executor
/// serving it. Swapped wholesale on reload; never mutated in place.
pub struct TenantEntry {
    deployment: Arc<Deployment>,
    executor: BatchExecutor<DeployedPlan>,
    generation: u64,
    bundle: Option<PathBuf>,
    /// monotonic clock captured when this generation was installed in the
    /// registry — the base of the uptime-normalized rates in
    /// [`Tenant::stats_json`], so a hot-swapped tenant's `rps` reflects
    /// the generation actually serving, not a stale lifetime average
    installed: Instant,
}

impl TenantEntry {
    /// The deployment this generation serves (also the bit-identity
    /// oracle: socket answers must equal `deployment().mvm(x)`).
    pub fn deployment(&self) -> &Arc<Deployment> {
        &self.deployment
    }

    /// Request/response vector length.
    pub fn dim(&self) -> usize {
        self.deployment.plan().dim()
    }

    /// Non-zeros one MVM touches (throughput accounting).
    pub fn nnz(&self) -> u64 {
        self.deployment.plan().nnz()
    }

    /// Monotonic per-tenant generation counter; bumped by every reload.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// The bundle file this generation was loaded from, if any.
    pub fn bundle(&self) -> Option<&Path> {
        self.bundle.as_deref()
    }

    /// When this generation was installed (monotonic).
    pub fn installed(&self) -> Instant {
        self.installed
    }

    /// Execute a request batch against this generation: permute in,
    /// run on the shared pool (through the fault harness's verified path
    /// when one is armed), permute back to original node ids. The flag
    /// reports whether the batch was served under a degraded fault epoch.
    pub fn execute(&self, xs: Vec<Vec<f64>>, sharded: bool) -> (Vec<Vec<f64>>, bool) {
        dispatch::execute_verified(&self.deployment, &self.executor, xs, sharded)
    }

    /// The armed fault harness of this generation's deployment, if any.
    pub fn fault_harness(&self) -> Option<&Arc<crate::fault::FaultHarness>> {
        self.deployment.fault_harness()
    }

    /// Run a whole graph-algorithm request ([`crate::algo`]) against this
    /// generation, iterating MVMs on the shared pool.
    pub fn run_algo(&self, req: &AlgoRequest, sharded: bool) -> Result<AlgoAnswer> {
        dispatch::run_algo(&self.deployment, &self.executor, sharded, req)
    }
}

/// Per-tenant serving state: the current entry, the admission counter,
/// and monotonic traffic counters (all atomics — stats never block
/// serving).
pub struct Tenant {
    name: String,
    queue_depth: usize,
    current: RwLock<Arc<TenantEntry>>,
    inflight: AtomicUsize,
    served: AtomicU64,
    batches: AtomicU64,
    errors: AtomicU64,
    rejected_busy: AtomicU64,
    rejected_deadline: AtomicU64,
    served_nnz: AtomicU64,
    // per-generation rate window: reset on every hot-swap so `rps` and
    // `nnz_per_s` are normalized by the *current* generation's uptime
    gen_served: AtomicU64,
    gen_served_nnz: AtomicU64,
    // per-algorithm request counters (cumulative across generations)
    algo_pagerank: AtomicU64,
    algo_bfs: AtomicU64,
    algo_sssp: AtomicU64,
    algo_gcn: AtomicU64,
    algo_mvms: AtomicU64,
    /// the tenant's dynamic-graph engine ([`crate::delta`]), attached
    /// lazily by the first `update` request and dropped by a bundle
    /// reload (a reload replaces the graph wholesale, so pending overlay
    /// state against the old graph is meaningless)
    delta: RwLock<Option<Arc<DeltaEngine>>>,
    t0: Instant,
}

/// RAII admission slot: dropping it (success or failure, panic included)
/// releases the tenant's in-flight slot.
pub struct AdmitGuard {
    tenant: Arc<Tenant>,
}

impl Drop for AdmitGuard {
    fn drop(&mut self) {
        self.tenant.inflight.fetch_sub(1, Ordering::AcqRel);
    }
}

impl Tenant {
    fn new(name: &str, queue_depth: usize, entry: Arc<TenantEntry>) -> Tenant {
        Tenant {
            name: name.to_string(),
            queue_depth: queue_depth.max(1),
            current: RwLock::new(entry),
            inflight: AtomicUsize::new(0),
            served: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            rejected_busy: AtomicU64::new(0),
            rejected_deadline: AtomicU64::new(0),
            served_nnz: AtomicU64::new(0),
            gen_served: AtomicU64::new(0),
            gen_served_nnz: AtomicU64::new(0),
            algo_pagerank: AtomicU64::new(0),
            algo_bfs: AtomicU64::new(0),
            algo_sssp: AtomicU64::new(0),
            algo_gcn: AtomicU64::new(0),
            algo_mvms: AtomicU64::new(0),
            delta: RwLock::new(None),
            t0: Instant::now(),
        }
    }

    /// The attached dynamic-graph engine, if any `update` request has
    /// attached one. Requests against a delta tenant must execute through
    /// the engine (it serves base + overlay); the entry alone would
    /// silently drop pending updates.
    pub fn delta(&self) -> Option<Arc<DeltaEngine>> {
        self.delta.read().unwrap().clone()
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn queue_depth(&self) -> usize {
        self.queue_depth
    }

    /// Requests currently admitted and not yet finished.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Acquire)
    }

    /// Snapshot the current generation. Callers execute against the
    /// returned `Arc` — a concurrent reload cannot pull the plan out from
    /// under them.
    pub fn entry(&self) -> Arc<TenantEntry> {
        self.current.read().unwrap().clone()
    }

    /// Try to claim an in-flight slot. At the depth limit this is a typed
    /// [`Error::Busy`] — the caller rejected the request before any work.
    pub fn admit(self: &Arc<Tenant>) -> Result<AdmitGuard> {
        let depth = self.queue_depth;
        let claimed = self
            .inflight
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |n| {
                (n < depth).then_some(n + 1)
            })
            .is_ok();
        if claimed {
            Ok(AdmitGuard {
                tenant: self.clone(),
            })
        } else {
            Err(Error::Busy {
                tenant: self.name.clone(),
                depth,
            })
        }
    }

    /// Account a successfully served batch of `requests` MVMs (both the
    /// lifetime counters and the current generation's rate window).
    pub fn record_served(&self, requests: u64, nnz_per_request: u64) {
        self.served.fetch_add(requests, Ordering::Relaxed);
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.served_nnz.fetch_add(requests * nnz_per_request, Ordering::Relaxed);
        self.gen_served.fetch_add(requests, Ordering::Relaxed);
        self.gen_served_nnz.fetch_add(requests * nnz_per_request, Ordering::Relaxed);
    }

    /// Account one finished graph-algorithm run of kind `key`, which
    /// issued `mvms` MVMs against the arena.
    pub fn record_algo(&self, key: &str, mvms: u64) {
        let counter = match key {
            "pagerank" => &self.algo_pagerank,
            "bfs" => &self.algo_bfs,
            "sssp" => &self.algo_sssp,
            "gcn" => &self.algo_gcn,
            _ => return,
        };
        counter.fetch_add(1, Ordering::Relaxed);
        self.algo_mvms.fetch_add(mvms, Ordering::Relaxed);
    }

    /// Account a failed request under the right rejection counter.
    pub fn record_failure(&self, err: &Error) {
        let counter = match err {
            Error::Busy { .. } => &self.rejected_busy,
            Error::Deadline { .. } => &self.rejected_deadline,
            _ => &self.errors,
        };
        counter.fetch_add(1, Ordering::Relaxed);
    }

    /// Swap in a new generation built by `make` (which receives the next
    /// generation number) under the tenant's write lock. The lifetime
    /// counters survive; the per-generation rate window restarts so the
    /// new generation is not credited with the old one's traffic.
    fn swap_with(&self, make: impl FnOnce(u64) -> Arc<TenantEntry>) -> Arc<TenantEntry> {
        let mut cur = self.current.write().unwrap();
        let entry = make(cur.generation + 1);
        *cur = entry.clone();
        self.gen_served.store(0, Ordering::Relaxed);
        self.gen_served_nnz.store(0, Ordering::Relaxed);
        entry
    }

    /// The per-tenant stats object the `{"admin":"stats"}` wire request
    /// returns: traffic rates, queue state, rejection counts, generation,
    /// the per-algorithm request mix, and the current generation's kernel
    /// mix (dense/sparse program counts, per-kernel nnz, pattern-dedup
    /// hits) so operators can see what a reload did to the serving hot
    /// path.
    ///
    /// `rps` / `nnz_per_s` are normalized by `wall_s`, the *current
    /// generation's* uptime (monotonic clock captured when the entry was
    /// installed), over traffic served by that generation alone — a
    /// hot-swapped tenant never reports a rate diluted or inflated by a
    /// predecessor's history. `served`, `batches`, and `uptime_s` stay
    /// cumulative over the tenant's lifetime.
    pub fn stats_json(&self) -> Json {
        let entry = self.entry();
        let kernels = entry.deployment().stats();
        let wall = entry.installed().elapsed().as_secs_f64().max(1e-9);
        let served = self.served.load(Ordering::Relaxed);
        let mut map = BTreeMap::new();
        map.insert("served".into(), Json::Num(served as f64));
        map.insert(
            "batches".into(),
            Json::Num(self.batches.load(Ordering::Relaxed) as f64),
        );
        map.insert(
            "errors".into(),
            Json::Num(self.errors.load(Ordering::Relaxed) as f64),
        );
        map.insert(
            "rejected_busy".into(),
            Json::Num(self.rejected_busy.load(Ordering::Relaxed) as f64),
        );
        map.insert(
            "rejected_deadline".into(),
            Json::Num(self.rejected_deadline.load(Ordering::Relaxed) as f64),
        );
        map.insert("inflight".into(), Json::Num(self.inflight() as f64));
        map.insert("queue_depth".into(), Json::Num(self.queue_depth as f64));
        map.insert("generation".into(), Json::Num(entry.generation as f64));
        map.insert("dim".into(), Json::Num(entry.dim() as f64));
        map.insert("nnz".into(), Json::Num(entry.nnz() as f64));
        map.insert("mapped_nnz".into(), Json::Num(kernels.mapped_nnz as f64));
        map.insert("spilled_nnz".into(), Json::Num(kernels.spilled_nnz as f64));
        map.insert(
            "kernel_dense".into(),
            Json::Num(kernels.kernel_dense as f64),
        );
        map.insert(
            "kernel_sparse".into(),
            Json::Num(kernels.kernel_sparse as f64),
        );
        map.insert("nnz_dense".into(), Json::Num(kernels.nnz_dense as f64));
        map.insert("nnz_sparse".into(), Json::Num(kernels.nnz_sparse as f64));
        map.insert("row_patterns".into(), Json::Num(kernels.patterns as f64));
        map.insert(
            "pattern_dedup_hits".into(),
            Json::Num(kernels.pattern_dedup_hits as f64),
        );
        map.insert(
            "rps".into(),
            Json::Num(self.gen_served.load(Ordering::Relaxed) as f64 / wall),
        );
        map.insert(
            "nnz_per_s".into(),
            Json::Num(self.gen_served_nnz.load(Ordering::Relaxed) as f64 / wall),
        );
        map.insert("wall_s".into(), Json::Num(wall));
        map.insert(
            "uptime_s".into(),
            Json::Num(self.t0.elapsed().as_secs_f64().max(1e-9)),
        );
        if kernels.health.armed {
            map.insert("health".into(), dispatch::health_json(&kernels.health));
        }
        if let Some(eng) = self.delta() {
            map.insert("delta".into(), dispatch::delta_stats_json(&eng));
        }
        let mut algo = BTreeMap::new();
        algo.insert(
            "pagerank".into(),
            Json::Num(self.algo_pagerank.load(Ordering::Relaxed) as f64),
        );
        algo.insert("bfs".into(), Json::Num(self.algo_bfs.load(Ordering::Relaxed) as f64));
        algo.insert("sssp".into(), Json::Num(self.algo_sssp.load(Ordering::Relaxed) as f64));
        algo.insert("gcn".into(), Json::Num(self.algo_gcn.load(Ordering::Relaxed) as f64));
        algo.insert("mvms".into(), Json::Num(self.algo_mvms.load(Ordering::Relaxed) as f64));
        map.insert("algo".into(), Json::Obj(algo));
        Json::Obj(map)
    }
}

/// The registry: deployment-id → [`Tenant`], one shared worker pool.
pub struct DeploymentRegistry {
    tenants: RwLock<BTreeMap<String, Arc<Tenant>>>,
    pool: Arc<WorkerPool>,
    queue_depth: usize,
    sharded: bool,
    fault: Option<crate::fault::FaultOptions>,
    remap_after: usize,
}

impl DeploymentRegistry {
    pub fn new(opts: &RegistryOptions) -> DeploymentRegistry {
        DeploymentRegistry {
            tenants: RwLock::new(BTreeMap::new()),
            pool: Arc::new(WorkerPool::new(opts.workers.max(1))),
            queue_depth: opts.queue_depth.max(1),
            sharded: opts.sharded,
            fault: opts.fault,
            remap_after: opts.remap_after,
        }
    }

    /// Threads in the shared pool.
    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// Whether tenants execute in the band-sharded multi-RHS mode.
    pub fn sharded(&self) -> bool {
        self.sharded
    }

    /// The shared pool (for binding further executors to it).
    pub fn pool(&self) -> &Arc<WorkerPool> {
        &self.pool
    }

    /// Auto-remap threshold ([`RegistryOptions::remap_after`]; 0 = manual
    /// remap only).
    pub fn remap_after(&self) -> usize {
        self.remap_after
    }

    /// The tenant's dynamic-graph engine, attaching one over the current
    /// generation on first use. The attach (which reconstructs the host
    /// CSR and warms the scheme cache) runs under the tenant's delta
    /// write lock, so concurrent first updates attach exactly once;
    /// serving reads are unaffected (they take the lock only to clone the
    /// `Arc` out).
    pub fn delta_engine(&self, id: &str) -> Result<Arc<DeltaEngine>> {
        let tenant = self.get(id)?;
        if let Some(eng) = tenant.delta() {
            return Ok(eng);
        }
        let mut slot = tenant.delta.write().unwrap();
        if let Some(eng) = slot.clone() {
            return Ok(eng); // another update attached while we waited
        }
        let entry = tenant.entry();
        let eng = DeltaEngine::attach((**entry.deployment()).clone(), self.pool.clone())?;
        *slot = Some(eng.clone());
        Ok(eng)
    }

    /// Fold a dynamic tenant's pending updates into a fresh arena
    /// generation: incremental remap on the delta engine, then install
    /// the folded deployment as the tenant's next [`TenantEntry`] (so
    /// algorithm requests and the stats surface see the new plan, and the
    /// per-generation rate window restarts — remap is a generation bump
    /// exactly like a bundle reload). A tenant with no attached engine
    /// gets one attached first, so `remap` on a never-updated tenant is a
    /// cheap no-op fold.
    pub fn remap(&self, id: &str) -> Result<(Arc<TenantEntry>, RemapReport)> {
        let tenant = self.get(id)?;
        let eng = self.delta_engine(id)?;
        let report = eng.remap()?;
        let bundle = tenant.entry().bundle().map(|p| p.to_path_buf());
        let dep = (*eng.deployment()).clone();
        let entry = tenant.swap_with(|generation| self.make_entry(dep, generation, bundle));
        Ok((entry, report))
    }

    fn make_entry(
        &self,
        mut dep: Deployment,
        generation: u64,
        bundle: Option<PathBuf>,
    ) -> Arc<TenantEntry> {
        // every generation — initial load and every hot-swap — arms its
        // own harness over its own healthy image
        if let Some(fopts) = self.fault {
            dep.arm_fault_harness(fopts);
        }
        let deployment = Arc::new(dep);
        let executor = BatchExecutor::with_pool(deployment.plan_arc(), self.pool.clone());
        Arc::new(TenantEntry {
            deployment,
            executor,
            generation,
            bundle,
            installed: Instant::now(),
        })
    }

    /// Register (or wholesale replace, counters included) a tenant
    /// serving `dep` under `id`. Prefer [`DeploymentRegistry::reload`] for
    /// replacing a live tenant — it keeps the counters and bumps the
    /// generation.
    pub fn insert(&self, id: &str, dep: Deployment, bundle: Option<PathBuf>) -> Arc<Tenant> {
        let entry = self.make_entry(dep, 1, bundle);
        let tenant = Arc::new(Tenant::new(id, self.queue_depth, entry));
        self.tenants.write().unwrap().insert(id.to_string(), tenant.clone());
        tenant
    }

    /// Load a bundle file and register it under `id`.
    pub fn load_bundle(&self, id: &str, path: &Path) -> Result<Arc<Tenant>> {
        let dep = Deployment::load(path)?;
        Ok(self.insert(id, dep, Some(path.to_path_buf())))
    }

    /// Look up a tenant; unknown ids get a validation error naming the
    /// deployed tenants so clients can self-correct.
    pub fn get(&self, id: &str) -> Result<Arc<Tenant>> {
        let tenants = self.tenants.read().unwrap();
        tenants.get(id).cloned().ok_or_else(|| {
            let known: Vec<&str> = tenants.keys().map(|k| k.as_str()).collect();
            Error::Validate(format!("unknown tenant {id:?}; deployed tenants: {known:?}"))
        })
    }

    /// Registered deployment ids, sorted.
    pub fn ids(&self) -> Vec<String> {
        self.tenants.read().unwrap().keys().cloned().collect()
    }

    /// Hot-swap: load `path` from disk (outside every lock — a slow disk
    /// never stalls serving), then atomically install it as `id`'s new
    /// generation. An existing tenant keeps its counters and in-flight
    /// requests (they finish on the old entry); an unknown `id` is
    /// registered fresh. Returns the installed entry.
    pub fn reload(&self, id: &str, path: &Path) -> Result<Arc<TenantEntry>> {
        let dep = Deployment::load(path)?;
        let existing = self.tenants.read().unwrap().get(id).cloned();
        match existing {
            Some(tenant) => {
                let entry = tenant.swap_with(|generation| {
                    self.make_entry(dep, generation, Some(path.to_path_buf()))
                });
                // a reload replaces the graph wholesale: drop the delta
                // engine (and any pending overlay against the old graph);
                // the next update re-attaches over the new generation
                tenant.delta.write().unwrap().take();
                Ok(entry)
            }
            None => Ok(self.load_tenant_entry(id, dep, path)),
        }
    }

    fn load_tenant_entry(&self, id: &str, dep: Deployment, path: &Path) -> Arc<TenantEntry> {
        let tenant = self.insert(id, dep, Some(path.to_path_buf()));
        tenant.entry()
    }

    /// Per-tenant stats keyed by deployment id — the `{"admin":"stats"}`
    /// response body.
    pub fn stats_json(&self) -> Json {
        let tenants = self.tenants.read().unwrap();
        let mut map = BTreeMap::new();
        for (id, t) in tenants.iter() {
            map.insert(id.clone(), t.stats_json());
        }
        Json::Obj(map)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{DeploymentBuilder, Source, Strategy};
    use crate::graph::synth;

    fn small_dep(block: usize) -> Deployment {
        DeploymentBuilder::new(
            Source::Matrix {
                label: "qm7".into(),
                matrix: synth::qm7_like(5828),
            },
            Strategy::FixedBlock { block },
        )
        .grid(2)
        .workers(2)
        .build()
        .unwrap()
    }

    fn small_registry(queue_depth: usize) -> DeploymentRegistry {
        DeploymentRegistry::new(&RegistryOptions {
            workers: 2,
            queue_depth,
            sharded: true,
            fault: None,
            remap_after: 0,
        })
    }

    #[test]
    fn admission_is_bounded_and_raii_releases() {
        let reg = small_registry(1);
        reg.insert("g", small_dep(1), None);
        let tenant = reg.get("g").unwrap();
        let guard = tenant.admit().unwrap();
        assert_eq!(tenant.inflight(), 1);
        // depth 1: the second admit is a typed busy rejection
        let err = tenant.admit().unwrap_err();
        assert_eq!(err.kind(), "busy");
        assert!(err.to_string().contains("\"g\""), "{err}");
        tenant.record_failure(&err);
        drop(guard);
        assert_eq!(tenant.inflight(), 0);
        // the slot is free again
        let _g2 = tenant.admit().unwrap();
        let stats = tenant.stats_json();
        assert_eq!(stats.get("rejected_busy").as_i64(), Some(1));
        assert_eq!(stats.get("queue_depth").as_i64(), Some(1));
    }

    #[test]
    fn unknown_tenant_error_names_known_ids() {
        let reg = small_registry(4);
        reg.insert("alpha", small_dep(1), None);
        let err = reg.get("beta").unwrap_err();
        assert_eq!(err.kind(), "validate");
        let msg = err.to_string();
        assert!(msg.contains("beta") && msg.contains("alpha"), "{msg}");
        assert_eq!(reg.ids(), vec!["alpha".to_string()]);
    }

    #[test]
    fn reload_swaps_generation_and_keeps_old_entries_alive() {
        let dir = std::env::temp_dir().join(format!("autogmap_reg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let bundle = dir.join("swap.json");
        small_dep(2).save(&bundle).unwrap();

        let reg = small_registry(4);
        reg.insert("g", small_dep(1), None);
        let tenant = reg.get("g").unwrap();
        let old = tenant.entry();
        assert_eq!(old.generation(), 1);

        let x: Vec<f64> = (0..old.dim()).map(|i| i as f64 * 0.25 - 2.0).collect();
        let want_old = old.deployment().mvm(&x).unwrap();

        let installed = reg.reload("g", &bundle).unwrap();
        assert_eq!(installed.generation(), 2);
        assert_eq!(tenant.entry().generation(), 2);
        assert_eq!(installed.bundle(), Some(bundle.as_path()));

        // the old generation still answers (in-flight requests finish on
        // it), and both generations agree with their own oracles exactly
        let (ys_old, degraded) = old.execute(vec![x.clone()], true);
        assert_eq!(ys_old[0], want_old);
        assert!(!degraded);
        let want_new = installed.deployment().mvm(&x).unwrap();
        let (ys_new, _) = tenant.entry().execute(vec![x.clone()], false);
        assert_eq!(ys_new[0], want_new);

        // reloading an unregistered id registers it
        let t2 = reg.reload("h", &bundle).unwrap();
        assert_eq!(t2.generation(), 1);
        assert_eq!(reg.ids(), vec!["g".to_string(), "h".to_string()]);
        let _ = std::fs::remove_file(&bundle);
    }

    #[test]
    fn reload_resets_the_rate_window_but_keeps_lifetime_counters() {
        let dir = std::env::temp_dir().join(format!("autogmap_regwin_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let bundle = dir.join("swap.json");
        small_dep(2).save(&bundle).unwrap();

        let reg = small_registry(4);
        reg.insert("g", small_dep(1), None);
        let tenant = reg.get("g").unwrap();
        tenant.record_served(40, tenant.entry().nnz());
        tenant.record_algo("pagerank", 21);
        let before = tenant.stats_json();
        assert!(before.get("rps").as_f64().unwrap() > 0.0);
        assert_eq!(before.get("algo").get("pagerank").as_i64(), Some(1));
        assert_eq!(before.get("algo").get("mvms").as_i64(), Some(21));

        reg.reload("g", &bundle).unwrap();
        let after = tenant.stats_json();
        // lifetime counters survive the swap; the rate window does not
        assert_eq!(after.get("served").as_i64(), Some(40));
        assert_eq!(after.get("generation").as_i64(), Some(2));
        assert_eq!(after.get("rps").as_f64(), Some(0.0), "fresh generation has served nothing");
        assert_eq!(after.get("nnz_per_s").as_f64(), Some(0.0));
        assert!(
            after.get("wall_s").as_f64().unwrap() < after.get("uptime_s").as_f64().unwrap(),
            "the rate window is the generation's uptime, not the tenant's"
        );
        assert_eq!(after.get("algo").get("pagerank").as_i64(), Some(1));

        // traffic after the swap is normalized by the new window alone
        tenant.record_served(5, tenant.entry().nnz());
        let s2 = tenant.stats_json();
        assert!(s2.get("rps").as_f64().unwrap() > 0.0);
        assert_eq!(s2.get("served").as_i64(), Some(45));
        let _ = std::fs::remove_file(&bundle);
    }

    #[test]
    fn tenants_share_one_pool_and_stats_cover_all() {
        let reg = small_registry(8);
        reg.insert("a", small_dep(1), None);
        reg.insert("b", small_dep(2), None);
        assert_eq!(reg.workers(), 2);
        let ea = reg.get("a").unwrap().entry();
        let eb = reg.get("b").unwrap().entry();
        let x: Vec<f64> = (0..ea.dim()).map(|i| (i % 7) as f64 - 3.0).collect();
        let (ya, _) = ea.execute(vec![x.clone()], true);
        let (yb, _) = eb.execute(vec![x.clone()], true);
        assert_eq!(ya[0], ea.deployment().mvm(&x).unwrap());
        assert_eq!(yb[0], eb.deployment().mvm(&x).unwrap());
        reg.get("a").unwrap().record_served(1, ea.nnz());
        let stats = reg.stats_json();
        assert_eq!(stats.get("a").get("served").as_i64(), Some(1));
        assert_eq!(stats.get("b").get("served").as_i64(), Some(0));
        assert!(stats.get("a").get("nnz_per_s").as_f64().unwrap() > 0.0);
        // the kernel-mix ledger is internally consistent per tenant
        for id in ["a", "b"] {
            let t = stats.get(id);
            let dense = t.get("kernel_dense").as_i64().unwrap();
            let sparse = t.get("kernel_sparse").as_i64().unwrap();
            assert!(dense + sparse > 0, "tenant {id} reports no kernels");
            assert_eq!(
                t.get("nnz_dense").as_i64().unwrap() + t.get("nnz_sparse").as_i64().unwrap(),
                t.get("mapped_nnz").as_i64().unwrap(),
                "tenant {id}: per-kernel nnz must partition the mapped nnz"
            );
            assert_eq!(
                t.get("mapped_nnz").as_i64().unwrap() + t.get("spilled_nnz").as_i64().unwrap(),
                t.get("nnz").as_i64().unwrap(),
                "tenant {id}: mapped + spilled must equal the total nnz"
            );
            assert_eq!(
                t.get("row_patterns").as_i64().unwrap()
                    + t.get("pattern_dedup_hits").as_i64().unwrap(),
                sparse,
                "tenant {id}: every sparse program is either a pattern owner or a dedup hit"
            );
        }
    }

    #[test]
    fn delta_engine_attaches_once_folds_on_remap_and_drops_on_reload() {
        let reg = small_registry(4);
        reg.insert("g", small_dep(2), None);
        let tenant = reg.get("g").unwrap();
        assert!(tenant.delta().is_none(), "no engine before the first update");

        let eng = reg.delta_engine("g").unwrap();
        let again = reg.delta_engine("g").unwrap();
        assert!(Arc::ptr_eq(&eng, &again), "lazy attach must be one-shot");

        let dim = eng.dim();
        let x: Vec<f64> = (0..dim).map(|i| (i % 9) as f64 * 0.5 - 2.0).collect();
        let before = tenant.entry().deployment().mvm(&x).unwrap();
        let ack = eng
            .apply(&[crate::delta::EdgeUpdate { row: 0, col: dim - 1, weight: 2.0 }])
            .unwrap();
        assert_eq!(ack.pending, 1);
        let stats = tenant.stats_json();
        assert_eq!(stats.get("delta").get("pending").as_i64(), Some(1));
        assert_eq!(stats.get("delta").get("updates").as_i64(), Some(1));

        // remap folds the overlay and bumps the tenant generation exactly
        // like a bundle reload does
        let (entry, report) = reg.remap("g").unwrap();
        assert_eq!(entry.generation(), 2);
        assert_eq!(tenant.entry().generation(), 2);
        assert_eq!(report.generation, 1);
        assert_eq!(eng.pending(), 0);
        let want = entry.deployment().mvm(&x).unwrap();
        assert_eq!(eng.mvm(&x).unwrap(), want, "entry and engine serve the same folded plan");
        assert_ne!(want, before, "the folded plan must carry the update");

        // a bundle reload replaces the graph wholesale: the engine (and
        // any pending overlay) is dropped, to be re-attached on demand
        let dir =
            std::env::temp_dir().join(format!("autogmap_regdelta_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let bundle = dir.join("swap.json");
        small_dep(1).save(&bundle).unwrap();
        reg.reload("g", &bundle).unwrap();
        assert!(tenant.delta().is_none(), "reload must drop the delta engine");
        let _ = std::fs::remove_file(&bundle);
    }

    #[test]
    fn fault_armed_registry_serves_verified_and_reports_health() {
        use crate::fault::{FaultKind, FaultOptions, FaultSpec};
        let reg = DeploymentRegistry::new(&RegistryOptions {
            workers: 2,
            queue_depth: 8,
            sharded: true,
            fault: Some(FaultOptions::default()),
            remap_after: 0,
        });
        let dep = DeploymentBuilder::new(
            Source::Matrix {
                label: "qm7".into(),
                matrix: synth::qm7_like(5828),
            },
            Strategy::FixedBlock { block: 2 },
        )
        .grid(2)
        .banks(2)
        .workers(2)
        .build()
        .unwrap();
        reg.insert("g", dep, None);
        let entry = reg.get("g").unwrap().entry();
        let h = entry.fault_harness().expect("registry must arm the harness").clone();
        let x: Vec<f64> = (0..entry.dim()).map(|i| (i % 5) as f64 - 2.0).collect();
        let want = entry.deployment().mvm(&x).unwrap();
        let oracle = entry.deployment().mvm_oracle(&x).unwrap();

        // healthy: verified path is bit-identical, not degraded
        let (ys, degraded) = entry.execute(vec![x.clone()], true);
        assert_eq!(ys[0], want);
        assert!(!degraded);
        let stats = reg.stats_json();
        assert_eq!(stats.get("g").get("health").get("armed").as_bool(), Some(true));
        assert_eq!(stats.get("g").get("health").get("degraded").as_bool(), Some(false));

        // corrupt a bank: the next answer is detected, exact, and flagged
        h.inject(&FaultSpec { bank: 0, kind: FaultKind::Outage, seed: 7 }).unwrap();
        let (ys, degraded) = entry.execute(vec![x.clone()], true);
        assert!(degraded);
        for ((a, b), c) in ys[0].iter().zip(want.iter()).zip(oracle.iter()) {
            assert!(a.to_bits() == b.to_bits() || a.to_bits() == c.to_bits());
        }
        let stats = reg.stats_json();
        let health = stats.get("g").get("health").clone();
        assert_eq!(health.get("degraded").as_bool(), Some(true));
        assert!(health.get("verify_detections").as_i64().unwrap() > 0);
        assert!(health.get("quarantined_rows").as_i64().unwrap() > 0);

        // repair restores exact healthy serving
        h.repair().unwrap();
        let (ys, degraded) = entry.execute(vec![x], true);
        assert_eq!(ys[0], want);
        assert!(!degraded);
        assert_eq!(
            reg.stats_json().get("g").get("health").get("repairs").as_i64(),
            Some(1)
        );
    }
}
