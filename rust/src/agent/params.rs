//! Controller parameter store: initialization, flattening to the AOT ABI
//! order, Adam state, and JSON checkpointing.

pub use crate::agent::lstm::Params;
use crate::runtime::manifest::ControllerEntry;
use crate::util::json::{Json, num_arr, obj};
use crate::util::rng::Pcg64;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Uniform(-0.1, 0.1) init — mirrors `model.init_params`' distribution
/// (not its bit-stream: the seed only needs to be deterministic per run,
/// the HLO artifacts never initialize parameters).
pub fn init_params(entry: &ControllerEntry, seed: u64) -> Params {
    let mut rng = Pcg64::seed_from_u64(seed ^ 0x7061_7261_6d73_0001); // "params"
    let mut params = Params::new();
    for spec in &entry.params {
        let data: Vec<f32> = (0..spec.elements())
            .map(|_| rng.uniform(-0.1, 0.1) as f32)
            .collect();
        params.insert(spec.name.clone(), data);
    }
    params
}

/// Zero-initialized tensors with the same shapes (Adam m/v).
pub fn zeros_like(entry: &ControllerEntry) -> Params {
    entry
        .params
        .iter()
        .map(|s| (s.name.clone(), vec![0.0f32; s.elements()]))
        .collect()
}

/// Full optimizer state (matches `model.adam_init`).
#[derive(Clone, Debug)]
pub struct AdamState {
    pub m: Params,
    pub v: Params,
    pub t: i32,
}

impl AdamState {
    pub fn new(entry: &ControllerEntry) -> AdamState {
        AdamState {
            m: zeros_like(entry),
            v: zeros_like(entry),
            t: 0,
        }
    }

    /// One fused Adam update from a flat ABI-order gradient (the native
    /// backend's hot path). Hyper-parameters match `model.train_step`:
    /// β₁ = 0.9, β₂ = 0.999, ε = 1e-8, bias correction with t starting
    /// at 1.
    pub fn apply_flat(
        &mut self,
        entry: &ControllerEntry,
        params: &mut Params,
        grad: &[f32],
        lr: f32,
    ) -> Result<()> {
        let total: usize = entry.params.iter().map(|s| s.elements()).sum();
        if grad.len() != total {
            bail!("flat gradient has {} elements, ABI wants {total}", grad.len());
        }
        let (b1, b2, eps) = (0.9f32, 0.999f32, 1e-8f32);
        self.t += 1;
        let bc1 = 1.0 - b1.powi(self.t);
        let bc2 = 1.0 - b2.powi(self.t);
        let mut off = 0;
        for spec in &entry.params {
            let n = spec.elements();
            let g = &grad[off..off + n];
            let p = params
                .get_mut(&spec.name)
                .with_context(|| format!("missing param {}", spec.name))?;
            let m = self
                .m
                .get_mut(&spec.name)
                .with_context(|| format!("missing adam m for {}", spec.name))?;
            let v = self
                .v
                .get_mut(&spec.name)
                .with_context(|| format!("missing adam v for {}", spec.name))?;
            for k in 0..n {
                m[k] = b1 * m[k] + (1.0 - b1) * g[k];
                v[k] = b2 * v[k] + (1.0 - b2) * g[k] * g[k];
                let mhat = m[k] / bc1;
                let vhat = v[k] / bc2;
                p[k] -= lr * mhat / (vhat.sqrt() + eps);
            }
            off += n;
        }
        Ok(())
    }
}

/// Flatten params in ABI order into literals for an artifact call.
pub fn to_literals(entry: &ControllerEntry, params: &Params) -> Result<Vec<xla::Literal>> {
    let mut out = Vec::with_capacity(entry.params.len());
    for spec in &entry.params {
        let data = params
            .get(&spec.name)
            .with_context(|| format!("missing param {}", spec.name))?;
        if data.len() != spec.elements() {
            bail!(
                "param {} has {} elements, ABI wants {:?}",
                spec.name,
                data.len(),
                spec.shape
            );
        }
        let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
        out.push(crate::runtime::literal::lit_f32(data, &dims)?);
    }
    Ok(out)
}

/// Read params back from artifact outputs (ABI order).
pub fn from_literals(entry: &ControllerEntry, lits: &[xla::Literal]) -> Result<Params> {
    if lits.len() < entry.params.len() {
        bail!(
            "expected {} param outputs, got {}",
            entry.params.len(),
            lits.len()
        );
    }
    let mut params = Params::new();
    for (spec, lit) in entry.params.iter().zip(lits.iter()) {
        let data = lit
            .to_vec::<f32>()
            .with_context(|| format!("reading param {}", spec.name))?;
        if data.len() != spec.elements() {
            bail!(
                "param {} output has {} elements, ABI wants {:?}",
                spec.name,
                data.len(),
                spec.shape
            );
        }
        params.insert(spec.name.clone(), data);
    }
    Ok(params)
}

/// Save a checkpoint (params + optimizer + bookkeeping) as JSON.
pub fn save_checkpoint(
    path: &Path,
    entry: &ControllerEntry,
    params: &Params,
    opt: &AdamState,
    epoch: usize,
    baseline: f64,
) -> Result<()> {
    let tensors = |p: &Params| -> Json {
        Json::Obj(
            p.iter()
                .map(|(k, v)| (k.clone(), num_arr(v.iter().map(|&x| x as f64))))
                .collect(),
        )
    };
    let doc = obj(vec![
        ("config", Json::Str(entry.name.clone())),
        ("epoch", Json::Num(epoch as f64)),
        ("baseline", Json::Num(baseline)),
        ("t", Json::Num(opt.t as f64)),
        ("params", tensors(params)),
        ("m", tensors(&opt.m)),
        ("v", tensors(&opt.v)),
    ]);
    std::fs::write(path, doc.to_string())
        .with_context(|| format!("writing checkpoint {}", path.display()))?;
    Ok(())
}

/// Load a checkpoint; validates shapes against the manifest entry.
pub fn load_checkpoint(
    path: &Path,
    entry: &ControllerEntry,
) -> Result<(Params, AdamState, usize, f64)> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading checkpoint {}", path.display()))?;
    let doc = Json::parse(&text).context("checkpoint is not valid JSON")?;
    if doc.get("config").as_str() != Some(entry.name.as_str()) {
        bail!(
            "checkpoint is for config {:?}, expected {:?}",
            doc.get("config").as_str(),
            entry.name
        );
    }
    let read_tensors = |key: &str| -> Result<Params> {
        let o = doc
            .get(key)
            .as_obj()
            .with_context(|| format!("checkpoint missing {key}"))?;
        let mut p = Params::new();
        for spec in &entry.params {
            let arr = o
                .get(&spec.name)
                .and_then(|v| v.as_arr())
                .with_context(|| format!("checkpoint {key} missing {}", spec.name))?;
            if arr.len() != spec.elements() {
                bail!(
                    "checkpoint {key}.{} has {} elements, ABI wants {:?}",
                    spec.name,
                    arr.len(),
                    spec.shape
                );
            }
            p.insert(
                spec.name.clone(),
                arr.iter()
                    .map(|v| v.as_f64().map(|x| x as f32).context("non-number"))
                    .collect::<Result<Vec<f32>>>()?,
            );
        }
        Ok(p)
    };
    let params = read_tensors("params")?;
    let opt = AdamState {
        m: read_tensors("m")?,
        v: read_tensors("v")?,
        t: doc.get("t").as_i64().unwrap_or(0) as i32,
    };
    let epoch = doc.get("epoch").as_usize().unwrap_or(0);
    let baseline = doc.get("baseline").as_f64().unwrap_or(0.0);
    Ok((params, opt, epoch, baseline))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ParamSpec;

    fn entry() -> ControllerEntry {
        ControllerEntry {
            name: "ck".into(),
            n: 4,
            hidden: 3,
            fill_classes: 2,
            batch: 1,
            bilstm: false,
            steps: 3,
            params: vec![
                ParamSpec { name: "x0".into(), shape: vec![3] },
                ParamSpec { name: "lstm_w".into(), shape: vec![6, 12] },
            ],
            artifacts: Default::default(),
        }
    }

    #[test]
    fn init_is_deterministic_and_bounded() {
        let e = entry();
        let a = init_params(&e, 1);
        let b = init_params(&e, 1);
        assert_eq!(a, b);
        let c = init_params(&e, 2);
        assert_ne!(a, c);
        for v in a.values().flatten() {
            assert!(v.abs() <= 0.1);
        }
    }

    #[test]
    fn literal_roundtrip() {
        let e = entry();
        let p = init_params(&e, 3);
        let lits = to_literals(&e, &p).unwrap();
        assert_eq!(lits.len(), 2);
        let back = from_literals(&e, &lits).unwrap();
        assert_eq!(p, back);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let e = entry();
        let p = init_params(&e, 4);
        let mut opt = AdamState::new(&e);
        opt.t = 17;
        opt.m.get_mut("x0").unwrap()[0] = 0.5;
        let dir = std::env::temp_dir().join("autogmap_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ck.json");
        save_checkpoint(&path, &e, &p, &opt, 42, 0.83).unwrap();
        let (p2, opt2, epoch, baseline) = load_checkpoint(&path, &e).unwrap();
        assert_eq!(p, p2);
        assert_eq!(opt2.t, 17);
        assert_eq!(opt2.m["x0"][0], 0.5);
        assert_eq!(epoch, 42);
        assert!((baseline - 0.83).abs() < 1e-12);
    }

    #[test]
    fn checkpoint_rejects_wrong_config() {
        let e = entry();
        let p = init_params(&e, 5);
        let opt = AdamState::new(&e);
        let dir = std::env::temp_dir().join("autogmap_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ck.json");
        save_checkpoint(&path, &e, &p, &opt, 0, 0.0).unwrap();
        let mut other = entry();
        other.name = "different".into();
        assert!(load_checkpoint(&path, &other).is_err());
    }

    #[test]
    fn adam_apply_flat_matches_hand_computation() {
        // single-tensor entry so the arithmetic is easy to follow
        let e = ControllerEntry {
            name: "adam".into(),
            n: 2,
            hidden: 1,
            fill_classes: 0,
            batch: 1,
            bilstm: false,
            steps: 1,
            params: vec![ParamSpec { name: "w".into(), shape: vec![2] }],
            artifacts: Default::default(),
        };
        let mut p: Params = [("w".to_string(), vec![1.0f32, -2.0])].into_iter().collect();
        let mut opt = AdamState::new(&e);
        let g = [0.5f32, -1.0];
        opt.apply_flat(&e, &mut p, &g, 0.1).unwrap();
        assert_eq!(opt.t, 1);
        // t=1: m = 0.1·g, v = 0.001·g²; mhat = g, vhat = g²
        // step = lr·g/(|g|+eps) = ±lr
        let w = &p["w"];
        assert!((w[0] - (1.0 - 0.1)).abs() < 1e-5, "w0 {}", w[0]);
        assert!((w[1] - (-2.0 + 0.1)).abs() < 1e-5, "w1 {}", w[1]);
        assert!((opt.m["w"][0] - 0.05).abs() < 1e-7);
        assert!((opt.v["w"][1] - 0.001).abs() < 1e-7);
        // wrong gradient length is rejected and leaves t advanced only on
        // the successful call
        assert!(opt.apply_flat(&e, &mut p, &[0.0], 0.1).is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        let e = entry();
        let mut p = init_params(&e, 6);
        p.get_mut("x0").unwrap().push(0.0);
        assert!(to_literals(&e, &p).is_err());
    }
}
