//! The request-dispatch core shared by every NDJSON transport.
//!
//! Both serving front ends — the single-bundle stdin loop
//! ([`crate::api::serve_loop`]) and the multi-tenant TCP tier
//! ([`crate::net`]) — speak the same wire dialect because they are built
//! from the helpers in this module instead of hand-rolling parsing and
//! formatting twice:
//!
//! - [`read_line_bounded`] — NDJSON framing with an upper bound on line
//!   length, so a malicious or broken client cannot make the server buffer
//!   an unbounded line. Oversized lines are *drained* (the connection
//!   stays usable) and reported as [`BoundedLine::TooLong`].
//! - [`parse_vec`] — request-vector validation with error messages that
//!   name both the offered and the expected length.
//! - [`execute_permuted`] — the one place a request batch crosses a
//!   [`Deployment`]: permute into served order, execute on the bound
//!   executor (sharded or scalar), permute back to original node ids, and
//!   recycle the executor's output buffers.
//! - [`error_obj`] / [`error_line`] — the *identical* machine-readable
//!   error object both transports answer with:
//!   `{"error": {"kind": <Error::kind()>, "message": ...}}`.
//! - [`check_deadline`] — the `deadline_ms` admission gate: a request
//!   whose budget expired before execution begins is rejected with a
//!   typed [`Error::Deadline`], never silently served late.

use super::deploy::{DeployedPlan, Deployment};
use super::error::{Error, Result};
use crate::algo::{
    bfs, gcn_forward, pagerank, sssp, AlgoTrace, BfsOptions, DeploymentEngine, GcnLayer,
    MvmEngine, PageRankOptions, SsspOptions,
};
use crate::engine::BatchExecutor;
use crate::util::json::{num_arr, obj, Json};
use std::io::BufRead;
use std::time::Instant;

/// Default cap on one NDJSON request line (64 MiB) — roomy enough for a
/// ~100k-dim explicit batch, small enough that a newline-free stream
/// cannot exhaust memory.
pub const DEFAULT_MAX_LINE_BYTES: usize = 64 * 1024 * 1024;

/// One framing step of a bounded NDJSON reader.
#[derive(Debug, PartialEq, Eq)]
pub enum BoundedLine {
    /// A complete line (without its trailing newline).
    Line(String),
    /// The line exceeded `limit` bytes; the excess was drained up to and
    /// including the next newline, so the stream is still line-aligned.
    TooLong { limit: usize },
    /// End of input.
    Eof,
}

/// Read one `\n`-terminated line holding at most `limit` bytes. Unlike
/// [`BufRead::read_line`], a line longer than `limit` does not grow the
/// buffer past the cap: the remainder is consumed and discarded and the
/// caller gets [`BoundedLine::TooLong`], leaving the reader positioned at
/// the start of the next line.
pub fn read_line_bounded<R: BufRead>(
    input: &mut R,
    limit: usize,
) -> std::io::Result<BoundedLine> {
    let mut acc: Vec<u8> = Vec::new();
    let mut overflowed = false;
    loop {
        let chunk = input.fill_buf()?;
        if chunk.is_empty() {
            // EOF: a part-read final line still counts as a line
            return Ok(if overflowed {
                BoundedLine::TooLong { limit }
            } else if acc.is_empty() {
                BoundedLine::Eof
            } else {
                BoundedLine::Line(String::from_utf8_lossy(&acc).into_owned())
            });
        }
        let newline = chunk.iter().position(|&b| b == b'\n');
        let take = newline.map(|p| p + 1).unwrap_or(chunk.len());
        if !overflowed {
            let keep = take - usize::from(newline.is_some());
            if acc.len() + keep > limit {
                overflowed = true;
                acc.clear();
            } else {
                acc.extend_from_slice(&chunk[..keep]);
            }
        }
        input.consume(take);
        if newline.is_some() {
            return Ok(if overflowed {
                BoundedLine::TooLong { limit }
            } else {
                BoundedLine::Line(String::from_utf8_lossy(&acc).into_owned())
            });
        }
    }
}

/// Parse one request vector against the deployment dimension. The length
/// mismatch message names *both* lengths so a client can see which side
/// is wrong without replaying the request.
pub fn parse_vec(v: &Json, dim: usize) -> Result<Vec<f64>> {
    let arr = v
        .as_arr()
        .ok_or_else(|| Error::Validate("request carries no \"x\" (or \"xs\") array".into()))?;
    if arr.len() != dim {
        return Err(Error::Validate(format!(
            "request has {} elements, deployment expects {dim}",
            arr.len()
        )));
    }
    let mut x = Vec::with_capacity(dim);
    for (i, e) in arr.iter().enumerate() {
        let f = e
            .as_f64()
            .ok_or_else(|| Error::Validate(format!("x[{i}] is not a number")))?;
        if !f.is_finite() {
            return Err(Error::Validate(format!("x[{i}] is not finite")));
        }
        x.push(f);
    }
    Ok(x)
}

/// Parse an explicit `"xs"` batch: every row validated by [`parse_vec`],
/// errors prefixed with the offending row index, empty batches rejected.
pub fn parse_batch(v: &Json, dim: usize) -> Result<Vec<Vec<f64>>> {
    let arr = v
        .as_arr()
        .ok_or_else(|| Error::Validate("\"xs\" is not an array".into()))?;
    if arr.is_empty() {
        return Err(Error::Validate("xs is empty".into()));
    }
    let mut xs = Vec::with_capacity(arr.len());
    for (i, xv) in arr.iter().enumerate() {
        let x = parse_vec(xv, dim).map_err(|e| match e {
            Error::Validate(msg) => Error::Validate(format!("xs[{i}]: {msg}")),
            other => other,
        })?;
        xs.push(x);
    }
    Ok(xs)
}

/// Permute a request batch into served order, execute it on `exec`
/// (sharded multi-RHS or scalar per-request mode), permute the answers
/// back to original node ids, and recycle the executor buffers.
pub fn execute_permuted(
    dep: &Deployment,
    exec: &BatchExecutor<DeployedPlan>,
    xs: Vec<Vec<f64>>,
    sharded: bool,
) -> Vec<Vec<f64>> {
    let permuted: Vec<Vec<f64>> = xs.iter().map(|x| dep.permute_in(x)).collect();
    let ys = if sharded {
        exec.execute_batch_sharded(permuted)
    } else {
        exec.execute_batch(permuted)
    };
    let outs: Vec<Vec<f64>> = ys.iter().map(|y| dep.permute_out(y)).collect();
    exec.recycle(ys);
    outs
}

/// [`execute_permuted`] through the deployment's fault harness when one
/// is armed: every output is ABFT-checksum-verified, quarantined rows are
/// answered by the digital reference, and the returned flag reports
/// whether this batch was served under a degraded epoch (the transports
/// surface it as `"degraded": true`). Unarmed deployments take the plain
/// path and are never degraded.
pub fn execute_verified(
    dep: &Deployment,
    exec: &BatchExecutor<DeployedPlan>,
    xs: Vec<Vec<f64>>,
    sharded: bool,
) -> (Vec<Vec<f64>>, bool) {
    match dep.fault_harness() {
        Some(h) => h.serve_permuted(dep, exec, xs, sharded),
        None => (execute_permuted(dep, exec, xs, sharded), false),
    }
}

/// Run `f` behind a panic boundary: a panic anywhere inside (a worker
/// job panic re-raised by the pool, a poisoned request, a plain bug)
/// becomes a typed [`Error::Internal`] carrying the panic message, so a
/// transport can answer the request machine-readably and keep serving
/// instead of tearing down the connection or the process.
pub fn catch_internal<T>(f: impl FnOnce() -> Result<T>) -> Result<T> {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)) {
        Ok(out) => out,
        Err(payload) => {
            let msg = if let Some(s) = payload.downcast_ref::<&str>() {
                (*s).to_string()
            } else if let Some(s) = payload.downcast_ref::<String>() {
                s.clone()
            } else {
                "request execution panicked".to_string()
            };
            Err(Error::Internal(msg))
        }
    }
}

/// The shared fault-health object both stats surfaces (the stdin stats
/// line and the TCP tier's `{"admin":"stats"}`) embed under `"health"`
/// when a fault harness is armed.
pub fn health_json(h: &crate::engine::FaultHealth) -> Json {
    obj(vec![
        ("armed", Json::Bool(h.armed)),
        ("degraded", Json::Bool(h.degraded)),
        ("generation", Json::Num(h.generation as f64)),
        ("faulty_cells", Json::Num(h.faulty_cells as f64)),
        ("quarantined_programs", Json::Num(h.quarantined_programs as f64)),
        ("quarantined_rows", Json::Num(h.quarantined_rows as f64)),
        ("failed_banks", Json::Num(h.failed_banks as f64)),
        ("verify_checks", Json::Num(h.verify_checks as f64)),
        ("verify_detections", Json::Num(h.verify_detections as f64)),
        ("scrubs", Json::Num(h.scrubs as f64)),
        ("scrub_detections", Json::Num(h.scrub_detections as f64)),
        ("repairs", Json::Num(h.repairs as f64)),
        ("degraded_served", Json::Num(h.degraded_served as f64)),
    ])
}

/// The shared `"delta"` stats object both stats surfaces (the stdin
/// stats line and the TCP tier's `{"admin":"stats"}`) embed once a
/// dynamic-graph delta engine is attached.
pub fn delta_stats_json(eng: &crate::delta::DeltaEngine) -> Json {
    obj(vec![
        ("updates", Json::Num(eng.updates_total() as f64)),
        ("pending", Json::Num(eng.pending() as f64)),
        ("remaps", Json::Num(eng.remaps_total() as f64)),
        ("generation", Json::Num(eng.generation() as f64)),
    ])
}

/// The shared machine-readable error object: `{"kind": ..., "message":
/// ...}` with the stable [`Error::kind`] label. Every transport embeds
/// exactly this object under its `"error"` key, so error handling written
/// against one front end works against the other.
pub fn error_obj(err: &Error) -> Json {
    obj(vec![
        ("kind", Json::Str(err.kind().into())),
        ("message", Json::Str(err.to_string())),
    ])
}

/// A full error response line carrying the request correlation id.
pub fn error_line(id: Json, err: &Error) -> Json {
    obj(vec![("id", id), ("error", error_obj(err))])
}

/// Enforce a request's `deadline_ms` budget at the moment execution would
/// begin. `arrival` is when the request line was read off the transport;
/// a budget of 0 ms always expires (useful as a deterministic probe).
pub fn check_deadline(arrival: Instant, deadline_ms: f64) -> Result<()> {
    let elapsed_ms = arrival.elapsed().as_secs_f64() * 1e3;
    if elapsed_ms >= deadline_ms {
        return Err(Error::Deadline { elapsed_ms, deadline_ms });
    }
    Ok(())
}

/// Parse an optional `deadline_ms` field: absent means no deadline;
/// present, it must be a finite non-negative number.
pub fn parse_deadline(doc: &Json) -> Result<Option<f64>> {
    match doc.get("deadline_ms") {
        Json::Null => Ok(None),
        v => {
            let ms = v.as_f64().filter(|m| m.is_finite() && *m >= 0.0).ok_or_else(|| {
                Error::Validate("deadline_ms must be a non-negative number".into())
            })?;
            Ok(Some(ms))
        }
    }
}

/// A parsed dynamic-graph update request: `{"update":{"edges":[[r,c,w],
/// ...]}}`. Node ids are original (pre-reordering); `w == 0` deletes the
/// edge. Both transports hand the parsed batch to
/// [`crate::delta::DeltaEngine::apply`].
#[derive(Clone, Debug)]
pub struct UpdateRequest {
    pub edges: Vec<crate::delta::EdgeUpdate>,
}

/// Recognize and validate an update request. `Ok(None)` means the
/// document carries no `"update"` key; a present-but-malformed body is a
/// typed [`Error::Validate`] naming the offending edge. Range checks
/// against the live graph happen in the delta engine, which also knows
/// `dim` — this parser only enforces wire shape and finiteness.
pub fn parse_update(doc: &Json) -> Result<Option<UpdateRequest>> {
    let body = doc.get("update");
    if body == &Json::Null {
        return Ok(None);
    }
    if body.as_obj().is_none() {
        return Err(Error::Validate("update request body must be an object".into()));
    }
    let arr = body
        .get("edges")
        .as_arr()
        .ok_or_else(|| Error::Validate("update.edges must be an array of [row, col, weight] triples".into()))?;
    if arr.is_empty() {
        return Err(Error::Validate("update.edges is empty".into()));
    }
    let mut edges = Vec::with_capacity(arr.len());
    for (i, e) in arr.iter().enumerate() {
        let triple = e.as_arr().filter(|t| t.len() == 3).ok_or_else(|| {
            Error::Validate(format!("update.edges[{i}] must be a [row, col, weight] triple"))
        })?;
        let row = triple[0].as_usize().ok_or_else(|| {
            Error::Validate(format!("update.edges[{i}] row must be a non-negative integer"))
        })?;
        let col = triple[1].as_usize().ok_or_else(|| {
            Error::Validate(format!("update.edges[{i}] col must be a non-negative integer"))
        })?;
        let weight = triple[2].as_f64().filter(|w| w.is_finite()).ok_or_else(|| {
            Error::Validate(format!("update.edges[{i}] weight must be a finite number"))
        })?;
        edges.push(crate::delta::EdgeUpdate { row, col, weight });
    }
    Ok(Some(UpdateRequest { edges }))
}

/// The shared update-acknowledgement object both transports answer with
/// under their `"update"` key.
pub fn update_ack_obj(ack: &crate::delta::UpdateAck) -> Json {
    obj(vec![
        ("applied", Json::Num(ack.applied as f64)),
        ("pending", Json::Num(ack.pending as f64)),
        ("generation", Json::Num(ack.generation as f64)),
    ])
}

/// A parsed graph-algorithm request — the four whole-algorithm kinds
/// (`{"pagerank":{...}}`, `{"bfs":{...}}`, `{"sssp":{...}}`,
/// `{"gcn":{...}}`) both transports answer via [`run_algo`].
#[derive(Clone, Debug)]
pub enum AlgoRequest {
    PageRank(PageRankOptions),
    Bfs(BfsOptions),
    Sssp(SsspOptions),
    Gcn {
        /// input features, row-major `[dim, layers[0].in_dim]`
        x: Vec<f64>,
        layers: Vec<GcnLayer>,
    },
}

impl AlgoRequest {
    /// The request key, also the response payload key and the stats
    /// counter label.
    pub fn key(&self) -> &'static str {
        match self {
            AlgoRequest::PageRank(_) => "pagerank",
            AlgoRequest::Bfs(_) => "bfs",
            AlgoRequest::Sssp(_) => "sssp",
            AlgoRequest::Gcn { .. } => "gcn",
        }
    }
}

/// A finished algorithm run in wire form: the payload to answer under
/// [`AlgoAnswer::key`], plus the MVM count for throughput accounting.
pub struct AlgoAnswer {
    pub key: &'static str,
    pub payload: Json,
    pub mvms: u64,
    /// true when any MVM of the run executed under a degraded fault epoch
    /// (the response line then carries `"degraded": true`)
    pub degraded: bool,
}

fn algo_body<'a>(doc: &'a Json, key: &str) -> Result<&'a Json> {
    let body = doc.get(key);
    if body.as_obj().is_none() {
        return Err(Error::Validate(format!("{key} request body must be an object")));
    }
    Ok(body)
}

fn field_f64(body: &Json, algo: &str, field: &str, default: f64) -> Result<f64> {
    match body.get(field) {
        Json::Null => Ok(default),
        v => v.as_f64().ok_or_else(|| {
            Error::Validate(format!("{algo}.{field} must be a number"))
        }),
    }
}

fn field_usize(body: &Json, algo: &str, field: &str, default: Option<usize>) -> Result<usize> {
    match (body.get(field), default) {
        (Json::Null, Some(d)) => Ok(d),
        (Json::Null, None) => Err(Error::Validate(format!(
            "{algo} request names no \"{field}\""
        ))),
        (v, _) => v.as_usize().ok_or_else(|| {
            Error::Validate(format!("{algo}.{field} must be a non-negative integer"))
        }),
    }
}

fn parse_gcn(body: &Json, dim: usize) -> Result<AlgoRequest> {
    let rows = body
        .get("x")
        .as_arr()
        .ok_or_else(|| Error::Validate("gcn.x must be an array of per-node feature rows".into()))?;
    if rows.len() != dim {
        return Err(Error::Validate(format!(
            "gcn.x has {} rows, deployment expects {dim}",
            rows.len()
        )));
    }
    let width = rows[0].as_arr().map(|r| r.len()).unwrap_or(0);
    if width == 0 {
        return Err(Error::Validate("gcn.x rows must be non-empty number arrays".into()));
    }
    let mut x = Vec::with_capacity(dim * width);
    for (r, row) in rows.iter().enumerate() {
        let vals = parse_vec(row, width).map_err(|e| match e {
            Error::Validate(msg) => Error::Validate(format!("gcn.x[{r}]: {msg}")),
            other => other,
        })?;
        x.extend(vals);
    }
    let specs = body
        .get("layers")
        .as_arr()
        .ok_or_else(|| Error::Validate("gcn.layers must be an array of layer objects".into()))?;
    if specs.is_empty() {
        return Err(Error::Validate("gcn.layers must name at least one layer".into()));
    }
    let mut layers = Vec::with_capacity(specs.len());
    let mut in_dim = width;
    for (k, spec) in specs.iter().enumerate() {
        let algo = format!("gcn.layers[{k}]");
        if spec.as_obj().is_none() {
            return Err(Error::Validate(format!("{algo} must be an object")));
        }
        let out_dim = field_usize(spec, &algo, "out_dim", None)?;
        if out_dim == 0 {
            return Err(Error::Validate(format!("{algo}.out_dim must be at least 1")));
        }
        let relu = match spec.get("relu") {
            Json::Null => true,
            v => v.as_bool().ok_or_else(|| {
                Error::Validate(format!("{algo}.relu must be a boolean"))
            })?,
        };
        let seed = field_usize(spec, &algo, "seed", Some(k))? as u64;
        // weights are derived deterministically from the seed, so both
        // transports (and every worker count) answer identically
        layers.push(GcnLayer::random(in_dim, out_dim, relu, seed));
        in_dim = out_dim;
    }
    Ok(AlgoRequest::Gcn { x, layers })
}

/// Recognize and validate an algorithm request. `Ok(None)` means the
/// document carries none of the four algorithm keys (the caller falls
/// through to plain `x`/`xs` handling); a present-but-malformed body is a
/// typed [`Error::Validate`] naming the offending field.
pub fn parse_algo(doc: &Json, dim: usize) -> Result<Option<AlgoRequest>> {
    let present: Vec<&str> = ["pagerank", "bfs", "sssp", "gcn"]
        .into_iter()
        .filter(|k| doc.get(k) != &Json::Null)
        .collect();
    let key = match present.as_slice() {
        [] => return Ok(None),
        [k] => *k,
        many => {
            return Err(Error::Validate(format!(
                "request carries more than one algorithm key: {many:?}"
            )))
        }
    };
    let body = algo_body(doc, key)?;
    let req = match key {
        "pagerank" => {
            let d = PageRankOptions::default();
            let opts = PageRankOptions {
                damping: field_f64(body, "pagerank", "damping", d.damping)?,
                tol: field_f64(body, "pagerank", "tol", d.tol)?,
                max_iters: field_usize(body, "pagerank", "max_iters", Some(d.max_iters))?,
            };
            opts.validate()?;
            AlgoRequest::PageRank(opts)
        }
        "bfs" => AlgoRequest::Bfs(BfsOptions {
            source: field_usize(body, "bfs", "source", None)?,
            max_levels: field_usize(body, "bfs", "max_levels", Some(0))?,
        }),
        "sssp" => AlgoRequest::Sssp(SsspOptions {
            source: field_usize(body, "sssp", "source", None)?,
            max_iters: field_usize(body, "sssp", "max_iters", Some(0))?,
            chunk: field_usize(body, "sssp", "chunk", Some(0))?,
        }),
        _ => parse_gcn(body, dim)?,
    };
    if let AlgoRequest::Bfs(BfsOptions { source, .. })
    | AlgoRequest::Sssp(SsspOptions { source, .. }) = req
    {
        if source >= dim {
            return Err(Error::Validate(format!(
                "{key}.source must be a node id below the dimension {dim}; got {source}"
            )));
        }
    }
    Ok(Some(req))
}

/// Run a parsed algorithm request on any [`MvmEngine`] and shape the wire
/// payload. `-1` stands in for "unreachable" on the wire (`-1` level,
/// `-1.0` distance) since NDJSON has no infinity literal.
pub fn run_algo_on<E: MvmEngine>(engine: &E, req: &AlgoRequest) -> Result<AlgoAnswer> {
    let (key, payload, trace): (&'static str, Vec<(&str, Json)>, AlgoTrace) = match req {
        AlgoRequest::PageRank(opts) => {
            let (scores, trace) = pagerank(engine, opts)?;
            ("pagerank", vec![("scores", num_arr(scores))], trace)
        }
        AlgoRequest::Bfs(opts) => {
            let (levels, trace) = bfs(engine, opts)?;
            let reached = levels.iter().filter(|&&l| l >= 0).count();
            (
                "bfs",
                vec![
                    ("levels", num_arr(levels.iter().map(|&l| l as f64))),
                    ("reached", Json::Num(reached as f64)),
                ],
                trace,
            )
        }
        AlgoRequest::Sssp(opts) => {
            let (dist, trace) = sssp(engine, opts)?;
            let reached = dist.iter().filter(|d| d.is_finite()).count();
            (
                "sssp",
                vec![
                    (
                        "dist",
                        num_arr(dist.iter().map(|&d| if d.is_finite() { d } else { -1.0 })),
                    ),
                    ("reached", Json::Num(reached as f64)),
                ],
                trace,
            )
        }
        AlgoRequest::Gcn { x, layers } => {
            let (z, trace) = gcn_forward(engine, x, layers)?;
            let out = layers.last().expect("validated non-empty").out_dim;
            let rows: Vec<Json> = z
                .chunks(out)
                .map(|row| num_arr(row.iter().copied()))
                .collect();
            ("gcn", vec![("features", Json::Arr(rows))], trace)
        }
    };
    let mvms = trace.mvms;
    let mut fields = payload;
    fields.push(("trace", trace.to_json()));
    Ok(AlgoAnswer { key, payload: obj(fields), mvms, degraded: false })
}

/// [`run_algo_on`] against a deployment facade: the engine permutes
/// requests into served order and answers in original node ids, so
/// algorithm semantics are identical across plan shapes and transports.
pub fn run_algo(
    dep: &Deployment,
    exec: &BatchExecutor<DeployedPlan>,
    sharded: bool,
    req: &AlgoRequest,
) -> Result<AlgoAnswer> {
    let engine = DeploymentEngine::new(dep, exec, sharded);
    let mut ans = run_algo_on(&engine, req)?;
    ans.degraded = engine.degraded();
    Ok(ans)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn bounded_reader_frames_drains_and_survives() {
        let text = "short\n".to_string() + &"x".repeat(100) + "\nafter\nlast";
        let mut r = Cursor::new(text);
        assert_eq!(read_line_bounded(&mut r, 16).unwrap(), BoundedLine::Line("short".into()));
        // the 100-byte line overflows the 16-byte cap but is fully drained
        assert_eq!(read_line_bounded(&mut r, 16).unwrap(), BoundedLine::TooLong { limit: 16 });
        assert_eq!(read_line_bounded(&mut r, 16).unwrap(), BoundedLine::Line("after".into()));
        // a final line without a trailing newline still arrives
        assert_eq!(read_line_bounded(&mut r, 16).unwrap(), BoundedLine::Line("last".into()));
        assert_eq!(read_line_bounded(&mut r, 16).unwrap(), BoundedLine::Eof);
    }

    #[test]
    fn bounded_reader_exact_limit_passes() {
        let mut r = Cursor::new("abcd\n".to_string());
        assert_eq!(read_line_bounded(&mut r, 4).unwrap(), BoundedLine::Line("abcd".into()));
        let mut r = Cursor::new("abcde\n".to_string());
        assert_eq!(read_line_bounded(&mut r, 4).unwrap(), BoundedLine::TooLong { limit: 4 });
    }

    #[test]
    fn parse_vec_names_both_lengths() {
        let doc = Json::parse("[1, 2, 3]").unwrap();
        let err = parse_vec(&doc, 5).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains('3') && msg.contains('5'), "{msg}");
        assert!(parse_vec(&doc, 3).is_ok());
        let bad = Json::parse("[1, \"x\", 3]").unwrap();
        assert!(parse_vec(&bad, 3).is_err());
    }

    #[test]
    fn parse_batch_prefixes_row_index() {
        let doc = Json::parse("[[1, 2], [1]]").unwrap();
        let err = parse_batch(&doc, 2).unwrap_err();
        assert!(err.to_string().contains("xs[1]"), "{err}");
        assert!(parse_batch(&Json::parse("[]").unwrap(), 2).is_err());
        assert_eq!(parse_batch(&Json::parse("[[1, 2]]").unwrap(), 2).unwrap().len(), 1);
    }

    #[test]
    fn deadline_zero_always_expires() {
        let t = Instant::now();
        match check_deadline(t, 0.0) {
            Err(Error::Deadline { deadline_ms, .. }) => assert_eq!(deadline_ms, 0.0),
            other => panic!("expected Deadline, got {other:?}"),
        }
        // a generous budget passes
        assert!(check_deadline(Instant::now(), 60_000.0).is_ok());
        // absent vs malformed deadline fields
        assert_eq!(parse_deadline(&Json::parse("{}").unwrap()).unwrap(), None);
        assert_eq!(
            parse_deadline(&Json::parse("{\"deadline_ms\": 5}").unwrap()).unwrap(),
            Some(5.0)
        );
        assert!(parse_deadline(&Json::parse("{\"deadline_ms\": -1}").unwrap()).is_err());
    }

    #[test]
    fn parse_algo_recognizes_kinds_and_defaults() {
        let doc = Json::parse(r#"{"id":1,"x":[1,2,3]}"#).unwrap();
        assert!(parse_algo(&doc, 3).unwrap().is_none());

        let doc = Json::parse(r#"{"pagerank":{}}"#).unwrap();
        match parse_algo(&doc, 8).unwrap().unwrap() {
            AlgoRequest::PageRank(o) => {
                assert_eq!(o.damping, 0.85);
                assert_eq!(o.max_iters, PageRankOptions::default().max_iters);
            }
            other => panic!("expected pagerank, got {other:?}"),
        }

        let doc = Json::parse(r#"{"bfs":{"source":2}}"#).unwrap();
        match parse_algo(&doc, 8).unwrap().unwrap() {
            AlgoRequest::Bfs(o) => {
                assert_eq!(o.source, 2);
                assert_eq!(o.max_levels, 0);
            }
            other => panic!("expected bfs, got {other:?}"),
        }

        let doc = Json::parse(r#"{"sssp":{"source":1,"chunk":8}}"#).unwrap();
        match parse_algo(&doc, 8).unwrap().unwrap() {
            AlgoRequest::Sssp(o) => assert_eq!(o.chunk, 8),
            other => panic!("expected sssp, got {other:?}"),
        }

        let doc = Json::parse(
            r#"{"gcn":{"x":[[1,2],[3,4]],"layers":[{"out_dim":3},{"out_dim":1,"relu":false}]}}"#,
        )
        .unwrap();
        match parse_algo(&doc, 2).unwrap().unwrap() {
            AlgoRequest::Gcn { x, layers } => {
                assert_eq!(x, vec![1.0, 2.0, 3.0, 4.0]);
                assert_eq!(layers.len(), 2);
                assert_eq!(layers[0].in_dim, 2);
                assert_eq!(layers[0].out_dim, 3);
                assert_eq!(layers[1].in_dim, 3);
                assert!(!layers[1].relu);
            }
            other => panic!("expected gcn, got {other:?}"),
        }
    }

    #[test]
    fn parse_algo_errors_name_the_field() {
        let cases = [
            (r#"{"pagerank":{"damping":2.0}}"#, "pagerank.damping"),
            (r#"{"pagerank":{"max_iters":"x"}}"#, "pagerank.max_iters"),
            (r#"{"bfs":{}}"#, "\"source\""),
            (r#"{"bfs":{"source":99}}"#, "bfs.source"),
            (r#"{"sssp":{"source":-1}}"#, "sssp.source"),
            (r#"{"gcn":{"x":[[1],[2]],"layers":[]}}"#, "gcn.layers"),
            (r#"{"gcn":{"x":[[1]],"layers":[{"out_dim":2}]}}"#, "gcn.x"),
            (r#"{"gcn":{"x":[[1],["y"]],"layers":[{"out_dim":2}]}}"#, "gcn.x[1]"),
            (r#"{"pagerank":{},"bfs":{"source":0}}"#, "more than one"),
            (r#"{"bfs":7}"#, "must be an object"),
        ];
        for (line, needle) in cases {
            let doc = Json::parse(line).unwrap();
            let err = parse_algo(&doc, 2).unwrap_err();
            assert_eq!(err.kind(), "validate", "{line}");
            assert!(err.to_string().contains(needle), "{line}: {err}");
        }
    }

    #[test]
    fn parse_update_validates_edge_triples() {
        let doc = Json::parse(r#"{"x":[1,2]}"#).unwrap();
        assert!(parse_update(&doc).unwrap().is_none());

        let doc = Json::parse(r#"{"update":{"edges":[[0,5,1.5],[3,3,0]]}}"#).unwrap();
        let req = parse_update(&doc).unwrap().unwrap();
        assert_eq!(req.edges.len(), 2);
        assert_eq!(req.edges[0].row, 0);
        assert_eq!(req.edges[0].col, 5);
        assert_eq!(req.edges[0].weight, 1.5);
        assert_eq!(req.edges[1].weight, 0.0, "zero weight = delete");

        let cases = [
            (r#"{"update":7}"#, "must be an object"),
            (r#"{"update":{}}"#, "update.edges"),
            (r#"{"update":{"edges":[]}}"#, "empty"),
            (r#"{"update":{"edges":[[1,2]]}}"#, "update.edges[0]"),
            (r#"{"update":{"edges":[[0,1,2],[-1,0,1]]}}"#, "update.edges[1]"),
            (r#"{"update":{"edges":[[0,"a",1]]}}"#, "update.edges[0] col"),
        ];
        for (line, needle) in cases {
            let doc = Json::parse(line).unwrap();
            let err = parse_update(&doc).unwrap_err();
            assert_eq!(err.kind(), "validate", "{line}");
            assert!(err.to_string().contains(needle), "{line}: {err}");
        }
    }

    #[test]
    fn error_objects_carry_stable_kinds() {
        let e = Error::Busy { tenant: "a".into(), depth: 1 };
        let o = error_obj(&e);
        assert_eq!(o.get("kind").as_str(), Some("busy"));
        assert!(o.get("message").as_str().unwrap().contains("depth limit"));
        let line = error_line(Json::Num(7.0), &e);
        assert_eq!(line.get("id").as_i64(), Some(7));
        assert_eq!(line.get("error").get("kind").as_str(), Some("busy"));
    }
}
