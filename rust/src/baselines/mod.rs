//! Baseline mapping strategies the paper compares against (Table II and
//! §Related Work), plus two oracles used for ablations:
//!
//! - **Vanilla**: fixed-size diagonal blocks (GraphR/[6]-style static
//!   partition restricted to the diagonal).
//! - **Vanilla+Fill**: Vanilla plus a fixed-size fill block pair at every
//!   junction (Balog et al. [6]: "a batch of diagonal-blocks and two
//!   additional batches of blocks to fill the gap", all sizes static).
//! - **GraphSAR-like**: sparsity-aware recursive partition (Dai et al.
//!   [2]): tile the whole matrix in `coarse`-cell blocks; store a block
//!   whole when its density > 0.5, otherwise subdivide into quadrants and
//!   keep only non-empty ones (recursing down to 1 cell).
//! - **GraphR-like**: static whole-matrix tiling keeping non-empty tiles.
//! - **DP oracle**: *optimal* diagonal-only complete-coverage partition
//!   (min total area such that every nnz falls inside a diagonal block) by
//!   O(N²) dynamic programming — a lower bound for diagonal-only RL.
//! - **Exhaustive**: brute-force over all 2^(N-1) diagonal partitions
//!   (N ≤ 20), optionally maximizing the scalarized reward instead of
//!   requiring complete coverage.

pub mod exhaustive;
pub mod oracle;

use crate::graph::GridSummary;
use crate::scheme::{FillRule, GridRect, Scheme};

/// Vanilla fixed-size diagonal partition: blocks of `block` grid cells.
pub fn vanilla(n: usize, block: usize) -> Scheme {
    assert!(block >= 1 && n >= 1);
    let mut diag_len = Vec::with_capacity(n.div_ceil(block));
    let mut left = n;
    while left > 0 {
        let l = left.min(block);
        diag_len.push(l);
        left -= l;
    }
    let fills = diag_len.len() - 1;
    Scheme {
        diag_len,
        fill_len: vec![0; fills],
    }
}

/// Vanilla + fixed-size fill at *every* junction (size `fill` grid cells,
/// clamped to the junction's neighbours like every fill in this codebase).
pub fn vanilla_fill(n: usize, block: usize, fill: usize) -> Scheme {
    let mut s = vanilla(n, block);
    let rule = FillRule::Fixed { size: fill };
    for j in 0..s.fill_len.len() {
        s.fill_len[j] = rule.fill_len(1, s.diag_len[j], s.diag_len[j + 1]);
    }
    s
}

/// GraphSAR-like sparsity-aware recursive partition over the whole grid.
/// Returns disjoint rectangles covering every non-zero (complete coverage
/// by construction). `coarse` is the top-level tile side in grid cells.
pub fn graphsar(g: &GridSummary, coarse: usize) -> Vec<GridRect> {
    assert!(coarse >= 1);
    let mut out = Vec::new();
    let n = g.n;
    let mut r0 = 0;
    while r0 < n {
        let mut c0 = 0;
        let r1 = (r0 + coarse).min(n);
        while c0 < n {
            let c1 = (c0 + coarse).min(n);
            subdivide(g, GridRect { r0, r1, c0, c1 }, &mut out);
            c0 = c1;
        }
        r0 = r1;
    }
    out
}

fn subdivide(g: &GridSummary, rect: GridRect, out: &mut Vec<GridRect>) {
    let nnz = rect.nnz(g);
    if nnz == 0 {
        return;
    }
    let area = rect.area_units(g);
    let density = nnz as f64 / area as f64;
    let h = rect.r1 - rect.r0;
    let w = rect.c1 - rect.c0;
    if density > 0.5 || (h <= 1 && w <= 1) {
        out.push(rect);
        return;
    }
    // quadrant split (GraphSAR's 8x8 -> 4x4 progressive partition)
    let rm = rect.r0 + h.div_ceil(2);
    let cm = rect.c0 + w.div_ceil(2);
    let quads = [
        GridRect { r0: rect.r0, r1: rm, c0: rect.c0, c1: cm },
        GridRect { r0: rect.r0, r1: rm, c0: cm, c1: rect.c1 },
        GridRect { r0: rm, r1: rect.r1, c0: rect.c0, c1: cm },
        GridRect { r0: rm, r1: rect.r1, c0: cm, c1: rect.c1 },
    ];
    for q in quads {
        if !q.is_empty() {
            subdivide(g, q, out);
        }
    }
}

/// GraphR-like static partition: tile the matrix with `tile`-cell blocks,
/// keep the non-empty ones.
pub fn graphr(g: &GridSummary, tile: usize) -> Vec<GridRect> {
    assert!(tile >= 1);
    let mut out = Vec::new();
    let n = g.n;
    let mut r0 = 0;
    while r0 < n {
        let r1 = (r0 + tile).min(n);
        let mut c0 = 0;
        while c0 < n {
            let c1 = (c0 + tile).min(n);
            let rect = GridRect { r0, r1, c0, c1 };
            if rect.nnz(g) > 0 {
                out.push(rect);
            }
            c0 = c1;
        }
        r0 = r1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::synth;
    use crate::scheme::{evaluate, eval::evaluate_rects, RewardWeights};

    #[test]
    fn vanilla_partitions_exactly() {
        let s = vanilla(11, 2); // QM7 grid-2: N=11
        assert_eq!(s.diag_len, vec![2, 2, 2, 2, 2, 1]);
        s.validate(11).unwrap();
        let s = vanilla(9, 3);
        assert_eq!(s.diag_len, vec![3, 3, 3]);
        s.validate(9).unwrap();
    }

    #[test]
    fn vanilla_matches_paper_table2_row1() {
        // Vanilla block 4 on QM7 (grid 1, N=22): [4,4,4,4,4,2], area 0.174.
        let m = synth::qm7_like(5828);
        let g = crate::graph::GridSummary::new(&m, 1);
        let s = vanilla(22, 4);
        assert_eq!(s.diag_len, vec![4, 4, 4, 4, 4, 2]);
        let e = evaluate(&s, &g, RewardWeights::new(0.8));
        let expect_area = (5.0 * 16.0 + 4.0) / 484.0;
        assert!((e.area_ratio - expect_area).abs() < 1e-12);
        assert!((expect_area - 0.174).abs() < 1e-3); // paper: 0.174
    }

    #[test]
    fn vanilla_fill_clamps_at_junctions() {
        let s = vanilla_fill(11, 3, 3);
        assert_eq!(s.diag_len, vec![3, 3, 3, 2]);
        // junctions: min(3,3,3)=3, min(3,3,3)=3, min(3,3,2)=2
        assert_eq!(s.fill_len, vec![3, 3, 2]);
        s.validate(11).unwrap();
    }

    #[test]
    fn vanilla_fill_matches_paper_block6_row() {
        // Vanilla+Fill block 6 fill 6 on QM7: blocks [6,6,6,4],
        // coverage 1.0, area 0.62 (paper Table II).
        let m = synth::qm7_like(5828);
        let g = crate::graph::GridSummary::new(&m, 1);
        let s = vanilla_fill(22, 6, 6);
        assert_eq!(s.diag_len, vec![6, 6, 6, 4]);
        assert_eq!(s.fill_len, vec![6, 6, 4]);
        let e = evaluate(&s, &g, RewardWeights::new(0.8));
        // area = 3·36 + 16 + 2·(36+36+16) = 300 -> 0.6198
        assert!((e.area_ratio - 300.0 / 484.0).abs() < 1e-12);
    }

    #[test]
    fn graphsar_complete_coverage() {
        let m = synth::qh882_like(882);
        let g = crate::graph::GridSummary::new(&m, 4);
        let rects = graphsar(&g, 8);
        let e = evaluate_rects(&rects, &g, RewardWeights::new(0.8));
        assert_eq!(e.coverage_ratio, 1.0);
        assert!(e.area_ratio < 1.0);
        // disjointness
        for i in 0..rects.len() {
            for j in (i + 1)..rects.len() {
                assert!(!rects[i].intersects(&rects[j]));
            }
        }
    }

    #[test]
    fn graphr_keeps_only_nonempty_tiles() {
        let m = synth::qm7_like(5828);
        let g = crate::graph::GridSummary::new(&m, 1);
        let rects = graphr(&g, 8);
        let e = evaluate_rects(&rects, &g, RewardWeights::new(0.8));
        assert_eq!(e.coverage_ratio, 1.0);
        assert!(rects.len() <= 9); // 3x3 tiling of a 22-cell grid
        assert!(rects.iter().all(|r| r.nnz(&g) > 0));
    }

    #[test]
    fn graphsar_beats_graphr_area_on_sparse() {
        // sparsity-aware subdivision must never use more area than the
        // static tiling at the same top-level tile size.
        let m = synth::qh882_like(7);
        let g = crate::graph::GridSummary::new(&m, 4);
        let sar = evaluate_rects(&graphsar(&g, 8), &g, RewardWeights::new(0.8));
        let gr = evaluate_rects(&graphr(&g, 8), &g, RewardWeights::new(0.8));
        assert!(sar.area_ratio <= gr.area_ratio);
        assert_eq!(sar.coverage_ratio, 1.0);
        assert_eq!(gr.coverage_ratio, 1.0);
    }
}
