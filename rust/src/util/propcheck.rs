//! Hand-rolled property-testing helper (no `proptest` in the vendored set).
//!
//! A property is a closure over a seeded [`Pcg64`]; the runner executes it
//! for `cases` distinct deterministic seeds and reports the failing seed so
//! a failure reproduces with `PROPCHECK_SEED=<n> cargo test <name>`.

use crate::util::rng::Pcg64;

/// Run `prop` for `cases` deterministic seeds. `prop` returns `Err(msg)` or
/// panics to signal failure. Set `PROPCHECK_SEED` to re-run a single case.
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Pcg64) -> Result<(), String>,
{
    if let Ok(seed) = std::env::var("PROPCHECK_SEED") {
        let seed: u64 = seed.parse().expect("PROPCHECK_SEED must be an integer");
        let mut rng = Pcg64::seed_from_u64(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property {name} failed for PROPCHECK_SEED={seed}: {msg}");
        }
        return;
    }
    for case in 0..cases {
        // Decorrelate case index from the seed space used elsewhere.
        let seed = case.wrapping_mul(0x9e37_79b9_7f4a_7c15).wrapping_add(1);
        let mut rng = Pcg64::seed_from_u64(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        match result {
            Ok(Ok(())) => {}
            Ok(Err(msg)) => panic!(
                "property {name} failed on case {case} (PROPCHECK_SEED={seed}): {msg}"
            ),
            Err(_) => panic!(
                "property {name} panicked on case {case} (PROPCHECK_SEED={seed})"
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("u64_roundtrip", 50, |rng| {
            let x = rng.next_u64();
            if x.wrapping_add(1).wrapping_sub(1) == x {
                Ok(())
            } else {
                Err("arithmetic broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property always_fails failed")]
    fn reports_failing_seed() {
        check("always_fails", 3, |_| Err("nope".into()));
    }
}
