//! Integration tests for the fault subsystem through the public API
//! facade: the zero-fault bit-identity contract (an armed harness that
//! never sees an injection serves exactly the unarmed path's bits — flat
//! and composite plans, 1/2/8 workers, both executor modes), and the
//! serving guarantee under stuck-at faults (every checksum-verified
//! answer is bit-identical to the healthy plan or to the host-CSR
//! oracle — wrong answers never escape, detection quarantines every
//! corrupted program, repair restores healthy serving).

use autogmap::api::dispatch::execute_verified;
use autogmap::api::{Deployment, DeploymentBuilder, Source, Strategy};
use autogmap::fault::{FaultKind, FaultOptions, FaultSpec};
use autogmap::graph::synth;
use autogmap::util::propcheck::check;
use autogmap::util::rng::Pcg64;

/// The paper's native flat path: one direct controller inference over the
/// QM7-like grid (23 nodes at cell side 2 fit qm7_dyn4's window).
fn flat_dep(banks: usize) -> Deployment {
    DeploymentBuilder::new(
        Source::Matrix {
            label: "qm7".into(),
            matrix: synth::qm7_like(5828),
        },
        Strategy::Direct {
            controller: "qm7_dyn4".into(),
        },
    )
    .grid(2)
    .rounds(1)
    .banks(banks)
    .build()
    .unwrap()
}

/// The composite path: a 200-node R-MAT graph under the fixed-block
/// baseline (diagonal blocks on the arena, off-block nnz in the digital
/// spill).
fn composite_dep(seed: u64, banks: usize) -> Deployment {
    DeploymentBuilder::new(
        Source::Matrix {
            label: "rmat200".into(),
            matrix: synth::rmat_like(200, 800, seed),
        },
        Strategy::FixedBlock { block: 2 },
    )
    .grid(8)
    .banks(banks)
    .build()
    .unwrap()
}

fn batch(rng: &mut Pcg64, dim: usize, n: usize) -> Vec<Vec<f64>> {
    (0..n)
        .map(|_| (0..dim).map(|_| rng.uniform(-2.0, 2.0)).collect())
        .collect()
}

/// The zero-fault contract as a property: arming the harness (without any
/// injection) changes no served bit relative to the unarmed path or to
/// `Deployment::mvm`, on flat and composite plans, at 1/2/8 workers, in
/// both executor modes — and no response is flagged degraded.
#[test]
fn zero_fault_harness_serves_bit_identically_to_the_unarmed_path() {
    check("fault_zero_fault_bit_identity", 2, |rng| {
        let sharded = rng.below(2) == 0;
        for flat in [true, false] {
            let mut dep = if flat {
                flat_dep(4)
            } else {
                composite_dep(7 + rng.below(3), 4)
            };
            let dim = dep.provenance.dim;
            let mut vrng = Pcg64::new(rng.next_u64(), 0x2e);
            let xs = batch(&mut vrng, dim, 5);
            let want: Vec<Vec<f64>> = xs
                .iter()
                .map(|x| dep.mvm(x).map_err(|e| e.to_string()))
                .collect::<Result<_, _>>()?;

            // the unarmed dispatch path first, then the armed one: both
            // must reproduce Deployment::mvm bit-for-bit
            for armed in [false, true] {
                if armed {
                    dep.arm_fault_harness(FaultOptions {
                        scrub_every: 2,
                        ..FaultOptions::default()
                    });
                }
                for &workers in &[1usize, 2, 8] {
                    let exec = dep.executor(workers);
                    let (got, degraded) = execute_verified(&dep, &exec, xs.clone(), sharded);
                    if degraded {
                        return Err(format!(
                            "flat={flat} armed={armed} workers={workers}: \
                             zero-fault serving flagged degraded"
                        ));
                    }
                    if got != want {
                        return Err(format!(
                            "flat={flat} armed={armed} workers={workers} sharded={sharded}: \
                             answers are not bit-identical to Deployment::mvm"
                        ));
                    }
                }
            }

            // the armed path verified and scrubbed but detected nothing
            let h = dep.fault_harness().expect("armed above").clone();
            let health = h.health();
            if !health.armed || health.degraded {
                return Err(format!("flat={flat}: bad health state {health:?}"));
            }
            if health.verify_checks < 15 {
                return Err(format!(
                    "flat={flat}: expected >=15 ABFT checks, saw {}",
                    health.verify_checks
                ));
            }
            if health.scrubs == 0 {
                return Err(format!("flat={flat}: periodic scrub never ran"));
            }
            if health.verify_detections != 0 || health.scrub_detections != 0 {
                return Err(format!(
                    "flat={flat}: phantom detection on a healthy arena ({health:?})"
                ));
            }
        }
        Ok(())
    });
}

/// Under stuck-at faults, every served element must bit-match either the
/// healthy plan or the host-CSR oracle — a wrong answer escaping the
/// checksum is a test failure. Detection quarantines 100% of the injected
/// programs, and repair restores undegraded bit-exact serving. Runs the
/// whole lifecycle twice: stuck-at-zero on a flat plan, stuck-at-one on a
/// composite.
#[test]
fn stuck_at_faults_never_escape_a_wrong_answer() {
    let cases: [(&str, Deployment, FaultKind); 2] = [
        (
            "flat/stuck0",
            flat_dep(2),
            FaultKind::StuckZero { rate: 0.5 },
        ),
        (
            "composite/stuck1",
            composite_dep(11, 4),
            FaultKind::StuckOne { rate: 0.5 },
        ),
    ];
    for (tag, mut dep, kind) in cases {
        let h = dep.arm_fault_harness(FaultOptions {
            scrub_every: 0, // this test exercises the per-request ABFT path
            ..FaultOptions::default()
        });
        let exec = dep.executor(2);
        let dim = dep.provenance.dim;
        let mut rng = Pcg64::new(0xfa57, 0xb0);

        let report = h
            .inject(&FaultSpec { bank: 0, kind, seed: 9 })
            .unwrap_or_else(|e| panic!("{tag}: inject failed: {e}"));
        assert!(report.cells_changed > 0, "{tag}: injection corrupted nothing");
        assert!(!report.programs.is_empty(), "{tag}: no program on bank 0");

        let mut degraded_seen = 0u32;
        for r in 0..20 {
            let x: Vec<f64> = (0..dim).map(|_| rng.uniform(-2.0, 2.0)).collect();
            let want = dep.mvm(&x).unwrap();
            let oracle = dep.mvm_oracle(&x).unwrap();
            let (ys, degraded) = execute_verified(&dep, &exec, vec![x], true);
            if degraded {
                degraded_seen += 1;
            }
            for (i, g) in ys[0].iter().enumerate() {
                assert!(
                    g.to_bits() == want[i].to_bits() || g.to_bits() == oracle[i].to_bits(),
                    "{tag}: req {r} row {i}: ESCAPED WRONG ANSWER \
                     (got {g}, plan {}, oracle {})",
                    want[i],
                    oracle[i]
                );
            }
        }
        assert!(degraded_seen > 0, "{tag}: corruption was never detected");

        let health = h.health();
        assert!(health.degraded, "{tag}: detection did not degrade the epoch");
        assert!(health.verify_detections >= 1, "{tag}: no ABFT detection counted");
        assert!(health.quarantined_rows > 0, "{tag}: nothing quarantined");
        let epoch = h.current_epoch();
        for p in &report.programs {
            assert!(
                epoch.quarantined_programs.contains(p),
                "{tag}: injected program {p} escaped quarantine"
            );
        }

        // repair: healthy bits come back, the degraded flag goes away
        let generation = h.repair().unwrap_or_else(|e| panic!("{tag}: repair failed: {e}"));
        assert!(generation >= 2, "{tag}: repair did not bump the fault epoch");
        let x: Vec<f64> = (0..dim).map(|_| rng.uniform(-2.0, 2.0)).collect();
        let want = dep.mvm(&x).unwrap();
        let (ys, degraded) = execute_verified(&dep, &exec, vec![x], true);
        assert!(!degraded, "{tag}: still degraded after repair");
        assert_eq!(ys[0], want, "{tag}: post-repair serving is not bit-exact");
        let health = h.health();
        assert!(!health.degraded, "{tag}");
        assert_eq!(health.repairs, 1, "{tag}");
        assert_eq!(health.quarantined_rows, 0, "{tag}");
    }
}

/// The scrub probe is the proactive detector: corruption that request
/// traffic has not touched yet is found by the periodic known-vector
/// probe, quarantined, and the very next request already serves exactly.
#[test]
fn scrub_probe_detects_silent_corruption_without_traffic() {
    let mut dep = composite_dep(13, 3);
    let h = dep.arm_fault_harness(FaultOptions::default());
    let exec = dep.executor(1);
    let dim = dep.provenance.dim;

    let report = h
        .inject(&FaultSpec {
            bank: 1,
            kind: FaultKind::Outage,
            seed: 0,
        })
        .unwrap();
    assert!(report.cells_changed > 0);
    assert!(!h.health().degraded, "injection must be silent until a detector runs");

    assert!(h.scrub(), "scrub missed a whole-bank outage");
    let health = h.health();
    assert!(health.degraded);
    assert!(health.scrub_detections >= 1);
    let epoch = h.current_epoch();
    for p in &report.programs {
        assert!(epoch.quarantined_programs.contains(p), "program {p} escaped the scrub");
    }

    // with the quarantine in place, a request through the degraded epoch
    // is answered plan-or-oracle exactly
    let mut rng = Pcg64::new(0x5c4b, 2);
    let x: Vec<f64> = (0..dim).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let want = dep.mvm(&x).unwrap();
    let oracle = dep.mvm_oracle(&x).unwrap();
    let (ys, degraded) = execute_verified(&dep, &exec, vec![x], false);
    assert!(degraded, "degraded epoch must flag its responses");
    for (i, g) in ys[0].iter().enumerate() {
        assert!(
            g.to_bits() == want[i].to_bits() || g.to_bits() == oracle[i].to_bits(),
            "row {i}: wrong answer under quarantine"
        );
    }
}
