//! Crossbar fleet: N simulated crossbar banks serving one plan.
//!
//! A deployment programs a plan's tiles onto a *fleet* of crossbar banks
//! that operate concurrently (GraphR-style sub-crossbar parallelism). The
//! fleet model answers the capacity-planning questions the cost model
//! ([`crate::crossbar::cost`]) answers for a single array: how do tiles
//! spread over banks, what does one fleet-wide MVM cost in energy, and how
//! long does it take when the slowest bank gates the answer?
//!
//! Two assignment policies:
//! - [`AssignPolicy::RoundRobin`] — tile i → bank i mod N (static, what a
//!   naive splitter does);
//! - [`AssignPolicy::BalancedNnz`] — LPT greedy on tile non-zero counts
//!   (heaviest tile first onto the lightest bank), which is what a learned
//!   sparsity-aware scheme enables: the planner knows each tile's load.
//!   Per-tile nnz comes from the plan arena's compile-time metadata
//!   ([`ExecPlan::program_nnz`]), so assignment never rescans program
//!   buffers.

use super::plan::ExecPlan;
use crate::crossbar::cost::{CostEstimate, CostModel};
use anyhow::{bail, ensure, Result};

/// Tile → bank assignment policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AssignPolicy {
    /// tile i → bank i mod N
    RoundRobin,
    /// greedy longest-processing-time on per-tile nnz
    BalancedNnz,
}

impl AssignPolicy {
    pub fn parse(s: &str) -> Result<AssignPolicy> {
        Ok(match s {
            "rr" | "round-robin" => AssignPolicy::RoundRobin,
            "balanced" | "nnz" => AssignPolicy::BalancedNnz,
            other => bail!("unknown assignment policy {other:?} (rr|balanced)"),
        })
    }
}

/// Aggregate load programmed onto one bank.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BankLoad {
    pub tiles: usize,
    /// non-zeros across the bank's tiles (compute load proxy)
    pub nnz: u64,
    /// programmed cells (clipped extents)
    pub cells: u64,
    /// ADC conversions per MVM: one per tile row inside the matrix
    pub adc_samples: u64,
    /// DAC drives per MVM: one per tile column inside the matrix
    pub dac_samples: u64,
}

/// A plan distributed over N concurrently operating crossbar banks.
#[derive(Clone, Debug)]
pub struct Fleet {
    pub banks: usize,
    pub policy: AssignPolicy,
    /// tile index (into the plan's schedule) → bank index
    pub assignment: Vec<usize>,
    pub loads: Vec<BankLoad>,
}

impl Fleet {
    /// Distribute a plan's tiles over `banks` banks.
    pub fn assign(plan: &ExecPlan, banks: usize, policy: AssignPolicy) -> Result<Fleet> {
        Fleet::assign_excluding(plan, banks, policy, &vec![false; banks.max(1)])
    }

    /// Distribute a plan's tiles over the *healthy* subset of `banks`
    /// banks: any bank with `failed[b] == true` receives no tiles. This
    /// is the fault-repair path ([`crate::fault`]) — re-programming a
    /// deployment around banks the scrub/verify loop has retired — and
    /// the all-healthy case is exactly [`Fleet::assign`] (RoundRobin
    /// walks the healthy banks in order; BalancedNnz runs LPT over
    /// them).
    pub fn assign_excluding(
        plan: &ExecPlan,
        banks: usize,
        policy: AssignPolicy,
        failed: &[bool],
    ) -> Result<Fleet> {
        ensure!(banks >= 1, "fleet needs at least one bank");
        ensure!(
            failed.len() == banks,
            "failed-bank mask covers {} banks, fleet has {banks}",
            failed.len()
        );
        let healthy: Vec<usize> = (0..banks).filter(|&b| !failed[b]).collect();
        ensure!(
            !healthy.is_empty(),
            "no healthy banks left to re-program onto ({banks} banks, all failed)"
        );
        let prog_nnz = plan.program_nnz();
        let tile_nnz = |i: usize| prog_nnz[plan.tiles[i].program];
        let mut assignment = vec![0usize; plan.tiles.len()];
        match policy {
            AssignPolicy::RoundRobin => {
                for (i, slot) in assignment.iter_mut().enumerate() {
                    *slot = healthy[i % healthy.len()];
                }
            }
            AssignPolicy::BalancedNnz => {
                let mut order: Vec<usize> = (0..plan.tiles.len()).collect();
                order.sort_by_key(|&i| std::cmp::Reverse(tile_nnz(i)));
                let mut load = vec![0u64; banks];
                for i in order {
                    let mut bank = healthy[0];
                    for &b in &healthy[1..] {
                        if load[b] < load[bank] {
                            bank = b;
                        }
                    }
                    assignment[i] = bank;
                    // every tile costs at least one read wave, so weight
                    // empty-looking tiles as 1 to keep counts balanced too
                    load[bank] += tile_nnz(i).max(1);
                }
            }
        }
        let mut loads = vec![BankLoad::default(); banks];
        for (i, t) in plan.tiles.iter().enumerate() {
            let l = &mut loads[assignment[i]];
            l.tiles += 1;
            l.nnz += tile_nnz(i);
            l.cells += (t.rows * t.cols) as u64;
            l.adc_samples += t.rows as u64;
            l.dac_samples += t.cols as u64;
        }
        Ok(Fleet {
            banks,
            policy,
            assignment,
            loads,
        })
    }

    /// Modelled latency of one fleet-wide MVM: banks run concurrently and
    /// each serializes its tiles in waves of `cost.parallel_tiles`, so the
    /// most-loaded bank gates the answer.
    pub fn mvm_latency_ns(&self, cost: &CostModel) -> f64 {
        self.bank_estimates(cost)
            .iter()
            .map(|e| e.latency_ns)
            .fold(0.0, f64::max)
    }

    /// Modelled energy of one fleet-wide MVM (sum over banks).
    pub fn mvm_energy_pj(&self, cost: &CostModel) -> f64 {
        self.bank_estimates(cost).iter().map(|e| e.energy_pj).sum()
    }

    /// Per-bank cost estimates from the shared peripheral-cost constants.
    pub fn bank_estimates(&self, cost: &CostModel) -> Vec<CostEstimate> {
        self.loads
            .iter()
            .map(|l| cost.estimate_counts(l.tiles, l.cells, l.adc_samples, l.dac_samples, 0, 0))
            .collect()
    }

    /// Load imbalance: max bank nnz over mean bank nnz (1.0 = perfect).
    pub fn imbalance(&self) -> f64 {
        let total: u64 = self.loads.iter().map(|l| l.nnz).sum();
        if total == 0 {
            return 1.0;
        }
        let mean = total as f64 / self.banks as f64;
        let max = self.loads.iter().map(|l| l.nnz).max().unwrap_or(0);
        max as f64 / mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::plan::compile;
    use crate::graph::{synth, GridSummary};
    use crate::reorder::{reorder, Reordering};
    use crate::scheme::Scheme;

    fn qh882_plan() -> ExecPlan {
        let m = synth::qh882_like(1);
        let r = reorder(&m, Reordering::CuthillMckee);
        let g = GridSummary::new(&r.matrix, 32);
        let scheme = Scheme {
            diag_len: vec![g.n],
            fill_len: vec![],
        };
        compile(&r.matrix, &g, &scheme).unwrap()
    }

    #[test]
    fn assignment_covers_every_tile_exactly_once() {
        let plan = qh882_plan();
        for banks in [1usize, 2, 8] {
            for policy in [AssignPolicy::RoundRobin, AssignPolicy::BalancedNnz] {
                let fleet = Fleet::assign(&plan, banks, policy).unwrap();
                assert_eq!(fleet.assignment.len(), plan.tiles.len());
                assert!(fleet.assignment.iter().all(|&b| b < banks));
                let tiles: usize = fleet.loads.iter().map(|l| l.tiles).sum();
                assert_eq!(tiles, plan.tiles.len());
                let cells: u64 = fleet.loads.iter().map(|l| l.cells).sum();
                assert_eq!(cells, plan.cells());
            }
        }
    }

    #[test]
    fn balanced_policy_meets_the_greedy_bound() {
        // LPT greedy guarantee: when the fullest bank received its last
        // tile it was the emptiest, so max load ≤ mean + heaviest tile.
        let plan = qh882_plan();
        let prog_nnz = plan.program_nnz();
        // elision means every placed tile has nnz >= 1, so the policy's
        // weights are exactly the raw per-tile nnz
        let heaviest = plan.tiles.iter().map(|t| prog_nnz[t.program]).max().unwrap();
        assert!(plan.tiles.iter().all(|t| prog_nnz[t.program] >= 1));
        let total: u64 = plan.tiles.iter().map(|t| prog_nnz[t.program]).sum();
        for banks in [2usize, 8] {
            let bal = Fleet::assign(&plan, banks, AssignPolicy::BalancedNnz).unwrap();
            let max_nnz = bal.loads.iter().map(|l| l.nnz).max().unwrap();
            let mean = total as f64 / banks as f64;
            assert!(
                (max_nnz as f64) <= mean + heaviest as f64 + 1.0,
                "banks {banks}: max {max_nnz} exceeds mean {mean} + heaviest {heaviest}"
            );
            assert!(bal.imbalance() >= 1.0 - 1e-12);
        }
    }

    #[test]
    fn fleet_latency_drops_with_more_banks() {
        let plan = qh882_plan();
        let mut cost = CostModel::default();
        cost.parallel_tiles = 1; // serialize within a bank to expose scaling
        let one = Fleet::assign(&plan, 1, AssignPolicy::BalancedNnz).unwrap();
        let eight = Fleet::assign(&plan, 8, AssignPolicy::BalancedNnz).unwrap();
        let l1 = one.mvm_latency_ns(&cost);
        let l8 = eight.mvm_latency_ns(&cost);
        assert!(l8 < l1, "8 banks {l8} should beat 1 bank {l1}");
        // energy is conserved: same tiles, same cells, just spread out
        let e1 = one.mvm_energy_pj(&cost);
        let e8 = eight.mvm_energy_pj(&cost);
        assert!((e1 - e8).abs() < 1e-6 * e1.max(1.0));
    }

    #[test]
    fn excluding_failed_banks_reassigns_onto_healthy_ones() {
        let plan = qh882_plan();
        for policy in [AssignPolicy::RoundRobin, AssignPolicy::BalancedNnz] {
            // no exclusions -> exactly the plain assignment
            let plain = Fleet::assign(&plan, 4, policy).unwrap();
            let none = Fleet::assign_excluding(&plan, 4, policy, &[false; 4]).unwrap();
            assert_eq!(plain.assignment, none.assignment);
            // retire bank 1: it must end up with zero tiles, coverage holds
            let failed = [false, true, false, false];
            let fleet = Fleet::assign_excluding(&plan, 4, policy, &failed).unwrap();
            assert!(fleet.assignment.iter().all(|&b| b != 1));
            assert_eq!(fleet.loads[1], BankLoad::default());
            let tiles: usize = fleet.loads.iter().map(|l| l.tiles).sum();
            assert_eq!(tiles, plan.tiles.len());
        }
        // a mask that retires every bank is a typed failure
        assert!(Fleet::assign_excluding(&plan, 2, AssignPolicy::RoundRobin, &[true, true]).is_err());
        // a mask of the wrong width is rejected
        assert!(Fleet::assign_excluding(&plan, 2, AssignPolicy::RoundRobin, &[false]).is_err());
    }

    #[test]
    fn assign_excluding_properties_hold_on_random_fleets() {
        // random plans × random bank counts × random exclusion masks, both
        // policies: every tile lands on exactly one healthy bank, retired
        // banks stay empty, and BalancedNnz keeps the LPT greedy bound on
        // its own weights (per-tile nnz, floored at 1).
        crate::util::propcheck::check("fleet_assign_excluding", 24, |rng| {
            let dim = 48 + rng.below(120) as usize;
            let band = 1 + rng.below(6) as usize;
            let m = synth::banded_like(dim, 0.9, band);
            let g = GridSummary::new(&m, 8);
            // random diagonal partition -> many tiles of varying nnz
            let mut diag = Vec::new();
            let mut left = g.n;
            while left > 0 {
                let b = (1 + rng.below(4) as usize).min(left);
                diag.push(b);
                left -= b;
            }
            let scheme = Scheme { diag_len: diag, fill_len: vec![] };
            let plan = compile(&m, &g, &scheme).map_err(|e| e.to_string())?;
            let banks = 1 + rng.below(8) as usize;
            let mut failed = vec![false; banks];
            for f in failed.iter_mut() {
                *f = rng.below(3) == 0;
            }
            if failed.iter().all(|&f| f) {
                failed[rng.below(banks as u64) as usize] = false;
            }
            let healthy: Vec<usize> = (0..banks).filter(|&b| !failed[b]).collect();
            let prog_nnz = plan.program_nnz();
            let weight = |i: usize| prog_nnz[plan.tiles[i].program].max(1);
            for policy in [AssignPolicy::RoundRobin, AssignPolicy::BalancedNnz] {
                let fleet = Fleet::assign_excluding(&plan, banks, policy, &failed)
                    .map_err(|e| e.to_string())?;
                if fleet.assignment.len() != plan.tiles.len() {
                    return Err(format!(
                        "{policy:?}: {} assignments for {} tiles",
                        fleet.assignment.len(),
                        plan.tiles.len()
                    ));
                }
                if let Some(&b) = fleet.assignment.iter().find(|&&b| failed[b]) {
                    return Err(format!("{policy:?}: tile landed on retired bank {b}"));
                }
                let tiles: usize = fleet.loads.iter().map(|l| l.tiles).sum();
                if tiles != plan.tiles.len() {
                    return Err(format!(
                        "{policy:?}: loads count {tiles} tiles, plan has {}",
                        plan.tiles.len()
                    ));
                }
                for &b in &healthy {
                    let want: u64 = fleet
                        .assignment
                        .iter()
                        .enumerate()
                        .filter(|&(_, &bank)| bank == b)
                        .map(|(i, _)| prog_nnz[plan.tiles[i].program])
                        .sum();
                    if fleet.loads[b].nnz != want {
                        return Err(format!(
                            "{policy:?}: bank {b} load {} != assigned nnz {want}",
                            fleet.loads[b].nnz
                        ));
                    }
                }
                if policy == AssignPolicy::BalancedNnz && !plan.tiles.is_empty() {
                    let mut wload = vec![0u64; banks];
                    for (i, &b) in fleet.assignment.iter().enumerate() {
                        wload[b] += weight(i);
                    }
                    let total: u64 = (0..plan.tiles.len()).map(weight).sum();
                    let heaviest = (0..plan.tiles.len()).map(weight).max().unwrap();
                    let mean = total as f64 / healthy.len() as f64;
                    let max = healthy.iter().map(|&b| wload[b]).max().unwrap();
                    if (max as f64) > mean + heaviest as f64 + 1e-9 {
                        return Err(format!(
                            "balance bound broken: max {max} > mean {mean} + heaviest {heaviest}"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn policy_parsing() {
        assert_eq!(AssignPolicy::parse("rr").unwrap(), AssignPolicy::RoundRobin);
        assert_eq!(
            AssignPolicy::parse("balanced").unwrap(),
            AssignPolicy::BalancedNnz
        );
        assert!(AssignPolicy::parse("magic").is_err());
    }

    #[test]
    fn empty_plan_fleet_is_sane() {
        // a plan with zero placed tiles (all elided) still forms a fleet
        let m = crate::graph::Coo::new(8, 8).to_csr();
        let g = GridSummary::new(&m, 2);
        let scheme = Scheme {
            diag_len: vec![g.n],
            fill_len: vec![],
        };
        let plan = compile(&m, &g, &scheme).unwrap();
        assert_eq!(plan.tiles.len(), 0);
        assert_eq!(plan.elided_tiles, plan.scheduled_tiles);
        let fleet = Fleet::assign(&plan, 4, AssignPolicy::BalancedNnz).unwrap();
        assert_eq!(fleet.imbalance(), 1.0);
        assert_eq!(fleet.mvm_latency_ns(&CostModel::default()), 0.0);
        assert!(Fleet::assign(&plan, 0, AssignPolicy::RoundRobin).is_err());
    }
}
