//! The RL agent driver: REINFORCE-with-baseline training loop (Algo. 2/3)
//! executed against a pluggable [`TrainBackend`].
//!
//! Per epoch the trainer makes exactly two backend calls:
//!   1. `rollout` — sample a batch of B episodes;
//!   2. `train_step` — teacher-forced REINFORCE + Adam update;
//! everything between (scheme parsing, the environment reward, the EMA
//! baseline, best-solution tracking) is plain Rust on the grid prefix sums
//! and identical across backends.
//!
//! Backends (see [`backend`]):
//! - [`backend::PjrtBackend`] runs the AOT `rollout_<cfg>` / `train_<cfg>`
//!   HLO artifacts through PJRT (requires `artifacts/`);
//! - [`native::NativeBackend`] is pure Rust — mirror-forward sampling on a
//!   worker pool plus full backprop-through-time — and needs no artifacts
//!   at all, so training works on a fresh checkout (`--backend native`, or
//!   `auto` which picks it whenever `artifacts/` is absent).

pub mod backend;
pub mod complexity;
pub mod lstm;
pub mod native;
pub mod params;

pub use backend::{BackendKind, PjrtBackend, RolloutBatch, StepStats, TrainBackend};
pub use native::NativeBackend;

use crate::graph::GridSummary;
use crate::runtime::manifest::ControllerEntry;
use crate::runtime::Runtime;
use crate::scheme::{evaluate, parse_actions, EvalResult, FillRule, RewardWeights, Scheme};
use crate::util::rng::Pcg64;
use anyhow::{ensure, Result};
use params::Params;
use std::path::Path;

/// Training hyper-parameters (paper defaults where stated).
#[derive(Clone, Copy, Debug)]
pub struct TrainOptions {
    pub lr: f32,
    /// entropy bonus; 0 reproduces the paper exactly.
    pub ent_coef: f32,
    /// EMA decay of the reward baseline (Algo. 2 line 1).
    pub baseline_decay: f64,
    /// scalarization weights (Eq. 21).
    pub weights: RewardWeights,
    /// fill geometry rule (must agree with the controller's fill_classes).
    pub fill_rule: FillRule,
    pub seed: u64,
    /// rollout/BPTT worker threads for the native backend (≥ 1; the PJRT
    /// backend ignores it). Results are identical for any value.
    pub workers: usize,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions {
            lr: 0.01,
            ent_coef: 0.0,
            baseline_decay: 0.95,
            weights: RewardWeights::new(0.8),
            fill_rule: FillRule::None,
            seed: 0,
            workers: 1,
        }
    }
}

/// Per-epoch statistics, logged by the coordinator.
#[derive(Clone, Debug)]
pub struct EpochStats {
    pub epoch: usize,
    pub mean_reward: f64,
    pub max_reward: f64,
    pub mean_coverage: f64,
    pub mean_area: f64,
    /// fraction of the batch reaching complete coverage
    pub frac_complete: f64,
    pub baseline: f64,
    pub loss: f32,
    pub mean_logp: f32,
}

/// Best-so-far complete-coverage solution.
#[derive(Clone, Debug)]
pub struct BestSolution {
    pub scheme: Scheme,
    pub eval: EvalResult,
    pub epoch: usize,
}

/// Seed-domain separator: the trainer's epoch-key stream must differ from
/// parameter init and every other consumer of the run seed.
const TRAINER_RNG_SALT: u64 = 0x6167_656e_7400_0001; // "agent"

/// REINFORCE trainer bound to one controller config + one matrix,
/// delegating rollouts and gradient steps to a [`TrainBackend`].
pub struct Trainer {
    pub entry: ControllerEntry,
    backend: Box<dyn TrainBackend>,
    pub baseline: f64,
    baseline_init: bool,
    rng: Pcg64,
    pub opts: TrainOptions,
    /// best *complete-coverage* solution by area (the paper's deployable pick)
    pub best: Option<BestSolution>,
    /// best solution by scalarized reward regardless of coverage (what the
    /// paper's diagonal-only Table II rows report, e.g. C=0.875 A=0.438)
    pub best_reward: Option<BestSolution>,
    pub epoch: usize,
}

impl Trainer {
    /// PJRT-backed trainer (requires AOT artifacts).
    pub fn new(rt: &Runtime, entry: ControllerEntry, opts: TrainOptions) -> Result<Trainer> {
        let be = PjrtBackend::new(rt, entry.clone(), opts.seed)?;
        Trainer::with_backend(Box::new(be), entry, opts)
    }

    /// Pure-Rust trainer (no artifacts needed).
    pub fn native(entry: ControllerEntry, opts: TrainOptions) -> Result<Trainer> {
        let be = NativeBackend::new(entry.clone(), opts.seed, opts.workers);
        Trainer::with_backend(Box::new(be), entry, opts)
    }

    /// Wrap an already-constructed backend.
    pub fn with_backend(
        backend: Box<dyn TrainBackend>,
        entry: ControllerEntry,
        opts: TrainOptions,
    ) -> Result<Trainer> {
        validate_fill_rule(&entry, &opts.fill_rule)?;
        Ok(Trainer {
            rng: Pcg64::seed_from_u64(opts.seed ^ TRAINER_RNG_SALT),
            entry,
            backend,
            baseline: 0.0,
            baseline_init: false,
            opts,
            best: None,
            best_reward: None,
            epoch: 0,
        })
    }

    /// Which backend this trainer runs on ("native" / "pjrt").
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Host-synced copy of the current parameters.
    pub fn params(&self) -> Result<Params> {
        self.backend.params()
    }

    /// Save params + optimizer + bookkeeping as a JSON checkpoint.
    pub fn save_checkpoint(&self, path: &Path) -> Result<()> {
        let p = self.backend.params()?;
        let opt = self.backend.opt_state()?;
        params::save_checkpoint(path, &self.entry, &p, &opt, self.epoch, self.baseline)
    }

    /// Restore params/opt/baseline from a checkpoint file. The epoch-key
    /// stream is replayed to the checkpoint's epoch, so a resumed run
    /// draws exactly the rollouts the uninterrupted run would have drawn
    /// and reproduces its epoch stats bit-for-bit.
    ///
    /// Scope: best-so-far *tracking* restarts — checkpoints do not carry
    /// the `best`/`best_reward` schemes, so a solution found only before
    /// the checkpoint is not re-reported by the resumed run.
    pub fn restore(&mut self, path: &Path) -> Result<()> {
        let (p, o, epoch, baseline) = params::load_checkpoint(path, &self.entry)?;
        self.backend.load_state(p, o)?;
        self.epoch = epoch;
        self.baseline = baseline;
        self.baseline_init = true;
        self.rng = Pcg64::seed_from_u64(self.opts.seed ^ TRAINER_RNG_SALT);
        for _ in 0..2 * epoch {
            self.rng.next_u32();
        }
        Ok(())
    }

    /// One REINFORCE epoch (Algo. 3 lines 2-8). Returns batch statistics.
    pub fn epoch(&mut self, grid: &GridSummary) -> Result<EpochStats> {
        let b = self.entry.batch;
        ensure!(
            grid.n == self.entry.n,
            "grid has {} cells but config {} expects {}",
            grid.n,
            self.entry.name,
            self.entry.n
        );

        // --- sample B episodes
        let key = [self.rng.next_u32(), self.rng.next_u32()];
        let rb = self.backend.rollout(key)?;

        // --- environment: parse + evaluate each episode
        let evals = self.evaluate_batch(grid, &rb.d_all, &rb.f_all);
        let rewards: Vec<f64> = evals.iter().map(|e| e.reward).collect();
        let mean_reward = rewards.iter().sum::<f64>() / b as f64;
        let max_reward = rewards.iter().cloned().fold(f64::MIN, f64::max);

        // --- EMA baseline (Algo. 2 line 1)
        if !self.baseline_init {
            self.baseline = mean_reward;
            self.baseline_init = true;
        } else {
            self.baseline = self.opts.baseline_decay * self.baseline
                + (1.0 - self.opts.baseline_decay) * mean_reward;
        }
        let adv: Vec<f32> = rewards.iter().map(|r| (r - self.baseline) as f32).collect();

        // --- track the best complete-coverage and best-reward solutions
        for (i, e) in evals.iter().enumerate() {
            if e.coverage_ratio >= 1.0 {
                let better = match &self.best {
                    None => true,
                    Some(bst) => e.covered_area_units < bst.eval.covered_area_units,
                };
                if better {
                    let scheme = self.parse_episode(grid, &rb.d_all, &rb.f_all, i);
                    self.best = Some(BestSolution {
                        scheme,
                        eval: e.clone(),
                        epoch: self.epoch,
                    });
                }
            }
            let better_reward = match &self.best_reward {
                None => true,
                Some(bst) => e.reward > bst.eval.reward,
            };
            if better_reward {
                let scheme = self.parse_episode(grid, &rb.d_all, &rb.f_all, i);
                self.best_reward = Some(BestSolution {
                    scheme,
                    eval: e.clone(),
                    epoch: self.epoch,
                });
            }
        }

        // --- REINFORCE + Adam step
        let step = self.backend.train_step(
            &rb.d_all,
            &rb.f_all,
            &adv,
            self.opts.lr,
            self.opts.ent_coef,
        )?;

        let stats = EpochStats {
            epoch: self.epoch,
            mean_reward,
            max_reward,
            mean_coverage: evals.iter().map(|e| e.coverage_ratio).sum::<f64>() / b as f64,
            mean_area: evals.iter().map(|e| e.area_ratio).sum::<f64>() / b as f64,
            frac_complete: evals.iter().filter(|e| e.coverage_ratio >= 1.0).count() as f64
                / b as f64,
            baseline: self.baseline,
            loss: step.loss,
            mean_logp: step.mean_logp,
        };
        self.epoch += 1;
        Ok(stats)
    }

    /// Deterministic greedy decode with the current parameters.
    pub fn greedy(&mut self, grid: &GridSummary) -> Result<(Scheme, EvalResult)> {
        let (d, f) = self.backend.greedy()?;
        let t = self.entry.steps;
        ensure!(
            d.len() >= t && f.len() >= t,
            "greedy decode returned {} actions, need {t}",
            d.len()
        );
        let scheme = self.parse_episode(grid, &d, &f, 0);
        let eval = evaluate(&scheme, grid, self.opts.weights);
        Ok((scheme, eval))
    }

    fn parse_episode(
        &self,
        grid: &GridSummary,
        d_all: &[i32],
        f_all: &[i32],
        i: usize,
    ) -> Scheme {
        let t = self.entry.steps;
        let d: Vec<u8> = d_all[i * t..(i + 1) * t].iter().map(|&x| x as u8).collect();
        let f: Vec<usize> = f_all[i * t..(i + 1) * t]
            .iter()
            .map(|&x| x as usize)
            .collect();
        parse_actions(grid.n, &d, &f, self.opts.fill_rule)
    }

    fn evaluate_batch(
        &self,
        grid: &GridSummary,
        d_all: &[i32],
        f_all: &[i32],
    ) -> Vec<EvalResult> {
        (0..self.entry.batch)
            .map(|i| {
                let s = self.parse_episode(grid, d_all, f_all, i);
                evaluate(&s, grid, self.opts.weights)
            })
            .collect()
    }
}

/// The controller's fill head and the Rust geometry rule must agree on the
/// number of classes.
pub fn validate_fill_rule(entry: &ControllerEntry, rule: &FillRule) -> Result<()> {
    let expected = rule.num_classes();
    ensure!(
        entry.fill_classes == expected,
        "config {} has {} fill classes but rule {:?} implies {}",
        entry.name,
        entry.fill_classes,
        rule,
        expected
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::synth;
    use crate::reorder::{reorder, Reordering};

    #[test]
    fn fill_rule_mismatch_is_rejected() {
        let entry = ControllerEntry::from_dims("x", 4, 2, 4, 1, false);
        assert!(validate_fill_rule(&entry, &FillRule::None).is_err());
        assert!(validate_fill_rule(&entry, &FillRule::Fixed { size: 1 }).is_err());
        assert!(validate_fill_rule(&entry, &FillRule::Dynamic { grades: 4 }).is_ok());
    }

    #[test]
    fn native_trainer_runs_epochs_and_tracks_best() {
        let m = synth::qm7_like(5828);
        let r = reorder(&m, Reordering::CuthillMckee);
        let grid = GridSummary::new(&r.matrix, 2);
        let entry = ControllerEntry::from_dims("qm7_dyn4", 11, 10, 4, 8, false);
        let opts = TrainOptions {
            lr: 0.02,
            ent_coef: 0.002,
            fill_rule: FillRule::Dynamic { grades: 4 },
            seed: 5,
            workers: 2,
            ..Default::default()
        };
        let mut trainer = Trainer::native(entry, opts).unwrap();
        assert_eq!(trainer.backend_name(), "native");
        for _ in 0..20 {
            let stats = trainer.epoch(&grid).unwrap();
            assert!(stats.loss.is_finite());
            assert!(stats.mean_logp < 0.0);
            assert!((0.0..=1.0).contains(&stats.mean_coverage));
        }
        assert_eq!(trainer.epoch, 20);
        // best-by-reward always exists after the first epoch
        let br = trainer.best_reward.as_ref().unwrap();
        br.scheme.validate(grid.n).unwrap();
        // greedy decodes a valid scheme too
        let (scheme, eval) = trainer.greedy(&grid).unwrap();
        scheme.validate(grid.n).unwrap();
        assert!(eval.reward.is_finite());
    }

    #[test]
    fn trainer_rejects_mismatched_grid() {
        let m = synth::qm7_like(5828);
        let grid = GridSummary::new(&m, 1); // 22 cells, config expects 11
        let entry = ControllerEntry::from_dims("qm7_diag", 11, 10, 0, 8, false);
        let mut trainer = Trainer::native(entry, TrainOptions::default()).unwrap();
        assert!(trainer.epoch(&grid).is_err());
    }
}
