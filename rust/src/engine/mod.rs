//! Execution engine: from trained mapping scheme to served MVM traffic.
//!
//! The paper ends where a scheme is chosen; this subsystem is the layer
//! between mapping and measurement that *executes* schemes at scale. The
//! flow is **(mapper →) plan → fleet → batch**:
//!
//! 0. **[`crate::mapper`]** (optional front stage) — for matrices far
//!    beyond the controller's native grid, the hierarchical mapper windows
//!    the matrix, infers one scheme per window, and stitches them into a
//!    [`crate::scheme::CompositeScheme`]; each window then compiles to its
//!    own plan ([`compile_rects`]) and the plans merge ([`merge_plans`])
//!    into one fleet-servable schedule with cross-window program dedup.
//! 1. **[`plan`]** — compile `Scheme + Csr + GridSummary` into an
//!    [`ExecPlan`]: a tile schedule with all-zero tiles elided, identical
//!    tile programmings deduplicated into one contiguous f32 **program
//!    arena** (per-program offset, extents, compile-time nnz, and kernel
//!    kind in [`ProgramMeta`]), tiles stable-sorted into disjoint **row
//!    bands** ([`Band`]) for write locality and intra-request sharding,
//!    **density-adaptive kernels** (dense row-dot vs compiled
//!    CSR-within-tile below [`plan::DEFAULT_SPARSE_THRESHOLD`]), a
//!    **multi-RHS kernel** ([`ExecPlan::mvm_span_batch`]) that serves a
//!    whole batch per arena traversal, and JSON (de)serialization
//!    (version 3 artifacts; versions 1 and 2 still load, gaining the
//!    pattern table and lane alignment on the way in).
//!
//! ## Kernel architecture
//!
//! The serving hot path is explicitly laid out for SIMD without ever
//! reassociating an f64 accumulation (the bit-identity contract):
//!
//! - **Lane alignment** — program offsets are padded at compile time so
//!    every dense program body starts on a [`LANE`]-wide f32 boundary
//!    (8 × 4 B = one 32-byte vector row); artifact readers repack old
//!    arenas onto the same boundaries on load.
//! - **Independent-chain unrolling** — the vectorized kernels unroll 4
//!    wide across *independent* accumulators only: 4 output rows per step
//!    in the single-RHS dense kernel, 4 requests per step in the
//!    multi-RHS dense/sparse kernels, and 4 pipelined gather products
//!    folded in scalar order in the single-RHS sparse kernel. One row's
//!    column sum is never split, so every path stays bit-identical to the
//!    preserved scalar loop ([`ExecPlan::mvm_scalar_into`]).
//! - **Row-pattern table** — sparse programs with identical row-pointer +
//!    column-index structure share one [`PatternMeta`] kernel body
//!    (FNV-hashed signatures, exact-compare collision chains — the
//!    mapper's window-signature cache idiom); only values stay
//!    per-program. The table ships in the v3 artifact and is re-derived
//!    and cross-checked on load.
//! 2. **[`fleet`]** — distribute the plan's tiles over N simulated
//!    crossbar banks ([`Fleet`]): round-robin or nnz-load-balanced
//!    assignment (reading the arena's cached per-program nnz — no buffer
//!    rescans), with per-bank energy/latency accounting built on
//!    [`crate::crossbar::cost::CostModel`].
//! 3. **[`batch`]** — serve request traffic: the one generic std-thread
//!    worker pool ([`BatchExecutor`]) over the unified [`Servable`] trait
//!    (implemented by [`ExecPlan`] and the mapper's `CompositePlan`
//!    alike, and reporting [`ServeStats`]), with two modes, both
//!    bit-identical to the [`crate::crossbar::CrossbarArray::mvm`] oracle
//!    for any worker count and batch size — scalar per-request fan-out
//!    (the seed mode), and the optimized mode that shards nnz-balanced
//!    row-band spans across workers *within* a request batch, each span
//!    serving every request through the multi-RHS kernel. The
//!    `crate::api` facade wraps this stage into deployments: build once,
//!    save a bundle, reload, serve (`deploy` / `serve` subcommands).
//!
//! The `serve-bench` CLI subcommand drives stages 1–3 against synthetic
//! request traces (this module's [`synth_trace`]), reports the
//! scalar-baseline and optimized throughput side by side (nnz/s, p50/p99),
//! and records both in `BENCH_engine.json`; `map-large` drives the whole
//! pipeline from a 100k-node graph down to served traffic
//! (`BENCH_mapper.json`).

pub mod batch;
pub mod fleet;
pub mod plan;

pub use batch::{BatchExecutor, FaultHealth, Servable, ServeStats};
pub use fleet::{AssignPolicy, BankLoad, Fleet};
pub use plan::{
    compile, compile_rects, merge_plans, Band, ExecPlan, KernelKind, PatternMeta, ProgramMeta,
    TileSpec, LANE,
};

use crate::util::rng::Pcg64;
use anyhow::{bail, Result};

/// Shape of a synthetic request trace.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceKind {
    /// constant batch size, dense random inputs
    Uniform,
    /// heavy-tailed batch sizes (exponential around the nominal size):
    /// quiet single-request stretches punctuated by large bursts
    Bursty,
    /// batch-supermatrix traffic: each request targets one sub-graph's
    /// index segment and is zero elsewhere
    BatchGraph,
}

impl TraceKind {
    pub fn parse(s: &str) -> Result<TraceKind> {
        Ok(match s {
            "uniform" => TraceKind::Uniform,
            "bursty" => TraceKind::Bursty,
            "batch" | "batch-graph" => TraceKind::BatchGraph,
            other => bail!("unknown trace kind {other:?} (uniform|bursty|batch)"),
        })
    }
}

/// Generate a deterministic request trace: a sequence of batches of input
/// vectors totalling exactly `requests` requests.
///
/// `segments` are the index ranges of the workload's sub-graphs (one
/// `(start, end)` pair per sub-graph of a batch supermatrix; pass
/// `&[(0, dim)]` for monolithic matrices) — only [`TraceKind::BatchGraph`]
/// uses them.
pub fn synth_trace(
    kind: TraceKind,
    dim: usize,
    requests: usize,
    batch: usize,
    segments: &[(usize, usize)],
    seed: u64,
) -> Vec<Vec<Vec<f64>>> {
    assert!(batch >= 1, "nominal batch size must be positive");
    assert!(
        !segments.is_empty() && segments.iter().all(|&(s, e)| s < e && e <= dim),
        "segments must be non-empty ranges inside the matrix"
    );
    let mut rng = Pcg64::seed_from_u64(seed ^ 0x7472_6163_6500_0001); // "trace"
    let mut batches = Vec::new();
    let mut left = requests;
    while left > 0 {
        let size = match kind {
            TraceKind::Uniform | TraceKind::BatchGraph => batch,
            TraceKind::Bursty => {
                // exponential with mean `batch`, clamped to [1, 8·batch]
                let draw = -rng.f64().max(1e-12).ln() * batch as f64;
                (draw.round() as usize).clamp(1, batch * 8)
            }
        }
        .min(left);
        let mut reqs = Vec::with_capacity(size);
        for _ in 0..size {
            let mut x = vec![0.0f64; dim];
            let (s, e) = match kind {
                TraceKind::BatchGraph => {
                    segments[rng.below(segments.len() as u64) as usize]
                }
                _ => (0, dim),
            };
            for v in &mut x[s..e] {
                *v = rng.uniform(-1.0, 1.0);
            }
            reqs.push(x);
        }
        left -= size;
        batches.push(reqs);
    }
    batches
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_kinds_parse() {
        assert_eq!(TraceKind::parse("uniform").unwrap(), TraceKind::Uniform);
        assert_eq!(TraceKind::parse("bursty").unwrap(), TraceKind::Bursty);
        assert_eq!(TraceKind::parse("batch").unwrap(), TraceKind::BatchGraph);
        assert!(TraceKind::parse("nope").is_err());
    }

    #[test]
    fn uniform_trace_has_exact_shape() {
        let t = synth_trace(TraceKind::Uniform, 10, 25, 8, &[(0, 10)], 1);
        let sizes: Vec<usize> = t.iter().map(|b| b.len()).collect();
        assert_eq!(sizes, vec![8, 8, 8, 1]);
        assert!(t.iter().flatten().all(|x| x.len() == 10));
    }

    #[test]
    fn bursty_trace_totals_and_varies() {
        let t = synth_trace(TraceKind::Bursty, 6, 300, 8, &[(0, 6)], 2);
        let total: usize = t.iter().map(|b| b.len()).sum();
        assert_eq!(total, 300);
        let sizes: Vec<usize> = t.iter().map(|b| b.len()).collect();
        let min = sizes.iter().min().unwrap();
        let max = sizes.iter().max().unwrap();
        assert!(min < max, "bursty sizes should vary, got constant {min}");
        assert!(*max <= 64);
    }

    #[test]
    fn batch_graph_trace_respects_segments() {
        let segs = [(0usize, 5usize), (5, 12)];
        let t = synth_trace(TraceKind::BatchGraph, 12, 40, 4, &segs, 3);
        let mut seen = [false; 2];
        for x in t.iter().flatten() {
            let lo_active = x[..5].iter().any(|v| *v != 0.0);
            let hi_active = x[5..].iter().any(|v| *v != 0.0);
            assert!(
                lo_active != hi_active,
                "request must target exactly one segment"
            );
            seen[usize::from(hi_active)] = true;
        }
        assert!(seen[0] && seen[1], "both segments should receive traffic");
    }

    #[test]
    fn traces_are_deterministic_in_the_seed() {
        let a = synth_trace(TraceKind::Bursty, 8, 50, 4, &[(0, 8)], 9);
        let b = synth_trace(TraceKind::Bursty, 8, 50, 4, &[(0, 8)], 9);
        assert_eq!(a, b);
        let c = synth_trace(TraceKind::Bursty, 8, 50, 4, &[(0, 8)], 10);
        assert_ne!(a, c);
    }
}
