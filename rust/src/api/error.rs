//! Typed errors for the deployment API boundary.
//!
//! Everything below `api` reports failures through `anyhow`-style context
//! chains, which is right for a CLI that prints and exits. A serving
//! facade needs more: the long-running `serve` loop must classify a
//! failure (bad request vs. bad bundle vs. the disk going away) to decide
//! whether to answer with a machine-readable NDJSON error object or to
//! stop, and callers embedding [`crate::api::Deployment`] need to match on
//! the cause without parsing strings. [`Error`] is that classification;
//! [`Error::kind`] is the stable wire label the serve loop puts in
//! `{"error":{"kind":...}}` responses.

use std::fmt;

/// What went wrong at the API boundary.
#[derive(Debug)]
pub enum Error {
    /// Input that is not even well-formed: broken JSON, an unreadable
    /// `.mtx` source file.
    Parse(String),
    /// Well-formed input that violates a semantic contract: a request
    /// line with no `x` array or the wrong vector length, a non-square
    /// matrix, a bundle whose pieces disagree.
    Validate(String),
    /// The operating system said no: file I/O on bundles, checkpoint
    /// files, or the request/response streams.
    Io(String),
    /// A bundle written by a different (newer) format revision.
    BundleVersion {
        found: usize,
        supported: usize,
    },
    /// Admission control said no: the tenant's bounded request queue is at
    /// its depth limit. Retryable — the request was rejected *before* any
    /// execution, so the client can back off and resend.
    Busy {
        /// deployment id whose queue is full
        tenant: String,
        /// the configured per-tenant queue depth that was hit
        depth: usize,
    },
    /// The request carried a `deadline_ms` budget that expired before
    /// execution began. Like [`Error::Busy`], nothing was executed.
    Deadline {
        /// milliseconds that elapsed between arrival and the admission check
        elapsed_ms: f64,
        /// the budget the request asked for
        deadline_ms: f64,
    },
    /// An iterative algorithm run ([`crate::algo`]) exhausted its
    /// iteration cap before reaching its fixed point. The partial answer
    /// is discarded — a traversal that stopped early would silently
    /// report unreachable nodes, so the failure is typed instead.
    NoConverge {
        /// stable algorithm label ("pagerank" | "bfs" | "sssp")
        algorithm: &'static str,
        /// iterations executed before giving up
        iterations: usize,
        /// the last residual (L1 rank delta, or remaining frontier size)
        residual: f64,
    },
    /// A connection sat idle past the server's read-timeout budget, so a
    /// stalled or half-open client cannot pin a connection slot under the
    /// `--max-conns` cap forever. One typed error line is written before
    /// the server closes the connection; nothing the client already sent
    /// is lost — every complete request line was answered first.
    Timeout {
        /// the configured idle budget that was exhausted
        idle_ms: u64,
    },
    /// The request itself panicked inside the execution path (a worker
    /// pool job, a kernel, a verification hook). The panic is caught at
    /// the request boundary and answered as a typed error with the
    /// request id echoed — one poisoned request must not take down the
    /// tenant or the serve process.
    Internal(String),
}

/// `Result` specialized to the API boundary's typed [`Error`].
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Stable machine-readable label, used as the `kind` field of NDJSON
    /// error responses.
    pub fn kind(&self) -> &'static str {
        match self {
            Error::Parse(_) => "parse",
            Error::Validate(_) => "validate",
            Error::Io(_) => "io",
            Error::BundleVersion { .. } => "bundle_version",
            Error::Busy { .. } => "busy",
            Error::Deadline { .. } => "deadline",
            Error::NoConverge { .. } => "no_converge",
            Error::Timeout { .. } => "timeout",
            Error::Internal(_) => "internal",
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Parse(m) => write!(f, "parse error: {m}"),
            Error::Validate(m) => write!(f, "validation error: {m}"),
            Error::Io(m) => write!(f, "io error: {m}"),
            Error::BundleVersion { found, supported } => write!(
                f,
                "unsupported bundle version {found} (this build reads versions 1..={supported})"
            ),
            Error::Busy { tenant, depth } => write!(
                f,
                "tenant {tenant:?} is at its queue depth limit {depth}; retry later"
            ),
            Error::Deadline { elapsed_ms, deadline_ms } => write!(
                f,
                "deadline exceeded before execution: {elapsed_ms:.3} ms elapsed of a \
                 {deadline_ms:.3} ms budget"
            ),
            Error::NoConverge { algorithm, iterations, residual } => write!(
                f,
                "{algorithm} did not converge within {iterations} iterations \
                 (residual {residual:e}); raise max_iters or loosen tol"
            ),
            Error::Timeout { idle_ms } => write!(
                f,
                "connection idle past the {idle_ms} ms read-timeout budget; closing"
            ),
            Error::Internal(m) => write!(f, "internal error: {m}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::Io(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_and_messages_are_stable() {
        assert_eq!(Error::Parse("x".into()).kind(), "parse");
        assert_eq!(Error::Validate("x".into()).kind(), "validate");
        assert_eq!(Error::Io("x".into()).kind(), "io");
        let v = Error::BundleVersion { found: 9, supported: 1 };
        assert_eq!(v.kind(), "bundle_version");
        assert!(v.to_string().contains("version 9"));
        assert!(Error::Parse("bad digit".into()).to_string().contains("bad digit"));
        let b = Error::Busy { tenant: "graphA".into(), depth: 4 };
        assert_eq!(b.kind(), "busy");
        assert!(b.to_string().contains("graphA"));
        assert!(b.to_string().contains('4'));
        let d = Error::Deadline { elapsed_ms: 12.5, deadline_ms: 10.0 };
        assert_eq!(d.kind(), "deadline");
        assert!(d.to_string().contains("12.5"));
        let nc = Error::NoConverge { algorithm: "pagerank", iterations: 100, residual: 2.5e-4 };
        assert_eq!(nc.kind(), "no_converge");
        let msg = nc.to_string();
        assert!(msg.contains("pagerank") && msg.contains("100"), "{msg}");
        assert!(msg.contains("2.5e-4") || msg.contains("2.5e-04"), "{msg}");
        let t = Error::Timeout { idle_ms: 250 };
        assert_eq!(t.kind(), "timeout");
        assert!(t.to_string().contains("250"));
        let i = Error::Internal("worker panicked: boom".into());
        assert_eq!(i.kind(), "internal");
        assert!(i.to_string().contains("boom"));
    }

    #[test]
    fn io_errors_convert() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert_eq!(e.kind(), "io");
        assert!(e.to_string().contains("gone"));
    }
}
