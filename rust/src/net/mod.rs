//! Multi-tenant network serving tier: a TCP front end over a registry of
//! deployed bundles, with admission control and live hot-swap.
//!
//! The stdin `serve` loop amortizes one graph's mapping cost over many
//! `y = Ax` queries; this tier amortizes it over many *graphs and
//! clients* at once. A [`DeploymentRegistry`] owns N loaded bundles, each
//! serving behind one shared worker pool; a [`NetServer`] accepts TCP
//! connections (one handler thread each, capped) and routes NDJSON
//! requests by deployment id. The `serve-net` CLI subcommand wires the
//! two together.
//!
//! # Wire protocol
//!
//! One JSON object per `\n`-terminated line, one response line per
//! request line, on the same connection, in order. Blank lines are
//! skipped; a line over the configured byte cap is drained and answered
//! with a `parse` error (the connection stays usable). All error objects
//! are exactly the stdin loop's dialect
//! (`{"kind": <api::Error::kind()>, "message": ...}`) — both transports
//! are built on [`crate::api::dispatch`].
//!
//! **Tenant requests** name a deployment id and carry one vector or an
//! explicit batch, with an optional pre-execution deadline budget:
//!
//! ```text
//! → {"tenant":"graphA","id":1,"x":[...dim floats...]}
//! ← {"tenant":"graphA","id":1,"y":[...]}
//! → {"tenant":"graphA","id":2,"xs":[[...],[...]],"deadline_ms":50}
//! ← {"tenant":"graphA","id":2,"ys":[[...],[...]]}
//! ← {"tenant":"graphA","id":3,"error":{"kind":"busy","message":...}}
//! ```
//!
//! Rejections are always typed error *responses*, never dropped
//! connections: `busy` when the tenant's bounded queue is at its depth
//! limit (admission happens before any execution), `deadline` when the
//! request's `deadline_ms` budget expired before execution began,
//! `validate` for unknown tenants (the message names the deployed ids)
//! and malformed vectors (length mismatches name both lengths).
//!
//! **Algorithm requests** run a whole iterative graph algorithm
//! ([`crate::algo`]) against a tenant's mapped plan — the request kinds,
//! parameters (and their defaults), payloads, and the embedded `trace`
//! object are exactly the stdin loop's, documented in
//! [`crate::api::dispatch::parse_algo`]:
//!
//! ```text
//! → {"tenant":"graphA","id":4,"pagerank":{"damping":0.85,"tol":1e-9}}
//! ← {"tenant":"graphA","id":4,"pagerank":{"scores":[...],"trace":{...}}}
//! → {"tenant":"graphA","id":5,"bfs":{"source":0}}
//! ← {"tenant":"graphA","id":5,"bfs":{"levels":[...],"reached":..,"trace":{...}}}
//! → {"tenant":"graphA","id":6,"sssp":{"source":0,"chunk":64}}
//! ← {"tenant":"graphA","id":6,"sssp":{"dist":[...],"reached":..,"trace":{...}}}
//! → {"tenant":"graphA","id":7,"gcn":{"x":[[...],...],"layers":[{"out_dim":16}]}}
//! ← {"tenant":"graphA","id":7,"gcn":{"features":[[...],...],"trace":{...}}}
//! ```
//!
//! An algorithm run holds one admission slot for its whole iteration
//! loop and counts once in `served`; `-1` encodes "unreachable" on the
//! wire (BFS level, SSSP distance). A run that exhausts its iteration
//! cap without meeting its tolerance is a typed `no_converge` error
//! whose message reports the iterations and final residual; bad
//! parameters are `validate` errors naming the offending field. Both
//! objects are byte-identical to the stdin loop's for the same request.
//!
//! **Admin requests** query or mutate the registry:
//!
//! ```text
//! → {"admin":"stats"}
//! ← {"admin":"stats","stats":{"graphA":{"served":..,"rps":..,
//!      "nnz_per_s":..,"inflight":..,"queue_depth":..,
//!      "rejected_busy":..,"rejected_deadline":..,"generation":..,
//!      "wall_s":..,"uptime_s":..,
//!      "algo":{"pagerank":..,"bfs":..,"sssp":..,"gcn":..,"mvms":..}},..}}
//! → {"admin":{"reload":{"id":"graphA","bundle":"remapped.json"}}}
//! ← {"admin":"reload","id":"graphA","generation":2,"dim":10000}
//! ```
//!
//! `reload` is the live hot-swap: the bundle is loaded from disk outside
//! any lock, then installed with an atomic `Arc` swap. In-flight requests
//! finish on the generation they were admitted against; requests arriving
//! after the ack are served by the new one. The serving invariant — every
//! socket answer is bit-identical to [`crate::api::Deployment::mvm`] on
//! the generation that served it — holds across the swap. A reload also
//! restarts the tenant's rate window: `rps` and `nnz_per_s` in `stats`
//! are normalized by the *current generation's* uptime (its `wall_s`),
//! while `served`, `uptime_s`, and the `algo` counters stay cumulative
//! across generations.
//!
//! # Pieces
//!
//! - [`DeploymentRegistry`] / [`Tenant`] / [`TenantEntry`] — ownership,
//!   routing, admission, counters, hot-swap ([`registry`]).
//! - [`NetServer`] / [`NetOptions`] — the accept loop and per-connection
//!   handlers ([`server`]).
//! - [`run_net_bench`] — the self-checking concurrent load driver behind
//!   `serve-net --bench` and the CI `net-smoke` job ([`bench`]).

pub mod bench;
pub mod registry;
pub mod server;

pub use bench::{run_net_bench, NetBenchOptions, NetBenchReport};
pub use registry::{AdmitGuard, DeploymentRegistry, RegistryOptions, Tenant, TenantEntry};
pub use server::{NetOptions, NetServer, CONN_CAP_TENANT};
