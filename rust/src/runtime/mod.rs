//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! The Rust side of the three-layer AOT bridge: `python/compile/aot.py`
//! lowers the L2 JAX computations (which embed the L1 Pallas kernels) to
//! HLO *text*; this module loads that text, compiles it on the PJRT CPU
//! client, and executes it from the coordinator's hot path. Python is never
//! involved at run time.
//!
//! This runtime is now *optional* for training: it backs
//! [`crate::agent::backend::PjrtBackend`], one of two `TrainBackend`
//! implementations — the pure-Rust
//! [`crate::agent::native::NativeBackend`] trains without any artifacts,
//! using [`Manifest::builtin`] for the controller shapes. Commands resolve
//! between them via `--backend {native,pjrt,auto}`.
//!
//! Pattern adapted from /opt/xla-example/load_hlo/ — text (not serialized
//! proto) is the interchange format because xla_extension 0.5.1 rejects
//! jax≥0.5's 64-bit-id protos.

pub mod literal;
pub mod manifest;

pub use literal::{lit_f32, lit_f32_1d, lit_i32_2d, lit_scalar_f32, lit_scalar_i32, lit_u32_1d};
pub use manifest::{ControllerEntry, Manifest, MvmEntry, ParamSpec};

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled artifact ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    /// Execute with literal inputs; returns the flattened tuple elements.
    ///
    /// All our artifacts are lowered with `return_tuple=True`, so the raw
    /// output is a 1-element buffer holding a tuple; this unwraps it.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let bufs = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing artifact {}", self.name))?;
        let result = bufs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        let parts = result
            .to_tuple()
            .with_context(|| format!("untupling result of {}", self.name))?;
        Ok(parts)
    }

    /// Like [`Self::run`] but borrowing the input literals — lets callers
    /// keep long-lived literals (e.g. controller parameters) across calls
    /// without cloning them each epoch.
    pub fn run_refs(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let bufs = self
            .exe
            .execute::<&xla::Literal>(inputs)
            .with_context(|| format!("executing artifact {}", self.name))?;
        let result = bufs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        result
            .to_tuple()
            .with_context(|| format!("untupling result of {}", self.name))
    }
}

/// PJRT client + executable cache. One per process.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    cache: std::sync::Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

impl Runtime {
    /// CPU-backed runtime rooted at an artifacts directory.
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            artifacts_dir: artifacts_dir.into(),
            cache: std::sync::Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    /// Load the manifest describing every artifact's ABI.
    pub fn manifest(&self) -> Result<Manifest> {
        Manifest::load(&self.artifacts_dir.join("manifest.json"))
    }

    /// Load + compile an artifact by file name (cached).
    pub fn load(&self, file_name: &str) -> Result<std::sync::Arc<Executable>> {
        if let Some(hit) = self.cache.lock().unwrap().get(file_name) {
            return Ok(hit.clone());
        }
        let path = self.artifacts_dir.join(file_name);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        let exec = std::sync::Arc::new(Executable {
            exe,
            name: file_name.to_string(),
        });
        self.cache
            .lock()
            .unwrap()
            .insert(file_name.to_string(), exec.clone());
        Ok(exec)
    }
}

/// Smoke-level check that the xla crate links and a CPU client can be built.
pub fn cpu_client_smoke() -> Result<String> {
    let client = xla::PjRtClient::cpu()?;
    Ok(format!(
        "platform={} devices={}",
        client.platform_name(),
        client.device_count()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_artifact_is_an_error() {
        let rt = Runtime::new("/nonexistent_dir_autogmap").unwrap();
        let err = rt.load("nope.hlo.txt");
        assert!(err.is_err());
    }

    #[test]
    fn smoke_client() {
        let s = cpu_client_smoke().unwrap();
        assert!(s.contains("cpu"));
    }
}
