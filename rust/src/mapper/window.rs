//! Window planning: slice a (reordered, banded) matrix's grid diagonal
//! into overlapping controller-sized windows and choose the ownership cuts
//! between neighbours.
//!
//! Windows are `n_window` grid cells wide (the controller's native grid)
//! and advance by `n_window − overlap`; the last window is pinned to the
//! grid's end, so it may overlap its predecessor by more. Between two
//! adjacent windows the *ownership cut* is chosen inside their overlap at
//! the grid boundary crossed by the fewest non-zeros (exact, via the grid
//! prefix sums) — band entries crossing a cut are the mapper's digital
//! spill, so the min-crossing cut is the sparsity-aware choice.

use crate::graph::GridSummary;

/// One diagonal window in global grid cells.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WindowSpan {
    pub start: usize,
    pub end: usize,
}

impl WindowSpan {
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.start >= self.end
    }
}

/// Tile the grid diagonal [0, g_cells) with windows of `n_window` cells
/// advancing by `n_window − overlap` (overlap is clamped to `n_window−1`).
/// Starts are strictly increasing; the last window ends exactly at
/// `g_cells`. When the whole grid fits in one window, a single (possibly
/// short) window is returned.
pub fn plan_windows(g_cells: usize, n_window: usize, overlap: usize) -> Vec<WindowSpan> {
    assert!(g_cells >= 1 && n_window >= 1);
    if g_cells <= n_window {
        return vec![WindowSpan { start: 0, end: g_cells }];
    }
    let stride = n_window - overlap.min(n_window - 1);
    let mut spans = Vec::new();
    let mut s = 0usize;
    loop {
        if s + n_window >= g_cells {
            spans.push(WindowSpan { start: g_cells - n_window, end: g_cells });
            return spans;
        }
        spans.push(WindowSpan { start: s, end: s + n_window });
        s += stride;
    }
}

/// Non-zeros crossing the grid boundary `b` (row < b, col ≥ b; the
/// symmetric lower triangle doubles it, but argmin does not care).
fn crossing_nnz(g: &GridSummary, b: usize) -> u64 {
    g.nnz_rect(0, b, b, g.n)
}

/// Choose the ownership cuts between consecutive windows: cut `i` lies in
/// `[max(windows[i+1].start, prev_cut + 1), windows[i].end]` (a cut at the
/// left window's end gives it its whole span — the only choice when
/// overlap is zero) and minimizes the exact band-crossing nnz (ties break
/// toward the smaller boundary, keeping the choice deterministic).
/// Returns `windows.len()−1` strictly increasing cuts; the owned ranges
/// are `[0, c_0), [c_0, c_1), …, [c_last, g_cells)`.
pub fn choose_cuts(g: &GridSummary, windows: &[WindowSpan]) -> Vec<usize> {
    let mut cuts = Vec::with_capacity(windows.len().saturating_sub(1));
    let mut prev = 0usize; // previous cut (exclusive lower bound)
    for pair in windows.windows(2) {
        let (left, right) = (pair[0], pair[1]);
        // Bounds are always satisfiable: right.start ≤ left.end (windows
        // abut or overlap), every non-last window ends before the grid
        // does, and the previous cut sits at or before the previous
        // window's end < left.end.
        let lo = right.start.max(prev + 1);
        let hi = left.end;
        debug_assert!(
            lo <= hi && hi < g.n,
            "degenerate windows [{},{}) and [{},{}) after cut {prev}",
            left.start,
            left.end,
            right.start,
            right.end
        );
        let mut best = lo;
        let mut best_cross = crossing_nnz(g, lo);
        for b in (lo + 1)..=hi {
            let c = crossing_nnz(g, b);
            if c < best_cross {
                best = b;
                best_cross = c;
            }
        }
        cuts.push(best);
        prev = best;
    }
    cuts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::sparse::Coo;

    #[test]
    fn windows_tile_small_grids_with_one_window() {
        assert_eq!(plan_windows(5, 8, 2), vec![WindowSpan { start: 0, end: 5 }]);
        assert_eq!(plan_windows(8, 8, 2), vec![WindowSpan { start: 0, end: 8 }]);
    }

    #[test]
    fn windows_overlap_and_cover_the_grid() {
        let spans = plan_windows(100, 28, 4);
        assert_eq!(spans[0], WindowSpan { start: 0, end: 28 });
        assert_eq!(spans.last().unwrap().end, 100);
        for pair in spans.windows(2) {
            assert!(pair[1].start > pair[0].start, "starts strictly increase");
            assert!(pair[1].start < pair[0].end, "windows overlap");
            assert_eq!(pair[0].len(), 28);
        }
        // stride 24 until the pinned last window
        assert_eq!(spans[1].start, 24);
        assert_eq!(spans.last().unwrap().start, 72);
    }

    #[test]
    fn zero_overlap_abuts_windows() {
        let spans = plan_windows(20, 5, 0);
        assert_eq!(
            spans,
            vec![
                WindowSpan { start: 0, end: 5 },
                WindowSpan { start: 5, end: 10 },
                WindowSpan { start: 10, end: 15 },
                WindowSpan { start: 15, end: 20 },
            ]
        );
    }

    #[test]
    fn cuts_prefer_the_empty_boundary() {
        // two clusters [0,12) and [16,28) with nothing between cells 12-16:
        // the cut inside the overlap must land on an empty boundary
        let dim = 28;
        let mut coo = Coo::new(dim, dim);
        for i in 0..12 {
            for j in i..12.min(i + 3) {
                coo.push_sym(j, i, 1.0);
            }
        }
        for i in 16..dim {
            for j in i..dim.min(i + 3) {
                coo.push_sym(j, i, 1.0);
            }
        }
        let m = coo.to_csr();
        let g = GridSummary::new(&m, 1);
        let windows = vec![
            WindowSpan { start: 0, end: 18 },
            WindowSpan { start: 10, end: 28 },
        ];
        let cuts = choose_cuts(&g, &windows);
        assert_eq!(cuts.len(), 1);
        assert!((12..=16).contains(&cuts[0]), "cut {} not in the gap", cuts[0]);
        assert_eq!(crossing_nnz(&g, cuts[0]), 0);
    }

    #[test]
    fn cuts_are_strictly_increasing_dense_overlaps() {
        // dense-ish band: cuts still come back strictly increasing and
        // inside their overlap ranges
        let dim = 60;
        let mut coo = Coo::new(dim, dim);
        for i in 1..dim {
            coo.push_sym(i, i - 1, 1.0);
            if i >= 2 {
                coo.push_sym(i, i - 2, 1.0);
            }
        }
        let m = coo.to_csr();
        let g = GridSummary::new(&m, 2); // n = 30
        let windows = plan_windows(g.n, 8, 3);
        let cuts = choose_cuts(&g, &windows);
        assert_eq!(cuts.len(), windows.len() - 1);
        let mut prev = 0;
        for (i, &c) in cuts.iter().enumerate() {
            assert!(c > prev, "cut {i} not increasing");
            assert!(c >= windows[i + 1].start && c <= windows[i].end);
            prev = c;
        }
    }

    #[test]
    fn zero_overlap_cuts_fall_on_window_boundaries() {
        let mut coo = Coo::new(40, 40);
        for i in 1..40 {
            coo.push_sym(i, i - 1, 1.0);
        }
        let m = coo.to_csr();
        let g = GridSummary::new(&m, 2); // n = 20
        let windows = plan_windows(g.n, 5, 0);
        let cuts = choose_cuts(&g, &windows);
        // abutting windows leave exactly one legal cut per boundary
        assert_eq!(cuts, vec![5, 10, 15]);
    }
}
