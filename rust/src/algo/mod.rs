//! Whole graph algorithms as iterative loops over a mapped [`Servable`] —
//! the layer that turns a programmed crossbar arena from a one-trick
//! `y = Ax` answerer into an asset amortized across traversals.
//!
//! GraphR (PAPERS.md) observes that the classic vertex programs are all
//! the *same* inner loop — a sparse matrix–vector product over a suitable
//! semiring — iterated to a fixed point. This module runs exactly that
//! loop against any [`Servable`] (flat engine plan or hierarchical
//! composite, via the [`MvmEngine`] adapters below), keeping the
//! programmed arena untouched: the crossbar always computes the plain
//! (+, ×) product, and the semiring reduction happens digitally in the
//! post-step.
//!
//! | algorithm | iterate | crossbar op | post-step (semiring) |
//! |-----------|---------|-------------|----------------------|
//! | [`pagerank`] | rank vector `p` | `y = A · D⁻¹p` | `p' = d·y + (d·dangling + 1−d)/n`, L1 residual |
//! | [`bfs`] | frontier indicator `f` | `y = A · f` | or–and: `y_i ≠ 0` ∧ unvisited ⇒ level `k+1` |
//! | [`sssp`] | frontier basis batch `e_j` | `A · e_j` (column extraction) | min–plus: `dist_i = min(dist_i, dist_j + w_ij)` |
//! | [`gcn`](gcn::gcn_forward) | feature matrix `Z` | one multi-RHS batch `A · (Z Wₗ)` per layer | dense GEMM `Z Wₗ` + ReLU |
//!
//! BFS and SSSP rely on a *no-cancellation* precondition: edge weights
//! must be positive so a nonzero matrix entry can never sum to zero in
//! the (+, ×) product (every graph this repo synthesizes has positive
//! weights). Under it, the or–and / min–plus post-steps reconstruct the
//! boolean and tropical semirings exactly, so both traversals are
//! bit-identical to their queue-based references.
//!
//! Every run reports an [`AlgoTrace`] — iteration count, residual curve
//! (L1 residuals for PageRank, per-level discovery counts for BFS/SSSP,
//! per-layer activation magnitude for GCN), MVMs issued, and amortized
//! nnz/s — and the serving tiers aggregate per-algorithm [`AlgoCounters`].
//!
//! The wire surface lives in [`crate::api::dispatch`] (request kinds
//! `{"pagerank":{...}}`, `{"bfs":{...}}`, `{"sssp":{...}}`,
//! `{"gcn":{...}}`, answered identically by stdin `serve` and the TCP
//! tier); `algo-bench` drives all four against flat and composite plans
//! and writes the BENCH_algo.json ledger.

use crate::api::deploy::{DeployedPlan, Deployment};
use crate::api::dispatch;
use crate::engine::{BatchExecutor, Servable};
use crate::graph::Csr;
use crate::util::json::{num_arr, obj, Json};

pub mod bench;
pub mod gcn;
pub mod pagerank;
pub mod traverse;

pub use bench::{run_algo_bench, AlgoBenchOptions};
pub use gcn::{gcn_forward, max_abs_diff, normalized_adjacency, GcnLayer};
pub use pagerank::{pagerank, PageRankOptions};
pub use traverse::{bfs, bfs_reference, sssp, sssp_reference, BfsOptions, SsspOptions};

/// The one capability every algorithm iterates over: a batched MVM with a
/// known dimension and per-MVM nnz cost. Three adapters cover the repo's
/// serving shapes — [`DeploymentEngine`] (a facade deployment serving in
/// original node ids), [`PlanEngine`] (a bare [`Servable`] plan on its own
/// executor), and [`CsrEngine`] (the host CSR oracle the property tests
/// compare against).
pub trait MvmEngine {
    /// Matrix dimension (request/response vector length).
    fn dim(&self) -> usize;

    /// Non-zeros one MVM touches — the unit of amortized-throughput
    /// accounting in [`AlgoTrace`].
    fn nnz(&self) -> u64;

    /// Execute a request batch; outputs in request order.
    fn mvm_batch(&self, xs: Vec<Vec<f64>>) -> Vec<Vec<f64>>;

    /// Single-request convenience over [`MvmEngine::mvm_batch`].
    fn mvm_one(&self, x: Vec<f64>) -> Vec<f64> {
        self.mvm_batch(vec![x]).pop().expect("batch of one answers one")
    }
}

/// [`MvmEngine`] over an [`crate::api::Deployment`] facade: requests are
/// permuted into served order, executed (sharded or scalar), and permuted
/// back — algorithms always see original node ids.
pub struct DeploymentEngine<'a> {
    dep: &'a Deployment,
    exec: &'a BatchExecutor<DeployedPlan>,
    sharded: bool,
    degraded: std::cell::Cell<bool>,
}

impl<'a> DeploymentEngine<'a> {
    pub fn new(
        dep: &'a Deployment,
        exec: &'a BatchExecutor<DeployedPlan>,
        sharded: bool,
    ) -> DeploymentEngine<'a> {
        DeploymentEngine {
            dep,
            exec,
            sharded,
            degraded: std::cell::Cell::new(false),
        }
    }

    /// Whether any batch this engine has executed was served under a
    /// degraded fault epoch (digital-fallback rows in play). Algorithm
    /// answers surface this as `"degraded": true` on the wire.
    pub fn degraded(&self) -> bool {
        self.degraded.get()
    }
}

impl MvmEngine for DeploymentEngine<'_> {
    fn dim(&self) -> usize {
        self.dep.plan().dim()
    }

    fn nnz(&self) -> u64 {
        self.dep.plan().nnz()
    }

    fn mvm_batch(&self, xs: Vec<Vec<f64>>) -> Vec<Vec<f64>> {
        let (ys, degraded) = dispatch::execute_verified(self.dep, self.exec, xs, self.sharded);
        if degraded {
            self.degraded.set(true);
        }
        ys
    }
}

/// [`MvmEngine`] over a bare [`Servable`] plan with its own executor — the
/// path `algo-bench` uses for flat engine plans that never went through
/// the deployment facade (no permutation around the plan).
pub struct PlanEngine<P: Servable> {
    exec: BatchExecutor<P>,
    sharded: bool,
}

impl<P: Servable> PlanEngine<P> {
    pub fn new(plan: std::sync::Arc<P>, workers: usize, sharded: bool) -> PlanEngine<P> {
        PlanEngine {
            exec: BatchExecutor::new(plan, workers),
            sharded,
        }
    }
}

impl<P: Servable> MvmEngine for PlanEngine<P> {
    fn dim(&self) -> usize {
        self.exec.plan().dim()
    }

    fn nnz(&self) -> u64 {
        self.exec.plan().nnz()
    }

    fn mvm_batch(&self, xs: Vec<Vec<f64>>) -> Vec<Vec<f64>> {
        if self.sharded {
            self.exec.execute_batch_sharded(xs)
        } else {
            self.exec.execute_batch(xs)
        }
    }
}

/// [`MvmEngine`] over a host CSR matrix — the straightforward oracle every
/// mapped run is property-tested against.
pub struct CsrEngine<'a>(pub &'a Csr);

impl MvmEngine for CsrEngine<'_> {
    fn dim(&self) -> usize {
        self.0.rows
    }

    fn nnz(&self) -> u64 {
        self.0.nnz() as u64
    }

    fn mvm_batch(&self, xs: Vec<Vec<f64>>) -> Vec<Vec<f64>> {
        xs.iter().map(|x| self.0.spmv(x)).collect()
    }
}

/// What one algorithm run did: the convergence story and the amortized
/// throughput over the mapped structure.
#[derive(Clone, Debug)]
pub struct AlgoTrace {
    /// stable algorithm label ("pagerank" | "bfs" | "sssp" | "gcn")
    pub algorithm: &'static str,
    /// iterations executed (levels for BFS, relaxation rounds for SSSP,
    /// layers for GCN)
    pub iterations: usize,
    /// whether the run reached its fixed point (PageRank in
    /// fixed-iteration mode reports `false` by construction)
    pub converged: bool,
    /// per-iteration residual curve: L1 rank residuals (PageRank),
    /// newly-discovered node counts (BFS/SSSP), max-abs layer activation
    /// (GCN)
    pub residuals: Vec<f64>,
    /// MVMs issued against the engine
    pub mvms: u64,
    /// total non-zeros those MVMs touched (`mvms × engine.nnz()`)
    pub nnz_total: u64,
    /// wall-clock seconds for the whole run
    pub wall_s: f64,
}

impl AlgoTrace {
    /// Amortized non-zeros per second over the whole run.
    pub fn nnz_per_s(&self) -> f64 {
        self.nnz_total as f64 / self.wall_s.max(1e-9)
    }

    /// Iterations per second over the whole run.
    pub fn iters_per_s(&self) -> f64 {
        self.iterations as f64 / self.wall_s.max(1e-9)
    }

    /// The wire/ledger form embedded in responses and BENCH_algo.json.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("algorithm", Json::Str(self.algorithm.into())),
            ("iterations", Json::Num(self.iterations as f64)),
            ("converged", Json::Bool(self.converged)),
            ("residuals", num_arr(self.residuals.iter().copied())),
            ("mvms", Json::Num(self.mvms as f64)),
            ("nnz_total", Json::Num(self.nnz_total as f64)),
            ("wall_s", Json::Num(self.wall_s)),
            ("nnz_per_s", Json::Num(self.nnz_per_s())),
            ("iters_per_s", Json::Num(self.iters_per_s())),
        ])
    }
}

/// Per-algorithm request counters the serving tiers aggregate — surfaced
/// in the stdin loop's stats line ([`crate::api::ServeReport`]) and in
/// the TCP tier's per-tenant `{"admin":"stats"}` object.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AlgoCounters {
    pub pagerank: u64,
    pub bfs: u64,
    pub sssp: u64,
    pub gcn: u64,
    /// MVMs those runs issued (each algorithm request fans out into many)
    pub mvms: u64,
}

impl AlgoCounters {
    /// Account one finished run of `key`, which issued `mvms` MVMs.
    pub fn record(&mut self, key: &str, mvms: u64) {
        match key {
            "pagerank" => self.pagerank += 1,
            "bfs" => self.bfs += 1,
            "sssp" => self.sssp += 1,
            "gcn" => self.gcn += 1,
            other => debug_assert!(false, "unknown algorithm key {other:?}"),
        }
        self.mvms += mvms;
    }

    /// Algorithm requests served, all kinds.
    pub fn total(&self) -> u64 {
        self.pagerank + self.bfs + self.sssp + self.gcn
    }

    /// The nested `"algo"` stats object both serving tiers emit.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("pagerank", Json::Num(self.pagerank as f64)),
            ("bfs", Json::Num(self.bfs as f64)),
            ("sssp", Json::Num(self.sssp as f64)),
            ("gcn", Json::Num(self.gcn as f64)),
            ("mvms", Json::Num(self.mvms as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::synth;

    #[test]
    fn csr_engine_matches_spmv_and_counts() {
        let a = synth::qm7_like(5828);
        let eng = CsrEngine(&a);
        assert_eq!(eng.dim(), a.rows);
        assert_eq!(eng.nnz(), a.nnz() as u64);
        let x: Vec<f64> = (0..a.rows).map(|i| i as f64 * 0.5 - 3.0).collect();
        assert_eq!(eng.mvm_one(x.clone()), a.spmv(&x));
    }

    #[test]
    fn counters_record_and_total() {
        let mut c = AlgoCounters::default();
        c.record("pagerank", 21);
        c.record("bfs", 5);
        c.record("bfs", 7);
        assert_eq!(c.total(), 3);
        assert_eq!(c.mvms, 33);
        let j = c.to_json();
        assert_eq!(j.get("bfs").as_i64(), Some(2));
        assert_eq!(j.get("mvms").as_i64(), Some(33));
    }

    #[test]
    fn trace_json_carries_throughput_fields() {
        let t = AlgoTrace {
            algorithm: "pagerank",
            iterations: 4,
            converged: true,
            residuals: vec![0.5, 0.25],
            mvms: 5,
            nnz_total: 500,
            wall_s: 2.0,
        };
        let j = t.to_json();
        assert_eq!(j.get("algorithm").as_str(), Some("pagerank"));
        assert_eq!(j.get("iterations").as_i64(), Some(4));
        assert_eq!(j.get("nnz_per_s").as_f64(), Some(250.0));
        assert_eq!(j.get("iters_per_s").as_f64(), Some(2.0));
        assert_eq!(j.get("residuals").as_arr().unwrap().len(), 2);
    }
}
