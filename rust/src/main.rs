//! `autogmap` — CLI for the AutoGMap reproduction.
//!
//! Subcommands:
//!   train      — run one RL experiment from a JSON config or flags
//!   eval       — greedy-decode a trained checkpoint and print the scheme
//!   baseline   — run the non-RL baselines on a dataset
//!   reproduce  — regenerate a paper table (--table) or figure (--figure)
//!   gen-data   — write the synthetic datasets to data/ as .mtx
//!   visualize  — spy-plot a dataset (ASCII + SVG)
//!   info       — runtime + manifest summary
//!   serve-bench — compile/load an execution plan and replay a synthetic
//!                request trace against the engine (throughput, p50/p99)
//!   train-bench — native-backend training throughput at 1/2/8 workers
//!                (BENCH_train.json, the training analogue of serve-bench)
//!   map-large  — hierarchical mapper pipeline: R-MAT graph → RCM →
//!                windowed controller inference (scheme cache) → composite
//!                plan → fleet-sharded serving (BENCH_mapper.json)
//!   deploy     — build a deployment through the api facade (source +
//!                strategy + kernel/fleet knobs) and save it as one
//!                self-contained bundle JSON
//!   serve      — load a bundle and serve NDJSON MVM requests from stdin
//!                (responses + periodic stats on stdout) until EOF
//!   serve-net  — multi-tenant TCP serving: N bundles behind one socket,
//!                per-tenant admission control, stats, and live hot-swap
//!                (--bench runs the self-checking concurrent load driver)
//!   algo-bench — run PageRank/BFS/SSSP/GCN over a mapped R-MAT graph on
//!                flat and composite plans at several worker counts,
//!                self-checked against CSR references (BENCH_algo.json)
//!   fault-bench — chaos harness: inject a device fault mid-stream under
//!                concurrent clients, assert zero wrong answers escape,
//!                ledger detection/repair latency (BENCH_fault.json)
//!   delta-bench — dynamic-graph harness: concurrent edge updaters and
//!                queriers against a live deployment, every answer checked
//!                vs a mutating host-CSR oracle, incremental vs full remap
//!                latency (BENCH_delta.json)
//!
//! Every training command takes `--backend {native,pjrt,auto}`: `native`
//! is the pure-Rust trainer (sampling + BPTT + Adam, no artifacts
//! required), `pjrt` executes the AOT HLO artifacts, and `auto` (default)
//! picks pjrt exactly when `artifacts/manifest.json` exists.

use autogmap::agent::BackendKind;
use autogmap::coordinator::config::{Dataset, ExperimentConfig};
use autogmap::coordinator::{reproduce, runner, RunnerOptions};
use autogmap::reorder::Reordering;
use autogmap::runtime::Runtime;
use autogmap::scheme::FillRule;
use autogmap::util::cli::Args;
use std::path::{Path, PathBuf};

const USAGE: &str = "\
autogmap — learning to map large-scale sparse graphs on memristive crossbars

USAGE: autogmap <subcommand> [options]

  train      --config cfg.json | [--dataset qm7|qh882|qh1484|batch|mtx
             --mtx-path p --grid N --controller NAME --fill none|fixed|dynamic
             --fill-arg N --reward-a F --lr F --epochs N --seed N]
             [--backend native|pjrt|auto] [--workers N]
             [--out runs] [--checkpoint-every N] [--verbose]
  eval       --config cfg.json --checkpoint runs/<name>/checkpoint.json
             [--backend native|pjrt|auto]
  baseline   --dataset qm7|qh882|qh1484 [--grid N] [--coarse N]
  reproduce  --table 2|3|4 | --figure 2|7|8|9|10|11|12|13 [--epochs N]
             [--backend native|pjrt|auto] [--workers N] [--out runs]
  gen-data   [--out data]
  visualize  --dataset qm7|qh882|qh1484 [--mtx-path p] [--out figures]
  info
  serve-bench [--dataset qm7|qh882|qh1484|batch|mtx|rmat --mtx-path p
             --grid N --nodes N --degree N]
             [--scheme full|unit|oracle | --plan plan.json] [--save-plan p]
             [--kernel auto|dense|sparse] [--dense-threshold F]
             [--exec both|scalar|sharded]
             [--banks N] [--policy rr|balanced] [--workers N]
             [--trace uniform|bursty|batch] [--batch N] [--requests N]
             [--trace-seed N] [--assert-speedup F]
             [--bench-json BENCH_engine.json]
  train-bench [--dataset qm7|qh882|qh1484 --controller NAME --fill kind
             --fill-arg N --epochs N --seed N]
             [--bench-json BENCH_train.json]
  map-large  [--nodes N] [--degree N] [--grid N] [--controller NAME]
             [--overlap N] [--rounds N] [--workers N] [--banks N]
             [--requests N] [--batch N] [--seed N]
             [--epochs N | --checkpoint ck.json]
             [--bench-json BENCH_mapper.json]
  deploy     [--dataset qm7|qh882|qh1484|batch|mtx|rmat --mtx-path p
             --nodes N --degree N --grid N --seed N]
             [--strategy hier|direct|fixed] [--controller NAME]
             [--block N] [--overlap N] [--rounds N] [--checkpoint ck.json]
             [--kernel auto|dense|sparse] [--dense-threshold F]
             [--banks N] [--policy rr|balanced]
             [--workers N] [--reward-a F] [--reorder identity|cm|rcm]
             [--out bundle.json]
  serve      --bundle bundle.json [--workers N] [--batch-window N]
             [--stats-every N] [--exec sharded|scalar] [--max-line-bytes N]
             [--fault-harness] [--scrub-every N] [--remap-after N]
  serve-net  --bundles id=path[,id=path...] [--listen 127.0.0.1:7070]
             [--workers N] [--queue-depth N] [--max-conns N]
             [--max-line-bytes N] [--exec sharded|scalar]
             [--fault-harness] [--scrub-every N] [--read-timeout-ms N]
             [--grace-ms N] [--remap-after N]
             [--bench] [--bench-clients N] [--bench-requests N]
             [--bench-swap id=path] [--seed N]
             [--bench-json BENCH_serve_net.json]
  algo-bench [--nodes N] [--degree N] [--grid N] [--block N] [--seed N]
             [--workers N] [--exec sharded|scalar] [--pagerank-iters N]
             [--bench-json BENCH_algo.json]
  fault-bench [--nodes N] [--degree N] [--grid N] [--banks N] [--workers N]
             [--queue-depth N] [--clients N] [--requests N]
             [--fault-bank N] [--fault-kind stuck0|stuck1|drift|outage]
             [--fault-rate F] [--fault-seed N] [--scrub-every N]
             [--seed N] [--listen 127.0.0.1:0] [--assert-recovery]
             [--bench-json BENCH_fault.json]
  delta-bench [--nodes N] [--degree N] [--grid N] [--controller NAME]
             [--overlap N] [--banks N] [--workers N]
             [--updaters N] [--queriers N] [--updates N] [--batch N]
             [--queries N] [--span F] [--seed N]
             [--bench-json BENCH_delta.json]

  global: --artifacts DIR (default: artifacts)

  backends: `native` trains in pure Rust (full BPTT + REINFORCE + Adam on
  a worker pool) and needs no artifacts; `pjrt` executes the AOT HLO
  artifacts; `auto` (default) = pjrt when artifacts/manifest.json exists,
  native otherwise. For a fixed --seed the native trainer is bit-exact
  regardless of --workers.

  train example (fresh checkout, no artifacts):
    autogmap train --backend native --dataset qm7 --fill dynamic \\
        --fill-arg 4 --epochs 2000 --verbose

  serve-bench example:
    autogmap serve-bench --dataset qh882 --banks 8 --trace bursty \\
        --requests 1024 --batch 64 --bench-json BENCH_engine.json
  compiles the scheme into an arena ExecPlan (all-zero tiles elided,
  density-adaptive dense/sparse kernels, row-banded schedule), spreads it
  over 8 simulated crossbar banks, and replays the trace four ways: the
  single-thread scalar baseline, the single-thread vectorized kernels,
  the per-request worker pool, and the optimized band-sharded multi-RHS
  mode — all bit-identical; the ledger records scalar vs vectorized vs
  optimized nnz/s plus a per-kernel roofline breakdown (dense/sparse
  nnz/s, arena bytes touched, pattern-dedup hit rate) from the same run.
  --kernel forces a kernel for A/B runs, --dense-threshold F re-selects
  the auto density cut, --exec narrows the executor modes, and
  --assert-speedup F fails the run if the vectorized kernels run below
  F x the scalar baseline (the CI regression gate). At-scale synthetic
  serving:
    autogmap serve-bench --dataset rmat --nodes 10000 --assert-speedup 1.5

  train-bench example:
    autogmap train-bench --dataset qm7 --epochs 100 \\
        --bench-json BENCH_train.json
  times native epochs/sec and rollout episodes/sec at 1, 2, and 8 workers
  so the training perf trajectory is tracked like the engine's.

  deploy + serve example (build once, serve forever):
    autogmap deploy --dataset rmat --nodes 10000 --strategy hier \\
        --controller qh882_dyn4 --out bundle.json
    autogmap serve --bundle bundle.json --workers 8 --batch-window 32
  serve-net example (two graphs, one socket, live hot-swap):
    autogmap deploy --dataset rmat --nodes 10000 --strategy hier \\
        --controller qh882_dyn4 --out a.json
    autogmap deploy --dataset rmat --nodes 10000 --strategy fixed \\
        --block 4 --out b.json
    autogmap serve-net --bundles graphA=a.json,graphB=b.json \\
        --listen 127.0.0.1:7070 --workers 8 --queue-depth 32
  speaks one JSON object per line over TCP: {\"tenant\": \"graphA\",
  \"id\": 1, \"x\": [..]} answers {\"tenant\": \"graphA\", \"id\": 1,
  \"y\": [..]}; {\"admin\": \"stats\"} returns per-tenant rps/queue/
  rejection counters; {\"admin\": {\"reload\": {\"id\": \"graphA\",
  \"bundle\": \"remapped.json\"}}} hot-swaps a tenant's bundle with zero
  dropped requests (in-flight requests finish on the old plan). Requests
  over a tenant's --queue-depth get typed {\"error\": {\"kind\":
  \"busy\"}} rejections; a request's optional \"deadline_ms\" budget is
  enforced before execution (kind \"deadline\"). `serve-net --bench`
  starts the server in-process, drives --bench-clients concurrent
  clients for --bench-requests requests each (optionally hot-swapping
  --bench-swap id=path mid-stream), verifies every socket answer
  bit-matches Deployment::mvm, and writes BENCH_serve_net.json.

  `deploy` runs graph -> reorder -> map -> compile -> fleet through the
  api facade and writes one self-contained bundle (the v3 plan arena, the
  composite's digital spill, the reordering permutation, fleet + worker
  config, provenance). `serve` reloads it in any process — no graph,
  controller, or training dependency — and serves NDJSON requests from
  stdin: {\"id\": 1, \"x\": [..dim floats..]} per line (or {\"id\": ..,
  \"xs\": [[..], ..]} for an explicit batch), answers {\"id\": 1,
  \"y\": [..]} in original node ids. Each request answers immediately by
  default; pass --batch-window N to coalesce up to N single requests per
  multi-RHS dispatch (a part-filled window waits for more input, so only
  use it when piping a stream). Bad lines get {\"error\":
  {\"kind\": \"parse\"|\"validate\", ..}} responses and the loop keeps
  serving; every --stats-every requests (and at EOF) it prints
  {\"stats\": {\"rps\", \"nnz_per_s\", \"shards\", ..}}. A reloaded
  bundle serves bit-identically to the deployment that wrote it.

  algo-bench example (fresh checkout, no artifacts):
    autogmap algo-bench --nodes 10000 --degree 8
  maps one deterministic R-MAT graph twice — a flat full-coverage
  ExecPlan and a fixed-block composite deployment — and runs all four
  graph algorithms ({\"pagerank\"}, {\"bfs\"}, {\"sssp\"}, {\"gcn\"})
  on each at 1/2/8 workers (or a single --workers N). Every answer is
  checked against host-CSR references: BFS levels and SSSP distances
  must be bit-identical to the queue/Dijkstra references, PageRank and
  GCN within 1e-8 / 1e-5 of the CSR runs at identical iteration counts;
  any disagreement fails the run. BENCH_algo.json records the per-
  algorithm trace (iterations, residual curve, MVMs, iters/s, amortized
  nnz/s) for every plan x worker configuration.

  fault-bench example (fresh checkout, no artifacts):
    autogmap fault-bench --nodes 10000 --banks 4 --clients 2
  builds a fault-armed R-MAT deployment behind a real socket, measures a
  pre-fault baseline (every answer must bit-match Deployment::mvm), then
  injects --fault-kind on --fault-bank mid-stream while --clients
  concurrent connections keep hammering. Every response — including the
  window between injection and detection — is checked element-wise
  against the healthy plan and the host-CSR oracle; anything else fails
  the run, so BENCH_fault.json's escaped_wrong_answers is 0 whenever the
  bench exits 0. The ledger records detection latency (inject -> harness
  degraded), repair latency ({\"admin\":{\"repair\":..}}), degraded vs
  pre-fault vs post-repair nnz/s, and the recovery_ratio
  (--assert-recovery fails the run below 0.9). The same fault surface is
  live on any fault-armed server: serve / serve-net --fault-harness arm
  per-deployment ABFT column checksums (one extra dot per request), a
  scrub probe every --scrub-every requests, quarantine-on-detect with
  exact digital fallback, and {\"admin\":{\"inject\"|\"repair\":..}}.

  delta-bench example (fresh checkout, no artifacts):
    autogmap delta-bench --nodes 10000 --updaters 2 --queriers 2
  deploys a 10k-node R-MAT graph and mutates it live: --updaters threads
  stream {\"update\":{\"edges\":[[r,c,w],..]}} batches (weight 0 deletes
  an edge) while --queriers threads keep issuing MVMs, every answer
  checked bit-exactly against a host-CSR oracle of the mutated graph.
  Mid-stream and again after the traffic it folds the pending overlay
  with an incremental windowed remap — only delta-touched windows rerun
  controller inference, the persistent scheme cache serves the rest —
  and times that against a from-scratch full remap of the same graph.
  BENCH_delta.json records update/s, query/s, mismatches (always 0 when
  the bench exits 0), cache hit stats, and remap_speedup_vs_full. The
  same dynamic surface is live on any server: serve and serve-net accept
  {\"update\":{\"edges\":..}} request lines and
  {\"admin\":{\"remap\":{\"id\":..}}}; --remap-after N folds the overlay
  automatically every N updates.

  map-large example (fresh checkout, no artifacts):
    autogmap map-large --nodes 100000 --workers 8
  synthesizes a 100k-node R-MAT graph, RCM-reorders it, slices the banded
  matrix into overlapping controller-sized windows, runs native-backend
  controller inference once per unique window sparsity signature (the
  scheme cache dedups repeated patterns), stitches a globally validated
  composite mapping (off-window nnz spills to digital COO storage),
  compiles per-window plans merged across an 8-bank fleet, serves a
  synthetic trace, and writes BENCH_mapper.json with mapped nnz/s at
  1/2/8 workers, the global area ratio vs. the fixed-block baseline at
  the same window size, and the cache hit rate. Add --epochs N to warm up
  the controller with REINFORCE on the densest window first.
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" {
        print!("{USAGE}");
        return;
    }
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> anyhow::Result<()> {
    let value_opts = [
        "config", "dataset", "mtx-path", "grid", "controller", "fill", "fill-arg",
        "reward-a", "lr", "ent-coef", "epochs", "seed", "out", "checkpoint-every",
        "checkpoint", "table", "figure", "artifacts", "coarse", "reorder", "log-every",
        "scheme", "plan", "save-plan", "banks", "policy", "workers", "trace", "batch",
        "requests", "trace-seed", "bench-json", "backend", "nodes", "degree", "overlap",
        "rounds", "kernel", "dense-threshold", "exec", "assert-speedup", "strategy", "block",
        "bundle",
        "batch-window", "stats-every", "listen", "bundles", "queue-depth", "max-conns",
        "max-line-bytes", "bench-clients", "bench-requests", "bench-swap", "pagerank-iters",
        "clients", "fault-bank", "fault-kind", "fault-rate", "fault-seed", "scrub-every",
        "read-timeout-ms", "grace-ms", "remap-after", "updaters", "queriers", "updates",
        "queries", "span",
    ];
    let flag_opts = ["verbose", "help", "bench", "fault-harness", "assert-recovery"];
    let args = Args::parse(argv, &value_opts, &flag_opts, true)
        .map_err(|e| anyhow::anyhow!("{e}\n\n{USAGE}"))?;
    if args.flag("help") {
        print!("{USAGE}");
        return Ok(());
    }
    let artifacts = args.get_or("artifacts", "artifacts").to_string();
    let sub = args.subcommand.clone().unwrap_or_default();
    match sub.as_str() {
        "train" => cmd_train(&args, &artifacts),
        "eval" => cmd_eval(&args, &artifacts),
        "baseline" => cmd_baseline(&args),
        "reproduce" => cmd_reproduce(&args, &artifacts),
        "gen-data" => cmd_gen_data(&args),
        "visualize" => cmd_visualize(&args),
        "info" => cmd_info(&artifacts),
        "serve-bench" => cmd_serve_bench(&args),
        "train-bench" => cmd_train_bench(&args),
        "map-large" => cmd_map_large(&args),
        "deploy" => cmd_deploy(&args),
        "serve" => cmd_serve(&args),
        "serve-net" => cmd_serve_net(&args),
        "algo-bench" => cmd_algo_bench(&args),
        "fault-bench" => cmd_fault_bench(&args),
        "delta-bench" => cmd_delta_bench(&args),
        other => anyhow::bail!("unknown subcommand {other:?}\n\n{USAGE}"),
    }
}

/// Parse `--backend` and build the PJRT runtime only when that backend
/// could actually be used: `native` never touches the artifacts dir, and
/// `auto` resolves to native (no runtime) when no manifest exists.
fn backend_and_runtime(
    args: &Args,
    artifacts: &str,
) -> anyhow::Result<(BackendKind, Option<Runtime>)> {
    let kind = BackendKind::parse(args.get_or("backend", "auto"))?;
    let rt = match kind {
        BackendKind::Native => None,
        BackendKind::Pjrt => Some(Runtime::new(artifacts)?),
        BackendKind::Auto => {
            if Path::new(artifacts).join("manifest.json").exists() {
                Some(Runtime::new(artifacts)?)
            } else {
                None
            }
        }
    };
    Ok((kind, rt))
}

fn dataset_from_args(args: &Args) -> anyhow::Result<Dataset> {
    let kind = args.get_or("dataset", "qm7");
    let seed = args.get_u64("seed").map_err(anyhow::Error::msg)?.unwrap_or_else(|| match kind {
        "qm7" => 5828,
        "qh882" => 882,
        "qh1484" => 1484,
        _ => 0,
    });
    Dataset::parse(kind, seed, args.get("mtx-path")).map_err(|e| anyhow::anyhow!(e))
}

fn config_from_args(args: &Args) -> anyhow::Result<ExperimentConfig> {
    if let Some(path) = args.get("config") {
        let mut cfg = ExperimentConfig::load(Path::new(path))?;
        // flag overrides
        if let Some(e) = args.get_usize("epochs").map_err(anyhow::Error::msg)? {
            cfg.epochs = e;
        }
        if let Some(s) = args.get_u64("seed").map_err(anyhow::Error::msg)? {
            cfg.seed = s;
        }
        return Ok(cfg);
    }
    let dataset = dataset_from_args(args)?;
    let fill_kind = args.get_or("fill", "dynamic");
    let fill_arg = args.get_usize("fill-arg").map_err(anyhow::Error::msg)?.unwrap_or(4);
    let fill_rule = match fill_kind {
        "none" => FillRule::None,
        "fixed" => FillRule::Fixed { size: fill_arg.max(1) },
        "dynamic" => FillRule::Dynamic { grades: fill_arg.max(2) },
        other => anyhow::bail!("unknown fill {other:?}"),
    };
    let default_controller = match (&dataset, &fill_rule) {
        (Dataset::Qm7 { .. }, FillRule::None) => "qm7_diag",
        (Dataset::Qm7 { .. }, FillRule::Fixed { .. }) => "qm7_fill",
        (Dataset::Qm7 { .. }, FillRule::Dynamic { grades: 6 }) => "qm7_dyn6",
        (Dataset::Qm7 { .. }, FillRule::Dynamic { .. }) => "qm7_dyn4",
        (Dataset::Qh882 { .. }, FillRule::Dynamic { grades: 6 }) => "qh882_dyn6",
        (Dataset::Qh882 { .. }, _) => "qh882_dyn4",
        (Dataset::Qh1484 { .. }, FillRule::Dynamic { grades: 6 }) => "qh1484_dyn6",
        (Dataset::Qh1484 { .. }, _) => "qh1484_dyn4",
        _ => anyhow::bail!("pass --controller for this dataset"),
    };
    let controller = args.get_or("controller", default_controller).to_string();
    let grid_default = match &dataset {
        Dataset::Qm7 { .. } => 2,
        _ => 32,
    };
    Ok(ExperimentConfig {
        name: format!("{}_{}", controller, args.get_or("reward-a", "0.8").replace('.', "")),
        dataset,
        grid: args.get_usize("grid").map_err(anyhow::Error::msg)?.unwrap_or(grid_default),
        reordering: Reordering::parse(args.get_or("reorder", "cm")).map_err(anyhow::Error::msg)?,
        controller,
        fill_rule,
        reward_a: args.get_f64("reward-a").map_err(anyhow::Error::msg)?.unwrap_or(0.8),
        lr: args.get_f64("lr").map_err(anyhow::Error::msg)?.unwrap_or(0.015) as f32,
        ent_coef: args.get_f64("ent-coef").map_err(anyhow::Error::msg)?.unwrap_or(0.002) as f32,
        baseline_decay: 0.95,
        epochs: args.get_usize("epochs").map_err(anyhow::Error::msg)?.unwrap_or(4000),
        seed: args.get_u64("seed").map_err(anyhow::Error::msg)?.unwrap_or(0),
        log_every: args.get_usize("log-every").map_err(anyhow::Error::msg)?.unwrap_or(50),
    })
}

fn cmd_train(args: &Args, artifacts: &str) -> anyhow::Result<()> {
    let cfg = config_from_args(args)?;
    let (backend, rt) = backend_and_runtime(args, artifacts)?;
    let opts = RunnerOptions {
        out_root: PathBuf::from(args.get_or("out", "runs")),
        checkpoint_every: args
            .get_usize("checkpoint-every")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(500),
        verbose: args.flag("verbose"),
        keep_history: true,
        backend,
        workers: args.get_usize("workers").map_err(anyhow::Error::msg)?.unwrap_or(0),
    };
    println!("training {} on {} for {} epochs …", cfg.controller, cfg.dataset.label(), cfg.epochs);
    let result = runner::run_experiment(rt.as_ref(), &cfg, &opts)?;
    println!("{}", runner::curves_ascii(&result.history, 78, 14));
    println!("best: {}", runner::describe_best(&result.best, &result.workload.grid));
    println!(
        "wall {:.1}s  ({:.1} epochs/s)  artifacts: {}",
        result.wall_seconds,
        cfg.epochs as f64 / result.wall_seconds,
        result.run_dir.display()
    );
    Ok(())
}

fn cmd_eval(args: &Args, artifacts: &str) -> anyhow::Result<()> {
    let cfg = config_from_args(args)?;
    let (backend, rt) = backend_and_runtime(args, artifacts)?;
    let workload = autogmap::coordinator::dataset::prepare(&cfg)?;
    let topts = autogmap::agent::TrainOptions {
        lr: cfg.lr,
        ent_coef: cfg.ent_coef,
        baseline_decay: cfg.baseline_decay,
        weights: cfg.weights(),
        fill_rule: cfg.fill_rule,
        seed: cfg.seed,
        workers: 1,
    };
    let mut trainer = runner::build_trainer(rt.as_ref(), &cfg.controller, topts, backend)?;
    println!("eval backend: {}", trainer.backend_name());
    if let Some(ck) = args.get("checkpoint") {
        trainer.restore(Path::new(ck))?;
        println!("restored checkpoint {ck} (epoch {})", trainer.epoch);
    }
    let (scheme, eval) = trainer.greedy(&workload.grid)?;
    println!(
        "greedy scheme: diag {:?} fill {:?}",
        scheme.diag_sizes_units(&workload.grid),
        scheme.fill_len
    );
    println!(
        "coverage {:.4}  area {:.4}  sparsity {:.4}  reward {:.4}",
        eval.coverage_ratio, eval.area_ratio, eval.sparsity, eval.reward
    );
    if workload.grid.dim <= 64 {
        println!(
            "{}",
            autogmap::viz::ascii_scheme(&workload.reordered.matrix, &workload.grid, &scheme)
        );
    }
    Ok(())
}

fn cmd_baseline(args: &Args) -> anyhow::Result<()> {
    let ds = dataset_from_args(args)?;
    let grid = args.get_usize("grid").map_err(anyhow::Error::msg)?.unwrap_or(match ds {
        Dataset::Qm7 { .. } => 1,
        _ => 32,
    });
    let coarse = args.get_usize("coarse").map_err(anyhow::Error::msg)?.unwrap_or(8);
    reproduce::baselines_report(&ds, grid, coarse)
}

fn cmd_reproduce(args: &Args, artifacts: &str) -> anyhow::Result<()> {
    let table = args.get_usize("table").map_err(anyhow::Error::msg)?;
    let figure = args.get_usize("figure").map_err(anyhow::Error::msg)?;
    let epochs = args.get_usize("epochs").map_err(anyhow::Error::msg)?;
    let out = PathBuf::from(args.get_or("out", "runs"));
    // figures 2 and 7 need no training backend at all
    match (table, figure) {
        (None, Some(2)) => return reproduce::figure2(&out.join("figures")),
        (None, Some(7)) => return reproduce::figure7(&out.join("figures")),
        _ => {}
    }
    let (backend, rt) = backend_and_runtime(args, artifacts)?;
    let opts = RunnerOptions {
        out_root: out,
        backend,
        workers: args.get_usize("workers").map_err(anyhow::Error::msg)?.unwrap_or(0),
        ..Default::default()
    };
    reproduce::dispatch(rt.as_ref(), table, figure, epochs, &opts)
}

/// `train-bench`: the training-side perf ledger. Times the *native*
/// backend (the PJRT path is covered by `benches/rollout.rs`) — full
/// epochs/sec and rollout episodes/sec at 1, 2, and 8 workers — and
/// writes BENCH_train.json for cross-PR trajectory tracking.
fn cmd_train_bench(args: &Args) -> anyhow::Result<()> {
    use autogmap::agent::{NativeBackend, TrainBackend};
    use autogmap::util::bench;
    use autogmap::util::json::Json;
    use std::time::Instant;

    let cfg = config_from_args(args)?;
    let fast = std::env::var("AUTOGMAP_BENCH_FAST").is_ok_and(|v| v == "1");
    let epochs = args
        .get_usize("epochs")
        .map_err(anyhow::Error::msg)?
        .unwrap_or(if fast { 20 } else { 100 });
    let workload = autogmap::coordinator::dataset::prepare(&cfg)?;
    println!(
        "train-bench {} on {} (grid {} -> N={}), {} epochs per worker count",
        cfg.controller,
        cfg.dataset.label(),
        cfg.grid,
        workload.grid.n,
        epochs
    );

    let ws = [1usize, 2, 8];
    let mut epoch_rate = [0f64; 3];
    let mut rollout_rate = [0f64; 3];
    let mut batch_size = 0usize;
    for (i, &w) in ws.iter().enumerate() {
        let topts = autogmap::agent::TrainOptions {
            lr: cfg.lr,
            ent_coef: cfg.ent_coef,
            baseline_decay: cfg.baseline_decay,
            weights: cfg.weights(),
            fill_rule: cfg.fill_rule,
            seed: cfg.seed,
            workers: w,
        };
        let mut trainer = runner::build_trainer(
            None,
            &cfg.controller,
            topts,
            autogmap::agent::BackendKind::Native,
        )?;
        batch_size = trainer.entry.batch;
        let t0 = Instant::now();
        let mut last_reward = 0.0;
        for _ in 0..epochs {
            last_reward = trainer.epoch(&workload.grid)?.mean_reward;
        }
        epoch_rate[i] = epochs as f64 / t0.elapsed().as_secs_f64();

        // rollout-only throughput (sampling without BPTT/Adam)
        let entry = trainer.entry.clone();
        let mut be = NativeBackend::new(entry, cfg.seed, w);
        let rounds = epochs.max(50);
        let t0 = Instant::now();
        for r in 0..rounds {
            let batch = be.rollout([r as u32, 0x5eed])?;
            std::hint::black_box(batch.d_all.len());
        }
        rollout_rate[i] = (rounds * batch_size) as f64 / t0.elapsed().as_secs_f64();
        println!(
            "  workers {w}: {:.0} epochs/s, {:.0} rollout episodes/s (final R̄ {:.4})",
            epoch_rate[i], rollout_rate[i], last_reward
        );
    }

    let out = args.get_or("bench-json", "BENCH_train.json");
    bench::write_bench_json(
        Path::new(out),
        vec![
            ("bench", Json::Str("train_native".into())),
            ("backend", Json::Str("native".into())),
            ("dataset", Json::Str(cfg.dataset.label())),
            ("controller", Json::Str(cfg.controller.clone())),
            ("grid", Json::Num(cfg.grid as f64)),
            ("batch", Json::Num(batch_size as f64)),
            ("epochs", Json::Num(epochs as f64)),
            ("epochs_per_sec_w1", Json::Num(epoch_rate[0])),
            ("epochs_per_sec_w2", Json::Num(epoch_rate[1])),
            ("epochs_per_sec_w8", Json::Num(epoch_rate[2])),
            ("rollout_eps_w1", Json::Num(rollout_rate[0])),
            ("rollout_eps_w2", Json::Num(rollout_rate[1])),
            ("rollout_eps_w8", Json::Num(rollout_rate[2])),
        ],
    )?;
    println!("wrote {out}");
    Ok(())
}

/// `map-large`: the hierarchical mapper pipeline end-to-end — see
/// [`autogmap::coordinator::maplarge`] for the driver.
fn cmd_map_large(args: &Args) -> anyhow::Result<()> {
    use autogmap::coordinator::MapLargeOptions;
    let defaults = MapLargeOptions::default();
    let opts = MapLargeOptions {
        nodes: args.get_usize("nodes").map_err(anyhow::Error::msg)?.unwrap_or(defaults.nodes),
        degree: args
            .get_usize("degree")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(defaults.degree)
            .max(1),
        grid: args
            .get_usize("grid")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(defaults.grid)
            .max(1),
        seed: args.get_u64("seed").map_err(anyhow::Error::msg)?.unwrap_or(defaults.seed),
        controller: args.get_or("controller", &defaults.controller).to_string(),
        overlap: args
            .get_usize("overlap")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(defaults.overlap),
        rounds: args
            .get_usize("rounds")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(defaults.rounds),
        workers: args
            .get_usize("workers")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(defaults.workers)
            .max(1),
        banks: args
            .get_usize("banks")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(defaults.banks)
            .max(1),
        requests: args
            .get_usize("requests")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(defaults.requests)
            .max(1),
        batch: args
            .get_usize("batch")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(defaults.batch)
            .max(1),
        epochs: args.get_usize("epochs").map_err(anyhow::Error::msg)?.unwrap_or(0),
        checkpoint: args.get("checkpoint").map(PathBuf::from),
        bench_json: PathBuf::from(args.get_or("bench-json", "BENCH_mapper.json")),
    };
    autogmap::coordinator::run_map_large(&opts)
}

/// `deploy`: build a deployment through the [`autogmap::api`] facade and
/// save it as one self-contained bundle — `build()` + `save()` behind
/// flags.
fn cmd_deploy(args: &Args) -> anyhow::Result<()> {
    use anyhow::Context;
    use autogmap::api::{DeploymentBuilder, KernelChoice, Source, Strategy};
    use autogmap::engine::AssignPolicy;
    use std::time::Instant;

    let ds_kind = args.get_or("dataset", "rmat").to_string();
    let seed = args.get_u64("seed").map_err(anyhow::Error::msg)?.unwrap_or(42);
    let source = match ds_kind.as_str() {
        "rmat" => Source::Rmat {
            nodes: args
                .get_usize("nodes")
                .map_err(anyhow::Error::msg)?
                .unwrap_or(10_000)
                .max(64),
            degree: args
                .get_usize("degree")
                .map_err(anyhow::Error::msg)?
                .unwrap_or(8)
                .max(1),
            seed,
        },
        "mtx" => Source::MtxFile(PathBuf::from(
            args.get("mtx-path").context("--dataset mtx needs --mtx-path")?,
        )),
        _ => {
            let ds = dataset_from_args(args)?;
            Source::Matrix {
                label: ds.label(),
                matrix: autogmap::coordinator::dataset::load_matrix(&ds)?,
            }
        }
    };
    let grid = args
        .get_usize("grid")
        .map_err(anyhow::Error::msg)?
        .unwrap_or(if ds_kind == "qm7" { 2 } else { 32 })
        .max(1);
    let controller = args.get_or("controller", "qh882_dyn4").to_string();
    let strategy = match args.get_or("strategy", "hier") {
        "hier" | "hierarchical" => Strategy::Hierarchical {
            controller,
            overlap: args.get_usize("overlap").map_err(anyhow::Error::msg)?.unwrap_or(4),
        },
        "direct" => Strategy::Direct { controller },
        "fixed" => Strategy::FixedBlock {
            block: args.get_usize("block").map_err(anyhow::Error::msg)?.unwrap_or(1).max(1),
        },
        other => anyhow::bail!("unknown strategy {other:?} (hier|direct|fixed)"),
    };
    let mut builder = DeploymentBuilder::new(source, strategy)
        .grid(grid)
        .seed(seed)
        .rounds(args.get_usize("rounds").map_err(anyhow::Error::msg)?.unwrap_or(2))
        .kernel(KernelChoice::parse(args.get_or("kernel", "auto"))?)
        .banks(args.get_usize("banks").map_err(anyhow::Error::msg)?.unwrap_or(8).max(1))
        .policy(AssignPolicy::parse(args.get_or("policy", "balanced"))?)
        .workers(args.get_usize("workers").map_err(anyhow::Error::msg)?.unwrap_or(8).max(1))
        .reward_a(args.get_f64("reward-a").map_err(anyhow::Error::msg)?.unwrap_or(0.8))
        .reordering(Reordering::parse(args.get_or("reorder", "rcm")).map_err(anyhow::Error::msg)?);
    if let Some(t) = args.get_f64("dense-threshold").map_err(anyhow::Error::msg)? {
        builder = builder.dense_threshold(t);
    }
    if let Some(ck) = args.get("checkpoint") {
        builder = builder.checkpoint(PathBuf::from(ck));
    }

    let t0 = Instant::now();
    let dep = builder.build()?;
    let s = dep.stats();
    println!(
        "deployed {} via {} in {:.2}s",
        dep.provenance.source,
        dep.provenance.strategy,
        t0.elapsed().as_secs_f64()
    );
    println!(
        "  plan: {} ({} tiles, {} programs, {} bands, kernels {} dense / {} sparse, \
         {} row patterns / {} dedup hits)",
        dep.plan().kind(),
        s.tiles,
        s.programs,
        s.bands,
        s.kernel_dense,
        s.kernel_sparse,
        s.patterns,
        s.pattern_dedup_hits
    );
    println!(
        "  serving: dim {}, {} mapped + {} spilled nnz, {} programmed cells",
        s.dim, s.mapped_nnz, s.spilled_nnz, s.area_cells
    );
    println!(
        "  fleet: {} banks ({:?}), imbalance {:.3}; default workers {}",
        dep.fleet.banks,
        dep.fleet.policy,
        dep.fleet.imbalance(),
        dep.workers
    );
    let out = PathBuf::from(args.get_or("out", "bundle.json"));
    dep.save(&out)?;
    let bytes = std::fs::metadata(&out).map(|m| m.len()).unwrap_or(0);
    println!("wrote bundle {} ({} KiB)", out.display(), bytes / 1024);
    println!("serve it with: autogmap serve --bundle {}", out.display());
    Ok(())
}

/// `serve`: load a bundle and run the long-running NDJSON loop
/// ([`autogmap::api::serve_loop`]) over stdin/stdout. The banner and the
/// final summary go to stderr so stdout stays pure NDJSON.
fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    use anyhow::Context;
    use autogmap::api::{serve_loop, Deployment, ServeOptions};
    use std::io::Write;

    let bundle = args.get("bundle").context("serve needs --bundle <bundle.json>")?;
    let mut dep = Deployment::load(Path::new(bundle))?;
    if args.flag("fault-harness") {
        let fopts = autogmap::fault::FaultOptions {
            scrub_every: args
                .get_u64("scrub-every")
                .map_err(anyhow::Error::msg)?
                .unwrap_or(autogmap::fault::FaultOptions::default().scrub_every),
            ..autogmap::fault::FaultOptions::default()
        };
        dep.arm_fault_harness(fopts);
        eprintln!(
            "fault harness armed: ABFT column checksums per request, scrub every {} requests",
            fopts.scrub_every
        );
    }
    let sharded = match args.get_or("exec", "sharded") {
        "sharded" => true,
        "scalar" => false,
        other => anyhow::bail!("unknown exec mode {other:?} (scalar|sharded)"),
    };
    let defaults = ServeOptions::default();
    let opts = ServeOptions {
        workers: args.get_usize("workers").map_err(anyhow::Error::msg)?.unwrap_or(0),
        batch_window: args
            .get_usize("batch-window")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(1)
            .max(1),
        stats_every: args.get_usize("stats-every").map_err(anyhow::Error::msg)?.unwrap_or(100),
        sharded,
        max_line_bytes: args
            .get_usize("max-line-bytes")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(defaults.max_line_bytes)
            .max(1),
        remap_after: args
            .get_usize("remap-after")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(defaults.remap_after),
    };
    let s = dep.stats();
    eprintln!(
        "serving {} ({}): dim {}, {} tiles / {} programs, {} mapped + {} spilled nnz — \
         NDJSON requests on stdin",
        dep.provenance.source,
        dep.provenance.strategy,
        s.dim,
        s.tiles,
        s.programs,
        s.mapped_nnz,
        s.spilled_nnz
    );
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let mut out = std::io::BufWriter::new(stdout.lock());
    let report = serve_loop(&dep, &opts, stdin.lock(), &mut out)?;
    out.flush()?;
    eprintln!(
        "served {} requests ({} batches, {} errors) in {:.2}s — {:.0} req/s, {:.3e} nnz/s",
        report.served,
        report.batches,
        report.errors,
        report.wall_seconds,
        report.rps,
        report.nnz_per_s
    );
    Ok(())
}

/// Parse a `--bundles` / `--bench-swap` style `id=path[,id=path...]`
/// list.
fn parse_bundle_list(spec: &str) -> anyhow::Result<Vec<(String, PathBuf)>> {
    let mut out = Vec::new();
    for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
        let (id, path) = part
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("bundle spec {part:?} is not id=path"))?;
        anyhow::ensure!(!id.trim().is_empty(), "bundle spec {part:?} has an empty id");
        out.push((id.trim().to_string(), PathBuf::from(path.trim())));
    }
    anyhow::ensure!(!out.is_empty(), "bundle list {spec:?} names no bundles");
    Ok(out)
}

/// `serve-net`: the multi-tenant TCP serving tier — load every
/// `--bundles id=path` into a [`autogmap::net::DeploymentRegistry`] and
/// serve NDJSON-over-socket until killed; or, with `--bench`, run the
/// self-checking concurrent load driver and exit.
fn cmd_serve_net(args: &Args) -> anyhow::Result<()> {
    use anyhow::Context;
    use autogmap::net::{
        run_net_bench, DeploymentRegistry, NetBenchOptions, NetOptions, NetServer,
        RegistryOptions,
    };
    use std::sync::Arc;

    let bundles =
        parse_bundle_list(args.get("bundles").context("serve-net needs --bundles id=path,...")?)?;
    let sharded = match args.get_or("exec", "sharded") {
        "sharded" => true,
        "scalar" => false,
        other => anyhow::bail!("unknown exec mode {other:?} (scalar|sharded)"),
    };
    let workers = args.get_usize("workers").map_err(anyhow::Error::msg)?.unwrap_or(8).max(1);
    let queue_depth =
        args.get_usize("queue-depth").map_err(anyhow::Error::msg)?.unwrap_or(32).max(1);

    if args.flag("bench") {
        let swap = match args.get("bench-swap") {
            Some(spec) => {
                let mut list = parse_bundle_list(spec)?;
                anyhow::ensure!(list.len() == 1, "--bench-swap takes exactly one id=path");
                Some(list.remove(0))
            }
            None => None,
        };
        let defaults = NetBenchOptions::default();
        let opts = NetBenchOptions {
            bundles,
            listen: args.get_or("listen", "127.0.0.1:0").to_string(),
            workers,
            queue_depth,
            sharded,
            clients: args
                .get_usize("bench-clients")
                .map_err(anyhow::Error::msg)?
                .unwrap_or(defaults.clients)
                .max(1),
            requests: args
                .get_usize("bench-requests")
                .map_err(anyhow::Error::msg)?
                .unwrap_or(defaults.requests)
                .max(1),
            swap,
            seed: args.get_u64("seed").map_err(anyhow::Error::msg)?.unwrap_or(defaults.seed),
            bench_json: PathBuf::from(args.get_or("bench-json", "BENCH_serve_net.json")),
        };
        let report = run_net_bench(&opts)?;
        println!(
            "serve-net bench: {} requests over {} tenants x {} clients in {:.2}s -> {:.0} req/s \
             (hot-swap: {}); every answer bit-matched Deployment::mvm",
            report.served,
            report.tenants,
            opts.clients,
            report.wall_s,
            report.rps,
            if report.swapped { "yes" } else { "no" }
        );
        println!("wrote {}", opts.bench_json.display());
        return Ok(());
    }

    let fault = if args.flag("fault-harness") {
        Some(autogmap::fault::FaultOptions {
            scrub_every: args
                .get_u64("scrub-every")
                .map_err(anyhow::Error::msg)?
                .unwrap_or(autogmap::fault::FaultOptions::default().scrub_every),
            ..autogmap::fault::FaultOptions::default()
        })
    } else {
        None
    };
    let registry = Arc::new(DeploymentRegistry::new(&RegistryOptions {
        workers,
        queue_depth,
        sharded,
        fault,
        remap_after: args
            .get_usize("remap-after")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(0),
    }));
    for (id, path) in &bundles {
        let tenant = registry.load_bundle(id, path)?;
        let entry = tenant.entry();
        eprintln!(
            "tenant {id}: dim {}, {} nnz, queue depth {}{} ({})",
            entry.dim(),
            entry.nnz(),
            tenant.queue_depth(),
            if entry.fault_harness().is_some() { ", fault harness armed" } else { "" },
            path.display()
        );
    }
    let net_defaults = NetOptions::default();
    let opts = NetOptions {
        max_conns: args
            .get_usize("max-conns")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(net_defaults.max_conns)
            .max(1),
        max_line_bytes: args
            .get_usize("max-line-bytes")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(net_defaults.max_line_bytes)
            .max(1),
        read_timeout_ms: args
            .get_u64("read-timeout-ms")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(net_defaults.read_timeout_ms),
    };
    let grace_ms = args.get_u64("grace-ms").map_err(anyhow::Error::msg)?.unwrap_or(5000);
    let listen = args.get_or("listen", "127.0.0.1:7070");
    let mut server = NetServer::start(registry.clone(), listen, &opts)?;
    eprintln!(
        "serve-net listening on {} ({} workers, {} max conns) — NDJSON per line; \
         {{\"admin\":\"stats\"}} for stats, SIGTERM/ctrl-c for graceful shutdown",
        server.addr(),
        workers,
        opts.max_conns
    );
    if install_shutdown_signals() {
        // graceful path: sleep until SIGTERM/SIGINT, then stop accepting,
        // drain in-flight requests, print a final stats line, exit 0
        while !shutdown_requested() {
            std::thread::sleep(std::time::Duration::from_millis(50));
        }
        eprintln!("serve-net: shutdown signal received, draining ({grace_ms}ms grace)");
        let drained = server.shutdown_graceful(std::time::Duration::from_millis(grace_ms));
        println!(
            "{}",
            autogmap::util::json::obj(vec![(
                "stats",
                registry.stats_json()
            )])
            .to_string()
        );
        eprintln!(
            "serve-net: {} — exiting",
            if drained { "all connections drained" } else { "grace expired with connections open" }
        );
    } else {
        // no signal support on this platform: block on the accept loop
        server.join();
    }
    Ok(())
}

static SHUTDOWN_FLAG: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

fn shutdown_requested() -> bool {
    SHUTDOWN_FLAG.load(std::sync::atomic::Ordering::SeqCst)
}

/// Route SIGTERM and SIGINT into [`SHUTDOWN_FLAG`] so `serve-net` can
/// drain gracefully. Uses the libc `signal` entry point directly (std
/// already links libc on unix); returns false on platforms without it,
/// where the caller falls back to blocking forever.
#[cfg(unix)]
fn install_shutdown_signals() -> bool {
    extern "C" fn on_shutdown_signal(_sig: i32) {
        SHUTDOWN_FLAG.store(true, std::sync::atomic::Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGINT, on_shutdown_signal as usize);
        signal(SIGTERM, on_shutdown_signal as usize);
    }
    true
}

#[cfg(not(unix))]
fn install_shutdown_signals() -> bool {
    false
}

fn cmd_algo_bench(args: &Args) -> anyhow::Result<()> {
    use autogmap::algo::{run_algo_bench, AlgoBenchOptions};

    let defaults = AlgoBenchOptions::default();
    let sharded = match args.get_or("exec", "sharded") {
        "sharded" => true,
        "scalar" => false,
        other => anyhow::bail!("unknown exec mode {other:?} (scalar|sharded)"),
    };
    let workers = match args.get_usize("workers").map_err(anyhow::Error::msg)? {
        Some(w) => vec![w.max(1)],
        None => defaults.workers.clone(),
    };
    let opts = AlgoBenchOptions {
        nodes: args.get_usize("nodes").map_err(anyhow::Error::msg)?.unwrap_or(defaults.nodes),
        degree: args.get_usize("degree").map_err(anyhow::Error::msg)?.unwrap_or(defaults.degree),
        grid: args.get_usize("grid").map_err(anyhow::Error::msg)?.unwrap_or(defaults.grid),
        block: args.get_usize("block").map_err(anyhow::Error::msg)?.unwrap_or(defaults.block),
        seed: args.get_u64("seed").map_err(anyhow::Error::msg)?.unwrap_or(defaults.seed),
        workers,
        sharded,
        pagerank_iters: args
            .get_usize("pagerank-iters")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(defaults.pagerank_iters)
            .max(1),
        bench_json: PathBuf::from(args.get_or("bench-json", "BENCH_algo.json")),
    };
    let ledger = run_algo_bench(&opts)?;
    let last = format!("workers_{}", opts.workers.last().copied().unwrap_or(1));
    for plan in ["flat", "composite"] {
        let cfg = ledger.get("plans").get(plan).get(last.as_str());
        for algo in ["pagerank", "bfs", "sssp", "gcn"] {
            let t = cfg.get(algo);
            println!(
                "algo-bench {plan}/{last} {algo}: {} iters in {:.3}s -> {:.1} iters/s, {:.3e} nnz/s",
                t.get("iterations").as_i64().unwrap_or(0),
                t.get("wall_s").as_f64().unwrap_or(0.0),
                t.get("iters_per_s").as_f64().unwrap_or(0.0),
                t.get("nnz_per_s").as_f64().unwrap_or(0.0),
            );
        }
    }
    println!(
        "all answers matched the CSR references (bfs/sssp bit-exact, pagerank <= 1e-8, \
         gcn <= 1e-5)"
    );
    println!("wrote {}", opts.bench_json.display());
    Ok(())
}

/// `fault-bench`: the chaos harness ([`autogmap::fault::run_fault_bench`])
/// — fault-armed R-MAT serving behind a real socket, mid-stream injection
/// under concurrent clients, every response oracle-checked.
fn cmd_fault_bench(args: &Args) -> anyhow::Result<()> {
    use autogmap::fault::{run_fault_bench, FaultBenchOptions};

    let defaults = FaultBenchOptions::default();
    let opts = FaultBenchOptions {
        nodes: args.get_usize("nodes").map_err(anyhow::Error::msg)?.unwrap_or(defaults.nodes),
        degree: args.get_usize("degree").map_err(anyhow::Error::msg)?.unwrap_or(defaults.degree),
        grid: args.get_usize("grid").map_err(anyhow::Error::msg)?.unwrap_or(defaults.grid),
        banks: args.get_usize("banks").map_err(anyhow::Error::msg)?.unwrap_or(defaults.banks),
        workers: args
            .get_usize("workers")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(defaults.workers)
            .max(1),
        queue_depth: args
            .get_usize("queue-depth")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(defaults.queue_depth)
            .max(1),
        clients: args
            .get_usize("clients")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(defaults.clients),
        requests: args
            .get_usize("requests")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(defaults.requests)
            .max(1),
        fault_bank: args
            .get_usize("fault-bank")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(defaults.fault_bank),
        fault_kind: args.get_or("fault-kind", &defaults.fault_kind).to_string(),
        fault_rate: args
            .get_f64("fault-rate")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(defaults.fault_rate),
        fault_seed: args
            .get_u64("fault-seed")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(defaults.fault_seed),
        scrub_every: args
            .get_u64("scrub-every")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(defaults.scrub_every),
        seed: args.get_u64("seed").map_err(anyhow::Error::msg)?.unwrap_or(defaults.seed),
        listen: args.get_or("listen", &defaults.listen).to_string(),
        bench_json: PathBuf::from(args.get_or("bench-json", "BENCH_fault.json")),
        assert_recovery: args.flag("assert-recovery"),
    };
    let report = run_fault_bench(&opts)?;
    println!(
        "fault-bench: {} requests served across 3 phases, {} degraded, 0 wrong answers \
         escaped ({} cells injected on bank {})",
        report.served, report.degraded_responses, report.injected_cells, opts.fault_bank
    );
    println!(
        "  detection {:.1}ms, repair {:.1}ms; nnz/s pre {:.3e} -> degraded {:.3e} -> \
         post-repair {:.3e} (recovery {:.0}%)",
        report.detection_ms,
        report.repair_ms,
        report.pre_fault_nnz_per_s,
        report.degraded_nnz_per_s,
        report.post_repair_nnz_per_s,
        report.recovery_ratio * 100.0
    );
    println!("wrote {}", opts.bench_json.display());
    Ok(())
}

/// `delta-bench`: the dynamic-graph harness
/// ([`autogmap::delta::run_delta_bench`]) — concurrent edge updaters and
/// queriers against a live deployment, every answer checked against a
/// mutating host-CSR oracle, incremental vs full remap latency.
fn cmd_delta_bench(args: &Args) -> anyhow::Result<()> {
    use autogmap::delta::{run_delta_bench, DeltaBenchOptions};

    let defaults = DeltaBenchOptions::default();
    let opts = DeltaBenchOptions {
        nodes: args.get_usize("nodes").map_err(anyhow::Error::msg)?.unwrap_or(defaults.nodes),
        degree: args.get_usize("degree").map_err(anyhow::Error::msg)?.unwrap_or(defaults.degree),
        grid: args.get_usize("grid").map_err(anyhow::Error::msg)?.unwrap_or(defaults.grid),
        controller: args.get_or("controller", &defaults.controller).to_string(),
        overlap: args
            .get_usize("overlap")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(defaults.overlap),
        banks: args
            .get_usize("banks")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(defaults.banks)
            .max(1),
        workers: args
            .get_usize("workers")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(defaults.workers)
            .max(1),
        updaters: args
            .get_usize("updaters")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(defaults.updaters)
            .max(1),
        queriers: args
            .get_usize("queriers")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(defaults.queriers)
            .max(1),
        updates: args
            .get_usize("updates")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(defaults.updates)
            .max(1),
        batch: args
            .get_usize("batch")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(defaults.batch)
            .max(1),
        queries: args
            .get_usize("queries")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(defaults.queries)
            .max(1),
        span: args.get_f64("span").map_err(anyhow::Error::msg)?.unwrap_or(defaults.span),
        seed: args.get_u64("seed").map_err(anyhow::Error::msg)?.unwrap_or(defaults.seed),
        bench_json: PathBuf::from(args.get_or("bench-json", "BENCH_delta.json")),
    };
    let report = run_delta_bench(&opts)?;
    println!(
        "delta-bench: {} updates applied and {} queries served against a {}-node graph \
         ({} nnz), 0 mismatches — {:.0} updates/s, {:.0} queries/s",
        report.updates_applied,
        report.queries_served,
        report.nodes,
        report.nnz,
        report.update_per_s,
        report.query_per_s
    );
    println!(
        "  incremental remap {:.3}s ({} of {} windows re-inferred, cache hit rate {:.2}) vs \
         full remap {:.3}s -> {:.2}x faster",
        report.remap_incremental.wall_seconds,
        report.remap_incremental.windows - report.remap_incremental.reused_windows,
        report.remap_incremental.windows,
        report.remap_incremental.cache_hit_rate,
        report.remap_full.wall_seconds,
        report.remap_speedup_vs_full
    );
    println!("wrote {}", opts.bench_json.display());
    Ok(())
}

fn cmd_gen_data(args: &Args) -> anyhow::Result<()> {
    let out = PathBuf::from(args.get_or("out", "data"));
    let stats = autogmap::coordinator::dataset::generate_all(&out)?;
    for (name, dim, nnz) in stats {
        println!("{}: {dim}x{dim}, nnz {nnz} -> {}", name, out.join(format!("{name}.mtx")).display());
    }
    Ok(())
}

fn cmd_visualize(args: &Args) -> anyhow::Result<()> {
    let ds = dataset_from_args(args)?;
    let m = autogmap::coordinator::dataset::load_matrix(&ds)?;
    let r = autogmap::reorder::reorder(&m, Reordering::CuthillMckee);
    println!(
        "{}: {}x{}, nnz {}, sparsity {:.4}, bandwidth {} -> {} (CM)",
        ds.label(),
        m.rows,
        m.cols,
        m.nnz(),
        m.sparsity(),
        r.bandwidth_before,
        r.bandwidth_after
    );
    println!("{}", autogmap::viz::ascii_spy(&r.matrix, 44));
    let out = PathBuf::from(args.get_or("out", "figures"));
    std::fs::create_dir_all(&out)?;
    let g = autogmap::graph::GridSummary::new(&r.matrix, if m.rows > 100 { 32 } else { 2 });
    let file = out.join(format!("{}.svg", ds.label()));
    std::fs::write(&file, autogmap::viz::svg_scheme(&r.matrix, &g, None, &ds.label()))?;
    println!("wrote {}", file.display());
    Ok(())
}

fn cmd_serve_bench(args: &Args) -> anyhow::Result<()> {
    use anyhow::Context;
    use autogmap::crossbar::{cost::CostModel, place, CrossbarArray};
    use autogmap::engine::{self, AssignPolicy, BatchExecutor, ExecPlan, Fleet, TraceKind};
    use autogmap::graph::GridSummary;
    use autogmap::scheme::Scheme;
    use autogmap::util::bench;
    use autogmap::util::json::Json;
    use std::sync::Arc;
    use std::time::Instant;

    // --- workload: a named dataset, or a synthetic R-MAT serving workload
    // (--dataset rmat --nodes N --degree D) for at-scale kernel numbers
    let ds_kind = args.get_or("dataset", "qm7").to_string();
    let reordering =
        Reordering::parse(args.get_or("reorder", "cm")).map_err(anyhow::Error::msg)?;
    let (label, m, grid, batch_ds) = if ds_kind == "rmat" {
        let nodes =
            args.get_usize("nodes").map_err(anyhow::Error::msg)?.unwrap_or(10_000).max(64);
        let degree = args.get_usize("degree").map_err(anyhow::Error::msg)?.unwrap_or(8).max(1);
        let seed = args.get_u64("seed").map_err(anyhow::Error::msg)?.unwrap_or(42);
        let grid = args.get_usize("grid").map_err(anyhow::Error::msg)?.unwrap_or(32).max(1);
        let m = autogmap::graph::synth::rmat_like(nodes, 2 * (nodes * degree / 2), seed);
        (format!("rmat{nodes}"), m, grid, None)
    } else {
        let ds = dataset_from_args(args)?;
        let grid = args.get_usize("grid").map_err(anyhow::Error::msg)?.unwrap_or(match ds {
            Dataset::Qm7 { .. } => 2,
            Dataset::Batch { .. } => 22,
            _ => 32,
        });
        let m = autogmap::coordinator::dataset::load_matrix(&ds)?;
        (ds.label(), m, grid, Some(ds))
    };
    let r = autogmap::reorder::reorder(&m, reordering);
    let g = GridSummary::new(&r.matrix, grid);

    // --- plan: load a deployable artifact, or compile from a scheme (the
    // latter also places the CrossbarArray oracle for the baseline loop;
    // skipped for rmat workloads — the oracle materializes every tile
    // densely, and the plan-scalar rung is the baseline there)
    let scheme_name;
    let (mut plan, oracle): (ExecPlan, Option<CrossbarArray>) = if let Some(p) = args.get("plan")
    {
        scheme_name = format!("plan:{p}");
        let plan = ExecPlan::load(Path::new(p))?;
        anyhow::ensure!(
            plan.dim == g.dim && plan.k == grid,
            "plan {p} is for dim {} grid {}, but the selected dataset is dim {} grid {grid}",
            plan.dim,
            plan.k,
            g.dim
        );
        (plan, None)
    } else {
        let kind = args.get_or("scheme", "full");
        let scheme = match kind {
            "full" => Scheme { diag_len: vec![g.n], fill_len: vec![] },
            "unit" => Scheme {
                diag_len: vec![1; g.n],
                fill_len: vec![1; g.n.saturating_sub(1)],
            },
            "oracle" => autogmap::baselines::oracle::optimal_diagonal(&g)
                .context("DP oracle found no complete-coverage partition")?,
            other => anyhow::bail!("unknown scheme {other:?} (full|unit|oracle)"),
        };
        scheme_name = kind.to_string();
        let plan = engine::compile(&r.matrix, &g, &scheme)?;
        let arr = if ds_kind == "rmat" { None } else { Some(place(&r.matrix, &g, &scheme)?) };
        (plan, arr)
    };

    // --- kernel mode: auto density-threshold selection (the compiled
    // default, retunable with --dense-threshold), or force one kernel
    // for A/B runs
    let kernel = args.get_or("kernel", "auto").to_string();
    match kernel.as_str() {
        "auto" => {
            if let Some(t) = args.get_f64("dense-threshold").map_err(anyhow::Error::msg)? {
                plan.rekernel(t);
            }
        }
        "dense" => plan.rekernel(0.0),
        "sparse" => plan.rekernel(f64::INFINITY),
        other => anyhow::bail!("unknown kernel {other:?} (auto|dense|sparse)"),
    }
    if let Some(p) = args.get("save-plan") {
        plan.save(Path::new(p))?;
        println!("wrote plan artifact {p}");
    }

    // --- fleet accounting (simulated banks; numerics run on the host)
    let banks = args.get_usize("banks").map_err(anyhow::Error::msg)?.unwrap_or(8).max(1);
    let policy = AssignPolicy::parse(args.get_or("policy", "balanced"))?;
    let fleet = Fleet::assign(&plan, banks, policy)?;
    let cost = CostModel::default();

    // --- synthetic request trace
    let trace_kind = TraceKind::parse(args.get_or("trace", "uniform"))?;
    let batch = args.get_usize("batch").map_err(anyhow::Error::msg)?.unwrap_or(64).max(1);
    let requests =
        args.get_usize("requests").map_err(anyhow::Error::msg)?.unwrap_or(512).max(1);
    // --seed selects the synthetic *dataset* (as in every other
    // subcommand); --trace-seed varies the request traffic independently,
    // so BENCH_engine.json stays comparable across traffic seeds.
    let trace_seed =
        args.get_u64("trace-seed").map_err(anyhow::Error::msg)?.unwrap_or(0x5eed);
    let segments: Vec<(usize, usize)> = match &batch_ds {
        Some(Dataset::Batch { count, .. }) if *count > 0 => {
            // index segments of the supermatrix, one per sub-graph
            let sub = g.dim / *count;
            (0..*count)
                .map(|i| (i * sub, if i + 1 == *count { g.dim } else { (i + 1) * sub }))
                .collect()
        }
        _ => vec![(0, g.dim)],
    };
    let trace = engine::synth_trace(trace_kind, g.dim, requests, batch, &segments, trace_seed);
    let workers = args.get_usize("workers").map_err(anyhow::Error::msg)?.unwrap_or(banks).max(1);
    let exec_sel = args.get_or("exec", "both").to_string();
    anyhow::ensure!(
        matches!(exec_sel.as_str(), "both" | "scalar" | "sharded"),
        "unknown exec mode {exec_sel:?} (scalar|sharded|both)"
    );

    let (kernel_dense, kernel_sparse) = plan.kernel_counts();
    let mapped_nnz = plan.mapped_nnz();
    println!(
        "serve-bench {label}: dim {} grid {grid} (N={}), scheme {scheme_name}, kernel {kernel}",
        g.dim,
        g.n
    );
    println!(
        "plan: {} scheduled tiles -> {} placed ({} elided, {:.1}% elision), {} unique programs ({:.1}% dedup), {} cells, {} nnz",
        plan.scheduled_tiles,
        plan.tiles.len(),
        plan.elided_tiles,
        plan.elision_ratio() * 100.0,
        plan.num_programs(),
        plan.dedup_ratio() * 100.0,
        plan.cells(),
        mapped_nnz
    );
    let (nnz_dense, nnz_sparse) = plan.kernel_nnz();
    let (bytes_dense, bytes_sparse) = plan.kernel_bytes();
    let pattern_hits = plan.pattern_dedup_hits();
    let pattern_hit_rate = if kernel_sparse > 0 {
        pattern_hits as f64 / kernel_sparse as f64
    } else {
        0.0
    };
    println!(
        "arena: {} row bands, {} cells (+{} lane padding, lane {}), kernels {kernel_dense} dense / {kernel_sparse} sparse",
        plan.bands().len(),
        plan.arena_len(),
        plan.arena_padding(),
        autogmap::engine::LANE
    );
    println!(
        "patterns: {} shared row patterns serve {kernel_sparse} sparse programs ({pattern_hits} dedup hits, {:.1}% hit rate)",
        plan.num_patterns(),
        pattern_hit_rate * 100.0
    );
    println!(
        "fleet: {} banks ({:?}), nnz imbalance {:.3}, modelled mvm latency {:.2} us, energy {:.2} nJ",
        fleet.banks,
        fleet.policy,
        fleet.imbalance(),
        fleet.mvm_latency_ns(&cost) / 1e3,
        fleet.mvm_energy_pj(&cost) / 1e3
    );

    // --- rung 1: the scalar per-request baseline (the seed's row-dot
    // loop, preserved verbatim as mvm_scalar_into), single-threaded —
    // the in-run reference every optimized number in the ledger is
    // compared against
    let nnz_work = mapped_nnz as f64 * requests as f64;
    let mut y = Vec::new();
    plan.mvm_scalar_into(&trace[0][0], &mut y); // warmup
    let t0 = Instant::now();
    for x in trace.iter().flatten() {
        plan.mvm_scalar_into(x, &mut y);
        std::hint::black_box(y.first().copied());
    }
    let scalar_wall = t0.elapsed().as_secs_f64();
    let scalar_rps = requests as f64 / scalar_wall;
    let scalar_nnz_per_s = nnz_work / scalar_wall;
    println!(
        "scalar baseline: 1 thread, {requests} requests in {scalar_wall:.3}s -> {scalar_rps:.0} req/s ({scalar_nnz_per_s:.3e} nnz/s)"
    );

    // --- rung 2: the vectorized kernels on the same single thread —
    // isolates the unroll + pattern-dedup win from worker fan-out
    plan.mvm_into(&trace[0][0], &mut y); // warmup
    let t0 = Instant::now();
    for x in trace.iter().flatten() {
        plan.mvm_into(x, &mut y);
        std::hint::black_box(y.first().copied());
    }
    let vectorized_wall = t0.elapsed().as_secs_f64();
    let vectorized_rps = requests as f64 / vectorized_wall;
    let vectorized_nnz_per_s = nnz_work / vectorized_wall;
    println!(
        "vectorized kernels: 1 thread, {requests} requests in {vectorized_wall:.3}s -> {vectorized_rps:.0} req/s ({vectorized_nnz_per_s:.3e} nnz/s, {:.2}x scalar)",
        scalar_wall / vectorized_wall
    );

    // --- per-kernel roofline rungs: replay the trace through one kernel
    // kind at a time so the ledger can attribute nnz/s to the dense and
    // sparse bodies separately (bytes touched come from the plan layout)
    let mut kind_nnz_per_s = |kind, kind_nnz: u64| -> Option<f64> {
        if kind_nnz == 0 {
            return None;
        }
        plan.mvm_kind_into(kind, &trace[0][0], &mut y); // warmup
        let t0 = Instant::now();
        for x in trace.iter().flatten() {
            plan.mvm_kind_into(kind, x, &mut y);
            std::hint::black_box(y.first().copied());
        }
        Some(kind_nnz as f64 * requests as f64 / t0.elapsed().as_secs_f64())
    };
    let dense_nnz_per_s = kind_nnz_per_s(autogmap::engine::KernelKind::Dense, nnz_dense);
    let sparse_nnz_per_s = kind_nnz_per_s(autogmap::engine::KernelKind::Sparse, nnz_sparse);
    for (name, rate, bytes, kind_nnz) in [
        ("dense", dense_nnz_per_s, bytes_dense, nnz_dense),
        ("sparse", sparse_nnz_per_s, bytes_sparse, nnz_sparse),
    ] {
        if let Some(r) = rate {
            println!(
                "roofline {name}: {r:.3e} nnz/s over {bytes} arena bytes ({:.3} flops/byte)",
                2.0 * kind_nnz as f64 / bytes as f64
            );
        }
    }

    // --- rungs 3-4: the executor modes over the same trace
    let plan = Arc::new(plan);
    let exec = BatchExecutor::new(plan.clone(), workers);
    let run_trace = |sharded: bool| -> (f64, f64, f64) {
        let warm = if sharded {
            exec.execute_batch_sharded(trace[0].clone())
        } else {
            exec.execute_batch(trace[0].clone())
        };
        exec.recycle(warm); // primes the buffer pool
        let mut latencies_ms: Vec<f64> = Vec::with_capacity(requests);
        let t0 = Instant::now();
        for batch_reqs in &trace {
            let tb = Instant::now();
            let ys = if sharded {
                exec.execute_batch_sharded(batch_reqs.clone())
            } else {
                exec.execute_batch(batch_reqs.clone())
            };
            let dt_ms = tb.elapsed().as_secs_f64() * 1e3;
            latencies_ms.extend(std::iter::repeat(dt_ms).take(ys.len()));
            exec.recycle(ys);
        }
        let wall = t0.elapsed().as_secs_f64();
        (
            wall,
            bench::percentile(&latencies_ms, 50.0),
            bench::percentile(&latencies_ms, 99.0),
        )
    };
    let parallel_scalar = if exec_sel != "sharded" {
        let (wall, p50, p99) = run_trace(false);
        println!(
            "engine scalar: {requests} requests / {} batches ({:?} trace) in {wall:.3}s -> {:.0} req/s, p50 {p50:.3} ms, p99 {p99:.3} ms ({workers} workers)",
            trace.len(),
            trace_kind,
            requests as f64 / wall
        );
        Some((wall, p50, p99))
    } else {
        None
    };
    let sharded_res = if exec_sel != "scalar" {
        let (wall, p50, p99) = run_trace(true);
        println!(
            "engine sharded multi-RHS: {requests} requests in {wall:.3}s -> {:.0} req/s, p50 {p50:.3} ms, p99 {p99:.3} ms ({workers} workers, {} spans)",
            requests as f64 / wall,
            plan.band_spans(workers).len()
        );
        Some((wall, p50, p99))
    } else {
        None
    };
    let (head_wall, p50, p99) =
        sharded_res.or(parallel_scalar).expect("at least one executor mode runs");
    let throughput = requests as f64 / head_wall;
    if sharded_res.is_some() {
        println!(
            "speedup: optimized {:.2}x over the single-thread scalar baseline",
            throughput / scalar_rps
        );
    }

    // --- single-threaded oracle loop over the same trace, plus a
    // correctness spot-check of the engine against it
    let mut oracle_rps = None;
    if let Some(arr) = &oracle {
        let want = arr.mvm(&trace[0][0]);
        let got = plan.mvm(&trace[0][0]);
        for (a, b) in got.iter().zip(want.iter()) {
            anyhow::ensure!((a - b).abs() < 1e-9, "engine diverged from oracle: {a} vs {b}");
        }
        let t0 = Instant::now();
        let mut sink = 0.0f64;
        for x in trace.iter().flatten() {
            sink += arr.mvm(x)[0];
        }
        let wall_oracle = t0.elapsed().as_secs_f64();
        std::hint::black_box(sink);
        let rps = requests as f64 / wall_oracle;
        println!(
            "oracle: single-threaded CrossbarArray::mvm -> {:.0} req/s (engine speedup {:.2}x)",
            rps,
            throughput / rps
        );
        oracle_rps = Some(rps);
    } else if ds_kind == "rmat" {
        println!("oracle: skipped (rmat workload; the plan-scalar rung is the baseline)");
    } else {
        println!("oracle: skipped (plan loaded from disk; no scheme to place)");
    }

    // --- machine-readable artifact for perf-trajectory tracking: the
    // scalar baseline and the optimized mode from the same run, always
    let out = args.get_or("bench-json", "BENCH_engine.json");
    let mut fields = vec![
        ("bench", Json::Str("engine_serve".into())),
        ("dataset", Json::Str(label)),
        ("dim", Json::Num(g.dim as f64)),
        ("grid", Json::Num(grid as f64)),
        ("scheme", Json::Str(scheme_name)),
        ("kernel", Json::Str(kernel)),
        ("exec", Json::Str(exec_sel)),
        ("trace", Json::Str(args.get_or("trace", "uniform").to_string())),
        ("requests", Json::Num(requests as f64)),
        ("nominal_batch", Json::Num(batch as f64)),
        ("banks", Json::Num(banks as f64)),
        ("workers", Json::Num(workers as f64)),
        ("policy", Json::Str(format!("{:?}", fleet.policy))),
        ("scheduled_tiles", Json::Num(plan.scheduled_tiles as f64)),
        ("placed_tiles", Json::Num(plan.tiles.len() as f64)),
        ("elision_ratio", Json::Num(plan.elision_ratio())),
        ("dedup_ratio", Json::Num(plan.dedup_ratio())),
        ("bands", Json::Num(plan.bands().len() as f64)),
        ("kernel_dense_programs", Json::Num(kernel_dense as f64)),
        ("kernel_sparse_programs", Json::Num(kernel_sparse as f64)),
        ("mapped_nnz", Json::Num(mapped_nnz as f64)),
        ("lane_width", Json::Num(autogmap::engine::LANE as f64)),
        ("arena_cells", Json::Num(plan.arena_len() as f64)),
        ("arena_padding_cells", Json::Num(plan.arena_padding() as f64)),
        ("row_patterns", Json::Num(plan.num_patterns() as f64)),
        ("pattern_dedup_hits", Json::Num(pattern_hits as f64)),
        ("pattern_dedup_hit_rate", Json::Num(pattern_hit_rate)),
        ("dense_arena_bytes", Json::Num(bytes_dense as f64)),
        ("sparse_arena_bytes", Json::Num(bytes_sparse as f64)),
        ("dense_nnz", Json::Num(nnz_dense as f64)),
        ("sparse_nnz", Json::Num(nnz_sparse as f64)),
        ("fleet_imbalance", Json::Num(fleet.imbalance())),
        ("fleet_latency_ns", Json::Num(fleet.mvm_latency_ns(&cost))),
        ("fleet_energy_pj", Json::Num(fleet.mvm_energy_pj(&cost))),
        ("scalar_rps", Json::Num(scalar_rps)),
        ("scalar_nnz_per_s", Json::Num(scalar_nnz_per_s)),
        ("vectorized_rps", Json::Num(vectorized_rps)),
        ("vectorized_nnz_per_s", Json::Num(vectorized_nnz_per_s)),
        ("vectorized_speedup_vs_scalar", Json::Num(vectorized_nnz_per_s / scalar_nnz_per_s)),
        ("throughput_rps", Json::Num(throughput)),
        ("p50_ms", Json::Num(p50)),
        ("p99_ms", Json::Num(p99)),
        ("wall_s", Json::Num(head_wall)),
    ];
    // the per-kind roofline rungs only exist when that kernel has work
    if let Some(r) = dense_nnz_per_s {
        fields.push(("dense_nnz_per_s", Json::Num(r)));
        fields.push((
            "dense_arith_intensity_flops_per_byte",
            Json::Num(2.0 * nnz_dense as f64 / bytes_dense as f64),
        ));
    }
    if let Some(r) = sparse_nnz_per_s {
        fields.push(("sparse_nnz_per_s", Json::Num(r)));
        fields.push((
            "sparse_arith_intensity_flops_per_byte",
            Json::Num(2.0 * nnz_sparse as f64 / bytes_sparse as f64),
        ));
    }
    // the optimized-rung fields describe the sharded multi-RHS mode only;
    // an --exec scalar run must not pass plain worker fan-out off as it
    if let Some((wall, _, _)) = sharded_res {
        fields.push(("optimized_nnz_per_s", Json::Num(nnz_work / wall)));
        fields.push(("speedup_vs_scalar", Json::Num((requests as f64 / wall) / scalar_rps)));
    }
    if let Some((wall, _, _)) = parallel_scalar {
        fields.push(("parallel_scalar_rps", Json::Num(requests as f64 / wall)));
    }
    if let Some(rps) = oracle_rps {
        fields.push(("oracle_rps", Json::Num(rps)));
        fields.push(("speedup_vs_oracle", Json::Num(throughput / rps)));
    }
    bench::write_bench_json(Path::new(out), fields)?;
    println!("wrote {out}");

    // --- optional in-run regression gate (CI): the vectorized kernels
    // must clear the given multiple of the scalar baseline on the same
    // thread — a pure kernel-level gate, independent of worker fan-out
    // (the sharded speedup is still recorded in the ledger)
    if let Some(min) = args.get_f64("assert-speedup").map_err(anyhow::Error::msg)? {
        let speedup = vectorized_nnz_per_s / scalar_nnz_per_s;
        anyhow::ensure!(
            speedup >= min,
            "vectorized kernels at {vectorized_nnz_per_s:.3e} nnz/s are only {speedup:.2}x the \
             scalar baseline {scalar_nnz_per_s:.3e} nnz/s (required {min:.2}x)"
        );
        println!("speedup gate passed: vectorized {speedup:.2}x >= {min:.2}x scalar");
    }
    Ok(())
}

fn cmd_info(artifacts: &str) -> anyhow::Result<()> {
    println!("{}", autogmap::runtime::cpu_client_smoke()?);
    let rt = Runtime::new(artifacts)?;
    match rt.manifest() {
        Ok(m) => {
            println!("manifest fingerprint: {}", m.fingerprint);
            println!("controller configs:");
            for (name, c) in &m.configs {
                println!(
                    "  {name:<18} N={:<3} T={:<3} H={:<3} F={:<2} B={:<2} bilstm={} params={}",
                    c.n,
                    c.steps,
                    c.hidden,
                    c.fill_classes,
                    c.batch,
                    c.bilstm,
                    c.total_param_elements()
                );
            }
            println!("mvm geometries:");
            for (name, v) in &m.mvm {
                println!("  {name:<18} K={} NB={} NR={}", v.k, v.nb, v.nr);
            }
        }
        Err(e) => {
            println!("no artifacts manifest ({e})");
            println!(
                "training still works: the native backend (`--backend native`, \
                 or `auto`) needs no artifacts. built-in controller configs:"
            );
            for (name, c) in &autogmap::runtime::Manifest::builtin().configs {
                println!(
                    "  {name:<18} N={:<3} T={:<3} H={:<3} F={:<2} B={:<2} bilstm={}",
                    c.n, c.steps, c.hidden, c.fill_classes, c.batch, c.bilstm
                );
            }
            println!("(run `make artifacts` to enable the pjrt backend)");
        }
    }
    Ok(())
}
