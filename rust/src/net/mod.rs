//! Multi-tenant network serving tier: a TCP front end over a registry of
//! deployed bundles, with admission control and live hot-swap.
//!
//! The stdin `serve` loop amortizes one graph's mapping cost over many
//! `y = Ax` queries; this tier amortizes it over many *graphs and
//! clients* at once. A [`DeploymentRegistry`] owns N loaded bundles, each
//! serving behind one shared worker pool; a [`NetServer`] accepts TCP
//! connections (one handler thread each, capped) and routes NDJSON
//! requests by deployment id. The `serve-net` CLI subcommand wires the
//! two together.
//!
//! # Wire protocol
//!
//! One JSON object per `\n`-terminated line, one response line per
//! request line, on the same connection, in order. Blank lines are
//! skipped; a line over the configured byte cap is drained and answered
//! with a `parse` error (the connection stays usable). All error objects
//! are exactly the stdin loop's dialect
//! (`{"kind": <api::Error::kind()>, "message": ...}`) — both transports
//! are built on [`crate::api::dispatch`].
//!
//! **Tenant requests** name a deployment id and carry one vector or an
//! explicit batch, with an optional pre-execution deadline budget:
//!
//! ```text
//! → {"tenant":"graphA","id":1,"x":[...dim floats...]}
//! ← {"tenant":"graphA","id":1,"y":[...]}
//! → {"tenant":"graphA","id":2,"xs":[[...],[...]],"deadline_ms":50}
//! ← {"tenant":"graphA","id":2,"ys":[[...],[...]]}
//! ← {"tenant":"graphA","id":3,"error":{"kind":"busy","message":...}}
//! ```
//!
//! Rejections are always typed error *responses*, never dropped
//! connections: `busy` when the tenant's bounded queue is at its depth
//! limit (admission happens before any execution), `deadline` when the
//! request's `deadline_ms` budget expired before execution began,
//! `validate` for unknown tenants (the message names the deployed ids)
//! and malformed vectors (length mismatches name both lengths).
//!
//! **Admin requests** query or mutate the registry:
//!
//! ```text
//! → {"admin":"stats"}
//! ← {"admin":"stats","stats":{"graphA":{"served":..,"rps":..,
//!      "nnz_per_s":..,"inflight":..,"queue_depth":..,
//!      "rejected_busy":..,"rejected_deadline":..,"generation":..},..}}
//! → {"admin":{"reload":{"id":"graphA","bundle":"remapped.json"}}}
//! ← {"admin":"reload","id":"graphA","generation":2,"dim":10000}
//! ```
//!
//! `reload` is the live hot-swap: the bundle is loaded from disk outside
//! any lock, then installed with an atomic `Arc` swap. In-flight requests
//! finish on the generation they were admitted against; requests arriving
//! after the ack are served by the new one. The serving invariant — every
//! socket answer is bit-identical to [`crate::api::Deployment::mvm`] on
//! the generation that served it — holds across the swap.
//!
//! # Pieces
//!
//! - [`DeploymentRegistry`] / [`Tenant`] / [`TenantEntry`] — ownership,
//!   routing, admission, counters, hot-swap ([`registry`]).
//! - [`NetServer`] / [`NetOptions`] — the accept loop and per-connection
//!   handlers ([`server`]).
//! - [`run_net_bench`] — the self-checking concurrent load driver behind
//!   `serve-net --bench` and the CI `net-smoke` job ([`bench`]).

pub mod bench;
pub mod registry;
pub mod server;

pub use bench::{run_net_bench, NetBenchOptions, NetBenchReport};
pub use registry::{AdmitGuard, DeploymentRegistry, RegistryOptions, Tenant, TenantEntry};
pub use server::{NetOptions, NetServer, CONN_CAP_TENANT};
