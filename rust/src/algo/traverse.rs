//! BFS and SSSP as semiring-style iterated SpMV — the GraphR traversal
//! pair, run with the programmed arena untouched.
//!
//! The crossbar only ever computes the plain (+, ×) product; the semiring
//! lives in the digital post-step:
//!
//! - **BFS (boolean or–and)** — the iterate is the indicator vector of
//!   the current frontier. `y = A·f` lights every neighbor of the
//!   frontier (no-cancellation: positive weights cannot sum to zero), and
//!   the post-step assigns level `k+1` to lit, unvisited nodes, which
//!   become the next frontier. One MVM per level.
//! - **SSSP (tropical min–plus)** — a synchronous frontier Bellman–Ford.
//!   Each round batches the basis vectors `e_j` of the frontier through
//!   the engine; `A·e_j` is exactly column `j` (each output element is a
//!   single product `w·1`, so the extraction is float-exact), and the
//!   post-step relaxes `dist_i = min(dist_i, dist_j + w_ij)`. Candidates
//!   are computed from a snapshot of `dist` taken at the start of the
//!   round, so the result is independent of the chunk order the frontier
//!   is batched in; nodes whose distance improved form the next frontier.
//!   Both this loop and Dijkstra minimize the identical set of
//!   left-accumulated floating-point path sums, so on non-negative
//!   weights the two agree *exactly*, not just within tolerance.
//!
//! Both traversals terminate when the frontier empties. Hitting the
//! iteration cap with a non-empty frontier is a typed
//! [`Error::NoConverge`] — a partial answer is never reported as a
//! complete one.

use super::{AlgoTrace, MvmEngine};
use crate::api::error::{Error, Result};
use crate::graph::Csr;
use std::collections::{BinaryHeap, VecDeque};
use std::time::Instant;

/// BFS knobs; the defaults are the wire defaults of `{"bfs":{...}}`.
#[derive(Clone, Copy, Debug)]
pub struct BfsOptions {
    /// start node (original id)
    pub source: usize,
    /// level cap; 0 = the graph dimension (can never trip)
    pub max_levels: usize,
}

/// SSSP knobs; the defaults are the wire defaults of `{"sssp":{...}}`.
#[derive(Clone, Copy, Debug)]
pub struct SsspOptions {
    /// start node (original id)
    pub source: usize,
    /// relaxation-round cap; 0 = the graph dimension
    pub max_iters: usize,
    /// frontier basis vectors batched per engine dispatch; 0 = 64
    pub chunk: usize,
}

fn check_source(name: &str, source: usize, n: usize) -> Result<()> {
    if source >= n {
        return Err(Error::Validate(format!(
            "{name}.source must be a node id below the dimension {n}; got {source}"
        )));
    }
    Ok(())
}

/// Level-synchronous BFS from `opts.source`. Returns per-node levels
/// (`-1` = unreachable) and the run's [`AlgoTrace`]; the residual curve
/// is the per-level count of newly discovered nodes.
pub fn bfs<E: MvmEngine>(engine: &E, opts: &BfsOptions) -> Result<(Vec<i64>, AlgoTrace)> {
    let n = engine.dim();
    check_source("bfs", opts.source, n)?;
    let cap = if opts.max_levels == 0 { n } else { opts.max_levels };
    let t0 = Instant::now();

    let mut levels = vec![-1i64; n];
    levels[opts.source] = 0;
    let mut frontier = vec![0.0; n];
    frontier[opts.source] = 1.0;
    let mut frontier_size = 1usize;
    let mut residuals = Vec::new();
    let mut mvms = 0u64;
    let mut level = 0usize;

    while frontier_size > 0 {
        if level >= cap {
            return Err(Error::NoConverge {
                algorithm: "bfs",
                iterations: level,
                residual: frontier_size as f64,
            });
        }
        let y = engine.mvm_one(frontier);
        mvms += 1;
        level += 1;
        let mut next = vec![0.0; n];
        let mut discovered = 0usize;
        for i in 0..n {
            if y[i] != 0.0 && levels[i] < 0 {
                levels[i] = level as i64;
                next[i] = 1.0;
                discovered += 1;
            }
        }
        residuals.push(discovered as f64);
        frontier = next;
        frontier_size = discovered;
    }

    let wall_s = t0.elapsed().as_secs_f64();
    let trace = AlgoTrace {
        algorithm: "bfs",
        iterations: level,
        converged: true,
        residuals,
        mvms,
        nnz_total: mvms * engine.nnz(),
        wall_s,
    };
    Ok((levels, trace))
}

/// Queue-based BFS reference (plain [`VecDeque`] level traversal) the
/// SpMV formulation must match exactly.
pub fn bfs_reference(a: &Csr, source: usize) -> Vec<i64> {
    let mut levels = vec![-1i64; a.rows];
    levels[source] = 0;
    let mut queue = VecDeque::from([source]);
    while let Some(u) = queue.pop_front() {
        for &v in a.row(u) {
            if levels[v] < 0 {
                levels[v] = levels[u] + 1;
                queue.push_back(v);
            }
        }
    }
    levels
}

/// Synchronous frontier Bellman–Ford SSSP from `opts.source`. Returns
/// per-node distances (`f64::INFINITY` = unreachable) and the run's
/// [`AlgoTrace`]; the residual curve is the per-round count of improved
/// nodes. Requires positive edge weights (the no-cancellation
/// precondition; also what makes the Dijkstra comparison exact).
pub fn sssp<E: MvmEngine>(engine: &E, opts: &SsspOptions) -> Result<(Vec<f64>, AlgoTrace)> {
    let n = engine.dim();
    check_source("sssp", opts.source, n)?;
    let cap = if opts.max_iters == 0 { n } else { opts.max_iters };
    let chunk = if opts.chunk == 0 { 64 } else { opts.chunk };
    let t0 = Instant::now();

    let mut dist = vec![f64::INFINITY; n];
    dist[opts.source] = 0.0;
    let mut frontier = vec![opts.source];
    let mut residuals = Vec::new();
    let mut mvms = 0u64;
    let mut rounds = 0usize;

    while !frontier.is_empty() {
        if rounds >= cap {
            return Err(Error::NoConverge {
                algorithm: "sssp",
                iterations: rounds,
                residual: frontier.len() as f64,
            });
        }
        // relax against the round-start snapshot so the answer does not
        // depend on how the frontier is chunked into batches
        let dist_prev = dist.clone();
        let mut improved = vec![false; n];
        for part in frontier.chunks(chunk) {
            let xs: Vec<Vec<f64>> = part
                .iter()
                .map(|&j| {
                    let mut e = vec![0.0; n];
                    e[j] = 1.0;
                    e
                })
                .collect();
            let cols = engine.mvm_batch(xs);
            mvms += part.len() as u64;
            for (&j, col) in part.iter().zip(&cols) {
                for (i, &w) in col.iter().enumerate() {
                    if w != 0.0 {
                        let cand = dist_prev[j] + w;
                        if cand < dist[i] {
                            dist[i] = cand;
                            improved[i] = true;
                        }
                    }
                }
            }
        }
        frontier = (0..n).filter(|&i| improved[i]).collect();
        residuals.push(frontier.len() as f64);
        rounds += 1;
    }

    let wall_s = t0.elapsed().as_secs_f64();
    let trace = AlgoTrace {
        algorithm: "sssp",
        iterations: rounds,
        converged: true,
        residuals,
        mvms,
        nnz_total: mvms * engine.nnz(),
        wall_s,
    };
    Ok((dist, trace))
}

/// Binary-heap Dijkstra reference the min–plus formulation must match
/// exactly on non-negative weights.
pub fn sssp_reference(a: &Csr, source: usize) -> Vec<f64> {
    #[derive(PartialEq)]
    struct Entry(f64, usize);
    impl Eq for Entry {}
    impl PartialOrd for Entry {
        fn partial_cmp(&self, other: &Entry) -> Option<std::cmp::Ordering> {
            Some(self.cmp(other))
        }
    }
    impl Ord for Entry {
        fn cmp(&self, other: &Entry) -> std::cmp::Ordering {
            // reversed: BinaryHeap is a max-heap, we want the min distance
            other.0.total_cmp(&self.0)
        }
    }

    let mut dist = vec![f64::INFINITY; a.rows];
    dist[source] = 0.0;
    let mut heap = BinaryHeap::from([Entry(0.0, source)]);
    while let Some(Entry(d, u)) = heap.pop() {
        if d > dist[u] {
            continue; // stale entry
        }
        for (idx, &v) in a.row(u).iter().enumerate() {
            let cand = d + a.row_vals(u)[idx];
            if cand < dist[v] {
                dist[v] = cand;
                heap.push(Entry(cand, v));
            }
        }
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::CsrEngine;
    use crate::graph::{synth, Coo};

    #[test]
    fn bfs_matches_queue_reference_exactly() {
        let a = synth::rmat_like(300, 1200, 11);
        let (levels, trace) = bfs(&CsrEngine(&a), &BfsOptions { source: 0, max_levels: 0 }).unwrap();
        assert_eq!(levels, bfs_reference(&a, 0));
        assert!(trace.converged);
        assert_eq!(trace.mvms as usize, trace.iterations);
        // discovery counts sum to the reached set (minus the source)
        let reached = levels.iter().filter(|&&l| l >= 0).count();
        let discovered: f64 = trace.residuals.iter().sum();
        assert_eq!(discovered as usize + 1, reached);
    }

    #[test]
    fn sssp_matches_dijkstra_exactly_on_weighted_graph() {
        // weights are multiples of 0.25 — exactly representable in f32,
        // so the mapped arena path stays float-exact too
        let base = synth::rmat_like(200, 800, 3);
        let mut coo = Coo::new(base.rows, base.cols);
        for r in 0..base.rows {
            for &c in base.row(r) {
                if r < c {
                    coo.push_sym(r, c, (1 + (r + c) % 7) as f64 * 0.25);
                }
            }
        }
        let a = coo.to_csr();
        for chunk in [1, 5, 64] {
            let opts = SsspOptions { source: 0, max_iters: 0, chunk };
            let (dist, trace) = sssp(&CsrEngine(&a), &opts).unwrap();
            assert_eq!(dist, sssp_reference(&a, 0), "chunk {chunk}");
            assert!(trace.converged);
        }
    }

    #[test]
    fn unreachable_nodes_stay_infinite_and_unleveled() {
        let mut coo = Coo::new(4, 4);
        coo.push_sym(0, 1, 1.0);
        coo.push_sym(2, 3, 1.0);
        let a = coo.to_csr();
        let (levels, _) = bfs(&CsrEngine(&a), &BfsOptions { source: 0, max_levels: 0 }).unwrap();
        assert_eq!(levels, vec![0, 1, -1, -1]);
        let (dist, _) =
            sssp(&CsrEngine(&a), &SsspOptions { source: 0, max_iters: 0, chunk: 0 }).unwrap();
        assert_eq!(dist[1], 1.0);
        assert!(dist[2].is_infinite() && dist[3].is_infinite());
    }

    #[test]
    fn caps_trip_as_typed_no_converge() {
        let a = synth::rmat_like(300, 1200, 11);
        let err = bfs(&CsrEngine(&a), &BfsOptions { source: 0, max_levels: 1 }).unwrap_err();
        assert_eq!(err.kind(), "no_converge");
        assert!(err.to_string().contains("bfs"), "{err}");
        let err = sssp(&CsrEngine(&a), &SsspOptions { source: 0, max_iters: 1, chunk: 0 })
            .unwrap_err();
        assert_eq!(err.kind(), "no_converge");
    }

    #[test]
    fn bad_source_names_the_field() {
        let a = synth::qm7_like(5828);
        let err = bfs(&CsrEngine(&a), &BfsOptions { source: 99, max_levels: 0 }).unwrap_err();
        assert!(err.to_string().contains("bfs.source"), "{err}");
        let err = sssp(&CsrEngine(&a), &SsspOptions { source: 99, max_iters: 0, chunk: 0 })
            .unwrap_err();
        assert!(err.to_string().contains("sssp.source"), "{err}");
    }
}
