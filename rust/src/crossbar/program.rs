//! Device programming model: matrix values → memristor conductances.
//!
//! Real memristive devices hold a small number of distinguishable
//! conductance levels and suffer programming variation; the paper lists
//! "variation and defect" as the device non-idealities its future work
//! targets ([54]-[56]). This module injects both so experiments can
//! measure how a mapping scheme's *numerical* fidelity degrades:
//!
//! - [`quantize`]: symmetric n-bit uniform quantization of tile weights
//!   (per-array absolute max scaling, like ex-situ programming flows);
//! - [`perturb`]: multiplicative Gaussian variation g ← g·(1 + σ·ξ),
//!   the standard log-normal-ish small-σ device model;
//! - [`stuck_at_faults`]: a fraction of cells stuck at zero conductance
//!   (SA0 defects).
//!
//! These operate on the training-side [`CrossbarArray`] and answer "how
//! much does the mapping's numerics degrade?". The *serving-side*
//! counterpart is [`crate::fault`]: the same device-fault taxonomy
//! (stuck-at-zero/one, conductance drift, whole-bank outage, see
//! [`crate::fault::FaultKind`]) injected into a deployed plan's program
//! arena per bank assignment — with ABFT checksum detection, quarantine,
//! exact digital fallback, and re-programming repair layered on top
//! rather than measured degradation.

use super::CrossbarArray;
use crate::util::rng::Pcg64;

/// Symmetric uniform `bits`-bit quantization (int-style: levels
/// −(2^(b−1)−1) … +(2^(b−1)−1), per-array absolute-max scaling).
/// Returns the quantized array and the scale used.
pub fn quantize(arr: &CrossbarArray, bits: u32) -> (CrossbarArray, f32) {
    assert!((2..=16).contains(&bits), "bits must be 2..=16");
    let max_abs = arr
        .tiles
        .iter()
        .flat_map(|t| t.g.iter())
        .fold(0.0f32, |m, &v| m.max(v.abs()));
    if max_abs == 0.0 {
        return (arr.clone(), 1.0);
    }
    let levels = ((1u32 << (bits - 1)) - 1) as f32;
    let scale = max_abs / levels;
    let mut out = arr.clone();
    for t in &mut out.tiles {
        for v in &mut t.g {
            *v = (*v / scale).round() * scale;
        }
    }
    (out, scale)
}

/// Multiplicative Gaussian conductance variation: g ← g · (1 + σξ), ξ~N(0,1).
pub fn perturb(arr: &CrossbarArray, sigma: f64, seed: u64) -> CrossbarArray {
    let mut rng = Pcg64::seed_from_u64(seed ^ 0x7661_7269_6174_696f); // "variatio"
    let mut out = arr.clone();
    for t in &mut out.tiles {
        for v in &mut t.g {
            if *v != 0.0 {
                *v *= 1.0 + (sigma * rng.normal()) as f32;
            }
        }
    }
    out
}

/// Stuck-at-zero faults on a fraction `rate` of *programmed* (non-zero)
/// cells.
pub fn stuck_at_faults(arr: &CrossbarArray, rate: f64, seed: u64) -> CrossbarArray {
    let mut rng = Pcg64::seed_from_u64(seed ^ 0x6661_756c_7473_0001); // "faults"
    let mut out = arr.clone();
    for t in &mut out.tiles {
        for v in &mut t.g {
            if *v != 0.0 && rng.bool(rate) {
                *v = 0.0;
            }
        }
    }
    out
}

/// Relative L2 error between an ideal and a degraded MVM result.
pub fn relative_error(ideal: &[f64], actual: &[f64]) -> f64 {
    let num: f64 = ideal
        .iter()
        .zip(actual.iter())
        .map(|(a, b)| (a - b) * (a - b))
        .sum();
    let den: f64 = ideal.iter().map(|a| a * a).sum();
    if den == 0.0 {
        return if num == 0.0 { 0.0 } else { f64::INFINITY };
    }
    (num / den).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crossbar::place;
    use crate::graph::{synth, GridSummary};
    use crate::reorder::{reorder, Reordering};
    use crate::scheme::Scheme;

    fn array() -> (crate::graph::Csr, CrossbarArray) {
        let m = synth::qm7_like(5828);
        let r = reorder(&m, Reordering::CuthillMckee);
        let g = GridSummary::new(&r.matrix, 2);
        let s = Scheme { diag_len: vec![g.n], fill_len: vec![] };
        let arr = place(&r.matrix, &g, &s).unwrap();
        (r.matrix, arr)
    }

    #[test]
    fn high_bit_quantization_is_nearly_lossless() {
        let (m, arr) = array();
        let (q, _) = quantize(&arr, 8);
        let x: Vec<f64> = (0..m.rows).map(|i| 0.1 * i as f64 - 1.0).collect();
        let err = relative_error(&m.spmv(&x), &q.mvm(&x));
        assert!(err < 1e-2, "8-bit error {err}");
    }

    #[test]
    fn adjacency_is_exactly_representable_at_2bits() {
        // 0/1 adjacency values survive 2-bit (levels -1,0,+1) exactly.
        let (m, arr) = array();
        let (q, _) = quantize(&arr, 2);
        let x: Vec<f64> = (0..m.rows).map(|i| (i % 5) as f64).collect();
        let err = relative_error(&m.spmv(&x), &q.mvm(&x));
        assert!(err < 1e-12, "binary adjacency must quantize exactly, err {err}");
    }

    #[test]
    fn quantization_error_decreases_with_bits() {
        // use a weighted matrix for a non-trivial quantization ladder
        let mut coo = crate::graph::Coo::new(16, 16);
        let mut rng = Pcg64::seed_from_u64(5);
        for i in 0..16 {
            for j in 0..16 {
                if rng.bool(0.4) {
                    coo.push(i, j, rng.uniform(-2.0, 2.0));
                }
            }
        }
        let m = coo.to_csr();
        let g = GridSummary::new(&m, 4);
        let s = Scheme { diag_len: vec![g.n], fill_len: vec![] };
        let arr = place(&m, &g, &s).unwrap();
        let x: Vec<f64> = (0..16).map(|i| (i as f64 * 0.7).sin()).collect();
        let ideal = m.spmv(&x);
        let mut last = f64::INFINITY;
        for bits in [2, 4, 6, 8] {
            let (q, _) = quantize(&arr, bits);
            let err = relative_error(&ideal, &q.mvm(&x));
            assert!(err <= last + 1e-12, "error should shrink with bits");
            last = err;
        }
        assert!(last < 5e-2);
    }

    #[test]
    fn variation_scales_with_sigma() {
        let (m, arr) = array();
        let x: Vec<f64> = (0..m.rows).map(|i| 1.0 + (i % 3) as f64).collect();
        let ideal = m.spmv(&x);
        let e_small = relative_error(&ideal, &perturb(&arr, 0.01, 1).mvm(&x));
        let e_big = relative_error(&ideal, &perturb(&arr, 0.2, 1).mvm(&x));
        assert!(e_small < e_big);
        assert!(e_small < 0.05);
    }

    #[test]
    fn faults_drop_contributions() {
        let (m, arr) = array();
        let x = vec![1.0; m.rows];
        let faulty = stuck_at_faults(&arr, 0.5, 3);
        let sum_ideal: f64 = arr.mvm(&x).iter().sum();
        let sum_faulty: f64 = faulty.mvm(&x).iter().sum();
        assert!(sum_faulty < sum_ideal);
        let none = stuck_at_faults(&arr, 0.0, 3);
        assert_eq!(none.mvm(&x), arr.mvm(&x));
    }

    use crate::util::rng::Pcg64;
}
