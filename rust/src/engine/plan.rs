//! Plan compilation: `Scheme + Csr + GridSummary → ExecPlan`.
//!
//! [`crate::crossbar::place`] materializes every K×K tile of a scheme —
//! including tiles whose sub-block holds no non-zeros at all, which on a
//! 0.995-sparse qh882-like matrix is the vast majority of a large block's
//! interior. An [`ExecPlan`] is the deployable artifact a trained scheme
//! compiles into:
//!
//! - **zero-tile elision**: all-zero tiles are dropped from the schedule
//!   (they contribute exactly nothing to y' = A'x');
//! - **programming dedup**: tiles with bit-identical conductance blocks
//!   share one program buffer (block-diagonal batch supermatrices repeat
//!   whole sub-graphs);
//! - **clipped extents**: each tile records the rows×cols actually inside
//!   the matrix, so edge tiles (882 = 27·32 + 18) neither compute nor
//!   account for their zero-padded overhang;
//! - **program arena**: all program buffers live in one contiguous f32
//!   arena ([`ProgramMeta`] records offset, extents, a compile-time nnz
//!   count, and the selected kernel), so an MVM streams one allocation
//!   instead of chasing a `Vec<Vec<f32>>`. Program offsets are padded at
//!   compile time so every body starts on a [`LANE`]-wide f32 boundary —
//!   the vectorized kernels' unrolled loads never straddle a lane;
//! - **row-pattern dedup**: sparse programs whose non-zeros sit in the
//!   same positions (identical row-pointer + column-index signature,
//!   FNV-hashed like the mapper's window-signature cache) share one
//!   [`PatternMeta`] entry in the plan's pattern table — one compiled
//!   kernel body serves many programs, only the values stay per-program;
//! - **row bands**: the tile schedule is stable-sorted by `row0` into
//!   disjoint [`Band`]s. Tiles in one band write one output row range, so
//!   bands shard across workers *within* a request with no write
//!   contention, and the stable sort preserves each row's accumulation
//!   order exactly;
//! - **density-adaptive kernels**: programs whose density falls below
//!   [`DEFAULT_SPARSE_THRESHOLD`] execute through a compiled
//!   CSR-within-tile kernel instead of the dense row-dot kernel
//!   ([`KernelKind`], chosen at compile time, recorded in the artifact);
//! - **multi-RHS batching**: [`ExecPlan::mvm_span_batch`] computes a
//!   Y-panel = tile × X-panel, so one traversal of the arena serves a
//!   whole batch of requests;
//! - **JSON serialization**: plans save/load as standalone artifacts
//!   (version 3: arena + per-program metadata + the shared pattern table;
//!   version 2 artifacts load with the pattern table and alignment
//!   backfilled, and the version 1 nested-array format still loads), so a
//!   mapping trained once deploys without re-running placement.
//!
//! Exactness contract: for finite inputs every kernel is **bit-identical**
//! to the seed scalar tile-at-a-time loop (and therefore to
//! [`crate::crossbar::CrossbarArray::mvm`]): the sparse kernel only skips
//! exact-zero products (adding ±0.0 never changes a finite accumulator),
//! the multi-RHS kernel runs each (row, request) accumulation in the same
//! scalar column order, and band sharding assigns each output row to
//! exactly one worker with a fixed intra-band tile order. The vectorized
//! kernels keep the contract by unrolling only across *independent*
//! accumulation chains — output rows within a tile, or requests within a
//! batch — never by splitting one row's column sum into partial
//! accumulators (f64 addition does not reassociate). The pre-unroll
//! scalar loop survives verbatim as [`ExecPlan::mvm_scalar_into`], the
//! in-tree oracle and serve-bench baseline rung.

use crate::graph::{Csr, GridSummary};
use crate::scheme::{GridRect, Scheme};
use crate::util::json::{num_arr, obj, Json};
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// Programs whose density (nnz / rows·cols) is strictly below this execute
/// through the compiled CSR-within-tile kernel.
pub const DEFAULT_SPARSE_THRESHOLD: f64 = 0.25;

/// f32 lanes per vector register the kernels are unrolled for (8 × 4 B =
/// one 32-byte row). Program offsets are padded to multiples of this at
/// compile time, so every dense program body starts on a lane boundary.
pub const LANE: usize = 8;

/// Requests / output rows processed per unrolled kernel step. Each chain
/// keeps its own accumulator, so the per-chain f64 addition order is
/// exactly the scalar kernel's.
const UNROLL: usize = 4;

/// One scheduled tile: geometry plus a reference into the deduplicated
/// program table.
#[derive(Clone, Debug, PartialEq)]
pub struct TileSpec {
    /// top-left corner in matrix units
    pub row0: usize,
    pub col0: usize,
    /// clipped extents: rows×cols actually inside the matrix (≤ K each)
    pub rows: usize,
    pub cols: usize,
    /// index into the plan's program table
    pub program: usize,
}

/// Which compiled kernel a program executes through.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// dense row-dot over the arena slice (the seed kernel)
    Dense,
    /// CSR-within-tile: skip exact zeros, same accumulation order
    Sparse,
}

/// Per-program arena metadata: where the dense buffer lives, its extents,
/// its non-zero count (cached at compile time — load balancing reads it
/// without scanning buffers), and the selected kernel.
#[derive(Clone, Debug, PartialEq)]
pub struct ProgramMeta {
    /// offset of the dense row-major buffer in the arena
    pub offset: usize,
    pub rows: usize,
    pub cols: usize,
    /// non-zeros in the buffer, counted once at compile time
    pub nnz: u32,
    pub kernel: KernelKind,
    /// index into the plan's shared pattern table (valid when `kernel` is
    /// [`KernelKind::Sparse`]; many programs may share one pattern)
    pattern: usize,
    /// base of this program's values in the sparse value arena
    sp_val: usize,
}

impl ProgramMeta {
    /// Index of the shared row pattern this sparse program executes
    /// through (0 for dense programs, which have no pattern).
    pub fn pattern(&self) -> usize {
        self.pattern
    }
}

/// One deduplicated sparse row pattern: the row-pointer + column-index
/// structure shared by every sparse program whose non-zeros sit in the
/// same positions. Values stay per-program; the pattern is the compiled
/// kernel body.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PatternMeta {
    /// base into the shared row-pointer arena (`rows + 1` entries)
    pub rowptr: usize,
    /// base into the shared column-index arena (`nnz` entries)
    pub cols: usize,
    pub rows: usize,
    pub nnz: u32,
}

/// A maximal run of tiles writing one disjoint output row range. Bands are
/// ordered by `row0` and pairwise disjoint in rows, so they shard across
/// workers within a request with no write contention.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Band {
    /// first output row the band writes
    pub row0: usize,
    /// one past the last output row
    pub row_end: usize,
    /// tile range [tile0, tile1) in the plan's (band-sorted) schedule
    pub tile0: usize,
    pub tile1: usize,
    /// non-zeros across the band's tiles (shard balancing weight)
    pub nnz: u64,
}

/// A compiled, servable mapping plan: the flat tile schedule of one scheme
/// with all-zero tiles elided, identical programmings shared, programs
/// packed into one arena, and tiles sorted into disjoint row bands.
#[derive(Clone, Debug, PartialEq)]
pub struct ExecPlan {
    /// physical crossbar tile side K
    pub k: usize,
    /// matrix dimension D
    pub dim: usize,
    /// tile schedule, stable-sorted by `row0` into row bands (within a
    /// band, tiles keep their scheme placement order, so every output
    /// row's accumulation order matches the placement oracle)
    pub tiles: Vec<TileSpec>,
    /// tiles the scheme demanded before elision
    pub scheduled_tiles: usize,
    /// all-zero tiles dropped from the schedule
    pub elided_tiles: usize,
    /// contiguous dense program storage (LANE-aligned offsets);
    /// `progs[p]` slices into it
    arena: Vec<f32>,
    progs: Vec<ProgramMeta>,
    /// shared row-pattern table for sparse-kernel programs: per pattern
    /// `rows + 1` relative row pointers and the column-ordered indices;
    /// programs with identical structure share one entry
    patterns: Vec<PatternMeta>,
    pat_rowptr: Vec<u32>,
    pat_cols: Vec<u32>,
    /// per-program sparse values, column-ordered to match the pattern
    sp_vals: Vec<f32>,
    bands: Vec<Band>,
}

/// Compile a scheme against a matrix into an executable plan.
///
/// Tile traversal order matches [`crate::crossbar::place`] up to the
/// band-stable sort, so a plan's MVM reproduces the oracle's per-row
/// accumulation order bit for bit.
pub fn compile(m: &Csr, g: &GridSummary, scheme: &Scheme) -> Result<ExecPlan> {
    scheme
        .validate(g.n)
        .map_err(|e| anyhow!("cannot compile invalid scheme: {e}"))?;
    compile_rects(m, g, &scheme.rects())
}

/// Compile an explicit (disjoint) rectangle schedule in grid coordinates —
/// the generalized core of [`compile`]. The mapper's composite mappings
/// produce clipped rectangles that are not expressible as one diagonal+fill
/// scheme; this entry point compiles them directly. Callers are responsible
/// for rectangle disjointness (overlapping rects would double-count nnz in
/// the MVM).
pub fn compile_rects(m: &Csr, g: &GridSummary, rects: &[GridRect]) -> Result<ExecPlan> {
    ensure!(
        m.rows == g.dim && m.cols == g.dim,
        "matrix/grid dimension mismatch"
    );
    let k = g.grid;
    let mut tiles = Vec::new();
    let mut programs: Vec<Vec<f32>> = Vec::new();
    let mut dedup: HashMap<Vec<u32>, usize> = HashMap::new();
    let mut scheduled = 0usize;
    let mut elided = 0usize;
    for rect in rects {
        ensure!(
            rect.r1 <= g.n && rect.c1 <= g.n,
            "rect {rect:?} exceeds the {}-cell grid",
            g.n
        );
        for gr in rect.r0..rect.r1 {
            for gc in rect.c0..rect.c1 {
                let row0 = gr * k;
                let col0 = gc * k;
                if row0 >= g.dim || col0 >= g.dim {
                    continue; // fully outside (possible for trailing cells)
                }
                scheduled += 1;
                let rows = (g.dim - row0).min(k);
                let cols = (g.dim - col0).min(k);
                let block = m.dense_block(row0, col0, k);
                let mut data = Vec::with_capacity(rows * cols);
                for r in 0..rows {
                    for c in 0..cols {
                        data.push(block[r * k + c] as f32);
                    }
                }
                if data.iter().all(|v| *v == 0.0) {
                    elided += 1;
                    continue;
                }
                // dedup key: extents + exact bit pattern
                let mut key = Vec::with_capacity(data.len() + 2);
                key.push(rows as u32);
                key.push(cols as u32);
                key.extend(data.iter().map(|v| v.to_bits()));
                let program = match dedup.get(&key) {
                    Some(&p) => p,
                    None => {
                        let p = programs.len();
                        programs.push(data);
                        dedup.insert(key, p);
                        p
                    }
                };
                tiles.push(TileSpec {
                    row0,
                    col0,
                    rows,
                    cols,
                    program,
                });
            }
        }
    }
    Ok(ExecPlan::from_parts(k, g.dim, tiles, programs, scheduled, elided))
}

/// Merge several plans over the *same* matrix into one flat schedule — the
/// multi-plan path the mapper uses: each window of a composite mapping
/// compiles to its own [`ExecPlan`], and the merged plan is what a
/// [`super::fleet::Fleet`] distributes and a
/// [`super::batch::BatchExecutor`] serves. Tiles concatenate in part
/// order before the band sort (so each output row accumulates in the
/// parts' order), and bit-identical programmings are re-deduplicated
/// *across* parts — repeated window sparsity patterns share one program
/// buffer fleet-wide.
pub fn merge_plans(parts: &[ExecPlan]) -> Result<ExecPlan> {
    ensure!(!parts.is_empty(), "cannot merge zero plans");
    let k = parts[0].k;
    let dim = parts[0].dim;
    let mut tiles = Vec::new();
    let mut programs: Vec<Vec<f32>> = Vec::new();
    let mut dedup: HashMap<Vec<u32>, usize> = HashMap::new();
    let mut scheduled = 0usize;
    let mut elided = 0usize;
    for (i, p) in parts.iter().enumerate() {
        ensure!(
            p.k == k && p.dim == dim,
            "part {i} is {}x{} tiles over a {}-unit matrix; expected k={k}, dim={dim}",
            p.k,
            p.k,
            p.dim
        );
        scheduled += p.scheduled_tiles;
        elided += p.elided_tiles;
        // dedup each part-program once (keyed by extents + bit pattern,
        // taken from its first referencing tile — all tiles sharing a
        // program share extents, that is what the part's compile deduped
        // on), then remap tiles in O(1) each
        let mut remap: Vec<Option<usize>> = vec![None; p.progs.len()];
        for t in &p.tiles {
            let program = match remap[t.program] {
                Some(id) => id,
                None => {
                    let data = p.program(t.program);
                    let mut key = Vec::with_capacity(data.len() + 2);
                    key.push(t.rows as u32);
                    key.push(t.cols as u32);
                    key.extend(data.iter().map(|v| v.to_bits()));
                    let id = match dedup.get(&key) {
                        Some(&id) => id,
                        None => {
                            let id = programs.len();
                            programs.push(data.to_vec());
                            dedup.insert(key, id);
                            id
                        }
                    };
                    remap[t.program] = Some(id);
                    id
                }
            };
            tiles.push(TileSpec {
                row0: t.row0,
                col0: t.col0,
                rows: t.rows,
                cols: t.cols,
                program,
            });
        }
    }
    Ok(ExecPlan::from_parts(k, dim, tiles, programs, scheduled, elided))
}

impl ExecPlan {
    /// Assemble a plan from a raw tile schedule and per-program dense
    /// buffers: pack the arena, cache per-program nnz, band-sort the
    /// schedule, and select kernels at the default density threshold.
    fn from_parts(
        k: usize,
        dim: usize,
        mut tiles: Vec<TileSpec>,
        mut programs: Vec<Vec<f32>>,
        scheduled_tiles: usize,
        elided_tiles: usize,
    ) -> ExecPlan {
        // tiles sharing a program must share extents (the dedup key
        // includes them); artifacts that violate this get the program
        // duplicated per distinct extents so kernels can trust geometry
        let mut extents: Vec<Option<(usize, usize)>> = vec![None; programs.len()];
        let mut variants: HashMap<(usize, usize, usize), usize> = HashMap::new();
        for t in &mut tiles {
            match extents[t.program] {
                None => extents[t.program] = Some((t.rows, t.cols)),
                Some(e) if e == (t.rows, t.cols) => {}
                Some(_) => {
                    let key = (t.program, t.rows, t.cols);
                    let id = *variants.entry(key).or_insert_with(|| {
                        let data = programs[t.program].clone();
                        programs.push(data);
                        extents.push(Some((t.rows, t.cols)));
                        programs.len() - 1
                    });
                    t.program = id;
                }
            }
        }
        let payload: usize = programs.iter().map(|p| p.len()).sum();
        let mut arena = Vec::with_capacity(payload + programs.len() * LANE);
        let mut progs = Vec::with_capacity(programs.len());
        for (i, p) in programs.into_iter().enumerate() {
            let (rows, cols) =
                extents[i].unwrap_or((if p.is_empty() { 0 } else { 1 }, p.len()));
            let nnz = p.iter().filter(|v| **v != 0.0).count() as u32;
            // pad so every program body starts on a lane boundary
            arena.resize(arena.len().next_multiple_of(LANE), 0.0);
            progs.push(ProgramMeta {
                offset: arena.len(),
                rows,
                cols,
                nnz,
                kernel: KernelKind::Dense,
                pattern: 0,
                sp_val: 0,
            });
            arena.extend_from_slice(&p);
        }
        let mut plan = ExecPlan::assemble(k, dim, tiles, arena, progs, scheduled_tiles, elided_tiles);
        plan.rekernel(DEFAULT_SPARSE_THRESHOLD);
        plan
    }

    /// The invariant-establishing constructor tail shared by compile and
    /// the artifact readers: band-sort the schedule, build the bands, and
    /// derive the pattern table and value arena from the programs'
    /// current kernel flags.
    fn assemble(
        k: usize,
        dim: usize,
        mut tiles: Vec<TileSpec>,
        arena: Vec<f32>,
        progs: Vec<ProgramMeta>,
        scheduled_tiles: usize,
        elided_tiles: usize,
    ) -> ExecPlan {
        let bands = band_layout(&mut tiles, &progs);
        let mut plan = ExecPlan {
            k,
            dim,
            tiles,
            scheduled_tiles,
            elided_tiles,
            arena,
            progs,
            patterns: Vec::new(),
            pat_rowptr: Vec::new(),
            pat_cols: Vec::new(),
            sp_vals: Vec::new(),
            bands,
        };
        plan.rebuild_sparse();
        plan
    }

    /// Re-select kernels: programs with density strictly below `threshold`
    /// get the compiled CSR-within-tile kernel, the rest the dense
    /// row-dot kernel. `0.0` forces every program dense,
    /// `f64::INFINITY` forces every program sparse. Results are
    /// bit-identical either way; only the instruction mix changes.
    pub fn rekernel(&mut self, threshold: f64) {
        for p in &mut self.progs {
            let cells = p.rows * p.cols;
            p.kernel = if cells > 0 && (p.nnz as f64 / cells as f64) < threshold {
                KernelKind::Sparse
            } else {
                KernelKind::Dense
            };
        }
        self.rebuild_sparse();
    }

    /// Mutate the dense arena cells of the named programs through `f`
    /// (called per cell as `f(program, row, col, current)` in
    /// program-order, row-major — deterministic for a seeded caller) and
    /// re-establish the plan invariants afterwards: per-program nnz is
    /// recounted and the shared sparse pattern table / value arena is
    /// rebuilt, so both kernels serve the *mutated* values (a cell stuck
    /// at zero disappears from the sparse pattern; a cell stuck high
    /// joins it). Returns the number of cells whose stored bits actually
    /// changed.
    ///
    /// This is the device-fault injection point ([`crate::fault`]): the
    /// arena is the programmed crossbar state, so mutating a program
    /// corrupts every tile that references it — exactly the blast radius
    /// of a failing physical bank under program dedup. Band nnz weights
    /// are deliberately left at their compile-time values (they only
    /// steer shard balancing, and a fault model must not rebalance work
    /// around the corruption it injects).
    pub fn mutate_program_cells<F>(&mut self, programs: &[usize], mut f: F) -> u64
    where
        F: FnMut(usize, usize, usize, f32) -> f32,
    {
        let mut changed = 0u64;
        for &p in programs {
            let (offset, rows, cols) = {
                let m = &self.progs[p];
                (m.offset, m.rows, m.cols)
            };
            let slice = &mut self.arena[offset..offset + rows * cols];
            for r in 0..rows {
                for c in 0..cols {
                    let old = slice[r * cols + c];
                    let new = f(p, r, c, old);
                    if new.to_bits() != old.to_bits() {
                        slice[r * cols + c] = new;
                        changed += 1;
                    }
                }
            }
            self.progs[p].nnz = slice.iter().filter(|v| **v != 0.0).count() as u32;
        }
        if changed > 0 {
            self.rebuild_sparse();
        }
        changed
    }

    /// Rebuild the shared pattern table and value arena from the current
    /// kernel flags (compile and every artifact reader end here, so a
    /// loaded plan is field-identical to the plan that was saved). Sparse
    /// programs with the same row-pointer + column-index structure are
    /// interned into one [`PatternMeta`] — FNV-hashed with exact-compare
    /// collision chains, the mapper's window-signature cache idiom — so
    /// one kernel body serves every program sharing the pattern.
    fn rebuild_sparse(&mut self) {
        self.patterns.clear();
        self.pat_rowptr.clear();
        self.pat_cols.clear();
        self.sp_vals.clear();
        let mut index: HashMap<u64, Vec<usize>> = HashMap::new();
        let mut rowptr: Vec<u32> = Vec::new();
        let mut cols: Vec<u32> = Vec::new();
        for p in &mut self.progs {
            if p.kernel != KernelKind::Sparse {
                p.pattern = 0;
                p.sp_val = 0;
                continue;
            }
            rowptr.clear();
            cols.clear();
            p.sp_val = self.sp_vals.len();
            let data = &self.arena[p.offset..p.offset + p.rows * p.cols];
            let mut count = 0u32;
            rowptr.push(0);
            for row in data.chunks_exact(p.cols.max(1)) {
                for (c, &v) in row.iter().enumerate() {
                    if v != 0.0 {
                        cols.push(c as u32);
                        self.sp_vals.push(v);
                        count += 1;
                    }
                }
                rowptr.push(count);
            }
            let hash = pattern_fnv(p.rows, &rowptr, &cols);
            let chain = index.entry(hash).or_default();
            let (patterns, pat_rowptr, pat_cols) =
                (&self.patterns, &self.pat_rowptr, &self.pat_cols);
            let found = chain.iter().copied().find(|&i| {
                let pat = &patterns[i];
                pat.rows == p.rows
                    && pat.nnz as usize == cols.len()
                    && pat_rowptr[pat.rowptr..pat.rowptr + pat.rows + 1] == rowptr[..]
                    && pat_cols[pat.cols..pat.cols + pat.nnz as usize] == cols[..]
            });
            p.pattern = match found {
                Some(i) => i,
                None => {
                    let i = self.patterns.len();
                    chain.push(i);
                    self.patterns.push(PatternMeta {
                        rowptr: self.pat_rowptr.len(),
                        cols: self.pat_cols.len(),
                        rows: p.rows,
                        nnz: cols.len() as u32,
                    });
                    self.pat_rowptr.extend_from_slice(&rowptr);
                    self.pat_cols.extend_from_slice(&cols);
                    i
                }
            };
        }
    }

    /// y' = A'x' over the scheduled tiles through the vectorized kernels,
    /// writing into a reusable output buffer (cleared and resized to
    /// `dim`). Per-row accumulation order matches
    /// [`crate::crossbar::CrossbarArray::mvm`] bit for bit.
    pub fn mvm_into(&self, x: &[f64], y: &mut Vec<f64>) {
        assert_eq!(x.len(), self.dim, "input vector length mismatch");
        y.clear();
        y.resize(self.dim, 0.0);
        for t in &self.tiles {
            match self.progs[t.program].kernel {
                KernelKind::Dense => self.tile_dense(t, x, y),
                KernelKind::Sparse => self.tile_sparse(t, x, y),
            }
        }
    }

    /// y' = A'x' through the pre-vectorization *scalar* kernels — the
    /// seed row-dot / CSR-within-tile loop kept verbatim as the in-tree
    /// bit-identity oracle and the serve-bench baseline rung.
    pub fn mvm_scalar_into(&self, x: &[f64], y: &mut Vec<f64>) {
        assert_eq!(x.len(), self.dim, "input vector length mismatch");
        y.clear();
        y.resize(self.dim, 0.0);
        self.accumulate_tiles_scalar(x, y);
    }

    /// Run only the tiles whose program executes through `kind`,
    /// accumulating into `y` (cleared and resized to `dim`) — the
    /// roofline ledger's per-kernel timing hook. Summing both kinds'
    /// outputs reproduces [`Self::mvm_into`] up to f64 addition order
    /// across kinds; this is a measurement tool, not a serving path.
    pub fn mvm_kind_into(&self, kind: KernelKind, x: &[f64], y: &mut Vec<f64>) {
        assert_eq!(x.len(), self.dim, "input vector length mismatch");
        y.clear();
        y.resize(self.dim, 0.0);
        for t in &self.tiles {
            if self.progs[t.program].kernel != kind {
                continue;
            }
            match kind {
                KernelKind::Dense => self.tile_dense(t, x, y),
                KernelKind::Sparse => self.tile_sparse(t, x, y),
            }
        }
    }

    /// Scalar kernel core (the seed loop, verbatim): run the whole
    /// schedule, accumulating into `out` (length `dim`), dispatching each
    /// tile's compiled kernel.
    fn accumulate_tiles_scalar(&self, x: &[f64], out: &mut [f64]) {
        for t in &self.tiles {
            let p = &self.progs[t.program];
            let xs = &x[t.col0..t.col0 + t.cols];
            match p.kernel {
                KernelKind::Dense => {
                    let prog = &self.arena[p.offset..p.offset + t.rows * t.cols];
                    for (r, row) in prog.chunks_exact(t.cols).enumerate() {
                        let mut acc = 0.0f64;
                        for (gv, xv) in row.iter().zip(xs.iter()) {
                            acc += *gv as f64 * xv;
                        }
                        out[t.row0 + r] += acc;
                    }
                }
                KernelKind::Sparse => {
                    let pat = &self.patterns[p.pattern];
                    let rp = &self.pat_rowptr[pat.rowptr..pat.rowptr + t.rows + 1];
                    for (r, w) in rp.windows(2).enumerate() {
                        let (s, e) = (w[0] as usize, w[1] as usize);
                        let cols = &self.pat_cols[pat.cols + s..pat.cols + e];
                        let vals = &self.sp_vals[p.sp_val + s..p.sp_val + e];
                        let mut acc = 0.0f64;
                        for (c, v) in cols.iter().zip(vals.iter()) {
                            acc += *v as f64 * xs[*c as usize];
                        }
                        out[t.row0 + r] += acc;
                    }
                }
            }
        }
    }

    /// Vectorized dense kernel for one tile: [`UNROLL`] output rows per
    /// step, each with its own accumulator walking columns in the scalar
    /// order, sharing one streamed load of x — the lane-aligned program
    /// rows autovectorize, and the bits match the scalar kernel exactly.
    #[inline]
    fn tile_dense(&self, t: &TileSpec, x: &[f64], out: &mut [f64]) {
        let p = &self.progs[t.program];
        let prog = &self.arena[p.offset..p.offset + t.rows * t.cols];
        let xs = &x[t.col0..t.col0 + t.cols];
        let cols = t.cols;
        let mut r = 0usize;
        while r + UNROLL <= t.rows {
            let r0 = &prog[r * cols..(r + 1) * cols];
            let r1 = &prog[(r + 1) * cols..(r + 2) * cols];
            let r2 = &prog[(r + 2) * cols..(r + 3) * cols];
            let r3 = &prog[(r + 3) * cols..(r + 4) * cols];
            let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
            for (c, &xv) in xs.iter().enumerate() {
                a0 += r0[c] as f64 * xv;
                a1 += r1[c] as f64 * xv;
                a2 += r2[c] as f64 * xv;
                a3 += r3[c] as f64 * xv;
            }
            out[t.row0 + r] += a0;
            out[t.row0 + r + 1] += a1;
            out[t.row0 + r + 2] += a2;
            out[t.row0 + r + 3] += a3;
            r += UNROLL;
        }
        for (rr, row) in prog.chunks_exact(cols).enumerate().skip(r) {
            let mut acc = 0.0f64;
            for (gv, xv) in row.iter().zip(xs.iter()) {
                acc += *gv as f64 * xv;
            }
            out[t.row0 + rr] += acc;
        }
    }

    /// Vectorized sparse kernel for one tile: the [`UNROLL`] products of
    /// each step may evaluate in any order, but the adds fold into the
    /// single accumulator in the scalar kernel's strict sequence, so the
    /// bits are unchanged while the gather loads pipeline.
    #[inline]
    fn tile_sparse(&self, t: &TileSpec, x: &[f64], out: &mut [f64]) {
        let p = &self.progs[t.program];
        let pat = &self.patterns[p.pattern];
        let rp = &self.pat_rowptr[pat.rowptr..pat.rowptr + t.rows + 1];
        let xs = &x[t.col0..t.col0 + t.cols];
        for (r, w) in rp.windows(2).enumerate() {
            let (s, e) = (w[0] as usize, w[1] as usize);
            let cols = &self.pat_cols[pat.cols + s..pat.cols + e];
            let vals = &self.sp_vals[p.sp_val + s..p.sp_val + e];
            let n = cols.len();
            let mut acc = 0.0f64;
            let mut i = 0usize;
            while i + UNROLL <= n {
                let p0 = vals[i] as f64 * xs[cols[i] as usize];
                let p1 = vals[i + 1] as f64 * xs[cols[i + 1] as usize];
                let p2 = vals[i + 2] as f64 * xs[cols[i + 2] as usize];
                let p3 = vals[i + 3] as f64 * xs[cols[i + 3] as usize];
                acc += p0;
                acc += p1;
                acc += p2;
                acc += p3;
                i += UNROLL;
            }
            for (v, c) in vals[i..].iter().zip(cols[i..].iter()) {
                acc += *v as f64 * xs[*c as usize];
            }
            out[t.row0 + r] += acc;
        }
    }

    /// Multi-RHS span kernel: compute output rows [span.0, span.1) for
    /// every request in `xs`, one traversal of the arena for the whole
    /// batch. `outs[b]` must be zero-filled with length `span.1 - span.0`.
    /// `span` must lie on band boundaries (anything [`Self::band_spans`]
    /// returns does). Per (row, request) the accumulation order is exactly
    /// [`Self::mvm_into`]'s, so results are bit-identical.
    pub fn mvm_span_batch(&self, span: (usize, usize), xs: &[Vec<f64>], outs: &mut [Vec<f64>]) {
        assert_eq!(xs.len(), outs.len(), "request/output count mismatch");
        let b0 = self.bands.partition_point(|b| b.row_end <= span.0);
        let b1 = self.bands.partition_point(|b| b.row0 < span.1);
        for band in &self.bands[b0..b1] {
            debug_assert!(
                band.row0 >= span.0 && band.row_end <= span.1,
                "span {span:?} splits band at row {}",
                band.row0
            );
            for t in &self.tiles[band.tile0..band.tile1] {
                let p = &self.progs[t.program];
                match p.kernel {
                    KernelKind::Dense => {
                        let prog = &self.arena[p.offset..p.offset + t.rows * t.cols];
                        for (r, row) in prog.chunks_exact(t.cols).enumerate() {
                            let orow = t.row0 - span.0 + r;
                            // UNROLL requests per step: one streamed pass
                            // over the program row feeds four independent
                            // accumulators, each in the scalar column
                            // order (bit-identical per request)
                            let mut b = 0usize;
                            while b + UNROLL <= xs.len() {
                                let x0 = &xs[b][t.col0..t.col0 + t.cols];
                                let x1 = &xs[b + 1][t.col0..t.col0 + t.cols];
                                let x2 = &xs[b + 2][t.col0..t.col0 + t.cols];
                                let x3 = &xs[b + 3][t.col0..t.col0 + t.cols];
                                let (mut a0, mut a1, mut a2, mut a3) =
                                    (0.0f64, 0.0f64, 0.0f64, 0.0f64);
                                for (c, &gv) in row.iter().enumerate() {
                                    let g = gv as f64;
                                    a0 += g * x0[c];
                                    a1 += g * x1[c];
                                    a2 += g * x2[c];
                                    a3 += g * x3[c];
                                }
                                outs[b][orow] += a0;
                                outs[b + 1][orow] += a1;
                                outs[b + 2][orow] += a2;
                                outs[b + 3][orow] += a3;
                                b += UNROLL;
                            }
                            for (x, out) in xs[b..].iter().zip(outs[b..].iter_mut()) {
                                let xv = &x[t.col0..t.col0 + t.cols];
                                let mut acc = 0.0f64;
                                for (gv, xs_v) in row.iter().zip(xv.iter()) {
                                    acc += *gv as f64 * xs_v;
                                }
                                out[orow] += acc;
                            }
                        }
                    }
                    KernelKind::Sparse => {
                        let pat = &self.patterns[p.pattern];
                        let rp = &self.pat_rowptr[pat.rowptr..pat.rowptr + t.rows + 1];
                        for (r, w) in rp.windows(2).enumerate() {
                            let (s, e) = (w[0] as usize, w[1] as usize);
                            let cols = &self.pat_cols[pat.cols + s..pat.cols + e];
                            let vals = &self.sp_vals[p.sp_val + s..p.sp_val + e];
                            let orow = t.row0 - span.0 + r;
                            let mut b = 0usize;
                            while b + UNROLL <= xs.len() {
                                let x0 = &xs[b][t.col0..];
                                let x1 = &xs[b + 1][t.col0..];
                                let x2 = &xs[b + 2][t.col0..];
                                let x3 = &xs[b + 3][t.col0..];
                                let (mut a0, mut a1, mut a2, mut a3) =
                                    (0.0f64, 0.0f64, 0.0f64, 0.0f64);
                                for (c, v) in cols.iter().zip(vals.iter()) {
                                    let g = *v as f64;
                                    let ci = *c as usize;
                                    a0 += g * x0[ci];
                                    a1 += g * x1[ci];
                                    a2 += g * x2[ci];
                                    a3 += g * x3[ci];
                                }
                                outs[b][orow] += a0;
                                outs[b + 1][orow] += a1;
                                outs[b + 2][orow] += a2;
                                outs[b + 3][orow] += a3;
                                b += UNROLL;
                            }
                            for (x, out) in xs[b..].iter().zip(outs[b..].iter_mut()) {
                                let xv = &x[t.col0..];
                                let mut acc = 0.0f64;
                                for (c, v) in cols.iter().zip(vals.iter()) {
                                    acc += *v as f64 * xv[*c as usize];
                                }
                                out[orow] += acc;
                            }
                        }
                    }
                }
            }
        }
    }

    /// Multi-RHS convenience over the full row range: `ys` is cleared and
    /// resized to match `xs`; each `ys[b]` is bit-identical to
    /// `mvm_into(&xs[b], ..)`. Delegates to the one shared implementation,
    /// the [`crate::engine::Servable`] trait default.
    pub fn mvm_batch_into(&self, xs: &[Vec<f64>], ys: &mut Vec<Vec<f64>>) {
        crate::engine::Servable::mvm_batch_into(self, xs, ys)
    }

    /// Partition the row bands into at most `shards` contiguous,
    /// nnz-balanced row spans that together cover [0, dim). Span
    /// boundaries fall on band starts, so no band is split and each
    /// output row belongs to exactly one span.
    pub fn band_spans(&self, shards: usize) -> Vec<(usize, usize)> {
        let shards = shards.max(1).min(self.bands.len().max(1));
        if self.bands.is_empty() || shards == 1 {
            return vec![(0, self.dim)];
        }
        let total: u64 = self.bands.iter().map(|b| b.nnz).sum::<u64>().max(1);
        let mut starts = vec![0usize];
        let mut consumed = 0u64;
        for (i, b) in self.bands.iter().enumerate() {
            if starts.len() == shards {
                break;
            }
            consumed += b.nnz;
            let remaining_bands = self.bands.len() - i - 1;
            if remaining_bands == 0 {
                break;
            }
            let remaining_groups = shards - starts.len();
            let target = total * starts.len() as u64 / shards as u64;
            if consumed >= target || remaining_bands == remaining_groups {
                starts.push(self.bands[i + 1].row0);
            }
        }
        let mut spans = Vec::with_capacity(starts.len());
        for (i, &s) in starts.iter().enumerate() {
            let e = if i + 1 < starts.len() { starts[i + 1] } else { self.dim };
            spans.push((s, e));
        }
        spans
    }

    /// Allocating convenience wrapper around [`Self::mvm_into`].
    pub fn mvm(&self, x: &[f64]) -> Vec<f64> {
        let mut y = Vec::new();
        self.mvm_into(x, &mut y);
        y
    }

    /// Fraction of scheduled tiles dropped because they held no non-zeros.
    pub fn elision_ratio(&self) -> f64 {
        if self.scheduled_tiles == 0 {
            0.0
        } else {
            self.elided_tiles as f64 / self.scheduled_tiles as f64
        }
    }

    /// Fraction of placed tiles served by a shared (deduplicated) program.
    pub fn dedup_ratio(&self) -> f64 {
        if self.tiles.is_empty() {
            0.0
        } else {
            1.0 - self.progs.len() as f64 / self.tiles.len() as f64
        }
    }

    /// Programmed cells inside the matrix (Σ rows·cols over the schedule).
    pub fn cells(&self) -> u64 {
        self.tiles.iter().map(|t| (t.rows * t.cols) as u64).sum()
    }

    /// Number of deduplicated program buffers.
    pub fn num_programs(&self) -> usize {
        self.progs.len()
    }

    /// Dense row-major view of one program buffer in the arena.
    pub fn program(&self, p: usize) -> &[f32] {
        let m = &self.progs[p];
        &self.arena[m.offset..m.offset + m.rows * m.cols]
    }

    /// Per-program arena metadata (offset, extents, cached nnz, kernel).
    pub fn program_meta(&self, p: usize) -> &ProgramMeta {
        &self.progs[p]
    }

    /// Non-zero count per program buffer (used by load-balancing
    /// policies). Counts are cached in the arena metadata at compile
    /// time, so this never rescans program buffers.
    pub fn program_nnz(&self) -> Vec<u64> {
        self.progs.iter().map(|p| p.nnz as u64).collect()
    }

    /// Non-zeros served by the schedule (Σ program nnz over tiles).
    pub fn mapped_nnz(&self) -> u64 {
        self.tiles
            .iter()
            .map(|t| self.progs[t.program].nnz as u64)
            .sum()
    }

    /// (dense, sparse) program counts under the current kernel selection.
    pub fn kernel_counts(&self) -> (usize, usize) {
        let sparse = self
            .progs
            .iter()
            .filter(|p| p.kernel == KernelKind::Sparse)
            .count();
        (self.progs.len() - sparse, sparse)
    }

    /// Number of deduplicated sparse row patterns (compiled kernel
    /// bodies) in the shared pattern table.
    pub fn num_patterns(&self) -> usize {
        self.patterns.len()
    }

    /// Sparse programs served by a pattern another program interned
    /// first — the cross-program row-pattern dedup win.
    pub fn pattern_dedup_hits(&self) -> usize {
        let sparse = self
            .progs
            .iter()
            .filter(|p| p.kernel == KernelKind::Sparse)
            .count();
        sparse - self.patterns.len()
    }

    /// Shared-pattern table entry `i`.
    pub fn pattern_meta(&self, i: usize) -> &PatternMeta {
        &self.patterns[i]
    }

    /// (dense, sparse) non-zeros served per MVM under the current kernel
    /// mix — per-tile sums, so shared programs count once per
    /// referencing tile.
    pub fn kernel_nnz(&self) -> (u64, u64) {
        let (mut dense, mut sparse) = (0u64, 0u64);
        for t in &self.tiles {
            let p = &self.progs[t.program];
            match p.kernel {
                KernelKind::Dense => dense += p.nnz as u64,
                KernelKind::Sparse => sparse += p.nnz as u64,
            }
        }
        (dense, sparse)
    }

    /// (dense, sparse) arena bytes touched per MVM: a dense tile streams
    /// its full rows·cols f32 body; a sparse tile streams the pattern's
    /// `rows + 1` row pointers and `nnz` column indices plus the
    /// program's `nnz` values (4 bytes each). The roofline ledger's
    /// bandwidth denominator.
    pub fn kernel_bytes(&self) -> (u64, u64) {
        let (mut dense, mut sparse) = (0u64, 0u64);
        for t in &self.tiles {
            let p = &self.progs[t.program];
            match p.kernel {
                KernelKind::Dense => dense += (t.rows * t.cols * 4) as u64,
                KernelKind::Sparse => {
                    sparse += ((t.rows + 1) * 4) as u64 + p.nnz as u64 * 8;
                }
            }
        }
        (dense, sparse)
    }

    /// Total f32 cells in the arena, alignment padding included.
    pub fn arena_len(&self) -> usize {
        self.arena.len()
    }

    /// Zero cells inserted so every program starts on a [`LANE`]
    /// boundary (arena length minus program payload).
    pub fn arena_padding(&self) -> usize {
        self.arena.len() - self.progs.iter().map(|p| p.rows * p.cols).sum::<usize>()
    }

    /// The disjoint, ordered row bands of the schedule.
    pub fn bands(&self) -> &[Band] {
        &self.bands
    }

    // ---- serialization ---------------------------------------------------

    fn tiles_json(&self) -> Vec<Json> {
        self.tiles
            .iter()
            .map(|t| {
                // flat [row0, col0, rows, cols, program] keeps the artifact
                // compact; the field order is part of the format.
                num_arr([
                    t.row0 as f64,
                    t.col0 as f64,
                    t.rows as f64,
                    t.cols as f64,
                    t.program as f64,
                ])
            })
            .collect()
    }

    /// Serialize to the deployable JSON artifact format (version 3: the
    /// lane-padded arena, per-program
    /// `[offset, rows, cols, nnz, kernel, pattern]` metadata, and the
    /// shared row-pattern table). Readers re-derive the table from the
    /// arena and reject artifacts where the two disagree.
    pub fn to_json(&self) -> Json {
        let progs = self
            .progs
            .iter()
            .map(|p| {
                num_arr([
                    p.offset as f64,
                    p.rows as f64,
                    p.cols as f64,
                    p.nnz as f64,
                    match p.kernel {
                        KernelKind::Dense => 0.0,
                        KernelKind::Sparse => 1.0,
                    },
                    p.pattern as f64,
                ])
            })
            .collect();
        let patterns = self
            .patterns
            .iter()
            .map(|pat| {
                num_arr([
                    pat.rowptr as f64,
                    pat.cols as f64,
                    pat.rows as f64,
                    pat.nnz as f64,
                ])
            })
            .collect();
        obj(vec![
            ("version", Json::Num(3.0)),
            ("k", Json::Num(self.k as f64)),
            ("dim", Json::Num(self.dim as f64)),
            ("lane", Json::Num(LANE as f64)),
            ("scheduled_tiles", Json::Num(self.scheduled_tiles as f64)),
            ("elided_tiles", Json::Num(self.elided_tiles as f64)),
            ("tiles", Json::Arr(self.tiles_json())),
            ("arena", num_arr(self.arena.iter().map(|&v| v as f64))),
            ("programs", Json::Arr(progs)),
            ("patterns", Json::Arr(patterns)),
            ("pattern_rowptr", num_arr(self.pat_rowptr.iter().map(|&v| v as f64))),
            ("pattern_cols", num_arr(self.pat_cols.iter().map(|&v| v as f64))),
        ])
    }

    /// Serialize to the version-2 format (flat arena plus 5-field program
    /// metadata, no pattern table) — kept for compatibility testing and
    /// rollback to pre-pattern readers.
    pub fn to_json_v2(&self) -> Json {
        let progs = self
            .progs
            .iter()
            .map(|p| {
                num_arr([
                    p.offset as f64,
                    p.rows as f64,
                    p.cols as f64,
                    p.nnz as f64,
                    match p.kernel {
                        KernelKind::Dense => 0.0,
                        KernelKind::Sparse => 1.0,
                    },
                ])
            })
            .collect();
        obj(vec![
            ("version", Json::Num(2.0)),
            ("k", Json::Num(self.k as f64)),
            ("dim", Json::Num(self.dim as f64)),
            ("scheduled_tiles", Json::Num(self.scheduled_tiles as f64)),
            ("elided_tiles", Json::Num(self.elided_tiles as f64)),
            ("tiles", Json::Arr(self.tiles_json())),
            ("arena", num_arr(self.arena.iter().map(|&v| v as f64))),
            ("programs", Json::Arr(progs)),
        ])
    }

    /// Serialize to the legacy version-1 format (programs as nested
    /// arrays, no kernel metadata) — kept for compatibility testing and
    /// rollback to pre-arena readers.
    pub fn to_json_v1(&self) -> Json {
        let programs = (0..self.progs.len())
            .map(|p| num_arr(self.program(p).iter().map(|&v| v as f64)))
            .collect();
        obj(vec![
            ("version", Json::Num(1.0)),
            ("k", Json::Num(self.k as f64)),
            ("dim", Json::Num(self.dim as f64)),
            ("scheduled_tiles", Json::Num(self.scheduled_tiles as f64)),
            ("elided_tiles", Json::Num(self.elided_tiles as f64)),
            ("tiles", Json::Arr(self.tiles_json())),
            ("programs", Json::Arr(programs)),
        ])
    }

    /// Parse and validate a plan document (version 1, 2, or 3). Pre-v3
    /// artifacts load with the pattern table and lane alignment
    /// backfilled: program bodies are repacked onto [`LANE`] boundaries
    /// (saved kernel flags preserved) and the pattern table re-derived
    /// from the arena, so an old artifact gains the full vectorized path
    /// on load.
    pub fn from_json(doc: &Json) -> Result<ExecPlan> {
        let version = doc.get("version").as_usize().context("plan missing version")?;
        match version {
            1 => Self::from_json_v1(doc),
            2 => Self::from_json_v2(doc),
            3 => Self::from_json_v3(doc),
            v => bail!("unsupported plan version {v}"),
        }
    }

    fn from_json_v1(doc: &Json) -> Result<ExecPlan> {
        let (k, dim, scheduled_tiles, elided_tiles) = parse_header(doc)?;
        let mut programs = Vec::new();
        for (i, p) in doc
            .get("programs")
            .as_arr()
            .context("plan missing programs")?
            .iter()
            .enumerate()
        {
            let vals = p.as_arr().with_context(|| format!("program {i} not an array"))?;
            let mut data = Vec::with_capacity(vals.len());
            for v in vals {
                data.push(v.as_f64().with_context(|| format!("program {i}: non-number"))? as f32);
            }
            programs.push(data);
        }
        let tiles = parse_tiles(doc, k, dim)?;
        for (i, t) in tiles.iter().enumerate() {
            let prog = programs
                .get(t.program)
                .with_context(|| format!("tile {i} references missing program {}", t.program))?;
            if prog.len() != t.rows * t.cols {
                bail!(
                    "tile {i} is {}x{} but program {} has {} elements",
                    t.rows,
                    t.cols,
                    t.program,
                    prog.len()
                );
            }
        }
        check_accounting(tiles.len(), elided_tiles, scheduled_tiles)?;
        Ok(ExecPlan::from_parts(k, dim, tiles, programs, scheduled_tiles, elided_tiles))
    }

    fn from_json_v2(doc: &Json) -> Result<ExecPlan> {
        let (k, dim, scheduled_tiles, elided_tiles) = parse_header(doc)?;
        let arena_vals = doc.get("arena").as_arr().context("plan missing arena")?;
        let mut arena = Vec::with_capacity(arena_vals.len());
        for v in arena_vals {
            arena.push(v.as_f64().context("arena: non-number")? as f32);
        }
        let mut progs = Vec::new();
        for (i, entry) in doc
            .get("programs")
            .as_arr()
            .context("plan missing programs")?
            .iter()
            .enumerate()
        {
            let f = entry.as_arr().with_context(|| format!("program {i} not an array"))?;
            ensure!(f.len() == 5, "program {i} needs 5 fields, got {}", f.len());
            let mut nums = [0usize; 5];
            for (slot, v) in nums.iter_mut().zip(f.iter()) {
                *slot = v.as_usize().with_context(|| format!("program {i}: bad field"))?;
            }
            let [offset, rows, cols, nnz, kernel] = nums;
            ensure!(
                offset + rows * cols <= arena.len(),
                "program {i} exceeds the {}-element arena",
                arena.len()
            );
            let actual = arena[offset..offset + rows * cols]
                .iter()
                .filter(|v| **v != 0.0)
                .count();
            ensure!(
                actual == nnz,
                "program {i} metadata says {nnz} nnz but the arena holds {actual}"
            );
            let kernel = match kernel {
                0 => KernelKind::Dense,
                1 => KernelKind::Sparse,
                other => bail!("program {i} has unknown kernel kind {other}"),
            };
            progs.push(ProgramMeta {
                offset,
                rows,
                cols,
                nnz: nnz as u32,
                kernel,
                pattern: 0,
                sp_val: 0,
            });
        }
        let tiles = parse_tiles(doc, k, dim)?;
        check_tile_programs(&tiles, &progs)?;
        check_accounting(tiles.len(), elided_tiles, scheduled_tiles)?;
        // v2 artifacts predate alignment padding: repack program bodies
        // onto lane boundaries, preserving each saved kernel flag
        // (from_parts would re-select at the default threshold); assemble
        // backfills the pattern table from the arena.
        let packed = repack_aligned(&arena, &mut progs);
        Ok(ExecPlan::assemble(k, dim, tiles, packed, progs, scheduled_tiles, elided_tiles))
    }

    fn from_json_v3(doc: &Json) -> Result<ExecPlan> {
        let (k, dim, scheduled_tiles, elided_tiles) = parse_header(doc)?;
        let lane = doc.get("lane").as_usize().context("plan missing lane")?;
        ensure!(lane >= 1, "plan has degenerate lane width");
        let arena_vals = doc.get("arena").as_arr().context("plan missing arena")?;
        let mut arena = Vec::with_capacity(arena_vals.len());
        for v in arena_vals {
            arena.push(v.as_f64().context("arena: non-number")? as f32);
        }
        let pat_rowptr = parse_u32_arr(doc, "pattern_rowptr")?;
        let pat_cols = parse_u32_arr(doc, "pattern_cols")?;
        let mut patterns = Vec::new();
        for (i, entry) in doc
            .get("patterns")
            .as_arr()
            .context("plan missing patterns")?
            .iter()
            .enumerate()
        {
            let f = entry.as_arr().with_context(|| format!("pattern {i} not an array"))?;
            ensure!(f.len() == 4, "pattern {i} needs 4 fields, got {}", f.len());
            let mut nums = [0usize; 4];
            for (slot, v) in nums.iter_mut().zip(f.iter()) {
                *slot = v.as_usize().with_context(|| format!("pattern {i}: bad field"))?;
            }
            let [rowptr, cols, rows, nnz] = nums;
            ensure!(
                rowptr + rows + 1 <= pat_rowptr.len() && cols + nnz <= pat_cols.len(),
                "pattern {i} exceeds the pattern arenas"
            );
            patterns.push(PatternMeta {
                rowptr,
                cols,
                rows,
                nnz: nnz as u32,
            });
        }
        let mut progs = Vec::new();
        let mut saved_patterns = Vec::new();
        for (i, entry) in doc
            .get("programs")
            .as_arr()
            .context("plan missing programs")?
            .iter()
            .enumerate()
        {
            let f = entry.as_arr().with_context(|| format!("program {i} not an array"))?;
            ensure!(f.len() == 6, "program {i} needs 6 fields, got {}", f.len());
            let mut nums = [0usize; 6];
            for (slot, v) in nums.iter_mut().zip(f.iter()) {
                *slot = v.as_usize().with_context(|| format!("program {i}: bad field"))?;
            }
            let [offset, rows, cols, nnz, kernel, pattern] = nums;
            ensure!(
                offset + rows * cols <= arena.len(),
                "program {i} exceeds the {}-element arena",
                arena.len()
            );
            let actual = arena[offset..offset + rows * cols]
                .iter()
                .filter(|v| **v != 0.0)
                .count();
            ensure!(
                actual == nnz,
                "program {i} metadata says {nnz} nnz but the arena holds {actual}"
            );
            let kernel = match kernel {
                0 => KernelKind::Dense,
                1 => KernelKind::Sparse,
                other => bail!("program {i} has unknown kernel kind {other}"),
            };
            match kernel {
                KernelKind::Sparse => ensure!(
                    pattern < patterns.len(),
                    "program {i} references missing pattern {pattern}"
                ),
                KernelKind::Dense => {
                    ensure!(pattern == 0, "dense program {i} carries pattern {pattern}")
                }
            }
            saved_patterns.push(pattern);
            progs.push(ProgramMeta {
                offset,
                rows,
                cols,
                nnz: nnz as u32,
                kernel,
                pattern: 0,
                sp_val: 0,
            });
        }
        let tiles = parse_tiles(doc, k, dim)?;
        check_tile_programs(&tiles, &progs)?;
        check_accounting(tiles.len(), elided_tiles, scheduled_tiles)?;
        // repack with the *current* lane width (forward-compatible with
        // artifacts written under a different LANE), then validate the
        // serialized pattern table against the arena-derived one — the
        // table is an integrity record, never trusted as-is
        let packed = repack_aligned(&arena, &mut progs);
        let plan = ExecPlan::assemble(k, dim, tiles, packed, progs, scheduled_tiles, elided_tiles);
        ensure!(
            plan.patterns == patterns
                && plan.pat_rowptr == pat_rowptr
                && plan.pat_cols == pat_cols,
            "pattern table mismatch: artifact disagrees with the arena-derived table"
        );
        for (i, (&saved, p)) in saved_patterns.iter().zip(plan.progs.iter()).enumerate() {
            ensure!(
                saved == p.pattern,
                "pattern table mismatch: program {i} says pattern {saved}, derived {}",
                p.pattern
            );
        }
        Ok(plan)
    }

    /// Write the plan artifact to disk.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing plan {}", path.display()))
    }

    /// Load a plan artifact from disk.
    pub fn load(path: &Path) -> Result<ExecPlan> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading plan {}", path.display()))?;
        let doc = Json::parse(&text)
            .with_context(|| format!("plan {} is not valid JSON", path.display()))?;
        Self::from_json(&doc).with_context(|| format!("parsing plan {}", path.display()))
    }
}

/// Stable-sort tiles by `row0` and derive the disjoint row bands. The
/// stable sort keeps tiles that write the same rows in their original
/// schedule order, so per-row accumulation order is unchanged.
fn band_layout(tiles: &mut [TileSpec], progs: &[ProgramMeta]) -> Vec<Band> {
    tiles.sort_by_key(|t| t.row0);
    let mut bands: Vec<Band> = Vec::new();
    for (i, t) in tiles.iter().enumerate() {
        let t_nnz = progs[t.program].nnz as u64;
        match bands.last_mut() {
            Some(b) if t.row0 < b.row_end => {
                b.row_end = b.row_end.max(t.row0 + t.rows);
                b.tile1 = i + 1;
                b.nnz += t_nnz;
            }
            _ => bands.push(Band {
                row0: t.row0,
                row_end: t.row0 + t.rows,
                tile0: i,
                tile1: i + 1,
                nnz: t_nnz,
            }),
        }
    }
    bands
}

/// Repack program bodies into a fresh arena with every offset padded to a
/// [`LANE`] boundary, updating offsets in place. Artifact readers route
/// through this, so pre-padding (v1/v2) artifacts gain the alignment
/// invariant on load; for an already-aligned arena it reproduces the
/// input byte for byte.
fn repack_aligned(arena: &[f32], progs: &mut [ProgramMeta]) -> Vec<f32> {
    let mut packed = Vec::with_capacity(arena.len() + progs.len() * LANE);
    for p in progs {
        let data = &arena[p.offset..p.offset + p.rows * p.cols];
        packed.resize(packed.len().next_multiple_of(LANE), 0.0);
        p.offset = packed.len();
        packed.extend_from_slice(data);
    }
    packed
}

/// FNV-1a over a row pattern (row count, relative row pointers, column
/// indices) — the same hash the mapper's window-signature cache uses.
fn pattern_fnv(rows: usize, rowptr: &[u32], cols: &[u32]) -> u64 {
    fn eat(mut hash: u64, word: u64) -> u64 {
        for b in word.to_le_bytes() {
            hash ^= b as u64;
            hash = hash.wrapping_mul(0x0000_0100_0000_01b3);
        }
        hash
    }
    let mut hash = 0xcbf2_9ce4_8422_2325u64;
    hash = eat(hash, rows as u64);
    for &v in rowptr {
        hash = eat(hash, v as u64);
    }
    for &v in cols {
        hash = eat(hash, v as u64);
    }
    hash
}

/// Every tile must reference an in-range program whose extents agree with
/// the tile's (shared by the v2 and v3 readers).
fn check_tile_programs(tiles: &[TileSpec], progs: &[ProgramMeta]) -> Result<()> {
    for (i, t) in tiles.iter().enumerate() {
        let p = progs
            .get(t.program)
            .with_context(|| format!("tile {i} references missing program {}", t.program))?;
        ensure!(
            p.rows == t.rows && p.cols == t.cols,
            "tile {i} is {}x{} but program {} is {}x{}",
            t.rows,
            t.cols,
            t.program,
            p.rows,
            p.cols
        );
    }
    Ok(())
}

fn parse_u32_arr(doc: &Json, field: &str) -> Result<Vec<u32>> {
    let vals = doc
        .get(field)
        .as_arr()
        .with_context(|| format!("plan missing {field}"))?;
    let mut out = Vec::with_capacity(vals.len());
    for v in vals {
        let n = v.as_usize().with_context(|| format!("{field}: bad entry"))?;
        ensure!(n <= u32::MAX as usize, "{field}: entry {n} overflows u32");
        out.push(n as u32);
    }
    Ok(out)
}

fn parse_header(doc: &Json) -> Result<(usize, usize, usize, usize)> {
    let k = doc.get("k").as_usize().context("plan missing k")?;
    let dim = doc.get("dim").as_usize().context("plan missing dim")?;
    ensure!(k >= 1 && dim >= 1, "plan has degenerate geometry");
    let scheduled = doc
        .get("scheduled_tiles")
        .as_usize()
        .context("plan missing scheduled_tiles")?;
    let elided = doc
        .get("elided_tiles")
        .as_usize()
        .context("plan missing elided_tiles")?;
    Ok((k, dim, scheduled, elided))
}

fn parse_tiles(doc: &Json, k: usize, dim: usize) -> Result<Vec<TileSpec>> {
    let mut tiles = Vec::new();
    for (i, t) in doc
        .get("tiles")
        .as_arr()
        .context("plan missing tiles")?
        .iter()
        .enumerate()
    {
        let f = t.as_arr().with_context(|| format!("tile {i} not an array"))?;
        ensure!(f.len() == 5, "tile {i} needs 5 fields, got {}", f.len());
        let mut nums = [0usize; 5];
        for (slot, v) in nums.iter_mut().zip(f.iter()) {
            *slot = v.as_usize().with_context(|| format!("tile {i}: bad field"))?;
        }
        let spec = TileSpec {
            row0: nums[0],
            col0: nums[1],
            rows: nums[2],
            cols: nums[3],
            program: nums[4],
        };
        if spec.rows == 0 || spec.cols == 0 || spec.rows > k || spec.cols > k {
            bail!("tile {i} has extents {}x{} outside 1..={k}", spec.rows, spec.cols);
        }
        if spec.row0 + spec.rows > dim || spec.col0 + spec.cols > dim {
            bail!("tile {i} exceeds the {dim}-unit matrix");
        }
        tiles.push(spec);
    }
    Ok(tiles)
}

fn check_accounting(placed: usize, elided: usize, scheduled: usize) -> Result<()> {
    ensure!(
        placed + elided == scheduled,
        "plan tile accounting is inconsistent: {placed} placed + {elided} elided != {scheduled} scheduled"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crossbar::place;
    use crate::engine::batch::BatchExecutor;
    use crate::graph::synth;
    use crate::reorder::{reorder, Reordering};
    use crate::scheme::{parse_actions, FillRule};
    use crate::util::propcheck::check;
    use std::sync::Arc;

    fn qh882_setup() -> (Csr, GridSummary) {
        let m = synth::qh882_like(1);
        let r = reorder(&m, Reordering::CuthillMckee);
        let g = GridSummary::new(&r.matrix, 32);
        (r.matrix, g)
    }

    /// The seed scalar kernel, verbatim: tiles in schedule order, dense
    /// row-dot over the program view. The optimized kernels must match it
    /// bit for bit (finite inputs).
    fn seed_reference(plan: &ExecPlan, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0f64; plan.dim];
        for t in &plan.tiles {
            let prog = plan.program(t.program);
            for r in 0..t.rows {
                let row = &prog[r * t.cols..r * t.cols + t.cols];
                let xs = &x[t.col0..t.col0 + t.cols];
                let mut acc = 0.0f64;
                for (gv, xv) in row.iter().zip(xs.iter()) {
                    acc += *gv as f64 * xv;
                }
                y[t.row0 + r] += acc;
            }
        }
        y
    }

    #[test]
    fn full_block_plan_elides_empty_tiles_and_matches_oracle() {
        let (m, g) = qh882_setup();
        let scheme = Scheme {
            diag_len: vec![g.n],
            fill_len: vec![],
        };
        let plan = compile(&m, &g, &scheme).unwrap();
        let arr = place(&m, &g, &scheme).unwrap();
        assert_eq!(plan.scheduled_tiles, arr.tiles.len());
        assert_eq!(plan.tiles.len() + plan.elided_tiles, plan.scheduled_tiles);
        // a CM-reordered banded matrix leaves most of the full block empty
        assert!(
            plan.elision_ratio() > 0.5,
            "elision {} too low",
            plan.elision_ratio()
        );
        let x: Vec<f64> = (0..g.dim).map(|i| ((i * 13) % 17) as f64 - 8.0).collect();
        let y = plan.mvm(&x);
        let want = arr.mvm(&x);
        for (a, b) in y.iter().zip(want.iter()) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }

    #[test]
    fn clipped_cells_match_scheme_area_on_full_block() {
        let (m, g) = qh882_setup();
        let scheme = Scheme {
            diag_len: vec![g.n],
            fill_len: vec![],
        };
        let plan = compile(&m, &g, &scheme).unwrap();
        // every *placed* tile's clipped extents stay inside the matrix
        for t in &plan.tiles {
            assert!(t.row0 + t.rows <= 882 && t.col0 + t.cols <= 882);
            assert_eq!(plan.program(t.program).len(), t.rows * t.cols);
        }
        // scheduled (pre-elision) clipped area would equal 882²; placed
        // cells are a subset
        assert!(plan.cells() <= 882 * 882);
        assert!(plan.cells() > 0);
    }

    #[test]
    fn dedup_shares_identical_programs() {
        // batch supermatrix of identical sub-graphs: the diagonal blocks
        // repeat, so unit-tiling them must dedup heavily.
        let sub = synth::qm7_like(5828);
        let m = synth::batch_supermatrix(&[sub.clone(), sub.clone(), sub.clone()]);
        let g = GridSummary::new(&m, 22);
        let scheme = Scheme {
            diag_len: vec![1; g.n],
            fill_len: vec![0; g.n - 1],
        };
        let plan = compile(&m, &g, &scheme).unwrap();
        assert_eq!(plan.tiles.len(), 3);
        assert_eq!(plan.num_programs(), 1, "identical sub-graphs must share a program");
        assert!(plan.dedup_ratio() > 0.6);
        // and the shared program still computes correctly per tile position
        let x: Vec<f64> = (0..66).map(|i| (i as f64 * 0.31).cos()).collect();
        let y = plan.mvm(&x);
        let want = m.spmv(&x);
        for (a, b) in y.iter().zip(want.iter()) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn band_layout_and_spans_are_disjoint_and_cover() {
        let (m, g) = qh882_setup();
        let scheme = Scheme {
            diag_len: vec![g.n],
            fill_len: vec![],
        };
        let plan = compile(&m, &g, &scheme).unwrap();
        let bands = plan.bands();
        assert!(!bands.is_empty());
        let mut tile_cursor = 0usize;
        let mut prev_end = 0usize;
        for b in bands {
            assert!(b.row0 >= prev_end, "bands overlap");
            assert!(b.row_end > b.row0 && b.row_end <= plan.dim);
            assert_eq!(b.tile0, tile_cursor, "bands must tile the schedule");
            assert!(b.tile1 > b.tile0);
            for t in &plan.tiles[b.tile0..b.tile1] {
                assert!(t.row0 >= b.row0 && t.row0 + t.rows <= b.row_end);
            }
            tile_cursor = b.tile1;
            prev_end = b.row_end;
        }
        assert_eq!(tile_cursor, plan.tiles.len());
        for shards in [1usize, 2, 3, 8, 1000] {
            let spans = plan.band_spans(shards);
            assert!(!spans.is_empty() && spans.len() <= shards.max(1));
            assert_eq!(spans[0].0, 0);
            assert_eq!(spans.last().unwrap().1, plan.dim);
            for w in spans.windows(2) {
                assert_eq!(w[0].1, w[1].0, "spans must be contiguous");
            }
            // every span boundary is a band start, so no band is split
            for &(s, _) in &spans[1..] {
                assert!(bands.iter().any(|b| b.row0 == s), "span start {s} off-band");
            }
        }
    }

    #[test]
    fn kernel_selection_is_density_driven_and_exact() {
        let (m, g) = qh882_setup();
        let scheme = Scheme {
            diag_len: vec![g.n],
            fill_len: vec![],
        };
        let plan = compile(&m, &g, &scheme).unwrap();
        // the sparse 882-band leaves most surviving tiles nearly empty
        let (dense, sparse) = plan.kernel_counts();
        assert_eq!(dense + sparse, plan.num_programs());
        assert!(sparse > 0, "a 0.99-sparse workload must select sparse kernels");
        let x: Vec<f64> = (0..g.dim).map(|i| ((i * 7) % 19) as f64 - 9.0).collect();
        let want = seed_reference(&plan, &x);
        assert_eq!(plan.mvm(&x), want, "auto kernels diverged from the seed loop");
        let mut all_dense = plan.clone();
        all_dense.rekernel(0.0);
        assert_eq!(all_dense.kernel_counts().1, 0);
        assert_eq!(all_dense.mvm(&x), want);
        let mut all_sparse = plan.clone();
        all_sparse.rekernel(f64::INFINITY);
        assert_eq!(all_sparse.kernel_counts().0, 0);
        assert_eq!(all_sparse.mvm(&x), want);
    }

    #[test]
    fn json_roundtrip_preserves_plan() {
        let (m, g) = qh882_setup();
        let scheme = parse_actions(
            g.n,
            &vec![1u8; g.n - 1],
            &vec![0usize; g.n - 1],
            FillRule::None,
        );
        let plan = compile(&m, &g, &scheme).unwrap();
        let doc = plan.to_json();
        let back = ExecPlan::from_json(&Json::parse(&doc.to_string()).unwrap()).unwrap();
        assert_eq!(plan, back);
    }

    #[test]
    fn v1_artifact_reader_roundtrips() {
        // the legacy nested-array format still loads, and re-deriving
        // arena + kernels reproduces the compiled plan exactly
        let (m, g) = qh882_setup();
        let scheme = Scheme {
            diag_len: vec![g.n],
            fill_len: vec![],
        };
        let plan = compile(&m, &g, &scheme).unwrap();
        let doc = plan.to_json_v1();
        assert_eq!(doc.get("version").as_usize(), Some(1));
        let back = ExecPlan::from_json(&Json::parse(&doc.to_string()).unwrap()).unwrap();
        assert_eq!(plan, back);
        let x: Vec<f64> = (0..g.dim).map(|i| ((i * 3) % 23) as f64 - 11.0).collect();
        assert_eq!(plan.mvm(&x), back.mvm(&x));
    }

    #[test]
    fn v2_artifact_reader_roundtrips_and_backfills() {
        // a v2 artifact written by this build round-trips exactly …
        let (m, g) = qh882_setup();
        let scheme = Scheme {
            diag_len: vec![g.n],
            fill_len: vec![],
        };
        let mut plan = compile(&m, &g, &scheme).unwrap();
        plan.rekernel(f64::INFINITY); // forced flags must survive the trip
        let doc = plan.to_json_v2();
        assert_eq!(doc.get("version").as_usize(), Some(2));
        let back = ExecPlan::from_json(&Json::parse(&doc.to_string()).unwrap()).unwrap();
        assert_eq!(plan, back);
        let x: Vec<f64> = (0..g.dim).map(|i| ((i * 5) % 29) as f64 - 14.0).collect();
        assert_eq!(plan.mvm(&x), back.mvm(&x));
        // … and a pre-padding artifact (programs packed back to back, as
        // the old writer emitted) loads with alignment and the pattern
        // table backfilled, kernel flags preserved
        let text = r#"{"version":2,"k":2,"dim":4,"scheduled_tiles":2,"elided_tiles":0,
            "tiles":[[0,0,2,2,0],[2,2,2,2,1]],"arena":[1,2,0,1,5,0,0,3],
            "programs":[[0,2,2,3,0],[4,2,2,2,1]]}"#;
        let old = ExecPlan::from_json(&Json::parse(text).unwrap()).unwrap();
        for p in 0..old.num_programs() {
            assert_eq!(old.program_meta(p).offset % LANE, 0, "program {p} unaligned");
        }
        // density 0.5 would re-select dense at the default threshold; the
        // saved sparse flag must win
        assert_eq!(old.kernel_counts(), (1, 1));
        assert_eq!(old.num_patterns(), 1, "one sparse program, one pattern");
        assert_eq!(old.mvm(&[1.0, 2.0, 3.0, 4.0]), vec![5.0, 2.0, 15.0, 12.0]);
        // cross-version: v3(v2(plan)) still equals the plan
        let v3 = old.to_json();
        let back = ExecPlan::from_json(&Json::parse(&v3.to_string()).unwrap()).unwrap();
        assert_eq!(old, back);
    }

    #[test]
    fn programs_start_on_lane_boundaries() {
        let (m, g) = qh882_setup();
        let scheme = Scheme {
            diag_len: vec![g.n],
            fill_len: vec![],
        };
        let plan = compile(&m, &g, &scheme).unwrap();
        let aligned = |p: &ExecPlan| {
            (0..p.num_programs()).all(|i| p.program_meta(i).offset % LANE == 0)
        };
        assert!(aligned(&plan), "compile must pad offsets to lanes");
        assert!(plan.arena_padding() < plan.num_programs().max(1) * LANE);
        let payload: usize = (0..plan.num_programs()).map(|i| plan.program(i).len()).sum();
        assert_eq!(plan.arena_len(), plan.arena_padding() + payload);
        let mut sparse = plan.clone();
        sparse.rekernel(f64::INFINITY);
        assert!(aligned(&sparse), "rekernel must not disturb the arena");
        let doc = plan.to_json_v1();
        let v1 = ExecPlan::from_json(&Json::parse(&doc.to_string()).unwrap()).unwrap();
        assert!(aligned(&v1), "v1 reader must backfill alignment");
    }

    #[test]
    fn row_pattern_dedup_shares_kernel_bodies() {
        // two 4×4 diagonal blocks with the same sparsity pattern but
        // different values: program dedup cannot share them, pattern
        // dedup must
        let mut coo = crate::graph::Coo::new(8, 8);
        for (b, scale) in [(0usize, 1.0f64), (4, 10.0)] {
            coo.push(b, b, scale);
            coo.push(b + 2, b + 1, 2.0 * scale);
            coo.push(b + 3, b + 3, 3.0 * scale);
        }
        let m = coo.to_csr();
        let g = GridSummary::new(&m, 4);
        let scheme = Scheme {
            diag_len: vec![1, 1],
            fill_len: vec![0],
        };
        let plan = compile(&m, &g, &scheme).unwrap();
        assert_eq!(plan.num_programs(), 2, "distinct values must stay distinct programs");
        assert_eq!(plan.kernel_counts(), (0, 2), "3/16 density selects sparse");
        assert_eq!(plan.num_patterns(), 1, "identical row patterns must share one body");
        assert_eq!(plan.pattern_dedup_hits(), 1);
        let pat = plan.pattern_meta(0);
        assert_eq!((pat.rows, pat.nnz), (4, 3));
        let x: Vec<f64> = (0..8).map(|i| i as f64 + 1.0).collect();
        let want = m.spmv(&x);
        assert_eq!(plan.mvm(&x), want, "shared pattern, per-program values");
        // forcing dense clears the table; sparse rebuilds it identically
        let mut dense = plan.clone();
        dense.rekernel(0.0);
        assert_eq!(dense.num_patterns(), 0);
        assert_eq!(dense.pattern_dedup_hits(), 0);
        dense.rekernel(f64::INFINITY);
        assert_eq!(dense.num_patterns(), 1);
        assert_eq!(dense.pattern_dedup_hits(), 1);
        assert_eq!(dense.mvm(&x), want);
    }

    #[test]
    fn kind_filtered_mvm_partitions_the_schedule() {
        let (m, g) = qh882_setup();
        let scheme = Scheme {
            diag_len: vec![g.n],
            fill_len: vec![],
        };
        let plan = compile(&m, &g, &scheme).unwrap();
        let x: Vec<f64> = (0..g.dim).map(|i| ((i * 11) % 31) as f64 - 15.0).collect();
        let (mut yd, mut ys) = (Vec::new(), Vec::new());
        plan.mvm_kind_into(KernelKind::Dense, &x, &mut yd);
        plan.mvm_kind_into(KernelKind::Sparse, &x, &mut ys);
        let y = plan.mvm(&x);
        for i in 0..plan.dim {
            assert!(
                (yd[i] + ys[i] - y[i]).abs() < 1e-9,
                "row {i}: kind split {} + {} vs {}",
                yd[i],
                ys[i],
                y[i]
            );
        }
        let (dn, sn) = plan.kernel_nnz();
        assert_eq!(dn + sn, plan.mapped_nnz(), "per-kind nnz must partition the total");
        let (dense_k, sparse_k) = plan.kernel_counts();
        let (db, sb) = plan.kernel_bytes();
        assert_eq!(db > 0, dense_k > 0, "dense bytes track dense programs");
        assert_eq!(sb > 0, sparse_k > 0, "sparse bytes track sparse programs");
        assert!(db + sb > 0, "a non-empty schedule touches arena bytes");
    }

    #[test]
    fn odd_geometry_kernels_stay_bit_identical_property() {
        // unaligned/odd-sized programs: rows and cols away from any lane
        // or unroll multiple, single-element tiles (grid 1), all-zero
        // rows inside surviving tiles, empty matrices — every path must
        // still reproduce the seed scalar loop bit for bit at 1/2/8
        // workers in both exec modes.
        check("engine_odd_geometry_bit_identical", 10, |rng| {
            let dims = [1usize, 2, 3, 5, 7, 9, 13, 17];
            let dim = dims[rng.below(dims.len() as u64) as usize];
            let grid = 1 + rng.below(7) as usize;
            let mut coo = crate::graph::Coo::new(dim, dim);
            let entries = rng.below((dim * dim) as u64 + 1) as usize;
            for _ in 0..entries {
                coo.push(
                    rng.below(dim as u64) as usize,
                    rng.below(dim as u64) as usize,
                    rng.uniform(-2.0, 2.0),
                );
            }
            let m = coo.to_csr();
            let g = GridSummary::new(&m, grid);
            let scheme = Scheme {
                diag_len: vec![g.n],
                fill_len: vec![],
            };
            let plan = compile(&m, &g, &scheme).map_err(|e| format!("{e:#}"))?;
            let bsz = 1 + rng.below(9) as usize;
            let xs: Vec<Vec<f64>> = (0..bsz)
                .map(|_| (0..dim).map(|_| rng.uniform(-3.0, 3.0)).collect())
                .collect();
            let want: Vec<Vec<f64>> = xs.iter().map(|x| seed_reference(&plan, x)).collect();
            let mut dense = plan.clone();
            dense.rekernel(0.0);
            let mut sparse = plan.clone();
            sparse.rekernel(f64::INFINITY);
            let mut y = Vec::new();
            for (x, w) in xs.iter().zip(want.iter()) {
                plan.mvm_scalar_into(x, &mut y);
                if &y != w {
                    return Err("scalar kernel diverged from seed".into());
                }
                if &plan.mvm(x) != w || &dense.mvm(x) != w || &sparse.mvm(x) != w {
                    return Err("vectorized kernel diverged from seed".into());
                }
            }
            let mut ys = Vec::new();
            plan.mvm_batch_into(&xs, &mut ys);
            if ys != want {
                return Err("multi-RHS kernel diverged from seed".into());
            }
            for variant in [plan, sparse] {
                let variant = Arc::new(variant);
                for &workers in &[1usize, 2, 8] {
                    let exec = BatchExecutor::new(variant.clone(), workers);
                    if exec.execute_batch_sharded(xs.clone()) != want {
                        return Err(format!("sharded mode at {workers} workers diverged"));
                    }
                    if exec.execute_batch(xs.clone()) != want {
                        return Err(format!("scalar mode at {workers} workers diverged"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn save_load_roundtrip_on_disk() {
        let sub = synth::qm7_like(5828);
        let g = GridSummary::new(&sub, 2);
        let scheme = Scheme {
            diag_len: vec![g.n],
            fill_len: vec![],
        };
        let plan = compile(&sub, &g, &scheme).unwrap();
        let dir = std::env::temp_dir().join("autogmap_engine_plan_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("plan.json");
        plan.save(&path).unwrap();
        let back = ExecPlan::load(&path).unwrap();
        assert_eq!(plan, back);
        let x: Vec<f64> = (0..22).map(|i| i as f64 - 11.0).collect();
        assert_eq!(plan.mvm(&x), back.mvm(&x));
    }

    #[test]
    fn from_json_rejects_corrupt_plans() {
        for text in [
            "{}",
            // future version
            r#"{"version":4,"k":2,"dim":4,"scheduled_tiles":0,"elided_tiles":0,"tiles":[],"programs":[]}"#,
            // v3 without a lane width
            r#"{"version":3,"k":2,"dim":4,"scheduled_tiles":0,"elided_tiles":0,"tiles":[],
                "arena":[],"programs":[],"patterns":[],"pattern_rowptr":[],"pattern_cols":[]}"#,
            // v3 program referencing a missing pattern
            r#"{"version":3,"k":2,"dim":4,"lane":8,"scheduled_tiles":1,"elided_tiles":0,
                "tiles":[[0,0,2,2,0]],"arena":[0,1,0,0],"programs":[[0,2,2,1,1,3]],
                "patterns":[[0,0,2,1]],"pattern_rowptr":[0,1,1],"pattern_cols":[1]}"#,
            // v3 dense program carrying a pattern index
            r#"{"version":3,"k":2,"dim":4,"lane":8,"scheduled_tiles":1,"elided_tiles":0,
                "tiles":[[0,0,2,2,0]],"arena":[0,1,0,0],"programs":[[0,2,2,1,0,1]],
                "patterns":[[0,0,2,1]],"pattern_rowptr":[0,1,1],"pattern_cols":[1]}"#,
            // v3 pattern table disagreeing with the arena (wrong column)
            r#"{"version":3,"k":2,"dim":4,"lane":8,"scheduled_tiles":1,"elided_tiles":0,
                "tiles":[[0,0,2,2,0]],"arena":[0,1,0,0],"programs":[[0,2,2,1,1,0]],
                "patterns":[[0,0,2,1]],"pattern_rowptr":[0,1,1],"pattern_cols":[0]}"#,
            // v3 pattern metadata exceeding the pattern arenas
            r#"{"version":3,"k":2,"dim":4,"lane":8,"scheduled_tiles":1,"elided_tiles":0,
                "tiles":[[0,0,2,2,0]],"arena":[0,1,0,0],"programs":[[0,2,2,1,1,0]],
                "patterns":[[0,0,2,1]],"pattern_rowptr":[0,1],"pattern_cols":[1]}"#,
            // v3 5-field (v2-shaped) program metadata
            r#"{"version":3,"k":2,"dim":4,"lane":8,"scheduled_tiles":1,"elided_tiles":0,
                "tiles":[[0,0,2,2,0]],"arena":[0,1,0,0],"programs":[[0,2,2,1,1]],
                "patterns":[[0,0,2,1]],"pattern_rowptr":[0,1,1],"pattern_cols":[1]}"#,
            // v2 without an arena
            r#"{"version":2,"k":2,"dim":4,"scheduled_tiles":0,"elided_tiles":0,"tiles":[],"programs":[]}"#,
            // v2 program metadata exceeding the arena
            r#"{"version":2,"k":2,"dim":4,"scheduled_tiles":1,"elided_tiles":0,
                "tiles":[[0,0,2,2,0]],"arena":[1,0],"programs":[[0,2,2,1,0]]}"#,
            // v2 nnz metadata inconsistent with the arena
            r#"{"version":2,"k":2,"dim":4,"scheduled_tiles":1,"elided_tiles":0,
                "tiles":[[0,0,2,2,0]],"arena":[1,0,0,1],"programs":[[0,2,2,3,0]]}"#,
            // v2 unknown kernel kind
            r#"{"version":2,"k":2,"dim":4,"scheduled_tiles":1,"elided_tiles":0,
                "tiles":[[0,0,2,2,0]],"arena":[1,0,0,1],"programs":[[0,2,2,2,7]]}"#,
            // v2 tile extents disagreeing with its program
            r#"{"version":2,"k":2,"dim":4,"scheduled_tiles":1,"elided_tiles":0,
                "tiles":[[0,0,1,2,0]],"arena":[1,0,0,1],"programs":[[0,2,2,2,0]]}"#,
            // tile referencing a missing program
            r#"{"version":1,"k":2,"dim":4,"scheduled_tiles":1,"elided_tiles":0,
                "tiles":[[0,0,2,2,0]],"programs":[]}"#,
            // tile exceeding the matrix
            r#"{"version":1,"k":2,"dim":3,"scheduled_tiles":1,"elided_tiles":0,
                "tiles":[[2,2,2,2,0]],"programs":[[1,0,0,1]]}"#,
            // program length mismatch
            r#"{"version":1,"k":2,"dim":4,"scheduled_tiles":1,"elided_tiles":0,
                "tiles":[[0,0,2,2,0]],"programs":[[1,0]]}"#,
            // inconsistent accounting
            r#"{"version":1,"k":2,"dim":4,"scheduled_tiles":5,"elided_tiles":0,
                "tiles":[[0,0,2,2,0]],"programs":[[1,0,0,1]]}"#,
        ] {
            let doc = Json::parse(text).unwrap();
            assert!(ExecPlan::from_json(&doc).is_err(), "should reject {text}");
        }
    }

    #[test]
    fn compile_rects_matches_compile_on_schemes() {
        let (m, g) = qh882_setup();
        let scheme = parse_actions(
            g.n,
            &vec![0u8; g.n - 1],
            &vec![1usize; g.n - 1],
            FillRule::Fixed { size: 1 },
        );
        let a = compile(&m, &g, &scheme).unwrap();
        let b = compile_rects(&m, &g, &scheme.rects()).unwrap();
        assert_eq!(a, b);
        // out-of-grid rects are rejected
        let bad = [crate::scheme::GridRect { r0: 0, r1: g.n + 1, c0: 0, c1: 1 }];
        assert!(compile_rects(&m, &g, &bad).is_err());
    }

    #[test]
    fn merge_plans_concatenates_and_dedups() {
        let (m, g) = qh882_setup();
        // two disjoint halves of the unit-block diagonal, merged, must equal
        // the plan compiled from the whole diagonal at once
        let half = g.n / 2;
        let lo: Vec<crate::scheme::GridRect> =
            (0..half).map(|i| crate::scheme::GridRect::square(i, 1)).collect();
        let hi: Vec<crate::scheme::GridRect> =
            (half..g.n).map(|i| crate::scheme::GridRect::square(i, 1)).collect();
        let p_lo = compile_rects(&m, &g, &lo).unwrap();
        let p_hi = compile_rects(&m, &g, &hi).unwrap();
        let merged = merge_plans(&[p_lo.clone(), p_hi.clone()]).unwrap();
        let whole = compile_rects(
            &m,
            &g,
            &(0..g.n).map(|i| crate::scheme::GridRect::square(i, 1)).collect::<Vec<_>>(),
        )
        .unwrap();
        assert_eq!(merged.tiles.len(), whole.tiles.len());
        assert_eq!(merged.scheduled_tiles, whole.scheduled_tiles);
        assert_eq!(merged.elided_tiles, whole.elided_tiles);
        assert_eq!(merged.num_programs(), whole.num_programs(), "cross-part dedup");
        assert_eq!(merged.num_patterns(), whole.num_patterns(), "cross-part pattern dedup");
        assert_eq!(merged.pattern_dedup_hits(), whole.pattern_dedup_hits());
        let x: Vec<f64> = (0..g.dim).map(|i| ((i * 7) % 13) as f64 - 6.0).collect();
        assert_eq!(merged.mvm(&x), whole.mvm(&x));
        // dimension mismatches are rejected
        let sub = synth::qm7_like(5828);
        let gs = GridSummary::new(&sub, 2);
        let tiny = compile_rects(&sub, &gs, &[crate::scheme::GridRect::square(0, 1)]).unwrap();
        assert!(merge_plans(&[p_lo, tiny]).is_err());
        assert!(merge_plans(&[]).is_err());
    }

    #[test]
    fn compile_rejects_invalid_scheme() {
        let (m, g) = qh882_setup();
        let bad = Scheme {
            diag_len: vec![g.n + 1],
            fill_len: vec![],
        };
        assert!(compile(&m, &g, &bad).is_err());
    }

    #[test]
    fn random_scheme_plans_match_oracle_property() {
        check("engine_plan_matches_oracle", 15, |rng| {
            let m = synth::molecule_like(30, 80, rng.next_u64());
            let r = reorder(&m, Reordering::CuthillMckee);
            let grid = 2 + rng.below(4) as usize;
            let g = GridSummary::new(&r.matrix, grid);
            if g.n < 2 {
                return Ok(());
            }
            let d: Vec<u8> = (0..g.n - 1).map(|_| rng.below(2) as u8).collect();
            let f: Vec<usize> = (0..g.n - 1).map(|_| rng.below(4) as usize).collect();
            let s = parse_actions(g.n, &d, &f, FillRule::Dynamic { grades: 4 });
            let plan = compile(&r.matrix, &g, &s).map_err(|e| format!("{e:#}"))?;
            let arr = place(&r.matrix, &g, &s).map_err(|e| format!("{e:#}"))?;
            let x: Vec<f64> = (0..g.dim).map(|_| rng.uniform(-3.0, 3.0)).collect();
            let y = plan.mvm(&x);
            let want = arr.mvm(&x);
            for (i, (a, b)) in y.iter().zip(want.iter()).enumerate() {
                if (a - b).abs() > 1e-9 {
                    return Err(format!("row {i}: plan {a} vs oracle {b}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn kernels_sharding_and_batching_are_bit_identical_property() {
        // The perf-layer acceptance property: across random matrices,
        // schemes, kernel mixes, batch sizes, and worker counts, every
        // optimized path reproduces the seed scalar loop bit for bit.
        check("engine_kernels_bit_identical", 12, |rng| {
            let m = synth::molecule_like(24 + rng.below(30) as usize, 90, rng.next_u64());
            let r = reorder(&m, Reordering::CuthillMckee);
            let grid = 2 + rng.below(5) as usize;
            let g = GridSummary::new(&r.matrix, grid);
            if g.n < 2 {
                return Ok(());
            }
            let d: Vec<u8> = (0..g.n - 1).map(|_| rng.below(2) as u8).collect();
            let f: Vec<usize> = (0..g.n - 1).map(|_| rng.below(4) as usize).collect();
            let s = parse_actions(g.n, &d, &f, FillRule::Dynamic { grades: 4 });
            let plan = compile(&r.matrix, &g, &s).map_err(|e| format!("{e:#}"))?;
            let bsz = 1 + rng.below(9) as usize;
            let xs: Vec<Vec<f64>> = (0..bsz)
                .map(|_| (0..g.dim).map(|_| rng.uniform(-2.0, 2.0)).collect())
                .collect();
            let want: Vec<Vec<f64>> = xs.iter().map(|x| seed_reference(&plan, x)).collect();
            // scalar mvm, forced-dense, forced-sparse
            let mut dense = plan.clone();
            dense.rekernel(0.0);
            let mut sparse = plan.clone();
            sparse.rekernel(f64::INFINITY);
            let mut scalar_y = Vec::new();
            for (x, w) in xs.iter().zip(want.iter()) {
                plan.mvm_scalar_into(x, &mut scalar_y);
                if &scalar_y != w {
                    return Err("preserved scalar kernel diverged from seed".into());
                }
                if &plan.mvm(x) != w {
                    return Err("auto-kernel mvm diverged from seed".into());
                }
                if &dense.mvm(x) != w {
                    return Err("dense kernel diverged from seed".into());
                }
                if &sparse.mvm(x) != w {
                    return Err("sparse kernel diverged from seed".into());
                }
            }
            // multi-RHS kernel
            let mut ys = Vec::new();
            plan.mvm_batch_into(&xs, &mut ys);
            if ys != want {
                return Err("multi-RHS kernel diverged from seed".into());
            }
            // intra-request band sharding through the executor
            let plan = Arc::new(plan);
            for &workers in &[1usize, 2, 8] {
                let exec = BatchExecutor::new(plan.clone(), workers);
                let ys = exec.execute_batch_sharded(xs.clone());
                if ys != want {
                    return Err(format!("sharded execution at {workers} workers diverged"));
                }
                let ys = exec.execute_batch(xs.clone());
                if ys != want {
                    return Err(format!("scalar execution at {workers} workers diverged"));
                }
            }
            Ok(())
        });
    }
}
