//! The `algo-bench` driver: every algorithm, on a flat engine plan and a
//! hierarchical composite, at several worker counts — self-checked and
//! ledgered.
//!
//! One deterministic R-MAT graph is mapped twice: once as a **flat**
//! [`crate::engine::ExecPlan`] (a full-diagonal scheme compiled directly,
//! served through [`PlanEngine`]), and once as a **composite**
//! fixed-block deployment built through the [`crate::api`] facade (served
//! through [`DeploymentEngine`], i.e. with the RCM permutation applied on
//! the way in and out). For each plan × worker count the driver runs
//! PageRank (fixed iteration count, so iters/s is comparable across
//! configs), BFS, SSSP, and a two-layer GCN forward, and **fails the run**
//! unless every answer agrees with the host-CSR references — BFS levels
//! and SSSP distances bit-exactly (queue-based [`bfs_reference`] /
//! Dijkstra [`sssp_reference`]), PageRank within 1e-8 and GCN within 1e-5
//! of the [`CsrEngine`] runs at identical iteration counts.
//!
//! The ledger (`BENCH_algo.json`) nests per-algorithm [`AlgoTrace`]
//! objects as `plans.<flat|composite>.workers_<w>.<algorithm>` so CI can
//! grep iterations, residuals, and amortized nnz/s per configuration.
//! `AUTOGMAP_BENCH_FAST=1` shrinks the graph for smoke runs.

use super::gcn::{gcn_forward, max_abs_diff, GcnLayer};
use super::pagerank::{pagerank, PageRankOptions};
use super::traverse::{bfs, bfs_reference, sssp, sssp_reference, BfsOptions, SsspOptions};
use super::{CsrEngine, DeploymentEngine, MvmEngine, PlanEngine};
use crate::api::{DeploymentBuilder, Error, Result, Source, Strategy};
use crate::engine;
use crate::graph::{synth, GridSummary};
use crate::scheme::Scheme;
use crate::util::bench::write_bench_json;
use crate::util::json::{obj, Json};
use crate::util::rng::Pcg64;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

/// Configuration for one `algo-bench` run.
#[derive(Clone, Debug)]
pub struct AlgoBenchOptions {
    /// R-MAT node count (`AUTOGMAP_BENCH_FAST=1` caps it at 2000)
    pub nodes: usize,
    /// average degree; `target_nnz = nodes · degree` rounded even
    pub degree: usize,
    /// grid cell side for both mappings
    pub grid: usize,
    /// fixed-block size (in grid cells) for the composite mapping
    pub block: usize,
    /// graph + feature rng seed
    pub seed: u64,
    /// worker counts to sweep (the ISSUE gate runs 1/2/8)
    pub workers: Vec<usize>,
    /// band-sharded execution
    pub sharded: bool,
    /// PageRank sweeps per run (fixed-iteration mode, `tol = 0`)
    pub pagerank_iters: usize,
    /// where to write the machine-readable ledger
    pub bench_json: PathBuf,
}

impl Default for AlgoBenchOptions {
    fn default() -> AlgoBenchOptions {
        AlgoBenchOptions {
            nodes: 10_000,
            degree: 8,
            grid: 32,
            block: 4,
            seed: 0x5eed,
            workers: vec![1, 2, 8],
            sharded: true,
            pagerank_iters: 20,
            bench_json: PathBuf::from("BENCH_algo.json"),
        }
    }
}

/// Host-CSR reference answers every mapped configuration must reproduce.
struct References {
    pagerank: Vec<f64>,
    bfs: Vec<i64>,
    sssp: Vec<f64>,
    gcn: Vec<f64>,
}

/// Run all four algorithms on `eng`, check each against the references,
/// and return the per-algorithm trace ledger for this configuration.
fn run_suite<E: MvmEngine>(
    eng: &E,
    label: &str,
    refs: &References,
    pr_opts: &PageRankOptions,
    x: &[f64],
    layers: &[GcnLayer],
) -> Result<Json> {
    let (pr, pr_trace) = pagerank(eng, pr_opts)?;
    let pr_err = max_abs_diff(&pr, &refs.pagerank);
    if pr_err > 1e-8 {
        return Err(Error::Validate(format!(
            "{label}: pagerank diverges from the CSR reference by {pr_err:e} (> 1e-8)"
        )));
    }
    let (levels, bfs_trace) = bfs(eng, &BfsOptions { source: 0, max_levels: 0 })?;
    if levels != refs.bfs {
        return Err(Error::Validate(format!(
            "{label}: bfs levels are not bit-identical to the queue reference"
        )));
    }
    let (dist, sssp_trace) = sssp(eng, &SsspOptions { source: 0, max_iters: 0, chunk: 0 })?;
    if dist != refs.sssp {
        return Err(Error::Validate(format!(
            "{label}: sssp distances are not bit-identical to the Dijkstra reference"
        )));
    }
    let (feat, gcn_trace) = gcn_forward(eng, x, layers)?;
    let gcn_err = max_abs_diff(&feat, &refs.gcn);
    if gcn_err > 1e-5 {
        return Err(Error::Validate(format!(
            "{label}: gcn features diverge from the dense oracle by {gcn_err:e} (> 1e-5)"
        )));
    }
    Ok(obj(vec![
        ("pagerank", pr_trace.to_json()),
        ("bfs", bfs_trace.to_json()),
        ("sssp", sssp_trace.to_json()),
        ("gcn", gcn_trace.to_json()),
        ("pagerank_max_abs_err", Json::Num(pr_err)),
        ("gcn_max_abs_err", Json::Num(gcn_err)),
        ("bfs_exact", Json::Bool(true)),
        ("sssp_exact", Json::Bool(true)),
    ]))
}

/// Run the bench (see module docs). Returns the full ledger object (also
/// written to `bench_json`); any reference disagreement is an error.
pub fn run_algo_bench(opts: &AlgoBenchOptions) -> Result<Json> {
    let fast = std::env::var("AUTOGMAP_BENCH_FAST").is_ok_and(|v| v == "1");
    let nodes = if fast { opts.nodes.min(2000) } else { opts.nodes }.max(16);
    let target_nnz = ((nodes * opts.degree.max(1)) / 2).max(1) * 2;
    let grid = opts.grid.max(1);
    let t0 = Instant::now();

    let m = synth::rmat_like(nodes, target_nnz, opts.seed);
    let oracle = CsrEngine(&m);

    // reference answers, one per algorithm, on the host CSR
    let pr_opts = PageRankOptions {
        damping: 0.85,
        tol: 0.0,
        max_iters: opts.pagerank_iters.max(1),
    };
    let (pr_ref, _) = pagerank(&oracle, &pr_opts)?;
    let bfs_ref = bfs_reference(&m, 0);
    let sssp_ref = sssp_reference(&m, 0);
    let layers = vec![
        GcnLayer::random(8, 16, true, opts.seed),
        GcnLayer::random(16, 4, false, opts.seed + 1),
    ];
    let mut rng = Pcg64::new(opts.seed, 7);
    let x: Vec<f64> = (0..nodes * 8).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let (gcn_ref, _) = gcn_forward(&oracle, &x, &layers)?;
    let refs = References {
        pagerank: pr_ref,
        bfs: bfs_ref,
        sssp: sssp_ref,
        gcn: gcn_ref,
    };

    // flat plan: a full-diagonal scheme compiled straight to an ExecPlan
    // (complete coverage — no controller window limit at this scale)
    let g = GridSummary::new(&m, grid);
    let scheme = Scheme {
        diag_len: vec![g.n],
        fill_len: vec![],
    };
    let flat = Arc::new(
        engine::compile(&m, &g, &scheme)
            .map_err(|e| Error::Validate(format!("algo-bench flat compile: {e}")))?,
    );

    // composite plan: the facade's fixed-block mapping of the same matrix
    let dep = DeploymentBuilder::new(
        Source::Matrix {
            label: format!("rmat{nodes}"),
            matrix: m.clone(),
        },
        Strategy::FixedBlock {
            block: opts.block.max(1),
        },
    )
    .grid(grid)
    .seed(opts.seed)
    .build()?;

    let workers: Vec<usize> = if opts.workers.is_empty() {
        vec![1, 2, 8]
    } else {
        opts.workers.iter().map(|&w| w.max(1)).collect()
    };
    let mut flat_rows: Vec<(String, Json)> = Vec::new();
    let mut composite_rows: Vec<(String, Json)> = Vec::new();
    for &w in &workers {
        let eng = PlanEngine::new(flat.clone(), w, opts.sharded);
        let label = format!("flat/workers_{w}");
        flat_rows.push((
            format!("workers_{w}"),
            run_suite(&eng, &label, &refs, &pr_opts, &x, &layers)?,
        ));

        let exec = dep.executor(w);
        let eng = DeploymentEngine::new(&dep, &exec, opts.sharded);
        let label = format!("composite/workers_{w}");
        composite_rows.push((
            format!("workers_{w}"),
            run_suite(&eng, &label, &refs, &pr_opts, &x, &layers)?,
        ));
    }
    let nest = |rows: Vec<(String, Json)>| {
        Json::Obj(rows.into_iter().collect())
    };

    let fields = vec![
        ("bench", Json::Str("algo".into())),
        ("nodes", Json::Num(nodes as f64)),
        ("nnz", Json::Num(m.nnz() as f64)),
        ("degree", Json::Num(opts.degree as f64)),
        ("grid", Json::Num(grid as f64)),
        ("block", Json::Num(opts.block.max(1) as f64)),
        ("seed", Json::Num(opts.seed as f64)),
        ("sharded", Json::Bool(opts.sharded)),
        ("pagerank_iters", Json::Num(pr_opts.max_iters as f64)),
        (
            "workers",
            Json::Arr(workers.iter().map(|&w| Json::Num(w as f64)).collect()),
        ),
        (
            "plans",
            obj(vec![
                ("flat", nest(flat_rows)),
                ("composite", nest(composite_rows)),
            ]),
        ),
        ("wall_s", Json::Num(t0.elapsed().as_secs_f64())),
    ];
    let ledger = obj(fields.iter().map(|(k, v)| (*k, v.clone())).collect());
    write_bench_json(&opts.bench_json, fields)?;
    Ok(ledger)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts(name: &str) -> AlgoBenchOptions {
        AlgoBenchOptions {
            nodes: 120,
            degree: 6,
            grid: 8,
            block: 2,
            seed: 0xa160,
            workers: vec![1, 2],
            sharded: true,
            pagerank_iters: 8,
            bench_json: std::env::temp_dir().join(name),
        }
    }

    #[test]
    fn bench_self_checks_and_ledgers_both_plans() {
        let opts = tiny_opts("BENCH_algo_test.json");
        let ledger = run_algo_bench(&opts).unwrap();
        assert_eq!(ledger.get("bench").as_str(), Some("algo"));
        for plan in ["flat", "composite"] {
            for w in ["workers_1", "workers_2"] {
                let cfg = ledger.get("plans").get(plan).get(w);
                assert_eq!(
                    cfg.get("pagerank").get("iterations").as_i64(),
                    Some(8),
                    "{plan}/{w} ran the fixed pagerank iteration count"
                );
                assert_eq!(cfg.get("bfs_exact").as_bool(), Some(true));
                assert!(cfg.get("sssp").get("nnz_per_s").as_f64().unwrap() > 0.0);
                assert!(cfg.get("gcn_max_abs_err").as_f64().unwrap() <= 1e-5);
            }
        }
        let written = std::fs::read_to_string(&opts.bench_json).unwrap();
        assert!(written.contains("\"plans\""));
        std::fs::remove_file(&opts.bench_json).ok();
    }
}
