//! Bench: environment/reward evaluation throughput — the per-episode hot
//! path of the L3 coordinator (Algo. 3 lines 3-7). One training epoch
//! evaluates B=8 schemes, so eval throughput bounds epochs/s from the Rust
//! side.

use autogmap::graph::{synth, GridSummary};
use autogmap::reorder::{reorder, Reordering};
use autogmap::scheme::{evaluate, parse_actions, FillRule, RewardWeights};
use autogmap::util::bench::{black_box, Bencher};
use autogmap::util::rng::Pcg64;

fn bench_dataset(b: &mut Bencher, name: &str, m: &autogmap::graph::Csr, grid: usize) {
    let r = reorder(m, Reordering::CuthillMckee);
    let g = GridSummary::new(&r.matrix, grid);
    let w = RewardWeights::new(0.8);
    let mut rng = Pcg64::seed_from_u64(1);
    let n = g.n;
    // pre-generate a pool of random action vectors (excluded from timing)
    let pool: Vec<(Vec<u8>, Vec<usize>)> = (0..64)
        .map(|_| {
            (
                (0..n - 1).map(|_| rng.below(2) as u8).collect(),
                (0..n - 1).map(|_| rng.below(6) as usize).collect(),
            )
        })
        .collect();
    let mut i = 0;
    b.bench(&format!("grid_summary/{name}"), || {
        GridSummary::new(&r.matrix, grid)
    });
    b.bench(&format!("parse/{name}"), || {
        let (d, f) = &pool[i % pool.len()];
        i += 1;
        parse_actions(n, d, f, FillRule::Dynamic { grades: 6 })
    });
    let schemes: Vec<_> = pool
        .iter()
        .map(|(d, f)| parse_actions(n, d, f, FillRule::Dynamic { grades: 6 }))
        .collect();
    let mut j = 0;
    b.bench(&format!("evaluate/{name}"), || {
        let s = &schemes[j % schemes.len()];
        j += 1;
        black_box(evaluate(s, &g, w))
    });
    let mut k = 0;
    b.bench(&format!("parse+evaluate/{name}"), || {
        let (d, f) = &pool[k % pool.len()];
        k += 1;
        let s = parse_actions(n, d, f, FillRule::Dynamic { grades: 6 });
        black_box(evaluate(&s, &g, w))
    });
}

fn main() {
    let mut b = Bencher::new();
    bench_dataset(&mut b, "qm7_g2", &synth::qm7_like(5828), 2);
    bench_dataset(&mut b, "qh882_g32", &synth::qh882_like(882), 32);
    bench_dataset(&mut b, "qh1484_g32", &synth::qh1484_like(1484), 32);
    // scalability stress: a 16k matrix at grid 64 (beyond the paper)
    bench_dataset(&mut b, "synth16k_g64", &synth::banded_like(16384, 0.999, 9), 64);
}
