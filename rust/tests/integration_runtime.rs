//! Integration tests over the PJRT runtime + AOT artifacts.
//!
//! These tests require `make artifacts` to have run; they are skipped
//! (with a visible message) when artifacts/ is absent so `cargo test`
//! stays green on a fresh checkout.

use autogmap::agent::lstm::{forward, Select};
use autogmap::agent::{params, TrainOptions, Trainer};
use autogmap::graph::{synth, GridSummary};
use autogmap::reorder::{reorder, Reordering};
use autogmap::runtime::{literal, Runtime};
use autogmap::scheme::{FillRule, RewardWeights};

fn runtime() -> Option<Runtime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/manifest.json missing (run `make artifacts`)");
        return None;
    }
    Some(Runtime::new(dir).expect("PJRT runtime"))
}

#[test]
fn all_artifacts_load_and_compile() {
    let Some(rt) = runtime() else { return };
    let manifest = rt.manifest().unwrap();
    assert!(!manifest.configs.is_empty());
    for entry in manifest.configs.values() {
        for file in entry.artifacts.values() {
            rt.load(file)
                .unwrap_or_else(|e| panic!("loading {file}: {e:#}"));
        }
    }
    for mvm in manifest.mvm.values() {
        rt.load(&mvm.artifact).unwrap();
    }
}

#[test]
fn rollout_artifact_produces_valid_episodes() {
    let Some(rt) = runtime() else { return };
    let manifest = rt.manifest().unwrap();
    let entry = manifest.config("qm7_dyn4").unwrap().clone();
    let exe = rt.load(entry.artifact("rollout").unwrap()).unwrap();
    let p = params::init_params(&entry, 7);
    let mut inputs = params::to_literals(&entry, &p).unwrap();
    inputs.push(literal::lit_u32_1d(&[1, 2]));
    let outs = exe.run(&inputs).unwrap();
    assert_eq!(outs.len(), 4);
    let d = literal::to_vec_i32(&outs[0]).unwrap();
    let f = literal::to_vec_i32(&outs[1]).unwrap();
    let logp = outs[2].to_vec::<f32>().unwrap();
    let ent = outs[3].to_vec::<f32>().unwrap();
    assert_eq!(d.len(), entry.batch * entry.steps);
    assert!(d.iter().all(|&x| x == 0 || x == 1));
    assert!(f.iter().all(|&x| x >= 0 && (x as usize) < entry.fill_classes));
    assert!(logp.iter().all(|&x| x < 0.0 && x.is_finite()));
    assert!(ent.iter().all(|&x| x > 0.0));
    // determinism in the key
    let outs2 = exe.run(&inputs).unwrap();
    assert_eq!(literal::to_vec_i32(&outs2[0]).unwrap(), d);
}

#[test]
fn hlo_rollout_logp_matches_rust_mirror() {
    // Teacher-force the HLO rollout's sampled actions through the pure-Rust
    // controller mirror; log-probs must agree (ABI + math cross-check).
    let Some(rt) = runtime() else { return };
    let manifest = rt.manifest().unwrap();
    for name in ["qm7_diag", "qm7_dyn4", "qm7_fill_bilstm"] {
        let entry = manifest.config(name).unwrap().clone();
        let exe = rt.load(entry.artifact("rollout").unwrap()).unwrap();
        let p = params::init_params(&entry, 99);
        let mut inputs = params::to_literals(&entry, &p).unwrap();
        inputs.push(literal::lit_u32_1d(&[11, 22]));
        let outs = exe.run(&inputs).unwrap();
        let d = literal::to_vec_i32(&outs[0]).unwrap();
        let f = literal::to_vec_i32(&outs[1]).unwrap();
        let logp = outs[2].to_vec::<f32>().unwrap();
        let t = entry.steps;
        for b in 0..entry.batch {
            let ep = forward(
                &entry,
                &p,
                Select::Teacher {
                    d: &d[b * t..(b + 1) * t],
                    f: &f[b * t..(b + 1) * t],
                },
            );
            assert!(
                (ep.logp - logp[b]).abs() < 2e-3,
                "{name} episode {b}: mirror logp {} vs HLO {}",
                ep.logp,
                logp[b]
            );
        }
    }
}

#[test]
fn trainer_improves_reward_on_qm7() {
    // End-to-end REINFORCE smoke: 150 epochs on the QM7-like matrix must
    // raise mean reward and find at least one complete-coverage scheme.
    let Some(rt) = runtime() else { return };
    let manifest = rt.manifest().unwrap();
    let entry = manifest.config("qm7_dyn4").unwrap().clone();
    let m = synth::qm7_like(5828);
    let r = reorder(&m, Reordering::CuthillMckee);
    let grid = GridSummary::new(&r.matrix, 2);
    let opts = TrainOptions {
        lr: 0.02,
        weights: RewardWeights::new(0.8),
        fill_rule: FillRule::Dynamic { grades: 4 },
        seed: 3,
        ..Default::default()
    };
    let mut trainer = Trainer::new(&rt, entry, opts).unwrap();
    let mut first = None;
    let mut last = None;
    for _ in 0..150 {
        let s = trainer.epoch(&grid).unwrap();
        if first.is_none() {
            first = Some(s.mean_reward);
        }
        last = Some(s.mean_reward);
    }
    let (first, last) = (first.unwrap(), last.unwrap());
    assert!(
        last > first - 0.02,
        "reward regressed: {first} -> {last}"
    );
    let best = trainer.best.as_ref().expect("no complete-coverage scheme found");
    assert_eq!(best.eval.coverage_ratio, 1.0);
    assert!(best.eval.area_ratio < 1.0);
    best.scheme.validate(grid.n).unwrap();
}

#[test]
fn greedy_artifact_matches_rust_greedy_mirror() {
    let Some(rt) = runtime() else { return };
    let manifest = rt.manifest().unwrap();
    let entry = manifest.config("qm7_dyn6").unwrap().clone();
    let exe = rt.load(entry.artifact("greedy").unwrap()).unwrap();
    let p = params::init_params(&entry, 5);
    let outs = exe.run(&params::to_literals(&entry, &p).unwrap()).unwrap();
    let d = literal::to_vec_i32(&outs[0]).unwrap();
    let f = literal::to_vec_i32(&outs[1]).unwrap();
    let ep = forward(&entry, &p, Select::Greedy);
    let t = entry.steps;
    // batch rows are identical (same params, deterministic decode)
    assert_eq!(&d[..t], ep.d_actions.as_slice());
    assert_eq!(&f[..t], ep.f_actions.as_slice());
}

#[test]
fn train_artifact_shifts_params_toward_positive_advantage() {
    let Some(rt) = runtime() else { return };
    let manifest = rt.manifest().unwrap();
    let entry = manifest.config("qm7_diag").unwrap().clone();
    let (b, t) = (entry.batch, entry.steps);
    let train = rt.load(entry.artifact("train").unwrap()).unwrap();
    let p = params::init_params(&entry, 13);
    let opt = params::AdamState::new(&entry);
    let d = vec![0i32; b * t];
    let f = vec![0i32; b * t];
    let adv = vec![1.0f32; b];
    let k = entry.params.len();
    let mut inputs = params::to_literals(&entry, &p).unwrap();
    inputs.extend(params::to_literals(&entry, &opt.m).unwrap());
    inputs.extend(params::to_literals(&entry, &opt.v).unwrap());
    inputs.push(literal::lit_scalar_i32(opt.t));
    inputs.push(literal::lit_i32_2d(&d, b, t).unwrap());
    inputs.push(literal::lit_i32_2d(&f, b, t).unwrap());
    inputs.push(literal::lit_f32_1d(&adv));
    inputs.push(literal::lit_scalar_f32(0.05));
    inputs.push(literal::lit_scalar_f32(0.0));
    let outs = train.run(&inputs).unwrap();
    assert_eq!(outs.len(), 3 * k + 3);
    let p2 = params::from_literals(&entry, &outs[..k]).unwrap();
    assert_ne!(p, p2, "train step must move parameters");
    // repeating the step must raise logp of the all-zeros action sequence
    let before = forward(&entry, &p, Select::Teacher { d: &d[..t], f: &f[..t] }).logp;
    let after = forward(&entry, &p2, Select::Teacher { d: &d[..t], f: &f[..t] }).logp;
    assert!(after > before, "logp {before} -> {after}");
    let new_t = outs[3 * k].to_vec::<i32>().unwrap()[0];
    assert_eq!(new_t, 1);
}
