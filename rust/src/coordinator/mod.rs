//! L3 coordinator: configuration, dataset preparation, the experiment
//! runner, metrics logging, and the paper-reproduction drivers.

pub mod config;
pub mod dataset;
pub mod maplarge;
pub mod metrics;
pub mod reproduce;
pub mod runner;

pub use config::{Dataset, ExperimentConfig};
pub use maplarge::{run_map_large, MapLargeOptions};
pub use runner::{build_trainer, default_workers, run_experiment, RunResult, RunnerOptions};
