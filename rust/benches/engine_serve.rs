//! Bench: the execution engine's serving path vs the oracle simulator.
//!
//! Rungs per workload, separating each win:
//!   oracle_mvm     — CrossbarArray::mvm, every tile walked (the seed path)
//!   plan_scalar    — compiled ExecPlan, the seed's scalar row-dot loop
//!                    (elision + dedup, no vectorization)
//!   plan_dense     — compiled ExecPlan, dense kernels forced (elision only)
//!   plan_mvm       — compiled ExecPlan, density-adaptive vectorized
//!                    kernels (elision × lane-unrolled dense bodies ×
//!                    pattern-deduped sparse CSR-within-tile kernels)
//!   plan_batchN    — multi-RHS kernel, single thread: one arena traversal
//!                    serves the whole batch
//!   scalarN_wW     — BatchExecutor scalar mode, W workers over N requests
//!   shardedN_wW    — BatchExecutor optimized mode: row bands sharded
//!                    across W workers, multi-RHS within each span

use autogmap::crossbar::place;
use autogmap::engine::{compile, BatchExecutor};
use autogmap::graph::{synth, GridSummary};
use autogmap::reorder::{reorder, Reordering};
use autogmap::scheme::Scheme;
use autogmap::util::bench::{black_box, Bencher};
use std::sync::Arc;

fn main() {
    let mut b = Bencher::new();
    for (name, m, grid) in [
        ("qm7_g2", synth::qm7_like(5828), 2usize),
        ("qh882_g32", synth::qh882_like(882), 32),
        ("qh1484_g32", synth::qh1484_like(1484), 32),
    ] {
        let r = reorder(&m, Reordering::CuthillMckee);
        let g = GridSummary::new(&r.matrix, grid);
        // the full-matrix block: complete coverage with maximal dead space,
        // i.e. the workload where compiled elision matters most
        let scheme = Scheme {
            diag_len: vec![g.n],
            fill_len: vec![],
        };
        let arr = place(&r.matrix, &g, &scheme).unwrap();
        let plan = compile(&r.matrix, &g, &scheme).unwrap();
        let (dense_k, sparse_k) = plan.kernel_counts();
        println!(
            "{name}: {} tiles scheduled, {} placed ({:.1}% elided), {} bands, kernels {dense_k}d/{sparse_k}s, {} row patterns ({} dedup hits)",
            plan.scheduled_tiles,
            plan.tiles.len(),
            plan.elision_ratio() * 100.0,
            plan.bands().len(),
            plan.num_patterns(),
            plan.pattern_dedup_hits()
        );
        let x: Vec<f64> = (0..g.dim).map(|i| (i as f64 * 0.1).sin()).collect();
        b.bench(&format!("oracle_mvm/{name} ({} tiles)", arr.tiles.len()), || {
            black_box(arr.mvm(&x))
        });
        let mut y_scalar = Vec::new();
        b.bench(&format!("plan_scalar/{name} ({} tiles)", plan.tiles.len()), || {
            plan.mvm_scalar_into(&x, &mut y_scalar);
            black_box(y_scalar.first().copied())
        });
        let mut dense_plan = plan.clone();
        dense_plan.rekernel(0.0);
        b.bench(&format!("plan_dense/{name} ({} tiles)", dense_plan.tiles.len()), || {
            black_box(dense_plan.mvm(&x))
        });
        b.bench(&format!("plan_mvm/{name} ({} tiles)", plan.tiles.len()), || {
            black_box(plan.mvm(&x))
        });
        let batch = 32usize;
        let xs: Vec<Vec<f64>> = (0..batch)
            .map(|s| (0..g.dim).map(|i| ((i + s) as f64 * 0.07).cos()).collect())
            .collect();
        let mut ys: Vec<Vec<f64>> = Vec::new();
        b.bench(&format!("plan_batch{batch}/{name}"), || {
            plan.mvm_batch_into(&xs, &mut ys);
            black_box(ys.len())
        });
        let plan = Arc::new(plan);
        for workers in [2usize, 8] {
            let exec = BatchExecutor::new(plan.clone(), workers);
            exec.recycle(exec.execute_batch(xs.clone())); // warm pool
            let stats = b
                .bench(&format!("scalar{batch}_w{workers}/{name}"), || {
                    let ys = exec.execute_batch(xs.clone());
                    exec.recycle(ys);
                })
                .clone();
            println!(
                "  -> {:.0} req/s scalar through {workers} workers",
                batch as f64 / stats.median_s
            );
            let stats = b
                .bench(&format!("sharded{batch}_w{workers}/{name}"), || {
                    let ys = exec.execute_batch_sharded(xs.clone());
                    exec.recycle(ys);
                })
                .clone();
            println!(
                "  -> {:.0} req/s sharded multi-RHS through {workers} workers",
                batch as f64 / stats.median_s
            );
        }
    }
}
