//! Quickstart: the 60-second tour of the public API.
//!
//! Loads the QM7-5828-like molecule graph, Cuthill-McKee-reorders it,
//! trains the LSTM+RL+Dynamic-fill agent for a few thousand epochs on the
//! pure-Rust native backend, and prints the best complete-coverage mapping
//! scheme next to the baselines.
//!
//! Run: `cargo run --release --example quickstart`
//! (no artifacts needed — the native backend trains on a fresh checkout;
//! build `make artifacts` and the default `auto` backend switches to the
//! AOT PJRT path instead)

use autogmap::agent::BackendKind;
use autogmap::baselines;
use autogmap::coordinator::config::{Dataset, ExperimentConfig};
use autogmap::coordinator::{run_experiment, RunnerOptions};
use autogmap::graph::GridSummary;
use autogmap::reorder::Reordering;
use autogmap::scheme::{evaluate, FillRule, RewardWeights};
use autogmap::viz;

fn main() -> anyhow::Result<()> {
    // 1. the workload: a 22×22 molecule adjacency (sparsity 0.868)
    let cfg = ExperimentConfig {
        name: "quickstart".into(),
        dataset: Dataset::Qm7 { seed: 5828 },
        grid: 2,
        reordering: Reordering::CuthillMckee,
        controller: "qm7_dyn4".into(),
        fill_rule: FillRule::Dynamic { grades: 4 },
        reward_a: 0.75,
        lr: 0.015,
        ent_coef: 0.002,
        baseline_decay: 0.95,
        epochs: 3000,
        seed: 42,
        log_every: 100,
    };

    // 2. train on the native backend: pure Rust (sampling rollouts, full
    // BPTT, Adam) — no artifacts directory, no PJRT
    let opts = RunnerOptions {
        backend: BackendKind::Native,
        ..Default::default()
    };
    let result = run_experiment(None, &cfg, &opts)?;
    println!(
        "\ntrained {} epochs in {:.1}s ({:.0} epochs/s, native backend)",
        cfg.epochs,
        result.wall_seconds,
        cfg.epochs as f64 / result.wall_seconds
    );

    // 3. inspect the best complete-coverage scheme
    let grid = &result.workload.grid;
    let best = result.best.as_ref().expect("agent found no complete scheme");
    println!(
        "best scheme: diagonal blocks {:?} (matrix units), fill {:?} (grid cells)",
        best.scheme.diag_sizes_units(grid),
        best.scheme.fill_len
    );
    println!(
        "coverage {:.3}  area {:.3}  sparsity {:.3}",
        best.eval.coverage_ratio, best.eval.area_ratio, best.eval.sparsity
    );
    println!(
        "\n{}",
        viz::ascii_scheme(&result.workload.reordered.matrix, grid, &best.scheme)
    );

    // 4. compare with the static baselines on the same (reordered) matrix
    let w = RewardWeights::new(cfg.reward_a);
    let g1 = GridSummary::new(&result.workload.reordered.matrix, 1);
    for block in [4, 6, 8] {
        let s = baselines::vanilla(22, block);
        let e = evaluate(&s, &g1, w);
        println!(
            "vanilla block {block}: coverage {:.3} area {:.3}",
            e.coverage_ratio, e.area_ratio
        );
    }
    if let Some(oracle) = baselines::oracle::optimal_diagonal(grid) {
        let e = evaluate(&oracle, grid, w);
        println!(
            "DP oracle (diagonal-only complete coverage): area {:.3}",
            e.area_ratio
        );
    }
    Ok(())
}
