//! Per-window mapping inference: controller rollouts + safety candidates.
//!
//! The paper's controller emits actions from a learned initial state — it
//! takes no observation — so at inference time content-conditioning comes
//! from *selection*: sample a batch of candidate schemes through the
//! trained controller ([`crate::agent::native::infer_episodes`], plus the
//! greedy decode), evaluate each against the window's grid summary, and
//! keep the least-area complete-coverage candidate. Two deterministic
//! safety candidates guarantee the composite principles regardless of how
//! well the controller is trained:
//!
//! - the DP oracle ([`crate::baselines::oracle::optimal_diagonal`]) — the
//!   optimal diagonal-only complete partition, the tightest no-fill bound;
//! - the full window block — complete by construction, the worst case.
//!
//! Selection depends only on the window's occupancy signature (the PRNG
//! key is derived from it), so identical windows map identically and the
//! scheme cache stays sound.

use crate::agent::native::infer_episodes;
use crate::agent::params::Params;
use crate::baselines::oracle;
use crate::graph::GridSummary;
use crate::runtime::manifest::ControllerEntry;
use crate::scheme::{evaluate, parse_actions, FillRule, RewardWeights, Scheme};

/// Everything window inference needs, shared across worker threads (and
/// embedded in [`crate::mapper::MapperConfig`] — the mapper adds only its
/// windowing/parallelism knobs on top).
#[derive(Clone)]
pub struct InferContext {
    pub entry: ControllerEntry,
    pub params: Params,
    pub fill_rule: FillRule,
    pub weights: RewardWeights,
    /// sampling rounds per window (each `entry.batch` episodes); 0 =
    /// greedy + safety candidates only
    pub rounds: usize,
    /// run seed folded into every window's rollout key
    pub seed: u64,
}

/// Map one window: returns the selected scheme over the window grid.
///
/// Preference order: complete coverage first, then least mapped area, then
/// candidate index (deterministic). The controller only runs when the
/// window length matches its native grid; short windows (a whole graph
/// smaller than one window) fall back to the safety candidates.
pub fn map_window(ctx: &InferContext, local: &GridSummary, sig_hash: u64) -> Scheme {
    let n = local.n;
    let mut candidates: Vec<Scheme> = Vec::new();
    if n == ctx.entry.n {
        let key = [
            (ctx.seed ^ sig_hash) as u32,
            ((ctx.seed ^ sig_hash) >> 32) as u32,
        ];
        let t = ctx.entry.steps;
        for ep in infer_episodes(&ctx.entry, &ctx.params, key, ctx.rounds) {
            let d: Vec<u8> = ep.d_actions[..t].iter().map(|&x| x as u8).collect();
            let f: Vec<usize> = ep.f_actions[..t].iter().map(|&x| x as usize).collect();
            candidates.push(parse_actions(n, &d, &f, ctx.fill_rule));
        }
    }
    // safety candidates: the DP oracle (optimal diagonal-only complete
    // partition; always exists — the full block is feasible) and the full
    // window block itself
    if let Some(orc) = oracle::optimal_diagonal(local) {
        candidates.push(orc);
    }
    candidates.push(Scheme { diag_len: vec![n], fill_len: vec![] });

    let mut best: Option<(u64, usize)> = None; // (area, candidate index)
    for (i, cand) in candidates.iter().enumerate() {
        if cand.validate(n).is_err() {
            continue;
        }
        let e = evaluate(cand, local, ctx.weights);
        if e.coverage_ratio < 1.0 {
            continue;
        }
        let better = match best {
            None => true,
            Some((area, _)) => e.covered_area_units < area,
        };
        if better {
            best = Some((e.covered_area_units, i));
        }
    }
    let (_, idx) = best.expect("full window block is always a complete candidate");
    candidates.swap_remove(idx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::params::init_params;
    use crate::graph::sparse::Coo;
    use crate::graph::synth;
    use crate::graph::GridSummary;

    fn ctx(n: usize, fill: usize, rounds: usize) -> InferContext {
        let entry = ControllerEntry::from_dims("infer_test", n, 5, fill, 4, false);
        let params = init_params(&entry, 3);
        InferContext {
            entry,
            params,
            fill_rule: if fill == 0 {
                FillRule::None
            } else {
                FillRule::Dynamic { grades: fill }
            },
            weights: RewardWeights::new(0.8),
            rounds,
            seed: 9,
        }
    }

    #[test]
    fn empty_window_maps_to_unit_blocks() {
        let m = Coo::new(12, 12).to_csr();
        let g = GridSummary::new(&m, 2); // n = 6
        let c = ctx(6, 4, 2);
        let s = map_window(&c, &g, 0x1234);
        // DP oracle: every block feasible on an empty window, unit blocks
        // minimize area
        assert_eq!(s.diag_len, vec![1; 6]);
        let e = evaluate(&s, &g, c.weights);
        assert_eq!(e.coverage_ratio, 1.0);
    }

    #[test]
    fn selection_is_complete_and_no_worse_than_oracle_with_fills() {
        let m = synth::banded_like(48, 0.85, 7);
        let g = GridSummary::new(&m, 8); // n = 6
        let c = ctx(6, 4, 3);
        let s = map_window(&c, &g, 0xbeef);
        let e = evaluate(&s, &g, c.weights);
        assert_eq!(e.coverage_ratio, 1.0, "selected scheme must be complete");
        let orc = oracle::optimal_diagonal(&g).unwrap();
        let eo = evaluate(&orc, &g, c.weights);
        assert!(
            e.covered_area_units <= eo.covered_area_units,
            "selection {} worse than its own oracle candidate {}",
            e.covered_area_units,
            eo.covered_area_units
        );
    }

    #[test]
    fn inference_is_deterministic_in_the_signature() {
        let m = synth::banded_like(48, 0.9, 1);
        let g = GridSummary::new(&m, 8);
        let c = ctx(6, 4, 2);
        assert_eq!(map_window(&c, &g, 42), map_window(&c, &g, 42));
    }

    #[test]
    fn short_window_skips_the_controller() {
        // grid smaller than the controller's native n: safety candidates
        // only, still complete
        let m = synth::qm7_like(5828);
        let g = GridSummary::new(&m, 8); // n = 3 < controller n = 6
        let c = ctx(6, 4, 2);
        let s = map_window(&c, &g, 7);
        s.validate(3).unwrap();
        let e = evaluate(&s, &g, c.weights);
        assert_eq!(e.coverage_ratio, 1.0);
    }
}
