"""L2 controller correctness: rollout/teacher-forcing/train invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model


def small_cfg(**kw):
    defaults = dict(name="t", n=6, hidden=8, fill_classes=4, batch=4, bilstm=False)
    defaults.update(kw)
    return model.ControllerConfig(**defaults)


CFGS = [
    small_cfg(),
    small_cfg(fill_classes=0),
    small_cfg(fill_classes=2),
    small_cfg(bilstm=True),
]


@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: f"F{c.fill_classes}_bi{c.bilstm}")
def test_rollout_shapes_and_ranges(cfg):
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    d, f, logp, ent = jax.jit(lambda p, k: model.rollout(cfg, p, k))(
        params, jax.random.PRNGKey(1)
    )
    B, T = cfg.batch, cfg.steps
    assert d.shape == (B, T) and f.shape == (B, T)
    assert logp.shape == (B,) and ent.shape == (B,)
    assert np.all((np.asarray(d) == 0) | (np.asarray(d) == 1))
    if cfg.fill_classes:
        assert np.all(np.asarray(f) >= 0)
        assert np.all(np.asarray(f) < cfg.fill_classes)
    assert np.all(np.asarray(logp) < 0.0)  # proper distribution
    assert np.all(np.asarray(ent) > 0.0)


@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: f"F{c.fill_classes}_bi{c.bilstm}")
def test_teacher_logp_matches_rollout_logp(cfg):
    """Recomputing the log-prob of sampled actions must reproduce the
    rollout's log-prob — this is the core sampling/training consistency
    invariant (rollout uses the Pallas cell, teacher forcing uses ref)."""
    params = model.init_params(cfg, jax.random.PRNGKey(2))
    d, f, logp, _ = model.rollout(cfg, params, jax.random.PRNGKey(3))
    tlogp, tent = model.teacher_logp(cfg, params, d, f)
    assert_allclose(np.asarray(tlogp), np.asarray(logp), rtol=1e-4, atol=1e-5)
    assert np.all(np.asarray(tent) > 0)


def test_rollout_is_deterministic_in_key():
    cfg = small_cfg()
    params = model.init_params(cfg, jax.random.PRNGKey(4))
    d1, f1, l1, _ = model.rollout(cfg, params, jax.random.PRNGKey(7))
    d2, f2, l2, _ = model.rollout(cfg, params, jax.random.PRNGKey(7))
    assert np.array_equal(np.asarray(d1), np.asarray(d2))
    assert np.array_equal(np.asarray(f1), np.asarray(f2))
    d3, _, _, _ = model.rollout(cfg, params, jax.random.PRNGKey(8))
    # different key should (overwhelmingly) differ somewhere
    assert not np.array_equal(np.asarray(d1), np.asarray(d3))


def test_train_step_increases_logp_of_positive_advantage():
    """REINFORCE sanity: repeating updates with a fixed positive advantage
    on fixed actions must raise their log-probability."""
    cfg = small_cfg()
    params = model.init_params(cfg, jax.random.PRNGKey(5))
    opt = model.adam_init(params)
    d, f, logp0, _ = model.rollout(cfg, params, jax.random.PRNGKey(6))
    adv = jnp.ones((cfg.batch,))
    lr = jnp.float32(0.02)
    ent = jnp.float32(0.0)
    step = jax.jit(
        lambda p, o: model.train_step(cfg, p, o, d, f, adv, lr, ent)
    )
    for _ in range(30):
        params, opt, loss, mean_logp = step(params, opt)
    tlogp, _ = model.teacher_logp(cfg, params, d, f)
    assert np.mean(np.asarray(tlogp)) > np.mean(np.asarray(logp0)) + 0.5
    assert int(opt["t"]) == 30


def test_train_step_respects_advantage_sign():
    """Negative-advantage actions must become less likely."""
    cfg = small_cfg(fill_classes=0)
    params = model.init_params(cfg, jax.random.PRNGKey(8))
    opt = model.adam_init(params)
    d, f, logp0, _ = model.rollout(cfg, params, jax.random.PRNGKey(9))
    adv = -jnp.ones((cfg.batch,))
    step = jax.jit(
        lambda p, o: model.train_step(
            cfg, p, o, d, f, adv, jnp.float32(0.02), jnp.float32(0.0)
        )
    )
    for _ in range(20):
        params, opt, _, _ = step(params, opt)
    tlogp, _ = model.teacher_logp(cfg, params, d, f)
    assert np.mean(np.asarray(tlogp)) < np.mean(np.asarray(logp0))


def test_grads_flow_to_all_params():
    cfg = small_cfg(bilstm=True)
    params = model.init_params(cfg, jax.random.PRNGKey(10))
    d, f, _, _ = model.rollout(cfg, params, jax.random.PRNGKey(11))
    adv = jnp.ones((cfg.batch,))

    def loss_fn(p):
        logp, ent = model.teacher_logp(cfg, p, d, f)
        return -jnp.mean(adv * logp) - 0.01 * jnp.mean(ent)

    grads = jax.grad(loss_fn)(params)
    for name, g in grads.items():
        assert np.all(np.isfinite(np.asarray(g))), name
        assert np.any(np.asarray(g) != 0.0), f"zero grad for {name}"


def test_param_spec_shapes_match_init():
    for cfg in CFGS:
        params = model.init_params(cfg, jax.random.PRNGKey(0))
        for name, shape in model.param_spec(cfg):
            assert params[name].shape == shape
        flat = model.params_to_list(cfg, params)
        back = model.params_from_list(cfg, flat)
        assert set(back.keys()) == set(params.keys())


def test_fill_masking_zeroes_fill_contribution():
    """When every diagonal action is 'extend' (1), fill log-probs must not
    contribute: logp equals the diagonal-only logp."""
    cfg = small_cfg(fill_classes=4)
    params = model.init_params(cfg, jax.random.PRNGKey(12))
    B, T = cfg.batch, cfg.steps
    d = jnp.ones((B, T), jnp.int32)
    f0 = jnp.zeros((B, T), jnp.int32)
    f3 = 3 * jnp.ones((B, T), jnp.int32)
    l0, _ = model.teacher_logp(cfg, params, d, f0)
    l3, _ = model.teacher_logp(cfg, params, d, f3)
    assert_allclose(np.asarray(l0), np.asarray(l3), rtol=1e-6)


def test_greedy_rollout_is_deterministic():
    cfg = small_cfg()
    params = model.init_params(cfg, jax.random.PRNGKey(13))
    d1, f1, _, _ = model.greedy_rollout(cfg, params)
    d2, f2, _, _ = model.greedy_rollout(cfg, params)
    assert np.array_equal(np.asarray(d1), np.asarray(d2))
    assert np.array_equal(np.asarray(f1), np.asarray(f2))
