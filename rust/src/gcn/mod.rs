//! Spectral GCN workload (Eq. 1) — the motivating application the paper
//! opens §III with:
//!
//!   Z_{l+1} = σ( D̂^{-1/2} Â D̂^{-1/2} Z_l W_l ),   Â = A + I
//!
//! The normalized adjacency is the sparse matrix mapped onto crossbars;
//! feature propagation is a batch of MVMs through the mapped tiles, with
//! the switch circuit applying P / Pᵀ around the array. The dense path is
//! the correctness oracle; `examples/gcn_inference.rs` runs both and
//! reports agreement + crossbar cost.

use crate::crossbar::switch::SwitchCircuit;
use crate::crossbar::CrossbarArray;
use crate::graph::{Coo, Csr};
use crate::util::rng::Pcg64;
use anyhow::{ensure, Result};

/// Symmetric-normalized adjacency with self-loops: D̂^{-1/2}(A+I)D̂^{-1/2}.
pub fn normalized_adjacency(a: &Csr) -> Csr {
    assert_eq!(a.rows, a.cols, "GCN adjacency must be square");
    let n = a.rows;
    // Â = A + I
    let mut coo = Coo::new(n, n);
    for r in 0..n {
        for (i, &c) in a.row(r).iter().enumerate() {
            if r != c {
                coo.push(r, c, a.row_vals(r)[i]);
            }
        }
        coo.push(r, r, a.get(r, r) + 1.0);
    }
    let ahat = coo.to_csr();
    // degrees
    let deg: Vec<f64> = (0..n).map(|r| ahat.row_vals(r).iter().sum()).collect();
    let dinv_sqrt: Vec<f64> = deg
        .iter()
        .map(|&d| if d > 0.0 { 1.0 / d.sqrt() } else { 0.0 })
        .collect();
    let mut out = Coo::new(n, n);
    for r in 0..n {
        for (i, &c) in ahat.row(r).iter().enumerate() {
            out.push(r, c, dinv_sqrt[r] * ahat.row_vals(r)[i] * dinv_sqrt[c]);
        }
    }
    out.to_csr()
}

/// One GCN layer's dense weights, row-major [in_dim, out_dim].
#[derive(Clone, Debug)]
pub struct GcnLayer {
    pub in_dim: usize,
    pub out_dim: usize,
    pub w: Vec<f64>,
    pub relu: bool,
}

impl GcnLayer {
    pub fn random(in_dim: usize, out_dim: usize, relu: bool, seed: u64) -> GcnLayer {
        let mut rng = Pcg64::seed_from_u64(seed ^ 0x6763_6e5f_7731_0001);
        let scale = (2.0 / in_dim as f64).sqrt();
        GcnLayer {
            in_dim,
            out_dim,
            w: (0..in_dim * out_dim)
                .map(|_| rng.normal() * scale)
                .collect(),
            relu,
        }
    }

    /// Z W (node-feature transform), Z row-major [n, in_dim].
    fn transform(&self, z: &[f64], n: usize) -> Vec<f64> {
        let mut out = vec![0.0; n * self.out_dim];
        for r in 0..n {
            for i in 0..self.in_dim {
                let zv = z[r * self.in_dim + i];
                if zv == 0.0 {
                    continue;
                }
                let wrow = &self.w[i * self.out_dim..(i + 1) * self.out_dim];
                for (o, wv) in out[r * self.out_dim..(r + 1) * self.out_dim]
                    .iter_mut()
                    .zip(wrow)
                {
                    *o += zv * wv;
                }
            }
        }
        out
    }

    fn activate(&self, x: &mut [f64]) {
        if self.relu {
            for v in x.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
        }
    }

    /// Dense oracle: σ(A_norm (Z W)).
    pub fn forward_dense(&self, a_norm: &Csr, z: &[f64]) -> Vec<f64> {
        let n = a_norm.rows;
        assert_eq!(z.len(), n * self.in_dim);
        let zw = self.transform(z, n);
        // propagate each output column through the sparse matrix
        let mut out = vec![0.0; n * self.out_dim];
        let mut col = vec![0.0; n];
        for o in 0..self.out_dim {
            for r in 0..n {
                col[r] = zw[r * self.out_dim + o];
            }
            let prop = a_norm.spmv(&col);
            for r in 0..n {
                out[r * self.out_dim + o] = prop[r];
            }
        }
        self.activate(&mut out);
        out
    }

    /// Crossbar path: σ(Pᵀ(A'(P(Z W)))) per feature column, where `arr`
    /// holds A' = P A_norm Pᵀ and `sw` is the switch circuit for P.
    pub fn forward_crossbar(
        &self,
        arr: &CrossbarArray,
        sw: &SwitchCircuit,
        z: &[f64],
    ) -> Result<Vec<f64>> {
        let n = arr.dim;
        ensure!(sw.len() == n, "switch/array size mismatch");
        ensure!(z.len() == n * self.in_dim, "feature matrix shape mismatch");
        let zw = self.transform(z, n);
        let mut out = vec![0.0; n * self.out_dim];
        let mut col = vec![0.0; n];
        for o in 0..self.out_dim {
            for r in 0..n {
                col[r] = zw[r * self.out_dim + o];
            }
            let xp = sw.forward(&col); // x' = P x   (Eq. 4)
            let yp = arr.mvm(&xp); //      y' = A' x' (crossbar pass)
            let y = sw.inverse(&yp); //    y = Pᵀ y'  (Eq. 6)
            for r in 0..n {
                out[r * self.out_dim + o] = y[r];
            }
        }
        self.activate(&mut out);
        Ok(out)
    }
}

/// Max absolute elementwise difference — agreement metric for the example.
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crossbar::place;
    use crate::graph::{synth, GridSummary};
    use crate::reorder::{reorder, Reordering};
    use crate::scheme::Scheme;

    #[test]
    fn normalization_rows_bounded() {
        let a = synth::qm7_like(5828);
        let nrm = normalized_adjacency(&a);
        assert_eq!(nrm.nnz(), a.nnz() + a.rows); // self loops added
        // spectral norm of sym-normalized adjacency is <= 1; cheap proxy:
        // every entry within (0, 1]
        for r in 0..nrm.rows {
            for &v in nrm.row_vals(r) {
                assert!(v > 0.0 && v <= 1.0 + 1e-12);
            }
        }
        assert!(nrm.is_symmetric());
    }

    #[test]
    fn crossbar_path_matches_dense_on_complete_coverage() {
        let a = synth::qm7_like(5828);
        let nrm = normalized_adjacency(&a);
        let r = reorder(&nrm, Reordering::CuthillMckee);
        let g = GridSummary::new(&r.matrix, 2);
        let scheme = Scheme { diag_len: vec![g.n], fill_len: vec![] };
        let arr = place(&r.matrix, &g, &scheme).unwrap();
        let sw = SwitchCircuit::new(r.perm.clone());
        let layer = GcnLayer::random(6, 4, true, 1);
        let mut rng = Pcg64::seed_from_u64(2);
        let z: Vec<f64> = (0..22 * 6).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let dense = layer.forward_dense(&nrm, &z);
        let xbar = layer.forward_crossbar(&arr, &sw, &z).unwrap();
        let diff = max_abs_diff(&dense, &xbar);
        assert!(diff < 1e-6, "dense vs crossbar diff {diff}");
    }

    #[test]
    fn relu_applied() {
        let a = synth::qm7_like(5828);
        let nrm = normalized_adjacency(&a);
        let layer = GcnLayer::random(3, 3, true, 7);
        let mut rng = Pcg64::seed_from_u64(3);
        let z: Vec<f64> = (0..22 * 3).map(|_| rng.uniform(-2.0, 2.0)).collect();
        let out = layer.forward_dense(&nrm, &z);
        assert!(out.iter().all(|&v| v >= 0.0));
        let lin = GcnLayer { relu: false, ..layer };
        let out2 = lin.forward_dense(&nrm, &z);
        assert!(out2.iter().any(|&v| v < 0.0));
    }

    use crate::util::rng::Pcg64;
}
