//! Backprop-through-time for the L2 controller: a teacher-forced forward
//! pass that retains per-step caches, followed by the exact reverse-mode
//! sweep — fused LSTM gates, per-step FC heads, log-softmax, the Algo. 1
//! double-step with fill masking, and the optional BiLSTM auxiliary pass.
//!
//! The forward math is shared with the [`crate::agent::lstm`] mirror
//! ([`LstmCell`]/[`head`]/[`log_softmax`]), so a teacher-forced
//! [`episode_gradient`] reproduces the mirror's `logp`/`entropy` exactly;
//! the backward sweep is validated against central finite differences of
//! the mirror forward in this module's property tests.
//!
//! Loss convention (matching `model.train_step`): the caller passes the
//! per-episode coefficients of `L_b = coef_logp · logp_b + coef_ent · H_b`
//! — for REINFORCE with a batch of B episodes, `coef_logp = -adv_b / B`
//! and `coef_ent = -ent_coef / B`, so summing episode gradients yields
//! d/dθ of `-mean(adv · logp) - ent_coef · mean(H)`.

use crate::agent::lstm::{head, head_backward, log_softmax, LstmCell, LstmStepCache, Params};
use crate::agent::native::ParamLayout;
use crate::runtime::manifest::ControllerEntry;

/// Per-step retained state of the teacher-forced forward pass.
struct StepRec {
    cache1: LstmStepCache,
    lsm_d: Vec<f32>,
    inp_d: Vec<f32>,
    /// present only when the fill branch executed (fill head exists and
    /// the diagonal action was 0): (cache2, lsm_f, inp_f)
    fill: Option<(LstmStepCache, Vec<f32>, Vec<f32>)>,
}

/// Gradient of `coef_logp · logp + coef_ent · entropy` for one episode,
/// flat in ABI order, plus the forward scalars.
pub struct EpisodeGrad {
    pub grad: Vec<f32>,
    pub logp: f32,
    pub entropy: f32,
}

/// d(loss)/d(logits) for one head decision under the log-softmax policy:
/// `d logp_a / dl_j = δ_aj − p_j` and `dH/dl_j = −p_j (log p_j + H)`.
fn dlogits(lsm: &[f32], action: usize, coef_logp: f32, coef_ent: f32) -> Vec<f32> {
    let h_t: f32 = -lsm.iter().map(|&l| l.exp() * l).sum::<f32>();
    lsm.iter()
        .enumerate()
        .map(|(j, &l)| {
            let p = l.exp();
            let ind = if j == action { 1.0 } else { 0.0 };
            coef_logp * (ind - p) - coef_ent * p * (l + h_t)
        })
        .collect()
}

/// Teacher-forced forward + full BPTT for one episode.
pub fn episode_gradient(
    entry: &ControllerEntry,
    params: &Params,
    layout: &ParamLayout,
    d_actions: &[i32],
    f_actions: &[i32],
    coef_logp: f32,
    coef_ent: f32,
) -> EpisodeGrad {
    let hn = entry.hidden;
    let t_steps = entry.steps;
    let fill = entry.fill_classes;
    let head_in = if entry.bilstm { 2 * hn } else { hn };
    assert_eq!(d_actions.len(), t_steps, "need T diagonal actions");
    if fill > 0 {
        assert_eq!(f_actions.len(), t_steps, "need T fill slots");
    }

    let get = |name: &str| -> &[f32] {
        params
            .get(name)
            .unwrap_or_else(|| panic!("missing param {name}"))
    };
    let cell = LstmCell::new(get("lstm_w"), get("lstm_b"), hn);
    let fc_d_w = get("fc_d_w");
    let fc_d_b = get("fc_d_b");
    let (fc_f_w, fc_f_b): (&[f32], &[f32]) = if fill > 0 {
        (get("fc_f_w"), get("fc_f_b"))
    } else {
        (&[], &[])
    };

    // ---- BiLSTM auxiliary pass (processed in reverse time order) --------
    let (hb, bwd_caches): (Vec<Vec<f32>>, Vec<LstmStepCache>) = if entry.bilstm {
        let emb = get("bwd_emb");
        let bwd_cell = LstmCell::new(get("bwd_w"), get("bwd_b"), hn);
        let mut h = vec![0.0f32; hn];
        let mut c = vec![0.0f32; hn];
        let mut hb = vec![Vec::new(); t_steps];
        let mut caches = Vec::with_capacity(t_steps);
        for t in (0..t_steps).rev() {
            let mut xh = emb[t * hn..(t + 1) * hn].to_vec();
            xh.extend_from_slice(&h);
            let (h2, cache) = bwd_cell.step_cached(xh, c);
            h = h2;
            c = cache.c.clone();
            hb[t] = h.clone();
            caches.push(cache);
        }
        caches.reverse(); // caches[t] now belongs to decision point t
        (hb, caches)
    } else {
        (Vec::new(), Vec::new())
    };

    // ---- teacher-forced forward with caches -----------------------------
    let mut x = get("x0").to_vec();
    let mut h = vec![0.0f32; hn];
    let mut c = vec![0.0f32; hn];
    let mut logp = 0.0f32;
    let mut entropy = 0.0f32;
    let mut steps: Vec<StepRec> = Vec::with_capacity(t_steps);

    for t in 0..t_steps {
        let mut xh1 = x.clone();
        xh1.extend_from_slice(&h);
        let (h1, cache1) = cell.step_cached(xh1, c.clone());
        let c1 = cache1.c.clone();
        let inp_d: Vec<f32> = if entry.bilstm {
            h1.iter().chain(hb[t].iter()).cloned().collect()
        } else {
            h1.clone()
        };
        let logits_d = head(
            &inp_d,
            &fc_d_w[t * head_in * 2..(t + 1) * head_in * 2],
            &fc_d_b[t * 2..(t + 1) * 2],
            2,
        );
        let lsm_d = log_softmax(&logits_d);
        logp += lsm_d[d_actions[t] as usize];
        entropy -= lsm_d.iter().map(|&l| l.exp() * l).sum::<f32>();

        let mut rec = StepRec {
            cache1,
            lsm_d,
            inp_d,
            fill: None,
        };
        if fill > 0 && d_actions[t] == 0 {
            // fill branch executes: second LSTM step fed its own output
            let mut xh2 = h1.clone();
            xh2.extend_from_slice(&h1);
            let (h2, cache2) = cell.step_cached(xh2, c1);
            let c2 = cache2.c.clone();
            let inp_f: Vec<f32> = if entry.bilstm {
                h2.iter().chain(hb[t].iter()).cloned().collect()
            } else {
                h2.clone()
            };
            let logits_f = head(
                &inp_f,
                &fc_f_w[t * head_in * fill..(t + 1) * head_in * fill],
                &fc_f_b[t * fill..(t + 1) * fill],
                fill,
            );
            let lsm_f = log_softmax(&logits_f);
            logp += lsm_f[f_actions[t] as usize];
            entropy -= lsm_f.iter().map(|&l| l.exp() * l).sum::<f32>();
            rec.fill = Some((cache2, lsm_f, inp_f));
            h = h2;
            c = c2;
        } else {
            // d == 1 (or no fill head): the discarded fill step — if any —
            // contributes neither loss nor recurrence, so it needs no cache
            h = h1;
            c = c1;
        }
        x = h.clone();
        steps.push(rec);
    }

    // ---- reverse sweep --------------------------------------------------
    let zeros = |n: usize| vec![0.0f32; n];
    let mut gx0 = zeros(hn);
    let mut glstm_w = zeros(2 * hn * 4 * hn);
    let mut glstm_b = zeros(4 * hn);
    let mut gfc_d_w = zeros(t_steps * head_in * 2);
    let mut gfc_d_b = zeros(t_steps * 2);
    let mut gfc_f_w = zeros(t_steps * head_in * fill);
    let mut gfc_f_b = zeros(t_steps * fill);
    let mut gbwd_emb = zeros(if entry.bilstm { t_steps * hn } else { 0 });
    let mut gbwd_w = zeros(if entry.bilstm { 2 * hn * 4 * hn } else { 0 });
    let mut gbwd_b = zeros(if entry.bilstm { 4 * hn } else { 0 });
    let mut dhb: Vec<Vec<f32>> = if entry.bilstm {
        (0..t_steps).map(|_| zeros(hn)).collect()
    } else {
        Vec::new()
    };

    // dh/dc: gradients w.r.t. the state after step t (both zero at t = T-1
    // since the final state feeds nothing)
    let mut dh = zeros(hn);
    let mut dc = zeros(hn);
    for (t, rec) in steps.iter().enumerate().rev() {
        // through the fill branch first (it sits between h1 and the state)
        let (mut dh1, dc1) = if let Some((cache2, lsm_f, inp_f)) = &rec.fill {
            let dl_f = dlogits(lsm_f, f_actions[t] as usize, coef_logp, coef_ent);
            let mut dinp_f = zeros(head_in);
            head_backward(
                inp_f,
                &fc_f_w[t * head_in * fill..(t + 1) * head_in * fill],
                &dl_f,
                &mut gfc_f_w[t * head_in * fill..(t + 1) * head_in * fill],
                &mut gfc_f_b[t * fill..(t + 1) * fill],
                &mut dinp_f,
            );
            let mut dh2 = dh.clone();
            for j in 0..hn {
                dh2[j] += dinp_f[j];
            }
            if entry.bilstm {
                for j in 0..hn {
                    dhb[t][j] += dinp_f[hn + j];
                }
            }
            let (dxh2, dc1) = cell.backward(cache2, &dh2, &dc, &mut glstm_w, &mut glstm_b);
            // xh2 = [h1, h1]: both halves flow back into h1
            let mut dh1 = zeros(hn);
            for j in 0..hn {
                dh1[j] = dxh2[j] + dxh2[hn + j];
            }
            (dh1, dc1)
        } else {
            (dh.clone(), dc.clone())
        };
        // diagonal head at t reads h1
        let dl_d = dlogits(&rec.lsm_d, d_actions[t] as usize, coef_logp, coef_ent);
        let mut dinp_d = zeros(head_in);
        head_backward(
            &rec.inp_d,
            &fc_d_w[t * head_in * 2..(t + 1) * head_in * 2],
            &dl_d,
            &mut gfc_d_w[t * head_in * 2..(t + 1) * head_in * 2],
            &mut gfc_d_b[t * 2..(t + 1) * 2],
            &mut dinp_d,
        );
        for j in 0..hn {
            dh1[j] += dinp_d[j];
        }
        if entry.bilstm {
            for j in 0..hn {
                dhb[t][j] += dinp_d[hn + j];
            }
        }
        let (dxh1, dc_prev) = cell.backward(&rec.cache1, &dh1, &dc1, &mut glstm_w, &mut glstm_b);
        if t == 0 {
            // x_0 is the learned initial input; h_{-1}/c_{-1} are constants
            for j in 0..hn {
                gx0[j] += dxh1[j];
            }
            dh = zeros(hn);
        } else {
            // x_t = h_{t-1}: both halves of xh1 flow back into h_{t-1}
            for j in 0..hn {
                dh[j] = dxh1[j] + dxh1[hn + j];
            }
        }
        dc = dc_prev;
    }

    // ---- BiLSTM BPTT (reverse of its reverse-time processing order) -----
    if entry.bilstm {
        let bwd_cell = LstmCell::new(get("bwd_w"), get("bwd_b"), hn);
        let mut dh_b = zeros(hn);
        let mut dc_b = zeros(hn);
        for t in 0..t_steps {
            for j in 0..hn {
                dh_b[j] += dhb[t][j];
            }
            let (dxh, dc_prev) =
                bwd_cell.backward(&bwd_caches[t], &dh_b, &dc_b, &mut gbwd_w, &mut gbwd_b);
            for j in 0..hn {
                gbwd_emb[t * hn + j] += dxh[j];
            }
            // the carry flows to the step processed before this one, i.e.
            // decision point t+1
            dh_b = dxh[hn..].to_vec();
            dc_b = dc_prev;
        }
    }

    // ---- flatten into ABI order -----------------------------------------
    let mut grad = layout.zeros();
    for spec in &entry.params {
        let src: &[f32] = match spec.name.as_str() {
            "x0" => &gx0,
            "lstm_w" => &glstm_w,
            "lstm_b" => &glstm_b,
            "bwd_emb" => &gbwd_emb,
            "bwd_w" => &gbwd_w,
            "bwd_b" => &gbwd_b,
            "fc_d_w" => &gfc_d_w,
            "fc_d_b" => &gfc_d_b,
            "fc_f_w" => &gfc_f_w,
            "fc_f_b" => &gfc_f_b,
            other => panic!("unknown param {other} in native gradient"),
        };
        grad[layout.range(&spec.name)].copy_from_slice(src);
    }

    EpisodeGrad {
        grad,
        logp,
        entropy,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::lstm::{forward, Select};
    use crate::agent::params::init_params;
    use crate::util::propcheck::check;
    use crate::util::rng::Pcg64;

    /// The scalar the gradient is taken of, via the *mirror* forward pass
    /// (an independent code path from the cached forward in this module).
    fn loss_of(
        entry: &ControllerEntry,
        params: &Params,
        d: &[i32],
        f: &[i32],
        coef_logp: f32,
        coef_ent: f32,
    ) -> f32 {
        let ep = forward(entry, params, Select::Teacher { d, f });
        coef_logp * ep.logp + coef_ent * ep.entropy
    }

    fn random_entry(rng: &mut Pcg64) -> ControllerEntry {
        let n = 3 + rng.below(4) as usize; // 3..=6 grid cells -> T = 2..=5
        let hidden = 3 + rng.below(4) as usize; // 3..=6
        let fill = [0usize, 2, 3, 4][rng.below(4) as usize];
        let bilstm = rng.bool(0.5);
        ControllerEntry::from_dims("fdcheck", n, hidden, fill, 1, bilstm)
    }

    fn random_actions(rng: &mut Pcg64, entry: &ControllerEntry) -> (Vec<i32>, Vec<i32>) {
        let d: Vec<i32> = (0..entry.steps).map(|_| rng.below(2) as i32).collect();
        let f: Vec<i32> = (0..entry.steps)
            .map(|_| rng.below(entry.fill_classes.max(1) as u64) as i32)
            .collect();
        (d, f)
    }

    #[test]
    fn cached_forward_matches_mirror_scalars() {
        let mut rng = Pcg64::seed_from_u64(77);
        for _ in 0..20 {
            let entry = random_entry(&mut rng);
            let params = init_params(&entry, rng.next_u64());
            let layout = ParamLayout::new(&entry);
            let (d, f) = random_actions(&mut rng, &entry);
            let eg = episode_gradient(&entry, &params, &layout, &d, &f, 1.0, 0.0);
            let ep = forward(&entry, &params, Select::Teacher { d: &d, f: &f });
            assert!(
                (eg.logp - ep.logp).abs() < 1e-5,
                "{}: cached logp {} vs mirror {}",
                entry.name,
                eg.logp,
                ep.logp
            );
            assert!(
                (eg.entropy - ep.entropy).abs() < 1e-4,
                "cached entropy {} vs mirror {}",
                eg.entropy,
                ep.entropy
            );
        }
    }

    #[test]
    fn gradient_matches_finite_difference_property() {
        // Central finite differences of the mirror forward vs the analytic
        // BPTT gradient, over random small controllers (with and without
        // fill heads and BiLSTM). Checks ~24 random coordinates per case.
        check("bptt_finite_difference", 12, |rng| {
            let entry = random_entry(rng);
            let params = init_params(&entry, rng.next_u64());
            let layout = ParamLayout::new(&entry);
            let (d, f) = random_actions(rng, &entry);
            let coef_logp = -1.0 + rng.uniform(-0.5, 0.5) as f32;
            let coef_ent = -0.05 * rng.f32();
            let eg = episode_gradient(&entry, &params, &layout, &d, &f, coef_logp, coef_ent);

            let eps = 1e-2f32;
            for _ in 0..24 {
                let flat = rng.below(layout.total as u64) as usize;
                let (name, idx) = layout.locate(flat);
                let name = name.to_string();
                let mut plus = params.clone();
                plus.get_mut(&name).unwrap()[idx] += eps;
                let mut minus = params.clone();
                minus.get_mut(&name).unwrap()[idx] -= eps;
                let lp = loss_of(&entry, &plus, &d, &f, coef_logp, coef_ent);
                let lm = loss_of(&entry, &minus, &d, &f, coef_logp, coef_ent);
                let fd = (lp - lm) / (2.0 * eps);
                let an = eg.grad[flat];
                let tol = 2e-3 + 2e-2 * fd.abs().max(an.abs());
                if (fd - an).abs() > tol {
                    return Err(format!(
                        "{} [{name}:{idx}] fd {fd} vs analytic {an} (tol {tol}, \
                         hidden {}, T {}, fill {}, bilstm {})",
                        entry.name, entry.hidden, entry.steps, entry.fill_classes, entry.bilstm
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn directional_derivative_matches_finite_difference() {
        // Aggregate check: g·u vs the central difference along a random
        // direction u — exercises every coordinate at once.
        check("bptt_directional", 8, |rng| {
            let entry = random_entry(rng);
            let params = init_params(&entry, rng.next_u64());
            let layout = ParamLayout::new(&entry);
            let (d, f) = random_actions(rng, &entry);
            let (cl, ce) = (-0.8f32, -0.01f32);
            let eg = episode_gradient(&entry, &params, &layout, &d, &f, cl, ce);

            // random unit direction in flat ABI order
            let mut u: Vec<f32> = (0..layout.total).map(|_| rng.normal() as f32).collect();
            let norm = u.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-12);
            for x in &mut u {
                *x /= norm;
            }
            let eps = 1e-2f32;
            let perturb = |sign: f32| -> Params {
                let mut p = params.clone();
                for spec in &entry.params {
                    let r = layout.range(&spec.name);
                    let dst = p.get_mut(&spec.name).unwrap();
                    for (x, &du) in dst.iter_mut().zip(u[r].iter()) {
                        *x += sign * eps * du;
                    }
                }
                p
            };
            let lp = loss_of(&entry, &perturb(1.0), &d, &f, cl, ce);
            let lm = loss_of(&entry, &perturb(-1.0), &d, &f, cl, ce);
            let fd = (lp - lm) / (2.0 * eps);
            let an: f32 = eg.grad.iter().zip(u.iter()).map(|(g, du)| g * du).sum();
            let tol = 2e-3 + 1e-2 * fd.abs().max(an.abs());
            if (fd - an).abs() > tol {
                return Err(format!(
                    "{}: directional fd {fd} vs analytic {an} (tol {tol})",
                    entry.name
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn entropy_coefficient_changes_gradient() {
        // the entropy term must actually flow: gradients with and without
        // coef_ent differ
        let entry = ControllerEntry::from_dims("ent", 5, 4, 4, 1, false);
        let params = init_params(&entry, 3);
        let layout = ParamLayout::new(&entry);
        let d = vec![0, 1, 0, 1];
        let f = vec![1, 0, 3, 2];
        let a = episode_gradient(&entry, &params, &layout, &d, &f, -1.0, 0.0);
        let b = episode_gradient(&entry, &params, &layout, &d, &f, -1.0, -0.1);
        assert_ne!(a.grad, b.grad);
        assert_eq!(a.logp, b.logp);
    }
}
