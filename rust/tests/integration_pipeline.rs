//! Cross-module integration: config → runner → metrics → checkpoint →
//! eval, plus baselines and the crossbar deployment path, end to end.

use autogmap::baselines;
use autogmap::coordinator::config::{Dataset, ExperimentConfig};
use autogmap::coordinator::dataset::prepare;
use autogmap::coordinator::metrics::read_csv;
use autogmap::coordinator::{run_experiment, RunnerOptions};
use autogmap::crossbar::switch::SwitchCircuit;
use autogmap::crossbar::{cost::CostModel, place};
use autogmap::graph::GridSummary;
use autogmap::reorder::Reordering;
use autogmap::runtime::Runtime;
use autogmap::scheme::{evaluate, FillRule, RewardWeights};

fn runtime() -> Option<Runtime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        return None;
    }
    Some(Runtime::new(dir).expect("runtime"))
}

fn qm7_config(tmp: &std::path::Path, epochs: usize) -> (ExperimentConfig, RunnerOptions) {
    let cfg = ExperimentConfig {
        name: "it_qm7".into(),
        dataset: Dataset::Qm7 { seed: 5828 },
        grid: 2,
        reordering: Reordering::CuthillMckee,
        controller: "qm7_dyn4".into(),
        fill_rule: FillRule::Dynamic { grades: 4 },
        reward_a: 0.8,
        lr: 0.02,
        ent_coef: 0.002,
        baseline_decay: 0.95,
        epochs,
        seed: 17,
        log_every: 10,
    };
    let opts = RunnerOptions {
        out_root: tmp.to_path_buf(),
        checkpoint_every: 50,
        verbose: false,
        keep_history: true,
        ..Default::default()
    };
    (cfg, opts)
}

#[test]
fn full_run_writes_metrics_summary_and_checkpoint() {
    let Some(rt) = runtime() else { return };
    let tmp = std::env::temp_dir().join("autogmap_it_run");
    let _ = std::fs::remove_dir_all(&tmp);
    let (cfg, opts) = qm7_config(&tmp, 120);
    let result = run_experiment(Some(&rt), &cfg, &opts).unwrap();

    // metrics CSV parses and is monotone in epoch
    let cols = read_csv(&result.run_dir.join("metrics.csv")).unwrap();
    let epochs: &Vec<f64> = &cols[0].1;
    assert!(!epochs.is_empty());
    assert!(epochs.windows(2).all(|w| w[0] < w[1]));

    // summary exists and matches the result
    let summary = std::fs::read_to_string(result.run_dir.join("summary.json")).unwrap();
    assert!(summary.contains("it_qm7"));

    // config echo
    let cfg_echo = ExperimentConfig::load(&result.run_dir.join("config.json")).unwrap();
    assert_eq!(cfg_echo.controller, "qm7_dyn4");

    // checkpoint restores into a fresh trainer and greedy-decodes
    let manifest = rt.manifest().unwrap();
    let entry = manifest.config("qm7_dyn4").unwrap().clone();
    let mut trainer = autogmap::agent::Trainer::new(
        &rt,
        entry,
        autogmap::agent::TrainOptions {
            fill_rule: FillRule::Dynamic { grades: 4 },
            weights: RewardWeights::new(0.8),
            ..Default::default()
        },
    )
    .unwrap();
    trainer.restore(&result.run_dir.join("checkpoint.json")).unwrap();
    assert!(trainer.epoch > 0);
    let (scheme, eval) = trainer.greedy(&result.workload.grid).unwrap();
    scheme.validate(result.workload.grid.n).unwrap();
    assert!(eval.reward.is_finite());
}

#[test]
fn trained_scheme_beats_vanilla_fill_on_qm7() {
    // The paper's core claim in miniature: RL + dynamic fill reaches
    // complete coverage at lower area than static Vanilla+Fill.
    let Some(rt) = runtime() else { return };
    let tmp = std::env::temp_dir().join("autogmap_it_claim");
    let (cfg, opts) = qm7_config(&tmp, 2500);
    let result = run_experiment(Some(&rt), &cfg, &opts).unwrap();
    let best = result.best.as_ref().expect("complete coverage not reached");
    assert_eq!(best.eval.coverage_ratio, 1.0);

    // Vanilla+Fill block 6 fill 6 reaches C=1 at area 0.62 (paper);
    // evaluate on the same reordered matrix at matrix-unit grid.
    let g1 = GridSummary::new(&result.workload.reordered.matrix, 1);
    let vf = baselines::vanilla_fill(22, 6, 6);
    let e_vf = evaluate(&vf, &g1, RewardWeights::new(0.8));
    assert_eq!(e_vf.coverage_ratio, 1.0);
    assert!(
        best.eval.area_ratio < e_vf.area_ratio,
        "RL area {} must beat Vanilla+Fill {}",
        best.eval.area_ratio,
        e_vf.area_ratio
    );
}

#[test]
fn deployed_best_scheme_computes_y_eq_ax() {
    let Some(rt) = runtime() else { return };
    let tmp = std::env::temp_dir().join("autogmap_it_deploy");
    let (cfg, opts) = qm7_config(&tmp, 1500);
    let result = run_experiment(Some(&rt), &cfg, &opts).unwrap();
    let Some(best) = &result.best else {
        panic!("no complete-coverage scheme")
    };
    let w = &result.workload;
    let arr = place(&w.reordered.matrix, &w.grid, &best.scheme).unwrap();
    let sw = SwitchCircuit::new(w.reordered.perm.clone());
    let x: Vec<f64> = (0..22).map(|i| (i as f64) * 0.5 - 5.0).collect();
    let y = sw.inverse(&arr.mvm(&sw.forward(&x)));
    let want = w.original.spmv(&x);
    for (a, b) in y.iter().zip(want.iter()) {
        assert!((a - b).abs() < 1e-9, "{a} vs {b}");
    }
    // cost model sees fewer cells than the monolithic crossbar
    let est = CostModel::default().estimate(&arr, sw.crossover_count());
    assert!(est.cells < 22 * 22);
}

#[test]
fn dataset_prepare_rejects_mismatched_controller() {
    let Some(rt) = runtime() else { return };
    let cfg = ExperimentConfig {
        name: "bad".into(),
        dataset: Dataset::Qm7 { seed: 5828 },
        grid: 2,
        reordering: Reordering::CuthillMckee,
        controller: "qh882_dyn6".into(), // wrong N for qm7@grid2
        fill_rule: FillRule::Dynamic { grades: 6 },
        reward_a: 0.8,
        lr: 0.01,
        ent_coef: 0.0,
        baseline_decay: 0.95,
        epochs: 1,
        seed: 0,
        log_every: 0,
    };
    let err = run_experiment(Some(&rt), &cfg, &RunnerOptions::default());
    assert!(err.is_err());
    let msg = format!("{:#}", err.err().unwrap());
    assert!(msg.contains("expects"), "unhelpful error: {msg}");
}

#[test]
fn rust_mirror_and_workload_agree_on_reward_semantics() {
    // sample with the pure-Rust mirror, evaluate, and confirm rewards stay
    // in [0, 1] and parsed schemes always validate.
    let Some(rt) = runtime() else { return };
    let manifest = rt.manifest().unwrap();
    let entry = manifest.config("qm7_dyn4").unwrap().clone();
    let params = autogmap::agent::params::init_params(&entry, 4);
    let cfg = ExperimentConfig {
        name: "mirror".into(),
        dataset: Dataset::Qm7 { seed: 5828 },
        grid: 2,
        reordering: Reordering::CuthillMckee,
        controller: "qm7_dyn4".into(),
        fill_rule: FillRule::Dynamic { grades: 4 },
        reward_a: 0.7,
        lr: 0.01,
        ent_coef: 0.0,
        baseline_decay: 0.95,
        epochs: 1,
        seed: 0,
        log_every: 0,
    };
    let w = prepare(&cfg).unwrap();
    let mut rng = autogmap::util::rng::Pcg64::seed_from_u64(9);
    for _ in 0..50 {
        let ep = autogmap::agent::lstm::forward(
            &entry,
            &params,
            autogmap::agent::lstm::Select::Sample(&mut rng),
        );
        let d: Vec<u8> = ep.d_actions.iter().map(|&x| x as u8).collect();
        let f: Vec<usize> = ep.f_actions.iter().map(|&x| x as usize).collect();
        let s = autogmap::scheme::parse_actions(w.grid.n, &d, &f, cfg.fill_rule);
        s.validate(w.grid.n).unwrap();
        let e = evaluate(&s, &w.grid, cfg.weights());
        assert!((0.0..=1.0).contains(&e.reward), "reward {}", e.reward);
    }
}
