//! Composite execution: compile a [`CompositeScheme`] into per-window
//! [`ExecPlan`]s, merge them into one fleet-servable schedule, and serve
//! y = Ax exactly by adding the digital spill (the nnz outside every
//! mapped rect) back on the host.
//!
//! Exactness contract: every non-zero is either inside exactly one mapped
//! tile (rects are disjoint; all-zero tiles elide nothing but zeros) or in
//! the spill CSR — never both, never neither — so a composite MVM equals
//! the dense oracle up to floating-point summation order, and *exactly*
//! (bit-identical) whenever products round to nothing, e.g. adjacency
//! weights with integer inputs. [`CompositePlan`] implements the unified
//! [`crate::engine::Servable`] trait, so the one generic
//! [`crate::engine::BatchExecutor`] serves it either per-request (one
//! worker per request, plan band order then spill row-order) or
//! band-sharded (disjoint row spans across workers within a request, each
//! span running mapped tiles then its spill rows through the multi-RHS
//! kernel); each output row is produced by one worker in one fixed order,
//! so both modes are bit-identical for any worker count and batch size.
//! (The pre-facade `CompositeExecutor` alias is gone — construct
//! `BatchExecutor::new(plan, workers)` directly, or better, go through
//! `crate::api::Deployment`.)
//!
//! Spill extraction builds per-grid-row *interval lists* of covered
//! columns (sorted, merged) instead of a dense n×n covered bitmap, so its
//! memory scales with the composite's rect count — not with the square of
//! a 100k-node graph's grid.

use crate::engine::batch::{Servable, ServeStats};
use crate::engine::plan::{compile_rects, merge_plans, ExecPlan};
use crate::graph::{Csr, GridSummary};
use crate::scheme::CompositeScheme;
use anyhow::{anyhow, Result};

/// A compiled composite mapping: the merged crossbar schedule plus the
/// digital remainder.
#[derive(Clone, Debug)]
pub struct CompositePlan {
    /// merged tile schedule over the full matrix (window plans merged in
    /// slice order and band-sorted, programs deduplicated across windows)
    pub plan: ExecPlan,
    /// off-plan entries, served from sparse digital storage
    pub spill: Csr,
    /// per-window placed-tile counts (slice order), for fleet reporting
    pub window_tiles: Vec<usize>,
}

/// Compile every slice of a composite to its own [`ExecPlan`] and merge.
pub fn compile_composite(
    m: &Csr,
    g: &GridSummary,
    comp: &CompositeScheme,
) -> Result<CompositePlan> {
    comp.validate(g.n).map_err(|e| anyhow!("invalid composite: {e}"))?;
    let mut parts = Vec::with_capacity(comp.slices.len());
    let mut window_tiles = Vec::with_capacity(comp.slices.len());
    for s in &comp.slices {
        let p = compile_rects(m, g, &s.rects())?;
        window_tiles.push(p.tiles.len());
        parts.push(p);
    }
    let plan = merge_plans(&parts)?;

    // per-grid-row covered column intervals (sorted + merged), then the
    // spill CSR: every entry whose grid cell no interval covers
    let n = g.n;
    let mut intervals: Vec<Vec<(u32, u32)>> = vec![Vec::new(); n];
    for s in &comp.slices {
        for r in s.rects() {
            for rr in r.r0..r.r1 {
                intervals[rr].push((r.c0 as u32, r.c1 as u32));
            }
        }
    }
    for iv in &mut intervals {
        iv.sort_unstable();
        let mut merged: Vec<(u32, u32)> = Vec::with_capacity(iv.len().min(8));
        for &(c0, c1) in iv.iter() {
            match merged.last_mut() {
                Some(last) if c0 <= last.1 => last.1 = last.1.max(c1),
                _ => merged.push((c0, c1)),
            }
        }
        *iv = merged;
    }
    let covered = |rr: usize, gc: usize| -> bool {
        let iv = &intervals[rr];
        let gc = gc as u32;
        match iv.partition_point(|&(c0, _)| c0 <= gc) {
            0 => false,
            i => gc < iv[i - 1].1,
        }
    };
    let k = g.grid;
    let mut indptr = Vec::with_capacity(m.rows + 1);
    indptr.push(0usize);
    let mut indices = Vec::new();
    let mut data = Vec::new();
    for r in 0..m.rows {
        let grid_row = r / k;
        for (i, &c) in m.row(r).iter().enumerate() {
            if !covered(grid_row, c / k) {
                indices.push(c);
                data.push(m.row_vals(r)[i]);
            }
        }
        indptr.push(indices.len());
    }
    let spill = Csr {
        rows: m.rows,
        cols: m.cols,
        indptr,
        indices,
        data,
    };
    Ok(CompositePlan {
        plan,
        spill,
        window_tiles,
    })
}

impl CompositePlan {
    /// y = Ax: mapped tiles in plan (band) order, then the spill in
    /// row-major CSR order, accumulated into the same output buffer.
    pub fn mvm_into(&self, x: &[f64], y: &mut Vec<f64>) {
        self.plan.mvm_into(x, y);
        self.spill_rows_into((0, self.spill.rows), x, y);
    }

    /// Accumulate spill rows [span.0, span.1) into `out`, whose index 0 is
    /// matrix row span.0 (scalar CSR row-dot, column order).
    fn spill_rows_into(&self, span: (usize, usize), x: &[f64], out: &mut [f64]) {
        for r in span.0..span.1 {
            let cols = self.spill.row(r);
            if cols.is_empty() {
                continue;
            }
            let vals = self.spill.row_vals(r);
            let mut acc = 0.0f64;
            for (&c, &v) in cols.iter().zip(vals.iter()) {
                acc += v * x[c];
            }
            out[r - span.0] += acc;
        }
    }

    /// Allocating convenience wrapper around [`Self::mvm_into`].
    pub fn mvm(&self, x: &[f64]) -> Vec<f64> {
        let mut y = Vec::new();
        self.mvm_into(x, &mut y);
        y
    }

    /// Non-zeros served by crossbar tiles (cached arena metadata).
    pub fn mapped_nnz(&self) -> u64 {
        self.plan.mapped_nnz()
    }

    /// Non-zeros served digitally.
    pub fn spilled_nnz(&self) -> u64 {
        self.spill.nnz() as u64
    }
}

impl Servable for CompositePlan {
    fn dim(&self) -> usize {
        self.plan.dim
    }

    fn mvm_into(&self, x: &[f64], y: &mut Vec<f64>) {
        CompositePlan::mvm_into(self, x, y)
    }

    fn shard_spans(&self, shards: usize) -> Vec<(usize, usize)> {
        // band boundaries of the merged plan; spill rows follow their
        // span, so every output row still belongs to exactly one worker.
        // Known limitation: spans are balanced on mapped-tile nnz only —
        // a composite whose spill concentrates in one row region loads
        // that span's worker heavier than the weights predict.
        let dim = self.plan.dim;
        if self.plan.bands().is_empty() && shards > 1 && dim > 0 && self.spill.nnz() > 0 {
            // tile-less (spill-dominated) composite: bands offer no split
            // points, but spill rows are independent — split [0, dim)
            // into even chunks so the sharded mode still parallelizes
            let shards = shards.min(dim);
            return (0..shards)
                .map(|s| (s * dim / shards, (s + 1) * dim / shards))
                .collect();
        }
        self.plan.band_spans(shards)
    }

    fn mvm_span_batch(&self, span: (usize, usize), xs: &[Vec<f64>], outs: &mut [Vec<f64>]) {
        self.plan.mvm_span_batch(span, xs, outs);
        for (x, out) in xs.iter().zip(outs.iter_mut()) {
            self.spill_rows_into(span, x, out);
        }
    }

    fn nnz(&self) -> u64 {
        self.mapped_nnz() + self.spilled_nnz()
    }

    fn area_cells(&self) -> u64 {
        self.plan.cells()
    }

    fn stats(&self) -> ServeStats {
        let (kernel_dense, kernel_sparse) = self.plan.kernel_counts();
        let (nnz_dense, nnz_sparse) = self.plan.kernel_nnz();
        ServeStats {
            dim: self.plan.dim,
            tiles: self.plan.tiles.len(),
            programs: self.plan.num_programs(),
            bands: self.plan.bands().len(),
            kernel_dense,
            kernel_sparse,
            nnz_dense,
            nnz_sparse,
            patterns: self.plan.num_patterns(),
            pattern_dedup_hits: self.plan.pattern_dedup_hits(),
            mapped_nnz: self.mapped_nnz(),
            spilled_nnz: self.spilled_nnz(),
            area_cells: self.plan.cells(),
            health: Default::default(),
            delta_updates: 0,
            delta_pending: 0,
            delta_remaps: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::BatchExecutor;
    use crate::graph::synth;
    use crate::scheme::{parse_actions, FillRule, Scheme, WindowSlice};
    use crate::util::propcheck::check;
    use std::sync::Arc;

    fn two_window_composite(n: usize, cut: usize, win: usize) -> CompositeScheme {
        CompositeScheme {
            n,
            slices: vec![
                WindowSlice {
                    win_start: 0,
                    win_end: win,
                    start: 0,
                    end: cut,
                    scheme: Scheme { diag_len: vec![win], fill_len: vec![] },
                    cache_hit: false,
                },
                WindowSlice {
                    win_start: n - win,
                    win_end: n,
                    start: cut,
                    end: n,
                    scheme: Scheme { diag_len: vec![win], fill_len: vec![] },
                    cache_hit: true,
                },
            ],
        }
    }

    #[test]
    fn composite_mvm_matches_spmv_exactly_on_integer_inputs() {
        let m = synth::banded_like(90, 0.92, 4);
        let g = GridSummary::new(&m, 5); // n = 18
        let comp = two_window_composite(18, 9, 12);
        let cp = compile_composite(&m, &g, &comp).unwrap();
        // conservation: mapped + spilled = total
        assert_eq!(cp.mapped_nnz() + cp.spilled_nnz(), m.nnz() as u64);
        assert!(cp.spilled_nnz() > 0, "band entries cross the cut");
        // per-kernel counters partition the mapped side and survive the
        // cross-window merge
        let s = Servable::stats(&cp);
        assert_eq!(s.nnz_dense + s.nnz_sparse, cp.mapped_nnz());
        assert_eq!(s.kernel_dense + s.kernel_sparse, s.programs);
        assert_eq!(s.patterns + s.pattern_dedup_hits, s.kernel_sparse);
        // integer inputs: adjacency products and partial sums are exact,
        // so any accumulation order gives the bit-identical dense answer
        let x: Vec<f64> = (0..90).map(|i| ((i * 11) % 23) as f64 - 11.0).collect();
        assert_eq!(cp.mvm(&x), m.spmv(&x));
    }

    #[test]
    fn executor_is_bit_identical_across_worker_counts_and_modes() {
        let m = synth::banded_like(60, 0.9, 2);
        let g = GridSummary::new(&m, 4); // n = 15
        let comp = two_window_composite(15, 8, 10);
        let cp = Arc::new(compile_composite(&m, &g, &comp).unwrap());
        let xs: Vec<Vec<f64>> = (0..9)
            .map(|s| (0..60).map(|i| ((i + 3 * s) % 13) as f64 - 6.0).collect())
            .collect();
        let want: Vec<Vec<f64>> = xs.iter().map(|x| cp.mvm(x)).collect();
        for workers in [1usize, 2, 8] {
            let exec = BatchExecutor::new(cp.clone(), workers);
            let ys = exec.execute_batch(xs.clone());
            assert_eq!(ys, want, "workers {workers}");
            exec.recycle(ys);
            let ys2 = exec.execute_batch(xs.clone());
            assert_eq!(ys2, want, "workers {workers} with recycled buffers");
            exec.recycle(ys2);
            let ys3 = exec.execute_batch_sharded(xs.clone());
            assert_eq!(ys3, want, "workers {workers} band-sharded");
        }
    }

    #[test]
    fn window_tiles_account_for_every_placed_tile() {
        let m = synth::qh882_like(5);
        let g = GridSummary::new(&m, 32); // n = 28
        let comp = two_window_composite(28, 14, 18);
        let cp = compile_composite(&m, &g, &comp).unwrap();
        assert_eq!(cp.window_tiles.len(), 2);
        assert_eq!(cp.window_tiles.iter().sum::<usize>(), cp.plan.tiles.len());
    }

    #[test]
    fn spill_only_composite_still_shards_and_serves_exactly() {
        // every nnz far off-diagonal, unit-diagonal windows: all tiles
        // elide, the whole matrix is spill — the sharded mode must still
        // split rows across workers and answer exactly
        let dim = 40usize;
        let mut coo = crate::graph::Coo::new(dim, dim);
        for i in 0..dim / 2 {
            coo.push(i, dim - 1 - i, (i + 1) as f64);
        }
        let m = coo.to_csr();
        let g = GridSummary::new(&m, 4); // n = 10
        let n = g.n;
        let unit = |len: usize| Scheme {
            diag_len: vec![1; len],
            fill_len: vec![0; len - 1],
        };
        let comp = CompositeScheme {
            n,
            slices: vec![
                WindowSlice {
                    win_start: 0,
                    win_end: 5,
                    start: 0,
                    end: 5,
                    scheme: unit(5),
                    cache_hit: false,
                },
                WindowSlice {
                    win_start: 5,
                    win_end: n,
                    start: 5,
                    end: n,
                    scheme: unit(n - 5),
                    cache_hit: false,
                },
            ],
        };
        let cp = compile_composite(&m, &g, &comp).unwrap();
        assert_eq!(cp.plan.tiles.len(), 0, "anti-diagonal nnz must all elide");
        assert_eq!(cp.spilled_nnz(), m.nnz() as u64);
        let spans = Servable::shard_spans(&cp, 4);
        assert_eq!(spans.len(), 4, "spill-only composites still split rows");
        assert_eq!(spans[0].0, 0);
        assert_eq!(spans.last().unwrap().1, dim);
        for w in spans.windows(2) {
            assert_eq!(w[0].1, w[1].0);
        }
        let cp = Arc::new(cp);
        let xs: Vec<Vec<f64>> = (0..5)
            .map(|s| (0..dim).map(|i| ((i + s) % 7) as f64 - 3.0).collect())
            .collect();
        let want: Vec<Vec<f64>> = xs.iter().map(|x| m.spmv(x)).collect();
        for workers in [1usize, 4] {
            let exec = BatchExecutor::new(cp.clone(), workers);
            assert_eq!(exec.execute_batch(xs.clone()), want);
            assert_eq!(exec.execute_batch_sharded(xs.clone()), want);
        }
    }

    #[test]
    fn invalid_composite_is_rejected() {
        let m = synth::qm7_like(5828);
        let g = GridSummary::new(&m, 2); // n = 11
        let mut comp = two_window_composite(11, 6, 8);
        comp.slices[1].start = 7; // ownership gap
        assert!(compile_composite(&m, &g, &comp).is_err());
    }

    #[test]
    fn composite_kernels_and_sharding_are_bit_identical_property() {
        // Composite half of the perf-layer acceptance property: across
        // random matrices, window layouts, per-window schemes, kernel
        // mixes, batch sizes, and 1/2/8 workers, every serving path
        // reproduces the scalar composite MVM bit for bit — mapped tiles
        // (dense and sparse kernels) plus the spill CSR.
        check("composite_kernels_bit_identical", 8, |rng| {
            let dim = 40 + rng.below(50) as usize;
            let m = synth::banded_like(dim, 0.88, 2 + rng.below(4) as usize);
            let grid = 3 + rng.below(3) as usize;
            let g = GridSummary::new(&m, grid);
            let n = g.n;
            if n < 4 {
                return Ok(());
            }
            // random 2-3 slice composite with overlapping windows and a
            // random scheme per window
            let cuts = if n >= 6 && rng.below(2) == 1 {
                let c1 = 1 + rng.below(n as u64 / 2) as usize;
                let c2 = c1 + 1 + rng.below((n - c1 - 1) as u64) as usize;
                vec![0, c1, c2, n]
            } else {
                vec![0, 1 + rng.below(n as u64 - 1) as usize, n]
            };
            let ov = rng.below(3) as usize;
            let mut slices = Vec::new();
            for w in cuts.windows(2) {
                let (start, end) = (w[0], w[1]);
                let win_start = start.saturating_sub(ov);
                let win_end = (end + ov).min(n);
                let len = win_end - win_start;
                let scheme = if len >= 2 && rng.below(2) == 1 {
                    let d: Vec<u8> = (0..len - 1).map(|_| rng.below(2) as u8).collect();
                    let f: Vec<usize> = (0..len - 1).map(|_| rng.below(3) as usize).collect();
                    parse_actions(len, &d, &f, FillRule::Dynamic { grades: 3 })
                } else {
                    Scheme { diag_len: vec![len], fill_len: vec![] }
                };
                slices.push(WindowSlice {
                    win_start,
                    win_end,
                    start,
                    end,
                    scheme,
                    cache_hit: false,
                });
            }
            let comp = CompositeScheme { n, slices };
            comp.validate(n)?;
            let cp = compile_composite(&m, &g, &comp).map_err(|e| format!("{e:#}"))?;
            if cp.mapped_nnz() + cp.spilled_nnz() != m.nnz() as u64 {
                return Err("mapped + spilled != total nnz".into());
            }
            let bsz = 1 + rng.below(7) as usize;
            let xs: Vec<Vec<f64>> = (0..bsz)
                .map(|_| (0..dim).map(|_| rng.uniform(-2.0, 2.0)).collect())
                .collect();
            let want: Vec<Vec<f64>> = xs.iter().map(|x| cp.mvm(x)).collect();
            // forced kernel mixes agree exactly on the scalar path
            let mut dense = cp.clone();
            dense.plan.rekernel(0.0);
            let mut sparse = cp.clone();
            sparse.plan.rekernel(f64::INFINITY);
            for ((x, w), i) in xs.iter().zip(want.iter()).zip(0..) {
                if &dense.mvm(x) != w {
                    return Err(format!("dense-kernel composite diverged on request {i}"));
                }
                if &sparse.mvm(x) != w {
                    return Err(format!("sparse-kernel composite diverged on request {i}"));
                }
            }
            // both executor modes at 1/2/8 workers
            let cp = Arc::new(cp);
            for &workers in &[1usize, 2, 8] {
                let exec = BatchExecutor::new(cp.clone(), workers);
                if exec.execute_batch(xs.clone()) != want {
                    return Err(format!("scalar mode diverged at {workers} workers"));
                }
                if exec.execute_batch_sharded(xs.clone()) != want {
                    return Err(format!("sharded mode diverged at {workers} workers"));
                }
            }
            Ok(())
        });
    }
}
