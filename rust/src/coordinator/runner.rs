//! Experiment runner: ties dataset + trainer + metrics together for one
//! full training run (Algo. 3's outer loop with logging/checkpointing).

use super::config::ExperimentConfig;
use super::dataset::{prepare, Workload};
use super::metrics::{write_summary, MetricsLog};
use crate::agent::{BestSolution, EpochStats, TrainOptions, Trainer};
use crate::runtime::Runtime;
use anyhow::{Context, Result};
use std::path::PathBuf;
use std::time::Instant;

/// Result of a completed run.
pub struct RunResult {
    pub best: Option<BestSolution>,
    /// best-by-reward regardless of coverage (paper's diag-only rows)
    pub best_reward: Option<BestSolution>,
    pub last: Option<EpochStats>,
    pub history: Vec<EpochStats>,
    pub workload: Workload,
    pub run_dir: PathBuf,
    pub wall_seconds: f64,
}

/// Options controlling run output.
#[derive(Clone, Debug)]
pub struct RunnerOptions {
    /// directory to place runs/<name>/ under
    pub out_root: PathBuf,
    /// write a checkpoint every N epochs (0 = never)
    pub checkpoint_every: usize,
    /// echo progress lines to stdout
    pub verbose: bool,
    /// keep the full in-memory history (figures); CSV is always written
    pub keep_history: bool,
}

impl Default for RunnerOptions {
    fn default() -> Self {
        RunnerOptions {
            out_root: PathBuf::from("runs"),
            checkpoint_every: 0,
            verbose: false,
            keep_history: true,
        }
    }
}

/// Execute one experiment end-to-end.
pub fn run_experiment(
    rt: &Runtime,
    cfg: &ExperimentConfig,
    opts: &RunnerOptions,
) -> Result<RunResult> {
    let manifest = rt.manifest()?;
    let entry = manifest.config(&cfg.controller)?.clone();
    let workload = prepare(cfg)?;
    anyhow::ensure!(
        workload.grid.n == entry.n,
        "dataset {} at grid {} yields {} cells; controller {} expects {} — \
         pick a matching controller config",
        cfg.dataset.label(),
        cfg.grid,
        workload.grid.n,
        entry.name,
        entry.n
    );

    let run_dir = opts.out_root.join(&cfg.name);
    std::fs::create_dir_all(&run_dir)
        .with_context(|| format!("creating {}", run_dir.display()))?;
    std::fs::write(run_dir.join("config.json"), cfg.to_json().to_pretty())?;
    let mut log = MetricsLog::create(&run_dir)?;

    let topts = TrainOptions {
        lr: cfg.lr,
        ent_coef: cfg.ent_coef,
        baseline_decay: cfg.baseline_decay,
        weights: cfg.weights(),
        fill_rule: cfg.fill_rule,
        seed: cfg.seed,
    };
    let mut trainer = Trainer::new(rt, entry, topts)?;

    let t0 = Instant::now();
    let mut history = Vec::new();
    let mut last: Option<EpochStats> = None;
    for e in 0..cfg.epochs {
        let stats = trainer.epoch(&workload.grid)?;
        let should_log =
            cfg.log_every > 0 && (e % cfg.log_every == 0 || e + 1 == cfg.epochs);
        if should_log {
            log.log(&stats)?;
            if opts.verbose {
                println!(
                    "[{}] epoch {:>6}  R̄={:.4}  C̄={:.4}  Ā={:.4}  complete={:.0}%  best_area={}",
                    cfg.name,
                    stats.epoch,
                    stats.mean_reward,
                    stats.mean_coverage,
                    stats.mean_area,
                    stats.frac_complete * 100.0,
                    trainer
                        .best
                        .as_ref()
                        .map(|b| format!("{:.4}", b.eval.area_ratio))
                        .unwrap_or_else(|| "-".into()),
                );
            }
        }
        if opts.checkpoint_every > 0 && (e + 1) % opts.checkpoint_every == 0 {
            trainer.sync_host()?;
            crate::agent::params::save_checkpoint(
                &run_dir.join("checkpoint.json"),
                &trainer.entry,
                &trainer.params,
                &trainer.opt,
                trainer.epoch,
                trainer.baseline,
            )?;
        }
        if opts.keep_history {
            history.push(stats.clone());
        }
        last = Some(stats);
    }
    log.flush()?;
    let wall_seconds = t0.elapsed().as_secs_f64();
    write_summary(
        &run_dir,
        &cfg.name,
        trainer.best.as_ref(),
        last.as_ref(),
        wall_seconds,
    )?;

    Ok(RunResult {
        best: trainer.best.clone(),
        best_reward: trainer.best_reward.clone(),
        last,
        history,
        workload,
        run_dir,
        wall_seconds,
    })
}

/// Render the run's training curves (coverage/area/reward vs epoch) as an
/// ASCII chart — the terminal analogue of Figs. 9/11/13.
pub fn curves_ascii(history: &[EpochStats], width: usize, height: usize) -> String {
    let cov: Vec<f64> = history.iter().map(|s| s.mean_coverage).collect();
    let area: Vec<f64> = history.iter().map(|s| s.mean_area).collect();
    let reward: Vec<f64> = history.iter().map(|s| s.mean_reward).collect();
    crate::viz::ascii_chart(
        &[
            ("coverage", &cov),
            ("area", &area),
            ("reward", &reward),
        ],
        width,
        height,
    )
}

/// Best-solution one-line description (Table II/IV row material).
pub fn describe_best(best: &Option<BestSolution>, grid: &crate::graph::GridSummary) -> String {
    match best {
        None => "no complete-coverage solution found".to_string(),
        Some(b) => format!(
            "diag {:?}  fill {:?}  C={:.3} A={:.3} sparsity={:.3} (epoch {})",
            b.scheme.diag_sizes_units(grid),
            b.scheme.fill_len,
            b.eval.coverage_ratio,
            b.eval.area_ratio,
            b.eval.sparsity,
            b.epoch
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn curves_ascii_smoke() {
        let h: Vec<EpochStats> = (0..50)
            .map(|e| EpochStats {
                epoch: e,
                mean_reward: 0.5 + e as f64 / 100.0,
                max_reward: 0.9,
                mean_coverage: 0.9,
                mean_area: 0.5 - e as f64 / 200.0,
                frac_complete: 0.5,
                baseline: 0.5,
                loss: 0.0,
                mean_logp: -3.0,
            })
            .collect();
        let s = curves_ascii(&h, 40, 10);
        assert!(s.contains("coverage"));
        assert!(s.contains("reward"));
    }
}
