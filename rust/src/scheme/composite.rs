//! Composite mapping schemes: a global mapping stitched from per-window
//! schemes — the object that takes the paper's single-rollout method to
//! matrices far beyond the controller's native grid.
//!
//! A [`CompositeScheme`] is an ordered list of [`WindowSlice`]s. Each slice
//! carries the diagonal *window* its scheme was inferred on (in global grid
//! cells) and the *owned* sub-range the slice is responsible for; owned
//! ranges partition the grid, while windows may overlap their neighbours.
//! A slice contributes the geometric intersection of its scheme's blocks
//! with its owned diagonal square — clipping guarantees the paper's
//! principles globally:
//!
//! - **no overlap**: rects within one slice are disjoint (validated
//!   schemes) and clipping keeps them inside the slice's owned square;
//!   owned squares are pairwise disjoint, so the global rect set is too;
//! - **complete coverage of windowed nnz**: if every slice's scheme fully
//!   covers its window, every non-zero inside an owned square stays
//!   covered after clipping (the covering rect's intersection with the
//!   square still contains it). Non-zeros *outside* every owned square —
//!   band entries crossing an ownership cut — are off-window by
//!   construction and are accounted as digital spill
//!   ([`crate::graph::storage`]) rather than mapped;
//! - **least area**: clipping only shrinks rects, so a slice never costs
//!   more than its owned square (the fixed-block bound), and the per-window
//!   inference minimizes window area among complete candidates.

use super::parse::Scheme;
use super::GridRect;
use crate::graph::{storage, GridSummary};

/// One window's contribution to a composite mapping.
#[derive(Clone, Debug, PartialEq)]
pub struct WindowSlice {
    /// window range in global grid cells (what the controller saw)
    pub win_start: usize,
    pub win_end: usize,
    /// owned range [start, end) in global grid cells; slices' owned ranges
    /// partition the grid
    pub start: usize,
    pub end: usize,
    /// scheme over the window grid (grid_count == win_end - win_start)
    pub scheme: Scheme,
    /// whether the scheme came out of the mapper's signature cache
    pub cache_hit: bool,
}

impl WindowSlice {
    /// The slice's mapped rectangles in global grid coordinates: the
    /// scheme's rects offset to the window origin and clipped to the owned
    /// diagonal square.
    pub fn rects(&self) -> Vec<GridRect> {
        self.scheme
            .rects()
            .iter()
            .filter_map(|r| {
                let r0 = (r.r0 + self.win_start).max(self.start);
                let r1 = (r.r1 + self.win_start).min(self.end);
                let c0 = (r.c0 + self.win_start).max(self.start);
                let c1 = (r.c1 + self.win_start).min(self.end);
                if r0 < r1 && c0 < c1 {
                    Some(GridRect { r0, r1, c0, c1 })
                } else {
                    None
                }
            })
            .collect()
    }
}

/// A globally valid mapping assembled from per-window schemes.
#[derive(Clone, Debug, PartialEq)]
pub struct CompositeScheme {
    /// global grid-cell count the slices partition
    pub n: usize,
    pub slices: Vec<WindowSlice>,
}

/// Evaluation of a composite mapping against the global grid summary —
/// the scaled-up analogue of [`super::EvalResult`], with the paper's
/// future-work sparse-storage axis (digital spill) made explicit.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CompositeEval {
    /// nnz inside the owned diagonal squares (what windowing can map)
    pub windowed_nnz: u64,
    /// nnz inside the composite's mapped rects
    pub covered_nnz: u64,
    /// total − covered: off-window band entries plus anything a partial
    /// window scheme missed; served from digital sparse storage
    pub spilled_nnz: u64,
    pub total_nnz: u64,
    /// matrix-unit area of the mapped rects
    pub covered_area_units: u64,
    /// covered area / D² (Eq. 23 at global scale)
    pub area_ratio: f64,
    /// covered / windowed (1.0 = the four principles hold end-to-end)
    pub coverage_windowed: f64,
    /// covered / total (the crossbar-served fraction of all nnz)
    pub mapped_fraction: f64,
    /// COO byte cost of holding the spill digitally
    pub spill_coo_bytes: u64,
    /// total diagonal blocks across slices (composite granularity)
    pub num_blocks: usize,
}

impl CompositeScheme {
    /// All mapped rectangles in global grid coordinates, slice order.
    pub fn rects(&self) -> Vec<GridRect> {
        self.slices.iter().flat_map(|s| s.rects()).collect()
    }

    /// Structural validation of the composite principles that do not need
    /// the matrix: owned ranges partition [0, n) in order, each window
    /// contains its owned range, and each slice's scheme is a valid
    /// diagonal+fill scheme over its window.
    pub fn validate(&self, n: usize) -> Result<(), String> {
        if self.n != n {
            return Err(format!("composite spans {} cells, expected {n}", self.n));
        }
        if self.slices.is_empty() {
            return Err("composite has no slices".into());
        }
        let mut expect = 0usize;
        for (i, s) in self.slices.iter().enumerate() {
            if s.start != expect {
                return Err(format!(
                    "slice {i} owns [{}, {}) but the previous slice ended at {expect}",
                    s.start, s.end
                ));
            }
            if s.start >= s.end {
                return Err(format!("slice {i} owns an empty range"));
            }
            if s.win_start > s.start || s.end > s.win_end || s.win_end > n {
                return Err(format!(
                    "slice {i} window [{}, {}) does not contain its owned range [{}, {})",
                    s.win_start, s.win_end, s.start, s.end
                ));
            }
            s.scheme
                .validate(s.win_end - s.win_start)
                .map_err(|e| format!("slice {i} scheme: {e}"))?;
            expect = s.end;
        }
        if expect != n {
            return Err(format!("slices end at {expect}, grid has {n} cells"));
        }
        Ok(())
    }

    /// Number of window slices (the block count is
    /// [`CompositeEval::num_blocks`]).
    pub fn num_windows(&self) -> usize {
        self.slices.len()
    }

    /// Fraction of slices served from the scheme cache.
    pub fn cache_hit_rate(&self) -> f64 {
        if self.slices.is_empty() {
            return 0.0;
        }
        self.slices.iter().filter(|s| s.cache_hit).count() as f64 / self.slices.len() as f64
    }

    /// Evaluate the composite against the global grid summary.
    /// `value_bytes` prices the digital spill (4 = f32 weights, 0 =
    /// pattern-only adjacency).
    pub fn evaluate(&self, g: &GridSummary, value_bytes: u64) -> CompositeEval {
        let mut covered_nnz = 0u64;
        let mut covered_area = 0u64;
        let mut num_blocks = 0usize;
        for s in &self.slices {
            num_blocks += s.scheme.diag_len.len();
            for r in s.rects() {
                covered_nnz += r.nnz(g);
                covered_area += r.area_units(g);
            }
        }
        let windowed_nnz: u64 = self
            .slices
            .iter()
            .map(|s| g.nnz_rect(s.start, s.end, s.start, s.end))
            .sum();
        let total_nnz = g.total_nnz as u64;
        let spilled_nnz = total_nnz - covered_nnz;
        let dim2 = (g.dim as u64) * (g.dim as u64);
        CompositeEval {
            windowed_nnz,
            covered_nnz,
            spilled_nnz,
            total_nnz,
            covered_area_units: covered_area,
            area_ratio: covered_area as f64 / dim2 as f64,
            coverage_windowed: if windowed_nnz == 0 {
                1.0
            } else {
                covered_nnz as f64 / windowed_nnz as f64
            },
            mapped_fraction: if total_nnz == 0 {
                1.0
            } else {
                covered_nnz as f64 / total_nnz as f64
            },
            spill_coo_bytes: storage::coo_spill_bytes(spilled_nnz, g.dim, value_bytes),
            num_blocks,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::sparse::Coo;
    use crate::graph::synth;

    fn slice(ws: usize, we: usize, s: usize, e: usize, scheme: Scheme) -> WindowSlice {
        WindowSlice {
            win_start: ws,
            win_end: we,
            start: s,
            end: e,
            scheme,
            cache_hit: false,
        }
    }

    fn full(n: usize) -> Scheme {
        Scheme {
            diag_len: vec![n],
            fill_len: vec![],
        }
    }

    #[test]
    fn clipping_keeps_rects_in_owned_square() {
        // window [0,6) owning [0,4): full block clips to the owned square
        let s = slice(0, 6, 0, 4, full(6));
        assert_eq!(s.rects(), vec![GridRect { r0: 0, r1: 4, c0: 0, c1: 4 }]);
        // window [2,8) owning [4,8): fill at the window-relative junction
        let sch = Scheme {
            diag_len: vec![3, 3],
            fill_len: vec![2],
        };
        // junction at global 5; fill rects [3,5)x[5,7) and transpose; the
        // owned square [4,8)² keeps only their intersections
        let s = slice(2, 8, 4, 8, sch);
        let rects = s.rects();
        assert!(rects.contains(&GridRect { r0: 4, r1: 5, c0: 4, c1: 5 })); // clipped diag 1
        assert!(rects.contains(&GridRect { r0: 5, r1: 8, c0: 5, c1: 8 })); // diag 2
        assert!(rects.contains(&GridRect { r0: 4, r1: 5, c0: 5, c1: 7 })); // clipped fill
        assert!(rects.contains(&GridRect { r0: 5, r1: 7, c0: 4, c1: 5 })); // clipped transpose
        assert_eq!(rects.len(), 4);
    }

    #[test]
    fn validate_checks_partition_and_schemes() {
        let good = CompositeScheme {
            n: 8,
            slices: vec![slice(0, 5, 0, 4, full(5)), slice(3, 8, 4, 8, full(5))],
        };
        good.validate(8).unwrap();
        // gap in ownership
        let gap = CompositeScheme {
            n: 8,
            slices: vec![slice(0, 5, 0, 3, full(5)), slice(3, 8, 4, 8, full(5))],
        };
        assert!(gap.validate(8).is_err());
        // window not containing its owned range
        let outside = CompositeScheme {
            n: 8,
            slices: vec![slice(0, 3, 0, 4, full(3)), slice(3, 8, 4, 8, full(5))],
        };
        assert!(outside.validate(8).is_err());
        // scheme not spanning its window
        let short = CompositeScheme {
            n: 8,
            slices: vec![slice(0, 5, 0, 4, full(4)), slice(3, 8, 4, 8, full(5))],
        };
        assert!(short.validate(8).is_err());
        // wrong total
        assert!(good.validate(9).is_err());
    }

    #[test]
    fn complete_windows_cover_all_windowed_nnz() {
        // banded matrix, two overlapping full-block windows: every nnz in
        // an owned square stays covered; cross-cut band entries spill
        let m = synth::banded_like(60, 0.9, 5);
        let g = GridSummary::new(&m, 5); // n = 12
        let comp = CompositeScheme {
            n: 12,
            slices: vec![slice(0, 8, 0, 6, full(8)), slice(4, 12, 6, 12, full(8))],
        };
        comp.validate(12).unwrap();
        let e = comp.evaluate(&g, 4);
        assert_eq!(e.coverage_windowed, 1.0);
        assert_eq!(e.covered_nnz, e.windowed_nnz);
        assert_eq!(e.covered_nnz + e.spilled_nnz, e.total_nnz);
        // the banded matrix has entries crossing the cut at 6
        assert!(e.spilled_nnz > 0);
        assert_eq!(e.spill_coo_bytes, e.spilled_nnz * (2 * 2 + 4));
        // area = two owned squares (full blocks clipped to them)
        assert_eq!(e.covered_area_units, 30 * 30 + 30 * 30);
    }

    #[test]
    fn composite_of_one_slice_matches_plain_evaluation() {
        let m = synth::qm7_like(5828);
        let g = GridSummary::new(&m, 2); // n = 11
        let sch = Scheme {
            diag_len: vec![4, 7],
            fill_len: vec![2],
        };
        let comp = CompositeScheme {
            n: 11,
            slices: vec![slice(0, 11, 0, 11, sch.clone())],
        };
        comp.validate(11).unwrap();
        let ce = comp.evaluate(&g, 4);
        let pe = super::super::evaluate(&sch, &g, super::super::RewardWeights::new(0.5));
        assert_eq!(ce.covered_nnz, pe.covered_nnz);
        assert_eq!(ce.covered_area_units, pe.covered_area_units);
        assert_eq!(ce.windowed_nnz, pe.total_nnz);
        assert!((ce.area_ratio - pe.area_ratio).abs() < 1e-12);
    }

    #[test]
    fn empty_matrix_evaluates_cleanly() {
        let m = Coo::new(10, 10).to_csr();
        let g = GridSummary::new(&m, 2);
        let comp = CompositeScheme {
            n: 5,
            slices: vec![slice(0, 5, 0, 5, full(5))],
        };
        let e = comp.evaluate(&g, 4);
        assert_eq!(e.total_nnz, 0);
        assert_eq!(e.coverage_windowed, 1.0);
        assert_eq!(e.mapped_fraction, 1.0);
        assert_eq!(e.spilled_nnz, 0);
    }
}
