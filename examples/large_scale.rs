//! Large-scale run: reproduce one bold row of Table IV end-to-end, then
//! scale past the paper with the hierarchical mapper.
//!
//! Part 1 trains LSTM+RL+Dynamic-fill (grades 6, a=0.8) on the qh882-like
//! matrix at grid 32 on the pure-Rust native backend, prints the training
//! curves, compares the converged scheme against every baseline, and
//! reports the crossbar deployment cost of the winning scheme. Part 2
//! takes the same machinery to a 20k-node R-MAT graph through the
//! `api::DeploymentBuilder` facade — no hand-wired mapper→engine plumbing:
//! one builder call runs windowed inference (reusing part 1's trained
//! checkpoint), stitches the composite, compiles the fleet-servable plan,
//! and the resulting deployment saves/reloads as a bundle that serves
//! bit-identically.
//!
//! Run: `cargo run --release --example large_scale`
//! (no artifacts needed; a few minutes — use AUTOGMAP_EPOCHS to override
//! the epoch budget)

use autogmap::agent::BackendKind;
use autogmap::api::{Deployment, DeploymentBuilder, Source, Strategy};
use autogmap::baselines;
use autogmap::coordinator::config::{Dataset, ExperimentConfig};
use autogmap::coordinator::{run_experiment, runner, RunnerOptions};
use autogmap::crossbar::cost::CostModel;
use autogmap::crossbar::place;
use autogmap::crossbar::switch::SwitchCircuit;
use autogmap::graph::synth;
use autogmap::reorder::Reordering;
use autogmap::scheme::{evaluate, eval::evaluate_rects, FillRule, RewardWeights};

fn main() -> anyhow::Result<()> {
    let epochs: usize = std::env::var("AUTOGMAP_EPOCHS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(12_000);
    let cfg = ExperimentConfig {
        name: "table4_qh882_dyn6_a80".into(),
        dataset: Dataset::Qh882 { seed: 882 },
        grid: 32,
        reordering: Reordering::CuthillMckee,
        controller: "qh882_dyn6".into(),
        fill_rule: FillRule::Dynamic { grades: 6 },
        reward_a: 0.8,
        lr: 0.015,
        ent_coef: 0.002,
        baseline_decay: 0.95,
        epochs,
        seed: 3,
        log_every: 25,
    };
    println!(
        "training {} for {} epochs on qh882-like (882×882, sparsity ≈0.995, native backend) …",
        cfg.controller, epochs
    );
    let opts = RunnerOptions {
        backend: BackendKind::Native,
        // checkpoint the final epoch so part 2 can reuse the trained
        // controller for per-window inference
        checkpoint_every: epochs,
        ..Default::default()
    };
    let result = run_experiment(None, &cfg, &opts)?;
    println!("{}", runner::curves_ascii(&result.history, 78, 16));

    let grid = &result.workload.grid;
    let best = result.best.as_ref().expect("no complete-coverage scheme found");
    println!(
        "best scheme (epoch {}): {} diagonal blocks {:?}",
        best.epoch,
        best.scheme.diag_len.len(),
        best.scheme.diag_sizes_units(grid)
    );
    println!(
        "fills {:?}  ->  C={:.3}  A={:.3}  sparsity={:.3}",
        best.scheme.fill_len,
        best.eval.coverage_ratio,
        best.eval.area_ratio,
        best.eval.sparsity
    );
    println!("paper Table IV (qh882, grades 6, a=0.8): C=1.0  A=0.225  sparsity=0.955");
    println!(
        "wall {:.1}s  ({:.0} epochs/s; paper: 40k epochs in minutes on an Intel CPU)",
        result.wall_seconds,
        epochs as f64 / result.wall_seconds
    );

    // --- baselines on the identical grid
    let w = RewardWeights::new(cfg.reward_a);
    println!("\nbaselines at grid 32:");
    for block in [1usize, 2, 4] {
        let s = baselines::vanilla(grid.n, block);
        let e = evaluate(&s, grid, w);
        println!(
            "  vanilla {:>3}-unit blocks: C {:.3}  A {:.3}",
            block * 32,
            e.coverage_ratio,
            e.area_ratio
        );
    }
    let sar = baselines::graphsar(grid, 8);
    let e = evaluate_rects(&sar, grid, w);
    println!(
        "  GraphSAR-like (whole-matrix, {} blocks): C {:.3}  A {:.3}",
        sar.len(),
        e.coverage_ratio,
        e.area_ratio
    );

    // --- deploy the winner on crossbars and price it
    let arr = place(&result.workload.reordered.matrix, grid, &best.scheme)?;
    let sw = SwitchCircuit::new(result.workload.reordered.perm.clone());
    let cost = CostModel::default().estimate(&arr, sw.crossover_count());
    println!(
        "\ndeployment: {} tiles of 32×32  ({} cells = {:.1}% of a monolithic 882² crossbar)",
        cost.tiles,
        cost.cells,
        100.0 * cost.cells as f64 / (882.0 * 882.0)
    );
    println!(
        "  energy {:.2} nJ/MVM   latency {:.1} µs/MVM   {} ADC row segments",
        cost.energy_pj / 1e3,
        cost.latency_ns / 1e3,
        cost.row_segments
    );
    // correctness of the deployed array
    let x: Vec<f64> = (0..882).map(|i| ((i * 13) % 7) as f64 - 3.0).collect();
    let y = sw.inverse(&arr.mvm(&sw.forward(&x)));
    let want = result.workload.original.spmv(&x);
    let diff = y
        .iter()
        .zip(want.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    anyhow::ensure!(diff < 1e-9, "deployed MVM mismatch: {diff}");
    println!("  deployed y=Ax verified exact (max|Δ| = {diff:.1e})");

    // --- part 2: past the paper — a 20k-node R-MAT graph deployed through
    // the api facade (no hand-wired mapper→engine plumbing), reusing the
    // controller checkpoint trained above for the per-window inference
    println!("\nscaling out: 20k-node R-MAT graph through api::DeploymentBuilder …");
    let ck = result.run_dir.join("checkpoint.json");
    let mut builder = DeploymentBuilder::new(
        // qh882_dyn6's window shape (N=28 at grid 32) is the mapper window
        Source::Rmat { nodes: 20_000, degree: 6, seed: 7 },
        Strategy::Hierarchical { controller: "qh882_dyn6".into(), overlap: 4 },
    )
    .grid(32)
    .seed(7)
    .rounds(4)
    .reward_a(cfg.reward_a)
    .workers(8);
    if ck.exists() {
        println!("  reusing the trained controller checkpoint {}", ck.display());
        builder = builder.checkpoint(ck);
    } else {
        println!("  no checkpoint found; deploying with fresh-init params");
    }
    let dep = builder.build()?;
    let stats = dep.stats();
    println!(
        "  deployment: {} plan, {} tiles / {} programs / {} bands, kernels {} dense / {} sparse",
        dep.plan().kind(),
        stats.tiles,
        stats.programs,
        stats.bands,
        stats.kernel_dense,
        stats.kernel_sparse
    );
    println!(
        "  serving {} mapped + {} spilled nnz over {} programmed cells ({} fleet banks)",
        stats.mapped_nnz, stats.spilled_nnz, stats.area_cells, dep.fleet.banks
    );
    // exact serving in ORIGINAL node ids — the facade carries the RCM
    // permutation, so callers never see the reordered space
    let big = synth::rmat_like(20_000, 120_000, 7);
    let xb: Vec<f64> = (0..20_000).map(|i| ((i * 3) % 11) as f64 - 5.0).collect();
    let yb = dep.mvm(&xb)?;
    anyhow::ensure!(yb == big.spmv(&xb), "deployment MVM diverged from the dense oracle");
    println!("  y=Ax bit-exact vs the dense oracle, in original node ids");

    // checkpoint reuse through the bundle: pay the mapping cost once,
    // reload in any process, serve bit-identically
    let bundle = result.run_dir.join("deployment.json");
    dep.save(&bundle)?;
    let back = Deployment::load(&bundle)?;
    anyhow::ensure!(back.stats() == stats, "reloaded bundle lost program stats");
    anyhow::ensure!(back.mvm(&xb)? == yb, "reloaded bundle answered differently");
    println!(
        "  bundle {} reloads and serves bit-identically (serve it: \
         autogmap serve --bundle {})",
        bundle.display(),
        bundle.display()
    );
    Ok(())
}
