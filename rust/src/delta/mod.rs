//! Dynamic-graph serving: exact edge updates over a live deployment, plus
//! incremental windowed remap with an atomic generation-numbered swap.
//!
//! Every deployed graph eventually mutates; this subsystem lets a
//! [`crate::api::Deployment`] keep serving exact answers while it does.
//! Three pieces:
//!
//! 1. **Exact overlay serving** — edge inserts/deletes/reweights
//!    ([`EdgeUpdate`], `weight == 0` deletes) accumulate in a
//!    [`DeltaOverlay`]: a sorted per-row delta served on the digital spill
//!    path. The programmed crossbar arena is *never* touched between
//!    remaps — an update into an already-mapped cell becomes a correction
//!    entry (`new − programmed`), an insert into an unmapped cell a plain
//!    overlay entry, a delete a negative correction. Served answers are
//!    `y = (A ± Δ)x`, bit-identical (under the repo's integer-valued
//!    exactness convention) to a fresh host-CSR oracle of the mutated
//!    graph.
//! 2. **Incremental windowed remap** — [`DeltaEngine::remap`] folds the
//!    overlay into a freshly mapped plan. The mutated matrix is
//!    re-windowed and every window's occupancy signature interned into a
//!    *persistent* [`crate::mapper::cache::SchemeCache`] (warmed with one
//!    mapping pass at attach), so windows the deltas never touched are
//!    cache hits by construction and skip controller inference entirely —
//!    only mutated windows pay. The recompiled composite swaps in behind
//!    an atomic generation bump: in-flight requests finish on the old
//!    plan + overlay, new requests see the folded plan with a drained
//!    overlay (updates that landed mid-build are carried over, never
//!    lost).
//! 3. **Wire + policy surface** — `{"update":{"edges":[[r,c,w],...]}}` and
//!    `{"admin":{"remap":{"id":...}}}` are parsed by the shared
//!    [`crate::api::dispatch`] core, so the stdin `serve` loop and the TCP
//!    tier answer them identically; `--remap-after N` auto-folds after N
//!    accumulated updates; delta counters ride
//!    [`crate::engine::ServeStats`] and `{"admin":"stats"}`; and the
//!    `delta-bench` CLI ([`bench`]) drives concurrent updaters + queriers
//!    against a mutating host-CSR oracle and ledgers update/s, query/s,
//!    and incremental-vs-full remap latency into `BENCH_delta.json`.
//!
//! Locking: queries hold a read lock for the duration of one (batch)
//! execution, updates and the remap swap take the write lock briefly, and
//! remap *building* (the expensive mapping) runs outside both under its
//! own serialization mutex — the harness never stops serving to remap.
//! Updates arrive in original node ids and are translated through the
//! deployment's reordering permutation; the RCM order itself is fixed at
//! deploy time, so heavy churn can erode bandedness until a full
//! re-deploy re-reorders (see ROADMAP).

pub mod bench;
pub mod remap;

pub use bench::{run_delta_bench, DeltaBenchOptions};
pub use remap::RemapReport;

use crate::api::deploy::{DeployedPlan, Deployment};
use crate::api::error::{Error, Result};
use crate::engine::{BatchExecutor, Servable, ServeStats};
use crate::graph::{Coo, Csr};
use crate::mapper::cache::SchemeCache;
use crate::util::pool::WorkerPool;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

/// One edge mutation in *original* node ids. `weight` is the edge's new
/// value — an insert or reweight; `weight == 0.0` deletes the edge.
/// Updates are applied as given (directed); symmetric graphs send both
/// `(r, c)` and `(c, r)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EdgeUpdate {
    pub row: usize,
    pub col: usize,
    pub weight: f64,
}

/// Acknowledgement for one applied update batch.
#[derive(Clone, Copy, Debug)]
pub struct UpdateAck {
    /// edges applied from this request
    pub applied: usize,
    /// overlay entries now pending the next remap
    pub pending: usize,
    /// plan generation the update landed on
    pub generation: u64,
}

/// Sorted COO delta between the mutated graph and the plan's programmed
/// base, served on the digital spill path. Rows iterate in ascending
/// order; within a row, columns ascend — exactly the composite spill's
/// shape, so the overlay stage keeps the per-row single-accumulator
/// contract the bit-identity tests rely on.
#[derive(Clone, Debug, Default)]
pub struct DeltaOverlay {
    rows: BTreeMap<usize, BTreeMap<usize, f64>>,
    entries: usize,
}

impl DeltaOverlay {
    /// Live delta entries (cells where the mutated graph differs from the
    /// programmed base).
    pub fn len(&self) -> usize {
        self.entries
    }

    pub fn is_empty(&self) -> bool {
        self.entries == 0
    }

    /// Current delta at `(r, c)` (0 when the cell matches the base).
    pub fn get(&self, r: usize, c: usize) -> f64 {
        self.rows
            .get(&r)
            .and_then(|cols| cols.get(&c))
            .copied()
            .unwrap_or(0.0)
    }

    /// Set the delta at `(r, c)`; an exact-zero delta removes the entry
    /// (the cell reverted to its programmed value).
    pub fn set(&mut self, r: usize, c: usize, delta: f64) {
        if delta == 0.0 {
            if let Some(cols) = self.rows.get_mut(&r) {
                if cols.remove(&c).is_some() {
                    self.entries -= 1;
                }
                if cols.is_empty() {
                    self.rows.remove(&r);
                }
            }
        } else if self.rows.entry(r).or_default().insert(c, delta).is_none() {
            self.entries += 1;
        }
    }

    /// Overlay stage of one served MVM, in served (reordered) coordinates:
    /// per occupied row, one accumulator over the columns in ascending
    /// order, folded into `y[r]` with a single add — the same shape as the
    /// composite spill stage it rides next to.
    pub fn apply_into(&self, x: &[f64], y: &mut [f64]) {
        for (&r, cols) in &self.rows {
            let mut acc = 0.0f64;
            for (&c, &v) in cols {
                acc += v * x[c];
            }
            y[r] += acc;
        }
    }
}

/// Mutable row-major truth store for the current mutated matrix (served
/// order). `Csr` is immutable by design; this is the delta layer's
/// editable twin, converted back to a `Csr` at every remap snapshot.
#[derive(Clone, Debug)]
struct RowStore {
    rows: Vec<BTreeMap<usize, f64>>,
}

impl RowStore {
    fn from_csr(m: &Csr) -> RowStore {
        let mut rows = vec![BTreeMap::new(); m.rows];
        for (r, row) in rows.iter_mut().enumerate() {
            for (i, &c) in m.row(r).iter().enumerate() {
                row.insert(c, m.row_vals(r)[i]);
            }
        }
        RowStore { rows }
    }

    fn get(&self, r: usize, c: usize) -> f64 {
        self.rows[r].get(&c).copied().unwrap_or(0.0)
    }

    fn set(&mut self, r: usize, c: usize, w: f64) {
        if w == 0.0 {
            self.rows[r].remove(&c);
        } else {
            self.rows[r].insert(c, w);
        }
    }

    fn to_csr(&self) -> Csr {
        let n = self.rows.len();
        let mut indptr = Vec::with_capacity(n + 1);
        indptr.push(0);
        let mut indices = Vec::new();
        let mut data = Vec::new();
        for row in &self.rows {
            for (&c, &v) in row {
                indices.push(c);
                data.push(v);
            }
            indptr.push(indices.len());
        }
        Csr {
            rows: n,
            cols: n,
            indptr,
            indices,
            data,
        }
    }
}

/// Reconstruct the exact host CSR a compiled plan serves — programmed
/// tiles plus the composite's digital spill — in the plan's own
/// (reordered) coordinates. This is the fault harness's digital-reference
/// construction reused as the delta base: overlay entries are corrections
/// against exactly this matrix.
pub fn plan_host_csr(plan: &DeployedPlan) -> Csr {
    let exec = plan.exec_plan();
    let dim = exec.dim;
    let mut coo = Coo::new(dim, dim);
    for t in &exec.tiles {
        let prog = exec.program(t.program);
        for r in 0..t.rows {
            for c in 0..t.cols {
                let v = prog[r * t.cols + c];
                if v != 0.0 {
                    coo.push(t.row0 + r, t.col0 + c, v as f64);
                }
            }
        }
    }
    if let DeployedPlan::Composite(cp) = plan {
        for r in 0..cp.spill.rows {
            for (i, &c) in cp.spill.row(r).iter().enumerate() {
                coo.push(r, c, cp.spill.row_vals(r)[i]);
            }
        }
    }
    coo.to_csr()
}

/// Epoch state behind the engine's read/write lock: everything a query
/// needs to answer exactly, swapped as a unit at remap time.
struct DeltaShared {
    /// bumps on every remap swap
    generation: u64,
    deployment: Arc<Deployment>,
    executor: BatchExecutor<DeployedPlan>,
    /// the matrix the plan's tiles + spill serve (served order)
    base: Arc<Csr>,
    /// truth − base, served on the overlay stage
    overlay: DeltaOverlay,
    /// the current mutated matrix (served order)
    truth: RowStore,
    /// served positions touched since `base` was snapshotted; the remap
    /// swap replays the tail that landed while the new plan was building
    log: Vec<(usize, usize)>,
    updates_since_remap: u64,
}

/// The dynamic-graph serving engine around one deployment: applies edge
/// updates exactly ([`DeltaEngine::apply`]), serves `y = (A ± Δ)x`
/// ([`DeltaEngine::mvm`] / [`DeltaEngine::execute`]), and folds the delta
/// into a freshly mapped plan behind an atomic generation swap
/// ([`DeltaEngine::remap`]).
pub struct DeltaEngine {
    shared: RwLock<DeltaShared>,
    /// serializes remaps; serving and updates continue under `shared`
    pub(crate) remap_lock: Mutex<()>,
    pub(crate) strategy: remap::RemapStrategy,
    pub(crate) grid: usize,
    pub(crate) workers: usize,
    pub(crate) pool: Arc<WorkerPool>,
    /// persistent scheme cache: survives across remaps so untouched
    /// windows stay cache hits (grows monotonically; one entry per unique
    /// occupancy signature ever seen)
    pub(crate) cache: Mutex<SchemeCache>,
    /// original → served node id
    inv_perm: Vec<usize>,
    dim: usize,
    updates_total: AtomicU64,
    remaps_total: AtomicU64,
    last_remap: Mutex<Option<RemapReport>>,
}

impl DeltaEngine {
    /// Wrap a deployment for dynamic serving. Reconstructs the host base
    /// CSR from the compiled plan, derives the remap strategy from the
    /// deployment's provenance, and warms the persistent scheme cache with
    /// one mapping pass over the base matrix — so even the *first*
    /// incremental remap skips inference for untouched windows.
    pub fn attach(dep: Deployment, pool: Arc<WorkerPool>) -> Result<Arc<DeltaEngine>> {
        let strategy = remap::RemapStrategy::from_provenance(&dep.provenance)?;
        let dim = dep.plan().dim();
        let grid = dep.provenance.grid.max(1);
        let base = plan_host_csr(dep.plan());
        if base.nnz() as u64 != Servable::nnz(dep.plan()) {
            return Err(Error::Internal(format!(
                "plan reconstruction lost nnz: host CSR holds {}, plan serves {}",
                base.nnz(),
                Servable::nnz(dep.plan())
            )));
        }
        let truth = RowStore::from_csr(&base);
        let perm = dep.permutation().to_vec();
        let mut inv_perm = vec![0usize; perm.len()];
        for (new, &old) in perm.iter().enumerate() {
            inv_perm[old] = new;
        }
        let workers = pool.workers();
        let mut cache = SchemeCache::new();
        strategy.warm(&base, grid, workers, &mut cache)?;
        let deployment = Arc::new(dep);
        let executor = BatchExecutor::with_pool(deployment.plan_arc(), pool.clone());
        Ok(Arc::new(DeltaEngine {
            shared: RwLock::new(DeltaShared {
                generation: 0,
                deployment,
                executor,
                base: Arc::new(base),
                overlay: DeltaOverlay::default(),
                truth,
                log: Vec::new(),
                updates_since_remap: 0,
            }),
            remap_lock: Mutex::new(()),
            strategy,
            grid,
            workers,
            pool,
            cache: Mutex::new(cache),
            inv_perm,
            dim,
            updates_total: AtomicU64::new(0),
            remaps_total: AtomicU64::new(0),
            last_remap: Mutex::new(None),
        }))
    }

    /// Apply one batch of edge updates (original node ids) to the live
    /// graph: the truth store mutates, and each touched cell's overlay
    /// entry becomes `new − programmed_base` — so the very next query
    /// already serves the mutated graph exactly. The programmed arena is
    /// untouched.
    pub fn apply(&self, edges: &[EdgeUpdate]) -> Result<UpdateAck> {
        for (i, e) in edges.iter().enumerate() {
            if e.row >= self.dim || e.col >= self.dim {
                return Err(Error::Validate(format!(
                    "update.edges[{i}] targets ({}, {}) outside the {}-node graph",
                    e.row, e.col, self.dim
                )));
            }
            if !e.weight.is_finite() {
                return Err(Error::Validate(format!(
                    "update.edges[{i}] weight must be finite, got {}",
                    e.weight
                )));
            }
        }
        let mut s = self.shared.write().unwrap();
        for e in edges {
            let r = self.inv_perm[e.row];
            let c = self.inv_perm[e.col];
            s.truth.set(r, c, e.weight);
            let d = e.weight - s.base.get(r, c);
            s.overlay.set(r, c, d);
            s.log.push((r, c));
        }
        s.updates_since_remap += edges.len() as u64;
        self.updates_total
            .fetch_add(edges.len() as u64, Ordering::Relaxed);
        Ok(UpdateAck {
            applied: edges.len(),
            pending: s.overlay.len(),
            generation: s.generation,
        })
    }

    /// One exact MVM over the mutated graph, in original node ids:
    /// permute in, plan (tiles + spill), overlay, permute out.
    pub fn mvm(&self, x: &[f64]) -> Result<Vec<f64>> {
        if x.len() != self.dim {
            return Err(Error::Validate(format!(
                "request has {} elements, deployment expects {}",
                x.len(),
                self.dim
            )));
        }
        let s = self.shared.read().unwrap();
        let xp = s.deployment.permute_in(x);
        let mut y = s.deployment.plan().mvm(&xp);
        s.overlay.apply_into(&xp, &mut y);
        Ok(s.deployment.permute_out(&y))
    }

    /// Batched exact MVMs over the mutated graph (original node ids),
    /// through the engine's executor in either mode. The overlay stage is
    /// applied per request after the plan stage, before permuting out.
    pub fn execute(&self, xs: &[Vec<f64>], sharded: bool) -> Result<Vec<Vec<f64>>> {
        for (i, x) in xs.iter().enumerate() {
            if x.len() != self.dim {
                return Err(Error::Validate(format!(
                    "request {i} has {} elements, deployment expects {}",
                    x.len(),
                    self.dim
                )));
            }
        }
        let s = self.shared.read().unwrap();
        let xps: Vec<Vec<f64>> = xs.iter().map(|x| s.deployment.permute_in(x)).collect();
        let mut ys = if sharded {
            s.executor.execute_batch_sharded(xps.clone())
        } else {
            s.executor.execute_batch(xps.clone())
        };
        if !s.overlay.is_empty() {
            for (xp, y) in xps.iter().zip(ys.iter_mut()) {
                s.overlay.apply_into(xp, y);
            }
        }
        Ok(ys.iter().map(|y| s.deployment.permute_out(y)).collect())
    }

    /// Matrix dimension (request/response length, original ids).
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Current plan generation (bumps on every remap swap).
    pub fn generation(&self) -> u64 {
        self.shared.read().unwrap().generation
    }

    /// Overlay entries pending the next remap.
    pub fn pending(&self) -> usize {
        self.shared.read().unwrap().overlay.len()
    }

    /// Edge updates applied since attach.
    pub fn updates_total(&self) -> u64 {
        self.updates_total.load(Ordering::Relaxed)
    }

    /// Remaps folded since attach.
    pub fn remaps_total(&self) -> u64 {
        self.remaps_total.load(Ordering::Relaxed)
    }

    /// Edge updates applied since the last remap snapshot (what
    /// `--remap-after N` compares against).
    pub fn updates_since_remap(&self) -> u64 {
        self.shared.read().unwrap().updates_since_remap
    }

    /// Snapshot of the current deployment (plan generation the caller
    /// observed; stays serviceable after a concurrent swap).
    pub fn deployment(&self) -> Arc<Deployment> {
        self.shared.read().unwrap().deployment.clone()
    }

    /// The most recent remap's report, if any.
    pub fn last_remap(&self) -> Option<RemapReport> {
        self.last_remap.lock().unwrap().clone()
    }

    /// Plan statistics with the live delta counters overlaid.
    pub fn stats(&self) -> ServeStats {
        let s = self.shared.read().unwrap();
        let mut st = s.deployment.stats();
        st.delta_updates = self.updates_total.load(Ordering::Relaxed);
        st.delta_pending = s.overlay.len();
        st.delta_remaps = self.remaps_total.load(Ordering::Relaxed);
        st
    }

    fn record_remap(&self, report: &RemapReport) {
        self.remaps_total.fetch_add(1, Ordering::Relaxed);
        *self.last_remap.lock().unwrap() = Some(report.clone());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::deploy::{DeploymentBuilder, Source, Strategy};
    use crate::graph::synth;

    fn integer_banded(dim: usize, band: usize, seed: u64) -> Csr {
        let mut rng = crate::util::rng::Pcg64::seed_from_u64(seed);
        let mut coo = Coo::new(dim, dim);
        for i in 0..dim {
            coo.push(i, i, 1.0 + rng.below(4) as f64);
            for d in 1..=band {
                if i + d < dim && rng.below(3) > 0 {
                    coo.push_sym(i, i + d, 1.0 + rng.below(4) as f64);
                }
            }
        }
        coo.to_csr()
    }

    fn fixed_block_deployment(dim: usize, seed: u64) -> Deployment {
        DeploymentBuilder::new(
            Source::Matrix {
                label: format!("delta-test-{dim}"),
                matrix: integer_banded(dim, 3, seed),
            },
            Strategy::FixedBlock { block: 2 },
        )
        .grid(8)
        .banks(2)
        .workers(2)
        .build()
        .unwrap()
    }

    #[test]
    fn overlay_set_get_and_apply_match_a_dense_delta() {
        let mut ov = DeltaOverlay::default();
        assert!(ov.is_empty());
        ov.set(1, 2, 3.0);
        ov.set(1, 0, -1.0);
        ov.set(3, 3, 2.0);
        assert_eq!(ov.len(), 3);
        assert_eq!(ov.get(1, 2), 3.0);
        ov.set(1, 2, 0.0); // reverted to base -> entry drops
        assert_eq!(ov.len(), 2);
        assert_eq!(ov.get(1, 2), 0.0);
        let x = [1.0, 2.0, 3.0, 4.0];
        let mut y = vec![0.0; 4];
        ov.apply_into(&x, &mut y);
        assert_eq!(y, vec![0.0, -1.0, 0.0, 8.0]);
    }

    #[test]
    fn row_store_roundtrips_and_mutates() {
        let m = integer_banded(24, 2, 7);
        let mut rs = RowStore::from_csr(&m);
        assert_eq!(rs.to_csr(), m);
        rs.set(0, 5, 9.0);
        assert_eq!(rs.get(0, 5), 9.0);
        rs.set(0, 5, 0.0);
        assert_eq!(rs.to_csr(), m);
    }

    #[test]
    fn plan_host_csr_reconstructs_the_served_matrix() {
        let dep = fixed_block_deployment(40, 11);
        let host = plan_host_csr(dep.plan());
        assert_eq!(host.nnz() as u64, Servable::nnz(dep.plan()));
        // the reconstruction must serve identically to the plan
        let x: Vec<f64> = (0..40).map(|i| ((i % 5) as f64) - 2.0).collect();
        assert_eq!(host.spmv(&x), dep.plan().mvm(&x));
    }

    #[test]
    fn updates_serve_exactly_against_a_mutated_oracle() {
        let dim = 40;
        let dep = fixed_block_deployment(dim, 3);
        let mut oracle = RowStore::from_csr(&integer_banded(dim, 3, 3));
        let pool = Arc::new(WorkerPool::new(2));
        let eng = DeltaEngine::attach(dep, pool).unwrap();
        let edges = [
            EdgeUpdate { row: 0, col: 39, weight: 2.0 },  // far insert (spill side)
            EdgeUpdate { row: 5, col: 6, weight: 7.0 },   // reweight a mapped cell
            EdgeUpdate { row: 10, col: 10, weight: 0.0 }, // delete the diagonal
        ];
        let ack = eng.apply(&edges).unwrap();
        assert_eq!(ack.applied, 3);
        assert!(ack.pending >= 1);
        for e in &edges {
            oracle.set(e.row, e.col, e.weight);
        }
        let want_m = oracle.to_csr();
        let x: Vec<f64> = (0..dim).map(|i| ((i % 7) as f64) - 3.0).collect();
        let want = want_m.spmv(&x);
        assert_eq!(eng.mvm(&x).unwrap(), want);
        for sharded in [false, true] {
            let ys = eng.execute(&[x.clone(), x.clone()], sharded).unwrap();
            assert_eq!(ys[0], want);
            assert_eq!(ys[1], want);
        }
        // reverting every edge to its base value drains the overlay
        let base_m = integer_banded(dim, 3, 3);
        let revert: Vec<EdgeUpdate> = edges
            .iter()
            .map(|e| EdgeUpdate {
                row: e.row,
                col: e.col,
                weight: base_m.get(e.row, e.col),
            })
            .collect();
        eng.apply(&revert).unwrap();
        assert_eq!(eng.pending(), 0);
        assert_eq!(eng.mvm(&x).unwrap(), base_m.spmv(&x));
    }

    #[test]
    fn out_of_range_and_non_finite_updates_are_rejected() {
        let dep = fixed_block_deployment(24, 5);
        let pool = Arc::new(WorkerPool::new(1));
        let eng = DeltaEngine::attach(dep, pool).unwrap();
        let bad = eng.apply(&[EdgeUpdate { row: 24, col: 0, weight: 1.0 }]);
        assert!(bad.unwrap_err().to_string().contains("outside"));
        let nan = eng.apply(&[EdgeUpdate { row: 0, col: 0, weight: f64::NAN }]);
        assert!(nan.unwrap_err().to_string().contains("finite"));
        assert_eq!(eng.updates_total(), 0, "rejected batches apply nothing");
    }

    #[test]
    fn stats_carry_delta_counters() {
        let dep = fixed_block_deployment(24, 9);
        let pool = Arc::new(WorkerPool::new(1));
        let eng = DeltaEngine::attach(dep, pool).unwrap();
        eng.apply(&[EdgeUpdate { row: 0, col: 23, weight: 1.0 }]).unwrap();
        let st = eng.stats();
        assert_eq!(st.delta_updates, 1);
        assert_eq!(st.delta_pending, 1);
        assert_eq!(st.delta_remaps, 0);
    }

    #[test]
    fn rmat_like_is_available_for_bench_shapes() {
        // the bench synthesizes via the same helper deploy uses
        let m = synth::rmat_like(300, 1200, 1);
        assert_eq!(m.rows, 300);
        assert!(m.nnz() > 0);
    }
}
