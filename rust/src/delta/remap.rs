//! Incremental windowed remap: fold the accumulated delta into a freshly
//! mapped plan, re-inferring only the windows the updates touched.
//!
//! The lever is the engine's *persistent* scheme cache: the mutated
//! matrix is re-windowed exactly like a fresh deployment, but every
//! window whose occupancy signature survived the updates is already
//! interned — [`crate::mapper::map_graph_with_cache`] answers it without
//! touching the controller. With updates confined to a few windows, an
//! incremental remap pays inference for those windows only while a full
//! remap (fresh cache — what [`DeltaEngine::remap_full`] measures) pays
//! for every unique signature again.
//!
//! The swap is atomic and generation-numbered, mirroring the fault
//! harness's repair epochs: the expensive mapping runs on a snapshot
//! outside the serving lock, then the new plan + executor + drained
//! overlay replace the old under one brief write lock. Updates that
//! landed while the new plan was building are replayed from the edge-log
//! tail against the new base, so no mutation is ever lost.

use super::{DeltaEngine, DeltaOverlay};
use crate::agent::params::init_params;
use crate::api::deploy::{fill_rule_for, DeployedPlan, Provenance};
use crate::api::error::{Error, Result};
use crate::engine::{BatchExecutor, Servable};
use crate::graph::{Csr, GridSummary};
use crate::mapper::cache::SchemeCache;
use crate::mapper::{compile_composite, InferContext, MapperConfig};
use crate::runtime::Manifest;
use crate::scheme::{CompositeScheme, RewardWeights, Scheme, WindowSlice};
use std::sync::Arc;
use std::time::Instant;

/// Default controller sampling rounds for remap inference. Provenance
/// does not record the deploy-time value; what matters for stability is
/// that every remap of one engine infers identically, which a fixed
/// default guarantees.
const REMAP_ROUNDS: usize = 2;

/// How a remap re-maps the mutated matrix, derived from the deployment's
/// provenance strategy label.
pub(crate) enum RemapStrategy {
    /// The hierarchical window mapper against the persistent scheme
    /// cache. Also used for `direct:` deployments (with zero overlap): a
    /// grid that fits one controller window stays a single window, but
    /// the result compiles as a composite, so a flat deployment becomes
    /// composite after its first remap.
    Windowed { ctx: InferContext, overlap: usize },
    /// The fixed-block baseline: rebuild the diagonal block slices, no
    /// inference (every remap is trivially "all windows reused").
    Fixed { block: usize },
}

/// Per-remap mapping statistics, normalized across strategies.
pub(crate) struct MapRunStats {
    pub windows: usize,
    pub cache_hits: usize,
    pub cache_entries: usize,
    pub cache_hit_rate: f64,
}

fn infer_context(controller: &str, seed: u64) -> Result<InferContext> {
    let entry = Manifest::builtin()
        .config(controller)
        .map_err(|e| Error::Validate(format!("{e:#}")))?
        .clone();
    let params = init_params(&entry, seed);
    let fill_rule = fill_rule_for(entry.fill_classes);
    Ok(InferContext {
        entry,
        params,
        fill_rule,
        weights: RewardWeights::new(0.8),
        rounds: REMAP_ROUNDS,
        seed,
    })
}

impl RemapStrategy {
    /// Derive the remap strategy from a deployment's recorded strategy
    /// label (`hierarchical:{controller}:overlap{N}`,
    /// `direct:{controller}`, or `fixed:{N}`).
    pub(crate) fn from_provenance(p: &Provenance) -> Result<RemapStrategy> {
        let label = p.strategy.as_str();
        if let Some(rest) = label.strip_prefix("hierarchical:") {
            let (controller, overlap) = rest.rsplit_once(":overlap").ok_or_else(|| {
                Error::Validate(format!("malformed hierarchical strategy label {label:?}"))
            })?;
            let overlap: usize = overlap.parse().map_err(|_| {
                Error::Validate(format!("malformed overlap in strategy label {label:?}"))
            })?;
            Ok(RemapStrategy::Windowed { ctx: infer_context(controller, p.seed)?, overlap })
        } else if let Some(controller) = label.strip_prefix("direct:") {
            Ok(RemapStrategy::Windowed { ctx: infer_context(controller, p.seed)?, overlap: 0 })
        } else if let Some(block) = label.strip_prefix("fixed:") {
            let block: usize = block.parse().map_err(|_| {
                Error::Validate(format!("malformed block in strategy label {label:?}"))
            })?;
            Ok(RemapStrategy::Fixed { block })
        } else {
            Err(Error::Validate(format!(
                "deployment strategy {label:?} has no remap path"
            )))
        }
    }

    /// Map a (snapshot) matrix into a servable plan against the given
    /// scheme cache.
    pub(crate) fn map(
        &self,
        m: &Csr,
        g: &GridSummary,
        workers: usize,
        cache: &mut SchemeCache,
    ) -> Result<(DeployedPlan, MapRunStats)> {
        match self {
            RemapStrategy::Windowed { ctx, overlap } => {
                let cfg = MapperConfig {
                    infer: ctx.clone(),
                    overlap: *overlap,
                    workers: workers.max(1),
                };
                let (comp, report) = crate::mapper::map_graph_with_cache(g, &cfg, cache)
                    .map_err(|e| Error::Validate(format!("remap mapping: {e:#}")))?;
                let cp = compile_composite(m, g, &comp)
                    .map_err(|e| Error::Validate(format!("remap compile: {e:#}")))?;
                Ok((
                    DeployedPlan::Composite(cp),
                    MapRunStats {
                        windows: report.windows,
                        cache_hits: report.cache_hits,
                        cache_entries: report.cache_entries,
                        cache_hit_rate: report.cache_hit_rate,
                    },
                ))
            }
            RemapStrategy::Fixed { block } => {
                let block = (*block).clamp(1, g.n);
                let mut slices = Vec::new();
                let mut start = 0usize;
                while start < g.n {
                    let end = (start + block).min(g.n);
                    slices.push(WindowSlice {
                        win_start: start,
                        win_end: end,
                        start,
                        end,
                        scheme: Scheme { diag_len: vec![end - start], fill_len: vec![] },
                        cache_hit: false,
                    });
                    start = end;
                }
                let windows = slices.len();
                let comp = CompositeScheme { n: g.n, slices };
                let cp = compile_composite(m, g, &comp)
                    .map_err(|e| Error::Validate(format!("remap compile: {e:#}")))?;
                Ok((
                    DeployedPlan::Composite(cp),
                    MapRunStats {
                        windows,
                        cache_hits: windows,
                        cache_entries: cache.unique(),
                        cache_hit_rate: 1.0,
                    },
                ))
            }
        }
    }

    /// Prime the persistent cache with one mapping pass over the base
    /// matrix (no compile), so the *first* incremental remap already hits
    /// for untouched windows. A no-op for the fixed baseline.
    pub(crate) fn warm(
        &self,
        base: &Csr,
        grid: usize,
        workers: usize,
        cache: &mut SchemeCache,
    ) -> Result<()> {
        if let RemapStrategy::Windowed { ctx, overlap } = self {
            let g = GridSummary::new(base, grid.max(1));
            let cfg = MapperConfig {
                infer: ctx.clone(),
                overlap: *overlap,
                workers: workers.max(1),
            };
            crate::mapper::map_graph_with_cache(&g, &cfg, cache)
                .map_err(|e| Error::Validate(format!("warming scheme cache: {e:#}")))?;
        }
        Ok(())
    }
}

/// Outcome of one remap: what was mapped, what the cache saved, and what
/// the swap carried over.
#[derive(Clone, Debug)]
pub struct RemapReport {
    /// plan generation after the swap
    pub generation: u64,
    /// true for [`DeltaEngine::remap_full`] (fresh cache, every window
    /// re-inferred)
    pub full: bool,
    /// windows mapped this remap
    pub windows: usize,
    /// windows answered from the scheme cache without inference
    pub reused_windows: usize,
    /// persistent-cache entries after the remap
    pub cache_entries: usize,
    /// `reused_windows / windows`
    pub cache_hit_rate: f64,
    /// overlay entries carried over (updates that landed mid-build)
    pub carried_updates: usize,
    /// nnz of the folded base matrix
    pub nnz: u64,
    pub wall_seconds: f64,
}

impl DeltaEngine {
    /// Fold the accumulated delta into a freshly mapped plan using the
    /// persistent scheme cache: windows the updates never touched are
    /// cache hits and skip inference. Serving continues on the old plan
    /// throughout the build; the swap is one brief write lock.
    pub fn remap(&self) -> Result<RemapReport> {
        self.remap_inner(false)
    }

    /// [`DeltaEngine::remap`] with a fresh throwaway cache — every unique
    /// window pays inference again. Same resulting plan quality; exists
    /// as the baseline the bench compares incremental latency against.
    pub fn remap_full(&self) -> Result<RemapReport> {
        self.remap_inner(true)
    }

    fn remap_inner(&self, full: bool) -> Result<RemapReport> {
        // one remap at a time; serving and updates continue under `shared`
        let _serialize = self.remap_lock.lock().unwrap();
        let t0 = Instant::now();
        let (snapshot, log_mark) = {
            let s = self.shared.read().unwrap();
            (s.truth.to_csr(), s.log.len())
        };
        let g = GridSummary::new(&snapshot, self.grid.max(1));
        let (plan, stats) = if full {
            let mut fresh = SchemeCache::new();
            self.strategy.map(&snapshot, &g, self.workers, &mut fresh)?
        } else {
            let mut cache = self.cache.lock().unwrap();
            self.strategy.map(&snapshot, &g, self.workers, &mut cache)?
        };
        if Servable::nnz(&plan) != snapshot.nnz() as u64 {
            return Err(Error::Internal(format!(
                "remapped plan serves {} nnz but the folded matrix holds {}",
                Servable::nnz(&plan),
                snapshot.nnz()
            )));
        }
        let mut s = self.shared.write().unwrap();
        let dep = Arc::new(s.deployment.with_swapped_plan(plan)?);
        let executor = BatchExecutor::with_pool(dep.plan_arc(), self.pool.clone());
        let base = Arc::new(snapshot);
        // replay updates that landed while the new plan was building: the
        // log tail, re-diffed against the new base
        let carried: Vec<(usize, usize)> = s.log[log_mark..].to_vec();
        let mut overlay = DeltaOverlay::default();
        for &(r, c) in &carried {
            overlay.set(r, c, s.truth.get(r, c) - base.get(r, c));
        }
        s.generation += 1;
        s.deployment = dep;
        s.executor = executor;
        s.base = base;
        s.overlay = overlay;
        s.log = carried;
        s.updates_since_remap = s.log.len() as u64;
        let report = RemapReport {
            generation: s.generation,
            full,
            windows: stats.windows,
            reused_windows: stats.cache_hits,
            cache_entries: stats.cache_entries,
            cache_hit_rate: stats.cache_hit_rate,
            carried_updates: s.overlay.len(),
            nnz: s.base.nnz() as u64,
            wall_seconds: t0.elapsed().as_secs_f64(),
        };
        drop(s);
        self.record_remap(&report);
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::deploy::{Deployment, DeploymentBuilder, Source, Strategy};
    use crate::delta::EdgeUpdate;
    use crate::graph::Coo;
    use crate::util::pool::WorkerPool;

    fn integer_banded(dim: usize, band: usize, seed: u64) -> Csr {
        let mut rng = crate::util::rng::Pcg64::seed_from_u64(seed);
        let mut coo = Coo::new(dim, dim);
        for i in 0..dim {
            coo.push(i, i, 1.0 + rng.below(4) as f64);
            for d in 1..=band {
                if i + d < dim && rng.below(3) > 0 {
                    coo.push_sym(i, i + d, 1.0 + rng.below(4) as f64);
                }
            }
        }
        coo.to_csr()
    }

    fn deploy(m: Csr, strategy: Strategy, grid: usize) -> Deployment {
        DeploymentBuilder::new(
            Source::Matrix { label: "remap-test".into(), matrix: m },
            strategy,
        )
        .grid(grid)
        .banks(2)
        .workers(2)
        .build()
        .unwrap()
    }

    #[test]
    fn strategy_labels_parse_and_unknown_labels_are_rejected() {
        let mut p = Provenance {
            source: "t".into(),
            strategy: "hierarchical:qm7_dyn4:overlap3".into(),
            dim: 10,
            grid: 4,
            cells: 3,
            nnz: 5,
            seed: 9,
            reordering: "rcm".into(),
            kernel: "auto".into(),
        };
        match RemapStrategy::from_provenance(&p).unwrap() {
            RemapStrategy::Windowed { ctx, overlap } => {
                assert_eq!(ctx.entry.name, "qm7_dyn4");
                assert_eq!(overlap, 3);
                assert_eq!(ctx.seed, 9);
            }
            _ => panic!("expected windowed"),
        }
        p.strategy = "direct:qh882_dyn4".into();
        match RemapStrategy::from_provenance(&p).unwrap() {
            RemapStrategy::Windowed { ctx, overlap } => {
                assert_eq!(ctx.entry.name, "qh882_dyn4");
                assert_eq!(overlap, 0);
            }
            _ => panic!("expected windowed"),
        }
        p.strategy = "fixed:3".into();
        match RemapStrategy::from_provenance(&p).unwrap() {
            RemapStrategy::Fixed { block } => assert_eq!(block, 3),
            _ => panic!("expected fixed"),
        }
        p.strategy = "fixed:x".into();
        assert!(RemapStrategy::from_provenance(&p).is_err());
        p.strategy = "mystery:1".into();
        assert!(RemapStrategy::from_provenance(&p).is_err());
        p.strategy = "hierarchical:qm7_dyn4".into();
        assert!(RemapStrategy::from_provenance(&p).is_err());
    }

    #[test]
    fn fixed_remap_folds_the_overlay_and_keeps_serving_exactly() {
        let dim = 48;
        let m = integer_banded(dim, 3, 21);
        let dep = deploy(m.clone(), Strategy::FixedBlock { block: 2 }, 8);
        let pool = Arc::new(WorkerPool::new(2));
        let eng = DeltaEngine::attach(dep, pool).unwrap();
        let edges = [
            EdgeUpdate { row: 2, col: 45, weight: 3.0 },
            EdgeUpdate { row: 7, col: 8, weight: 5.0 },
            EdgeUpdate { row: 11, col: 11, weight: 0.0 },
        ];
        eng.apply(&edges).unwrap();
        assert!(eng.pending() > 0);
        let report = eng.remap().unwrap();
        assert_eq!(report.generation, 1);
        assert!(!report.full);
        assert_eq!(report.carried_updates, 0, "no concurrent traffic");
        assert_eq!(eng.pending(), 0, "overlay folded into the plan");
        assert_eq!(eng.generation(), 1);
        assert_eq!(eng.remaps_total(), 1);

        // post-remap answers match a from-scratch deployment of the
        // mutated matrix, bit for bit
        let mut truth = super::super::RowStore::from_csr(&m);
        for e in &edges {
            truth.set(e.row, e.col, e.weight);
        }
        let fresh = deploy(truth.to_csr(), Strategy::FixedBlock { block: 2 }, 8);
        let x: Vec<f64> = (0..dim).map(|i| ((i % 9) as f64) - 4.0).collect();
        let want = fresh.mvm(&x).unwrap();
        assert_eq!(eng.mvm(&x).unwrap(), want);
        for sharded in [false, true] {
            assert_eq!(eng.execute(&[x.clone()], sharded).unwrap()[0], want);
        }
    }

    #[test]
    fn windowed_remap_reuses_untouched_window_schemes() {
        let dim = 260;
        let m = integer_banded(dim, 2, 5);
        let dep = deploy(
            m,
            Strategy::Hierarchical { controller: "qm7_dyn4".into(), overlap: 2 },
            4, // 65 grid cells -> several 11-cell windows
        );
        let pool = Arc::new(WorkerPool::new(2));
        let eng = DeltaEngine::attach(dep, pool).unwrap();
        // touch a single far-corner cell: at most a couple of windows'
        // signatures change
        eng.apply(&[EdgeUpdate { row: 0, col: 1, weight: 9.0 }]).unwrap();
        let inc = eng.remap().unwrap();
        assert!(inc.windows > 3, "expected several windows, got {}", inc.windows);
        assert!(
            inc.reused_windows > 0,
            "warm cache must reuse untouched windows: {inc:?}"
        );
        let full = eng.remap_full().unwrap();
        assert_eq!(full.generation, 2);
        assert_eq!(full.windows, inc.windows, "same matrix, same windowing");
        // serving stays exact across both swaps
        let x: Vec<f64> = (0..dim).map(|i| ((i % 5) as f64) - 2.0).collect();
        let dep2 = eng.deployment();
        assert_eq!(eng.mvm(&x).unwrap(), dep2.mvm(&x).unwrap());
    }
}
