//! Conversion helpers between Rust slices and `xla::Literal`s.

use anyhow::{ensure, Context, Result};

/// f32 literal with arbitrary shape.
pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let expect: i64 = dims.iter().product();
    ensure!(
        expect as usize == data.len(),
        "literal shape {dims:?} needs {expect} elements, got {}",
        data.len()
    );
    xla::Literal::vec1(data)
        .reshape(dims)
        .context("reshaping f32 literal")
}

/// 1-D f32 literal.
pub fn lit_f32_1d(data: &[f32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

/// 1-D u32 literal (PRNG keys).
pub fn lit_u32_1d(data: &[u32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

/// 2-D i32 literal (action matrices, row-major).
pub fn lit_i32_2d(data: &[i32], rows: usize, cols: usize) -> Result<xla::Literal> {
    ensure!(data.len() == rows * cols, "i32 literal shape mismatch");
    xla::Literal::vec1(data)
        .reshape(&[rows as i64, cols as i64])
        .context("reshaping i32 literal")
}

/// Scalar literals.
pub fn lit_scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn lit_scalar_i32(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Extract a Vec<f32> from a literal.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().context("literal to f32 vec")
}

/// Extract a Vec<i32> from a literal.
pub fn to_vec_i32(lit: &xla::Literal) -> Result<Vec<i32>> {
    lit.to_vec::<i32>().context("literal to i32 vec")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let l = lit_f32(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
        assert_eq!(l.element_count(), 6);
        assert_eq!(to_vec_f32(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(lit_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(lit_i32_2d(&[1, 2, 3], 2, 2).is_err());
    }

    #[test]
    fn scalars() {
        assert_eq!(lit_scalar_f32(2.5).to_vec::<f32>().unwrap(), vec![2.5]);
        assert_eq!(lit_scalar_i32(-3).to_vec::<i32>().unwrap(), vec![-3]);
    }
}
