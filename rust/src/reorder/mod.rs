//! Matrix reordering: Cuthill-McKee and Reverse Cuthill-McKee.
//!
//! The paper preprocesses every matrix with Cuthill-McKee ("the matrices
//! are reordered to lower-bandwidth symmetric matrices by Cuthill-McKee
//! reordering algorithm") so non-zeros concentrate around the diagonal
//! before the agent partitions it. We implement:
//!
//! - classic CM / RCM (George & Liu formulation): BFS from a
//!   pseudo-peripheral vertex, neighbours visited in increasing-degree
//!   order, per connected component;
//! - pseudo-peripheral vertex finding by repeated rooted level structures;
//! - bandwidth / profile quality metrics (on `Csr`).
//!
//! Permutation convention: `perm[new] = old`, matching
//! [`Csr::permute_sym`](crate::graph::sparse::Csr::permute_sym).

use crate::graph::sparse::Csr;

/// Rooted level structure: BFS levels from `root`, visiting neighbours in
/// increasing-degree order (the CM tie-break).
fn rooted_levels(m: &Csr, root: usize, level_of: &mut [usize], order: &mut Vec<usize>) -> usize {
    order.clear();
    level_of.iter_mut().for_each(|l| *l = usize::MAX);
    level_of[root] = 0;
    order.push(root);
    let mut head = 0;
    let mut max_level = 0;
    let mut nbrs: Vec<usize> = Vec::new();
    while head < order.len() {
        let v = order[head];
        head += 1;
        nbrs.clear();
        nbrs.extend(
            m.row(v)
                .iter()
                .copied()
                .filter(|&u| u != v && level_of[u] == usize::MAX),
        );
        nbrs.sort_by_key(|&u| (m.degree(u), u));
        for &u in &nbrs {
            level_of[u] = level_of[v] + 1;
            max_level = max_level.max(level_of[u]);
            order.push(u);
        }
    }
    max_level
}

/// George-Liu pseudo-peripheral vertex: start anywhere in the component,
/// repeatedly re-root at a minimum-degree vertex of the deepest level until
/// eccentricity stops growing.
fn pseudo_peripheral(m: &Csr, start: usize, scratch: &mut [usize]) -> usize {
    let mut root = start;
    let mut order = Vec::new();
    let mut ecc = rooted_levels(m, root, scratch, &mut order);
    loop {
        // minimum-degree vertex in the last level
        let last = order
            .iter()
            .copied()
            .filter(|&v| scratch[v] == ecc)
            .min_by_key(|&v| (m.degree(v), v))
            .unwrap_or(root);
        let new_ecc = rooted_levels(m, last, scratch, &mut order);
        if new_ecc > ecc {
            ecc = new_ecc;
            root = last;
        } else {
            return root;
        }
    }
}

/// Cuthill-McKee ordering. Returns `perm` with `perm[new] = old`.
/// Handles disconnected graphs (each component gets its own
/// pseudo-peripheral root; components are processed in index order, so
/// batch-supermatrix inputs keep their block grouping).
pub fn cuthill_mckee(m: &Csr) -> Vec<usize> {
    assert_eq!(m.rows, m.cols, "CM needs a square (symmetric) matrix");
    let n = m.rows;
    let mut perm = Vec::with_capacity(n);
    let mut visited = vec![false; n];
    let mut scratch = vec![usize::MAX; n];
    let mut order = Vec::new();
    for seed in 0..n {
        if visited[seed] {
            continue;
        }
        // restrict pseudo-peripheral search to this component by masking:
        // rooted_levels naturally stays in the component.
        let root = pseudo_peripheral(m, seed, &mut scratch);
        rooted_levels(m, root, &mut scratch, &mut order);
        for &v in &order {
            debug_assert!(!visited[v]);
            visited[v] = true;
            perm.push(v);
        }
    }
    debug_assert_eq!(perm.len(), n);
    perm
}

/// Reverse Cuthill-McKee: CM order reversed (usually smaller profile).
pub fn reverse_cuthill_mckee(m: &Csr) -> Vec<usize> {
    let mut perm = cuthill_mckee(m);
    perm.reverse();
    perm
}

/// Which reordering to apply as pre-processing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Reordering {
    /// Keep the input order.
    Identity,
    CuthillMckee,
    ReverseCuthillMckee,
}

impl Reordering {
    pub fn parse(s: &str) -> Result<Reordering, String> {
        match s {
            "identity" | "none" => Ok(Reordering::Identity),
            "cm" | "cuthill-mckee" => Ok(Reordering::CuthillMckee),
            "rcm" | "reverse-cuthill-mckee" => Ok(Reordering::ReverseCuthillMckee),
            other => Err(format!("unknown reordering {other:?} (identity|cm|rcm)")),
        }
    }

    /// Compute the permutation for matrix `m`.
    pub fn permutation(&self, m: &Csr) -> Vec<usize> {
        match self {
            Reordering::Identity => (0..m.rows).collect(),
            Reordering::CuthillMckee => cuthill_mckee(m),
            Reordering::ReverseCuthillMckee => reverse_cuthill_mckee(m),
        }
    }
}

/// Reordering result bundling the permuted matrix with its permutation, so
/// downstream consumers (crossbar switch circuit, GCN driver) can apply
/// Eqs. (4)/(6).
#[derive(Clone, Debug)]
pub struct Reordered {
    pub matrix: Csr,
    /// perm[new] = old
    pub perm: Vec<usize>,
    pub bandwidth_before: usize,
    pub bandwidth_after: usize,
}

/// Apply `kind` to `m`.
pub fn reorder(m: &Csr, kind: Reordering) -> Reordered {
    let perm = kind.permutation(m);
    let bw_before = m.bandwidth();
    let matrix = m.permute_sym(&perm);
    let bandwidth_after = matrix.bandwidth();
    Reordered {
        matrix,
        perm,
        bandwidth_before: bw_before,
        bandwidth_after,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::sparse::{perm, Coo};
    use crate::graph::synth;
    use crate::util::propcheck::check;

    fn path_graph_shuffled(n: usize, seed: u64) -> Csr {
        // path graph with a shuffled labelling: worst-ish bandwidth, CM
        // should recover bandwidth 1.
        let mut rng = crate::util::rng::Pcg64::seed_from_u64(seed);
        let mut label: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut label);
        let mut coo = Coo::new(n, n);
        for i in 1..n {
            coo.push_sym(label[i - 1], label[i], 1.0);
        }
        coo.to_csr()
    }

    #[test]
    fn cm_recovers_path_bandwidth() {
        let m = path_graph_shuffled(50, 3);
        assert!(m.bandwidth() > 1);
        let r = reorder(&m, Reordering::CuthillMckee);
        assert_eq!(r.bandwidth_after, 1);
        assert!(perm::is_permutation(&r.perm));
    }

    #[test]
    fn rcm_profile_not_worse_than_cm_on_fem_like() {
        let m = synth::banded_like(200, 0.95, 9);
        let cm = reorder(&m, Reordering::CuthillMckee);
        let rcm = reorder(&m, Reordering::ReverseCuthillMckee);
        assert_eq!(cm.bandwidth_after, rcm.bandwidth_after); // reversal preserves bandwidth
        assert!(rcm.matrix.profile() <= cm.matrix.profile());
    }

    #[test]
    fn cm_reduces_bandwidth_on_qh_like() {
        let m = synth::qh882_like(882);
        let r = reorder(&m, Reordering::CuthillMckee);
        assert!(
            r.bandwidth_after < r.bandwidth_before,
            "bandwidth {} -> {}",
            r.bandwidth_before,
            r.bandwidth_after
        );
        assert_eq!(r.matrix.nnz(), m.nnz());
        assert!(r.matrix.is_symmetric());
    }

    #[test]
    fn handles_disconnected_components() {
        let a = synth::qm7_like(1);
        let b = synth::qm7_like(2);
        let s = synth::batch_supermatrix(&[a, b]);
        let r = reorder(&s, Reordering::CuthillMckee);
        assert!(perm::is_permutation(&r.perm));
        assert_eq!(r.matrix.nnz(), s.nnz());
        // block-diagonal structure cannot gain cross-block entries
        assert!(r.matrix.is_symmetric());
    }

    #[test]
    fn handles_isolated_vertices_and_self_loops() {
        let mut coo = Coo::new(6, 6);
        coo.push(0, 0, 1.0); // self loop
        coo.push_sym(2, 3, 1.0);
        // vertices 1,4,5 isolated
        let m = coo.to_csr();
        let r = reorder(&m, Reordering::CuthillMckee);
        assert!(perm::is_permutation(&r.perm));
        assert_eq!(r.matrix.nnz(), m.nnz());
    }

    #[test]
    fn identity_reordering_is_noop() {
        let m = synth::qm7_like(5828);
        let r = reorder(&m, Reordering::Identity);
        assert_eq!(r.matrix, m);
        assert_eq!(r.perm, (0..22).collect::<Vec<_>>());
    }

    #[test]
    fn parse_kind() {
        assert_eq!(Reordering::parse("cm").unwrap(), Reordering::CuthillMckee);
        assert_eq!(Reordering::parse("rcm").unwrap(), Reordering::ReverseCuthillMckee);
        assert_eq!(Reordering::parse("none").unwrap(), Reordering::Identity);
        assert!(Reordering::parse("bogus").is_err());
    }

    #[test]
    fn cm_never_worse_than_random_labelling_property() {
        check("cm_bandwidth_improvement", 25, |rng| {
            let n = 20 + rng.below(80) as usize;
            let edges = n + rng.below(3 * n as u64) as usize;
            let mut coo = Coo::new(n, n);
            // connected: chain + random extras, then shuffle labels
            let mut label: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut label);
            for i in 1..n {
                coo.push_sym(label[i - 1], label[i], 1.0);
            }
            for _ in 0..edges {
                let a = rng.below(n as u64) as usize;
                let b = rng.below(n as u64) as usize;
                if a != b {
                    coo.push_sym(a.max(b), a.min(b), 1.0);
                }
            }
            let m = coo.to_csr();
            let r = reorder(&m, Reordering::CuthillMckee);
            if r.bandwidth_after <= r.bandwidth_before {
                Ok(())
            } else {
                Err(format!(
                    "CM increased bandwidth {} -> {} (n={n})",
                    r.bandwidth_before, r.bandwidth_after
                ))
            }
        });
    }

    #[test]
    fn spmv_through_reordering_matches_direct_property() {
        check("reorder_spmv_roundtrip", 20, |rng| {
            let n = 10 + rng.below(60) as usize;
            let mut coo = Coo::new(n, n);
            for _ in 0..3 * n {
                let a = rng.below(n as u64) as usize;
                let b = rng.below(n as u64) as usize;
                coo.push_sym(a.max(b), a.min(b), rng.uniform(-2.0, 2.0));
            }
            let m = coo.to_csr();
            let r = reorder(&m, Reordering::ReverseCuthillMckee);
            let x: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let direct = m.spmv(&x);
            let via = perm::apply_inverse(&r.perm, &r.matrix.spmv(&perm::apply(&r.perm, &x)));
            for (u, v) in direct.iter().zip(via.iter()) {
                if (u - v).abs() > 1e-9 {
                    return Err(format!("mismatch {u} vs {v}"));
                }
            }
            Ok(())
        });
    }
}
