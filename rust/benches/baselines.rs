//! Bench: baseline partitioners (Vanilla / GraphSAR-like / GraphR-like /
//! DP-oracle / exhaustive) — the comparison set behind Table II.

use autogmap::baselines::{self, exhaustive, oracle};
use autogmap::graph::{synth, GridSummary};
use autogmap::reorder::{reorder, Reordering};
use autogmap::scheme::RewardWeights;
use autogmap::util::bench::{black_box, Bencher};

fn main() {
    let mut b = Bencher::new();
    let qm7 = reorder(&synth::qm7_like(5828), Reordering::CuthillMckee).matrix;
    let qh882 = reorder(&synth::qh882_like(882), Reordering::CuthillMckee).matrix;
    let g_qm7 = GridSummary::new(&qm7, 1);
    let g_qm7g2 = GridSummary::new(&qm7, 2);
    let g_qh = GridSummary::new(&qh882, 32);

    b.bench("vanilla/qm7", || baselines::vanilla(22, 4));
    b.bench("vanilla_fill/qm7", || baselines::vanilla_fill(22, 6, 6));
    b.bench("graphsar/qm7", || baselines::graphsar(&g_qm7, 8));
    b.bench("graphsar/qh882_g32", || baselines::graphsar(&g_qh, 8));
    b.bench("graphr/qh882_g32", || baselines::graphr(&g_qh, 8));
    b.bench("dp_oracle/qm7_g2 (N=11)", || {
        oracle::optimal_diagonal(&g_qm7g2)
    });
    b.bench("dp_oracle/qh882_g32 (N=28)", || {
        oracle::optimal_diagonal(&g_qh)
    });
    b.bench("exhaustive/qm7_g2 (2^10 schemes)", || {
        black_box(exhaustive::best_diagonal(&g_qm7g2, RewardWeights::new(0.8)))
    });
    // DP scales to grids far beyond the exhaustive horizon
    let big = GridSummary::new(
        &reorder(&synth::banded_like(8192, 0.999, 3), Reordering::CuthillMckee).matrix,
        64,
    );
    b.bench("dp_oracle/synth8k_g64 (N=128)", || {
        oracle::optimal_diagonal(&big)
    });
}
