//! Action-sequence parsing: the `parse_d` / `parse_f` functions of Algo. 3.
//!
//! Diagonal actions `x ∈ {0,1}^{N-1}`: decision point i sits at grid
//! boundary i (between grid cell i-1 and i); 0 = "start a new block",
//! 1 = "continue to expand the previous block" — exactly Eq. (8).
//!
//! Fill actions exist only at boundaries where a new block starts (masked
//! by the diagonal sequence, Algo. 1 line 10) and choose the size of the
//! two symmetric fill blocks straddling that junction.

use super::GridRect;
use crate::graph::GridSummary;

/// Fill-block sizing rule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FillRule {
    /// No fill blocks at all ("LSTM+RL" rows of Table II).
    None,
    /// Fixed-size fill, binary decision (Eq. 16): action 1 places a fill of
    /// `size` grid cells (clamped to both neighbours), action 0 places none.
    Fixed { size: usize },
    /// Dynamic fill (Eq. 17): `grades` classes; action z ∈ {0..grades-1}
    /// places a fill of round(z/(grades−1) · s_prev) grid cells, clamped to
    /// min(s_prev, s_next) — "a proportion of the current diagonal-block".
    /// (Fig. 4: grades 6 ⇒ indices [0..5] ⇒ ratios [0, 1/5, …, 1]; Table
    /// II/IV fill actions never exceed grades−1.)
    Dynamic { grades: usize },
}

impl FillRule {
    /// Number of classes the fill head must emit.
    pub fn num_classes(&self) -> usize {
        match self {
            FillRule::None => 0,
            FillRule::Fixed { .. } => 2,
            FillRule::Dynamic { grades } => *grades,
        }
    }

    /// Resolve a fill action into a size in grid cells at a junction
    /// between diagonal blocks of `s_prev` and `s_next` grid cells.
    pub fn fill_len(&self, action: usize, s_prev: usize, s_next: usize) -> usize {
        let cap = s_prev.min(s_next);
        match self {
            FillRule::None => 0,
            FillRule::Fixed { size } => {
                if action == 0 {
                    0
                } else {
                    (*size).min(cap)
                }
            }
            FillRule::Dynamic { grades } => {
                debug_assert!(*grades >= 2, "dynamic fill needs at least 2 grades");
                debug_assert!(action < *grades);
                let ratio = action as f64 / (*grades - 1) as f64;
                let g = (ratio * s_prev as f64).round() as usize;
                g.min(cap)
            }
        }
    }
}

/// A parsed mapping scheme.
#[derive(Clone, Debug, PartialEq)]
pub struct Scheme {
    /// Diagonal block lengths in grid cells; sums to the grid count N.
    pub diag_len: Vec<usize>,
    /// Fill block lengths in grid cells, one per junction
    /// (len = diag_len.len() - 1). 0 = no fill at that junction.
    pub fill_len: Vec<usize>,
}

impl Scheme {
    /// Diagonal block sizes in matrix units (Table II/IV "Diagonal-blocks
    /// size" column — trailing block truncated at the matrix edge).
    pub fn diag_sizes_units(&self, g: &GridSummary) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.diag_len.len());
        let mut g0 = 0;
        for &len in &self.diag_len {
            out.push(g.span_units(g0, len));
            g0 += len;
        }
        out
    }

    /// All block rectangles in grid coordinates: diagonal blocks then the
    /// two symmetric rectangles per non-zero fill junction.
    pub fn rects(&self) -> Vec<GridRect> {
        let mut rects = Vec::with_capacity(self.diag_len.len() + 2 * self.fill_len.len());
        let mut g0 = 0;
        let mut boundaries = Vec::with_capacity(self.fill_len.len());
        for &len in &self.diag_len {
            rects.push(GridRect::square(g0, len));
            g0 += len;
            boundaries.push(g0);
        }
        boundaries.pop(); // last boundary is the matrix edge, not a junction
        for (&b, &f) in boundaries.iter().zip(self.fill_len.iter()) {
            if f == 0 {
                continue;
            }
            // upper-right square touching the junction from above...
            rects.push(GridRect {
                r0: b - f,
                r1: b,
                c0: b,
                c1: b + f,
            });
            // ...and its transpose below the diagonal
            rects.push(GridRect {
                r0: b,
                r1: b + f,
                c0: b - f,
                c1: b,
            });
        }
        rects
    }

    /// Grid count N this scheme spans.
    pub fn grid_count(&self) -> usize {
        self.diag_len.iter().sum()
    }

    /// Validate the paper's structural principles: blocks tile the
    /// diagonal, fills are junction-clamped, nothing exceeds the area,
    /// nothing overlaps.
    pub fn validate(&self, n: usize) -> Result<(), String> {
        if self.diag_len.is_empty() || self.diag_len.iter().any(|&l| l == 0) {
            return Err("diagonal blocks must be non-empty".into());
        }
        if self.grid_count() != n {
            return Err(format!(
                "diagonal blocks cover {} grid cells, expected {n}",
                self.grid_count()
            ));
        }
        if self.fill_len.len() != self.diag_len.len() - 1 {
            return Err(format!(
                "expected {} fill slots, got {}",
                self.diag_len.len() - 1,
                self.fill_len.len()
            ));
        }
        for (j, &f) in self.fill_len.iter().enumerate() {
            let cap = self.diag_len[j].min(self.diag_len[j + 1]);
            if f > cap {
                return Err(format!(
                    "fill {f} at junction {j} exceeds neighbour cap {cap}"
                ));
            }
        }
        // no-overlap: diagonal blocks are disjoint by construction; fills
        // are clamped to the junction's neighbours so they can only overlap
        // a *diagonal* block if f > cap (checked above); two fills at
        // adjacent junctions could only overlap if f_j + f_{j+1} exceeded
        // the block between them on the same side — impossible since each
        // is ≤ that block's length and they occupy opposite corners; we
        // still verify pairwise as defence in depth.
        let rects = self.rects();
        for i in 0..rects.len() {
            for j in (i + 1)..rects.len() {
                if rects[i].intersects(&rects[j]) {
                    return Err(format!("blocks {i} and {j} overlap: {:?} {:?}", rects[i], rects[j]));
                }
            }
        }
        if let Some(r) = rects.iter().find(|r| r.r1 > n || r.c1 > n) {
            return Err(format!("block {r:?} exceeds the {n}-cell grid"));
        }
        Ok(())
    }
}

/// Parse raw agent actions into a scheme.
///
/// `d_actions` has length N-1 (one per interior grid boundary; 0 = start a
/// new block, 1 = extend). `f_actions` has length N-1 as well — the agent
/// emits a slot per boundary and the parser *masks* it: only boundaries
/// where `d == 0` consume their fill action (Algo. 1 line 10).
pub fn parse_actions(
    n: usize,
    d_actions: &[u8],
    f_actions: &[usize],
    rule: FillRule,
) -> Scheme {
    assert!(n >= 1);
    assert_eq!(d_actions.len(), n.saturating_sub(1), "need N-1 diagonal actions");
    if rule != FillRule::None {
        assert_eq!(f_actions.len(), n.saturating_sub(1), "need N-1 fill slots");
    }

    let mut diag_len = Vec::new();
    let mut cur = 1usize;
    for &d in d_actions {
        if d == 0 {
            diag_len.push(cur);
            cur = 1;
        } else {
            cur += 1;
        }
    }
    diag_len.push(cur);

    // fill decisions: one per junction, i.e. per d==0 boundary, in order.
    let mut fill_len = Vec::with_capacity(diag_len.len() - 1);
    if rule != FillRule::None {
        let mut junction = 0usize;
        for (i, &d) in d_actions.iter().enumerate() {
            if d == 0 {
                let s_prev = diag_len[junction];
                let s_next = diag_len[junction + 1];
                fill_len.push(rule.fill_len(f_actions[i], s_prev, s_next));
                junction += 1;
            }
        }
    } else {
        fill_len = vec![0; diag_len.len() - 1];
    }
    Scheme { diag_len, fill_len }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::check;

    #[test]
    fn parse_all_extend_is_single_block() {
        let s = parse_actions(5, &[1, 1, 1, 1], &[0, 0, 0, 0], FillRule::None);
        assert_eq!(s.diag_len, vec![5]);
        assert_eq!(s.fill_len, vec![]);
        s.validate(5).unwrap();
    }

    #[test]
    fn parse_all_start_is_unit_blocks() {
        let s = parse_actions(4, &[0, 0, 0], &[1, 1, 1], FillRule::Fixed { size: 1 });
        assert_eq!(s.diag_len, vec![1, 1, 1, 1]);
        assert_eq!(s.fill_len, vec![1, 1, 1]);
        s.validate(4).unwrap();
    }

    #[test]
    fn parse_mixed_matches_paper_example() {
        // paper QM7 grid 2 (N=11): diagonal-blocks size [8,2,12] in matrix
        // units = [4,1,6] grid cells -> boundaries at 4 and 5.
        let d = [1, 1, 1, 0, 0, 1, 1, 1, 1, 1];
        let s = parse_actions(11, &d, &[0; 10], FillRule::None);
        assert_eq!(s.diag_len, vec![4, 1, 6]);
    }

    #[test]
    fn fill_mask_only_consumes_at_starts() {
        // d: boundaries 0,1 extend; boundary 2 starts (junction 0);
        // boundary 3 starts (junction 1).
        let d = [1, 1, 0, 0];
        let f = [9, 9, 1, 0]; // slots 0,1 must be ignored
        let s = parse_actions(5, &d, &f, FillRule::Fixed { size: 2 });
        assert_eq!(s.diag_len, vec![3, 1, 1]);
        // junction 0: cap = min(3,1) = 1 -> fill size min(2,1)=1 (action 1)
        // junction 1: action 0 -> no fill
        assert_eq!(s.fill_len, vec![1, 0]);
        s.validate(5).unwrap();
    }

    #[test]
    fn dynamic_fill_grades() {
        let rule = FillRule::Dynamic { grades: 4 };
        // 4 grades => ratios [0, 1/3, 2/3, 1].
        // s_prev=6, s_next=9: z=1 -> round(6/3)=2; z=3 -> 6; z=0 -> 0
        assert_eq!(rule.fill_len(1, 6, 9), 2);
        assert_eq!(rule.fill_len(3, 6, 9), 6);
        assert_eq!(rule.fill_len(0, 6, 9), 0);
        // clamped by next: s_prev=6, s_next=2, z=3 -> min(6,2)=2
        assert_eq!(rule.fill_len(3, 6, 2), 2);
        assert_eq!(rule.num_classes(), 4);
        assert_eq!(FillRule::Fixed { size: 3 }.num_classes(), 2);
        assert_eq!(FillRule::None.num_classes(), 0);
    }

    #[test]
    fn rects_geometry() {
        let s = Scheme {
            diag_len: vec![3, 2],
            fill_len: vec![2],
        };
        let rects = s.rects();
        assert_eq!(rects.len(), 4);
        assert_eq!(rects[0], GridRect::square(0, 3));
        assert_eq!(rects[1], GridRect::square(3, 2));
        assert_eq!(rects[2], GridRect { r0: 1, r1: 3, c0: 3, c1: 5 });
        assert_eq!(rects[3], GridRect { r0: 3, r1: 5, c0: 1, c1: 3 });
        s.validate(5).unwrap();
    }

    #[test]
    fn validate_catches_bad_schemes() {
        assert!(Scheme { diag_len: vec![], fill_len: vec![] }.validate(0).is_err());
        assert!(Scheme { diag_len: vec![2, 0], fill_len: vec![0] }.validate(2).is_err());
        assert!(Scheme { diag_len: vec![2, 2], fill_len: vec![0] }.validate(5).is_err());
        assert!(Scheme { diag_len: vec![2, 2], fill_len: vec![3] }.validate(4).is_err());
        assert!(Scheme { diag_len: vec![2, 2], fill_len: vec![] }.validate(4).is_err());
    }

    #[test]
    fn parsed_schemes_always_validate_property() {
        check("parse_validates", 100, |rng| {
            let n = 2 + rng.below(60) as usize;
            let grades = 2 + rng.below(5) as usize;
            let rule = match rng.below(3) {
                0 => FillRule::None,
                1 => FillRule::Fixed { size: 1 + rng.below(4) as usize },
                _ => FillRule::Dynamic { grades },
            };
            let d: Vec<u8> = (0..n - 1).map(|_| rng.below(2) as u8).collect();
            let f: Vec<usize> = (0..n - 1)
                .map(|_| rng.below(rule.num_classes().max(1) as u64) as usize)
                .collect();
            let s = parse_actions(n, &d, &f, rule);
            s.validate(n).map_err(|e| format!("n={n} rule={rule:?}: {e}"))
        });
    }

    #[test]
    fn blocks_partition_diagonal_property() {
        check("parse_partition", 100, |rng| {
            let n = 2 + rng.below(100) as usize;
            let d: Vec<u8> = (0..n - 1).map(|_| rng.below(2) as u8).collect();
            let s = parse_actions(n, &d, &[], FillRule::None);
            if s.grid_count() == n && s.diag_len.len() == d.iter().filter(|&&x| x == 0).count() + 1 {
                Ok(())
            } else {
                Err(format!("bad partition {:?} for n={n}", s.diag_len))
            }
        });
    }
}
