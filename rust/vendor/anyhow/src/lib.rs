//! Offline shim for the subset of `anyhow` this repository uses.
//!
//! The vendored crate set contains no registry dependencies, so this crate
//! re-implements the `anyhow` surface the codebase relies on — `Error`,
//! `Result`, the `Context` trait for `Result` and `Option`, and the
//! `anyhow!` / `bail!` / `ensure!` macros — with the same semantics:
//!
//! - `Display` prints the outermost message only;
//! - alternate `Display` (`{:#}`) prints the whole context chain joined
//!   with `": "` (what the CLI prints on fatal errors);
//! - any `std::error::Error + Send + Sync + 'static` converts into
//!   [`Error`] via `?`, capturing its `source()` chain.

use std::fmt;

/// Error type: an ordered context chain, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error {
            chain: vec![msg.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, ctx: C) -> Error {
        self.chain.insert(0, ctx.to_string());
        self
    }

    /// The context chain, outermost first (mirrors `anyhow::Error::chain`).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Like real `anyhow`, `Error` deliberately does NOT implement
// `std::error::Error`: that keeps the blanket `From` below coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src: Option<&(dyn std::error::Error + 'static)> = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (`Result`) or turn `None` into an error
/// (`Option`), exactly like `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(format!(
                "condition failed: {}",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file gone")
    }

    #[test]
    fn display_outer_alternate_chain() {
        let e: Error = Error::from(io_err()).context("reading config");
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: file gone");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert!(format!("{e:#}").contains("file gone"));

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "x")).unwrap_err();
        assert_eq!(format!("{e}"), "missing x");
        assert_eq!(Some(3).context("fine").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            ensure!(x != 7);
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(1).unwrap(), 1);
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert!(format!("{}", f(7).unwrap_err()).contains("condition failed"));
        assert!(format!("{}", f(3).unwrap_err()).contains("three"));
        let msg = String::from("plain");
        assert_eq!(format!("{}", anyhow!(msg)), "plain");
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(format!("{:#}", f().unwrap_err()).contains("file gone"));
    }
}
