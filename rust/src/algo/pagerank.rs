//! Damped PageRank as iterated SpMV over the mapped structure.
//!
//! The GraphR formulation: per sweep the crossbar computes `y = A q` with
//! `q = D⁻¹ p` (ranks pre-divided by degree on the host), and the
//! post-step applies damping plus the teleport term:
//!
//! ```text
//! p'ᵢ = d·yᵢ + (d·dangling + (1 − d)) / n
//! ```
//!
//! where `dangling = Σ_{deg_j = 0} p_j` redistributes the rank parked on
//! isolated nodes. Degrees come from one extra MVM (`deg = A·1` — the row
//! sums, which equal the column sums on the symmetric graphs this repo
//! builds), so the whole algorithm touches the arena only through plain
//! MVMs. On a stochastic iterate the total rank is invariant:
//! `Σp' = d·Σ_{deg>0} p + d·dangling + (1−d) = 1` whenever `Σp = 1` — the
//! mass-conservation invariant the property suite checks every iteration.
//!
//! Convergence is an L1 residual `‖p' − p‖₁ < tol`; a run that exhausts
//! `max_iters` first fails with [`Error::NoConverge`]. Setting `tol = 0`
//! selects *fixed-iteration mode*: exactly `max_iters` sweeps, no
//! convergence claim, never an error — the mode the oracle comparisons
//! use to pin identical iteration counts on both engines.

use super::{AlgoTrace, MvmEngine};
use crate::api::error::{Error, Result};
use std::time::Instant;

/// PageRank knobs; the defaults are the wire defaults of the
/// `{"pagerank":{...}}` request kind.
#[derive(Clone, Copy, Debug)]
pub struct PageRankOptions {
    /// damping factor `d` in `[0, 1)`
    pub damping: f64,
    /// L1 convergence threshold; `0` = fixed-iteration mode
    pub tol: f64,
    /// sweep cap; exceeding it with `tol > 0` is a typed `no_converge`
    pub max_iters: usize,
}

impl Default for PageRankOptions {
    fn default() -> PageRankOptions {
        // the cap must leave room for the tolerance at the default
        // damping: the L1 residual contracts by at most d per sweep, so
        // reaching 1e-9 needs ~ln(1e-9)/ln(0.85) ≈ 130 sweeps — 200 keeps
        // the default request convergent instead of a guaranteed
        // `no_converge`
        PageRankOptions {
            damping: 0.85,
            tol: 1e-9,
            max_iters: 200,
        }
    }
}

impl PageRankOptions {
    /// Validate the knob ranges with messages that name the wire field.
    pub fn validate(&self) -> Result<()> {
        if !self.damping.is_finite() || !(0.0..1.0).contains(&self.damping) {
            return Err(Error::Validate(format!(
                "pagerank.damping must be in [0, 1); got {}",
                self.damping
            )));
        }
        if !self.tol.is_finite() || self.tol < 0.0 {
            return Err(Error::Validate(format!(
                "pagerank.tol must be a finite number >= 0; got {}",
                self.tol
            )));
        }
        if self.max_iters == 0 {
            return Err(Error::Validate(
                "pagerank.max_iters must be at least 1".into(),
            ));
        }
        Ok(())
    }
}

/// Run damped power iteration on `engine`. Returns the rank vector
/// (summing to 1) and the run's [`AlgoTrace`].
pub fn pagerank<E: MvmEngine>(engine: &E, opts: &PageRankOptions) -> Result<(Vec<f64>, AlgoTrace)> {
    opts.validate()?;
    let n = engine.dim();
    if n == 0 {
        return Err(Error::Validate("pagerank needs a non-empty graph".into()));
    }
    let t0 = Instant::now();
    let nf = n as f64;

    // deg = A·1: weighted out-degrees (== in-degrees on symmetric graphs)
    let deg = engine.mvm_one(vec![1.0; n]);
    let mut mvms = 1u64;

    let mut p = vec![1.0 / nf; n];
    let mut residuals = Vec::new();
    let mut converged = false;
    let mut iterations = 0usize;

    while iterations < opts.max_iters {
        let mut q = vec![0.0; n];
        let mut dangling = 0.0;
        for j in 0..n {
            if deg[j] > 0.0 {
                q[j] = p[j] / deg[j];
            } else {
                dangling += p[j];
            }
        }
        let y = engine.mvm_one(q);
        mvms += 1;
        let base = (opts.damping * dangling + (1.0 - opts.damping)) / nf;
        let mut residual = 0.0;
        for i in 0..n {
            let next = opts.damping * y[i] + base;
            residual += (next - p[i]).abs();
            p[i] = next;
        }
        residuals.push(residual);
        iterations += 1;
        if opts.tol > 0.0 && residual < opts.tol {
            converged = true;
            break;
        }
    }

    let residual = residuals.last().copied().unwrap_or(0.0);
    if opts.tol > 0.0 && !converged {
        return Err(Error::NoConverge {
            algorithm: "pagerank",
            iterations,
            residual,
        });
    }

    let wall_s = t0.elapsed().as_secs_f64();
    let trace = AlgoTrace {
        algorithm: "pagerank",
        iterations,
        converged,
        residuals,
        mvms,
        nnz_total: mvms * engine.nnz(),
        wall_s,
    };
    Ok((p, trace))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::CsrEngine;
    use crate::graph::{synth, Coo};

    #[test]
    fn converges_on_small_graph_and_conserves_mass() {
        let a = synth::qm7_like(5828);
        let opts = PageRankOptions { tol: 1e-12, max_iters: 500, ..Default::default() };
        let (p, trace) = pagerank(&CsrEngine(&a), &opts).unwrap();
        assert!(trace.converged);
        assert!(trace.iterations < 500);
        let mass: f64 = p.iter().sum();
        assert!((mass - 1.0).abs() < 1e-9, "mass {mass}");
        assert!(p.iter().all(|&v| v > 0.0));
        // residual curve is recorded per iteration and ends under tol
        assert_eq!(trace.residuals.len(), trace.iterations);
        assert!(*trace.residuals.last().unwrap() < 1e-12);
        assert_eq!(trace.mvms, trace.iterations as u64 + 1);
    }

    #[test]
    fn fixed_iteration_mode_runs_exactly_max_iters() {
        let a = synth::qm7_like(5828);
        let opts = PageRankOptions { tol: 0.0, max_iters: 7, ..Default::default() };
        let (_, trace) = pagerank(&CsrEngine(&a), &opts).unwrap();
        assert_eq!(trace.iterations, 7);
        assert!(!trace.converged);
    }

    #[test]
    fn exhausting_the_cap_is_a_typed_no_converge() {
        let a = synth::rmat_like(64, 256, 5);
        let opts = PageRankOptions { tol: 1e-15, max_iters: 2, ..Default::default() };
        let err = pagerank(&CsrEngine(&a), &opts).unwrap_err();
        assert_eq!(err.kind(), "no_converge");
        assert!(err.to_string().contains("pagerank"), "{err}");
    }

    #[test]
    fn dangling_mass_is_redistributed() {
        // node 2 is isolated: its rank must teleport, not vanish
        let mut coo = Coo::new(3, 3);
        coo.push_sym(0, 1, 1.0);
        let a = coo.to_csr();
        let opts = PageRankOptions { tol: 1e-12, max_iters: 200, ..Default::default() };
        let (p, _) = pagerank(&CsrEngine(&a), &opts).unwrap();
        let mass: f64 = p.iter().sum();
        assert!((mass - 1.0).abs() < 1e-9);
        assert!(p[2] > 0.0);
    }

    #[test]
    fn bad_parameters_name_the_field() {
        let bad = PageRankOptions { damping: 1.5, ..Default::default() };
        let err = bad.validate().unwrap_err();
        assert!(err.to_string().contains("pagerank.damping"), "{err}");
        let bad = PageRankOptions { max_iters: 0, ..Default::default() };
        assert!(bad.validate().unwrap_err().to_string().contains("pagerank.max_iters"));
        let bad = PageRankOptions { tol: f64::NAN, ..Default::default() };
        assert!(bad.validate().unwrap_err().to_string().contains("pagerank.tol"));
    }
}
