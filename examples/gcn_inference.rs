//! End-to-end driver: spectral GCN inference through mapped crossbars.
//!
//! This is the workload the paper's §III motivates (Eq. 1): the GCN's
//! normalized adjacency Â is the sparse matrix mapped onto crossbars. The
//! pipeline exercised here is the full stack:
//!
//!   synth graph → CM reorder (Eq. 3) → RL-trained mapping scheme →
//!   crossbar tile placement → per-layer propagation with the switch
//!   circuit (x'=Px in, y=Pᵀy' out, Eqs. 4-6)
//!
//! computed twice: through the host crossbar simulator AND — when an
//! `artifacts/` directory exists — through the AOT `mvm_qm7` artifact (the
//! L1 Pallas block_mvm kernel via PJRT). Both are verified against the
//! dense oracle, and latency/throughput + the crossbar cost model are
//! reported.
//!
//! Run: `cargo run --release --example gcn_inference`
//! (fresh checkout: trains on the native backend and skips the PJRT
//! section; `make artifacts` enables the AOT path end-to-end)

use autogmap::coordinator::config::{Dataset, ExperimentConfig};
use autogmap::coordinator::{run_experiment, RunnerOptions};
use autogmap::crossbar::cost::CostModel;
use autogmap::crossbar::switch::SwitchCircuit;
use autogmap::crossbar::{place, CrossbarArray};
use autogmap::gcn::{max_abs_diff, normalized_adjacency, GcnLayer};
use autogmap::graph::GridSummary;
use autogmap::reorder::{reorder, Reordering};
use autogmap::runtime::{literal, Runtime};
use autogmap::scheme::FillRule;
use autogmap::util::rng::Pcg64;
use std::time::Instant;

/// Run one y' = A'x' pass through the AOT block_mvm artifact.
fn mvm_via_artifact(
    rt: &Runtime,
    arr: &CrossbarArray,
    nb: usize,
    nr: usize,
    xp: &[f64],
) -> anyhow::Result<Vec<f64>> {
    let manifest = rt.manifest()?;
    let entry = manifest.mvm_entry("mvm_qm7")?;
    anyhow::ensure!(entry.k == arr.k && entry.nb == nb && entry.nr == nr);
    let exe = rt.load(&entry.artifact)?;
    let k = arr.k;
    anyhow::ensure!(arr.tiles.len() <= nb, "scheme needs more tiles than the artifact holds");
    let mut tiles = vec![0.0f32; nb * k * k];
    let mut x_tiles = vec![0.0f32; nb * k];
    let mut onehot = vec![0.0f32; nb * nr];
    for (i, t) in arr.tiles.iter().enumerate() {
        tiles[i * k * k..(i + 1) * k * k].copy_from_slice(&t.g);
        for j in 0..k.min(arr.dim - t.col0) {
            x_tiles[i * k + j] = xp[t.col0 + j] as f32;
        }
        onehot[i * nr + t.row0 / k] = 1.0;
    }
    let outs = exe.run(&[
        literal::lit_f32(&tiles, &[nb as i64, k as i64, k as i64])?,
        literal::lit_f32(&x_tiles, &[nb as i64, k as i64])?,
        literal::lit_f32(&onehot, &[nb as i64, nr as i64])?,
    ])?;
    let seg = outs[0].to_vec::<f32>()?; // [NR, K]
    Ok(seg.iter().take(arr.dim).map(|&v| v as f64).collect())
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new("artifacts")?;

    // --- build the GCN workload on the molecule graph
    let a = autogmap::graph::synth::qm7_like(5828);
    let a_norm = normalized_adjacency(&a);
    let r = reorder(&a_norm, Reordering::CuthillMckee);
    let grid = GridSummary::new(&r.matrix, 2);
    let sw = SwitchCircuit::new(r.perm.clone());

    // --- train a mapping scheme for Â (the paper's core contribution)
    let cfg = ExperimentConfig {
        name: "gcn_map".into(),
        dataset: Dataset::Qm7 { seed: 5828 }, // same sparsity pattern as Â minus self-loops
        grid: 2,
        reordering: Reordering::CuthillMckee,
        controller: "qm7_dyn4".into(),
        fill_rule: FillRule::Dynamic { grades: 4 },
        reward_a: 0.8,
        lr: 0.015,
        ent_coef: 0.002,
        baseline_decay: 0.95,
        epochs: 2500,
        seed: 7,
        log_every: 0,
    };
    // Â has the same off-diagonal pattern as A plus the diagonal, which the
    // diagonal blocks always cover — but train on Â's own grid to be exact.
    // The default `auto` backend trains through PJRT when artifacts exist
    // and on the pure-Rust native backend otherwise.
    let result = run_experiment(Some(&rt), &cfg, &RunnerOptions::default())?;
    let mut best = result.best.expect("no complete-coverage scheme").scheme;
    // re-validate on Â's grid (self-loops only add diagonal cells)
    let eval = autogmap::scheme::evaluate(&best, &grid, cfg.weights());
    if eval.coverage_ratio < 1.0 {
        println!("scheme misses Â's self-loops; falling back to full block");
        best = autogmap::scheme::Scheme { diag_len: vec![grid.n], fill_len: vec![] };
    }
    let eval = autogmap::scheme::evaluate(&best, &grid, cfg.weights());
    println!(
        "mapping scheme for Â: diag {:?}, coverage {:.3}, area {:.3}",
        best.diag_sizes_units(&grid),
        eval.coverage_ratio,
        eval.area_ratio
    );

    // --- place on crossbars
    let arr = place(&r.matrix, &grid, &best)?;
    let cost = CostModel::default().estimate(&arr, sw.crossover_count());
    println!(
        "placed {} tiles of {}×{} ({} cells, {:.1} nJ/pass, {:.1} µs/pass, {} row segments)",
        cost.tiles,
        arr.k,
        arr.k,
        cost.cells,
        cost.energy_pj / 1e3,
        cost.latency_ns / 1e3,
        cost.row_segments
    );

    // --- two-layer GCN inference
    let n = a.rows;
    let (f_in, f_hidden, f_out) = (8, 16, 4);
    let layer1 = GcnLayer::random(f_in, f_hidden, true, 1);
    let layer2 = GcnLayer::random(f_hidden, f_out, false, 2);
    let mut rng = Pcg64::seed_from_u64(3);
    let z0: Vec<f64> = (0..n * f_in).map(|_| rng.uniform(-1.0, 1.0)).collect();

    // dense oracle
    let t0 = Instant::now();
    let dense = layer2.forward_dense(&a_norm, &layer1.forward_dense(&a_norm, &z0));
    let dense_time = t0.elapsed();

    // crossbar simulator path
    let t0 = Instant::now();
    let h1 = layer1.forward_crossbar(&arr, &sw, &z0)?;
    let xbar = layer2.forward_crossbar(&arr, &sw, &h1)?;
    let sim_time = t0.elapsed();
    let diff = max_abs_diff(&dense, &xbar);
    println!(
        "\ncrossbar-sim 2-layer GCN: max|Δ| vs dense = {diff:.2e}  \
         (dense {dense_time:?}, sim {sim_time:?})"
    );
    anyhow::ensure!(diff < 1e-6, "crossbar GCN diverged from dense oracle");

    // AOT Pallas-kernel path for one representative propagation column
    // (needs built artifacts; a fresh checkout stops at the verified
    // crossbar-simulator path above)
    let manifest = match rt.manifest() {
        Ok(m) => m,
        Err(_) => {
            println!(
                "\nno artifacts manifest — skipping the AOT block_mvm path \
                 (run `make artifacts` to enable it)"
            );
            println!("\nend-to-end OK: scheme → tiles → switch circuit → GCN verified (host sim)");
            return Ok(());
        }
    };
    let mv = manifest.mvm_entry("mvm_qm7")?;
    let col: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
    let xp = sw.forward(&col);
    let t0 = Instant::now();
    let mut iters = 0;
    let mut yp = Vec::new();
    while t0.elapsed().as_millis() < 300 {
        yp = mvm_via_artifact(&rt, &arr, mv.nb, mv.nr, &xp)?;
        iters += 1;
    }
    let per_call = t0.elapsed().as_secs_f64() / iters as f64;
    let y = sw.inverse(&yp);
    let want = a_norm.spmv(&col);
    let diff = max_abs_diff(&y, &want);
    println!(
        "AOT block_mvm artifact (PJRT, L1 Pallas): max|Δ| vs dense = {diff:.2e}, \
         {:.2} ms/pass ({iters} calls), {:.1} propagations/s",
        per_call * 1e3,
        1.0 / per_call
    );
    anyhow::ensure!(diff < 1e-4, "AOT crossbar path diverged");

    println!("\nend-to-end OK: scheme → tiles → switch circuit → GCN verified on all paths");
    Ok(())
}
