//! Sparse matrix core types: COO and CSR, tailored to symmetric graph
//! adjacency matrices (the paper's workload) but general enough for the
//! crossbar simulator's block extraction.

/// Coordinate-format sparse matrix. Entries may arrive unsorted; `to_csr`
/// sorts and deduplicates (last write wins, mirroring typical assembly).
#[derive(Clone, Debug, Default)]
pub struct Coo {
    pub rows: usize,
    pub cols: usize,
    pub entries: Vec<(usize, usize, f64)>,
}

impl Coo {
    pub fn new(rows: usize, cols: usize) -> Self {
        Coo {
            rows,
            cols,
            entries: Vec::new(),
        }
    }

    pub fn push(&mut self, r: usize, c: usize, v: f64) {
        debug_assert!(r < self.rows && c < self.cols, "entry out of bounds");
        self.entries.push((r, c, v));
    }

    /// Insert both (r,c) and (c,r); for building symmetric adjacencies.
    pub fn push_sym(&mut self, r: usize, c: usize, v: f64) {
        self.push(r, c, v);
        if r != c {
            self.push(c, r, v);
        }
    }

    pub fn nnz(&self) -> usize {
        self.entries.len()
    }

    pub fn to_csr(&self) -> Csr {
        let mut entries = self.entries.clone();
        entries.sort_by_key(|&(r, c, _)| (r, c));
        entries.dedup_by(|later, earlier| {
            if later.0 == earlier.0 && later.1 == earlier.1 {
                // keep the later value (last write wins)
                earlier.2 = later.2;
                true
            } else {
                false
            }
        });
        let mut indptr = vec![0usize; self.rows + 1];
        for &(r, _, _) in &entries {
            indptr[r + 1] += 1;
        }
        for i in 0..self.rows {
            indptr[i + 1] += indptr[i];
        }
        let indices = entries.iter().map(|&(_, c, _)| c).collect();
        let data = entries.iter().map(|&(_, _, v)| v).collect();
        Csr {
            rows: self.rows,
            cols: self.cols,
            indptr,
            indices,
            data,
        }
    }
}

/// Compressed-sparse-row matrix; the canonical in-memory representation.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    /// Length rows+1.
    pub indptr: Vec<usize>,
    /// Column index per entry, sorted within each row.
    pub indices: Vec<usize>,
    pub data: Vec<f64>,
}

impl Csr {
    /// Identity adjacency of size n (used for Â = A + I normalization).
    pub fn identity(n: usize) -> Csr {
        Csr {
            rows: n,
            cols: n,
            indptr: (0..=n).collect(),
            indices: (0..n).collect(),
            data: vec![1.0; n],
        }
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Fraction of zero entries, the paper's "sparsity of original matrix".
    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz() as f64 / (self.rows as f64 * self.cols as f64)
    }

    /// Column indices of row r (sorted).
    pub fn row(&self, r: usize) -> &[usize] {
        &self.indices[self.indptr[r]..self.indptr[r + 1]]
    }

    /// Values of row r.
    pub fn row_vals(&self, r: usize) -> &[f64] {
        &self.data[self.indptr[r]..self.indptr[r + 1]]
    }

    pub fn get(&self, r: usize, c: usize) -> f64 {
        let cols = self.row(r);
        match cols.binary_search(&c) {
            Ok(i) => self.data[self.indptr[r] + i],
            Err(_) => 0.0,
        }
    }

    pub fn degree(&self, r: usize) -> usize {
        self.indptr[r + 1] - self.indptr[r]
    }

    /// True when the sparsity pattern and values are symmetric.
    pub fn is_symmetric(&self) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for r in 0..self.rows {
            for (i, &c) in self.row(r).iter().enumerate() {
                let v = self.data[self.indptr[r] + i];
                if (self.get(c, r) - v).abs() > 1e-12 {
                    return false;
                }
            }
        }
        true
    }

    /// Matrix bandwidth: max |r - c| over non-zeros.
    pub fn bandwidth(&self) -> usize {
        let mut bw = 0;
        for r in 0..self.rows {
            for &c in self.row(r) {
                bw = bw.max(r.abs_diff(c));
            }
        }
        bw
    }

    /// Envelope/profile: Σ_r (r - min col in row r), a finer reordering
    /// quality metric than bandwidth.
    pub fn profile(&self) -> usize {
        let mut p = 0;
        for r in 0..self.rows {
            if let Some(&c0) = self.row(r).first() {
                p += r.saturating_sub(c0);
            }
        }
        p
    }

    /// Dense row-major expansion (small matrices / tests / viz only).
    pub fn to_dense(&self) -> Vec<f64> {
        let mut d = vec![0.0; self.rows * self.cols];
        for r in 0..self.rows {
            for (i, &c) in self.row(r).iter().enumerate() {
                d[r * self.cols + c] = self.data[self.indptr[r] + i];
            }
        }
        d
    }

    /// y = A x (reference SpMV used by tests and the dense oracle).
    pub fn spmv(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols, "spmv dimension mismatch");
        let mut y = vec![0.0; self.rows];
        for r in 0..self.rows {
            let mut acc = 0.0;
            for (i, &c) in self.row(r).iter().enumerate() {
                acc += self.data[self.indptr[r] + i] * x[c];
            }
            y[r] = acc;
        }
        y
    }

    /// Apply a symmetric permutation: B = P A Pᵀ where `perm[new] = old`
    /// (i.e. row `new` of B is row `perm[new]` of A). Eq. (3) of the paper.
    pub fn permute_sym(&self, perm: &[usize]) -> Csr {
        assert_eq!(self.rows, self.cols, "symmetric permutation needs square");
        assert_eq!(perm.len(), self.rows);
        // inverse: inv[old] = new
        let mut inv = vec![0usize; perm.len()];
        for (new, &old) in perm.iter().enumerate() {
            inv[old] = new;
        }
        let mut coo = Coo::new(self.rows, self.cols);
        for r in 0..self.rows {
            for (i, &c) in self.row(r).iter().enumerate() {
                coo.push(inv[r], inv[c], self.data[self.indptr[r] + i]);
            }
        }
        coo.to_csr()
    }

    /// Extract the dense k×k block with top-left corner (r0, c0), truncated
    /// at the matrix edge (truncated area is zero-padded). Used by the
    /// crossbar programming path.
    pub fn dense_block(&self, r0: usize, c0: usize, k: usize) -> Vec<f64> {
        let mut out = vec![0.0; k * k];
        let rend = (r0 + k).min(self.rows);
        for r in r0..rend {
            let cols = self.row(r);
            let vals = self.row_vals(r);
            // binary search the first column >= c0
            let start = cols.partition_point(|&c| c < c0);
            for i in start..cols.len() {
                let c = cols[i];
                if c >= c0 + k || c >= self.cols {
                    break;
                }
                out[(r - r0) * k + (c - c0)] = vals[i];
            }
        }
        out
    }

    /// Count non-zeros inside the half-open rectangle rows [r0,r1) × cols [c0,c1).
    pub fn nnz_in_rect(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> usize {
        let mut n = 0;
        for r in r0..r1.min(self.rows) {
            let cols = self.row(r);
            let lo = cols.partition_point(|&c| c < c0);
            let hi = cols.partition_point(|&c| c < c1);
            n += hi - lo;
        }
        n
    }

    /// Serialize to a JSON object (`rows`, `cols`, `indptr`, `indices`,
    /// `data`) for embedding in deployment bundles. Values round-trip
    /// exactly: the writer emits shortest-round-trip decimal for every
    /// finite f64.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{num_arr, obj, Json};
        obj(vec![
            ("rows", Json::Num(self.rows as f64)),
            ("cols", Json::Num(self.cols as f64)),
            ("indptr", num_arr(self.indptr.iter().map(|&v| v as f64))),
            ("indices", num_arr(self.indices.iter().map(|&v| v as f64))),
            ("data", num_arr(self.data.iter().copied())),
        ])
    }

    /// Parse and structurally validate a [`Self::to_json`] document:
    /// indptr must be a monotone length-`rows + 1` prefix ending at the
    /// entry count, and every column index must be in range and strictly
    /// increasing within its row.
    pub fn from_json(doc: &crate::util::json::Json) -> Result<Csr, String> {
        let rows = doc.get("rows").as_usize().ok_or("csr missing rows")?;
        let cols = doc.get("cols").as_usize().ok_or("csr missing cols")?;
        let read_usizes = |key: &str| -> Result<Vec<usize>, String> {
            let arr = doc.get(key).as_arr().ok_or_else(|| format!("csr missing {key}"))?;
            let mut out = Vec::with_capacity(arr.len());
            for (i, v) in arr.iter().enumerate() {
                out.push(v.as_usize().ok_or_else(|| format!("csr {key}[{i}] not an index"))?);
            }
            Ok(out)
        };
        let indptr = read_usizes("indptr")?;
        let indices = read_usizes("indices")?;
        let data_arr = doc.get("data").as_arr().ok_or("csr missing data")?;
        let mut data = Vec::with_capacity(data_arr.len());
        for (i, v) in data_arr.iter().enumerate() {
            data.push(v.as_f64().ok_or_else(|| format!("csr data[{i}] not a number"))?);
        }
        if indptr.len() != rows + 1 {
            return Err(format!("csr indptr has {} entries, expected {}", indptr.len(), rows + 1));
        }
        if indptr[0] != 0 || *indptr.last().unwrap() != indices.len() {
            return Err("csr indptr does not span the entry arrays".into());
        }
        if indices.len() != data.len() {
            return Err(format!(
                "csr has {} indices but {} values",
                indices.len(),
                data.len()
            ));
        }
        for w in indptr.windows(2) {
            if w[0] > w[1] {
                return Err("csr indptr is not monotone".into());
            }
        }
        for r in 0..rows {
            let row = &indices[indptr[r]..indptr[r + 1]];
            for (i, &c) in row.iter().enumerate() {
                if c >= cols {
                    return Err(format!("csr row {r} column {c} out of range"));
                }
                if i > 0 && row[i - 1] >= c {
                    return Err(format!("csr row {r} columns not strictly increasing"));
                }
            }
        }
        Ok(Csr {
            rows,
            cols,
            indptr,
            indices,
            data,
        })
    }
}

/// Permutation helpers (Eqs. 4 and 6: x' = P x, y = Pᵀ y').
pub mod perm {
    /// Apply `out[new] = x[perm[new]]` (x' = P x with perm[new]=old).
    pub fn apply(perm: &[usize], x: &[f64]) -> Vec<f64> {
        perm.iter().map(|&old| x[old]).collect()
    }

    /// Apply the inverse: `out[perm[new]] = y[new]` (y = Pᵀ y').
    pub fn apply_inverse(perm: &[usize], y: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; y.len()];
        for (new, &old) in perm.iter().enumerate() {
            out[old] = y[new];
        }
        out
    }

    /// Validity check: perm is a bijection on 0..n.
    pub fn is_permutation(perm: &[usize]) -> bool {
        let n = perm.len();
        let mut seen = vec![false; n];
        for &p in perm {
            if p >= n || seen[p] {
                return false;
            }
            seen[p] = true;
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Csr {
        // [[1,2,0],[0,0,3],[4,0,5]]
        let mut coo = Coo::new(3, 3);
        coo.push(0, 0, 1.0);
        coo.push(0, 1, 2.0);
        coo.push(1, 2, 3.0);
        coo.push(2, 0, 4.0);
        coo.push(2, 2, 5.0);
        coo.to_csr()
    }

    #[test]
    fn coo_to_csr_sorts_and_counts() {
        let m = small();
        assert_eq!(m.nnz(), 5);
        assert_eq!(m.row(0), &[0, 1]);
        assert_eq!(m.get(2, 0), 4.0);
        assert_eq!(m.get(1, 0), 0.0);
    }

    #[test]
    fn coo_dedup_last_wins() {
        let mut coo = Coo::new(2, 2);
        coo.push(0, 0, 1.0);
        coo.push(0, 0, 9.0);
        let m = coo.to_csr();
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.get(0, 0), 9.0);
    }

    #[test]
    fn spmv_matches_dense() {
        let m = small();
        let x = [1.0, 2.0, 3.0];
        assert_eq!(m.spmv(&x), vec![5.0, 9.0, 19.0]);
    }

    #[test]
    fn bandwidth_and_profile() {
        let m = small();
        assert_eq!(m.bandwidth(), 2);
        // row0 min col 0 -> 0; row1 min col 2 -> 0 (saturating); row2 min col 0 -> 2
        assert_eq!(m.profile(), 2);
    }

    #[test]
    fn permute_sym_roundtrip() {
        let mut coo = Coo::new(4, 4);
        coo.push_sym(0, 3, 1.0);
        coo.push_sym(1, 2, 2.0);
        coo.push(2, 2, 7.0);
        let m = coo.to_csr();
        let perm = vec![2, 0, 3, 1];
        let b = m.permute_sym(&perm);
        // b[new_r][new_c] == m[perm[new_r]][perm[new_c]]
        for nr in 0..4 {
            for nc in 0..4 {
                assert_eq!(b.get(nr, nc), m.get(perm[nr], perm[nc]));
            }
        }
        // permuting back with the inverse recovers m
        let mut inv = vec![0usize; 4];
        for (new, &old) in perm.iter().enumerate() {
            inv[old] = new;
        }
        assert_eq!(b.permute_sym(&inv), m);
    }

    #[test]
    fn perm_vector_roundtrip() {
        let permv = vec![2, 0, 3, 1];
        let x = vec![10.0, 11.0, 12.0, 13.0];
        let xp = perm::apply(&permv, &x);
        assert_eq!(xp, vec![12.0, 10.0, 13.0, 11.0]);
        assert_eq!(perm::apply_inverse(&permv, &xp), x);
        assert!(perm::is_permutation(&permv));
        assert!(!perm::is_permutation(&[0, 0, 1, 2]));
        assert!(!perm::is_permutation(&[0, 5, 1, 2]));
    }

    #[test]
    fn spmv_commutes_with_permutation() {
        // y' = A'x' with A' = PAPᵀ, x' = Px must satisfy y = Pᵀ y' (Eq. 5/6).
        let mut coo = Coo::new(5, 5);
        coo.push_sym(0, 1, 1.0);
        coo.push_sym(1, 3, 2.0);
        coo.push_sym(2, 4, 3.0);
        coo.push(3, 3, 4.0);
        let a = coo.to_csr();
        let permv = vec![4, 2, 0, 3, 1];
        let ap = a.permute_sym(&permv);
        let x = vec![1.0, -2.0, 0.5, 3.0, 2.0];
        let y = a.spmv(&x);
        let yp = ap.spmv(&perm::apply(&permv, &x));
        let back = perm::apply_inverse(&permv, &yp);
        for (u, v) in y.iter().zip(back.iter()) {
            assert!((u - v).abs() < 1e-12);
        }
    }

    #[test]
    fn dense_block_truncates() {
        let m = small();
        let b = m.dense_block(1, 1, 4); // overruns the 3x3 edge
        assert_eq!(b.len(), 16);
        assert_eq!(b[0 * 4 + 1], 3.0); // (1,2)
        assert_eq!(b[1 * 4 + 1], 5.0); // (2,2)
        assert_eq!(b.iter().filter(|v| **v != 0.0).count(), 2);
    }

    #[test]
    fn nnz_in_rect_counts() {
        let m = small();
        assert_eq!(m.nnz_in_rect(0, 3, 0, 3), 5);
        assert_eq!(m.nnz_in_rect(0, 1, 0, 2), 2);
        assert_eq!(m.nnz_in_rect(2, 3, 0, 1), 1);
        assert_eq!(m.nnz_in_rect(1, 2, 0, 2), 0);
    }

    #[test]
    fn symmetry_check() {
        let mut coo = Coo::new(3, 3);
        coo.push_sym(0, 1, 2.0);
        coo.push(2, 2, 1.0);
        assert!(coo.to_csr().is_symmetric());
        let mut coo = Coo::new(3, 3);
        coo.push(0, 1, 2.0);
        assert!(!coo.to_csr().is_symmetric());
    }
}
