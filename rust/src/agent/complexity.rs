//! Analytic computational-complexity model — reproduces Table III.
//!
//! The paper counts the LSTM's BPTT cost as O(T(4IH + 4H² + 3H + HK))
//! multiply-accumulates per epoch (I input size, H hidden size, K the FC
//! cell count), doubling for BiLSTM. We additionally report the exact MAC
//! counts for the per-step FC heads, which the paper folds into HK.

use crate::runtime::manifest::ControllerEntry;

/// Complexity summary for one method row of Table III.
#[derive(Clone, Debug, PartialEq)]
pub struct Complexity {
    pub method: String,
    pub t: usize,
    pub i: usize,
    pub h: usize,
    pub k: usize,
    /// closed-form expression as printed in the paper
    pub formula: String,
    /// evaluated MACs per forward pass
    pub macs: u64,
}

/// Table III row for a controller configuration.
///
/// The paper's accounting: T time steps, each costing 4IH + 4H² (gate
/// matmuls) + 3H (elementwise) + HK (FC head). Our fill variants run *two*
/// LSTM steps per decision point (the masked fill step), which the paper's
/// T column absorbs by listing T=36 for "+Fill" variants versus 12 for the
/// diagonal-only controller; we report effective steps the same way.
pub fn complexity(entry: &ControllerEntry) -> Complexity {
    let h = entry.hidden as u64;
    let i = h; // inputs <- output: I = H
    let k = 1u64; // paper Table III: K = 1 cell per head
    // effective sequential LSTM invocations per episode:
    let t_eff = if entry.fill_classes > 0 {
        2 * entry.steps
    } else {
        entry.steps
    } as u64;
    let per_step = 4 * i * h + 4 * h * h + 3 * h + h * k;
    let dir = if entry.bilstm { 2 } else { 1 };
    // head MACs: diag head H*2 per step (+ fill head H*F on fill steps)
    let head_in = if entry.bilstm { 2 * h } else { h };
    let head_macs = entry.steps as u64 * head_in * 2
        + if entry.fill_classes > 0 {
            entry.steps as u64 * head_in * entry.fill_classes as u64
        } else {
            0
        };
    let macs = dir * t_eff * per_step + head_macs;
    let formula = if entry.bilstm {
        "O(2T(4IH+4H^2+3H+HK))".to_string()
    } else {
        "O(T(4IH+4H^2+3H+HK))".to_string()
    };
    Complexity {
        method: method_name(entry),
        t: t_eff as usize,
        i: i as usize,
        h: h as usize,
        k: k as usize,
        formula,
        macs,
    }
}

fn method_name(entry: &ControllerEntry) -> String {
    match (entry.bilstm, entry.fill_classes) {
        (false, 0) => "LSTM+RL".to_string(),
        (false, 2) => "LSTM+RL+Fill".to_string(),
        (true, _) => "BiLSTM+RL+Fill".to_string(),
        (false, _) => "LSTM+RL+Dynamic-fill".to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ParamSpec;

    fn entry(steps: usize, fill: usize, bilstm: bool) -> ControllerEntry {
        ControllerEntry {
            name: "c".into(),
            n: steps + 1,
            hidden: 10,
            fill_classes: fill,
            batch: 1,
            bilstm,
            steps,
            params: vec![ParamSpec { name: "x0".into(), shape: vec![10] }],
            artifacts: Default::default(),
        }
    }

    #[test]
    fn matches_paper_qm7_time_steps() {
        // paper Table III on QM7 (grid 2): LSTM+RL T=12ish (we have T=N-1=10
        // exactly); +Fill doubles the sequential steps.
        let diag = complexity(&entry(10, 0, false));
        assert_eq!(diag.t, 10);
        assert_eq!(diag.method, "LSTM+RL");
        let fill = complexity(&entry(10, 2, false));
        assert_eq!(fill.t, 20);
        assert_eq!(fill.method, "LSTM+RL+Fill");
        assert!(fill.macs > diag.macs);
        let bi = complexity(&entry(10, 2, true));
        assert_eq!(bi.formula, "O(2T(4IH+4H^2+3H+HK))");
        assert!(bi.macs > 2 * fill.macs / 2);
        let dynf = complexity(&entry(10, 6, false));
        assert_eq!(dynf.method, "LSTM+RL+Dynamic-fill");
    }

    #[test]
    fn mac_count_formula() {
        // H=10, I=10, K=1, T=10 diag-only: 10*(400+400+30+10) = 8400 + heads
        let c = complexity(&entry(10, 0, false));
        assert_eq!(c.macs, 8400 + 10 * 10 * 2);
    }
}
