//! Memristive crossbar simulator — the deployment substrate the paper's
//! schemes are mapped to (Figs. 1 and 5).
//!
//! The simulator models:
//! - **tile placement** ([`place`]): a mapping scheme's blocks decomposed
//!   into discrete K×K crossbar tiles ("the current fabrication technology
//!   … is difficult to fabricate large-scale memristive crossbars" — only
//!   small tiles exist);
//! - **programming** ([`program`]): matrix values → conductances, with
//!   optional n-bit quantization and Gaussian device variation;
//! - **analog compute** ([`CrossbarArray::mvm`]): per-tile Ohm's-law
//!   multiply + Kirchhoff current accumulation; tiles in the same block
//!   row share an output segment (Fig. 5);
//! - **the switch circuit** ([`switch`]): the x' = Px input permutation and
//!   y = Pᵀy' inverse transform (Eqs. 4-6);
//! - **peripheral cost** ([`cost`]): DAC/ADC counts, energy and latency
//!   estimates as functions of the mapped blocks.
//!
//! The AOT path (`runtime` + `mvm_*.hlo.txt`, L1 `block_mvm` Pallas kernel)
//! executes the same tile schedule through PJRT; [`CrossbarArray::mvm`] is
//! the host-side oracle used by tests and the cost model.

pub mod cost;
pub mod program;
pub mod switch;

use crate::graph::{Csr, GridSummary};
use crate::scheme::Scheme;
use anyhow::{ensure, Result};

/// One K×K crossbar tile programmed with a sub-block of the matrix.
#[derive(Clone, Debug)]
pub struct Tile {
    /// top-left corner in matrix units
    pub row0: usize,
    pub col0: usize,
    /// conductances, row-major K×K (zero-padded beyond the matrix edge)
    pub g: Vec<f32>,
}

/// A placed scheme: the discrete-crossbar realization of a mapping scheme.
#[derive(Clone, Debug)]
pub struct CrossbarArray {
    /// physical tile side K (= allowable crossbar size)
    pub k: usize,
    /// matrix dimension D
    pub dim: usize,
    pub tiles: Vec<Tile>,
}

/// Decompose every block of `scheme` into K×K tiles where K = grid cell
/// size, programming tile conductances from the (reordered) matrix.
///
/// Grid cells are exactly crossbar-sized, so every block of L grid cells
/// becomes an L×L arrangement of tiles — matching the paper's setting
/// where "the grid size is set subject to the allowable crossbar's size".
pub fn place(m: &Csr, g: &GridSummary, scheme: &Scheme) -> Result<CrossbarArray> {
    ensure!(
        m.rows == g.dim && m.cols == g.dim,
        "matrix/grid dimension mismatch"
    );
    let k = g.grid;
    let mut tiles = Vec::new();
    for rect in scheme.rects() {
        for gr in rect.r0..rect.r1 {
            for gc in rect.c0..rect.c1 {
                let row0 = gr * k;
                let col0 = gc * k;
                if row0 >= g.dim || col0 >= g.dim {
                    continue; // fully outside (possible for trailing cells)
                }
                let data = m.dense_block(row0, col0, k);
                tiles.push(Tile {
                    row0,
                    col0,
                    g: data.iter().map(|&v| v as f32).collect(),
                });
            }
        }
    }
    Ok(CrossbarArray {
        k,
        dim: g.dim,
        tiles,
    })
}

impl CrossbarArray {
    /// Analog MVM: y' = A' x' over the mapped tiles (Fig. 5). Each tile
    /// contributes `tile.g @ x'[col0..col0+k]` to `y'[row0..row0+k]` —
    /// Ohm's law per cell, Kirchhoff sum along each row wire, and
    /// same-block-row tiles summing into the same output segment.
    ///
    /// Non-zeros outside every tile are *dropped* — exactly the incomplete-
    /// coverage failure mode the paper's complete-coverage principle rules
    /// out; tests assert exactness iff coverage == 1.
    pub fn mvm(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.dim, "input vector length mismatch");
        let mut y = vec![0.0f64; self.dim];
        let k = self.k;
        for tile in &self.tiles {
            let rmax = (self.dim - tile.row0).min(k);
            let cmax = (self.dim - tile.col0).min(k);
            for r in 0..rmax {
                let mut acc = 0.0f64;
                let row = &tile.g[r * k..r * k + cmax];
                let xs = &x[tile.col0..tile.col0 + cmax];
                for (gv, xv) in row.iter().zip(xs.iter()) {
                    acc += *gv as f64 * xv;
                }
                y[tile.row0 + r] += acc;
            }
        }
        y
    }

    /// Total *physical* crossbar area in cells (Σ K²): every tile occupies
    /// a full K×K array, including the zero-padded overhang of
    /// edge-truncated tiles. For matrix-side cost accounting use
    /// [`Self::area_cells_clipped`], which matches the scheme evaluator's
    /// covered-area metric.
    pub fn area_cells(&self) -> u64 {
        (self.tiles.len() as u64) * (self.k as u64) * (self.k as u64)
    }

    /// Clipped extents of a tile: the (rows, cols) of it that actually lie
    /// inside the matrix (≤ K each; smaller only for edge tiles).
    pub fn clipped_extents(&self, tile: &Tile) -> (usize, usize) {
        (
            (self.dim - tile.row0).min(self.k),
            (self.dim - tile.col0).min(self.k),
        )
    }

    /// Programmed cells that lie inside the matrix (Σ rows·cols after edge
    /// clipping). Unlike [`Self::area_cells`] this does not overcount
    /// edge-truncated tiles — for 882 = 27·32 + 18, the 28th tile row and
    /// column contribute 18-wide strips, not full 32s — so a complete
    /// tiling's clipped area equals the scheme's covered matrix-unit area.
    pub fn area_cells_clipped(&self) -> u64 {
        self.tiles
            .iter()
            .map(|t| {
                let (r, c) = self.clipped_extents(t);
                (r * c) as u64
            })
            .sum()
    }

    /// Number of distinct block-row segments (peripheral accumulation
    /// wires; "blocks in the same row are connected").
    pub fn row_segments(&self) -> usize {
        let mut rows: Vec<usize> = self.tiles.iter().map(|t| t.row0).collect();
        rows.sort_unstable();
        rows.dedup();
        rows.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::synth;
    use crate::reorder::{reorder, Reordering};
    use crate::scheme::{evaluate, parse_actions, FillRule, RewardWeights};
    use crate::util::propcheck::check;

    fn setup(grid: usize) -> (Csr, GridSummary) {
        let m = synth::qm7_like(5828);
        let r = reorder(&m, Reordering::CuthillMckee);
        let g = GridSummary::new(&r.matrix, grid);
        (r.matrix, g)
    }

    #[test]
    fn full_block_mvm_is_exact() {
        let (m, g) = setup(2);
        let scheme = Scheme {
            diag_len: vec![g.n],
            fill_len: vec![],
        };
        let arr = place(&m, &g, &scheme).unwrap();
        let x: Vec<f64> = (0..m.rows).map(|i| (i as f64) * 0.3 - 2.0).collect();
        let y = arr.mvm(&x);
        let want = m.spmv(&x);
        for (a, b) in y.iter().zip(want.iter()) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }

    #[test]
    fn complete_coverage_schemes_compute_exactly_property() {
        check("crossbar_complete_exact", 20, |rng| {
            let (m, g) = setup(2);
            // random scheme; only assert exactness when coverage == 1
            let d: Vec<u8> = (0..g.n - 1).map(|_| rng.below(2) as u8).collect();
            let f: Vec<usize> = (0..g.n - 1).map(|_| rng.below(4) as usize).collect();
            let s = parse_actions(g.n, &d, &f, FillRule::Dynamic { grades: 4 });
            let e = evaluate(&s, &g, RewardWeights::new(0.8));
            let arr = place(&m, &g, &s).unwrap();
            let x: Vec<f64> = (0..m.rows).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let y = arr.mvm(&x);
            let want = m.spmv(&x);
            let exact = y
                .iter()
                .zip(want.iter())
                .all(|(a, b)| (a - b).abs() < 1e-9);
            if (e.coverage_ratio >= 1.0) != exact {
                return Err(format!(
                    "coverage {} but exact={exact}",
                    e.coverage_ratio
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn tile_count_matches_scheme_area() {
        let (m, g) = setup(2);
        let s = parse_actions(
            g.n,
            &[0, 1, 0, 1, 1, 0, 1, 1, 1, 0],
            &[1, 1, 1, 1, 1, 1, 1, 1, 1, 1],
            FillRule::Fixed { size: 1 },
        );
        let e = evaluate(&s, &g, RewardWeights::new(0.8));
        let arr = place(&m, &g, &s).unwrap();
        // every tile is fully inside the 22x22 matrix (22 = 11*2), so the
        // placed cell area equals the scheme's covered area
        assert_eq!(arr.area_cells(), e.covered_area_units);
        assert_eq!(arr.area_cells_clipped(), arr.area_cells());
    }

    #[test]
    fn clipped_area_matches_scheme_area_on_truncated_edges() {
        // 882 = 27*32 + 18: the trailing tile row/column overhangs the
        // matrix by 14 units. area_cells counts the physical K² arrays;
        // the clipped accessor must match the scheme evaluator exactly.
        let m = synth::qh882_like(1);
        let r = reorder(&m, Reordering::CuthillMckee);
        let g = GridSummary::new(&r.matrix, 32);
        let s = Scheme {
            diag_len: vec![g.n],
            fill_len: vec![],
        };
        let e = evaluate(&s, &g, RewardWeights::new(0.8));
        let arr = place(&r.matrix, &g, &s).unwrap();
        assert_eq!(arr.area_cells_clipped(), e.covered_area_units);
        assert_eq!(arr.area_cells_clipped(), 882 * 882);
        assert!(arr.area_cells() > arr.area_cells_clipped());
        // per-tile extents: full tiles are 32x32, edge tiles carry the 18s
        for t in &arr.tiles {
            let (rr, cc) = arr.clipped_extents(t);
            assert_eq!(rr, if t.row0 == 27 * 32 { 18 } else { 32 });
            assert_eq!(cc, if t.col0 == 27 * 32 { 18 } else { 32 });
        }
    }

    #[test]
    fn truncated_edge_tiles_stay_in_bounds() {
        // 882 = 27*32 + 18: trailing tiles are zero-padded, MVM stays exact
        let m = synth::qh882_like(1);
        let r = reorder(&m, Reordering::CuthillMckee);
        let g = GridSummary::new(&r.matrix, 32);
        let s = Scheme {
            diag_len: vec![g.n],
            fill_len: vec![],
        };
        let arr = place(&r.matrix, &g, &s).unwrap();
        let x: Vec<f64> = (0..882).map(|i| ((i * 37) % 11) as f64 - 5.0).collect();
        let y = arr.mvm(&x);
        let want = r.matrix.spmv(&x);
        for (a, b) in y.iter().zip(want.iter()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn row_segments_counts_distinct_rows() {
        let (m, g) = setup(2);
        let s = parse_actions(g.n, &[0; 10], &[0; 10], FillRule::None);
        let arr = place(&m, &g, &s).unwrap();
        assert_eq!(arr.row_segments(), g.n); // unit diagonal blocks
    }
}
