//! The TCP front end: NDJSON-over-socket serving against a
//! [`DeploymentRegistry`].
//!
//! One accept loop, one handler thread per connection (bounded by
//! [`NetOptions::max_conns`] — a connection over the cap is answered with
//! a typed `busy` error line and closed, never silently dropped). Each
//! handler reads bounded NDJSON lines ([`dispatch::read_line_bounded`])
//! and answers every request on the same connection, in order. The
//! request dialect and per-request handling are documented in
//! [`crate::net`]; the error wire format is byte-identical to the stdin
//! `serve` loop because both are built from [`crate::api::dispatch`].
//!
//! Request lifecycle inside a handler: read line (arrival timestamp) →
//! parse → route (`admin` or tenant) → snapshot the tenant's current
//! [`TenantEntry`] → validate vectors against that entry's dimension →
//! admit (typed `busy` at the queue-depth limit) → deadline check (typed
//! `deadline` if the budget expired before execution) → execute → answer.
//! The entry snapshot makes hot-swap safe: a reload that lands mid-request
//! does not affect that request, which finishes on the plan it validated
//! against.

use super::registry::{DeploymentRegistry, Tenant};
use crate::api::dispatch::{self, BoundedLine};
use crate::api::Error;
use crate::fault::{FaultKind, FaultSpec};
use crate::util::json::{num_arr, obj, Json};
use std::io::{BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

/// Front-end configuration.
#[derive(Clone, Copy, Debug)]
pub struct NetOptions {
    /// concurrent connection cap; connections over it are answered with a
    /// `busy` error line and closed
    pub max_conns: usize,
    /// cap on one NDJSON request line; longer lines are drained and
    /// rejected with a `parse` error (the connection stays usable)
    pub max_line_bytes: usize,
    /// per-connection read-timeout budget in milliseconds; a connection
    /// idle past it is answered with a typed `timeout` error line and
    /// closed. 0 disables the timeout (connections may idle forever).
    pub read_timeout_ms: u64,
}

impl Default for NetOptions {
    fn default() -> NetOptions {
        NetOptions {
            max_conns: 64,
            max_line_bytes: dispatch::DEFAULT_MAX_LINE_BYTES,
            read_timeout_ms: 0,
        }
    }
}

/// Sentinel "tenant" named in the busy rejection a connection over
/// [`NetOptions::max_conns`] receives.
pub const CONN_CAP_TENANT: &str = "<connections>";

/// A running TCP server. Dropping it (or calling [`NetServer::stop`])
/// shuts the accept loop down; [`NetServer::join`] instead blocks forever
/// serving (the CLI path).
pub struct NetServer {
    registry: Arc<DeploymentRegistry>,
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    /// when set, connection handlers finish the request they are on and
    /// close instead of reading another line — the graceful-drain half of
    /// [`NetServer::shutdown_graceful`]
    draining: Arc<AtomicBool>,
    /// live connection count (shared with the accept loop's cap check)
    conns: Arc<AtomicUsize>,
    accept: Option<JoinHandle<()>>,
}

/// Decrements the live-connection counter when a handler ends, however it
/// ends.
struct ConnGuard(Arc<AtomicUsize>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::AcqRel);
    }
}

impl NetServer {
    /// Bind `listen` (e.g. `127.0.0.1:7070`; port 0 picks a free port —
    /// read it back from [`NetServer::addr`]) and start the accept loop.
    pub fn start(
        registry: Arc<DeploymentRegistry>,
        listen: &str,
        opts: &NetOptions,
    ) -> crate::api::Result<NetServer> {
        let listener = TcpListener::bind(listen)
            .map_err(|e| Error::Io(format!("binding {listen}: {e}")))?;
        let addr = listener
            .local_addr()
            .map_err(|e| Error::Io(format!("resolving bound address: {e}")))?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let draining = Arc::new(AtomicBool::new(false));
        let conns = Arc::new(AtomicUsize::new(0));
        let max_conns = opts.max_conns.max(1);
        let max_line = opts.max_line_bytes.max(1);
        let read_timeout_ms = opts.read_timeout_ms;
        let reg = registry.clone();
        let stop = shutdown.clone();
        let drain = draining.clone();
        let live = conns.clone();
        let accept = thread::Builder::new()
            .name("net-accept".into())
            .spawn(move || {
                for stream in listener.incoming() {
                    if stop.load(Ordering::Acquire) {
                        break;
                    }
                    let stream = match stream {
                        Ok(s) => s,
                        Err(_) => continue,
                    };
                    let admitted = live
                        .fetch_update(Ordering::AcqRel, Ordering::Acquire, |n| {
                            (n < max_conns).then_some(n + 1)
                        })
                        .is_ok();
                    if !admitted {
                        // typed rejection, not a silent close
                        let err = Error::Busy {
                            tenant: CONN_CAP_TENANT.into(),
                            depth: max_conns,
                        };
                        let mut w = BufWriter::new(&stream);
                        let _ = writeln!(w, "{}", error_response(None, Json::Null, &err).to_string());
                        let _ = w.flush();
                        continue;
                    }
                    let reg = reg.clone();
                    let guard = ConnGuard(live.clone());
                    let drain = drain.clone();
                    // if the spawn fails the closure (and guard) drop,
                    // releasing the connection slot
                    let _ = thread::Builder::new().name("net-conn".into()).spawn(move || {
                        let _guard = guard;
                        handle_conn(stream, &reg, max_line, read_timeout_ms, &drain);
                    });
                }
            })
            .map_err(|e| Error::Io(format!("spawning accept thread: {e}")))?;
        Ok(NetServer {
            registry,
            addr,
            shutdown,
            draining,
            conns,
            accept: Some(accept),
        })
    }

    /// The bound address (resolves `:0` listens).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn registry(&self) -> &Arc<DeploymentRegistry> {
        &self.registry
    }

    /// Stop accepting and join the accept loop. Live connections drain on
    /// their own handler threads.
    pub fn stop(&mut self) {
        if self.accept.is_none() {
            return;
        }
        self.shutdown.store(true, Ordering::Release);
        // wake the blocking accept with a throwaway connection
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Block on the accept loop forever (the `serve-net` CLI path).
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Live connection count.
    pub fn connections(&self) -> usize {
        self.conns.load(Ordering::Acquire)
    }

    /// Graceful shutdown: stop accepting new connections, let every
    /// handler finish the request it is serving (handlers close instead
    /// of reading another line), and wait up to `grace` for the live
    /// connection count to reach zero. No in-flight request is dropped —
    /// a request already being executed when the drain starts still gets
    /// its response. Returns true when fully drained, false when the
    /// grace budget expired with connections still open (the process may
    /// exit anyway; those connections were idle or stuck).
    pub fn shutdown_graceful(&mut self, grace: Duration) -> bool {
        self.draining.store(true, Ordering::Release);
        self.stop();
        let deadline = Instant::now() + grace;
        while self.conns.load(Ordering::Acquire) > 0 {
            if Instant::now() >= deadline {
                return false;
            }
            thread::sleep(Duration::from_millis(5));
        }
        true
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Per-connection loop: bounded framing, one answer per non-blank line.
/// With a read timeout configured, an idle connection is answered with a
/// typed `timeout` error line and closed; when `draining` is set the
/// handler finishes the request it is on and closes instead of reading
/// another.
fn handle_conn(
    stream: TcpStream,
    registry: &DeploymentRegistry,
    max_line: usize,
    read_timeout_ms: u64,
    draining: &AtomicBool,
) {
    if read_timeout_ms > 0 {
        let _ = stream.set_read_timeout(Some(Duration::from_millis(read_timeout_ms)));
    }
    let read = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut input = BufReader::new(read);
    let mut out = BufWriter::new(stream);
    loop {
        if draining.load(Ordering::Acquire) {
            break;
        }
        let step = match dispatch::read_line_bounded(&mut input, max_line) {
            Ok(s) => s,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                // idle past the read-timeout budget: say why, then close
                let err = Error::Timeout { idle_ms: read_timeout_ms };
                let _ = respond(&mut out, &error_response(None, Json::Null, &err));
                break;
            }
            Err(_) => break, // transport died
        };
        let arrival = Instant::now();
        let line = match step {
            BoundedLine::Eof => break,
            BoundedLine::TooLong { limit } => {
                let err = Error::Parse(format!("request line exceeds the {limit}-byte limit"));
                if respond(&mut out, &error_response(None, Json::Null, &err)).is_err() {
                    break;
                }
                continue;
            }
            BoundedLine::Line(l) => l,
        };
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue; // blank lines are keep-alives, not errors
        }
        let reply = handle_line(registry, trimmed, arrival);
        if respond(&mut out, &reply).is_err() {
            break;
        }
    }
}

fn respond<W: Write>(out: &mut W, doc: &Json) -> std::io::Result<()> {
    writeln!(out, "{}", doc.to_string())?;
    out.flush()
}

/// Route one parsed-or-not request line to an answer document.
fn handle_line(registry: &DeploymentRegistry, line: &str, arrival: Instant) -> Json {
    let doc = match Json::parse(line) {
        Ok(d) => d,
        Err(e) => return error_response(None, Json::Null, &Error::Parse(e.to_string())),
    };
    if doc.get("admin") != &Json::Null {
        return handle_admin(registry, &doc);
    }
    let id = doc.get("id").clone();
    let tenant_id = match doc.get("tenant").as_str() {
        Some(t) => t.to_string(),
        None => {
            return error_response(
                None,
                id,
                &Error::Validate("request names no \"tenant\" deployment id".into()),
            )
        }
    };
    match serve_request(registry, &tenant_id, &doc, arrival) {
        Ok((key, payload, degraded)) => {
            let mut fields = vec![
                ("tenant", Json::Str(tenant_id)),
                ("id", id),
                (key, payload),
            ];
            if degraded {
                fields.push(("degraded", Json::Bool(true)));
            }
            obj(fields)
        }
        Err(e) => error_response(Some(&tenant_id), id, &e),
    }
}

/// One tenant request end to end; counters are updated on every path.
/// Execution runs behind [`dispatch::catch_internal`], so a worker-pool
/// panic becomes a typed `internal` error echoing the request id (the
/// caller attaches it) and the connection keeps serving.
fn serve_request(
    registry: &DeploymentRegistry,
    tenant_id: &str,
    doc: &Json,
    arrival: Instant,
) -> crate::api::Result<(&'static str, Json, bool)> {
    let tenant: Arc<Tenant> = registry.get(tenant_id)?;
    let outcome = (|| {
        // snapshot the generation first: everything below (validation,
        // execution, accounting) is against this one consistent entry
        let entry = tenant.entry();
        let dim = entry.dim();
        let deadline = dispatch::parse_deadline(doc)?;
        if let Some(req) = dispatch::parse_update(doc)? {
            // dynamic-graph edge updates ([`crate::delta`]): the first one
            // attaches a delta engine over the tenant's current
            // generation; afterwards every x/xs request routes through the
            // engine so pending updates are always visible
            let _slot = tenant.admit()?;
            if let Some(ms) = deadline {
                dispatch::check_deadline(arrival, ms)?;
            }
            let eng = registry.delta_engine(tenant_id)?;
            let mut ack = dispatch::catch_internal(|| eng.apply(&req.edges))?;
            if registry.remap_after() > 0
                && eng.updates_since_remap() >= registry.remap_after() as u64
            {
                dispatch::catch_internal(|| registry.remap(tenant_id).map(|_| ()))?;
                ack.pending = eng.pending();
                ack.generation = eng.generation();
            }
            return Ok(("update", dispatch::update_ack_obj(&ack), false));
        }
        if let Some(req) = dispatch::parse_algo(doc, dim)? {
            // a whole-algorithm run occupies one admission slot for its
            // entire iterative lifetime — deliberate: queue depth bounds
            // arena pressure, not request count
            let _slot = tenant.admit()?;
            if let Some(ms) = deadline {
                dispatch::check_deadline(arrival, ms)?;
            }
            let ans =
                dispatch::catch_internal(|| entry.run_algo(&req, registry.sharded()))?;
            tenant.record_algo(ans.key, ans.mvms);
            tenant.record_served(1, ans.mvms * entry.nnz());
            return Ok((ans.key, ans.payload, ans.degraded));
        }
        let batched = doc.get("xs") != &Json::Null;
        let xs = if batched {
            dispatch::parse_batch(doc.get("xs"), dim)?
        } else {
            vec![dispatch::parse_vec(doc.get("x"), dim)?]
        };
        let _slot = tenant.admit()?;
        if let Some(ms) = deadline {
            dispatch::check_deadline(arrival, ms)?;
        }
        let n = xs.len() as u64;
        let (mut ys, degraded) = match tenant.delta() {
            // a delta tenant serves base + pending overlay through its
            // engine (which bypasses the fault harness — see crate::delta)
            Some(eng) => (dispatch::catch_internal(|| eng.execute(&xs, registry.sharded()))?, false),
            None => dispatch::catch_internal(|| Ok(entry.execute(xs, registry.sharded())))?,
        };
        tenant.record_served(n, entry.nnz());
        Ok(if batched {
            ("ys", Json::Arr(ys.into_iter().map(num_arr).collect()), degraded)
        } else {
            ("y", num_arr(ys.pop().expect("one request, one answer")), degraded)
        })
    })();
    if let Err(e) = &outcome {
        tenant.record_failure(e);
    }
    outcome
}

/// Admin requests: `{"admin":"stats"}`,
/// `{"admin":{"reload":{"id":...,"bundle":...}}}`,
/// `{"admin":{"inject":...}}` / `{"admin":{"repair":...}}`, and
/// `{"admin":{"remap":{"id":...}}}` (fold a dynamic tenant's pending
/// updates into a fresh arena generation).
fn handle_admin(registry: &DeploymentRegistry, doc: &Json) -> Json {
    let admin = doc.get("admin");
    if admin.as_str() == Some("stats") {
        return obj(vec![
            ("admin", Json::Str("stats".into())),
            ("stats", registry.stats_json()),
        ]);
    }
    let reload = admin.get("reload");
    if reload != &Json::Null {
        let id = match reload.get("id").as_str() {
            Some(s) => s.to_string(),
            None => {
                return error_response(
                    None,
                    Json::Null,
                    &Error::Validate("reload names no \"id\"".into()),
                )
            }
        };
        let bundle = match reload.get("bundle").as_str() {
            Some(s) => s.to_string(),
            None => {
                return error_response(
                    Some(&id),
                    Json::Null,
                    &Error::Validate("reload names no \"bundle\" path".into()),
                )
            }
        };
        return match registry.reload(&id, Path::new(&bundle)) {
            Ok(entry) => obj(vec![
                ("admin", Json::Str("reload".into())),
                ("id", Json::Str(id)),
                ("generation", Json::Num(entry.generation() as f64)),
                ("dim", Json::Num(entry.dim() as f64)),
            ]),
            Err(e) => error_response(Some(&id), Json::Null, &e),
        };
    }
    let remap = admin.get("remap");
    if remap != &Json::Null {
        let id = match remap.get("id").as_str() {
            Some(s) => s.to_string(),
            None => {
                return error_response(
                    None,
                    Json::Null,
                    &Error::Validate("remap names no \"id\"".into()),
                )
            }
        };
        return match registry.remap(&id) {
            Ok((entry, report)) => obj(vec![
                ("admin", Json::Str("remap".into())),
                ("id", Json::Str(id)),
                ("generation", Json::Num(entry.generation() as f64)),
                ("windows", Json::Num(report.windows as f64)),
                ("reused_windows", Json::Num(report.reused_windows as f64)),
                ("cache_hit_rate", Json::Num(report.cache_hit_rate)),
                ("carried_updates", Json::Num(report.carried_updates as f64)),
                ("wall_s", Json::Num(report.wall_seconds)),
            ]),
            Err(e) => error_response(Some(&id), Json::Null, &e),
        };
    }
    let inject = admin.get("inject");
    if inject != &Json::Null {
        let id = match inject.get("id").as_str() {
            Some(s) => s.to_string(),
            None => {
                return error_response(
                    None,
                    Json::Null,
                    &Error::Validate("inject names no \"id\"".into()),
                )
            }
        };
        return match inject_fault(registry, &id, inject) {
            Ok(report) => obj(vec![
                ("admin", Json::Str("inject".into())),
                ("id", Json::Str(id)),
                ("generation", Json::Num(report.generation as f64)),
                ("cells_changed", Json::Num(report.cells_changed as f64)),
                (
                    "programs",
                    Json::Arr(
                        report
                            .programs
                            .iter()
                            .map(|&p| Json::Num(p as f64))
                            .collect(),
                    ),
                ),
            ]),
            Err(e) => error_response(Some(&id), Json::Null, &e),
        };
    }
    let repair = admin.get("repair");
    if repair != &Json::Null {
        let id = match repair.get("id").as_str() {
            Some(s) => s.to_string(),
            None => {
                return error_response(
                    None,
                    Json::Null,
                    &Error::Validate("repair names no \"id\"".into()),
                )
            }
        };
        return match repair_tenant(registry, &id) {
            Ok(generation) => obj(vec![
                ("admin", Json::Str("repair".into())),
                ("id", Json::Str(id)),
                ("generation", Json::Num(generation as f64)),
            ]),
            Err(e) => error_response(Some(&id), Json::Null, &e),
        };
    }
    error_response(
        None,
        Json::Null,
        &Error::Validate(
            "unknown admin request; use \"stats\", {\"reload\":{\"id\":..,\"bundle\":..}}, \
             {\"remap\":{\"id\":..}}, {\"inject\":{\"id\":..,\"bank\":..,\"kind\":..}}, \
             or {\"repair\":{\"id\":..}}"
                .into(),
        ),
    )
}

/// `{"admin":{"inject":..}}`: corrupt one bank of a fault-armed tenant.
/// The injection is silent — detection is the harness's job — so the
/// reply only describes what was corrupted, not what was noticed.
fn inject_fault(
    registry: &DeploymentRegistry,
    id: &str,
    spec: &Json,
) -> crate::api::Result<crate::fault::InjectReport> {
    let tenant = registry.get(id)?;
    let entry = tenant.entry();
    let harness = match entry.fault_harness() {
        Some(h) => h.clone(),
        None => {
            return Err(Error::Validate(
                "no armed fault harness; start serve-net with --fault-harness".into(),
            ))
        }
    };
    let bank = match spec.get("bank").as_f64() {
        Some(b) if b >= 0.0 => b as usize,
        _ => return Err(Error::Validate("inject names no \"bank\"".into())),
    };
    let kind = spec
        .get("kind")
        .as_str()
        .ok_or_else(|| Error::Validate("inject names no \"kind\"".into()))?;
    let rate = spec.get("rate").as_f64().unwrap_or(0.05);
    let seed = spec.get("seed").as_f64().unwrap_or(0.0) as u64;
    let kind = FaultKind::parse(kind, rate)?;
    harness.inject(&FaultSpec { bank, kind, seed })
}

/// `{"admin":{"repair":..}}`: re-program a fault-armed tenant's quarantined
/// work onto healthy banks and return the fresh epoch generation.
fn repair_tenant(registry: &DeploymentRegistry, id: &str) -> crate::api::Result<u64> {
    let tenant = registry.get(id)?;
    let entry = tenant.entry();
    let harness = match entry.fault_harness() {
        Some(h) => h.clone(),
        None => {
            return Err(Error::Validate(
                "no armed fault harness; start serve-net with --fault-harness".into(),
            ))
        }
    };
    harness.repair()?;
    Ok(harness.generation())
}

/// The shared error line ([`dispatch::error_line`]) with the tenant echo
/// the socket dialect adds when the tenant is known.
fn error_response(tenant: Option<&str>, id: Json, err: &Error) -> Json {
    let mut line = dispatch::error_line(id, err);
    if let (Some(t), Json::Obj(map)) = (tenant, &mut line) {
        map.insert("tenant".into(), Json::Str(t.into()));
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{DeploymentBuilder, Source, Strategy};
    use crate::graph::synth;
    use crate::net::RegistryOptions;

    fn registry_with_tenant(queue_depth: usize) -> DeploymentRegistry {
        registry_with_options(queue_depth, None)
    }

    fn registry_with_options(
        queue_depth: usize,
        fault: Option<crate::fault::FaultOptions>,
    ) -> DeploymentRegistry {
        let reg = DeploymentRegistry::new(&RegistryOptions {
            workers: 2,
            queue_depth,
            sharded: true,
            fault,
            remap_after: 0,
        });
        let dep = DeploymentBuilder::new(
            Source::Matrix {
                label: "qm7".into(),
                matrix: synth::qm7_like(5828),
            },
            Strategy::FixedBlock { block: 1 },
        )
        .grid(2)
        .build()
        .unwrap();
        reg.insert("g", dep, None);
        reg
    }

    fn now() -> Instant {
        Instant::now()
    }

    #[test]
    fn routes_requests_and_echoes_tenant_and_id() {
        let reg = registry_with_tenant(4);
        let dim = reg.get("g").unwrap().entry().dim();
        let x: Vec<f64> = (0..dim).map(|i| i as f64 * 0.5 - 4.0).collect();
        let req = obj(vec![
            ("tenant", Json::Str("g".into())),
            ("id", Json::Num(7.0)),
            ("x", num_arr(x.clone())),
        ]);
        let resp = handle_line(&reg, &req.to_string(), now());
        assert_eq!(resp.get("tenant").as_str(), Some("g"));
        assert_eq!(resp.get("id").as_i64(), Some(7));
        let want = reg.get("g").unwrap().entry().deployment().mvm(&x).unwrap();
        let got: Vec<f64> =
            resp.get("y").as_arr().unwrap().iter().map(|v| v.as_f64().unwrap()).collect();
        assert_eq!(got, want, "socket answer must equal Deployment::mvm");
    }

    #[test]
    fn unknown_and_missing_tenant_are_typed_validate_errors() {
        let reg = registry_with_tenant(4);
        let resp = handle_line(&reg, r#"{"tenant":"nope","id":1,"x":[1.0]}"#, now());
        assert_eq!(resp.get("error").get("kind").as_str(), Some("validate"));
        let msg = resp.get("error").get("message").as_str().unwrap();
        assert!(msg.contains("nope") && msg.contains('g'), "{msg}");
        let resp = handle_line(&reg, r#"{"id":1,"x":[1.0]}"#, now());
        assert_eq!(resp.get("error").get("kind").as_str(), Some("validate"));
        // bad JSON is a parse error, not a dead connection
        let resp = handle_line(&reg, "{nope", now());
        assert_eq!(resp.get("error").get("kind").as_str(), Some("parse"));
    }

    #[test]
    fn deadline_zero_is_rejected_before_execution() {
        let reg = registry_with_tenant(4);
        let dim = reg.get("g").unwrap().entry().dim();
        let req = obj(vec![
            ("tenant", Json::Str("g".into())),
            ("id", Json::Num(1.0)),
            ("deadline_ms", Json::Num(0.0)),
            ("x", num_arr(vec![0.5; dim])),
        ]);
        let resp = handle_line(&reg, &req.to_string(), now());
        assert_eq!(resp.get("error").get("kind").as_str(), Some("deadline"));
        let stats = reg.get("g").unwrap().stats_json();
        assert_eq!(stats.get("rejected_deadline").as_i64(), Some(1));
        assert_eq!(stats.get("served").as_i64(), Some(0));
    }

    #[test]
    fn admin_stats_and_reload_validation() {
        let reg = registry_with_tenant(4);
        let resp = handle_line(&reg, r#"{"admin":"stats"}"#, now());
        assert_eq!(resp.get("admin").as_str(), Some("stats"));
        assert_eq!(resp.get("stats").get("g").get("served").as_i64(), Some(0));
        // malformed admin requests are typed errors
        let resp = handle_line(&reg, r#"{"admin":{"reload":{"id":"g"}}}"#, now());
        assert_eq!(resp.get("error").get("kind").as_str(), Some("validate"));
        let resp = handle_line(&reg, r#"{"admin":"nonsense"}"#, now());
        assert_eq!(resp.get("error").get("kind").as_str(), Some("validate"));
        // a reload pointing at a missing bundle is an io error, not a crash
        let resp = handle_line(
            &reg,
            r#"{"admin":{"reload":{"id":"g","bundle":"/nonexistent/b.json"}}}"#,
            now(),
        );
        assert_eq!(resp.get("error").get("kind").as_str(), Some("io"));
    }

    #[test]
    fn algo_requests_answer_over_the_socket_dialect() {
        let reg = registry_with_tenant(4);
        let dim = reg.get("g").unwrap().entry().dim();
        let resp = handle_line(
            &reg,
            r#"{"tenant":"g","id":3,"pagerank":{"tol":1e-10,"max_iters":500}}"#,
            now(),
        );
        assert_eq!(resp.get("tenant").as_str(), Some("g"));
        assert_eq!(resp.get("id").as_i64(), Some(3));
        let pr = resp.get("pagerank");
        let mass: f64 =
            pr.get("scores").as_arr().unwrap().iter().map(|v| v.as_f64().unwrap()).sum();
        assert!((mass - 1.0).abs() < 1e-9, "rank mass {mass}");
        assert_eq!(pr.get("trace").get("algorithm").as_str(), Some("pagerank"));
        assert_eq!(pr.get("trace").get("converged").as_bool(), Some(true));

        let resp = handle_line(&reg, r#"{"tenant":"g","id":4,"bfs":{"source":0}}"#, now());
        assert_eq!(resp.get("bfs").get("levels").as_arr().unwrap().len(), dim);

        // the admin stats surface reports the per-algorithm request mix
        let stats = handle_line(&reg, r#"{"admin":"stats"}"#, now());
        let algo = stats.get("stats").get("g").get("algo");
        assert_eq!(algo.get("pagerank").as_i64(), Some(1));
        assert_eq!(algo.get("bfs").as_i64(), Some(1));
        assert_eq!(algo.get("sssp").as_i64(), Some(0));
        assert!(algo.get("mvms").as_i64().unwrap() > 0);

        // algorithm failures are typed error answers, not dead connections
        let resp = handle_line(
            &reg,
            r#"{"tenant":"g","id":5,"pagerank":{"tol":1e-15,"max_iters":1}}"#,
            now(),
        );
        assert_eq!(resp.get("error").get("kind").as_str(), Some("no_converge"));
        let resp = handle_line(&reg, r#"{"tenant":"g","id":6,"bfs":{"source":9999}}"#, now());
        assert_eq!(resp.get("error").get("kind").as_str(), Some("validate"));
        // an algorithm run respects the deadline admission gate
        let resp = handle_line(
            &reg,
            r#"{"tenant":"g","id":7,"deadline_ms":0,"bfs":{"source":0}}"#,
            now(),
        );
        assert_eq!(resp.get("error").get("kind").as_str(), Some("deadline"));
    }

    #[test]
    fn batch_requests_answer_with_ys() {
        let reg = registry_with_tenant(4);
        let dim = reg.get("g").unwrap().entry().dim();
        let xs: Vec<Vec<f64>> = (0..3).map(|s| vec![s as f64 - 1.0; dim]).collect();
        let req = obj(vec![
            ("tenant", Json::Str("g".into())),
            ("id", Json::Num(2.0)),
            ("xs", Json::Arr(xs.iter().cloned().map(num_arr).collect())),
        ]);
        let resp = handle_line(&reg, &req.to_string(), now());
        let ys = resp.get("ys").as_arr().unwrap();
        assert_eq!(ys.len(), 3);
        let dep = reg.get("g").unwrap().entry();
        for (x, y) in xs.iter().zip(ys) {
            let want = dep.deployment().mvm(x).unwrap();
            let got: Vec<f64> = y.as_arr().unwrap().iter().map(|v| v.as_f64().unwrap()).collect();
            assert_eq!(got, want);
        }
        let stats = reg.get("g").unwrap().stats_json();
        assert_eq!(stats.get("served").as_i64(), Some(3));
        assert_eq!(stats.get("batches").as_i64(), Some(1));
    }

    #[test]
    fn update_and_remap_over_the_socket_dialect() {
        let reg = registry_with_tenant(4);
        let dim = reg.get("g").unwrap().entry().dim();
        let x: Vec<f64> = (0..dim).map(|i| (i % 11) as f64 * 0.5 - 2.0).collect();
        let query = obj(vec![
            ("tenant", Json::Str("g".into())),
            ("id", Json::Num(9.0)),
            ("x", num_arr(x.clone())),
        ]);
        let before: Vec<f64> = handle_line(&reg, &query.to_string(), now())
            .get("y")
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect();

        // malformed update bodies are typed validate errors
        let resp =
            handle_line(&reg, r#"{"tenant":"g","id":1,"update":{"edges":[]}}"#, now());
        assert_eq!(resp.get("error").get("kind").as_str(), Some("validate"));

        // one edge update attaches the engine and acks the pending count
        let resp = handle_line(
            &reg,
            r#"{"tenant":"g","id":2,"update":{"edges":[[0,1,1000.5]]}}"#,
            now(),
        );
        assert_eq!(resp.get("tenant").as_str(), Some("g"));
        assert_eq!(resp.get("update").get("applied").as_i64(), Some(1));
        assert_eq!(resp.get("update").get("pending").as_i64(), Some(1));
        assert_eq!(resp.get("update").get("generation").as_i64(), Some(0));

        // queries now route through the overlay: the answer shifts
        let shifted: Vec<f64> = handle_line(&reg, &query.to_string(), now())
            .get("y")
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect();
        assert_ne!(shifted, before, "a pending update must be visible to queries");

        // the stats surface exposes the per-tenant delta block
        let stats = handle_line(&reg, r#"{"admin":"stats"}"#, now());
        let delta = stats.get("stats").get("g").get("delta");
        assert_eq!(delta.get("pending").as_i64(), Some(1));
        assert_eq!(delta.get("updates").as_i64(), Some(1));

        // admin remap folds the overlay into a fresh tenant generation
        let resp = handle_line(&reg, r#"{"admin":{"remap":{"id":"g"}}}"#, now());
        assert_eq!(resp.get("admin").as_str(), Some("remap"));
        assert_eq!(resp.get("generation").as_i64(), Some(2));
        assert!(resp.get("windows").as_i64().unwrap() >= 1);
        assert_eq!(resp.get("carried_updates").as_i64(), Some(1));

        // post-fold the wire answer equals the new entry's own oracle bits
        let after: Vec<f64> = handle_line(&reg, &query.to_string(), now())
            .get("y")
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect();
        let want = reg.get("g").unwrap().entry().deployment().mvm(&x).unwrap();
        assert_eq!(after, want, "folded plan must serve its own oracle exactly");

        // malformed remap requests are typed errors
        let resp = handle_line(&reg, r#"{"admin":{"remap":{}}}"#, now());
        assert_eq!(resp.get("error").get("kind").as_str(), Some("validate"));
    }

    #[test]
    fn admin_inject_without_harness_is_a_validate_error() {
        let reg = registry_with_tenant(4);
        let resp = handle_line(
            &reg,
            r#"{"admin":{"inject":{"id":"g","bank":0,"kind":"outage"}}}"#,
            now(),
        );
        assert_eq!(resp.get("error").get("kind").as_str(), Some("validate"));
        let msg = resp.get("error").get("message").as_str().unwrap();
        assert!(msg.contains("--fault-harness"), "{msg}");
        // same for repair: both admin verbs require an armed harness
        let resp = handle_line(&reg, r#"{"admin":{"repair":{"id":"g"}}}"#, now());
        assert_eq!(resp.get("error").get("kind").as_str(), Some("validate"));
    }

    #[test]
    fn panic_inside_execution_is_a_typed_internal_error() {
        let reg =
            registry_with_options(4, Some(crate::fault::FaultOptions::default()));
        let entry = reg.get("g").unwrap().entry();
        let dim = entry.dim();
        entry.fault_harness().unwrap().poison_next_request();
        let req = obj(vec![
            ("tenant", Json::Str("g".into())),
            ("id", Json::Num(41.0)),
            ("x", num_arr(vec![1.0; dim])),
        ]);
        let resp = handle_line(&reg, &req.to_string(), now());
        assert_eq!(resp.get("error").get("kind").as_str(), Some("internal"));
        assert_eq!(resp.get("id").as_i64(), Some(41), "request id must echo back");
        // the poison is one-shot: the connection (and pool) keep serving
        let req = obj(vec![
            ("tenant", Json::Str("g".into())),
            ("id", Json::Num(42.0)),
            ("x", num_arr(vec![1.0; dim])),
        ]);
        let resp = handle_line(&reg, &req.to_string(), now());
        assert_eq!(resp.get("id").as_i64(), Some(42));
        assert!(resp.get("y").as_arr().is_some(), "next request must succeed");
    }

    #[test]
    fn inject_detect_repair_over_the_admin_dialect() {
        let reg =
            registry_with_options(4, Some(crate::fault::FaultOptions::default()));
        let entry = reg.get("g").unwrap().entry();
        let dim = entry.dim();
        let x: Vec<f64> = (0..dim).map(|i| (i % 13) as f64 * 0.25 - 1.5).collect();
        let healthy = entry.deployment().mvm(&x).unwrap();
        let oracle = entry.deployment().mvm_oracle(&x).unwrap();

        // a healthy fault-armed tenant serves bit-identically, undegraded
        let req = obj(vec![
            ("tenant", Json::Str("g".into())),
            ("id", Json::Num(1.0)),
            ("x", num_arr(x.clone())),
        ]);
        let resp = handle_line(&reg, &req.to_string(), now());
        assert_eq!(resp.get("degraded"), &Json::Null);
        let got: Vec<f64> =
            resp.get("y").as_arr().unwrap().iter().map(|v| v.as_f64().unwrap()).collect();
        assert_eq!(got, healthy);

        // corrupt a whole bank through the admin surface
        let resp = handle_line(
            &reg,
            r#"{"admin":{"inject":{"id":"g","bank":0,"kind":"outage","seed":9}}}"#,
            now(),
        );
        assert_eq!(resp.get("admin").as_str(), Some("inject"));
        assert!(resp.get("cells_changed").as_i64().unwrap() > 0);
        assert!(!resp.get("programs").as_arr().unwrap().is_empty());

        // the next request detects, degrades, and every element is either
        // the healthy-plan bits or the host-CSR oracle bits — never garbage
        let req = obj(vec![
            ("tenant", Json::Str("g".into())),
            ("id", Json::Num(2.0)),
            ("x", num_arr(x.clone())),
        ]);
        let resp = handle_line(&reg, &req.to_string(), now());
        assert_eq!(resp.get("degraded").as_bool(), Some(true));
        let got: Vec<f64> =
            resp.get("y").as_arr().unwrap().iter().map(|v| v.as_f64().unwrap()).collect();
        for (i, &g) in got.iter().enumerate() {
            assert!(
                g == healthy[i] || g == oracle[i],
                "row {i}: {g} is neither plan {} nor oracle {}",
                healthy[i],
                oracle[i]
            );
        }

        // out-of-range banks are typed errors, not crashes
        let resp = handle_line(
            &reg,
            r#"{"admin":{"inject":{"id":"g","bank":999,"kind":"outage"}}}"#,
            now(),
        );
        assert_eq!(resp.get("error").get("kind").as_str(), Some("validate"));

        // repair re-programs onto healthy banks and restores bit-identity
        let resp = handle_line(&reg, r#"{"admin":{"repair":{"id":"g"}}}"#, now());
        assert_eq!(resp.get("admin").as_str(), Some("repair"));
        assert!(resp.get("generation").as_i64().unwrap() > 0);
        let req = obj(vec![
            ("tenant", Json::Str("g".into())),
            ("id", Json::Num(3.0)),
            ("x", num_arr(x.clone())),
        ]);
        let resp = handle_line(&reg, &req.to_string(), now());
        assert_eq!(resp.get("degraded"), &Json::Null);
        let got: Vec<f64> =
            resp.get("y").as_arr().unwrap().iter().map(|v| v.as_f64().unwrap()).collect();
        assert_eq!(got, healthy, "repaired tenant must serve healthy bits again");

        // stats now carry the health block with the full episode recorded
        let stats = handle_line(&reg, r#"{"admin":"stats"}"#, now());
        let health = stats.get("stats").get("g").get("health");
        assert_eq!(health.get("armed").as_bool(), Some(true));
        assert_eq!(health.get("degraded").as_bool(), Some(false));
        assert!(health.get("verify_detections").as_i64().unwrap() >= 1);
        assert_eq!(health.get("repairs").as_i64(), Some(1));
    }
}
