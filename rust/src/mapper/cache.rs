//! Scheme cache keyed by a window's grid-occupancy signature.
//!
//! At 0.99+ sparsity most controller-sized windows of a banded matrix
//! carry one of a handful of occupancy patterns (empty, pure-diagonal,
//! narrow band, …), and everything the per-window mapper decides —
//! complete-coverage feasibility, block geometry, area — depends only on
//! *which* cells are occupied, never on the exact counts. Interning
//! windows by their occupancy bitset therefore lets repeated patterns be
//! mapped once: the mapper runs inference per *unique* signature and every
//! other window is a cache hit. The full bitset is stored next to its FNV
//! hash, so hash collisions degrade to a comparison, never to a wrong
//! scheme.

use crate::graph::GridSummary;
use crate::scheme::Scheme;
use std::collections::HashMap;

/// A window's content signature: occupancy bitset + geometry, pre-hashed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Signature {
    /// FNV-1a of the words below (cheap map key)
    pub hash: u64,
    /// n, dim (truncation), then the occupancy bitset words
    words: Vec<u64>,
}

/// Occupancy signature of a window grid: one bit per cell (row-major),
/// plus the cell count and matrix-unit dim so trailing-cell truncation
/// distinguishes otherwise identical patterns.
pub fn signature(local: &GridSummary) -> Signature {
    let n = local.n;
    let mut words = Vec::with_capacity(2 + (n * n).div_ceil(64));
    words.push(n as u64);
    words.push(local.dim as u64);
    let mut acc = 0u64;
    let mut bits = 0u32;
    for &c in &local.cell_nnz {
        acc = (acc << 1) | u64::from(c > 0);
        bits += 1;
        if bits == 64 {
            words.push(acc);
            acc = 0;
            bits = 0;
        }
    }
    if bits > 0 {
        words.push(acc);
    }
    // FNV-1a over the words
    let mut hash = 0xcbf2_9ce4_8422_2325u64;
    for w in &words {
        for b in w.to_le_bytes() {
            hash ^= b as u64;
            hash = hash.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    Signature { hash, words }
}

/// Intern-style cache: windows intern their signature (recording hit or
/// miss), the mapper runs inference once per missed entry, and every
/// window then reads its scheme back by entry id.
#[derive(Default)]
pub struct SchemeCache {
    entries: Vec<(Signature, Option<Scheme>)>,
    index: HashMap<u64, Vec<usize>>, // hash -> entry ids (collision chain)
    hits: usize,
    misses: usize,
}

impl SchemeCache {
    pub fn new() -> SchemeCache {
        SchemeCache::default()
    }

    /// Intern a signature; returns `(entry_id, was_hit)`.
    pub fn intern(&mut self, sig: Signature) -> (usize, bool) {
        let chain = self.index.entry(sig.hash).or_default();
        for &id in chain.iter() {
            if self.entries[id].0 == sig {
                self.hits += 1;
                return (id, true);
            }
        }
        let id = self.entries.len();
        chain.push(id);
        self.entries.push((sig, None));
        self.misses += 1;
        (id, false)
    }

    /// Store the scheme inferred for a missed entry.
    pub fn fill(&mut self, id: usize, scheme: Scheme) {
        self.entries[id].1 = Some(scheme);
    }

    /// Scheme for an interned entry (panics if never filled — the mapper
    /// fills every miss before reading).
    pub fn scheme(&self, id: usize) -> &Scheme {
        self.entries[id].1.as_ref().expect("cache entry not filled")
    }

    /// Entry ids still awaiting inference, in intern order.
    pub fn unfilled(&self) -> Vec<usize> {
        self.entries
            .iter()
            .enumerate()
            .filter(|(_, (_, s))| s.is_none())
            .map(|(i, _)| i)
            .collect()
    }

    pub fn unique(&self) -> usize {
        self.entries.len()
    }

    pub fn hits(&self) -> usize {
        self.hits
    }

    /// Hits over all interned lookups.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::sparse::Coo;
    use crate::graph::synth;

    #[test]
    fn signature_depends_on_occupancy_not_counts() {
        let mut a = Coo::new(8, 8);
        a.push(0, 0, 1.0);
        a.push_sym(3, 2, 1.0);
        let mut b = Coo::new(8, 8);
        b.push(0, 0, 5.0);
        b.push(1, 1, 2.0); // same cell as (0,0) at grid 2
        b.push_sym(3, 2, 7.0);
        b.push_sym(2, 2, 1.0); // same cell as (3,2)/(2,3) block
        let ga = GridSummary::new(&a.to_csr(), 2);
        let gb = GridSummary::new(&b.to_csr(), 2);
        assert_eq!(signature(&ga), signature(&gb));
        // a different occupied cell changes the signature
        let mut c = Coo::new(8, 8);
        c.push(0, 0, 1.0);
        c.push_sym(7, 6, 1.0);
        let gc = GridSummary::new(&c.to_csr(), 2);
        assert_ne!(signature(&ga), signature(&gc));
    }

    #[test]
    fn signature_distinguishes_truncated_windows() {
        // same occupancy bits but different matrix-unit dims (trailing
        // truncation) must not collide
        let m = synth::banded_like(100, 0.9, 1);
        let g = GridSummary::new(&m, 8); // n = 13, last cell 4 units
        let a = g.window(0, 3);
        let b = g.window(10, 3); // touches the truncated edge
        assert_eq!(a.n, b.n);
        if a.cell_nnz.iter().map(|&c| c > 0).collect::<Vec<_>>()
            == b.cell_nnz.iter().map(|&c| c > 0).collect::<Vec<_>>()
        {
            assert_ne!(signature(&a), signature(&b), "dim must separate them");
        } else {
            assert_ne!(signature(&a).words, signature(&b).words);
        }
    }

    #[test]
    fn cache_interns_and_reports_hit_rate() {
        let m = synth::qh882_like(1);
        let g = GridSummary::new(&m, 32);
        let mut cache = SchemeCache::new();
        let s0 = signature(&g.window(0, 4));
        let s1 = signature(&g.window(0, 4));
        let (id0, hit0) = cache.intern(s0);
        let (id1, hit1) = cache.intern(s1);
        assert!(!hit0 && hit1);
        assert_eq!(id0, id1);
        assert_eq!(cache.unique(), 1);
        assert_eq!(cache.unfilled(), vec![0]);
        cache.fill(
            id0,
            Scheme { diag_len: vec![4], fill_len: vec![] },
        );
        assert!(cache.unfilled().is_empty());
        assert_eq!(cache.scheme(id0).diag_len, vec![4]);
        assert!((cache.hit_rate() - 0.5).abs() < 1e-12);
    }
}
