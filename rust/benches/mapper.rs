//! Bench: the hierarchical mapper pipeline on R-MAT graphs.
//!
//! Three rungs per scale, mirroring the pipeline's stages:
//!   map_wW        — windowing + signatures + per-unique-window inference
//!                   at W workers (the scheme cache's amortization)
//!   compile       — per-window plan compilation + merge + spill extraction
//!   composite_mvm — one exact y = Ax through the merged plan + spill

use autogmap::agent::params::init_params;
use autogmap::graph::{synth, GridSummary};
use autogmap::mapper::{self, MapperConfig};
use autogmap::reorder::{reorder, Reordering};
use autogmap::runtime::Manifest;
use autogmap::scheme::{FillRule, RewardWeights};
use autogmap::util::bench::{black_box, Bencher};

fn main() {
    let mut b = Bencher::new();
    let entry = Manifest::builtin().config("qh882_dyn4").unwrap().clone();
    let params = init_params(&entry, 1);
    for (name, nodes, degree) in [("rmat_10k", 10_000usize, 6usize), ("rmat_30k", 30_000, 8)] {
        let m = synth::rmat_like(nodes, 2 * (nodes * degree / 2), 42);
        let r = reorder(&m, Reordering::ReverseCuthillMckee);
        let g = GridSummary::new(&r.matrix, 32);
        let cfg_for = |workers: usize| MapperConfig {
            infer: mapper::InferContext {
                entry: entry.clone(),
                params: params.clone(),
                fill_rule: FillRule::Dynamic { grades: 4 },
                weights: RewardWeights::new(0.8),
                rounds: 4,
                seed: 7,
            },
            overlap: 4,
            workers,
        };
        for workers in [1usize, 2, 8] {
            let cfg = cfg_for(workers);
            b.bench(&format!("map_w{workers}/{name}"), || {
                black_box(mapper::map_graph(&g, &cfg).unwrap())
            });
        }
        let (comp, report) = mapper::map_graph(&g, &cfg_for(8)).unwrap();
        println!(
            "{name}: {} windows, {} unique, cache hit rate {:.1}%",
            report.windows,
            report.unique_windows,
            report.cache_hit_rate * 100.0
        );
        b.bench(&format!("compile/{name}"), || {
            black_box(mapper::compile_composite(&r.matrix, &g, &comp).unwrap())
        });
        let cplan = mapper::compile_composite(&r.matrix, &g, &comp).unwrap();
        let x: Vec<f64> = (0..g.dim).map(|i| (i as f64 * 0.1).sin()).collect();
        b.bench(
            &format!(
                "composite_mvm/{name} ({} tiles + {} spill nnz)",
                cplan.plan.tiles.len(),
                cplan.spilled_nnz()
            ),
            || black_box(cplan.mvm(&x)),
        );
    }
}
