//! The `delta-bench` driver: concurrent edge updaters and queriers
//! against one [`DeltaEngine`], every served answer checked bit-for-bit
//! against a mutating host-CSR oracle, plus an incremental-vs-full remap
//! latency comparison on the same folded matrix.
//!
//! The run builds a deterministic R-MAT deployment (integer weights, so
//! the repo's exactness convention applies), attaches a delta engine, and
//! drives two thread groups under one wall clock:
//!
//! - **updaters** mutate edges confined to a `span` fraction of the
//!   served (reordered) row range — the locality assumption the
//!   incremental remap exploits — keeping an original-id oracle matrix in
//!   lockstep under a write lock; updater 0 triggers one mid-stream
//!   [`DeltaEngine::remap`] at its halfway point, so the swap happens
//!   under live traffic;
//! - **queriers** issue exact MVMs (scalar and batched, both executor
//!   modes) and compare every element against the oracle under a read
//!   lock. Any mismatch fails the run — `"mismatches": 0` in the ledger
//!   is a checked invariant, not an observation.
//!
//! After traffic drains, one more confined update batch lands and the
//! same folded matrix is remapped twice: incrementally (persistent warm
//! cache — untouched windows are scheme-cache hits and skip controller
//! inference) and fully (fresh cache — every unique window pays again).
//! The ledger (`BENCH_delta.json`) records update/s, query/s, both remap
//! latencies, and `remap_speedup_vs_full`; the CI `delta-smoke` job
//! asserts the speedup stays ≥ 2 on the default 10k-node graph.

use super::{DeltaEngine, EdgeUpdate, RemapReport, RowStore};
use crate::api::deploy::{DeploymentBuilder, Source, Strategy};
use crate::api::error::{Error, Result};
use crate::graph::synth;
use crate::util::bench::write_bench_json;
use crate::util::json::Json;
use crate::util::pool::WorkerPool;
use crate::util::rng::Pcg64;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Instant;

/// Configuration for one dynamic-graph bench run.
#[derive(Clone, Debug)]
pub struct DeltaBenchOptions {
    /// R-MAT node count (`AUTOGMAP_BENCH_FAST=1` caps it at 1200)
    pub nodes: usize,
    /// average edges per node (nnz ≈ nodes × degree)
    pub degree: usize,
    /// grid summary resolution the mapper works at
    pub grid: usize,
    /// controller the hierarchical mapper infers with
    pub controller: String,
    /// window overlap in grid cells
    pub overlap: usize,
    /// crossbar banks the fleet spreads tiles over
    pub banks: usize,
    /// shared-pool worker threads (mapper inference + batch execution)
    pub workers: usize,
    /// concurrent updater threads (floored at 1)
    pub updaters: usize,
    /// concurrent querier threads (floored at 1)
    pub queriers: usize,
    /// update batches per updater
    pub updates: usize,
    /// edges per update batch
    pub batch: usize,
    /// queries per querier
    pub queries: usize,
    /// fraction of the served row range updates are confined to — the
    /// window-locality the incremental remap exploits (clamped to
    /// [1 row, everything])
    pub span: f64,
    /// rng seed (graph, update, and query streams derive from it)
    pub seed: u64,
    /// where to write the machine-readable ledger
    pub bench_json: PathBuf,
}

impl Default for DeltaBenchOptions {
    fn default() -> DeltaBenchOptions {
        DeltaBenchOptions {
            nodes: 10_000,
            degree: 8,
            grid: 32,
            controller: "qh882_dyn4".into(),
            overlap: 4,
            banks: 4,
            workers: 4,
            updaters: 2,
            queriers: 2,
            updates: 40,
            batch: 8,
            queries: 60,
            span: 0.05,
            seed: 0xde17a,
            bench_json: PathBuf::from("BENCH_delta.json"),
        }
    }
}

/// What a finished run measured. A report is only returned when every
/// served answer bit-matched the oracle; a mismatch is an `Err` (after
/// the ledger is written, so CI can still inspect the artifact).
#[derive(Clone, Debug)]
pub struct DeltaBenchReport {
    pub nodes: usize,
    pub nnz: u64,
    pub updates_applied: u64,
    pub queries_served: u64,
    pub mismatches: u64,
    pub update_per_s: f64,
    pub query_per_s: f64,
    pub remap_incremental: RemapReport,
    pub remap_full: RemapReport,
    pub remap_speedup_vs_full: f64,
}

/// Oracle state shared between updaters and queriers: the mutated matrix
/// in original node ids. Updaters hold the write lock across
/// {engine.apply + oracle mutate} so queriers always compare against a
/// consistent pair.
struct Oracle {
    truth: RowStore,
}

impl Oracle {
    fn spmv(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0f64; x.len()];
        for (r, row) in self.truth.rows.iter().enumerate() {
            let mut acc = 0.0f64;
            for (&c, &v) in row {
                acc += v * x[c];
            }
            y[r] = acc;
        }
        y
    }
}

fn integer_vec(rng: &mut Pcg64, dim: usize) -> Vec<f64> {
    (0..dim).map(|_| (rng.below(7) as f64) - 3.0).collect()
}

/// Random confined update batch: served positions in `[0, lim)` mapped
/// through the permutation to the original ids the engine's wire surface
/// speaks. Integer weights in `0..=5`; 0 deletes.
fn confined_batch(
    rng: &mut Pcg64,
    perm: &[usize],
    lim: usize,
    batch: usize,
) -> Vec<EdgeUpdate> {
    (0..batch)
        .map(|_| {
            let rs = rng.below(lim as u64) as usize;
            let cs = rng.below(lim as u64) as usize;
            EdgeUpdate {
                row: perm[rs],
                col: perm[cs],
                weight: rng.below(6) as f64,
            }
        })
        .collect()
}

/// Run the bench and write `BENCH_delta.json`.
pub fn run_delta_bench(opts: &DeltaBenchOptions) -> Result<DeltaBenchReport> {
    let fast = std::env::var("AUTOGMAP_BENCH_FAST").is_ok_and(|v| v == "1");
    let nodes = if fast { opts.nodes.min(1200) } else { opts.nodes }.max(16);
    let degree = opts.degree.clamp(1, (nodes - 1) / 2);
    let updaters = opts.updaters.max(1);
    let queriers = opts.queriers.max(1);

    // the bench owns the matrix so the oracle sees the same bits the
    // deployment mapped (weights are all 1.0 — integer-exact)
    let target_nnz = 2 * (nodes * degree / 2);
    let m = synth::rmat_like(nodes, target_nnz, opts.seed);
    let dep = DeploymentBuilder::new(
        Source::Matrix { label: format!("delta-rmat{nodes}"), matrix: m.clone() },
        Strategy::Hierarchical { controller: opts.controller.clone(), overlap: opts.overlap },
    )
    .grid(opts.grid.max(1))
    .banks(opts.banks.max(1))
    .workers(opts.workers.max(1))
    .seed(opts.seed)
    .build()?;
    let dim = nodes;
    let perm = dep.permutation().to_vec();
    let lim = ((dim as f64 * opts.span).ceil() as usize).clamp(1, dim);

    let pool = Arc::new(WorkerPool::new(opts.workers.max(1)));
    let engine = DeltaEngine::attach(dep, pool)?;
    let oracle = Arc::new(RwLock::new(Oracle { truth: RowStore::from_csr(&m) }));

    let applied = AtomicU64::new(0);
    let served = AtomicU64::new(0);
    let mismatches = AtomicU64::new(0);
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for u in 0..updaters {
            let engine = &engine;
            let oracle = &oracle;
            let applied = &applied;
            let perm = &perm;
            scope.spawn(move || {
                let mut rng = Pcg64::new(opts.seed, 0x0b5_0000 + u as u64);
                for round in 0..opts.updates {
                    // updater 0 folds the plan mid-stream: the swap must be
                    // invisible to concurrent queriers
                    if u == 0 && round == opts.updates / 2 {
                        engine.remap().expect("mid-stream remap");
                    }
                    let edges = confined_batch(&mut rng, perm, lim, opts.batch.max(1));
                    let mut o = oracle.write().unwrap();
                    engine.apply(&edges).expect("update batch");
                    for e in &edges {
                        o.truth.set(e.row, e.col, e.weight);
                    }
                    drop(o);
                    applied.fetch_add(edges.len() as u64, Ordering::Relaxed);
                }
            });
        }
        for q in 0..queriers {
            let engine = &engine;
            let oracle = &oracle;
            let served = &served;
            let mismatches = &mismatches;
            scope.spawn(move || {
                let mut rng = Pcg64::new(opts.seed, 0x4e7_0000 + q as u64);
                for round in 0..opts.queries {
                    let x = integer_vec(&mut rng, dim);
                    let o = oracle.read().unwrap();
                    let want = o.spmv(&x);
                    // rotate serving modes: scalar, batched, batched-sharded
                    let got = match round % 3 {
                        0 => engine.mvm(&x).expect("query"),
                        r => engine
                            .execute(std::slice::from_ref(&x), r == 2)
                            .expect("batch query")
                            .remove(0),
                    };
                    drop(o);
                    if got != want {
                        mismatches.fetch_add(1, Ordering::Relaxed);
                    }
                    served.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
    });
    let elapsed = t0.elapsed().as_secs_f64().max(1e-9);
    let updates_applied = applied.load(Ordering::Relaxed);
    let queries_served = served.load(Ordering::Relaxed);
    let bad = mismatches.load(Ordering::Relaxed);

    // one more confined batch, then remap the SAME folded matrix twice:
    // warm persistent cache vs fresh cache
    {
        let mut rng = Pcg64::new(opts.seed, 0xf01d);
        let edges = confined_batch(&mut rng, &perm, lim, opts.batch.max(1) * 4);
        let mut o = oracle.write().unwrap();
        engine.apply(&edges)?;
        for e in &edges {
            o.truth.set(e.row, e.col, e.weight);
        }
    }
    let inc = engine.remap()?;
    let full = engine.remap_full()?;
    let speedup = full.wall_seconds / inc.wall_seconds.max(1e-9);

    // post-remap answers must still match the oracle exactly
    let mut post_bad = 0u64;
    {
        let mut rng = Pcg64::new(opts.seed, 0xaf7e6);
        let o = oracle.read().unwrap();
        for _ in 0..4 {
            let x = integer_vec(&mut rng, dim);
            if engine.mvm(&x)? != o.spmv(&x) {
                post_bad += 1;
            }
        }
    }
    let bad = bad + post_bad;

    let report = DeltaBenchReport {
        nodes,
        nnz: inc.nnz,
        updates_applied,
        queries_served,
        mismatches: bad,
        update_per_s: updates_applied as f64 / elapsed,
        query_per_s: queries_served as f64 / elapsed,
        remap_incremental: inc.clone(),
        remap_full: full.clone(),
        remap_speedup_vs_full: speedup,
    };
    write_bench_json(
        &opts.bench_json,
        vec![
            ("bench", Json::Str("delta".into())),
            ("nodes", Json::Num(nodes as f64)),
            ("nnz", Json::Num(report.nnz as f64)),
            ("updaters", Json::Num(updaters as f64)),
            ("queriers", Json::Num(queriers as f64)),
            ("span", Json::Num(opts.span)),
            ("updates_applied", Json::Num(updates_applied as f64)),
            ("queries_served", Json::Num(queries_served as f64)),
            ("mismatches", Json::Num(bad as f64)),
            ("update_per_s", Json::Num(report.update_per_s)),
            ("query_per_s", Json::Num(report.query_per_s)),
            ("remap_incremental_s", Json::Num(inc.wall_seconds)),
            ("remap_full_s", Json::Num(full.wall_seconds)),
            ("remap_speedup_vs_full", Json::Num(speedup)),
            ("windows", Json::Num(inc.windows as f64)),
            ("reused_windows", Json::Num(inc.reused_windows as f64)),
            ("cache_entries", Json::Num(inc.cache_entries as f64)),
            ("cache_hit_rate", Json::Num(inc.cache_hit_rate)),
            ("generation", Json::Num(full.generation as f64)),
        ],
    )
    .map_err(|e| Error::Io(format!("writing {}: {e}", opts.bench_json.display())))?;
    if bad > 0 {
        return Err(Error::Internal(format!(
            "{bad} served answers diverged from the host-CSR oracle"
        )));
    }
    if inc.reused_windows == 0 && inc.windows > 1 {
        return Err(Error::Internal(format!(
            "incremental remap reused no window schemes across {} windows — \
             the persistent cache is not warming",
            inc.windows
        )));
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_delta_bench_is_exact_end_to_end() {
        let dir = std::env::temp_dir().join(format!("delta_bench_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let opts = DeltaBenchOptions {
            nodes: 700,
            degree: 4,
            grid: 8,
            controller: "qm7_dyn4".into(),
            overlap: 2,
            banks: 2,
            workers: 2,
            updaters: 2,
            queriers: 2,
            updates: 6,
            batch: 4,
            queries: 9,
            span: 0.08,
            seed: 77,
            bench_json: dir.join("BENCH_delta.json"),
        };
        let report = run_delta_bench(&opts).unwrap();
        assert_eq!(report.mismatches, 0);
        assert_eq!(report.updates_applied, 2 * 6 * 4);
        assert_eq!(report.queries_served, 2 * 9);
        assert!(report.update_per_s > 0.0);
        assert!(report.query_per_s > 0.0);
        // mid-stream remap + incremental + full
        assert_eq!(report.remap_full.generation, 3);
        assert!(report.remap_incremental.windows >= 1);
        let doc = std::fs::read_to_string(&opts.bench_json).unwrap();
        for key in [
            "\"mismatches\"",
            "\"update_per_s\"",
            "\"query_per_s\"",
            "\"remap_incremental_s\"",
            "\"remap_full_s\"",
            "\"remap_speedup_vs_full\"",
            "\"reused_windows\"",
        ] {
            assert!(doc.contains(key), "ledger missing {key}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
