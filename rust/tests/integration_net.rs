//! Socket-level integration tests for the multi-tenant network serving
//! tier: the load-bearing invariant (answers through the socket are
//! bit-identical to direct `Deployment::mvm`, per tenant, under
//! concurrency and across a live hot-swap), typed busy/deadline
//! rejections, NDJSON robustness, and stdin/socket error-format parity.

use autogmap::algo::{
    bfs_reference, max_abs_diff, pagerank, sssp_reference, CsrEngine, PageRankOptions,
};
use autogmap::api::{serve_loop, Deployment, DeploymentBuilder, ServeOptions, Source, Strategy};
use autogmap::graph::synth;
use autogmap::net::{DeploymentRegistry, NetOptions, NetServer, RegistryOptions};
use autogmap::util::json::{num_arr, obj, Json};
use autogmap::util::propcheck::check;
use autogmap::util::rng::Pcg64;
use std::io::{BufRead, BufReader, BufWriter, Cursor, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;

fn temp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A small deployment over a 200-node R-MAT graph. The same `seed` gives
/// the same matrix, so two calls with different `block` are two distinct
/// mappings of one graph — exactly what a hot-swap installs.
fn small_dep(label: &str, seed: u64, block: usize) -> Deployment {
    DeploymentBuilder::new(
        Source::Matrix {
            label: label.into(),
            matrix: synth::rmat_like(200, 800, seed),
        },
        Strategy::FixedBlock { block },
    )
    .grid(8)
    .workers(2)
    .build()
    .unwrap()
}

fn registry(workers: usize, queue_depth: usize, sharded: bool) -> Arc<DeploymentRegistry> {
    Arc::new(DeploymentRegistry::new(&RegistryOptions {
        workers,
        queue_depth,
        sharded,
        fault: None,
        remap_after: 0,
    }))
}

/// A blocking NDJSON test client over a real TCP connection.
struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Result<Client, String> {
        let s = TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
        let r = s.try_clone().map_err(|e| format!("clone: {e}"))?;
        Ok(Client {
            reader: BufReader::new(r),
            writer: BufWriter::new(s),
        })
    }

    fn send(&mut self, line: &str) -> Result<(), String> {
        writeln!(self.writer, "{line}").map_err(|e| format!("send: {e}"))?;
        self.writer.flush().map_err(|e| format!("flush: {e}"))
    }

    fn recv(&mut self) -> Result<Option<Json>, String> {
        let mut buf = String::new();
        let n = self.reader.read_line(&mut buf).map_err(|e| format!("recv: {e}"))?;
        if n == 0 {
            return Ok(None);
        }
        Json::parse(buf.trim()).map(Some).map_err(|e| format!("bad response: {e}"))
    }

    fn roundtrip(&mut self, line: &str) -> Result<Json, String> {
        self.send(line)?;
        self.recv()?.ok_or_else(|| "connection closed mid-request".into())
    }
}

fn req_line(tenant: &str, id: u64, x: &[f64]) -> String {
    obj(vec![
        ("tenant", Json::Str(tenant.into())),
        ("id", Json::Num(id as f64)),
        ("x", num_arr(x.to_vec())),
    ])
    .to_string()
}

fn parse_y(resp: &Json) -> Result<Vec<f64>, String> {
    if resp.get("error") != &Json::Null {
        return Err(format!("error response: {}", resp.to_string()));
    }
    resp.get("y")
        .as_arr()
        .ok_or_else(|| format!("no y in {}", resp.to_string()))?
        .iter()
        .map(|v| v.as_f64().ok_or_else(|| "non-numeric y element".to_string()))
        .collect()
}

/// The tentpole property: at 1, 2, and 8 workers, with 3 concurrent
/// clients interleaving 2 tenants over one socket, every answer is
/// bit-identical to `Deployment::mvm` on the very deployment the registry
/// serves — in both executor modes.
#[test]
fn socket_answers_bit_match_direct_mvm_property() {
    check("net_socket_matches_mvm", 2, |rng| {
        let sharded = rng.below(2) == 0;
        for &workers in &[1usize, 2, 8] {
            let reg = registry(workers, 16, sharded);
            reg.insert("graphA", small_dep("graphA", 7, 1), None);
            reg.insert("graphB", small_dep("graphB", 11, 2), None);
            let server = NetServer::start(reg.clone(), "127.0.0.1:0", &NetOptions::default())
                .map_err(|e| e.to_string())?;
            let addr = server.addr();
            let mut handles = Vec::new();
            for c in 0..3u64 {
                let seed = rng.next_u64();
                let reg = reg.clone();
                handles.push(std::thread::spawn(move || -> Result<(), String> {
                    let mut conn = Client::connect(addr)?;
                    let mut rng = Pcg64::new(seed, c);
                    for r in 0..8u64 {
                        let tenant = if rng.below(2) == 0 { "graphA" } else { "graphB" };
                        let entry = reg.get(tenant).map_err(|e| e.to_string())?.entry();
                        let x: Vec<f64> =
                            (0..entry.dim()).map(|_| rng.uniform(-2.0, 2.0)).collect();
                        let want =
                            entry.deployment().mvm(&x).map_err(|e| e.to_string())?;
                        let resp = conn.roundtrip(&req_line(tenant, r, &x))?;
                        if resp.get("tenant").as_str() != Some(tenant) {
                            return Err(format!("tenant echo lost: {}", resp.to_string()));
                        }
                        let got = parse_y(&resp)?;
                        if got != want {
                            return Err(format!(
                                "workers {workers} client {c} req {r} tenant {tenant}: \
                                 socket answer != Deployment::mvm"
                            ));
                        }
                    }
                    Ok(())
                }));
            }
            for h in handles {
                h.join().map_err(|_| "client thread panicked".to_string())??;
            }
        }
        Ok(())
    });
}

/// Hot-swap under load: two clients stream requests while one of them
/// reloads the tenant's bundle mid-stream. Every response must bit-match
/// the old or the new generation's own `mvm` (nothing dropped, nothing
/// half-swapped), and every post-swap request must match the new one.
#[test]
fn hot_swap_under_load_drops_and_mismatches_nothing() {
    let dir = temp_dir("autogmap_net_swap");
    let bundle = dir.join("remapped.json");
    small_dep("g", 13, 4).save(&bundle).unwrap();
    let new_oracle = Arc::new(Deployment::load(&bundle).unwrap());

    let reg = registry(2, 16, true);
    reg.insert("g", small_dep("g", 13, 1), None);
    let old_entry = reg.get("g").unwrap().entry();
    assert_eq!(old_entry.generation(), 1);
    let server = NetServer::start(reg.clone(), "127.0.0.1:0", &NetOptions::default()).unwrap();
    let addr = server.addr();
    let swap_line = obj(vec![(
        "admin",
        obj(vec![(
            "reload",
            obj(vec![
                ("id", Json::Str("g".into())),
                ("bundle", Json::Str(bundle.display().to_string())),
            ]),
        )]),
    )])
    .to_string();

    let requests_per_client = 40u64;
    let mut handles = Vec::new();
    for c in 0..2u64 {
        let old_entry = old_entry.clone();
        let new_oracle = new_oracle.clone();
        let swap_line = swap_line.clone();
        handles.push(std::thread::spawn(move || -> Result<u64, String> {
            let mut conn = Client::connect(addr)?;
            let mut rng = Pcg64::new(0xabcd, c);
            let mut served = 0u64;
            for r in 0..requests_per_client {
                let x: Vec<f64> =
                    (0..old_entry.dim()).map(|_| rng.uniform(-1.0, 1.0)).collect();
                let want_old = old_entry.deployment().mvm(&x).map_err(|e| e.to_string())?;
                let want_new = new_oracle.mvm(&x).map_err(|e| e.to_string())?;
                let got = parse_y(&conn.roundtrip(&req_line("g", r, &x))?)?;
                if got != want_old && got != want_new {
                    return Err(format!(
                        "client {c} req {r}: answer matches neither generation"
                    ));
                }
                served += 1;
                if c == 0 && r == requests_per_client / 2 {
                    let ack = conn.roundtrip(&swap_line)?;
                    if ack.get("admin").as_str() != Some("reload") {
                        return Err(format!("swap rejected: {}", ack.to_string()));
                    }
                    if ack.get("generation").as_i64() != Some(2) {
                        return Err(format!("generation not bumped: {}", ack.to_string()));
                    }
                }
            }
            Ok(served)
        }));
    }
    let total: u64 = handles
        .into_iter()
        .map(|h| h.join().expect("client panicked").expect("client failed"))
        .sum();
    assert_eq!(total, 2 * requests_per_client, "zero dropped responses under swap");

    // the registry now serves generation 2, and new requests bit-match
    // the reloaded bundle's own mvm
    let entry = reg.get("g").unwrap().entry();
    assert_eq!(entry.generation(), 2);
    let mut conn = Client::connect(addr).unwrap();
    let x: Vec<f64> = (0..entry.dim()).map(|i| (i as f64 * 0.37).sin()).collect();
    let got = parse_y(&conn.roundtrip(&req_line("g", 999, &x)).unwrap()).unwrap();
    assert_eq!(got, new_oracle.mvm(&x).unwrap(), "post-swap requests serve the new plan");
    // in-flight-era entries stayed alive and still answer on the old plan
    assert_eq!(
        old_entry.execute(vec![x.clone()], true).0[0],
        old_entry.deployment().mvm(&x).unwrap()
    );
    let stats = conn.roundtrip(r#"{"admin":"stats"}"#).unwrap();
    let g = stats.get("stats").get("g").clone();
    assert_eq!(g.get("served").as_i64(), Some(2 * requests_per_client as i64 + 1));
    assert_eq!(g.get("generation").as_i64(), Some(2));
    assert_eq!(g.get("errors").as_i64(), Some(0));
}

/// Busy and deadline rejections at queue depth 1 are machine-readable
/// typed error responses on a connection that keeps serving — never
/// connection drops.
#[test]
fn busy_and_deadline_rejections_are_typed_not_drops() {
    let reg = registry(2, 1, true);
    reg.insert("g", small_dep("g", 17, 1), None);
    let tenant = reg.get("g").unwrap();
    let dim = tenant.entry().dim();
    let server = NetServer::start(reg.clone(), "127.0.0.1:0", &NetOptions::default()).unwrap();
    let mut conn = Client::connect(server.addr()).unwrap();
    let x = vec![0.5f64; dim];

    // hold the tenant's only admission slot through the shared registry
    // handle, then a wire request must get a typed busy rejection
    let slot = tenant.admit().unwrap();
    let resp = conn.roundtrip(&req_line("g", 1, &x)).unwrap();
    assert_eq!(resp.get("error").get("kind").as_str(), Some("busy"));
    assert_eq!(resp.get("tenant").as_str(), Some("g"));
    let msg = resp.get("error").get("message").as_str().unwrap();
    assert!(msg.contains("depth limit 1"), "{msg}");
    drop(slot);

    // the same connection serves normally once the slot frees
    let resp = conn.roundtrip(&req_line("g", 2, &x)).unwrap();
    assert_eq!(resp.get("error"), &Json::Null);
    assert_eq!(
        parse_y(&resp).unwrap(),
        tenant.entry().deployment().mvm(&x).unwrap()
    );

    // an already-expired deadline budget is rejected before execution
    let req = obj(vec![
        ("tenant", Json::Str("g".into())),
        ("id", Json::Num(3.0)),
        ("deadline_ms", Json::Num(0.0)),
        ("x", num_arr(x.clone())),
    ]);
    let resp = conn.roundtrip(&req.to_string()).unwrap();
    assert_eq!(resp.get("error").get("kind").as_str(), Some("deadline"));

    let stats = conn.roundtrip(r#"{"admin":"stats"}"#).unwrap();
    let g = stats.get("stats").get("g").clone();
    assert_eq!(g.get("rejected_busy").as_i64(), Some(1));
    assert_eq!(g.get("rejected_deadline").as_i64(), Some(1));
    assert_eq!(g.get("served").as_i64(), Some(1));
    assert_eq!(g.get("inflight").as_i64(), Some(0), "RAII released every slot");
}

/// NDJSON robustness on the socket, and byte-identical error objects
/// between the stdin serve loop and the TCP tier (both are built on the
/// same dispatch core).
#[test]
fn wire_robustness_and_error_parity_with_stdin_loop() {
    let reg = registry(2, 8, true);
    reg.insert("g", small_dep("g", 7, 1), None);
    let entry = reg.get("g").unwrap().entry();
    let dim = entry.dim();
    let opts = NetOptions {
        max_conns: 8,
        max_line_bytes: 2048,
        ..NetOptions::default()
    };
    let server = NetServer::start(reg.clone(), "127.0.0.1:0", &opts).unwrap();
    let mut conn = Client::connect(server.addr()).unwrap();
    let x = vec![0.25f64; dim];

    // blank lines are skipped, not Parse errors: the next response
    // belongs to the next real request
    conn.send("").unwrap();
    conn.send("   ").unwrap();
    let resp = conn.roundtrip(&req_line("g", 9, &x)).unwrap();
    assert_eq!(resp.get("id").as_i64(), Some(9));
    assert!(parse_y(&resp).is_ok());

    // a length mismatch names both lengths
    let resp = conn.roundtrip(&req_line("g", 1, &[1.0, 2.0, 3.0])).unwrap();
    assert_eq!(resp.get("error").get("kind").as_str(), Some("validate"));
    let msg = resp.get("error").get("message").as_str().unwrap().to_string();
    assert!(msg.contains('3') && msg.contains(&dim.to_string()), "{msg}");

    // ... and the error object is byte-identical to the stdin loop's for
    // the same deployment and the same bad request
    let socket_err = resp.get("error").clone();
    let stdin_input = r#"{"id":1,"x":[1,2,3]}"#.to_string() + "\n";
    let mut stdin_out: Vec<u8> = Vec::new();
    serve_loop(
        entry.deployment(),
        &ServeOptions::default(),
        Cursor::new(stdin_input),
        &mut stdin_out,
    )
    .unwrap();
    let first = String::from_utf8(stdin_out).unwrap().lines().next().unwrap().to_string();
    let stdin_err = Json::parse(&first).unwrap().get("error").clone();
    assert_eq!(socket_err, stdin_err, "both transports share one error wire format");

    // an oversized line is drained and rejected with a bounded read; the
    // connection keeps working
    let resp = conn.roundtrip(&"x".repeat(4000)).unwrap();
    assert_eq!(resp.get("error").get("kind").as_str(), Some("parse"));
    assert!(resp.get("error").get("message").as_str().unwrap().contains("2048"));
    let resp = conn.roundtrip(&req_line("g", 10, &x)).unwrap();
    assert_eq!(parse_y(&resp).unwrap(), entry.deployment().mvm(&x).unwrap());

    // unknown tenants are typed validate errors naming the deployed ids
    let resp = conn.roundtrip(&req_line("nope", 1, &x)).unwrap();
    assert_eq!(resp.get("error").get("kind").as_str(), Some("validate"));
    let msg = resp.get("error").get("message").as_str().unwrap();
    assert!(msg.contains("nope") && msg.contains("\"g\""), "{msg}");

    // explicit batches answer with ys, bit-identical per row
    let xs: Vec<Vec<f64>> = (0..3).map(|s| vec![s as f64 * 0.5 - 0.5; dim]).collect();
    let req = obj(vec![
        ("tenant", Json::Str("g".into())),
        ("id", Json::Num(11.0)),
        ("xs", Json::Arr(xs.iter().cloned().map(num_arr).collect())),
    ]);
    let resp = conn.roundtrip(&req.to_string()).unwrap();
    let ys = resp.get("ys").as_arr().unwrap();
    assert_eq!(ys.len(), 3);
    for (xi, yi) in xs.iter().zip(ys) {
        let got: Vec<f64> =
            yi.as_arr().unwrap().iter().map(|v| v.as_f64().unwrap()).collect();
        assert_eq!(got, entry.deployment().mvm(xi).unwrap());
    }
}

/// Algorithm-request error objects — bad parameters and non-convergence —
/// are byte-identical between the stdin serve loop and the TCP tier for
/// the same deployment and the same request body.
#[test]
fn algo_error_objects_are_byte_identical_across_transports() {
    let reg = registry(2, 8, true);
    reg.insert("g", small_dep("g", 29, 1), None);
    let entry = reg.get("g").unwrap().entry();
    let server = NetServer::start(reg.clone(), "127.0.0.1:0", &NetOptions::default()).unwrap();
    let mut conn = Client::connect(server.addr()).unwrap();

    // both failure shapes: a validate error naming the wire field, and a
    // typed no_converge whose message embeds the (deterministic) residual
    let bodies = [
        (r#"{"pagerank":{"damping":1.5}}"#, "validate"),
        (r#"{"pagerank":{"tol":0.000001,"max_iters":1}}"#, "no_converge"),
    ];
    for (body, kind) in bodies {
        let socket_req = format!(r#"{{"tenant":"g","id":1,{}"#, &body[1..]);
        let resp = conn.roundtrip(&socket_req).unwrap();
        assert_eq!(resp.get("error").get("kind").as_str(), Some(kind), "{body}");
        let socket_err = resp.get("error").clone();

        let stdin_input = format!("{{\"id\":1,{}\n", &body[1..]);
        let mut stdin_out: Vec<u8> = Vec::new();
        serve_loop(
            entry.deployment(),
            &ServeOptions::default(),
            Cursor::new(stdin_input),
            &mut stdin_out,
        )
        .unwrap();
        let first =
            String::from_utf8(stdin_out).unwrap().lines().next().unwrap().to_string();
        let stdin_err = Json::parse(&first).unwrap().get("error").clone();
        assert_eq!(socket_err, stdin_err, "transports disagree for {body}");
    }

    // the validate message names the field; no_converge names the knobs
    // that would fix it
    let resp = conn.roundtrip(r#"{"tenant":"g","id":2,"pagerank":{"damping":1.5}}"#).unwrap();
    let msg = resp.get("error").get("message").as_str().unwrap();
    assert!(msg.contains("pagerank.damping"), "{msg}");
}

/// Algorithm requests across a mid-stream hot-swap: the same graph
/// remapped at a different block size keeps answering PageRank, BFS, and
/// SSSP correctly against the host-CSR oracles, on the same connection,
/// before and after the reload — the algorithm layer is plan-shape
/// agnostic even while the plan changes under it.
#[test]
fn algo_requests_stay_oracle_correct_across_hot_swap() {
    let dir = temp_dir("autogmap_net_algo_swap");
    let bundle = dir.join("algo_remapped.json");
    small_dep("g", 23, 4).save(&bundle).unwrap();

    // host-CSR oracles on the very graph both generations map
    let m = synth::rmat_like(200, 800, 23);
    let want_bfs: Vec<f64> = bfs_reference(&m, 0).into_iter().map(|l| l as f64).collect();
    let want_sssp: Vec<f64> = sssp_reference(&m, 0)
        .into_iter()
        .map(|d| if d.is_finite() { d } else { -1.0 })
        .collect();
    let (want_pr, _) = pagerank(&CsrEngine(&m), &PageRankOptions::default()).unwrap();

    let reg = registry(2, 8, true);
    reg.insert("g", small_dep("g", 23, 1), None);
    let server = NetServer::start(reg.clone(), "127.0.0.1:0", &NetOptions::default()).unwrap();
    let mut conn = Client::connect(server.addr()).unwrap();

    let verify = |conn: &mut Client, round: &str| {
        let resp = conn.roundtrip(r#"{"tenant":"g","id":1,"pagerank":{}}"#).unwrap();
        let scores: Vec<f64> = resp
            .get("pagerank")
            .get("scores")
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect();
        let d = max_abs_diff(&scores, &want_pr);
        assert!(d <= 1e-8, "{round}: pagerank off the CSR oracle by {d:e}");
        assert_eq!(
            resp.get("pagerank").get("trace").get("converged").as_bool(),
            Some(true),
            "{round}"
        );
        let resp = conn.roundtrip(r#"{"tenant":"g","id":2,"bfs":{"source":0}}"#).unwrap();
        let lv: Vec<f64> = resp
            .get("bfs")
            .get("levels")
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect();
        assert_eq!(lv, want_bfs, "{round}: BFS levels not bit-identical");
        let resp = conn.roundtrip(r#"{"tenant":"g","id":3,"sssp":{"source":0}}"#).unwrap();
        let dist: Vec<f64> = resp
            .get("sssp")
            .get("dist")
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect();
        assert_eq!(dist, want_sssp, "{round}: SSSP distances not bit-identical");
    };

    verify(&mut conn, "generation 1 (block 1)");

    let swap_line = obj(vec![(
        "admin",
        obj(vec![(
            "reload",
            obj(vec![
                ("id", Json::Str("g".into())),
                ("bundle", Json::Str(bundle.display().to_string())),
            ]),
        )]),
    )])
    .to_string();
    let ack = conn.roundtrip(&swap_line).unwrap();
    assert_eq!(ack.get("generation").as_i64(), Some(2));

    verify(&mut conn, "generation 2 (block 4)");

    // per-tenant algo counters are cumulative across generations
    let stats = conn.roundtrip(r#"{"admin":"stats"}"#).unwrap();
    let algo = stats.get("stats").get("g").get("algo").clone();
    assert_eq!(algo.get("pagerank").as_i64(), Some(2));
    assert_eq!(algo.get("bfs").as_i64(), Some(2));
    assert_eq!(algo.get("sssp").as_i64(), Some(2));
    assert_eq!(algo.get("gcn").as_i64(), Some(0));
    assert!(algo.get("mvms").as_i64().unwrap() > 6, "algo runs fan out into many MVMs");
}

/// A connection over the `--max-conns` cap gets a typed busy line and a
/// clean close — not a silent drop.
#[test]
fn connection_cap_rejects_with_typed_busy() {
    let reg = registry(1, 4, true);
    reg.insert("g", small_dep("g", 19, 1), None);
    let dim = reg.get("g").unwrap().entry().dim();
    let opts = NetOptions {
        max_conns: 1,
        max_line_bytes: 1 << 20,
        ..NetOptions::default()
    };
    let server = NetServer::start(reg.clone(), "127.0.0.1:0", &opts).unwrap();

    // first connection is admitted and serves (the roundtrip guarantees
    // the accept loop has processed it before we open the second)
    let mut first = Client::connect(server.addr()).unwrap();
    let x = vec![1.0f64; dim];
    assert!(parse_y(&first.roundtrip(&req_line("g", 1, &x)).unwrap()).is_ok());

    // second connection: one busy line, then EOF
    let mut second = Client::connect(server.addr()).unwrap();
    let line = second.recv().unwrap().expect("rejection line, not a silent drop");
    assert_eq!(line.get("error").get("kind").as_str(), Some("busy"));
    assert!(line
        .get("error")
        .get("message")
        .as_str()
        .unwrap()
        .contains("<connections>"));
    assert!(second.recv().unwrap().is_none(), "rejected connection closes cleanly");

    // the admitted connection is unaffected
    assert!(parse_y(&first.roundtrip(&req_line("g", 2, &x)).unwrap()).is_ok());
}

/// A connection idle past `--read-timeout-ms` is answered with a typed
/// `timeout` error line and closed — never a silent drop. An active
/// connection is unaffected.
#[test]
fn idle_connections_time_out_with_a_typed_error_line() {
    let reg = registry(1, 4, true);
    reg.insert("g", small_dep("g", 31, 1), None);
    let dim = reg.get("g").unwrap().entry().dim();
    let opts = NetOptions {
        read_timeout_ms: 150,
        ..NetOptions::default()
    };
    let server = NetServer::start(reg.clone(), "127.0.0.1:0", &opts).unwrap();
    let mut conn = Client::connect(server.addr()).unwrap();

    // active traffic inside the budget serves normally
    let x = vec![0.5f64; dim];
    assert!(parse_y(&conn.roundtrip(&req_line("g", 1, &x)).unwrap()).is_ok());

    // then go idle: the server says why before closing
    let line = conn.recv().unwrap().expect("timeout line, not a silent drop");
    assert_eq!(line.get("error").get("kind").as_str(), Some("timeout"));
    let msg = line.get("error").get("message").as_str().unwrap();
    assert!(msg.contains("150"), "timeout message names the budget: {msg}");
    assert!(conn.recv().unwrap().is_none(), "timed-out connection closes cleanly");
}

/// Graceful shutdown answers the request it is serving before closing:
/// a client whose batch is in flight when the drain starts still gets its
/// full, bit-exact response, and the server reports a complete drain.
#[test]
fn graceful_shutdown_answers_in_flight_requests_before_closing() {
    let reg = registry(2, 8, true);
    reg.insert("g", small_dep("g", 37, 1), None);
    let entry = reg.get("g").unwrap().entry();
    let dim = entry.dim();
    let mut server =
        NetServer::start(reg.clone(), "127.0.0.1:0", &NetOptions::default()).unwrap();
    let addr = server.addr();

    let xs: Vec<Vec<f64>> = (0..64).map(|s| vec![(s as f64 * 0.1).sin(); dim]).collect();
    let want: Vec<Vec<f64>> =
        xs.iter().map(|x| entry.deployment().mvm(x).unwrap()).collect();
    let req = obj(vec![
        ("tenant", Json::Str("g".into())),
        ("id", Json::Num(1.0)),
        ("xs", Json::Arr(xs.iter().cloned().map(num_arr).collect())),
    ])
    .to_string();
    let h = std::thread::spawn(move || -> Result<Json, String> {
        let mut conn = Client::connect(addr)?;
        conn.roundtrip(&req)
    });
    // let the batch get in flight, then drain while it (possibly still)
    // executes — the handler must finish and answer before closing
    std::thread::sleep(std::time::Duration::from_millis(20));
    let drained = server.shutdown_graceful(std::time::Duration::from_secs(10));
    let resp = h
        .join()
        .expect("client panicked")
        .expect("in-flight request was dropped by the drain");
    let ys = resp.get("ys").as_arr().unwrap();
    assert_eq!(ys.len(), want.len(), "partial response escaped the drain");
    for (yi, wi) in ys.iter().zip(&want) {
        let got: Vec<f64> =
            yi.as_arr().unwrap().iter().map(|v| v.as_f64().unwrap()).collect();
        assert_eq!(&got, wi, "drained answer must stay bit-exact");
    }
    assert!(drained, "drain must complete within the grace budget");
    assert_eq!(server.connections(), 0, "no handler left after the drain");
}
