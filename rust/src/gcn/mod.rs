//! Deprecated home of the GCN workload — the implementation moved to
//! [`crate::algo::gcn`], where the multi-layer forward pass runs over any
//! [`crate::engine::Servable`] through the [`crate::algo::MvmEngine`]
//! adapters and is served end-to-end as the `{"gcn":{...}}` request kind.
//!
//! This module keeps the old paths alive for one deprecation cycle:
//! [`GcnLayer`], [`normalized_adjacency`], and [`max_abs_diff`] re-export
//! the moved items, and [`forward_crossbar`] preserves the original
//! pre-engine demonstration path — a raw [`crate::crossbar::CrossbarArray`]
//! with the switch circuit applying P / Pᵀ around the array (Eqs. 4–6).
//! New code should use [`crate::algo::gcn::gcn_forward`] over a mapped
//! plan; `examples/gcn_inference.rs` shows the replacement end to end.

use crate::crossbar::switch::SwitchCircuit;
use crate::crossbar::CrossbarArray;
use crate::graph::Csr;
use anyhow::{ensure, Result};

/// Moved to [`crate::algo::gcn::GcnLayer`].
#[deprecated(note = "moved to crate::algo::gcn::GcnLayer")]
pub type GcnLayer = crate::algo::gcn::GcnLayer;

/// Moved to [`crate::algo::gcn::normalized_adjacency`].
#[deprecated(note = "moved to crate::algo::gcn::normalized_adjacency")]
pub fn normalized_adjacency(a: &Csr) -> Csr {
    crate::algo::gcn::normalized_adjacency(a)
}

/// Moved to [`crate::algo::gcn::max_abs_diff`].
#[deprecated(note = "moved to crate::algo::gcn::max_abs_diff")]
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    crate::algo::gcn::max_abs_diff(a, b)
}

/// One layer through a raw placed array: σ(Pᵀ(A'(P(Z W)))) per feature
/// column, where `arr` holds A' = P A_norm Pᵀ and `sw` is the switch
/// circuit for P. This was `GcnLayer::forward_crossbar` before the move;
/// the engine path ([`crate::algo::gcn::gcn_forward`] over a compiled
/// plan) supersedes it — one multi-RHS batch per layer instead of one
/// array pass per feature column.
#[deprecated(note = "use crate::algo::gcn::gcn_forward over a mapped plan")]
pub fn forward_crossbar(
    layer: &crate::algo::gcn::GcnLayer,
    arr: &CrossbarArray,
    sw: &SwitchCircuit,
    z: &[f64],
) -> Result<Vec<f64>> {
    let n = arr.dim;
    ensure!(sw.len() == n, "switch/array size mismatch");
    ensure!(z.len() == n * layer.in_dim, "feature matrix shape mismatch");
    // Z W on the host (weights are dense), one switched array pass per
    // output column
    let mut zw = vec![0.0; n * layer.out_dim];
    for r in 0..n {
        for i in 0..layer.in_dim {
            let zv = z[r * layer.in_dim + i];
            if zv == 0.0 {
                continue;
            }
            let wrow = &layer.w[i * layer.out_dim..(i + 1) * layer.out_dim];
            for (o, wv) in zw[r * layer.out_dim..(r + 1) * layer.out_dim]
                .iter_mut()
                .zip(wrow)
            {
                *o += zv * wv;
            }
        }
    }
    let mut out = vec![0.0; n * layer.out_dim];
    let mut col = vec![0.0; n];
    for o in 0..layer.out_dim {
        for r in 0..n {
            col[r] = zw[r * layer.out_dim + o];
        }
        let xp = sw.forward(&col); // x' = P x   (Eq. 4)
        let yp = arr.mvm(&xp); //      y' = A' x' (crossbar pass)
        let y = sw.inverse(&yp); //    y = Pᵀ y'  (Eq. 6)
        for r in 0..n {
            out[r * layer.out_dim + o] = y[r];
        }
    }
    if layer.relu {
        for v in out.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::algo::gcn::GcnLayer;
    use crate::crossbar::place;
    use crate::graph::{synth, GridSummary};
    use crate::reorder::{reorder, Reordering};
    use crate::scheme::Scheme;
    use crate::util::rng::Pcg64;

    #[test]
    fn crossbar_path_matches_dense_on_complete_coverage() {
        let a = synth::qm7_like(5828);
        let nrm = crate::algo::gcn::normalized_adjacency(&a);
        let r = reorder(&nrm, Reordering::CuthillMckee);
        let g = GridSummary::new(&r.matrix, 2);
        let scheme = Scheme { diag_len: vec![g.n], fill_len: vec![] };
        let arr = place(&r.matrix, &g, &scheme).unwrap();
        let sw = SwitchCircuit::new(r.perm.clone());
        let layer = GcnLayer::random(6, 4, true, 1);
        let mut rng = Pcg64::seed_from_u64(2);
        let z: Vec<f64> = (0..22 * 6).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let dense = layer.forward_dense(&nrm, &z);
        let xbar = forward_crossbar(&layer, &arr, &sw, &z).unwrap();
        let diff = crate::algo::gcn::max_abs_diff(&dense, &xbar);
        assert!(diff < 1e-6, "dense vs crossbar diff {diff}");
    }

    #[test]
    fn deprecated_reexports_answer_like_the_moved_items() {
        let a = synth::qm7_like(5828);
        assert_eq!(
            normalized_adjacency(&a).to_dense(),
            crate::algo::gcn::normalized_adjacency(&a).to_dense()
        );
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[0.5, 4.0]), 2.0);
    }
}
