"""L1 Pallas kernel: blocked crossbar matrix-vector multiply.

The digital twin of the paper's analog compute path (Fig. 5): each mapped
block is a small crossbar tile; a tile computes ``y_tile = A_tile @ x_tile``
(Ohm's law multiply + Kirchhoff current sum), and tiles in the same block
row accumulate into the same output segment ("blocks in the same row are
connected").

Layout:
  tiles:    [NB, K, K]  tile conductance matrices (zero-padded at edges)
  x_tiles:  [NB, K]     per-tile input sub-vector (x' sliced by block cols)
  row_onehot: [NB, NR]  tile -> output-row-segment assignment (one-hot);
                        scatter expressed as a matmul so the whole
                        accumulation runs on the MXU instead of serial
                        scatter-adds.

  out:      [NR, K]     accumulated output segments.

Grid: one Pallas program per tile (grid=(NB,)); each step loads one K×K
tile into VMEM (K ≤ 128 ⇒ 64 KiB), computes the K-vector product, and
accumulates ``outer(row_onehot[nb], y_tile)`` into the [NR, K] accumulator,
which stays VMEM-resident across the whole grid (index_map returns the same
block for every step).

``interpret=True`` as everywhere (CPU PJRT cannot run Mosaic custom-calls).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _block_mvm_kernel(tiles_ref, x_ref, onehot_ref, out_ref):
    nb = pl.program_id(0)

    @pl.when(nb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # y_tile[k] = sum_j tiles[nb, k, j] * x[nb, j]  -- one crossbar pass
    y_tile = jnp.dot(
        tiles_ref[0], x_ref[0][:, None], preferred_element_type=jnp.float32
    )[:, 0]
    # scatter-by-matmul: out[r, :] += onehot[nb, r] * y_tile
    out_ref[...] += onehot_ref[0][:, None] * y_tile[None, :]


def block_mvm(tiles, x_tiles, row_onehot):
    """Crossbar-blocked MVM.

    Args:
      tiles:      [NB, K, K] float32.
      x_tiles:    [NB, K]    float32.
      row_onehot: [NB, NR]   float32 one-hot row assignment.

    Returns:
      [NR, K] accumulated row segments.
    """
    nb, k, _ = tiles.shape
    nr = row_onehot.shape[1]
    return pl.pallas_call(
        _block_mvm_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, k, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, nr), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((nr, k), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((nr, k), jnp.float32),
        interpret=True,
    )(
        tiles.astype(jnp.float32),
        x_tiles.astype(jnp.float32),
        row_onehot.astype(jnp.float32),
    )
