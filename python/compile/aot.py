"""AOT lowering: JAX → HLO **text** → artifacts/ for the Rust runtime.

Interchange is HLO text, NOT a serialized HloModuleProto: jax ≥ 0.5 emits
protos with 64-bit instruction ids which the xla crate's XLA (xla_extension
0.5.1) rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids, so
text round-trips cleanly. See /opt/xla-example/README.md.

Per controller configuration this emits three executables:
  rollout_<name>.hlo.txt  (params.., key u32[2]) -> (d, f, logp, entropy)
  greedy_<name>.hlo.txt   (params..)             -> (d, f, logp, entropy)
  train_<name>.hlo.txt    (params.., m.., v.., t, d, f, adv, lr, ent)
                           -> (params'.., m'.., v'.., t', loss, mean_logp)
plus one blocked-MVM executable per crossbar geometry, and a
`manifest.json` describing every artifact's ABI for the Rust loader.

Usage: python -m compile.aot --out-dir ../artifacts [--only name]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels.block_mvm import block_mvm

# ---------------------------------------------------------------------------
# experiment configurations (single source of truth; the Rust coordinator
# reads these back from manifest.json)

CONTROLLER_CONFIGS = [
    # QM7-5828 (22x22), grid 2 -> N = 11 grid cells, T = 10 (Table II)
    model.ControllerConfig("qm7_diag", n=11, hidden=10, fill_classes=0, batch=8),
    model.ControllerConfig("qm7_fill", n=11, hidden=10, fill_classes=2, batch=8),
    model.ControllerConfig(
        "qm7_fill_bilstm", n=11, hidden=10, fill_classes=2, batch=8, bilstm=True
    ),
    model.ControllerConfig("qm7_dyn4", n=11, hidden=10, fill_classes=4, batch=8),
    model.ControllerConfig("qm7_dyn6", n=11, hidden=10, fill_classes=6, batch=8),
    # batched-throughput variant (perf ablation, EXPERIMENTS.md §Perf):
    # 4x the episodes per PJRT call at the same per-epoch overhead
    model.ControllerConfig("qm7_dyn4_b32", n=11, hidden=10, fill_classes=4, batch=32),
    # qh882 (882x882), grid 32 -> N = 28, T = 27 (Table IV)
    model.ControllerConfig("qh882_dyn4", n=28, hidden=10, fill_classes=4, batch=8),
    model.ControllerConfig("qh882_dyn6", n=28, hidden=10, fill_classes=6, batch=8),
    # qh1484 (1484x1484), grid 32 -> N = 47, T = 46 (Table IV)
    model.ControllerConfig("qh1484_dyn4", n=47, hidden=10, fill_classes=4, batch=8),
    model.ControllerConfig("qh1484_dyn6", n=47, hidden=10, fill_classes=6, batch=8),
]

# blocked-MVM geometries: (name, tile side K, max tiles NB, row segments NR)
MVM_CONFIGS = [
    ("mvm_qm7", 2, 128, 11),       # 22x22, grid/tile 2
    ("mvm_qh882", 32, 256, 28),    # 882x882, tile 32
    ("mvm_qh1484", 32, 512, 47),   # 1484x1484, tile 32
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def u32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.uint32)


def lower_controller(cfg: model.ControllerConfig, out_dir: str) -> dict:
    """Lower rollout/greedy/train for one config; return manifest entry."""
    spec = model.param_spec(cfg)
    pshapes = [f32(shape) for _, shape in spec]
    B, T = cfg.batch, cfg.steps

    rollout = jax.jit(model.rollout_flat(cfg))
    rollout_hlo = to_hlo_text(rollout.lower(*pshapes, u32((2,))))

    greedy = jax.jit(model.greedy_flat(cfg))
    greedy_hlo = to_hlo_text(greedy.lower(*pshapes))

    train = jax.jit(model.train_flat(cfg))
    train_hlo = to_hlo_text(
        train.lower(
            *pshapes,          # params
            *pshapes,          # adam m
            *pshapes,          # adam v
            i32(()),           # adam t
            i32((B, T)),       # d_actions
            i32((B, T)),       # f_actions
            f32((B,)),         # advantage
            f32(()),           # lr
            f32(()),           # ent_coef
        )
    )

    files = {}
    for kind, text in [
        ("rollout", rollout_hlo),
        ("greedy", greedy_hlo),
        ("train", train_hlo),
    ]:
        fname = f"{kind}_{cfg.name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        files[kind] = fname

    return {
        "n": cfg.n,
        "hidden": cfg.hidden,
        "fill_classes": cfg.fill_classes,
        "batch": cfg.batch,
        "bilstm": cfg.bilstm,
        "steps": T,
        "params": [{"name": name, "shape": list(shape)} for name, shape in spec],
        "artifacts": files,
    }


def lower_mvm(name: str, k: int, nb: int, nr: int, out_dir: str) -> dict:
    fn = jax.jit(lambda tiles, x, onehot: (block_mvm(tiles, x, onehot),))
    hlo = to_hlo_text(fn.lower(f32((nb, k, k)), f32((nb, k)), f32((nb, nr))))
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(hlo)
    return {"k": k, "nb": nb, "nr": nr, "artifact": fname}


def source_fingerprint() -> str:
    """Hash of the compile-path sources, recorded in the manifest so `make
    artifacts` can skip when nothing changed."""
    h = hashlib.sha256()
    base = os.path.dirname(os.path.abspath(__file__))
    for root, _, names in sorted(os.walk(base)):
        for n in sorted(names):
            if n.endswith(".py"):
                with open(os.path.join(root, n), "rb") as f:
                    h.update(f.read())
    return h.hexdigest()[:16]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="lower a single config by name")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"fingerprint": source_fingerprint(), "configs": {}, "mvm": {}}
    for cfg in CONTROLLER_CONFIGS:
        if args.only and cfg.name != args.only:
            continue
        print(f"lowering controller {cfg.name} (T={cfg.steps}, B={cfg.batch}, "
              f"F={cfg.fill_classes}, bilstm={cfg.bilstm})", flush=True)
        manifest["configs"][cfg.name] = lower_controller(cfg, args.out_dir)
    for name, k, nb, nr in MVM_CONFIGS:
        if args.only and name != args.only:
            continue
        print(f"lowering {name} (K={k}, NB={nb}, NR={nr})", flush=True)
        manifest["mvm"][name] = lower_mvm(name, k, nb, nr, args.out_dir)

    path = os.path.join(args.out_dir, "manifest.json")
    # merge with an existing manifest when --only is used
    if args.only and os.path.exists(path):
        with open(path) as f:
            old = json.load(f)
        old["configs"].update(manifest["configs"])
        old["mvm"].update(manifest["mvm"])
        old["fingerprint"] = manifest["fingerprint"]
        manifest = old
    with open(path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {path}")


if __name__ == "__main__":
    sys.exit(main())
