//! The `serve-net --bench` driver: an end-to-end, self-checking load run
//! against a real socket.
//!
//! It loads the given bundles into a [`DeploymentRegistry`], starts an
//! in-process [`NetServer`], and drives N concurrent client threads over
//! real TCP connections — each client round-robins the tenants and checks
//! **every** response bit-exactly against `Deployment::mvm` on the same
//! deployment the registry serves. With `--bench-swap`, client 0 issues an
//! admin reload halfway through its stream; responses for the swapped
//! tenant must then match the old *or* the new oracle (a re-mapped bundle
//! of the same matrix is a different summation order, so the two
//! generations are distinct bit patterns), and a post-swap probe on a
//! fresh connection must match the new oracle exactly. Any dropped
//! connection, error response, or mismatched float fails the run — this
//! is the CI `net-smoke` gate as well as the perf ledger
//! (`BENCH_serve_net.json`: per-tenant rps/nnz_per_s under concurrency).

use super::registry::{DeploymentRegistry, RegistryOptions, TenantEntry};
use super::server::{NetOptions, NetServer};
use crate::api::{Deployment, Error, Result};
use crate::util::bench::write_bench_json;
use crate::util::json::{num_arr, obj, Json};
use crate::util::rng::Pcg64;
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

/// Configuration for one bench run.
#[derive(Clone, Debug)]
pub struct NetBenchOptions {
    /// (deployment id, bundle path) pairs to register
    pub bundles: Vec<(String, PathBuf)>,
    /// listen address; `127.0.0.1:0` picks a free port
    pub listen: String,
    /// shared-pool worker threads
    pub workers: usize,
    /// per-tenant queue depth (keep >= clients so admission never rejects
    /// the bench's own well-behaved traffic)
    pub queue_depth: usize,
    /// band-sharded execution
    pub sharded: bool,
    /// concurrent client connections
    pub clients: usize,
    /// requests per client
    pub requests: usize,
    /// mid-stream hot-swap: (tenant id, replacement bundle)
    pub swap: Option<(String, PathBuf)>,
    /// request-vector rng seed
    pub seed: u64,
    /// where to write the machine-readable ledger
    pub bench_json: PathBuf,
}

impl Default for NetBenchOptions {
    fn default() -> NetBenchOptions {
        NetBenchOptions {
            bundles: Vec::new(),
            listen: "127.0.0.1:0".into(),
            workers: 4,
            queue_depth: 32,
            sharded: true,
            clients: 2,
            requests: 200,
            swap: None,
            seed: 0x5eed,
            bench_json: PathBuf::from("BENCH_serve_net.json"),
        }
    }
}

/// What a finished bench run measured. A report is only returned when
/// every response was bit-identical to its oracle — mismatches are an
/// `Err`, not a statistic.
#[derive(Clone, Debug)]
pub struct NetBenchReport {
    pub served: u64,
    pub tenants: usize,
    pub wall_s: f64,
    pub rps: f64,
    pub swapped: bool,
}

struct Conn {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Conn {
    fn connect(addr: SocketAddr) -> std::result::Result<Conn, String> {
        let s = TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
        let r = s.try_clone().map_err(|e| format!("clone stream: {e}"))?;
        Ok(Conn {
            reader: BufReader::new(r),
            writer: BufWriter::new(s),
        })
    }

    fn roundtrip(&mut self, line: &str) -> std::result::Result<Json, String> {
        writeln!(self.writer, "{line}").map_err(|e| format!("send: {e}"))?;
        self.writer.flush().map_err(|e| format!("flush: {e}"))?;
        let mut buf = String::new();
        let n = self.reader.read_line(&mut buf).map_err(|e| format!("recv: {e}"))?;
        if n == 0 {
            return Err("connection closed mid-request (dropped response)".into());
        }
        Json::parse(buf.trim()).map_err(|e| format!("bad response JSON: {e}"))
    }
}

fn parse_y(resp: &Json) -> std::result::Result<Vec<f64>, String> {
    if resp.get("error") != &Json::Null {
        return Err(format!("error response: {}", resp.get("error").to_string()));
    }
    resp.get("y")
        .as_arr()
        .ok_or_else(|| format!("response carries no \"y\": {}", resp.to_string()))?
        .iter()
        .map(|v| v.as_f64().ok_or_else(|| "non-numeric element in y".to_string()))
        .collect()
}

/// Run the bench (see module docs). Returns the aggregate report and
/// writes `BENCH_serve_net.json`; any correctness violation is an error.
pub fn run_net_bench(opts: &NetBenchOptions) -> Result<NetBenchReport> {
    if opts.bundles.is_empty() {
        return Err(Error::Validate("bench needs at least one --bundles id=path".into()));
    }
    let registry = Arc::new(DeploymentRegistry::new(&RegistryOptions {
        workers: opts.workers,
        queue_depth: opts.queue_depth.max(opts.clients.max(1)),
        sharded: opts.sharded,
        fault: None,
        remap_after: 0,
    }));
    let mut oracles: BTreeMap<String, Arc<TenantEntry>> = BTreeMap::new();
    for (id, path) in &opts.bundles {
        registry.load_bundle(id, path)?;
        oracles.insert(id.clone(), registry.get(id)?.entry());
    }
    // the swap target's oracle: the same bundle the admin reload will
    // load, loaded here once (bundle loads are deterministic, so the two
    // loads serve bit-identically)
    let swap_oracle: Option<(String, Arc<Deployment>)> = match &opts.swap {
        Some((id, path)) => {
            if !oracles.contains_key(id) {
                return Err(Error::Validate(format!(
                    "--bench-swap tenant {id:?} is not among the --bundles ids"
                )));
            }
            Some((id.clone(), Arc::new(Deployment::load(path)?)))
        }
        None => None,
    };

    let server = NetServer::start(registry.clone(), &opts.listen, &NetOptions::default())?;
    let addr = server.addr();
    let ids: Vec<String> = opts.bundles.iter().map(|b| b.0.clone()).collect();
    let clients = opts.clients.max(1);
    let requests = opts.requests.max(1);
    let oracles = Arc::new(oracles);
    let swap_oracle = Arc::new(swap_oracle);
    let swap_req: Option<String> = opts.swap.as_ref().map(|(id, path)| {
        obj(vec![(
            "admin",
            obj(vec![(
                "reload",
                obj(vec![
                    ("id", Json::Str(id.clone())),
                    ("bundle", Json::Str(path.display().to_string())),
                ]),
            )]),
        )])
        .to_string()
    });

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let ids = ids.clone();
        let oracles = oracles.clone();
        let swap_oracle = swap_oracle.clone();
        let swap_req = swap_req.clone();
        let seed = opts.seed;
        let handle = std::thread::spawn(move || -> std::result::Result<u64, String> {
            let mut conn = Conn::connect(addr)?;
            let mut rng = Pcg64::new(seed, c as u64);
            let mut served = 0u64;
            for r in 0..requests {
                let tenant = &ids[(r + c) % ids.len()];
                let entry = &oracles[tenant];
                let x: Vec<f64> =
                    (0..entry.dim()).map(|_| rng.uniform(-2.0, 2.0)).collect();
                let want_old = entry
                    .deployment()
                    .mvm(&x)
                    .map_err(|e| format!("oracle mvm: {e}"))?;
                let want_new = match swap_oracle.as_ref() {
                    Some((sid, dep)) if sid == tenant => {
                        Some(dep.mvm(&x).map_err(|e| format!("swap oracle mvm: {e}"))?)
                    }
                    _ => None,
                };
                let req = obj(vec![
                    ("tenant", Json::Str(tenant.clone())),
                    ("id", Json::Num(r as f64)),
                    ("x", num_arr(x)),
                ]);
                let resp = conn.roundtrip(&req.to_string())?;
                let got = parse_y(&resp).map_err(|e| format!("client {c} req {r}: {e}"))?;
                let ok = got == want_old || want_new.as_deref() == Some(&got[..]);
                if !ok {
                    return Err(format!(
                        "client {c} req {r} tenant {tenant}: response does not bit-match \
                         either generation's Deployment::mvm"
                    ));
                }
                served += 1;
                // client 0 hot-swaps mid-stream
                if c == 0 && r + 1 == (requests / 2).max(1) {
                    if let Some(line) = &swap_req {
                        let ack = conn.roundtrip(line)?;
                        if ack.get("admin").as_str() != Some("reload") {
                            return Err(format!("reload rejected: {}", ack.to_string()));
                        }
                        if ack.get("generation").as_i64().unwrap_or(0) < 2 {
                            return Err("reload did not bump the generation".into());
                        }
                    }
                }
            }
            Ok(served)
        });
        handles.push(handle);
    }
    let mut served_total = 0u64;
    let mut failures: Vec<String> = Vec::new();
    for h in handles {
        match h.join() {
            Ok(Ok(n)) => served_total += n,
            Ok(Err(e)) => failures.push(e),
            Err(_) => failures.push("client thread panicked".into()),
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    if !failures.is_empty() {
        return Err(Error::Validate(format!(
            "{} of {clients} clients failed; first: {}",
            failures.len(),
            failures[0]
        )));
    }

    // post-swap probe: a *new* request must be served by the new
    // generation, bit-identical to the reloaded bundle's own mvm
    let mut probe = Conn::connect(addr).map_err(Error::Validate)?;
    if let Some((sid, new_dep)) = swap_oracle.as_ref() {
        let mut rng = Pcg64::new(opts.seed ^ 0x9e37_79b9_7f4a_7c15, 999);
        let x: Vec<f64> =
            (0..oracles[sid].dim()).map(|_| rng.uniform(-2.0, 2.0)).collect();
        let req = obj(vec![
            ("tenant", Json::Str(sid.clone())),
            ("id", Json::Str("post-swap-probe".into())),
            ("x", num_arr(x.clone())),
        ]);
        let resp = probe.roundtrip(&req.to_string()).map_err(Error::Validate)?;
        let got = parse_y(&resp).map_err(Error::Validate)?;
        let want = new_dep.mvm(&x)?;
        if got != want {
            return Err(Error::Validate(
                "post-swap probe did not match the new generation's Deployment::mvm".into(),
            ));
        }
        served_total += 1;
    }
    let stats = probe
        .roundtrip(r#"{"admin":"stats"}"#)
        .map_err(Error::Validate)?
        .get("stats")
        .clone();
    drop(probe);

    let report = NetBenchReport {
        served: served_total,
        tenants: ids.len(),
        wall_s,
        rps: served_total as f64 / wall_s.max(1e-9),
        swapped: opts.swap.is_some(),
    };
    write_bench_json(
        &opts.bench_json,
        vec![
            ("bench", Json::Str("serve_net".into())),
            ("clients", Json::Num(clients as f64)),
            ("requests_per_client", Json::Num(requests as f64)),
            ("tenants", Json::Num(ids.len() as f64)),
            ("workers", Json::Num(registry.workers() as f64)),
            ("queue_depth", Json::Num(opts.queue_depth as f64)),
            ("sharded", Json::Bool(opts.sharded)),
            ("hot_swap", Json::Bool(report.swapped)),
            ("served", Json::Num(report.served as f64)),
            ("wall_s", Json::Num(report.wall_s)),
            ("total_rps", Json::Num(report.rps)),
            ("tenant_stats", stats),
        ],
    )?;
    Ok(report)
}
