//! Integration tests for the `api` facade: the unified `Servable` trait
//! served by the one generic executor (bit-identical to the scalar oracle
//! for both plan shapes), deployment bundles that round-trip save → load →
//! serve without moving an ulp, the NDJSON serve loop with typed
//! machine-readable errors, and the typed error surface of bundle loading.

use autogmap::api::{
    serve_loop, DeployedPlan, Deployment, DeploymentBuilder, Error, ServeOptions, Source, Strategy,
};
use autogmap::engine::{self, BatchExecutor, Servable};
use autogmap::graph::{synth, GridSummary};
use autogmap::mapper;
use autogmap::reorder::{reorder, Reordering};
use autogmap::scheme::{parse_actions, CompositeScheme, FillRule, Scheme, WindowSlice};
use autogmap::util::json::{num_arr, obj, Json};
use autogmap::util::propcheck::check;
use std::io::Cursor;
use std::path::PathBuf;
use std::sync::Arc;

fn temp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// The tentpole property: one generic executor serves BOTH `Servable`
/// implementations — flat `ExecPlan`s and mapper `CompositePlan`s, here
/// behind the same `DeployedPlan` enum a deployment holds — bit-identically
/// to the scalar seed oracle (`Servable::mvm`) across schemes, batch
/// sizes, both executor modes, and 1/2/8 workers.
#[test]
fn generic_executor_serves_both_plan_shapes_bit_identically_property() {
    check("api_generic_executor_bit_identical", 6, |rng| {
        let dim = 40 + rng.below(50) as usize;
        let m = synth::banded_like(dim, 0.9, 1 + rng.below(5));
        let r = reorder(&m, Reordering::CuthillMckee);
        let grid = 3 + rng.below(3) as usize;
        let g = GridSummary::new(&r.matrix, grid);
        let n = g.n;
        if n < 4 {
            return Ok(());
        }

        // flat shape: a random diagonal+fill scheme compiled directly
        let d: Vec<u8> = (0..n - 1).map(|_| rng.below(2) as u8).collect();
        let f: Vec<usize> = (0..n - 1).map(|_| rng.below(3) as usize).collect();
        let scheme = parse_actions(n, &d, &f, FillRule::Dynamic { grades: 3 });
        let flat = DeployedPlan::Flat(
            engine::compile(&r.matrix, &g, &scheme).map_err(|e| format!("{e:#}"))?,
        );

        // composite shape: two overlapping full-block windows with a cut
        let cut = 1 + rng.below(n as u64 - 1) as usize;
        let ov = rng.below(3) as usize;
        let comp = CompositeScheme {
            n,
            slices: vec![
                WindowSlice {
                    win_start: 0,
                    win_end: (cut + ov).min(n),
                    start: 0,
                    end: cut,
                    scheme: Scheme {
                        diag_len: vec![(cut + ov).min(n)],
                        fill_len: vec![],
                    },
                    cache_hit: false,
                },
                WindowSlice {
                    win_start: cut.saturating_sub(ov),
                    win_end: n,
                    start: cut,
                    end: n,
                    scheme: Scheme {
                        diag_len: vec![n - cut.saturating_sub(ov)],
                        fill_len: vec![],
                    },
                    cache_hit: false,
                },
            ],
        };
        let composite = DeployedPlan::Composite(
            mapper::compile_composite(&r.matrix, &g, &comp).map_err(|e| format!("{e:#}"))?,
        );

        let bsz = 1 + rng.below(7) as usize;
        let xs: Vec<Vec<f64>> = (0..bsz)
            .map(|_| (0..dim).map(|_| rng.uniform(-2.0, 2.0)).collect())
            .collect();
        for (label, plan) in [("flat", flat), ("composite", composite)] {
            // the seed scalar oracle: per-request Servable::mvm
            let want: Vec<Vec<f64>> = xs.iter().map(|x| plan.mvm(x)).collect();
            if plan.nnz() < plan.stats().mapped_nnz {
                return Err(format!("{label}: nnz accounting shrank below mapped"));
            }
            let plan = Arc::new(plan);
            for workers in [1usize, 2, 8] {
                let exec = BatchExecutor::new(plan.clone(), workers);
                if exec.execute_batch(xs.clone()) != want {
                    return Err(format!("{label}: scalar mode diverged at {workers} workers"));
                }
                if exec.execute_batch_sharded(xs.clone()) != want {
                    return Err(format!("{label}: sharded mode diverged at {workers} workers"));
                }
            }
        }
        Ok(())
    });
}

/// Bundle round-trip property: a saved deployment reloads with identical
/// program stats, provenance, and fleet loads, serves bit-identically in
/// original node ids, and the embedded plan artifact is the version-2
/// arena format.
#[test]
fn bundle_roundtrip_matches_fresh_deployment_property() {
    let dir = temp_dir("autogmap_api_bundle_roundtrip");
    check("api_bundle_roundtrip", 4, |rng| {
        let nodes = 400 + rng.below(400) as usize;
        let degree = 3 + rng.below(3) as usize;
        let seed = rng.next_u64();
        let block = 1 + rng.below(3) as usize;
        let dep = DeploymentBuilder::new(
            Source::Rmat { nodes, degree, seed },
            Strategy::FixedBlock { block },
        )
        .grid(8)
        .seed(seed)
        .banks(1 + rng.below(4) as usize)
        .workers(2)
        .build()
        .map_err(|e| e.to_string())?;

        let path = dir.join(format!("bundle_{nodes}_{block}.json"));
        dep.save(&path).map_err(|e| e.to_string())?;
        let text = std::fs::read_to_string(&path).map_err(|e| e.to_string())?;
        let doc = Json::parse(&text).map_err(|e| e.to_string())?;
        if doc.get("plan").get("version").as_usize() != Some(2) {
            return Err("bundle must embed the v2 plan arena artifact".into());
        }

        let back = Deployment::load(&path).map_err(|e| e.to_string())?;
        if back.stats() != dep.stats() {
            return Err(format!("stats drifted: {:?} vs {:?}", back.stats(), dep.stats()));
        }
        if back.provenance != dep.provenance {
            return Err("provenance drifted".into());
        }
        if back.fleet.loads != dep.fleet.loads || back.fleet.banks != dep.fleet.banks {
            return Err("fleet assignment drifted".into());
        }

        // bit-identical serving in original node ids (integer inputs make
        // every accumulation exact), against the source matrix itself
        let m = synth::rmat_like(nodes, 2 * (nodes * degree / 2), seed);
        let x: Vec<f64> = (0..nodes).map(|i| ((i * 7) % 11) as f64 - 5.0).collect();
        let fresh_y = dep.mvm(&x).map_err(|e| e.to_string())?;
        if fresh_y != m.spmv(&x) {
            return Err("fresh deployment is not exact vs the source matrix".into());
        }
        if back.mvm(&x).map_err(|e| e.to_string())? != fresh_y {
            return Err("reloaded bundle answered differently".into());
        }
        // executor path over the loaded bundle, both modes
        let xs: Vec<Vec<f64>> = (0..3)
            .map(|s| (0..nodes).map(|i| ((i + s * 3) % 9) as f64 - 4.0).collect())
            .collect();
        let want: Vec<Vec<f64>> = xs
            .iter()
            .map(|x| dep.mvm(x).map_err(|e| e.to_string()))
            .collect::<Result<_, _>>()?;
        let exec = back.executor(3);
        let perm_in: Vec<Vec<f64>> = xs.iter().map(|x| back.permute_in(x)).collect();
        let ys = exec.execute_batch_sharded(perm_in.clone());
        let got: Vec<Vec<f64>> = ys.iter().map(|y| back.permute_out(y)).collect();
        if got != want {
            return Err("loaded executor (sharded) diverged from the fresh deployment".into());
        }
        exec.recycle(ys);
        let ys = exec.execute_batch(perm_in);
        let got: Vec<Vec<f64>> = ys.iter().map(|y| back.permute_out(y)).collect();
        if got != want {
            return Err("loaded executor (scalar) diverged from the fresh deployment".into());
        }
        Ok(())
    });
}

/// Both bundle kinds round-trip: a hierarchical (composite) deployment at
/// beyond-window scale and a direct-controller (flat) deployment, each
/// reloaded in-process and compared answer-for-answer and stat-for-stat.
#[test]
fn hierarchical_and_direct_bundles_reload_and_serve_identically() {
    let dir = temp_dir("autogmap_api_bundle_kinds");

    // hierarchical: 1500 nodes, qm7_dyn4 windows over a 188-cell grid
    let dep = DeploymentBuilder::new(
        Source::Rmat { nodes: 1500, degree: 4, seed: 11 },
        Strategy::Hierarchical { controller: "qm7_dyn4".into(), overlap: 2 },
    )
    .grid(8)
    .seed(11)
    .rounds(1)
    .workers(2)
    .banks(4)
    .build()
    .unwrap();
    assert_eq!(dep.plan().kind(), "composite");
    let m = synth::rmat_like(1500, 2 * (1500 * 4 / 2), 11);
    assert_eq!(dep.stats().total_nnz(), m.nnz() as u64, "exactness needs every nnz served");
    let path = dir.join("hier.json");
    dep.save(&path).unwrap();
    let back = Deployment::load(&path).unwrap();
    assert_eq!(back.stats(), dep.stats());
    let x: Vec<f64> = (0..1500).map(|i| ((i * 13) % 17) as f64 - 8.0).collect();
    let y = dep.mvm(&x).unwrap();
    assert_eq!(y, m.spmv(&x), "hierarchical deployment must be exact");
    assert_eq!(back.mvm(&x).unwrap(), y, "reloaded bundle must answer bit-identically");

    // direct: the paper-scale path produces a flat bundle
    let dep = DeploymentBuilder::new(
        Source::Matrix { label: "qm7".into(), matrix: synth::qm7_like(5828) },
        Strategy::Direct { controller: "qm7_dyn4".into() },
    )
    .grid(2)
    .rounds(1)
    .banks(2)
    .workers(2)
    .build()
    .unwrap();
    assert_eq!(dep.plan().kind(), "flat");
    let path = dir.join("direct.json");
    dep.save(&path).unwrap();
    let back = Deployment::load(&path).unwrap();
    assert_eq!(back.stats(), dep.stats());
    assert_eq!(back.plan().kind(), "flat");
    let m = synth::qm7_like(5828);
    let x: Vec<f64> = (0..22).map(|i| ((i * 3) % 7) as f64 - 3.0).collect();
    assert_eq!(dep.mvm(&x).unwrap(), m.spmv(&x));
    assert_eq!(back.mvm(&x).unwrap(), dep.mvm(&x).unwrap());
}

/// The serve loop: NDJSON in, NDJSON out — singles coalesced into batch
/// windows, explicit batches, flush commands, typed error responses that
/// never kill the loop, and a final stats line with nonzero throughput.
#[test]
fn serve_loop_speaks_ndjson_with_typed_errors() {
    let dep = DeploymentBuilder::new(
        Source::Matrix { label: "qm7".into(), matrix: synth::qm7_like(5828) },
        Strategy::FixedBlock { block: 2 },
    )
    .grid(2)
    .workers(2)
    .build()
    .unwrap();
    let dim = 22usize;
    let xv = |s: usize| -> Vec<f64> { (0..dim).map(|i| ((i + s) % 5) as f64 - 2.0).collect() };
    let line = |id: i64, x: &[f64]| {
        obj(vec![
            ("id", Json::Num(id as f64)),
            ("x", num_arr(x.iter().copied())),
        ])
        .to_string()
    };

    let mut input = String::new();
    input.push_str(&line(1, &xv(1)));
    input.push('\n');
    input.push_str(&line(2, &xv(2)));
    input.push('\n'); // window of 2 -> ids 1,2 flush here
    input.push_str(&line(3, &xv(3)));
    input.push('\n');
    input.push_str("this is not json\n");
    input.push_str(&line(4, &xv(4)[..5])); // wrong length -> validate error
    input.push('\n');
    // explicit batch (flushes pending id 3 first)
    let batch = obj(vec![
        ("id", Json::Num(5.0)),
        (
            "xs",
            Json::Arr(vec![num_arr(xv(5)), num_arr(xv(6))]),
        ),
    ]);
    input.push_str(&batch.to_string());
    input.push('\n');
    input.push_str(&line(6, &xv(7)));
    input.push('\n');
    input.push_str("{\"flush\":true}\n");

    let opts = ServeOptions {
        workers: 2,
        batch_window: 2,
        stats_every: 0,
        sharded: true,
        ..ServeOptions::default()
    };
    let mut out: Vec<u8> = Vec::new();
    let report = serve_loop(&dep, &opts, Cursor::new(input), &mut out).unwrap();
    assert_eq!(report.served, 6, "4 singles + 2 batched");
    assert_eq!(report.errors, 2);
    assert_eq!(report.batches, 4, "window, pending-before-batch, batch, flush");
    assert!(report.rps > 0.0);
    assert!(report.nnz_per_s > 0.0);

    let text = String::from_utf8(out).unwrap();
    let docs: Vec<Json> = text.lines().map(|l| Json::parse(l).unwrap()).collect();
    let parse_vec = |j: &Json| -> Vec<f64> {
        j.as_arr().unwrap().iter().map(|v| v.as_f64().unwrap()).collect()
    };
    let mut answered = 0;
    let mut error_kinds = Vec::new();
    let mut stats_lines = 0;
    for doc in &docs {
        if doc.get("stats") != &Json::Null {
            stats_lines += 1;
            let s = doc.get("stats");
            assert_eq!(s.get("served").as_usize(), Some(6));
            assert_eq!(s.get("errors").as_usize(), Some(2));
            assert!(s.get("rps").as_f64().unwrap() > 0.0);
            assert!(s.get("nnz_per_s").as_f64().unwrap() > 0.0);
            assert!(s.get("shards").as_usize().unwrap() >= 1);
        } else if doc.get("error") != &Json::Null {
            error_kinds.push(doc.get("error").get("kind").as_str().unwrap().to_string());
        } else if doc.get("ys") != &Json::Null {
            assert_eq!(doc.get("id").as_i64(), Some(5));
            let ys = doc.get("ys").as_arr().unwrap();
            assert_eq!(ys.len(), 2);
            assert_eq!(parse_vec(&ys[0]), dep.mvm(&xv(5)).unwrap());
            assert_eq!(parse_vec(&ys[1]), dep.mvm(&xv(6)).unwrap());
            answered += 2;
        } else {
            let id = doc.get("id").as_i64().unwrap();
            let want = match id {
                1 => dep.mvm(&xv(1)).unwrap(),
                2 => dep.mvm(&xv(2)).unwrap(),
                3 => dep.mvm(&xv(3)).unwrap(),
                6 => dep.mvm(&xv(7)).unwrap(),
                other => panic!("unexpected response id {other}"),
            };
            assert_eq!(parse_vec(doc.get("y")), want, "response {id} drifted");
            answered += 1;
        }
    }
    assert_eq!(answered, 6);
    assert_eq!(stats_lines, 1, "stats_every 0 -> final stats only");
    assert_eq!(error_kinds, vec!["parse".to_string(), "validate".to_string()]);
}

/// Bundle loading reports typed, matchable errors instead of strings.
#[test]
fn bundle_load_reports_typed_errors() {
    let dir = temp_dir("autogmap_api_typed_errors");

    // missing file -> Io
    match Deployment::load(&dir.join("nope.json")) {
        Err(Error::Io(_)) => {}
        other => panic!("expected Io, got {other:?}"),
    }

    // garbage bytes -> Parse
    let garbage = dir.join("garbage.json");
    std::fs::write(&garbage, "not json at all {{{").unwrap();
    match Deployment::load(&garbage) {
        Err(Error::Parse(_)) => {}
        other => panic!("expected Parse, got {other:?}"),
    }

    // future format revision -> BundleVersion with the found number
    let future = dir.join("future.json");
    std::fs::write(&future, "{\"bundle_version\": 99}").unwrap();
    match Deployment::load(&future) {
        Err(Error::BundleVersion { found: 99, supported }) => {
            assert_eq!(supported, autogmap::api::BUNDLE_VERSION)
        }
        other => panic!("expected BundleVersion, got {other:?}"),
    }

    // structurally broken bundle -> Validate (take a real bundle, corrupt
    // its kind tag)
    let dep = DeploymentBuilder::new(
        Source::Matrix { label: "qm7".into(), matrix: synth::qm7_like(5828) },
        Strategy::FixedBlock { block: 2 },
    )
    .grid(2)
    .build()
    .unwrap();
    let good = dir.join("good.json");
    dep.save(&good).unwrap();
    let text = std::fs::read_to_string(&good).unwrap();
    assert!(text.contains("\"kind\":\"composite\""));
    let bad = dir.join("bad_kind.json");
    std::fs::write(&bad, text.replace("\"kind\":\"composite\"", "\"kind\":\"weird\"")).unwrap();
    match Deployment::load(&bad) {
        Err(Error::Validate(msg)) => assert!(msg.contains("weird"), "{msg}"),
        other => panic!("expected Validate, got {other:?}"),
    }

    // and the good one still loads
    assert!(Deployment::load(&good).is_ok());
}
