//! Experiment runner: ties dataset + trainer + metrics together for one
//! full training run (Algo. 3's outer loop with logging/checkpointing),
//! and resolves which [`TrainBackend`](crate::agent::TrainBackend) a run
//! trains on (see [`build_trainer`]).

use super::config::ExperimentConfig;
use super::dataset::{prepare, Workload};
use super::metrics::{write_summary, MetricsLog};
use crate::agent::{BackendKind, BestSolution, EpochStats, TrainOptions, Trainer};
use crate::runtime::{Manifest, Runtime};
use anyhow::{Context, Result};
use std::path::PathBuf;
use std::time::Instant;

/// Result of a completed run.
pub struct RunResult {
    pub best: Option<BestSolution>,
    /// best-by-reward regardless of coverage (paper's diag-only rows)
    pub best_reward: Option<BestSolution>,
    pub last: Option<EpochStats>,
    pub history: Vec<EpochStats>,
    pub workload: Workload,
    pub run_dir: PathBuf,
    pub wall_seconds: f64,
}

/// Options controlling run output and backend selection.
#[derive(Clone, Debug)]
pub struct RunnerOptions {
    /// directory to place runs/<name>/ under
    pub out_root: PathBuf,
    /// write a checkpoint every N epochs (0 = never)
    pub checkpoint_every: usize,
    /// echo progress lines to stdout
    pub verbose: bool,
    /// keep the full in-memory history (figures); CSV is always written
    pub keep_history: bool,
    /// which training backend to use (Auto = PJRT when an artifacts
    /// manifest loads, native otherwise)
    pub backend: BackendKind,
    /// native-backend worker threads (0 = one per core, capped at 8).
    /// Training results are identical for any value.
    pub workers: usize,
}

impl Default for RunnerOptions {
    fn default() -> Self {
        RunnerOptions {
            out_root: PathBuf::from("runs"),
            checkpoint_every: 0,
            verbose: false,
            keep_history: true,
            backend: BackendKind::Auto,
            workers: 0,
        }
    }
}

/// Default native worker count: one per available core, capped at 8 (the
/// paper's batch sizes saturate well before that).
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(8)
}

/// Resolve `kind` against the (optional) runtime and build a trainer for
/// `controller`.
///
/// - `Pjrt` requires a runtime with a loadable artifacts manifest; the
///   error otherwise points at `--backend native`.
/// - `Native` looks the controller up in the artifacts manifest when one
///   is present (shapes may be customized there) and falls back to the
///   built-in paper configs ([`Manifest::builtin`]).
/// - `Auto` picks PJRT exactly when a manifest loads.
pub fn build_trainer(
    rt: Option<&Runtime>,
    controller: &str,
    topts: TrainOptions,
    kind: BackendKind,
) -> Result<Trainer> {
    let manifest = rt.and_then(|rt| match rt.manifest() {
        Ok(m) => Some(m),
        Err(e) => {
            // a *corrupt* manifest must not be silently treated as absent
            // (Auto would quietly ignore custom configs); a missing one is
            // the normal fresh-checkout state and stays quiet
            if rt.artifacts_dir().join("manifest.json").exists() {
                eprintln!(
                    "warning: artifacts manifest at {} exists but failed to load ({e:#}); \
                     treating artifacts as absent (auto backend -> native, builtin configs)",
                    rt.artifacts_dir().display()
                );
            }
            None
        }
    });
    let use_pjrt = match kind {
        BackendKind::Pjrt => true,
        BackendKind::Native => false,
        BackendKind::Auto => manifest.is_some(),
    };
    if use_pjrt {
        let rt = rt.context(
            "the pjrt backend needs an artifacts runtime — pass --artifacts DIR, \
             or rerun with `--backend native` (pure-Rust trainer, no artifacts needed)",
        )?;
        let manifest = rt.manifest().with_context(|| {
            format!(
                "no AOT manifest under {} — rerun with `--backend native` \
                 (pure-Rust trainer, no artifacts needed) or build artifacts \
                 with `make artifacts`",
                rt.artifacts_dir().display()
            )
        })?;
        let entry = manifest.config(controller)?.clone();
        Trainer::new(rt, entry, topts)
    } else {
        let entry = match manifest.as_ref().and_then(|m| m.configs.get(controller)) {
            Some(e) => e.clone(),
            None => Manifest::builtin()
                .config(controller)
                .with_context(|| {
                    format!(
                        "controller {controller:?} is neither a built-in config nor \
                         present in an artifacts manifest"
                    )
                })?
                .clone(),
        };
        Trainer::native(entry, topts)
    }
}

/// Execute one experiment end-to-end. `rt` may be `None` for native-only
/// training (no artifacts directory involved at all).
pub fn run_experiment(
    rt: Option<&Runtime>,
    cfg: &ExperimentConfig,
    opts: &RunnerOptions,
) -> Result<RunResult> {
    let topts = TrainOptions {
        lr: cfg.lr,
        ent_coef: cfg.ent_coef,
        baseline_decay: cfg.baseline_decay,
        weights: cfg.weights(),
        fill_rule: cfg.fill_rule,
        seed: cfg.seed,
        workers: if opts.workers == 0 {
            default_workers()
        } else {
            opts.workers
        },
    };
    let mut trainer = build_trainer(rt, &cfg.controller, topts, opts.backend)?;
    let workload = prepare(cfg)?;
    anyhow::ensure!(
        workload.grid.n == trainer.entry.n,
        "dataset {} at grid {} yields {} cells; controller {} expects {} — \
         pick a matching controller config",
        cfg.dataset.label(),
        cfg.grid,
        workload.grid.n,
        trainer.entry.name,
        trainer.entry.n
    );

    let run_dir = opts.out_root.join(&cfg.name);
    std::fs::create_dir_all(&run_dir)
        .with_context(|| format!("creating {}", run_dir.display()))?;
    std::fs::write(run_dir.join("config.json"), cfg.to_json().to_pretty())?;
    let mut log = MetricsLog::create(&run_dir)?;

    if opts.verbose {
        println!(
            "[{}] backend {} ({} workers)",
            cfg.name,
            trainer.backend_name(),
            topts.workers
        );
    }

    let t0 = Instant::now();
    let mut history = Vec::new();
    let mut last: Option<EpochStats> = None;
    for e in 0..cfg.epochs {
        let stats = trainer.epoch(&workload.grid)?;
        let should_log =
            cfg.log_every > 0 && (e % cfg.log_every == 0 || e + 1 == cfg.epochs);
        if should_log {
            log.log(&stats)?;
            if opts.verbose {
                println!(
                    "[{}] epoch {:>6}  R̄={:.4}  C̄={:.4}  Ā={:.4}  complete={:.0}%  best_area={}",
                    cfg.name,
                    stats.epoch,
                    stats.mean_reward,
                    stats.mean_coverage,
                    stats.mean_area,
                    stats.frac_complete * 100.0,
                    trainer
                        .best
                        .as_ref()
                        .map(|b| format!("{:.4}", b.eval.area_ratio))
                        .unwrap_or_else(|| "-".into()),
                );
            }
        }
        if opts.checkpoint_every > 0 && (e + 1) % opts.checkpoint_every == 0 {
            trainer.save_checkpoint(&run_dir.join("checkpoint.json"))?;
        }
        if opts.keep_history {
            history.push(stats.clone());
        }
        last = Some(stats);
    }
    log.flush()?;
    let wall_seconds = t0.elapsed().as_secs_f64();
    write_summary(
        &run_dir,
        &cfg.name,
        trainer.best.as_ref(),
        last.as_ref(),
        wall_seconds,
    )?;

    Ok(RunResult {
        best: trainer.best.clone(),
        best_reward: trainer.best_reward.clone(),
        last,
        history,
        workload,
        run_dir,
        wall_seconds,
    })
}

/// Render the run's training curves (coverage/area/reward vs epoch) as an
/// ASCII chart — the terminal analogue of Figs. 9/11/13.
pub fn curves_ascii(history: &[EpochStats], width: usize, height: usize) -> String {
    let cov: Vec<f64> = history.iter().map(|s| s.mean_coverage).collect();
    let area: Vec<f64> = history.iter().map(|s| s.mean_area).collect();
    let reward: Vec<f64> = history.iter().map(|s| s.mean_reward).collect();
    crate::viz::ascii_chart(
        &[
            ("coverage", &cov),
            ("area", &area),
            ("reward", &reward),
        ],
        width,
        height,
    )
}

/// Best-solution one-line description (Table II/IV row material).
pub fn describe_best(best: &Option<BestSolution>, grid: &crate::graph::GridSummary) -> String {
    match best {
        None => "no complete-coverage solution found".to_string(),
        Some(b) => format!(
            "diag {:?}  fill {:?}  C={:.3} A={:.3} sparsity={:.3} (epoch {})",
            b.scheme.diag_sizes_units(grid),
            b.scheme.fill_len,
            b.eval.coverage_ratio,
            b.eval.area_ratio,
            b.eval.sparsity,
            b.epoch
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheme::FillRule;

    #[test]
    fn curves_ascii_smoke() {
        let h: Vec<EpochStats> = (0..50)
            .map(|e| EpochStats {
                epoch: e,
                mean_reward: 0.5 + e as f64 / 100.0,
                max_reward: 0.9,
                mean_coverage: 0.9,
                mean_area: 0.5 - e as f64 / 200.0,
                frac_complete: 0.5,
                baseline: 0.5,
                loss: 0.0,
                mean_logp: -3.0,
            })
            .collect();
        let s = curves_ascii(&h, 40, 10);
        assert!(s.contains("coverage"));
        assert!(s.contains("reward"));
    }

    #[test]
    fn auto_backend_without_runtime_is_native() {
        let topts = TrainOptions {
            fill_rule: FillRule::Dynamic { grades: 4 },
            workers: 1,
            ..Default::default()
        };
        let t = build_trainer(None, "qm7_dyn4", topts, BackendKind::Auto).unwrap();
        assert_eq!(t.backend_name(), "native");
    }

    #[test]
    fn pjrt_backend_without_artifacts_suggests_native() {
        let rt = Runtime::new("/nonexistent_dir_autogmap_runner").unwrap();
        let topts = TrainOptions {
            fill_rule: FillRule::Dynamic { grades: 4 },
            ..Default::default()
        };
        let err = build_trainer(Some(&rt), "qm7_dyn4", topts, BackendKind::Pjrt).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("--backend native"), "unhelpful: {msg}");
        // Auto with a runtime but no manifest also falls back to native
        let topts2 = TrainOptions {
            fill_rule: FillRule::Dynamic { grades: 4 },
            ..Default::default()
        };
        let t = build_trainer(Some(&rt), "qm7_dyn4", topts2, BackendKind::Auto).unwrap();
        assert_eq!(t.backend_name(), "native");
    }

    #[test]
    fn unknown_controller_is_rejected_everywhere() {
        let topts = TrainOptions::default();
        assert!(build_trainer(None, "no_such_cfg", topts, BackendKind::Native).is_err());
    }
}
