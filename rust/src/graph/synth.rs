//! Synthetic dataset generators.
//!
//! The paper evaluates on QM7 molecule #5828 (22×22, sparsity 0.868) and
//! the Harwell-Boeing matrices qh882 / qh1484 (sparsity 0.995 / 0.997).
//! Those exact files are not redistributable/offline-fetchable, so we
//! generate structure-matched substitutes (see DESIGN.md §6): same
//! dimensions, same sparsity, and a comparable bandwidth profile after
//! Cuthill-McKee reordering. All generators are deterministic in the seed.

use crate::graph::sparse::{Coo, Csr};
use crate::util::rng::Pcg64;

/// A 22×22 molecule-like adjacency: spanning-tree backbone (bounded valence,
/// like a C/N/O skeleton) plus ring-closure edges until the nnz count of the
/// paper's QM7-5828 matrix (64 non-zeros ⇒ sparsity 1 − 64/484 = 0.868) is
/// reached.
pub fn qm7_like(seed: u64) -> Csr {
    molecule_like(22, 64, seed)
}

/// General molecule-like generator: `dim` atoms, symmetric, no self-loops,
/// exactly `target_nnz` non-zeros (must be even and ≥ 2(dim−1)).
pub fn molecule_like(dim: usize, target_nnz: usize, seed: u64) -> Csr {
    assert!(target_nnz % 2 == 0, "symmetric off-diagonal nnz must be even");
    let edges = target_nnz / 2;
    assert!(
        edges >= dim - 1,
        "need at least a spanning tree ({} edges)",
        dim - 1
    );
    assert!(
        edges <= dim * (dim - 1) / 2,
        "cannot place {edges} edges in a simple graph on {dim} nodes"
    );
    let mut rng = Pcg64::seed_from_u64(seed ^ qm7_stream());
    let mut adj = vec![false; dim * dim];
    let mut deg = vec![0usize; dim];
    let mut coo = Coo::new(dim, dim);
    let add = |coo: &mut Coo, adj: &mut Vec<bool>, deg: &mut Vec<usize>, a: usize, b: usize| {
        adj[a * dim + b] = true;
        adj[b * dim + a] = true;
        deg[a] += 1;
        deg[b] += 1;
        coo.push_sym(a, b, 1.0);
    };

    // Backbone: chain with occasional short branches (valence ≤ 4), so the
    // graph looks like an organic skeleton rather than a uniform tree.
    let mut placed = 0usize;
    for v in 1..dim {
        // attach to one of the previous few vertices with free valence
        let lo = v.saturating_sub(4);
        let mut candidates: Vec<usize> = (lo..v).filter(|&u| deg[u] < 4).collect();
        if candidates.is_empty() {
            candidates = (0..v).filter(|&u| deg[u] < 4).collect();
        }
        if candidates.is_empty() {
            candidates = (0..v).collect(); // degenerate; keep connectivity
        }
        let u = candidates[rng.below(candidates.len() as u64) as usize];
        add(&mut coo, &mut adj, &mut deg, u, v);
        placed += 1;
    }

    // Ring closures: short-range extra edges (cycle lengths 3–6, as in
    // molecules) until the edge budget is met.
    let mut guard = 0;
    while placed < edges {
        guard += 1;
        assert!(guard < 100_000, "molecule generator failed to place edges");
        let a = rng.below(dim as u64) as usize;
        let span = 2 + rng.below(4) as usize; // partner 2..5 positions away
        let b = if rng.bool(0.5) {
            a.saturating_sub(span)
        } else {
            (a + span).min(dim - 1)
        };
        if a == b || adj[a * dim + b] || deg[a] >= 4 || deg[b] >= 4 {
            continue;
        }
        add(&mut coo, &mut adj, &mut deg, a, b);
        placed += 1;
    }
    let m = coo.to_csr();
    debug_assert_eq!(m.nnz(), target_nnz);
    m
}

// The xor constant for the molecule generator stream, kept out of line so
// the seed derivation is documented in one place.
#[inline]
fn qm7_stream() -> u64 {
    0x516d_3758_3238_0001 // "Qm7X28…"
}

/// qh882-like matrix: 882×882 symmetric, sparsity ≈ 0.995.
pub fn qh882_like(seed: u64) -> Csr {
    banded_like(882, 0.995, seed)
}

/// qh1484-like matrix: 1484×1484 symmetric, sparsity ≈ 0.997.
pub fn qh1484_like(seed: u64) -> Csr {
    banded_like(1484, 0.997, seed)
}

/// Variable-bandwidth symmetric matrix with the locality structure typical
/// of reordered FEM/graph matrices: most entries near the diagonal with a
/// heavy-tailed offset distribution, plus a small fraction of long-range
/// entries, plus a full diagonal (qh* matrices have structural diagonals).
pub fn banded_like(dim: usize, sparsity: f64, seed: u64) -> Csr {
    assert!((0.0..1.0).contains(&sparsity));
    let target_nnz = ((1.0 - sparsity) * (dim as f64) * (dim as f64)).round() as usize;
    let mut rng = Pcg64::seed_from_u64(seed ^ 0x7168_5f6c_696b_6500); // "qh_like"
    let mut coo = Coo::new(dim, dim);
    let mut have = std::collections::BTreeSet::new();

    // Structural diagonal.
    for i in 0..dim {
        coo.push(i, i, 1.0);
        have.insert((i, i));
    }
    let mut placed = dim;

    // Local chain so the matrix is connected (helps CM produce one level
    // structure, like the originals).
    for i in 1..dim {
        if placed + 2 > target_nnz {
            break;
        }
        coo.push_sym(i, i - 1, 1.0);
        have.insert((i, i - 1));
        have.insert((i - 1, i));
        placed += 2;
    }

    // Local offsets with a slowly varying band scale. The bandwidth
    // "waviness" (wide and narrow sections alternating along the diagonal)
    // is what gives Table IV its variable diagonal-block sizes. Offsets are
    // hard-capped: the real qh* matrices are *purely* banded after
    // Cuthill-McKee (no long-range outliers), which is what makes small
    // diagonal-block schemes complete-coverage-feasible at all.
    let cap = (dim as f64 * 0.075).round() as usize;
    let mut guard = 0usize;
    while placed + 2 <= target_nnz {
        guard += 1;
        assert!(guard < 100 * target_nnz, "banded generator stalled");
        let r = rng.below(dim as u64) as usize;
        // local band scale varies sinusoidally along the diagonal: 1%–4% of dim
        let phase = r as f64 / dim as f64 * std::f64::consts::TAU * 3.0;
        let scale = dim as f64 * (0.008 + 0.016 * (1.0 + phase.sin()) / 2.0);
        // geometric-ish local offset, capped to keep the matrix banded
        let offset =
            ((scale * (-rng.f64().max(1e-9).ln())).round() as usize).min(cap);
        if offset == 0 {
            continue;
        }
        let c = if rng.bool(0.5) {
            r.saturating_sub(offset)
        } else {
            (r + offset).min(dim - 1)
        };
        if r == c {
            continue;
        }
        let key = (r.max(c), r.min(c));
        if have.contains(&key) {
            continue;
        }
        have.insert(key);
        have.insert((key.1, key.0));
        coo.push_sym(key.0, key.1, 1.0);
        placed += 2;
    }
    coo.to_csr()
}

/// Power-law (preferential-attachment) graph for the extra workloads the
/// paper's intro motivates (social networks / knowledge graphs).
pub fn power_law(dim: usize, edges_per_node: usize, seed: u64) -> Csr {
    assert!(dim > edges_per_node && edges_per_node >= 1);
    let mut rng = Pcg64::seed_from_u64(seed ^ 0x706c_6177_0000_0001);
    let mut coo = Coo::new(dim, dim);
    let mut targets: Vec<usize> = Vec::new(); // repeated-by-degree pool
    let mut have = std::collections::BTreeSet::new();
    // seed clique
    for v in 0..=edges_per_node {
        for u in 0..v {
            coo.push_sym(v, u, 1.0);
            have.insert((v, u));
            targets.push(u);
            targets.push(v);
        }
    }
    for v in (edges_per_node + 1)..dim {
        let mut added = 0;
        let mut guard = 0;
        while added < edges_per_node {
            guard += 1;
            if guard > 10_000 {
                break;
            }
            let u = targets[rng.below(targets.len() as u64) as usize];
            if u == v || have.contains(&(v, u)) {
                continue;
            }
            coo.push_sym(v, u, 1.0);
            have.insert((v, u));
            targets.push(u);
            targets.push(v);
            added += 1;
        }
    }
    coo.to_csr()
}

/// Deterministic R-MAT-style generator (Chakrabarti et al.) for the
/// large-scale power-law graphs the mapper pipeline targets: each edge is
/// drawn by recursive quadrant descent with the classic skewed
/// probabilities (a, b, c, d) = (0.57, 0.19, 0.19, 0.05), then
/// symmetrized. No self-loops, exactly `target_nnz` non-zeros
/// (`target_nnz` must be even — entries come in (u,v)/(v,u) pairs), fully
/// reproducible from the seed. Intended for sparse regimes
/// (`target_nnz ≪ n²`); the duplicate-rejection loop asserts if asked to
/// fill a near-dense quadrant the skew cannot reach.
pub fn rmat_like(n: usize, target_nnz: usize, seed: u64) -> Csr {
    assert!(n >= 2, "rmat_like needs at least 2 nodes");
    assert!(target_nnz % 2 == 0, "symmetric nnz must be even");
    let edges = target_nnz / 2;
    assert!(
        edges <= n * (n - 1) / 2,
        "cannot place {edges} undirected edges in a simple graph on {n} nodes"
    );
    // bits needed to index [0, n): descend one quadrant level per bit
    let levels = (usize::BITS - (n - 1).leading_zeros()) as usize;
    let mut rng = Pcg64::seed_from_u64(seed ^ 0x726d_6174_0000_0001); // "rmat"
    let mut have = std::collections::HashSet::with_capacity(edges * 2);
    let mut coo = Coo::new(n, n);
    let mut placed = 0usize;
    let mut guard = 0usize;
    while placed < edges {
        guard += 1;
        assert!(
            guard < 400 * edges + 10_000,
            "rmat generator stalled ({placed}/{edges} edges placed) — \
             target_nnz is too dense for the R-MAT skew"
        );
        let (mut r, mut c) = (0usize, 0usize);
        for _ in 0..levels {
            r <<= 1;
            c <<= 1;
            let u = rng.f64();
            if u < 0.57 {
                // top-left quadrant: both bits stay 0
            } else if u < 0.76 {
                c |= 1;
            } else if u < 0.95 {
                r |= 1;
            } else {
                r |= 1;
                c |= 1;
            }
        }
        if r >= n || c >= n || r == c {
            continue; // out of the non-power-of-two range, or a self-loop
        }
        let key = (r.min(c) as u64) * n as u64 + r.max(c) as u64;
        if !have.insert(key) {
            continue; // duplicate edge
        }
        coo.push_sym(r, c, 1.0);
        placed += 1;
    }
    let m = coo.to_csr();
    debug_assert_eq!(m.nnz(), target_nnz);
    m
}

/// Batch-graphs super-matrix: block-diagonal integration of several graphs
/// ("the adjacency matrices are usually integrated into a large-scale
/// super-matrix, with only the sub-graphs being internally connected").
pub fn batch_supermatrix(graphs: &[Csr]) -> Csr {
    let dim: usize = graphs.iter().map(|g| g.rows).sum();
    let mut coo = Coo::new(dim, dim);
    let mut off = 0;
    for g in graphs {
        assert_eq!(g.rows, g.cols, "batch graphs must be square");
        for r in 0..g.rows {
            for (i, &c) in g.row(r).iter().enumerate() {
                coo.push(off + r, off + c, g.row_vals(r)[i]);
            }
        }
        off += g.rows;
    }
    coo.to_csr()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qm7_like_matches_paper_stats() {
        let m = qm7_like(5828);
        assert_eq!(m.rows, 22);
        assert_eq!(m.nnz(), 64);
        assert!((m.sparsity() - 0.868).abs() < 2e-3, "sparsity {}", m.sparsity());
        assert!(m.is_symmetric());
        // no self loops
        for i in 0..22 {
            assert_eq!(m.get(i, i), 0.0);
        }
    }

    #[test]
    fn qm7_like_is_connected() {
        let m = qm7_like(5828);
        // BFS from 0
        let mut seen = vec![false; m.rows];
        let mut stack = vec![0usize];
        seen[0] = true;
        while let Some(v) = stack.pop() {
            for &u in m.row(v) {
                if !seen[u] {
                    seen[u] = true;
                    stack.push(u);
                }
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn qh882_like_stats() {
        let m = qh882_like(882);
        assert_eq!(m.rows, 882);
        assert!((m.sparsity() - 0.995).abs() < 5e-4, "sparsity {}", m.sparsity());
        assert!(m.is_symmetric());
    }

    #[test]
    fn qh1484_like_stats() {
        let m = qh1484_like(1484);
        assert_eq!(m.rows, 1484);
        assert!((m.sparsity() - 0.997).abs() < 5e-4, "sparsity {}", m.sparsity());
        assert!(m.is_symmetric());
    }

    #[test]
    fn generators_are_deterministic() {
        assert_eq!(qm7_like(1), qm7_like(1));
        assert_eq!(qh882_like(7), qh882_like(7));
        assert_ne!(qm7_like(1).to_dense(), qm7_like(2).to_dense());
    }

    #[test]
    fn power_law_has_heavy_tail() {
        let m = power_law(300, 2, 3);
        assert!(m.is_symmetric());
        let max_deg = (0..m.rows).map(|r| m.degree(r)).max().unwrap();
        let mean_deg = m.nnz() as f64 / m.rows as f64;
        assert!(max_deg as f64 > 3.0 * mean_deg, "max {max_deg}, mean {mean_deg}");
    }

    #[test]
    fn rmat_like_stats_and_determinism() {
        let m = rmat_like(2000, 16_000, 7);
        assert_eq!(m.rows, 2000);
        assert_eq!(m.nnz(), 16_000);
        assert!(m.is_symmetric());
        for i in 0..m.rows {
            assert_eq!(m.get(i, i), 0.0, "no self-loops");
        }
        assert_eq!(m.to_dense(), rmat_like(2000, 16_000, 7).to_dense());
        assert_ne!(m.to_dense(), rmat_like(2000, 16_000, 8).to_dense());
    }

    #[test]
    fn rmat_like_has_power_law_tail() {
        let m = rmat_like(1500, 12_000, 3);
        let max_deg = (0..m.rows).map(|r| m.degree(r)).max().unwrap();
        let mean_deg = m.nnz() as f64 / m.rows as f64;
        assert!(
            max_deg as f64 > 4.0 * mean_deg,
            "R-MAT skew should make hubs: max {max_deg}, mean {mean_deg}"
        );
    }

    #[test]
    fn rmat_like_non_power_of_two_dims() {
        // 100 is not a power of two: out-of-range draws are rejected, the
        // edge budget is still met exactly
        let m = rmat_like(100, 600, 1);
        assert_eq!(m.rows, 100);
        assert_eq!(m.nnz(), 600);
        assert!(m.is_symmetric());
    }

    #[test]
    fn batch_supermatrix_is_block_diagonal() {
        let a = qm7_like(1);
        let b = qm7_like(2);
        let s = batch_supermatrix(&[a.clone(), b.clone()]);
        assert_eq!(s.rows, 44);
        assert_eq!(s.nnz(), a.nnz() + b.nnz());
        // no cross-graph adjacency
        assert_eq!(s.nnz_in_rect(0, 22, 22, 44), 0);
        assert_eq!(s.nnz_in_rect(22, 44, 0, 22), 0);
        assert_eq!(s.get(23, 22 + a.row(1)[0] - a.row(1)[0]), s.get(23, 22));
    }
}
