//! Pluggable training backends behind the [`TrainBackend`] trait.
//!
//! The trait captures exactly what Algo. 2/3 need from the learner — sample
//! a batch of episodes, take one REINFORCE+Adam step on them, decode
//! greedily, and expose state for checkpointing. Everything else (scheme
//! parsing, the environment reward, the EMA baseline, best-solution
//! tracking) lives in [`crate::agent::Trainer`] and is backend-agnostic.
//!
//! Two implementations ship:
//!
//! - [`PjrtBackend`] — the AOT path: per epoch one `rollout_<cfg>` and one
//!   `train_<cfg>` PJRT artifact call (requires a built `artifacts/`
//!   directory);
//! - [`crate::agent::native::NativeBackend`] — pure Rust: sampling through
//!   the [`crate::agent::lstm`] mirror on a std-thread worker pool,
//!   gradients by full backprop-through-time, Adam on the host. Needs no
//!   artifacts at all.
//!
//! [`BackendKind::Auto`] resolves to PJRT when an artifacts manifest is
//! loadable and to native otherwise, so `train` works on a fresh checkout.

use crate::agent::params::{self, AdamState, Params};
use crate::runtime::manifest::ControllerEntry;
use crate::runtime::{literal, Executable, Runtime};
use anyhow::{bail, ensure, Context, Result};
use std::sync::Arc;

/// Which backend executes rollouts and gradient steps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// PJRT when an artifacts manifest is present, native otherwise.
    Auto,
    /// Pure-Rust BPTT trainer (no artifacts needed).
    Native,
    /// AOT PJRT artifacts (requires `artifacts/`).
    Pjrt,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<BackendKind> {
        Ok(match s {
            "auto" => BackendKind::Auto,
            "native" => BackendKind::Native,
            "pjrt" => BackendKind::Pjrt,
            other => bail!("unknown backend {other:?} (native|pjrt|auto)"),
        })
    }
}

/// One sampled batch: row-major [B, T] action matrices.
pub struct RolloutBatch {
    pub d_all: Vec<i32>,
    pub f_all: Vec<i32>,
}

/// Scalar outputs of one gradient step.
#[derive(Clone, Copy, Debug)]
pub struct StepStats {
    pub loss: f32,
    pub mean_logp: f32,
}

/// What a training backend must provide.
///
/// Contract notes: `rollout` returns `entry.batch` episodes of
/// `entry.steps` actions each; `train_step` applies
/// `loss = -mean(adv · logp) - ent_coef · mean(H)` (the REINFORCE
/// objective of `model.train_step`) followed by one Adam update; `greedy`
/// returns at least one episode, row-major, and callers read episode 0.
pub trait TrainBackend {
    fn name(&self) -> &'static str;
    /// Sample `entry.batch` episodes with the given PRNG key.
    fn rollout(&mut self, key: [u32; 2]) -> Result<RolloutBatch>;
    /// One REINFORCE + Adam step on the sampled episodes.
    fn train_step(
        &mut self,
        d_all: &[i32],
        f_all: &[i32],
        adv: &[f32],
        lr: f32,
        ent_coef: f32,
    ) -> Result<StepStats>;
    /// Deterministic argmax decode.
    fn greedy(&mut self) -> Result<(Vec<i32>, Vec<i32>)>;
    /// Host-synced copy of the current parameters.
    fn params(&self) -> Result<Params>;
    /// Host-synced copy of the optimizer state.
    fn opt_state(&self) -> Result<AdamState>;
    /// Replace parameters + optimizer state (checkpoint restore).
    fn load_state(&mut self, params: Params, opt: AdamState) -> Result<()>;
}

/// Actionable context for a failed artifact load: the most common cause is
/// simply that `artifacts/` was never built, and the fix is one flag away.
fn artifact_hint(rt: &Runtime, config: &str) -> String {
    format!(
        "loading PJRT artifacts for config {config} from {} — if you have \
         not built artifacts, rerun with `--backend native` (the pure-Rust \
         trainer needs none) or build them with `make artifacts`",
        rt.artifacts_dir().display()
    )
}

/// The original AOT path: rollout/train/greedy HLO artifacts executed
/// through PJRT. Parameter and Adam literals are cached across epochs and
/// refreshed in-place from the train step's *output* literals — avoids two
/// Vec<f32> ↔ Literal conversions per epoch (EXPERIMENTS.md §Perf).
pub struct PjrtBackend {
    entry: ControllerEntry,
    rollout_exe: Arc<Executable>,
    train_exe: Arc<Executable>,
    greedy_exe: Option<Arc<Executable>>,
    /// cheap host mirror, kept in sync after every train step
    params: Params,
    opt: AdamState,
    /// cached literal forms of (params, m, v)
    lits: Option<(Vec<xla::Literal>, Vec<xla::Literal>, Vec<xla::Literal>)>,
}

impl PjrtBackend {
    pub fn new(rt: &Runtime, entry: ControllerEntry, seed: u64) -> Result<PjrtBackend> {
        let rollout_exe = entry
            .artifact("rollout")
            .and_then(|f| rt.load(f))
            .with_context(|| artifact_hint(rt, &entry.name))?;
        let train_exe = entry
            .artifact("train")
            .and_then(|f| rt.load(f))
            .with_context(|| artifact_hint(rt, &entry.name))?;
        let greedy_exe = entry
            .artifacts
            .get("greedy")
            .map(|f| rt.load(f))
            .transpose()
            .with_context(|| artifact_hint(rt, &entry.name))?;
        let params = params::init_params(&entry, seed);
        let opt = AdamState::new(&entry);
        Ok(PjrtBackend {
            entry,
            rollout_exe,
            train_exe,
            greedy_exe,
            params,
            opt,
            lits: None,
        })
    }

    fn ensure_lits(&mut self) -> Result<()> {
        if self.lits.is_none() {
            self.lits = Some((
                params::to_literals(&self.entry, &self.params)?,
                params::to_literals(&self.entry, &self.opt.m)?,
                params::to_literals(&self.entry, &self.opt.v)?,
            ));
        }
        Ok(())
    }
}

impl TrainBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn rollout(&mut self, key: [u32; 2]) -> Result<RolloutBatch> {
        let (b, t) = (self.entry.batch, self.entry.steps);
        self.ensure_lits()?;
        let (p_lits, _, _) = self.lits.as_ref().unwrap();
        let key_lit = literal::lit_u32_1d(&key);
        let mut inputs: Vec<&xla::Literal> = p_lits.iter().collect();
        inputs.push(&key_lit);
        let outs = self.rollout_exe.run_refs(&inputs)?;
        ensure!(outs.len() == 4, "rollout returned {} outputs", outs.len());
        let d_all = literal::to_vec_i32(&outs[0])?;
        let f_all = literal::to_vec_i32(&outs[1])?;
        ensure!(d_all.len() == b * t && f_all.len() == b * t);
        Ok(RolloutBatch { d_all, f_all })
    }

    fn train_step(
        &mut self,
        d_all: &[i32],
        f_all: &[i32],
        adv: &[f32],
        lr: f32,
        ent_coef: f32,
    ) -> Result<StepStats> {
        let (b, t) = (self.entry.batch, self.entry.steps);
        let k = self.entry.params.len();
        self.ensure_lits()?;
        let (p_lits, m_lits, v_lits) = self.lits.as_ref().unwrap();
        let t_lit = literal::lit_scalar_i32(self.opt.t);
        let d_lit = literal::lit_i32_2d(d_all, b, t)?;
        let f_lit = literal::lit_i32_2d(f_all, b, t)?;
        let adv_lit = literal::lit_f32_1d(adv);
        let lr_lit = literal::lit_scalar_f32(lr);
        let ent_lit = literal::lit_scalar_f32(ent_coef);
        let mut tin: Vec<&xla::Literal> = Vec::with_capacity(3 * k + 6);
        tin.extend(p_lits.iter());
        tin.extend(m_lits.iter());
        tin.extend(v_lits.iter());
        tin.extend([&t_lit, &d_lit, &f_lit, &adv_lit, &lr_lit, &ent_lit]);
        let mut touts = self.train_exe.run_refs(&tin)?;
        ensure!(
            touts.len() == 3 * k + 3,
            "train returned {} outputs, expected {}",
            touts.len(),
            3 * k + 3
        );
        self.opt.t = touts[3 * k].to_vec::<i32>().context("adam t")?[0];
        let loss = touts[3 * k + 1].to_vec::<f32>().context("loss")?[0];
        let mean_logp = touts[3 * k + 2].to_vec::<f32>().context("mean_logp")?[0];
        touts.truncate(3 * k);
        let new_v: Vec<xla::Literal> = touts.split_off(2 * k);
        let new_m: Vec<xla::Literal> = touts.split_off(k);
        // keep the cheap Vec<f32> mirror in sync for checkpoints/inspection
        self.params = params::from_literals(&self.entry, &touts)?;
        self.lits = Some((touts, new_m, new_v));
        Ok(StepStats { loss, mean_logp })
    }

    fn greedy(&mut self) -> Result<(Vec<i32>, Vec<i32>)> {
        let exe = self
            .greedy_exe
            .as_ref()
            .context("no greedy artifact for this config")?;
        let inputs = params::to_literals(&self.entry, &self.params)?;
        let outs = exe.run(&inputs)?;
        Ok((
            literal::to_vec_i32(&outs[0])?,
            literal::to_vec_i32(&outs[1])?,
        ))
    }

    fn params(&self) -> Result<Params> {
        Ok(self.params.clone())
    }

    fn opt_state(&self) -> Result<AdamState> {
        // the hot loop keeps m/v only as device literals; sync on demand
        let mut opt = self.opt.clone();
        if let Some((_, m_lits, v_lits)) = self.lits.as_ref() {
            opt.m = params::from_literals(&self.entry, m_lits)?;
            opt.v = params::from_literals(&self.entry, v_lits)?;
        }
        Ok(opt)
    }

    fn load_state(&mut self, params: Params, opt: AdamState) -> Result<()> {
        self.params = params;
        self.opt = opt;
        self.lits = None; // invalidate cached literals
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_parses() {
        assert_eq!(BackendKind::parse("auto").unwrap(), BackendKind::Auto);
        assert_eq!(BackendKind::parse("native").unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::parse("pjrt").unwrap(), BackendKind::Pjrt);
        assert!(BackendKind::parse("gpu").is_err());
    }

    #[test]
    fn missing_artifacts_error_is_actionable() {
        let rt = Runtime::new("/nonexistent_dir_autogmap_backend").unwrap();
        let entry = ControllerEntry::from_dims("qm7_dyn4", 11, 10, 4, 8, false);
        // builtin entries have no artifact files at all -> load must fail
        // with a message that points at the native backend
        let err = PjrtBackend::new(&rt, entry, 0).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("--backend native"), "unhelpful: {msg}");
    }
}
