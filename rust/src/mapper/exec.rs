//! Composite execution: compile a [`CompositeScheme`] into per-window
//! [`ExecPlan`]s, merge them into one fleet-servable schedule, and serve
//! y = Ax exactly by adding the digital spill (the nnz outside every
//! mapped rect) back on the host.
//!
//! Exactness contract: every non-zero is either inside exactly one mapped
//! tile (rects are disjoint; all-zero tiles elide nothing but zeros) or in
//! the spill CSR — never both, never neither — so a composite MVM equals
//! the dense oracle up to floating-point summation order, and *exactly*
//! (bit-identical) whenever products round to nothing, e.g. adjacency
//! weights with integer inputs. The [`CompositeExecutor`] parallelizes
//! across requests only (one worker per request, plan order then spill
//! row-order inside it), so results are bit-identical for any worker
//! count.

use crate::engine::batch::ServablePlan;
use crate::engine::plan::{compile_rects, merge_plans, ExecPlan};
use crate::graph::{Csr, GridSummary};
use crate::scheme::CompositeScheme;
use anyhow::{anyhow, Result};

/// A compiled composite mapping: the merged crossbar schedule plus the
/// digital remainder.
#[derive(Clone, Debug)]
pub struct CompositePlan {
    /// merged tile schedule over the full matrix (window plans
    /// concatenated in slice order, programs deduplicated across windows)
    pub plan: ExecPlan,
    /// off-plan entries, served from sparse digital storage
    pub spill: Csr,
    /// per-window placed-tile counts (slice order), for fleet reporting
    pub window_tiles: Vec<usize>,
}

/// Compile every slice of a composite to its own [`ExecPlan`] and merge.
pub fn compile_composite(
    m: &Csr,
    g: &GridSummary,
    comp: &CompositeScheme,
) -> Result<CompositePlan> {
    comp.validate(g.n).map_err(|e| anyhow!("invalid composite: {e}"))?;
    let mut parts = Vec::with_capacity(comp.slices.len());
    let mut window_tiles = Vec::with_capacity(comp.slices.len());
    for s in &comp.slices {
        let p = compile_rects(m, g, &s.rects())?;
        window_tiles.push(p.tiles.len());
        parts.push(p);
    }
    let plan = merge_plans(&parts)?;

    // covered-cell bitmap over the global grid, then the spill CSR: every
    // entry whose grid cell is not covered by any mapped rect
    let n = g.n;
    let mut covered = vec![false; n * n];
    for s in &comp.slices {
        for r in s.rects() {
            for rr in r.r0..r.r1 {
                covered[rr * n + r.c0..rr * n + r.c1].fill(true);
            }
        }
    }
    let k = g.grid;
    let mut indptr = Vec::with_capacity(m.rows + 1);
    indptr.push(0usize);
    let mut indices = Vec::new();
    let mut data = Vec::new();
    for r in 0..m.rows {
        let row_cells = (r / k) * n;
        for (i, &c) in m.row(r).iter().enumerate() {
            if !covered[row_cells + c / k] {
                indices.push(c);
                data.push(m.row_vals(r)[i]);
            }
        }
        indptr.push(indices.len());
    }
    let spill = Csr {
        rows: m.rows,
        cols: m.cols,
        indptr,
        indices,
        data,
    };
    Ok(CompositePlan {
        plan,
        spill,
        window_tiles,
    })
}

impl CompositePlan {
    /// y = Ax: mapped tiles in plan order, then the spill in row-major CSR
    /// order, accumulated into the same output buffer.
    pub fn mvm_into(&self, x: &[f64], y: &mut Vec<f64>) {
        self.plan.mvm_into(x, y);
        for r in 0..self.spill.rows {
            let cols = self.spill.row(r);
            if cols.is_empty() {
                continue;
            }
            let vals = self.spill.row_vals(r);
            let mut acc = 0.0f64;
            for (&c, &v) in cols.iter().zip(vals.iter()) {
                acc += v * x[c];
            }
            y[r] += acc;
        }
    }

    /// Allocating convenience wrapper around [`Self::mvm_into`].
    pub fn mvm(&self, x: &[f64]) -> Vec<f64> {
        let mut y = Vec::new();
        self.mvm_into(x, &mut y);
        y
    }

    /// Non-zeros served by crossbar tiles.
    pub fn mapped_nnz(&self) -> u64 {
        let pn = self.plan.program_nnz();
        self.plan.tiles.iter().map(|t| pn[t.program]).sum()
    }

    /// Non-zeros served digitally.
    pub fn spilled_nnz(&self) -> u64 {
        self.spill.nnz() as u64
    }
}

impl ServablePlan for CompositePlan {
    fn dim(&self) -> usize {
        self.plan.dim
    }

    fn mvm_into(&self, x: &[f64], y: &mut Vec<f64>) {
        CompositePlan::mvm_into(self, x, y)
    }
}

/// Request-parallel executor for a composite plan: the shared
/// [`crate::engine::BatchExecutor`] machinery (pooled output buffers,
/// request-order results, one worker per request so results are
/// bit-identical for any worker count) serving a [`CompositePlan`].
pub type CompositeExecutor = crate::engine::BatchExecutor<CompositePlan>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::synth;
    use crate::scheme::{Scheme, WindowSlice};
    use std::sync::Arc;

    fn two_window_composite(n: usize, cut: usize, win: usize) -> CompositeScheme {
        CompositeScheme {
            n,
            slices: vec![
                WindowSlice {
                    win_start: 0,
                    win_end: win,
                    start: 0,
                    end: cut,
                    scheme: Scheme { diag_len: vec![win], fill_len: vec![] },
                    cache_hit: false,
                },
                WindowSlice {
                    win_start: n - win,
                    win_end: n,
                    start: cut,
                    end: n,
                    scheme: Scheme { diag_len: vec![win], fill_len: vec![] },
                    cache_hit: true,
                },
            ],
        }
    }

    #[test]
    fn composite_mvm_matches_spmv_exactly_on_integer_inputs() {
        let m = synth::banded_like(90, 0.92, 4);
        let g = GridSummary::new(&m, 5); // n = 18
        let comp = two_window_composite(18, 9, 12);
        let cp = compile_composite(&m, &g, &comp).unwrap();
        // conservation: mapped + spilled = total
        assert_eq!(cp.mapped_nnz() + cp.spilled_nnz(), m.nnz() as u64);
        assert!(cp.spilled_nnz() > 0, "band entries cross the cut");
        // integer inputs: adjacency products and partial sums are exact,
        // so any accumulation order gives the bit-identical dense answer
        let x: Vec<f64> = (0..90).map(|i| ((i * 11) % 23) as f64 - 11.0).collect();
        assert_eq!(cp.mvm(&x), m.spmv(&x));
    }

    #[test]
    fn executor_is_bit_identical_across_worker_counts() {
        let m = synth::banded_like(60, 0.9, 2);
        let g = GridSummary::new(&m, 4); // n = 15
        let comp = two_window_composite(15, 8, 10);
        let cp = Arc::new(compile_composite(&m, &g, &comp).unwrap());
        let xs: Vec<Vec<f64>> = (0..9)
            .map(|s| (0..60).map(|i| ((i + 3 * s) % 13) as f64 - 6.0).collect())
            .collect();
        let want: Vec<Vec<f64>> = xs.iter().map(|x| cp.mvm(x)).collect();
        for workers in [1usize, 2, 8] {
            let exec = CompositeExecutor::new(cp.clone(), workers);
            let ys = exec.execute_batch(xs.clone());
            assert_eq!(ys, want, "workers {workers}");
            exec.recycle(ys);
            let ys2 = exec.execute_batch(xs.clone());
            assert_eq!(ys2, want, "workers {workers} with recycled buffers");
        }
    }

    #[test]
    fn window_tiles_account_for_every_placed_tile() {
        let m = synth::qh882_like(5);
        let g = GridSummary::new(&m, 32); // n = 28
        let comp = two_window_composite(28, 14, 18);
        let cp = compile_composite(&m, &g, &comp).unwrap();
        assert_eq!(cp.window_tiles.len(), 2);
        assert_eq!(cp.window_tiles.iter().sum::<usize>(), cp.plan.tiles.len());
    }

    #[test]
    fn invalid_composite_is_rejected() {
        let m = synth::qm7_like(5828);
        let g = GridSummary::new(&m, 2); // n = 11
        let mut comp = two_window_composite(11, 6, 8);
        comp.slices[1].start = 7; // ownership gap
        assert!(compile_composite(&m, &g, &comp).is_err());
    }
}
