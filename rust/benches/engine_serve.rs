//! Bench: the execution engine's serving path vs the oracle simulator.
//!
//! Three rungs per workload, so the report separates the two wins:
//!   oracle_mvm   — CrossbarArray::mvm, every tile walked (the seed path)
//!   plan_mvm     — compiled ExecPlan, single thread (zero-tile elision)
//!   batchN_wW    — BatchExecutor, W workers over N-request batches
//!                  (elision × request parallelism)

use autogmap::crossbar::place;
use autogmap::engine::{compile, BatchExecutor};
use autogmap::graph::{synth, GridSummary};
use autogmap::reorder::{reorder, Reordering};
use autogmap::scheme::Scheme;
use autogmap::util::bench::{black_box, Bencher};
use std::sync::Arc;

fn main() {
    let mut b = Bencher::new();
    for (name, m, grid) in [
        ("qm7_g2", synth::qm7_like(5828), 2usize),
        ("qh882_g32", synth::qh882_like(882), 32),
        ("qh1484_g32", synth::qh1484_like(1484), 32),
    ] {
        let r = reorder(&m, Reordering::CuthillMckee);
        let g = GridSummary::new(&r.matrix, grid);
        // the full-matrix block: complete coverage with maximal dead space,
        // i.e. the workload where compiled elision matters most
        let scheme = Scheme {
            diag_len: vec![g.n],
            fill_len: vec![],
        };
        let arr = place(&r.matrix, &g, &scheme).unwrap();
        let plan = compile(&r.matrix, &g, &scheme).unwrap();
        println!(
            "{name}: {} tiles scheduled, {} placed ({:.1}% elided)",
            plan.scheduled_tiles,
            plan.tiles.len(),
            plan.elision_ratio() * 100.0
        );
        let x: Vec<f64> = (0..g.dim).map(|i| (i as f64 * 0.1).sin()).collect();
        b.bench(&format!("oracle_mvm/{name} ({} tiles)", arr.tiles.len()), || {
            black_box(arr.mvm(&x))
        });
        b.bench(&format!("plan_mvm/{name} ({} tiles)", plan.tiles.len()), || {
            black_box(plan.mvm(&x))
        });
        let plan = Arc::new(plan);
        let batch = 32usize;
        let xs: Vec<Vec<f64>> = (0..batch)
            .map(|s| (0..g.dim).map(|i| ((i + s) as f64 * 0.07).cos()).collect())
            .collect();
        for workers in [2usize, 8] {
            let exec = BatchExecutor::new(plan.clone(), workers);
            exec.recycle(exec.execute_batch(xs.clone())); // warm pool
            let stats = b
                .bench(&format!("batch{batch}_w{workers}/{name}"), || {
                    let ys = exec.execute_batch(xs.clone());
                    exec.recycle(ys);
                })
                .clone();
            println!(
                "  -> {:.0} req/s through {workers} workers",
                batch as f64 / stats.median_s
            );
        }
    }
}
