//! MatrixMarket (.mtx) reader/writer — the interchange format for the
//! qh882/qh1484-class datasets (originally distributed as Harwell-Boeing /
//! MatrixMarket files). Supports `matrix coordinate real|pattern|integer
//! general|symmetric`, which covers every file this repo produces or loads.

use crate::graph::sparse::{Coo, Csr};
use std::io::{BufRead, Write};
use std::path::Path;

#[derive(Debug)]
pub enum MtxError {
    Io(std::io::Error),
    Parse { line: usize, msg: String },
}

impl std::fmt::Display for MtxError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MtxError::Io(e) => write!(f, "mtx io error: {e}"),
            MtxError::Parse { line, msg } => write!(f, "mtx parse error at line {line}: {msg}"),
        }
    }
}

impl std::error::Error for MtxError {}

impl From<std::io::Error> for MtxError {
    fn from(e: std::io::Error) -> Self {
        MtxError::Io(e)
    }
}

fn perr(line: usize, msg: impl Into<String>) -> MtxError {
    MtxError::Parse {
        line,
        msg: msg.into(),
    }
}

/// Read a MatrixMarket coordinate file into CSR. Symmetric files are
/// expanded (both triangles materialized), matching how the paper treats
/// adjacency matrices.
pub fn read(path: &Path) -> Result<Csr, MtxError> {
    let file = std::fs::File::open(path)?;
    read_from(std::io::BufReader::new(file))
}

pub fn read_from<R: BufRead>(reader: R) -> Result<Csr, MtxError> {
    let mut lines = reader.lines().enumerate();

    // Header line.
    let (_, header) = lines
        .next()
        .ok_or_else(|| perr(1, "empty file"))
        .and_then(|(i, l)| l.map(|l| (i, l)).map_err(MtxError::from))?;
    let head: Vec<String> = header.split_whitespace().map(|t| t.to_lowercase()).collect();
    if head.len() < 5 || head[0] != "%%matrixmarket" || head[1] != "matrix" {
        return Err(perr(1, format!("bad header {header:?}")));
    }
    if head[2] != "coordinate" {
        return Err(perr(1, format!("unsupported format {}", head[2])));
    }
    let field = head[3].as_str();
    if !matches!(field, "real" | "pattern" | "integer") {
        return Err(perr(1, format!("unsupported field type {field}")));
    }
    let symmetry = head[4].as_str();
    if !matches!(symmetry, "general" | "symmetric") {
        return Err(perr(1, format!("unsupported symmetry {symmetry}")));
    }

    // Size line (skipping comments).
    let mut size_line = None;
    for (i, line) in lines.by_ref() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        size_line = Some((i + 1, line));
        break;
    }
    let (lineno, size) = size_line.ok_or_else(|| perr(0, "missing size line"))?;
    let dims: Vec<usize> = size
        .split_whitespace()
        .map(|t| t.parse().map_err(|_| perr(lineno, format!("bad size token {t:?}"))))
        .collect::<Result<_, _>>()?;
    if dims.len() != 3 {
        return Err(perr(lineno, "size line must be `rows cols nnz`"));
    }
    let (rows, cols, nnz) = (dims[0], dims[1], dims[2]);

    let mut coo = Coo::new(rows, cols);
    let mut seen = 0usize;
    for (i, line) in lines {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let toks: Vec<&str> = t.split_whitespace().collect();
        let need = if field == "pattern" { 2 } else { 3 };
        if toks.len() < need {
            return Err(perr(i + 1, format!("expected {need} tokens, got {}", toks.len())));
        }
        let r: usize = toks[0]
            .parse()
            .map_err(|_| perr(i + 1, format!("bad row index {:?}", toks[0])))?;
        let c: usize = toks[1]
            .parse()
            .map_err(|_| perr(i + 1, format!("bad col index {:?}", toks[1])))?;
        if r == 0 || c == 0 || r > rows || c > cols {
            return Err(perr(i + 1, format!("index ({r},{c}) out of bounds {rows}x{cols}")));
        }
        let v: f64 = if field == "pattern" {
            1.0
        } else {
            toks[2]
                .parse()
                .map_err(|_| perr(i + 1, format!("bad value {:?}", toks[2])))?
        };
        let (r, c) = (r - 1, c - 1); // 1-based on disk
        if symmetry == "symmetric" {
            if c > r {
                return Err(perr(i + 1, "symmetric file must store lower triangle"));
            }
            coo.push_sym(r, c, v);
        } else {
            coo.push(r, c, v);
        }
        seen += 1;
    }
    if seen != nnz {
        return Err(perr(0, format!("expected {nnz} entries, found {seen}")));
    }
    Ok(coo.to_csr())
}

/// Write CSR as `coordinate real`. If `m` is symmetric, stores the lower
/// triangle with `symmetric` tagging to halve file size (like the originals).
pub fn write(path: &Path, m: &Csr) -> Result<(), MtxError> {
    let sym = m.is_symmetric();
    let file = std::fs::File::create(path)?;
    let mut w = std::io::BufWriter::new(file);
    writeln!(
        w,
        "%%MatrixMarket matrix coordinate real {}",
        if sym { "symmetric" } else { "general" }
    )?;
    writeln!(w, "% generated by autogmap (synthetic dataset)")?;
    let mut entries = Vec::new();
    for r in 0..m.rows {
        for (i, &c) in m.row(r).iter().enumerate() {
            if !sym || c <= r {
                entries.push((r, c, m.row_vals(r)[i]));
            }
        }
    }
    writeln!(w, "{} {} {}", m.rows, m.cols, entries.len())?;
    for (r, c, v) in entries {
        writeln!(w, "{} {} {}", r + 1, c + 1, v)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::sparse::Coo;

    #[test]
    fn roundtrip_general() {
        let mut coo = Coo::new(3, 4);
        coo.push(0, 1, 2.5);
        coo.push(2, 3, -1.0);
        let m = coo.to_csr();
        let dir = std::env::temp_dir().join("autogmap_mtx_test_gen");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.mtx");
        write(&p, &m).unwrap();
        let m2 = read(&p).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn roundtrip_symmetric() {
        let mut coo = Coo::new(5, 5);
        coo.push_sym(0, 4, 1.0);
        coo.push_sym(1, 2, 3.0);
        coo.push(3, 3, 2.0);
        let m = coo.to_csr();
        assert!(m.is_symmetric());
        let dir = std::env::temp_dir().join("autogmap_mtx_test_sym");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("s.mtx");
        write(&p, &m).unwrap();
        // On-disk file must be tagged symmetric and store nnz = 3 entries.
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.contains("symmetric"));
        let m2 = read(&p).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn reads_pattern_files() {
        let text = "%%MatrixMarket matrix coordinate pattern symmetric\n3 3 2\n2 1\n3 3\n";
        let m = read_from(std::io::Cursor::new(text)).unwrap();
        assert_eq!(m.nnz(), 3); // (1,0),(0,1),(2,2)
        assert_eq!(m.get(0, 1), 1.0);
    }

    #[test]
    fn rejects_corrupt_inputs() {
        let cases = [
            "",                                                     // empty
            "%%MatrixMarket matrix array real general\n2 2 0\n",    // array format
            "%%MatrixMarket matrix coordinate real general\n2 2\n", // bad size line
            "%%MatrixMarket matrix coordinate real general\n2 2 1\n3 1 1.0\n", // oob
            "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n", // wrong count
            "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 x 1.0\n", // bad token
            "%%MatrixMarket matrix coordinate real symmetric\n2 2 1\n1 2 1.0\n", // upper tri
        ];
        for text in cases {
            assert!(
                read_from(std::io::Cursor::new(text)).is_err(),
                "should reject {text:?}"
            );
        }
    }

    #[test]
    fn skips_comments_and_blank_lines() {
        let text = "%%MatrixMarket matrix coordinate real general\n% a comment\n\n2 2 1\n% mid\n1 1 5.0\n";
        let m = read_from(std::io::Cursor::new(text)).unwrap();
        assert_eq!(m.get(0, 0), 5.0);
    }
}
