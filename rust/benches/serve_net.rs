//! serve_net bench: stands up the TCP serving tier on a loopback socket
//! with two R-MAT tenants, drives concurrent clients through
//! `run_net_bench` — every response is checked bit-identical against the
//! served deployment's own `mvm`, with a live hot-swap mid-stream — and
//! writes `BENCH_serve_net.json`.
//!
//! `AUTOGMAP_BENCH_FAST=1` shrinks the graphs and request counts for
//! quick local runs.

use autogmap::api::{DeploymentBuilder, Source, Strategy};
use autogmap::net::{run_net_bench, NetBenchOptions};
use std::path::{Path, PathBuf};

fn bundle(dir: &Path, label: &str, nodes: usize, block: usize) -> PathBuf {
    let path = dir.join(format!("{label}.json"));
    let dep = DeploymentBuilder::new(
        Source::Rmat {
            nodes,
            degree: 8,
            seed: 42,
        },
        Strategy::FixedBlock { block },
    )
    .grid(32)
    .workers(4)
    .build()
    .expect("build deployment");
    dep.save(&path).expect("save bundle");
    path
}

fn main() {
    let fast = std::env::var("AUTOGMAP_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let nodes = if fast { 2_000 } else { 10_000 };
    let requests = if fast { 40 } else { 200 };
    let dir = std::env::temp_dir().join("autogmap_bench_serve_net");
    std::fs::create_dir_all(&dir).expect("temp dir");

    eprintln!("serve_net: building three {nodes}-node R-MAT bundles under {}", dir.display());
    let a = bundle(&dir, "graph_a", nodes, 2);
    let b = bundle(&dir, "graph_b", nodes, 4);
    // the swap target remaps the same graph with a different block size:
    // a genuinely different plan that answers the same queries
    let b_remap = bundle(&dir, "graph_b_remap", nodes, 8);

    let opts = NetBenchOptions {
        bundles: vec![("graphA".into(), a), ("graphB".into(), b)],
        listen: "127.0.0.1:0".into(),
        workers: 4,
        queue_depth: 32,
        sharded: true,
        clients: 2,
        requests,
        swap: Some(("graphB".into(), b_remap)),
        seed: 0x5eed,
        bench_json: PathBuf::from("BENCH_serve_net.json"),
    };
    match run_net_bench(&opts) {
        Ok(report) => {
            println!(
                "serve_net: served {} requests across {} tenants in {:.3} s \
                 ({:.0} rps), hot-swap {}; ledger in BENCH_serve_net.json",
                report.served,
                report.tenants,
                report.wall_s,
                report.rps,
                if report.swapped { "verified" } else { "skipped" },
            );
        }
        Err(e) => {
            eprintln!("serve_net bench FAILED: {e}");
            std::process::exit(1);
        }
    }
}
