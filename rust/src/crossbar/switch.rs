//! The switch circuit: input/output permutation around the crossbar array
//! (Fig. 1, Eqs. 2-6).
//!
//! After Cuthill-McKee reordering A' = P A Pᵀ is programmed into the
//! crossbars; at compute time the switch circuit applies x' = P x on the
//! way in and y = Pᵀ y' on the way out, so callers see plain y = A x.

use crate::graph::sparse::perm;

/// A configured switch circuit for one permutation (perm[new] = old).
#[derive(Clone, Debug)]
pub struct SwitchCircuit {
    perm: Vec<usize>,
}

impl SwitchCircuit {
    pub fn new(permutation: Vec<usize>) -> SwitchCircuit {
        assert!(
            perm::is_permutation(&permutation),
            "switch circuit needs a valid permutation"
        );
        SwitchCircuit { perm: permutation }
    }

    pub fn len(&self) -> usize {
        self.perm.len()
    }

    pub fn is_empty(&self) -> bool {
        self.perm.is_empty()
    }

    /// x' = P x (Eq. 4).
    pub fn forward(&self, x: &[f64]) -> Vec<f64> {
        perm::apply(&self.perm, x)
    }

    /// y = Pᵀ y' (Eq. 6).
    pub fn inverse(&self, y: &[f64]) -> Vec<f64> {
        perm::apply_inverse(&self.perm, y)
    }

    /// Number of crossover switch points a crossbar-style permutation
    /// network needs (inversions of the permutation) — a peripheral-cost
    /// proxy for how "far" the reordering scrambles the I/O wiring.
    pub fn crossover_count(&self) -> u64 {
        // O(n log n) inversion count via merge sort
        fn count(xs: &mut Vec<usize>) -> u64 {
            let n = xs.len();
            if n <= 1 {
                return 0;
            }
            let mut right = xs.split_off(n / 2);
            let mut inv = count(xs) + count(&mut right);
            let mut merged = Vec::with_capacity(n);
            let (mut i, mut j) = (0, 0);
            while i < xs.len() && j < right.len() {
                if xs[i] <= right[j] {
                    merged.push(xs[i]);
                    i += 1;
                } else {
                    inv += (xs.len() - i) as u64;
                    merged.push(right[j]);
                    j += 1;
                }
            }
            merged.extend_from_slice(&xs[i..]);
            merged.extend_from_slice(&right[j..]);
            *xs = merged;
            inv
        }
        let mut xs = self.perm.clone();
        count(&mut xs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::check;
    use crate::util::rng::Pcg64;

    #[test]
    fn roundtrip_identity() {
        let sw = SwitchCircuit::new(vec![0, 1, 2, 3]);
        let x = vec![4.0, 3.0, 2.0, 1.0];
        assert_eq!(sw.forward(&x), x);
        assert_eq!(sw.inverse(&x), x);
        assert_eq!(sw.crossover_count(), 0);
    }

    #[test]
    fn forward_then_inverse_is_identity_property() {
        check("switch_roundtrip", 50, |rng| {
            let n = 1 + rng.below(200) as usize;
            let mut p: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut p);
            let sw = SwitchCircuit::new(p);
            let x: Vec<f64> = (0..n).map(|_| rng.uniform(-5.0, 5.0)).collect();
            let back = sw.inverse(&sw.forward(&x));
            if back != x {
                return Err("roundtrip failed".into());
            }
            Ok(())
        });
    }

    #[test]
    fn crossover_count_matches_brute_force() {
        let mut rng = Pcg64::seed_from_u64(7);
        for _ in 0..20 {
            let n = 2 + rng.below(40) as usize;
            let mut p: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut p);
            let sw = SwitchCircuit::new(p.clone());
            let mut brute = 0u64;
            for i in 0..n {
                for j in (i + 1)..n {
                    if p[i] > p[j] {
                        brute += 1;
                    }
                }
            }
            assert_eq!(sw.crossover_count(), brute);
        }
    }

    #[test]
    #[should_panic(expected = "valid permutation")]
    fn rejects_non_permutation() {
        SwitchCircuit::new(vec![0, 0, 1]);
    }
}
