//! Scheme evaluation — the RL *environment* (Table I: Environment = the
//! original matrix; Reward = f(p(x,z))).
//!
//! Implements the paper's metrics:
//!   C_ratio  (Eq. 22) = nnz covered by mapped blocks / total nnz
//!   A_ratio  (Eq. 23) = matrix-unit area of mapped blocks / D²
//!   Sparsity (Eq. 24) = as *reported* by the paper: 1 − nnz/area of the
//!                       mapped blocks (their Eq. prints a density but the
//!                       table rows ≈0.98 on a 0.995-sparse matrix are
//!                       unambiguously 1 − density; we reproduce the table)
//! and the scalarized reward (Eq. 21 with the area term sign-corrected):
//!   R = a · C_ratio + (1−a) · (1 − A_ratio).

use super::parse::Scheme;
use crate::graph::GridSummary;

/// Reward scalarization weights ("Reward ratio a / 1-a" of Tables II/IV).
#[derive(Clone, Copy, Debug)]
pub struct RewardWeights {
    /// Harmonic coefficient a ∈ [0,1]: weight on the coverage ratio.
    pub a: f64,
}

impl RewardWeights {
    pub fn new(a: f64) -> RewardWeights {
        assert!((0.0..=1.0).contains(&a), "reward weight a must be in [0,1]");
        RewardWeights { a }
    }

    /// Scalarize (Eq. 21, area term sign-corrected).
    pub fn reward(&self, coverage: f64, area: f64) -> f64 {
        self.a * coverage + (1.0 - self.a) * (1.0 - area)
    }
}

/// Full evaluation of one scheme against one matrix.
#[derive(Clone, Debug)]
pub struct EvalResult {
    pub coverage_ratio: f64,
    pub area_ratio: f64,
    /// Paper's Table sparsity: 1 − covered_nnz / covered_area.
    pub sparsity: f64,
    pub reward: f64,
    /// Raw counts for downstream consumers (crossbar cost model, logs).
    pub covered_nnz: u64,
    pub covered_area_units: u64,
    pub total_nnz: u64,
    pub num_blocks: usize,
}

/// Evaluate `scheme` on the grid summary of a matrix.
///
/// Blocks never overlap (validated schemes), so coverage is a plain sum.
/// Each block is O(1) via 2-D prefix sums; total O(#blocks).
pub fn evaluate(scheme: &Scheme, g: &GridSummary, w: RewardWeights) -> EvalResult {
    let mut covered_nnz = 0u64;
    let mut covered_area = 0u64;
    let rects = scheme.rects();
    for r in &rects {
        covered_nnz += r.nnz(g);
        covered_area += r.area_units(g);
    }
    let total_nnz = g.total_nnz as u64;
    let dim2 = (g.dim as u64) * (g.dim as u64);
    let coverage_ratio = if total_nnz == 0 {
        1.0
    } else {
        covered_nnz as f64 / total_nnz as f64
    };
    let area_ratio = covered_area as f64 / dim2 as f64;
    let sparsity = if covered_area == 0 {
        0.0
    } else {
        1.0 - covered_nnz as f64 / covered_area as f64
    };
    EvalResult {
        coverage_ratio,
        area_ratio,
        sparsity,
        reward: w.reward(coverage_ratio, area_ratio),
        covered_nnz,
        covered_area_units: covered_area,
        total_nnz,
        num_blocks: rects.len(),
    }
}

/// Evaluate an arbitrary *disjoint* rectangle set (used by the GraphSAR /
/// GraphR baselines whose blocks are not diagonal+fill structured).
pub fn evaluate_rects(
    rects: &[super::GridRect],
    g: &GridSummary,
    w: RewardWeights,
) -> EvalResult {
    let mut covered_nnz = 0u64;
    let mut covered_area = 0u64;
    for r in rects {
        covered_nnz += r.nnz(g);
        covered_area += r.area_units(g);
    }
    let total_nnz = g.total_nnz as u64;
    let dim2 = (g.dim as u64) * (g.dim as u64);
    let coverage_ratio = if total_nnz == 0 {
        1.0
    } else {
        covered_nnz as f64 / total_nnz as f64
    };
    let area_ratio = covered_area as f64 / dim2 as f64;
    EvalResult {
        coverage_ratio,
        area_ratio,
        sparsity: if covered_area == 0 {
            0.0
        } else {
            1.0 - covered_nnz as f64 / covered_area as f64
        },
        reward: w.reward(coverage_ratio, area_ratio),
        covered_nnz,
        covered_area_units: covered_area,
        total_nnz,
        num_blocks: rects.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::sparse::Coo;
    use crate::graph::synth;
    use crate::scheme::parse::{parse_actions, FillRule};
    use crate::util::propcheck::check;
    use crate::util::rng::Pcg64;

    fn grid_of(m: &crate::graph::Csr, k: usize) -> GridSummary {
        GridSummary::new(m, k)
    }

    #[test]
    fn full_matrix_block_covers_everything() {
        let m = synth::qm7_like(5828);
        let g = grid_of(&m, 2);
        let s = Scheme {
            diag_len: vec![11],
            fill_len: vec![],
        };
        let e = evaluate(&s, &g, RewardWeights::new(0.8));
        assert_eq!(e.coverage_ratio, 1.0);
        assert_eq!(e.area_ratio, 1.0);
        // reward = 0.8*1 + 0.2*0
        assert!((e.reward - 0.8).abs() < 1e-12);
        // paper: "Sparsity of original matrix: 0.868"
        assert!((e.sparsity - m.sparsity()).abs() < 1e-12);
    }

    #[test]
    fn diagonal_band_matrix_perfect_unit_blocks() {
        // pure diagonal matrix: unit blocks give full coverage at area N·k²/D².
        let mut coo = Coo::new(8, 8);
        for i in 0..8 {
            coo.push(i, i, 1.0);
        }
        let m = coo.to_csr();
        let g = grid_of(&m, 2);
        let s = parse_actions(4, &[0, 0, 0], &[0, 0, 0], FillRule::Dynamic { grades: 4 });
        let e = evaluate(&s, &g, RewardWeights::new(0.5));
        assert_eq!(e.coverage_ratio, 1.0);
        assert!((e.area_ratio - (4.0 * 4.0) / 64.0).abs() < 1e-12);
        assert_eq!(e.num_blocks, 4);
    }

    #[test]
    fn fill_blocks_pick_up_junction_nnz() {
        // entry exactly at the junction corner: (1,2) with grid 1, blocks [2,2].
        let mut coo = Coo::new(4, 4);
        coo.push_sym(1, 2, 1.0);
        coo.push(0, 0, 1.0);
        coo.push(3, 3, 1.0);
        let m = coo.to_csr();
        let g = grid_of(&m, 1);
        let no_fill = parse_actions(4, &[1, 0, 1], &[0, 0, 0], FillRule::None);
        let e0 = evaluate(&no_fill, &g, RewardWeights::new(0.8));
        assert!(e0.coverage_ratio < 1.0);
        let with_fill = parse_actions(4, &[1, 0, 1], &[0, 1, 0], FillRule::Fixed { size: 1 });
        let e1 = evaluate(&with_fill, &g, RewardWeights::new(0.8));
        assert_eq!(e1.coverage_ratio, 1.0);
        assert!(e1.area_ratio > e0.area_ratio);
    }

    #[test]
    fn truncated_trailing_block_area() {
        // dim 5, grid 2 -> N=3, last cell is 1 unit wide.
        let mut coo = Coo::new(5, 5);
        for i in 0..5 {
            coo.push(i, i, 1.0);
        }
        let m = coo.to_csr();
        let g = grid_of(&m, 2);
        let s = parse_actions(3, &[0, 0], &[0, 0], FillRule::None);
        let e = evaluate(&s, &g, RewardWeights::new(1.0));
        // areas: 2² + 2² + 1² = 9 over 25
        assert!((e.area_ratio - 9.0 / 25.0).abs() < 1e-12);
        assert_eq!(e.coverage_ratio, 1.0);
    }

    #[test]
    fn reward_monotonicity() {
        let w = RewardWeights::new(0.7);
        assert!(w.reward(1.0, 0.2) > w.reward(0.9, 0.2)); // more coverage better
        assert!(w.reward(1.0, 0.2) > w.reward(1.0, 0.4)); // less area better
        // a=1 ignores area
        let w1 = RewardWeights::new(1.0);
        assert_eq!(w1.reward(0.5, 0.1), w1.reward(0.5, 0.9));
    }

    #[test]
    #[should_panic]
    fn reward_weight_out_of_range_panics() {
        RewardWeights::new(1.5);
    }

    #[test]
    fn coverage_bounds_property() {
        check("eval_bounds", 60, |rng| {
            let dim = 8 + rng.below(120) as usize;
            let grid = 1 + rng.below(8) as usize;
            let mut coo = Coo::new(dim, dim);
            for _ in 0..dim * 2 {
                let a = rng.below(dim as u64) as usize;
                let b = rng.below(dim as u64) as usize;
                coo.push_sym(a.max(b), a.min(b), 1.0);
            }
            let m = coo.to_csr();
            let g = GridSummary::new(&m, grid);
            let n = g.n;
            let d: Vec<u8> = (0..n - 1).map(|_| rng.below(2) as u8).collect();
            let f: Vec<usize> = (0..n - 1).map(|_| rng.below(4) as usize).collect();
            let s = parse_actions(n, &d, &f, FillRule::Dynamic { grades: 4 });
            s.validate(n)?;
            let e = evaluate(&s, &g, RewardWeights::new(0.75));
            if !(0.0..=1.0 + 1e-12).contains(&e.coverage_ratio) {
                return Err(format!("coverage {} out of bounds", e.coverage_ratio));
            }
            if !(0.0..=1.0 + 1e-12).contains(&e.area_ratio) {
                return Err(format!("area {} out of bounds", e.area_ratio));
            }
            // single full block must dominate any scheme's coverage
            let full = Scheme { diag_len: vec![n], fill_len: vec![] };
            let ef = evaluate(&full, &g, RewardWeights::new(0.75));
            if ef.coverage_ratio < e.coverage_ratio - 1e-12 {
                return Err("full block not max coverage".into());
            }
            Ok(())
        });
    }

    #[test]
    fn union_area_equals_sum_property() {
        // blocks never overlap, so Σ area computed here must equal the area
        // of the union measured by brute-force rasterization.
        check("eval_union_area", 30, |rng| {
            let dim = 6 + rng.below(40) as usize;
            let grid = 1 + rng.below(4) as usize;
            let mut coo = Coo::new(dim, dim);
            coo.push(0, 0, 1.0);
            let m = coo.to_csr();
            let g = GridSummary::new(&m, grid);
            let n = g.n;
            if n < 2 {
                return Ok(());
            }
            let d: Vec<u8> = (0..n - 1).map(|_| rng.below(2) as u8).collect();
            let f: Vec<usize> = (0..n - 1).map(|_| rng.below(6) as usize).collect();
            let s = parse_actions(n, &d, &f, FillRule::Dynamic { grades: 6 });
            let e = evaluate(&s, &g, RewardWeights::new(0.5));
            // rasterize
            let mut mask = vec![false; dim * dim];
            for r in s.rects() {
                let r0 = (r.r0 * grid).min(dim);
                let r1 = (r.r1 * grid).min(dim);
                let c0 = (r.c0 * grid).min(dim);
                let c1 = (r.c1 * grid).min(dim);
                for rr in r0..r1 {
                    for cc in c0..c1 {
                        if mask[rr * dim + cc] {
                            return Err(format!("overlap at ({rr},{cc})"));
                        }
                        mask[rr * dim + cc] = true;
                    }
                }
            }
            let union: u64 = mask.iter().filter(|&&b| b).count() as u64;
            if union != e.covered_area_units {
                return Err(format!(
                    "union {union} != sum {} (dim {dim} grid {grid})",
                    e.covered_area_units
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn complete_coverage_schemes_cover_every_nnz_property() {
        // any scheme whose blocks rasterize over all nnz must report C=1;
        // conversely C=1 means every nnz lies inside some block.
        check("eval_complete_coverage", 30, |rng| {
            let dim = 10 + rng.below(50) as usize;
            let mut coo = Coo::new(dim, dim);
            for _ in 0..dim {
                let a = rng.below(dim as u64) as usize;
                let b = rng.below(dim as u64) as usize;
                coo.push_sym(a.max(b), a.min(b), 1.0);
            }
            let m = coo.to_csr();
            let g = GridSummary::new(&m, 2);
            let n = g.n;
            let d: Vec<u8> = (0..n - 1).map(|_| rng.below(2) as u8).collect();
            let s = parse_actions(n, &d, &[], FillRule::None);
            let e = evaluate(&s, &g, RewardWeights::new(0.9));
            // brute-force check
            let mut covered = 0u64;
            for r in 0..dim {
                for &c in m.row(r) {
                    let inside = s.rects().iter().any(|rect| {
                        let (r0, r1) = ((rect.r0 * 2).min(dim), (rect.r1 * 2).min(dim));
                        let (c0, c1) = ((rect.c0 * 2).min(dim), (rect.c1 * 2).min(dim));
                        r >= r0 && r < r1 && c >= c0 && c < c1
                    });
                    if inside {
                        covered += 1;
                    }
                }
            }
            let expect = covered as f64 / m.nnz() as f64;
            if (expect - e.coverage_ratio).abs() > 1e-9 {
                return Err(format!("coverage {} != brute {expect}", e.coverage_ratio));
            }
            Ok(())
        });
    }
}
