//! Integration tests for the graph-algorithm layer: PageRank invariants
//! (mass conservation every sweep, worker-count determinism, CSR-oracle
//! agreement at identical iteration counts), BFS/SSSP bit-exactness
//! against queue/Dijkstra references on random R-MAT graphs, the GCN
//! forward within 1e-5 of the dense oracle — on flat and composite plans,
//! in both executor modes — and the NDJSON wire surface through the stdin
//! serve loop (payloads, traces, typed errors, per-algorithm stats).

use autogmap::algo::{
    bfs, bfs_reference, gcn_forward, max_abs_diff, normalized_adjacency, pagerank, sssp,
    sssp_reference, BfsOptions, CsrEngine, DeploymentEngine, GcnLayer, PageRankOptions,
    PlanEngine, SsspOptions,
};
use autogmap::api::{serve_loop, Deployment, DeploymentBuilder, ServeOptions, Source, Strategy};
use autogmap::engine::{self, ExecPlan};
use autogmap::graph::{synth, Csr, GridSummary};
use autogmap::scheme::Scheme;
use autogmap::util::json::Json;
use autogmap::util::propcheck::check;
use autogmap::util::rng::Pcg64;
use std::io::Cursor;
use std::sync::Arc;

/// A fixed-block composite deployment over `m` — the facade path with the
/// RCM permutation applied around every request.
fn composite(m: &Csr, block: usize, grid: usize) -> Deployment {
    DeploymentBuilder::new(
        Source::Matrix { label: "algo_test".into(), matrix: m.clone() },
        Strategy::FixedBlock { block },
    )
    .grid(grid)
    .workers(2)
    .build()
    .unwrap()
}

/// A flat full-coverage `ExecPlan` over `m` on its own executor — no
/// permutation, no facade.
fn flat_engine(m: &Csr, grid: usize, workers: usize, sharded: bool) -> PlanEngine<ExecPlan> {
    let g = GridSummary::new(m, grid);
    let scheme = Scheme { diag_len: vec![g.n], fill_len: vec![] };
    let plan = engine::compile(m, &g, &scheme).unwrap();
    PlanEngine::new(Arc::new(plan), workers, sharded)
}

/// PageRank on a mapped plan: probability mass is conserved at every
/// sweep count, ranks are bit-identical across 1/2/8 workers and both
/// executor modes, and agree with the host-CSR run of the same loop to
/// 1e-8 at identical iteration counts.
#[test]
fn pagerank_conserves_mass_and_is_worker_deterministic_property() {
    check("algo_pagerank_invariants", 4, |rng| {
        let n = 60 + rng.below(60) as usize;
        let target = n * (3 + rng.below(3) as usize) / 2 * 2;
        let m = synth::rmat_like(n, target, 0x9a9e + rng.below(1 << 20));
        let dep = composite(&m, 1 + rng.below(3) as usize, 8);

        // tol = 0 runs exactly k sweeps; Σp must stay 1 after every k
        for k in [1usize, 3, 7] {
            let opts = PageRankOptions { damping: 0.85, tol: 0.0, max_iters: k };
            let exec = dep.executor(2);
            let eng = DeploymentEngine::new(&dep, &exec, true);
            let (p, trace) = pagerank(&eng, &opts).map_err(|e| e.to_string())?;
            if trace.iterations != k {
                return Err(format!("expected {k} sweeps, trace says {}", trace.iterations));
            }
            let mass: f64 = p.iter().sum();
            if (mass - 1.0).abs() > 1e-9 {
                return Err(format!("mass {mass} after {k} sweeps"));
            }
        }

        let opts = PageRankOptions { damping: 0.85, tol: 0.0, max_iters: 15 };
        let (want, _) = pagerank(&CsrEngine(&m), &opts).map_err(|e| e.to_string())?;
        let mut first: Option<Vec<f64>> = None;
        for workers in [1usize, 2, 8] {
            for sharded in [true, false] {
                let exec = dep.executor(workers);
                let eng = DeploymentEngine::new(&dep, &exec, sharded);
                let (p, _) = pagerank(&eng, &opts).map_err(|e| e.to_string())?;
                let d = max_abs_diff(&p, &want);
                if d > 1e-8 {
                    return Err(format!(
                        "workers {workers} sharded {sharded}: mapped ranks diverge from \
                         the CSR run by {d:e}"
                    ));
                }
                match &first {
                    None => first = Some(p),
                    Some(f) => {
                        if *f != p {
                            return Err(format!(
                                "ranks depend on the executor config (workers {workers}, \
                                 sharded {sharded})"
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

/// BFS levels and SSSP distances from mapped plans are bit-identical to
/// the queue/Dijkstra references, for random sources, every chunking,
/// both plan shapes, both executor modes, and 1/8 workers.
#[test]
fn traversals_match_queue_references_exactly_property() {
    check("algo_traversals_bit_exact", 4, |rng| {
        let n = 50 + rng.below(70) as usize;
        let target = n * 3 / 2 * 2;
        let m = synth::rmat_like(n, target, 0xb0b + rng.below(1 << 20));
        let dep = composite(&m, 2, 8);
        let flat = flat_engine(&m, 8, 2, true);

        for _ in 0..3 {
            let src = rng.below(n as u64) as usize;
            let want_bfs = bfs_reference(&m, src);
            let want_sssp = sssp_reference(&m, src);
            let chunk = [0usize, 1, 5][rng.below(3) as usize];

            for workers in [1usize, 8] {
                for sharded in [true, false] {
                    let exec = dep.executor(workers);
                    let eng = DeploymentEngine::new(&dep, &exec, sharded);
                    let (lv, _) = bfs(&eng, &BfsOptions { source: src, max_levels: 0 })
                        .map_err(|e| e.to_string())?;
                    if lv != want_bfs {
                        return Err(format!(
                            "bfs(src {src}, workers {workers}, sharded {sharded}) is not \
                             bit-identical to the queue reference"
                        ));
                    }
                    let (d, _) = sssp(&eng, &SsspOptions { source: src, max_iters: 0, chunk })
                        .map_err(|e| e.to_string())?;
                    if d != want_sssp {
                        return Err(format!(
                            "sssp(src {src}, chunk {chunk}, workers {workers}, sharded \
                             {sharded}) is not bit-identical to Dijkstra"
                        ));
                    }
                }
            }

            let (lv, _) = bfs(&flat, &BfsOptions { source: src, max_levels: 0 })
                .map_err(|e| e.to_string())?;
            if lv != want_bfs {
                return Err(format!("flat-plan bfs(src {src}) diverged from the reference"));
            }
            let (d, _) = sssp(&flat, &SsspOptions { source: src, max_iters: 0, chunk })
                .map_err(|e| e.to_string())?;
            if d != want_sssp {
                return Err(format!("flat-plan sssp(src {src}) diverged from Dijkstra"));
            }
        }
        Ok(())
    });
}

/// The multi-layer GCN forward: bit-near the chained dense oracle on the
/// host CSR, and within 1e-5 on both mapped plan shapes (the normalized
/// adjacency's values exercise the f32 program arena) at every worker
/// count and both executor modes.
#[test]
fn gcn_forward_matches_dense_oracle_on_both_plan_shapes() {
    let a = synth::rmat_like(120, 480, 9);
    let nrm = normalized_adjacency(&a);
    let layers = vec![
        GcnLayer::random(6, 8, true, 1),
        GcnLayer::random(8, 3, false, 2),
    ];
    let mut rng = Pcg64::seed_from_u64(5);
    let x: Vec<f64> = (0..120 * 6).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let want = layers[1].forward_dense(&nrm, &layers[0].forward_dense(&nrm, &x));

    // the iterated form over the host CSR is the same float program as
    // the chained dense oracle
    let (host, trace) = gcn_forward(&CsrEngine(&nrm), &x, &layers).unwrap();
    assert!(max_abs_diff(&host, &want) <= 1e-12);
    assert_eq!(trace.iterations, 2, "one iteration per layer");
    assert_eq!(trace.mvms, 8 + 3, "one MVM per output column per layer");
    assert_eq!(trace.residuals.len(), 2);

    let dep = composite(&nrm, 2, 8);
    for workers in [1usize, 2, 8] {
        for sharded in [true, false] {
            let exec = dep.executor(workers);
            let eng = DeploymentEngine::new(&dep, &exec, sharded);
            let (got, _) = gcn_forward(&eng, &x, &layers).unwrap();
            let d = max_abs_diff(&got, &want);
            assert!(
                d <= 1e-5,
                "composite gcn (workers {workers}, sharded {sharded}) off by {d:e}"
            );
        }
    }
    let flat = flat_engine(&nrm, 8, 2, true);
    let (got, _) = gcn_forward(&flat, &x, &layers).unwrap();
    let d = max_abs_diff(&got, &want);
    assert!(d <= 1e-5, "flat gcn off by {d:e}");
}

/// The stdin serve loop answers all four request kinds with payloads and
/// embedded traces that match direct library runs, rejects bad
/// parameters and non-convergence with typed errors that never kill the
/// loop, and reports the per-algorithm mix in the stats line and the
/// final report.
#[test]
fn serve_loop_answers_algo_requests_with_traces_and_stats() {
    let m = synth::rmat_like(60, 240, 3);
    let dep = composite(&m, 2, 8);
    let n = 60usize;

    let mut input = String::new();
    input.push_str(r#"{"id":1,"pagerank":{"damping":0.85,"tol":1e-10,"max_iters":500}}"#);
    input.push('\n');
    input.push_str(r#"{"id":2,"bfs":{"source":0}}"#);
    input.push('\n');
    input.push_str(r#"{"id":3,"sssp":{"source":0,"chunk":5}}"#);
    input.push('\n');
    // gcn: 2 features per node, one 3-wide relu layer (seed defaults to
    // the layer index, matching GcnLayer::random(2, 3, true, 0))
    let x_rows: Vec<Json> = (0..n)
        .map(|r| Json::Arr(vec![Json::Num(r as f64 * 0.01), Json::Num(1.0 - r as f64 * 0.02)]))
        .collect();
    input.push_str(
        &format!(
            r#"{{"id":4,"gcn":{{"x":{},"layers":[{{"out_dim":3}}]}}}}"#,
            Json::Arr(x_rows).to_string()
        ),
    );
    input.push('\n');
    // typed failures: bad parameter, then guaranteed non-convergence
    input.push_str(r#"{"id":5,"pagerank":{"damping":1.5}}"#);
    input.push('\n');
    input.push_str(r#"{"id":6,"pagerank":{"tol":0.000001,"max_iters":1}}"#);
    input.push('\n');

    let opts = ServeOptions { workers: 2, stats_every: 0, ..ServeOptions::default() };
    let mut out: Vec<u8> = Vec::new();
    let report = serve_loop(&dep, &opts, Cursor::new(input), &mut out).unwrap();
    assert_eq!(report.served, 4);
    assert_eq!(report.errors, 2);
    assert_eq!(report.algo.pagerank, 1);
    assert_eq!(report.algo.bfs, 1);
    assert_eq!(report.algo.sssp, 1);
    assert_eq!(report.algo.gcn, 1);
    assert!(report.algo.mvms > 3, "algorithm runs fan out into many MVMs");

    let text = String::from_utf8(out).unwrap();
    let docs: Vec<Json> = text.lines().map(|l| Json::parse(l).unwrap()).collect();
    fn by_id(docs: &[Json], id: i64) -> &Json {
        docs.iter()
            .find(|d| d.get("id").as_i64() == Some(id))
            .unwrap_or_else(|| panic!("no response for id {id}"))
    }

    // pagerank: scores sum to 1, trace converged, matches the direct run
    let pr = by_id(&docs, 1).get("pagerank");
    let scores: Vec<f64> =
        pr.get("scores").as_arr().unwrap().iter().map(|v| v.as_f64().unwrap()).collect();
    assert_eq!(scores.len(), n);
    let mass: f64 = scores.iter().sum();
    assert!((mass - 1.0).abs() < 1e-9, "wire scores carry mass {mass}");
    assert_eq!(pr.get("trace").get("converged").as_bool(), Some(true));
    {
        let exec = dep.executor(2);
        let eng = DeploymentEngine::new(&dep, &exec, true);
        let opts = PageRankOptions { damping: 0.85, tol: 1e-10, max_iters: 500 };
        let (direct, _) = pagerank(&eng, &opts).unwrap();
        assert_eq!(scores, direct, "wire run and library run are the same floats");
    }

    // bfs: levels bit-identical to the queue reference
    let lv: Vec<i64> = by_id(&docs, 2)
        .get("bfs")
        .get("levels")
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_i64().unwrap())
        .collect();
    assert_eq!(lv, bfs_reference(&m, 0));
    let reached = by_id(&docs, 2).get("bfs").get("reached").as_i64().unwrap();
    assert_eq!(reached, lv.iter().filter(|&&l| l >= 0).count() as i64);

    // sssp: -1 encodes unreachable; finite entries match Dijkstra exactly
    let wire_dist: Vec<f64> = by_id(&docs, 3)
        .get("sssp")
        .get("dist")
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap())
        .collect();
    let want: Vec<f64> = sssp_reference(&m, 0)
        .into_iter()
        .map(|d| if d.is_finite() { d } else { -1.0 })
        .collect();
    assert_eq!(wire_dist, want);

    // gcn: one 3-wide layer over the served matrix, verified against the
    // same deterministic layer construction
    let feats = by_id(&docs, 4).get("gcn").get("features").as_arr().unwrap();
    assert_eq!(feats.len(), n);
    let got: Vec<f64> = feats
        .iter()
        .flat_map(|row| row.as_arr().unwrap().iter().map(|v| v.as_f64().unwrap()))
        .collect();
    let x_flat: Vec<f64> = (0..n)
        .flat_map(|r| [r as f64 * 0.01, 1.0 - r as f64 * 0.02])
        .collect();
    let layer = GcnLayer::random(2, 3, true, 0);
    let want = layer.forward_dense(&m, &x_flat);
    assert!(max_abs_diff(&got, &want) <= 1e-5);

    // typed failures name the field / report the residual
    let bad = by_id(&docs, 5).get("error");
    assert_eq!(bad.get("kind").as_str(), Some("validate"));
    assert!(bad.get("message").as_str().unwrap().contains("pagerank.damping"));
    let nc = by_id(&docs, 6).get("error");
    assert_eq!(nc.get("kind").as_str(), Some("no_converge"));
    let msg = nc.get("message").as_str().unwrap();
    assert!(msg.contains("pagerank") && msg.contains("max_iters"), "{msg}");

    // the stats line carries the per-algorithm mix
    let stats = docs.iter().rev().find(|d| d.get("stats") != &Json::Null).unwrap().get("stats");
    assert_eq!(stats.get("algo").get("pagerank").as_i64(), Some(1));
    assert_eq!(stats.get("algo").get("gcn").as_i64(), Some(1));
    assert_eq!(stats.get("served").as_i64(), Some(4));
    assert_eq!(stats.get("errors").as_i64(), Some(2));
}
