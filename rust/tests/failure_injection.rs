//! Failure injection: every external input (files, configs, artifacts,
//! parameter blobs) must fail loudly and descriptively, never corrupt a
//! run silently.

use autogmap::agent::params;
use autogmap::coordinator::config::ExperimentConfig;
use autogmap::graph::matrix_market;
use autogmap::runtime::manifest::Manifest;
use autogmap::runtime::Runtime;
use autogmap::util::json::Json;
use std::io::Write;
use std::path::PathBuf;

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("autogmap_fail_{name}"));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn truncated_mtx_rejected() {
    let d = tmpdir("mtx");
    let p = d.join("trunc.mtx");
    // header promises 5 entries, file has 2
    std::fs::write(
        &p,
        "%%MatrixMarket matrix coordinate real general\n10 10 5\n1 1 1.0\n2 2 2.0\n",
    )
    .unwrap();
    let err = matrix_market::read(&p).unwrap_err();
    assert!(format!("{err}").contains("expected 5 entries"));
}

#[test]
fn binary_garbage_mtx_rejected() {
    let d = tmpdir("mtx_bin");
    let p = d.join("garbage.mtx");
    let mut f = std::fs::File::create(&p).unwrap();
    f.write_all(&[0u8, 159, 146, 150, 255, 254, 10, 13]).unwrap();
    drop(f);
    assert!(matrix_market::read(&p).is_err());
}

#[test]
fn missing_artifact_file_reports_path() {
    let d = tmpdir("artifacts_missing");
    let rt = Runtime::new(&d).unwrap();
    let err = rt.load("rollout_nope.hlo.txt").err().expect("must fail");
    let msg = format!("{err:#}");
    assert!(msg.contains("rollout_nope.hlo.txt"), "{msg}");
}

#[test]
fn corrupt_hlo_text_rejected() {
    let d = tmpdir("artifacts_corrupt");
    std::fs::write(d.join("bad.hlo.txt"), "HloModule this is not hlo (((").unwrap();
    let rt = Runtime::new(&d).unwrap();
    assert!(rt.load("bad.hlo.txt").is_err());
}

#[test]
fn manifest_missing_and_malformed() {
    let d = tmpdir("manifest");
    let rt = Runtime::new(&d).unwrap();
    assert!(rt.manifest().is_err()); // missing

    std::fs::write(d.join("manifest.json"), "{ not json").unwrap();
    assert!(rt.manifest().is_err()); // malformed

    // structurally valid JSON but missing required fields
    std::fs::write(
        d.join("manifest.json"),
        r#"{"configs": {"x": {"n": 3, "params": [{"name": "p"}]}}}"#,
    )
    .unwrap();
    assert!(rt.manifest().is_err());
}

#[test]
fn manifest_param_shape_mismatch_rejected_at_literal_build() {
    let text = r#"{
      "fingerprint": "x",
      "configs": {
        "c": {
          "n": 3, "hidden": 2, "fill_classes": 0, "batch": 1,
          "bilstm": false, "steps": 2,
          "params": [{"name": "x0", "shape": [2]}],
          "artifacts": {}
        }
      },
      "mvm": {}
    }"#;
    let m = Manifest::parse(text).unwrap();
    let entry = m.config("c").unwrap();
    // params with the wrong number of elements must be rejected
    let mut p = params::init_params(entry, 0);
    p.get_mut("x0").unwrap().push(1.0);
    assert!(params::to_literals(entry, &p).is_err());
    // missing param must be rejected
    let mut p2 = params::init_params(entry, 0);
    p2.remove("x0");
    assert!(params::to_literals(entry, &p2).is_err());
}

#[test]
fn corrupt_checkpoint_rejected() {
    let text = r#"{
      "fingerprint": "x",
      "configs": {
        "c": {
          "n": 3, "hidden": 2, "fill_classes": 0, "batch": 1,
          "bilstm": false, "steps": 2,
          "params": [{"name": "x0", "shape": [2]}],
          "artifacts": {}
        }
      },
      "mvm": {}
    }"#;
    let m = Manifest::parse(text).unwrap();
    let entry = m.config("c").unwrap();
    let d = tmpdir("ckpt");
    // not json
    std::fs::write(d.join("ck1.json"), "garbage").unwrap();
    assert!(params::load_checkpoint(&d.join("ck1.json"), entry).is_err());
    // wrong shapes
    std::fs::write(
        d.join("ck2.json"),
        r#"{"config":"c","params":{"x0":[1.0]},"m":{"x0":[0,0]},"v":{"x0":[0,0]},"t":0}"#,
    )
    .unwrap();
    assert!(params::load_checkpoint(&d.join("ck2.json"), entry).is_err());
}

#[test]
fn experiment_config_validation() {
    // reward out of range
    let bad = Json::parse(
        r#"{"name":"x","dataset":"qm7","grid":2,"controller":"c","reward_a":2.0}"#,
    )
    .unwrap();
    assert!(ExperimentConfig::from_json(&bad).is_err());
    // unknown dataset
    let bad = Json::parse(r#"{"name":"x","dataset":"wat","grid":2,"controller":"c"}"#).unwrap();
    assert!(ExperimentConfig::from_json(&bad).is_err());
    // unknown fill kind
    let bad = Json::parse(
        r#"{"name":"x","dataset":"qm7","grid":2,"controller":"c","fill":"maybe"}"#,
    )
    .unwrap();
    assert!(ExperimentConfig::from_json(&bad).is_err());
    // missing file
    assert!(ExperimentConfig::load(std::path::Path::new("/nope/cfg.json")).is_err());
}

#[test]
fn nan_rewards_cannot_enter_the_reward_path() {
    // RewardWeights::new rejects out-of-range a; evaluate() on empty
    // matrices defines coverage := 1 (no NaN).
    let m = autogmap::graph::Coo::new(8, 8).to_csr();
    let g = autogmap::graph::GridSummary::new(&m, 2);
    let s = autogmap::scheme::Scheme {
        diag_len: vec![4],
        fill_len: vec![],
    };
    let e = autogmap::scheme::evaluate(&s, &g, autogmap::scheme::RewardWeights::new(0.5));
    assert!(e.reward.is_finite());
    assert_eq!(e.coverage_ratio, 1.0);
    assert_eq!(e.sparsity, 1.0); // all-zero block: fully sparse, not NaN
}
