//! Sparse-storage cost model — the paper's stated future work ("the fusion
//! of the automatic mapping scheme and the sparse storage (CSC, CSR,
//! COO)") and the axis GraphR [1] reports on (0.2% of original size with
//! COO on WikiVote).
//!
//! Computes the byte cost of holding a matrix (or the *uncovered remainder*
//! of a mapping scheme) in each classic compressed format, so experiments
//! can compare "crossbar cells spent" against "bytes spilled to digital
//! storage" for partial-coverage schemes.

use crate::graph::{Csr, GridSummary};
use crate::scheme::Scheme;

/// Byte costs of one matrix in each storage format.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StorageCost {
    pub dense_bytes: u64,
    pub coo_bytes: u64,
    pub csr_bytes: u64,
    pub csc_bytes: u64,
}

/// Index width in bytes needed for dimension `dim`.
fn idx_bytes(dim: usize) -> u64 {
    if dim <= u16::MAX as usize {
        2
    } else {
        4
    }
}

/// Storage costs for a full matrix at `value_bytes` per stored value
/// (4 = f32 weights; 0 = pattern-only adjacency, indices still stored).
pub fn storage_cost(m: &Csr, value_bytes: u64) -> StorageCost {
    let nnz = m.nnz() as u64;
    let (rows, cols) = (m.rows as u64, m.cols as u64);
    let ib = idx_bytes(m.rows.max(m.cols));
    StorageCost {
        dense_bytes: rows * cols * value_bytes.max(1), // dense materializes every value
        coo_bytes: nnz * (2 * ib + value_bytes),
        csr_bytes: (rows + 1) * 8 + nnz * (ib + value_bytes),
        csc_bytes: (cols + 1) * 8 + nnz * (ib + value_bytes),
    }
}

/// COO byte cost of holding `nnz` spilled entries of a `dim`-dimensional
/// matrix digitally — the composite mapper's off-window remainder
/// ([`crate::scheme::CompositeScheme`]) uses the same per-entry pricing as
/// [`storage_cost`].
pub fn coo_spill_bytes(nnz: u64, dim: usize, value_bytes: u64) -> u64 {
    nnz * (2 * idx_bytes(dim) + value_bytes)
}

/// Non-zeros NOT covered by `scheme` (the digital-spill set for a
/// partial-coverage mapping), counted via the grid summary.
pub fn uncovered_nnz(scheme: &Scheme, g: &GridSummary) -> u64 {
    let covered: u64 = scheme.rects().iter().map(|r| r.nnz(g)).sum();
    g.total_nnz as u64 - covered
}

/// Hybrid deployment cost: crossbar cells for the mapped blocks plus COO
/// bytes for the uncovered remainder — the quantity a deployment planner
/// would actually minimize when complete coverage is not mandated.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HybridCost {
    pub crossbar_cells: u64,
    pub spilled_nnz: u64,
    pub spill_coo_bytes: u64,
}

pub fn hybrid_cost(scheme: &Scheme, g: &GridSummary, value_bytes: u64) -> HybridCost {
    let cells: u64 = scheme.rects().iter().map(|r| r.area_units(g)).sum();
    let spilled = uncovered_nnz(scheme, g);
    let ib = idx_bytes(g.dim);
    HybridCost {
        crossbar_cells: cells,
        spilled_nnz: spilled,
        spill_coo_bytes: spilled * (2 * ib + value_bytes),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::synth;
    use crate::scheme::{parse_actions, FillRule};

    #[test]
    fn compressed_formats_beat_dense_on_sparse() {
        let m = synth::qh882_like(882);
        let c = storage_cost(&m, 4);
        assert!(c.coo_bytes < c.dense_bytes / 50, "coo {} dense {}", c.coo_bytes, c.dense_bytes);
        assert!(c.csr_bytes < c.coo_bytes); // row pointers amortize
        assert_eq!(c.csr_bytes, c.csc_bytes); // square symmetric
    }

    #[test]
    fn index_width_switches_at_u16_boundary() {
        assert_eq!(idx_bytes(65_535), 2);
        assert_eq!(idx_bytes(65_536), 4);
    }

    #[test]
    fn spill_bytes_match_coo_pricing() {
        // 16-bit indices below 64k nodes, 32-bit above; f32 values
        assert_eq!(coo_spill_bytes(10, 1000, 4), 10 * 8);
        assert_eq!(coo_spill_bytes(10, 100_000, 4), 10 * 12);
        let m = synth::qh882_like(882);
        let c = storage_cost(&m, 4);
        assert_eq!(coo_spill_bytes(m.nnz() as u64, 882, 4), c.coo_bytes);
    }

    #[test]
    fn full_coverage_spills_nothing() {
        let m = synth::qm7_like(5828);
        let g = GridSummary::new(&m, 2);
        let s = Scheme { diag_len: vec![g.n], fill_len: vec![] };
        assert_eq!(uncovered_nnz(&s, &g), 0);
        let h = hybrid_cost(&s, &g, 4);
        assert_eq!(h.spilled_nnz, 0);
        assert_eq!(h.spill_coo_bytes, 0);
        assert_eq!(h.crossbar_cells, 22 * 22);
    }

    #[test]
    fn partial_coverage_spill_is_consistent() {
        let m = synth::qm7_like(5828);
        let g = GridSummary::new(&m, 2);
        // unit diagonal blocks, no fill: off-diagonal nnz spill
        let s = parse_actions(g.n, &[0; 10], &[0; 10], FillRule::None);
        let spilled = uncovered_nnz(&s, &g);
        assert!(spilled > 0);
        let e = crate::scheme::evaluate(&s, &g, crate::scheme::RewardWeights::new(0.5));
        let expect = (m.nnz() as f64 * (1.0 - e.coverage_ratio)).round() as u64;
        assert_eq!(spilled, expect);
        let h = hybrid_cost(&s, &g, 4);
        assert_eq!(h.spill_coo_bytes, spilled * 8); // 2×u16 idx + f32
    }

    #[test]
    fn hybrid_tradeoff_moves_monotonically() {
        // growing diagonal blocks covers more (less spill) at more cells
        let m = synth::qh882_like(882);
        let g = GridSummary::new(&m, 32);
        let mut last_cells = 0;
        let mut last_spill = u64::MAX;
        for blk in [1usize, 2, 4, 7] {
            let s = crate::baselines::vanilla(g.n, blk);
            let h = hybrid_cost(&s, &g, 4);
            assert!(h.crossbar_cells >= last_cells);
            assert!(h.spilled_nnz <= last_spill);
            last_cells = h.crossbar_cells;
            last_spill = h.spilled_nnz;
        }
    }
}
