//! The long-running serve loop: NDJSON requests in, NDJSON responses and
//! periodic stats out.
//!
//! One JSON object per input line:
//!
//! - `{"id": <any json>, "x": [f64; dim]}` — a single MVM request. The
//!   response is `{"id": ..., "y": [...]}`.
//! - `{"id": ..., "xs": [[f64; dim], ...]}` — an explicit batch, executed
//!   as one dispatch; the response is `{"id": ..., "ys": [[...], ...]}`.
//! - `{"id": ..., "pagerank": {...}}` / `{"bfs": {...}}` / `{"sssp":
//!   {...}}` / `{"gcn": {...}}` — whole graph-algorithm runs
//!   ([`crate::algo`]) answered as `{"id": ..., "<kind>": {..., "trace":
//!   {...}}}`; parameters and payloads are documented in
//!   [`crate::api::dispatch::parse_algo`] and mirrored by the TCP tier.
//! - `{"id": ..., "update": {"edges": [[r, c, w], ...]}}` — dynamic-graph
//!   edge mutations ([`crate::delta`]; `w == 0` deletes). The first update
//!   attaches a [`crate::delta::DeltaEngine`] over the deployment; from
//!   then on every MVM answer is `y = (A ± Δ)x` over the mutated graph,
//!   and with [`ServeOptions::remap_after`] > 0 the engine folds the
//!   accumulated delta into a freshly mapped plan every N updates. The
//!   response is `{"id": ..., "update": {"applied", "pending",
//!   "generation"}}`. Two delta-mode caveats: MVMs bypass the ABFT fault
//!   harness (the overlay path has no checksum column), and
//!   whole-algorithm runs execute on the last *folded* plan — edge
//!   updates still pending the next remap are not visible to them.
//! - `{"flush": true}` — force the coalescing window to dispatch now.
//!
//! Single requests coalesce into executor batches of up to
//! [`ServeOptions::batch_window`] requests (the window also flushes on an
//! explicit batch, a `flush` command, and end of input), so a pipe of many
//! one-line requests still gets multi-RHS batching. Responses are written
//! in request order at each flush. The default window is 1 — every request
//! answers immediately; coalescing is opt-in (`--batch-window N`) because
//! a part-filled window waits for more input, which would deadlock an
//! interactive client that blocks on the response before sending more.
//!
//! Bad input never kills the loop: a line that fails to parse or validate
//! gets a machine-readable `{"id": ..., "error": {"kind": "parse" |
//! "validate", "message": ...}}` response (kinds are
//! [`crate::api::Error::kind`]) and serving continues. Blank lines are
//! skipped, and a line longer than [`ServeOptions::max_line_bytes`] is
//! drained with a bounded read and answered with a `parse` error instead
//! of buffering without limit. Execution runs behind a panic boundary
//! ([`crate::api::dispatch::catch_internal`]): a worker-pool panic is
//! answered as a typed `internal` error echoing the request id(s), and
//! the loop keeps serving. Only transport failures (the input or output
//! stream dying) end the loop with an [`Error::Io`].
//!
//! When the deployment carries an armed fault harness
//! ([`crate::fault::FaultHarness`]), every MVM is checksum-verified and
//! any response served under a degraded epoch carries `"degraded": true`;
//! the stats line gains a `"health"` object mirroring the TCP tier's.
//!
//! The parsing, validation, execution, and error-formatting primitives
//! live in [`crate::api::dispatch`], shared with the multi-tenant network
//! tier ([`crate::net`]) — both transports answer with byte-identical
//! error objects by construction.
//!
//! Every [`ServeOptions::stats_every`] served requests — and always once
//! at end of input — the loop emits `{"stats": {"served", "errors",
//! "batches", "rps", "nnz_per_s", "shards", "workers", "wall_s", "algo":
//! {"pagerank", "bfs", "sssp", "gcn", "mvms"}}}` so operators can watch
//! throughput (including the per-algorithm request mix) without parsing
//! responses.

use super::deploy::Deployment;
use super::dispatch::{self, BoundedLine};
use super::error::{Error, Result};
use crate::algo::AlgoCounters;
use crate::delta::DeltaEngine;
use crate::engine::{BatchExecutor, Servable};
use crate::util::json::{num_arr, obj, Json};
use crate::util::pool::WorkerPool;
use std::io::{BufRead, Write};
use std::sync::Arc;
use std::time::Instant;

/// Serve-loop configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    /// executor worker threads; 0 = the deployment's default
    pub workers: usize,
    /// max single requests coalesced into one executor dispatch
    pub batch_window: usize,
    /// emit a stats line every N served requests (0 = only at end of input)
    pub stats_every: usize,
    /// band-sharded multi-RHS serving (false = scalar per-request mode)
    pub sharded: bool,
    /// cap on one NDJSON request line; longer lines are drained and
    /// rejected with a `parse` error
    pub max_line_bytes: usize,
    /// auto-fold the dynamic-graph delta into a fresh plan after this
    /// many accumulated edge updates (0 = only on explicit request; only
    /// meaningful once an `update` request attached the delta engine)
    pub remap_after: usize,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            workers: 0,
            batch_window: 1,
            stats_every: 100,
            sharded: true,
            max_line_bytes: dispatch::DEFAULT_MAX_LINE_BYTES,
            remap_after: 0,
        }
    }
}

/// What a finished serve loop did.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ServeReport {
    pub served: u64,
    pub errors: u64,
    pub batches: u64,
    pub wall_seconds: f64,
    pub rps: f64,
    pub nnz_per_s: f64,
    /// graph-algorithm requests served, by kind (an algorithm run counts
    /// once in `served` however many MVMs it issued)
    pub algo: AlgoCounters,
}

/// Run the serve loop over a deployment until `input` ends. Returns the
/// aggregate report (also emitted as the final stats line on `out`).
pub fn serve_loop<R: BufRead, W: Write>(
    dep: &Deployment,
    opts: &ServeOptions,
    mut input: R,
    out: &mut W,
) -> Result<ServeReport> {
    let exec = dep.executor(opts.workers);
    let dim = dep.plan().dim();
    let plan_nnz = dep.plan().nnz();
    let shards = dep.plan().shard_spans(exec.workers()).len();
    let window = opts.batch_window.max(1);
    let max_line = opts.max_line_bytes.max(1);

    let mut pending_ids: Vec<Json> = Vec::new();
    let mut pending_xs: Vec<Vec<f64>> = Vec::new();
    let mut served = 0u64;
    let mut errors = 0u64;
    let mut batches = 0u64;
    let mut algo = AlgoCounters::default();
    let mut next_stats = match opts.stats_every {
        0 => u64::MAX,
        n => n as u64,
    };
    let t0 = Instant::now();
    // attached by the first `update` request; from then on MVMs serve the
    // mutated graph exactly (plan + overlay)
    let mut delta: Option<Arc<DeltaEngine>> = None;

    let emit_stats = |out: &mut W,
                      served: u64,
                      errors: u64,
                      batches: u64,
                      algo: &AlgoCounters,
                      delta: Option<&DeltaEngine>|
     -> Result<()> {
        let wall = t0.elapsed().as_secs_f64();
        let rps = served as f64 / wall.max(1e-9);
        let mut fields = vec![
            ("served", Json::Num(served as f64)),
            ("errors", Json::Num(errors as f64)),
            ("batches", Json::Num(batches as f64)),
            ("rps", Json::Num(rps)),
            ("nnz_per_s", Json::Num(rps * plan_nnz as f64)),
            ("shards", Json::Num(shards as f64)),
            ("workers", Json::Num(exec.workers() as f64)),
            ("wall_s", Json::Num(wall)),
            ("algo", algo.to_json()),
        ];
        if let Some(h) = dep.fault_harness() {
            fields.push(("health", dispatch::health_json(&h.health())));
        }
        if let Some(eng) = delta {
            fields.push(("delta", dispatch::delta_stats_json(eng)));
        }
        let line = obj(vec![("stats", obj(fields))]);
        writeln!(out, "{}", line.to_string())?;
        out.flush()?;
        Ok(())
    };

    loop {
        let line = match read_framed(&mut input, max_line)? {
            BoundedLine::Eof => break,
            BoundedLine::TooLong { limit } => {
                errors += 1;
                let err =
                    Error::Parse(format!("request line exceeds the {limit}-byte limit"));
                write_error(out, Json::Null, &err)?;
                continue;
            }
            BoundedLine::Line(l) => l,
        };
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let doc = match Json::parse(trimmed) {
            Ok(d) => d,
            Err(e) => {
                errors += 1;
                write_error(out, Json::Null, &Error::Parse(e.to_string()))?;
                continue;
            }
        };
        let id = doc.get("id").clone();

        if doc.get("flush").as_bool() == Some(true) {
            flush_pending(
                dep,
                &exec,
                opts.sharded,
                delta.as_deref(),
                &mut pending_ids,
                &mut pending_xs,
                &mut served,
                &mut errors,
                &mut batches,
                out,
            )?;
        } else if let Some(req) = match dispatch::parse_update(&doc) {
            Ok(r) => r,
            Err(e) => {
                errors += 1;
                write_error(out, id, &e)?;
                continue;
            }
        } {
            // dispatch pending singles first: their answers reflect the
            // graph as it stood when they were accepted
            flush_pending(
                dep,
                &exec,
                opts.sharded,
                delta.as_deref(),
                &mut pending_ids,
                &mut pending_xs,
                &mut served,
                &mut errors,
                &mut batches,
                out,
            )?;
            let eng = match &delta {
                Some(eng) => eng.clone(),
                None => {
                    // first update: attach the delta engine (reconstructs
                    // the host base CSR and warms the scheme cache)
                    let pool = Arc::new(WorkerPool::new(exec.workers().max(1)));
                    match dispatch::catch_internal(|| DeltaEngine::attach(dep.clone(), pool)) {
                        Ok(eng) => {
                            delta = Some(eng.clone());
                            eng
                        }
                        Err(e) => {
                            errors += 1;
                            write_error(out, id, &e)?;
                            continue;
                        }
                    }
                }
            };
            match eng.apply(&req.edges) {
                Ok(mut ack) => {
                    served += 1;
                    if opts.remap_after > 0
                        && eng.updates_since_remap() >= opts.remap_after as u64
                    {
                        match dispatch::catch_internal(|| eng.remap()) {
                            Ok(_) => {
                                ack.pending = eng.pending();
                                ack.generation = eng.generation();
                            }
                            Err(e) => {
                                errors += 1;
                                write_error(out, id, &e)?;
                                continue;
                            }
                        }
                    }
                    write_response(
                        out,
                        obj(vec![("id", id), ("update", dispatch::update_ack_obj(&ack))]),
                    )?;
                    out.flush()?;
                }
                Err(e) => {
                    errors += 1;
                    write_error(out, id, &e)?;
                }
            }
        } else if let Some(req) = match dispatch::parse_algo(&doc, dim) {
            Ok(r) => r,
            Err(e) => {
                errors += 1;
                write_error(out, id, &e)?;
                continue;
            }
        } {
            // a whole-algorithm run: dispatch pending singles first so
            // responses stay in request order, then iterate to completion
            flush_pending(
                dep,
                &exec,
                opts.sharded,
                delta.as_deref(),
                &mut pending_ids,
                &mut pending_xs,
                &mut served,
                &mut errors,
                &mut batches,
                out,
            )?;
            // in delta mode, run against the engine's current (folded)
            // deployment — generation-correct across remap swaps, though
            // overlay entries still pending the next remap are not seen
            let answer = match delta.as_deref() {
                Some(eng) => {
                    let snap = eng.deployment();
                    let ex = BatchExecutor::with_pool(snap.plan_arc(), eng.pool.clone());
                    dispatch::catch_internal(|| dispatch::run_algo(&snap, &ex, opts.sharded, &req))
                }
                None => {
                    dispatch::catch_internal(|| dispatch::run_algo(dep, &exec, opts.sharded, &req))
                }
            };
            match answer {
                Ok(ans) => {
                    algo.record(ans.key, ans.mvms);
                    served += 1;
                    batches += 1;
                    let mut fields = vec![("id", id), (ans.key, ans.payload)];
                    if ans.degraded {
                        fields.push(("degraded", Json::Bool(true)));
                    }
                    write_response(out, obj(fields))?;
                    out.flush()?;
                }
                Err(e) => {
                    errors += 1;
                    write_error(out, id, &e)?;
                }
            }
        } else if doc.get("xs") != &Json::Null {
            // explicit batch: dispatch pending singles first so responses
            // stay in request order, then run the batch as one dispatch
            flush_pending(
                dep,
                &exec,
                opts.sharded,
                delta.as_deref(),
                &mut pending_ids,
                &mut pending_xs,
                &mut served,
                &mut errors,
                &mut batches,
                out,
            )?;
            let xs = match dispatch::parse_batch(doc.get("xs"), dim) {
                Ok(xs) => xs,
                Err(e) => {
                    errors += 1;
                    write_error(out, id, &e)?;
                    continue;
                }
            };
            let n = xs.len() as u64;
            let result = match delta.as_deref() {
                Some(eng) => {
                    dispatch::catch_internal(|| Ok((eng.execute(&xs, opts.sharded)?, false)))
                }
                None => dispatch::catch_internal(|| {
                    Ok(dispatch::execute_verified(dep, &exec, xs, opts.sharded))
                }),
            };
            match result {
                Ok((ys, degraded)) => {
                    batches += 1;
                    served += n;
                    let ys_json = Json::Arr(ys.into_iter().map(num_arr).collect());
                    let mut fields = vec![("id", id), ("ys", ys_json)];
                    if degraded {
                        fields.push(("degraded", Json::Bool(true)));
                    }
                    write_response(out, obj(fields))?;
                    out.flush()?;
                }
                Err(e) => {
                    errors += 1;
                    write_error(out, id, &e)?;
                }
            }
        } else {
            match dispatch::parse_vec(doc.get("x"), dim) {
                Ok(x) => {
                    pending_ids.push(id);
                    pending_xs.push(x);
                    if pending_xs.len() >= window {
                        flush_pending(
                            dep,
                            &exec,
                            opts.sharded,
                            delta.as_deref(),
                            &mut pending_ids,
                            &mut pending_xs,
                            &mut served,
                            &mut errors,
                            &mut batches,
                            out,
                        )?;
                    }
                }
                Err(e) => {
                    errors += 1;
                    write_error(out, id, &e)?;
                }
            }
        }

        if served >= next_stats {
            emit_stats(out, served, errors, batches, &algo, delta.as_deref())?;
            next_stats = served + opts.stats_every.max(1) as u64;
        }
    }

    flush_pending(
        dep,
        &exec,
        opts.sharded,
        delta.as_deref(),
        &mut pending_ids,
        &mut pending_xs,
        &mut served,
        &mut errors,
        &mut batches,
        out,
    )?;
    emit_stats(out, served, errors, batches, &algo, delta.as_deref())?;

    let wall = t0.elapsed().as_secs_f64();
    let rps = served as f64 / wall.max(1e-9);
    Ok(ServeReport {
        served,
        errors,
        batches,
        wall_seconds: wall,
        rps,
        nnz_per_s: rps * plan_nnz as f64,
        algo,
    })
}

/// One bounded framing step with transport failures mapped to the typed
/// [`Error::Io`] that ends the loop.
fn read_framed<R: BufRead>(input: &mut R, max_line: usize) -> Result<BoundedLine> {
    dispatch::read_line_bounded(input, max_line)
        .map_err(|e| Error::Io(format!("reading request stream: {e}")))
}

#[allow(clippy::too_many_arguments)]
fn flush_pending<W: Write>(
    dep: &Deployment,
    exec: &crate::engine::BatchExecutor<super::deploy::DeployedPlan>,
    sharded: bool,
    delta: Option<&DeltaEngine>,
    ids: &mut Vec<Json>,
    xs: &mut Vec<Vec<f64>>,
    served: &mut u64,
    errors: &mut u64,
    batches: &mut u64,
    out: &mut W,
) -> Result<()> {
    if xs.is_empty() {
        return Ok(());
    }
    let reqs = std::mem::take(xs);
    let ids_now = std::mem::take(ids);
    let result = match delta {
        // delta mode: the engine serves the mutated graph (plan + overlay)
        // on its own generation-current executor
        Some(eng) => dispatch::catch_internal(|| Ok((eng.execute(&reqs, sharded)?, false))),
        None => {
            dispatch::catch_internal(|| Ok(dispatch::execute_verified(dep, exec, reqs, sharded)))
        }
    };
    match result {
        Ok((ys, degraded)) => {
            *batches += 1;
            *served += ys.len() as u64;
            for (id, y) in ids_now.into_iter().zip(ys) {
                let mut fields = vec![("id", id), ("y", num_arr(y))];
                if degraded {
                    fields.push(("degraded", Json::Bool(true)));
                }
                write_response(out, obj(fields))?;
            }
        }
        Err(e) => {
            // the panic boundary: every coalesced request gets a typed
            // `internal` error echoing its own id, and the loop lives on
            *errors += ids_now.len() as u64;
            for id in ids_now {
                write_response(out, dispatch::error_line(id, &e))?;
            }
        }
    }
    out.flush()?;
    Ok(())
}

fn write_response<W: Write>(out: &mut W, doc: Json) -> Result<()> {
    writeln!(out, "{}", doc.to_string())?;
    Ok(())
}

fn write_error<W: Write>(out: &mut W, id: Json, err: &Error) -> Result<()> {
    writeln!(out, "{}", dispatch::error_line(id, err).to_string())?;
    out.flush()?;
    Ok(())
}
