//! Std-thread worker pool: the crate's shared fan-out substrate, used by
//! the native trainer (sampling rollouts, per-episode BPTT) and the
//! serving engine's [`crate::engine::batch::BatchExecutor`]. Jobs are
//! type-erased closures pulled from a shared deque by persistent workers;
//! results land in submission order, so downstream reductions are
//! deterministic regardless of worker count or scheduling, and a
//! panicking job is re-raised on the caller instead of hanging the run.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct QueueState {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

struct Queue {
    state: Mutex<QueueState>,
    cv: Condvar,
}

struct Sink<T> {
    remaining: usize,
    out: Vec<Option<std::thread::Result<T>>>,
}

/// Persistent worker pool; threads live as long as the pool, so per-epoch
/// dispatch costs one lock + notify per job, not a thread spawn.
pub struct WorkerPool {
    queue: Arc<Queue>,
    workers: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    pub fn new(workers: usize) -> WorkerPool {
        assert!(workers >= 1, "pool needs at least one worker");
        let queue = Arc::new(Queue {
            state: Mutex::new(QueueState {
                jobs: VecDeque::new(),
                shutdown: false,
            }),
            cv: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|w| {
                let q = queue.clone();
                std::thread::Builder::new()
                    .name(format!("pool-worker-{w}"))
                    .spawn(move || worker_loop(q))
                    .expect("spawning pool worker")
            })
            .collect();
        WorkerPool {
            queue,
            workers: handles,
        }
    }

    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Run every job to completion; returns results in job order.
    ///
    /// A panicking job does not hang the pool: the panic is caught on the
    /// worker, carried through the sink, and re-raised on the calling
    /// thread once all jobs have settled.
    pub fn run<T, F>(&self, jobs: Vec<F>) -> Vec<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let n = jobs.len();
        if n == 0 {
            return Vec::new();
        }
        let sink = Arc::new((
            Mutex::new(Sink::<T> {
                remaining: n,
                out: (0..n).map(|_| None).collect(),
            }),
            Condvar::new(),
        ));
        {
            let mut st = self.queue.state.lock().unwrap();
            for (i, job) in jobs.into_iter().enumerate() {
                let sink = sink.clone();
                st.jobs.push_back(Box::new(move || {
                    let v = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                    let (lock, cv) = &*sink;
                    let mut s = lock.lock().unwrap();
                    s.out[i] = Some(v);
                    s.remaining -= 1;
                    if s.remaining == 0 {
                        cv.notify_all();
                    }
                }));
            }
        }
        self.queue.cv.notify_all();
        let (lock, cv) = &*sink;
        let mut s = lock.lock().unwrap();
        while s.remaining > 0 {
            s = cv.wait(s).unwrap();
        }
        s.out
            .iter_mut()
            .map(|o| match o.take().unwrap() {
                Ok(v) => v,
                Err(payload) => std::panic::resume_unwind(payload),
            })
            .collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.queue.state.lock().unwrap();
            st.shutdown = true;
        }
        self.queue.cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(q: Arc<Queue>) {
    loop {
        let job = {
            let mut st = q.state.lock().unwrap();
            loop {
                if let Some(j) = st.jobs.pop_front() {
                    break j;
                }
                if st.shutdown {
                    return;
                }
                st = q.cv.wait(st).unwrap();
            }
        };
        job();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_keep_submission_order() {
        let pool = WorkerPool::new(4);
        let jobs: Vec<_> = (0..64u64)
            .map(|i| {
                move || {
                    // stagger finish times so out-of-order completion is likely
                    if i % 7 == 0 {
                        std::thread::sleep(std::time::Duration::from_millis(1));
                    }
                    i * i
                }
            })
            .collect();
        let out = pool.run(jobs);
        let want: Vec<u64> = (0..64).map(|i| i * i).collect();
        assert_eq!(out, want);
    }

    #[test]
    fn empty_job_list_is_a_noop() {
        let pool = WorkerPool::new(1);
        let out: Vec<u32> = pool.run(Vec::<fn() -> u32>::new());
        assert!(out.is_empty());
    }

    #[test]
    fn panicking_job_propagates_instead_of_hanging() {
        let pool = WorkerPool::new(2);
        let jobs: Vec<Box<dyn FnOnce() -> u32 + Send>> = vec![
            Box::new(|| 1),
            Box::new(|| panic!("job blew up")),
            Box::new(|| 3),
        ];
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| pool.run(jobs)));
        assert!(result.is_err(), "panic must surface to the caller");
        // the pool is still serviceable afterwards
        let out = pool.run(vec![Box::new(|| 7u32) as Box<dyn FnOnce() -> u32 + Send>]);
        assert_eq!(out, vec![7]);
    }

    #[test]
    fn pool_survives_many_rounds() {
        let pool = WorkerPool::new(2);
        for round in 0..50u32 {
            let jobs: Vec<_> = (0..8u32).map(|i| move || i + round).collect();
            let out = pool.run(jobs);
            assert_eq!(out.len(), 8);
            assert_eq!(out[3], 3 + round);
        }
        assert_eq!(pool.workers(), 2);
    }
}
