//! Bench: native-backend training throughput — sampling rollouts at
//! 1/2/8 workers and full epochs (rollout + BPTT + Adam) per paper
//! workload class. Runs on a fresh checkout (no artifacts needed); the
//! `train-bench` CLI subcommand emits the machine-readable counterpart
//! (BENCH_train.json).

use autogmap::agent::{BackendKind, NativeBackend, TrainBackend, TrainOptions};
use autogmap::coordinator::config::Dataset;
use autogmap::coordinator::dataset::load_matrix;
use autogmap::coordinator::runner::build_trainer;
use autogmap::graph::GridSummary;
use autogmap::reorder::{reorder, Reordering};
use autogmap::runtime::Manifest;
use autogmap::scheme::{FillRule, RewardWeights};
use autogmap::util::bench::Bencher;

fn main() {
    let mut b = Bencher::new();
    let manifest = Manifest::builtin();
    let specs: [(&str, Dataset, usize, &str, FillRule); 3] = [
        (
            "qm7",
            Dataset::Qm7 { seed: 5828 },
            2,
            "qm7_dyn4",
            FillRule::Dynamic { grades: 4 },
        ),
        (
            "qh882",
            Dataset::Qh882 { seed: 882 },
            32,
            "qh882_dyn6",
            FillRule::Dynamic { grades: 6 },
        ),
        (
            "qh1484",
            Dataset::Qh1484 { seed: 1484 },
            32,
            "qh1484_dyn6",
            FillRule::Dynamic { grades: 6 },
        ),
    ];
    for (label, ds, grid_size, controller, rule) in specs {
        let m = load_matrix(&ds).unwrap();
        let r = reorder(&m, Reordering::CuthillMckee);
        let grid = GridSummary::new(&r.matrix, grid_size);
        let entry = manifest.config(controller).unwrap().clone();
        let batch = entry.batch;

        // sampling-only throughput across worker counts
        for workers in [1usize, 2, 8] {
            let mut be = NativeBackend::new(entry.clone(), 1, workers);
            let mut key = 0u32;
            let stats = b.bench(
                &format!("native_rollout/{label} (B={batch}) w={workers}"),
                || {
                    key = key.wrapping_add(1);
                    be.rollout([key, 0x5eed]).unwrap()
                },
            );
            println!(
                "  -> {:.0} episodes/s",
                batch as f64 / stats.median_s
            );
        }

        // full epoch: rollout + environment + BPTT + Adam
        let opts = TrainOptions {
            weights: RewardWeights::new(0.8),
            fill_rule: rule,
            workers: 2,
            ..Default::default()
        };
        let mut trainer = build_trainer(None, controller, opts, BackendKind::Native).unwrap();
        let stats = b.bench(&format!("native_epoch/{label} (w=2)"), || {
            trainer.epoch(&grid).unwrap()
        });
        println!(
            "  -> {:.0} epochs/s ({:.0} episodes/s); paper's 40k-epoch budget ≈ {:.0}s at this rate",
            1.0 / stats.median_s,
            batch as f64 / stats.median_s,
            40_000.0 * stats.median_s
        );
    }
}
