//! Batch-graphs scenario: the paper's second motivating workload —
//! "batch graphs computing, in which the adjacency matrices are usually
//! integrated into a large-scale super-matrix, with only the sub-graphs
//! being internally connected".
//!
//! Builds a block-diagonal super-matrix of several molecule graphs and
//! shows that (a) naive whole-matrix mapping wastes quadratically more
//! crossbar area as the batch grows, (b) the DP-oracle / vanilla / RL-free
//! diagonal partitions recover the per-graph structure automatically after
//! Cuthill-McKee, and (c) the evaluation machinery quantifies the gap.
//!
//! Run: `cargo run --release --example batch_graphs` (no artifacts needed)

use autogmap::baselines::{self, oracle};
use autogmap::graph::{synth, GridSummary};
use autogmap::reorder::{reorder, Reordering};
use autogmap::scheme::{evaluate, RewardWeights, Scheme};

fn main() -> anyhow::Result<()> {
    let w = RewardWeights::new(0.8);
    println!(
        "{:<8} {:>6} {:>8} | {:>14} {:>14} {:>14} {:>18}",
        "batch", "dim", "nnz", "full-map A", "vanilla-8 A/C", "graphsar A", "DP-oracle A (C=1)"
    );
    for batch in [1usize, 2, 4, 8, 16] {
        let graphs: Vec<_> = (0..batch)
            .map(|i| synth::qm7_like(5828 + i as u64))
            .collect();
        let sm = synth::batch_supermatrix(&graphs);
        let r = reorder(&sm, Reordering::CuthillMckee);
        let g = GridSummary::new(&r.matrix, 2);

        // naive: one giant crossbar for the whole super-matrix
        let full = Scheme { diag_len: vec![g.n], fill_len: vec![] };
        let e_full = evaluate(&full, &g, w);

        // vanilla fixed blocks (8 matrix units = 4 grid cells)
        let v = baselines::vanilla(g.n, 4);
        let e_v = evaluate(&v, &g, w);

        // sparsity-aware whole-matrix partition
        let sar = baselines::graphsar(&g, 8);
        let e_sar = autogmap::scheme::eval::evaluate_rects(&sar, &g, w);

        // optimal diagonal-only complete coverage: should track the
        // per-graph diagonal structure (area ~ 1/batch of the full map)
        let orc = oracle::optimal_diagonal(&g).expect("oracle");
        let e_orc = evaluate(&orc, &g, w);
        assert_eq!(e_orc.coverage_ratio, 1.0);

        println!(
            "{:<8} {:>6} {:>8} | {:>14.3} {:>8.3}/{:<5.3} {:>14.3} {:>10.3} ({} blocks)",
            batch,
            sm.rows,
            sm.nnz(),
            e_full.area_ratio,
            e_v.area_ratio,
            e_v.coverage_ratio,
            e_sar.area_ratio,
            e_orc.area_ratio,
            orc.diag_len.len(),
        );
    }
    println!(
        "\nThe full-map area ratio is constant (=1) but its absolute cell count grows \
         quadratically with batch size;\nthe oracle's per-graph blocks keep absolute cost \
         linear — the utilization argument of the paper's introduction."
    );

    // absolute-cell view for the largest batch
    let graphs: Vec<_> = (0..16).map(|i| synth::qm7_like(5828 + i as u64)).collect();
    let sm = synth::batch_supermatrix(&graphs);
    let r = reorder(&sm, Reordering::CuthillMckee);
    let g = GridSummary::new(&r.matrix, 2);
    let orc = oracle::optimal_diagonal(&g).unwrap();
    let e = evaluate(&orc, &g, w);
    let full_cells = (sm.rows * sm.rows) as f64;
    println!(
        "batch 16: full map {} cells vs oracle {} cells — {:.1}× saving at complete coverage",
        full_cells,
        e.covered_area_units,
        full_cells / e.covered_area_units as f64
    );
    Ok(())
}
