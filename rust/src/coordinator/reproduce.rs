//! Reproduction drivers: regenerate every table and figure of the paper's
//! evaluation section (see DESIGN.md §7 for the experiment index).
//!
//! Each driver prints the same rows/series the paper reports, side by side
//! with the paper's own numbers where they exist. Absolute agreement is
//! not expected on the qh-matrices (ours are structure-matched synthetics,
//! DESIGN.md §6) — the comparison target is the *shape*: who wins, by
//! roughly what factor, where the trade-offs move as a/grades change.

use super::config::{Dataset, ExperimentConfig};
use super::dataset::{load_matrix, prepare};
use super::runner::{run_experiment, RunnerOptions};
use crate::agent::complexity::complexity;
use crate::baselines;
use crate::graph::GridSummary;
use crate::reorder::{reorder, Reordering};
use crate::runtime::{Manifest, Runtime};
use crate::scheme::{evaluate, eval::evaluate_rects, EvalResult, FillRule, RewardWeights, Scheme};
use crate::viz;
use anyhow::Result;
use std::path::{Path, PathBuf};

/// One printed row of Table II/IV.
struct Row {
    method: String,
    config: String,
    a: Option<f64>,
    diag: Vec<usize>,
    fill: Vec<usize>,
    coverage: f64,
    area: f64,
    sparsity: f64,
    paper: Option<(f64, f64)>, // paper (coverage, area) for the analogous row
}

fn print_rows(title: &str, rows: &[Row]) {
    println!("\n=== {title} ===");
    println!(
        "{:<26} {:<14} {:>5}  {:>8} {:>8} {:>8}  {:>8} {:>8}  {}",
        "method", "config", "a", "C_ratio", "A_ratio", "sparsity", "paper_C", "paper_A", "blocks (diag | fill)"
    );
    for r in rows {
        let (pc, pa) = r
            .paper
            .map(|(c, a)| (format!("{c:.3}"), format!("{a:.3}")))
            .unwrap_or_else(|| ("-".into(), "-".into()));
        println!(
            "{:<26} {:<14} {:>5}  {:>8.3} {:>8.3} {:>8.3}  {:>8} {:>8}  {:?} | {:?}",
            r.method,
            r.config,
            r.a.map(|a| format!("{a:.2}")).unwrap_or_else(|| "-".into()),
            r.coverage,
            r.area,
            r.sparsity,
            pc,
            pa,
            r.diag,
            r.fill,
        );
    }
}

fn eval_to_row(
    method: &str,
    config: &str,
    a: Option<f64>,
    scheme: &Scheme,
    grid: &GridSummary,
    eval: &EvalResult,
    paper: Option<(f64, f64)>,
) -> Row {
    Row {
        method: method.to_string(),
        config: config.to_string(),
        a,
        diag: scheme.diag_sizes_units(grid),
        fill: scheme.fill_len.clone(),
        coverage: eval.coverage_ratio,
        area: eval.area_ratio,
        sparsity: eval.sparsity,
        paper,
    }
}

/// RL training rows share this helper: run one experiment, convert the
/// best complete-coverage solution to a table row. Backend selection and
/// worker count ride along in `opts`.
#[allow(clippy::too_many_arguments)]
fn rl_row(
    rt: Option<&Runtime>,
    method: &str,
    dataset: Dataset,
    grid: usize,
    controller: &str,
    fill_rule: FillRule,
    a: f64,
    epochs: usize,
    seed: u64,
    opts: &RunnerOptions,
    paper: Option<(f64, f64)>,
) -> Result<(Row, super::runner::RunResult)> {
    let cfg = ExperimentConfig {
        name: format!("{controller}_a{:02}_s{seed}", (a * 100.0) as u32),
        dataset,
        grid,
        reordering: Reordering::CuthillMckee,
        controller: controller.to_string(),
        fill_rule,
        reward_a: a,
        lr: 0.015,
        ent_coef: 0.002,
        baseline_decay: 0.95,
        epochs,
        seed,
        log_every: (epochs / 200).max(1),
    };
    let result = run_experiment(rt, &cfg, opts)?;
    // Diagonal-only rows mirror the paper: the reported solution is the
    // best-by-reward one, which may be incomplete (paper Table II shows
    // C=0.875/0.938 for LSTM+RL). Fill rows report the best complete-
    // coverage solution, falling back to best-by-reward.
    let pick = if fill_rule == FillRule::None {
        result.best_reward.as_ref().or(result.best.as_ref())
    } else {
        result.best.as_ref().or(result.best_reward.as_ref())
    };
    let row = match pick {
        Some(b) => eval_to_row(
            method,
            &cfg.controller,
            Some(a),
            &b.scheme,
            &result.workload.grid,
            &b.eval,
            paper,
        ),
        None => {
            // fall back to the full block so the table always has a row
            let w = prepare(&cfg)?;
            let full = Scheme { diag_len: vec![w.grid.n], fill_len: vec![] };
            let e = evaluate(&full, &w.grid, cfg.weights());
            eval_to_row(method, &cfg.controller, Some(a), &full, &w.grid, &e, paper)
        }
    };
    Ok((row, result))
}

// ---------------------------------------------------------------------------
// Table II — QM7-5828 comparison + ablation

pub fn table2(rt: Option<&Runtime>, epochs: usize, opts: &RunnerOptions) -> Result<()> {
    let m = load_matrix(&Dataset::Qm7 { seed: 5828 })?;
    let r = reorder(&m, Reordering::CuthillMckee);
    let w = RewardWeights::new(0.8);
    let mut rows = Vec::new();

    // --- Vanilla (fixed-size diagonal blocks, matrix-unit granularity)
    let g1 = GridSummary::new(&r.matrix, 1);
    for (block, paper) in [(4, (0.5, 0.174)), (6, (0.531, 0.256)), (8, (0.813, 0.339))] {
        let s = baselines::vanilla(22, block);
        let e = evaluate(&s, &g1, w);
        rows.push(eval_to_row(
            "Vanilla",
            &format!("block {block}"),
            None,
            &s,
            &g1,
            &e,
            Some(paper),
        ));
    }
    // --- Vanilla + Fill
    for (block, fill, paper) in [(4, 4, (0.938, 0.445)), (6, 6, (1.0, 0.62))] {
        let s = baselines::vanilla_fill(22, block, fill);
        let e = evaluate(&s, &g1, w);
        rows.push(eval_to_row(
            "Vanilla+Fill",
            &format!("block {block} fill {fill}"),
            None,
            &s,
            &g1,
            &e,
            Some(paper),
        ));
    }

    // --- RL rows (grid 2, like the paper's "Grid size 2")
    let qm7 = Dataset::Qm7 { seed: 5828 };
    let specs: Vec<(&str, &str, FillRule, f64, Option<(f64, f64)>)> = vec![
        ("LSTM+RL", "qm7_diag", FillRule::None, 0.6, Some((0.875, 0.438))),
        ("LSTM+RL", "qm7_diag", FillRule::None, 0.8, Some((0.938, 0.537))),
        ("LSTM+RL+Fill", "qm7_fill", FillRule::Fixed { size: 1 }, 0.8, Some((0.938, 0.455))),
        ("LSTM+RL+Fill", "qm7_fill", FillRule::Fixed { size: 2 }, 0.8, Some((0.969, 0.388))),
        ("LSTM+RL+Fill", "qm7_fill", FillRule::Fixed { size: 2 }, 0.9, Some((1.0, 0.521))),
        ("LSTM+RL+Fill", "qm7_fill", FillRule::Fixed { size: 3 }, 0.9, Some((1.0, 0.537))),
        ("LSTM+RL+Fill", "qm7_fill", FillRule::Fixed { size: 3 }, 0.8, Some((1.0, 0.455))),
        ("LSTM+RL+Fill", "qm7_fill", FillRule::Fixed { size: 3 }, 0.7, Some((0.969, 0.438))),
        ("BiLSTM+RL+Fill", "qm7_fill_bilstm", FillRule::Fixed { size: 2 }, 0.9, Some((1.0, 0.504))),
        ("BiLSTM+RL+Fill", "qm7_fill_bilstm", FillRule::Fixed { size: 3 }, 0.8, Some((1.0, 0.471))),
        ("LSTM+RL+Dynamic-fill", "qm7_dyn4", FillRule::Dynamic { grades: 4 }, 0.9, Some((1.0, 0.558))),
        ("LSTM+RL+Dynamic-fill", "qm7_dyn4", FillRule::Dynamic { grades: 4 }, 0.8, Some((1.0, 0.558))),
        ("LSTM+RL+Dynamic-fill", "qm7_dyn4", FillRule::Dynamic { grades: 4 }, 0.75, Some((1.0, 0.43))),
        ("LSTM+RL+Dynamic-fill", "qm7_dyn6", FillRule::Dynamic { grades: 6 }, 0.8, Some((1.0, 0.521))),
        ("LSTM+RL+Dynamic-fill", "qm7_dyn6", FillRule::Dynamic { grades: 6 }, 0.75, Some((0.969, 0.397))),
    ];
    for (method, controller, rule, a, paper) in specs {
        let (row, _) = rl_row(
            rt, method, qm7.clone(), 2, controller, rule, a, epochs, 5828, opts, paper,
        )?;
        rows.push(row);
    }

    // --- DP oracle reference (not in the paper; tightest diagonal-only)
    let g2 = GridSummary::new(&r.matrix, 2);
    if let Some(s) = baselines::oracle::optimal_diagonal(&g2) {
        let e = evaluate(&s, &g2, w);
        rows.push(eval_to_row("DP-oracle (diag only)", "grid 2", None, &s, &g2, &e, None));
    }

    print_rows(
        "Table II — QM7-5828 (22×22, original sparsity 0.868)",
        &rows,
    );
    println!("note: paper_C/paper_A are the corresponding rows of the paper's Table II.");
    Ok(())
}

// ---------------------------------------------------------------------------
// Table III — complexity comparison

pub fn table3(rt: Option<&Runtime>) -> Result<()> {
    // the complexity model only needs controller dimensions, so the
    // built-in configs serve when no artifacts manifest exists
    let manifest = match rt.and_then(|r| r.manifest().ok()) {
        Some(m) => m,
        None => Manifest::builtin(),
    };
    println!("\n=== Table III — computational complexity (QM7 configs) ===");
    println!(
        "{:<22} {:>6} {:>4} {:>4} {:>4}  {:<26} {:>10}",
        "method", "T_eff", "I", "H", "K", "complexity", "MACs/fwd"
    );
    for name in ["qm7_diag", "qm7_fill", "qm7_fill_bilstm", "qm7_dyn6"] {
        let entry = manifest.config(name)?;
        let c = complexity(entry);
        println!(
            "{:<22} {:>6} {:>4} {:>4} {:>4}  {:<26} {:>10}",
            c.method, c.t, c.i, c.h, c.k, c.formula, c.macs
        );
    }
    println!("paper: O(T(4IH+4H²+3H+HK)) with T=12/36, I=1, H=10, K=1 — same asymptotic family.");
    Ok(())
}

// ---------------------------------------------------------------------------
// Table IV — qh882 / qh1484 with LSTM+RL+Dynamic-fill

pub fn table4(rt: Option<&Runtime>, epochs: usize, opts: &RunnerOptions) -> Result<()> {
    let mut rows = Vec::new();
    let specs: Vec<(Dataset, &str, usize, f64, Option<(f64, f64)>)> = vec![
        (Dataset::Qh882 { seed: 882 }, "qh882_dyn4", 4, 0.7, Some((0.998, 0.196))),
        (Dataset::Qh882 { seed: 882 }, "qh882_dyn4", 4, 0.8, Some((0.998, 0.204))),
        (Dataset::Qh882 { seed: 882 }, "qh882_dyn6", 6, 0.7, Some((0.995, 0.2))),
        (Dataset::Qh882 { seed: 882 }, "qh882_dyn6", 6, 0.8, Some((1.0, 0.225))),
        (Dataset::Qh1484 { seed: 1484 }, "qh1484_dyn4", 4, 0.7, Some((0.992, 0.148))),
        (Dataset::Qh1484 { seed: 1484 }, "qh1484_dyn4", 4, 0.8, Some((0.999, 0.185))),
        (Dataset::Qh1484 { seed: 1484 }, "qh1484_dyn6", 6, 0.7, Some((0.993, 0.173))),
        (Dataset::Qh1484 { seed: 1484 }, "qh1484_dyn6", 6, 0.8, Some((1.0, 0.171))),
    ];
    for (ds, controller, grades, a, paper) in specs {
        let label = ds.label();
        let (row, _) = rl_row(
            rt,
            &format!("LSTM+RL+Dynamic ({label})"),
            ds,
            32,
            controller,
            FillRule::Dynamic { grades },
            a,
            epochs,
            7,
            opts,
            paper,
        )?;
        rows.push(row);
    }
    print_rows(
        "Table IV — qh882 (sparsity 0.995) and qh1484 (sparsity 0.997), grid 32",
        &rows,
    );
    println!("note: qh matrices are structure-matched synthetics (DESIGN.md §6); compare shapes, not decimals.");
    Ok(())
}

// ---------------------------------------------------------------------------
// Figures

/// Fig. 2 — coverage/area of hand-built schemes (complete-but-costly vs
/// infeasible cheaper ones).
pub fn figure2(out_dir: &Path) -> Result<()> {
    let m = load_matrix(&Dataset::Qm7 { seed: 5828 })?;
    let r = reorder(&m, Reordering::CuthillMckee);
    let g = GridSummary::new(&r.matrix, 2);
    let w = RewardWeights::new(0.8);
    let schemes = [
        ("left: one full block (complete, costly)", Scheme { diag_len: vec![g.n], fill_len: vec![] }),
        ("middle: two blocks (cheaper, incomplete)", Scheme { diag_len: vec![6, 5], fill_len: vec![0] }),
        ("right: unit blocks (cheapest, infeasible)", Scheme { diag_len: vec![1; g.n], fill_len: vec![0; g.n - 1] }),
    ];
    println!("\n=== Figure 2 — schedule schemes trade coverage vs area ===");
    std::fs::create_dir_all(out_dir)?;
    for (name, s) in &schemes {
        let e = evaluate(s, &g, w);
        println!("{name}: coverage {:.3}, area {:.3}", e.coverage_ratio, e.area_ratio);
        println!("{}", viz::ascii_scheme(&r.matrix, &g, s));
        let file = out_dir.join(format!(
            "fig2_{}.svg",
            name.split(':').next().unwrap_or("scheme")
        ));
        std::fs::write(&file, viz::svg_scheme(&r.matrix, &g, Some(s), name))?;
    }
    Ok(())
}

/// Fig. 7 — dataset spy plots.
pub fn figure7(out_dir: &Path) -> Result<()> {
    println!("\n=== Figure 7 — dataset visualizations ===");
    std::fs::create_dir_all(out_dir)?;
    for (name, ds) in [
        ("qm7_5828", Dataset::Qm7 { seed: 5828 }),
        ("qh882", Dataset::Qh882 { seed: 882 }),
        ("qh1484", Dataset::Qh1484 { seed: 1484 }),
    ] {
        let m = load_matrix(&ds)?;
        let r = reorder(&m, Reordering::CuthillMckee);
        println!(
            "{name}: {}x{}, nnz {}, sparsity {:.3}, bandwidth {} -> {} after CM",
            m.rows,
            m.cols,
            m.nnz(),
            m.sparsity(),
            r.bandwidth_before,
            r.bandwidth_after
        );
        println!("{}", viz::ascii_spy(&r.matrix, 44));
        let g = GridSummary::new(&r.matrix, if m.rows > 100 { 32 } else { 2 });
        std::fs::write(
            out_dir.join(format!("fig7_{name}.svg")),
            viz::svg_scheme(&r.matrix, &g, None, name),
        )?;
    }
    Ok(())
}

/// Figs. 8 / 10 / 12 — representative mapping-scheme visualizations from a
/// short training run per dataset.
#[allow(clippy::too_many_arguments)]
pub fn figure_schemes(
    rt: Option<&Runtime>,
    dataset: Dataset,
    grid: usize,
    controller: &str,
    grades: usize,
    epochs: usize,
    fig: &str,
    opts: &RunnerOptions,
) -> Result<()> {
    println!("\n=== Figure {fig} — representative mapping schemes ({}) ===", dataset.label());
    let out_dir = opts.out_root.as_path();
    std::fs::create_dir_all(out_dir)?;
    let mut count = 0;
    for (i, a) in [0.7, 0.75, 0.8, 0.9].iter().enumerate() {
        let (row, result) = rl_row(
            rt,
            "LSTM+RL+Dynamic",
            dataset.clone(),
            grid,
            controller,
            FillRule::Dynamic { grades },
            *a,
            epochs,
            100 + i as u64,
            opts,
            None,
        )?;
        let Some(best) = &result.best else { continue };
        count += 1;
        println!(
            "scheme {count} (a={a}): diag {:?} fill {:?}  C={:.3} A={:.3}",
            row.diag, row.fill, row.coverage, row.area
        );
        if result.workload.grid.dim <= 64 {
            println!(
                "{}",
                viz::ascii_scheme(&result.workload.reordered.matrix, &result.workload.grid, &best.scheme)
            );
        }
        std::fs::write(
            out_dir.join(format!("fig{fig}_scheme{count}_a{:02}.svg", (a * 100.0) as u32)),
            viz::svg_scheme(
                &result.workload.reordered.matrix,
                &result.workload.grid,
                Some(&best.scheme),
                &format!("{} a={a} C={:.3} A={:.3}", dataset.label(), row.coverage, row.area),
            ),
        )?;
    }
    anyhow::ensure!(count > 0, "no complete-coverage schemes found for figure {fig}");
    Ok(())
}

/// Figs. 9 / 11 / 13 — training curves (coverage, area, reward vs epoch).
#[allow(clippy::too_many_arguments)]
pub fn figure_curves(
    rt: Option<&Runtime>,
    dataset: Dataset,
    grid: usize,
    controller: &str,
    grades: usize,
    a: f64,
    epochs: usize,
    fig: &str,
    opts: &RunnerOptions,
) -> Result<()> {
    println!(
        "\n=== Figure {fig} — training curves ({}, grades {grades}, a={a}) ===",
        dataset.label()
    );
    let cfg = ExperimentConfig {
        name: format!("fig{fig}_{}", dataset.label()),
        dataset,
        grid,
        reordering: Reordering::CuthillMckee,
        controller: controller.to_string(),
        fill_rule: FillRule::Dynamic { grades },
        reward_a: a,
        lr: 0.015,
        ent_coef: 0.002,
        baseline_decay: 0.95,
        epochs,
        seed: 11,
        log_every: 1,
    };
    let result = run_experiment(rt, &cfg, opts)?;
    println!("{}", super::runner::curves_ascii(&result.history, 78, 16));
    println!(
        "best: {}",
        super::runner::describe_best(&result.best, &result.workload.grid)
    );
    println!(
        "full per-epoch CSV: {}",
        result.run_dir.join("metrics.csv").display()
    );
    Ok(())
}

/// Dispatch `reproduce --table N | --figure N`. `opts.out_root` is the run
/// root; figures land under `<out_root>/figures`. `opts.backend`/
/// `opts.workers` select and size the training backend (native needs no
/// runtime: `rt` may be `None`).
pub fn dispatch(
    rt: Option<&Runtime>,
    table: Option<usize>,
    figure: Option<usize>,
    epochs: Option<usize>,
    opts: &RunnerOptions,
) -> Result<()> {
    let figs: PathBuf = opts.out_root.join("figures");
    let fig_opts = RunnerOptions {
        out_root: figs.clone(),
        ..opts.clone()
    };
    match (table, figure) {
        (Some(2), None) => table2(rt, epochs.unwrap_or(4000), opts),
        (Some(3), None) => table3(rt),
        (Some(4), None) => table4(rt, epochs.unwrap_or(2500), opts),
        (None, Some(2)) => figure2(&figs),
        (None, Some(7)) => figure7(&figs),
        (None, Some(8)) => figure_schemes(
            rt, Dataset::Qm7 { seed: 5828 }, 2, "qm7_dyn6", 6, epochs.unwrap_or(3000), "8", &fig_opts,
        ),
        (None, Some(9)) => figure_curves(
            rt, Dataset::Qm7 { seed: 5828 }, 2, "qm7_dyn4", 4, 0.75, epochs.unwrap_or(4000), "9", &fig_opts,
        ),
        (None, Some(10)) => figure_schemes(
            rt, Dataset::Qh882 { seed: 882 }, 32, "qh882_dyn6", 6, epochs.unwrap_or(2000), "10", &fig_opts,
        ),
        (None, Some(11)) => figure_curves(
            rt, Dataset::Qh882 { seed: 882 }, 32, "qh882_dyn6", 6, 0.8, epochs.unwrap_or(2500), "11", &fig_opts,
        ),
        (None, Some(12)) => figure_schemes(
            rt, Dataset::Qh1484 { seed: 1484 }, 32, "qh1484_dyn6", 6, epochs.unwrap_or(2000), "12", &fig_opts,
        ),
        (None, Some(13)) => figure_curves(
            rt, Dataset::Qh1484 { seed: 1484 }, 32, "qh1484_dyn6", 6, 0.8, epochs.unwrap_or(2500), "13", &fig_opts,
        ),
        _ => anyhow::bail!(
            "pass exactly one of --table {{2,3,4}} or --figure {{2,7,8,9,10,11,12,13}}"
        ),
    }
}

/// Baseline comparison printout (GraphSAR/GraphR-style whole-matrix
/// partitions vs the diagonal+fill family) — §Related-Work ablation.
pub fn baselines_report(ds: &Dataset, grid: usize, coarse: usize) -> Result<()> {
    let m = load_matrix(ds)?;
    let r = reorder(&m, Reordering::CuthillMckee);
    let g = GridSummary::new(&r.matrix, grid);
    let w = RewardWeights::new(0.8);
    println!(
        "\n=== baselines on {} (grid {grid}, coarse tile {coarse}) ===",
        ds.label()
    );
    let sar = baselines::graphsar(&g, coarse);
    let e = evaluate_rects(&sar, &g, w);
    println!(
        "GraphSAR-like   blocks {:>5}  C {:.3}  A {:.3}",
        e.num_blocks, e.coverage_ratio, e.area_ratio
    );
    let gr = baselines::graphr(&g, coarse);
    let e = evaluate_rects(&gr, &g, w);
    println!(
        "GraphR-like     blocks {:>5}  C {:.3}  A {:.3}",
        e.num_blocks, e.coverage_ratio, e.area_ratio
    );
    if let Some(s) = baselines::oracle::optimal_diagonal(&g) {
        let e = evaluate(&s, &g, w);
        println!(
            "DP-oracle diag  blocks {:>5}  C {:.3}  A {:.3}",
            s.diag_len.len(),
            e.coverage_ratio,
            e.area_ratio
        );
    }
    for block in [2, 4, 8] {
        let s = baselines::vanilla(g.n, block);
        let e = evaluate(&s, &g, w);
        println!(
            "Vanilla b={block:<2}    blocks {:>5}  C {:.3}  A {:.3}",
            s.diag_len.len(),
            e.coverage_ratio,
            e.area_ratio
        );
    }
    // storage-fusion view (the paper's stated future work): crossbar cells
    // for the mapped blocks + COO bytes for the uncovered remainder
    let sc = crate::graph::storage::storage_cost(&r.matrix, 4);
    println!(
        "storage: dense {} B, COO {} B, CSR {} B",
        sc.dense_bytes, sc.coo_bytes, sc.csr_bytes
    );
    for block in [1usize, 2, 4] {
        let s = baselines::vanilla(g.n, block);
        let h = crate::graph::storage::hybrid_cost(&s, &g, 4);
        println!(
            "hybrid  b={block:<2}    cells {:>8}  spill_nnz {:>6}  spill_coo {:>8} B",
            h.crossbar_cells, h.spilled_nnz, h.spill_coo_bytes
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure2_runs_without_runtime() {
        let dir = std::env::temp_dir().join("autogmap_fig2_test");
        figure2(&dir).unwrap();
        assert!(dir.join("fig2_left.svg").exists());
    }

    #[test]
    fn baselines_report_runs() {
        baselines_report(&Dataset::Qm7 { seed: 5828 }, 1, 8).unwrap();
    }
}
